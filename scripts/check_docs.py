#!/usr/bin/env python
"""Docs CI gate: markdown links must resolve, README snippets must run.

Two checks, so the documentation set cannot rot silently:

1. **Links** — every repo-relative markdown link in ``README.md`` and
   ``docs/*.md`` must point at a file or directory that exists (external
   ``http(s)``/``mailto`` links and pure ``#anchor`` links are skipped;
   ``path#anchor`` links are checked for the path part).
2. **Snippets** — every fenced ```` ```python ```` block in ``README.md`` is
   executed (with ``src`` on ``sys.path``), so the quickstart the README
   advertises keeps working.  Keep illustrative-but-unrunnable README blocks
   in other languages (``sql``, ``text``, ``bash``).

Usage: ``python scripts/check_docs.py [--no-snippets]``.  Exits non-zero on
the first class of failure, printing every offending link.  Run in CI by the
``docs`` job in ``.github/workflows/ci.yml``.
"""
from __future__ import annotations

import argparse
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]

# [text](target) — excluding images' srcset edge cases; good enough for our docs
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_FENCE = re.compile(r"^```python\s*$(.*?)^```\s*$", re.MULTILINE | re.DOTALL)
_SKIP = ("http://", "https://", "mailto:")


def doc_files() -> list[Path]:
    files = [REPO / "README.md"]
    files += sorted((REPO / "docs").glob("*.md"))
    return [f for f in files if f.exists()]


def check_links(files: list[Path]) -> list[str]:
    errors = []
    for md in files:
        text = md.read_text(encoding="utf-8")
        for target in _LINK.findall(text):
            if target.startswith(_SKIP) or target.startswith("#"):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            resolved = (md.parent / path).resolve()
            if not resolved.exists():
                errors.append(
                    f"{md.relative_to(REPO)}: broken link -> {target}"
                )
    return errors


def run_snippets(readme: Path) -> int:
    sys.path.insert(0, str(REPO / "src"))
    blocks = _FENCE.findall(readme.read_text(encoding="utf-8"))
    # one namespace shared across blocks, so a later block may build on an
    # earlier one (the normal multi-block docs pattern)
    ns: dict = {}
    for i, code in enumerate(blocks):
        print(f"[check-docs] running README python block {i + 1}/{len(blocks)}")
        exec(compile(code, f"<README block {i + 1}>", "exec"), ns)
    return len(blocks)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--no-snippets", action="store_true",
                    help="only check links (fast, no repro import)")
    args = ap.parse_args()

    files = doc_files()
    print(f"[check-docs] checking links in {len(files)} files: "
          + ", ".join(str(f.relative_to(REPO)) for f in files))
    errors = check_links(files)
    for e in errors:
        print(f"[check-docs] ERROR {e}", file=sys.stderr)
    if errors:
        raise SystemExit(1)
    print("[check-docs] all links resolve")

    if not args.no_snippets:
        n = run_snippets(REPO / "README.md")
        print(f"[check-docs] {n} README snippet(s) ran clean")


if __name__ == "__main__":
    main()
