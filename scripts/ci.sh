#!/usr/bin/env bash
# Tier-1 CI entry point: install dev-only deps, run the full suite.
# Usage: scripts/ci.sh [extra pytest args...]
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pip install -q -r requirements-dev.txt || \
  echo "WARN: dev deps install failed (offline?); property tests will skip" >&2

PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q "$@"

# Oracle execution-layer smoke benchmark: fails loudly if the batched
# labelling path regresses (see benchmarks/bench_oracle.py).
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m benchmarks.run \
  --only oracle --smoke
