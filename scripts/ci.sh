#!/usr/bin/env bash
# Tier-1 CI entry point: lint, run the test suite, smoke the benchmark gates.
#
# Default is the fast tier: tests marked `slow` or `pallas` (registered in
# pyproject.toml) are deselected.  CI_FULL=1 opts into everything.
# Usage: scripts/ci.sh [extra pytest args...]
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pip install -q -r requirements-dev.txt || \
  echo "WARN: dev deps install failed (offline?); property tests will skip" >&2

# Lint gate (ruff is a dev dep; skip with a warning when the install above
# could not fetch it, e.g. in offline containers).
if command -v ruff >/dev/null 2>&1; then
  ruff check .
else
  echo "WARN: ruff unavailable; skipping lint gate" >&2
fi

if [[ "${CI_FULL:-0}" == "1" ]]; then
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m pytest -x -q "$@"
else
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m pytest -x -q -m "not slow and not pallas" "$@"
fi

# Oracle execution-layer smoke benchmark: fails loudly if the batched
# labelling path regresses.  The async service's timing-sensitive >=2x
# coalescing gate (bench_service) runs once, in the workflow's dedicated
# smoke-bench job, not on every matrix leg.
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m benchmarks.run \
  --only oracle --smoke
