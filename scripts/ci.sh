#!/usr/bin/env bash
# Tier-1 CI entry point: lint, run the test suite, smoke the benchmark gates.
#
# Default is the fast tier: tests marked `slow` or `pallas` (registered in
# pyproject.toml) are deselected.  CI_FULL=1 opts into everything.
# Usage: scripts/ci.sh [extra pytest args...]
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pip install -q -r requirements-dev.txt || \
  echo "WARN: dev deps install failed (offline?); property tests will skip" >&2

# Lint gate (ruff is a dev dep; skip with a warning when the install above
# could not fetch it, e.g. in offline containers).
if command -v ruff >/dev/null 2>&1; then
  ruff check .
else
  echo "WARN: ruff unavailable; skipping lint gate" >&2
fi

# Coverage is a dev dep like ruff: measure when pytest-cov is importable,
# warn and run plain otherwise (offline containers).  The XML feeds the
# scripts/check_coverage.py soft floor below and the CI artifact upload.
COV_ARGS=()
if python -c "import pytest_cov" >/dev/null 2>&1; then
  COV_ARGS=(--cov=src/repro/core --cov-report=xml:coverage.xml --cov-report=)
else
  echo "WARN: pytest-cov unavailable; skipping coverage measurement" >&2
fi

if [[ "${CI_FULL:-0}" == "1" ]]; then
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m pytest -x -q "${COV_ARGS[@]}" "$@"
else
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m pytest -x -q -m "not slow and not pallas" "${COV_ARGS[@]}" "$@"
fi

# Soft floor on statistical-core line coverage: catches a new core module
# landing untested or a refactor orphaning a test file.  The fast tier
# deselects slow/pallas tests, so it uses a lower floor than the full run.
if [[ -f coverage.xml && ${#COV_ARGS[@]} -gt 0 ]]; then
  python scripts/check_coverage.py coverage.xml --floor "${COV_FLOOR:-60}"
fi

# Oracle execution-layer smoke benchmark: fails loudly if the batched
# labelling path regresses.  The async service's timing-sensitive >=2x
# coalescing gate (bench_service) runs once, in the workflow's dedicated
# smoke-bench job, not on every matrix leg.
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m benchmarks.run \
  --only oracle --smoke
