#!/usr/bin/env python
"""Soft line-coverage floor over the estimator core.

Reads a Cobertura ``coverage.xml`` (what ``pytest --cov --cov-report=xml``
emits), restricts it to files under ``--prefix`` (default: the statistical
core, ``repro/core/``), and fails when aggregate line coverage drops below
``--floor``.

This is a *soft* floor, not a target: it sits well under the suite's
current coverage and exists to catch a structural regression — a new core
module landing with no tests, or a refactor orphaning a test file — rather
than to police individual lines.  Raise the floor as the suite grows; never
lower it to make a PR pass.

``--warn-only`` reports without failing (used while bootstrapping a new
environment).  Usage::

    python scripts/check_coverage.py coverage.xml [--floor 60]
        [--prefix repro/core/] [--warn-only]
"""
from __future__ import annotations

import argparse
import sys
import xml.etree.ElementTree as ET


def collect(root: ET.Element, prefix: str) -> dict[str, tuple[int, int]]:
    """filename -> (covered, total) statement lines, for files under prefix."""
    files: dict[str, tuple[int, int]] = {}
    for cls in root.iter("class"):
        fn = cls.get("filename", "")
        if prefix not in fn.replace("\\", "/"):
            continue
        lines = cls.findall("./lines/line")
        covered = sum(1 for ln in lines if int(ln.get("hits", "0")) > 0)
        prev_c, prev_t = files.get(fn, (0, 0))
        files[fn] = (prev_c + covered, prev_t + len(lines))
    return files


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("xml", help="Cobertura coverage.xml from pytest --cov")
    ap.add_argument("--prefix", default="repro/core/",
                    help="path fragment selecting the files under the floor")
    ap.add_argument("--floor", type=float, default=60.0,
                    help="minimum aggregate line coverage percent")
    ap.add_argument("--warn-only", action="store_true",
                    help="report but always exit 0")
    args = ap.parse_args()

    files = collect(ET.parse(args.xml).getroot(), args.prefix)
    if not files:
        print(f"[coverage] no files matching {args.prefix!r} in {args.xml} "
              f"— the coverage run never measured the core", file=sys.stderr)
        raise SystemExit(2)

    for fn in sorted(files):
        c, t = files[fn]
        pct = 100.0 * c / t if t else 100.0
        print(f"[coverage]   {fn}: {pct:.1f}% ({c}/{t})")
    covered = sum(c for c, _ in files.values())
    total = sum(t for _, t in files.values())
    pct = 100.0 * covered / total if total else 100.0
    ok = pct >= args.floor
    print(f"[coverage] {args.prefix} line coverage {pct:.1f}% "
          f"({covered}/{total}) vs floor {args.floor:.1f}% "
          f"-> {'ok' if ok else 'BELOW FLOOR'}")
    if not ok and not args.warn_only:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
