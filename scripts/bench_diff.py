#!/usr/bin/env python
"""Diff a ``benchmarks/run.py --json`` report against checked-in baselines.

Starts the perf trajectory the ROADMAP asks for: ``benchmarks/baselines/``
holds one JSON per benchmark family (``BENCH_kernels.json``,
``BENCH_latency.json``, recorded with ``--smoke`` on the CI CPU profile) and
this script compares a fresh run row-by-row:

* a row slower than ``--threshold`` x its baseline is a **regression**;
* a row in the baseline but missing from the run is a **regression** (a
  renamed/removed benchmark must update its baseline in the same PR);
* new rows are reported informationally.

Exit code is non-zero on regressions unless ``--warn-only`` — which is how
CI runs it on CPU, where the Pallas kernels execute in interpret mode and
wall times are noise-dominated; the diff output still lands in the job log
and the JSON artifact, so drift is visible before a TPU run gates on it.

Certification: the run report records which backend produced the timings
(``benchmarks/run.py`` emits ``backend``).  When that backend is ``cpu`` or
``unknown`` — interpret-mode numbers — every diff line carries an explicit
``uncertified: compiled-only gate`` label, so a green CPU diff can never be
read as a certified perf result.  ``--require-compiled`` turns the label
into a hard failure: the diff exits non-zero (even under ``--warn-only``)
unless the results came from a compiled backend — this is the flag the
eventual TPU perf job sets so only compiled runs gate merges.

``--require-rows name1,name2`` declares rows the run must contain regardless
of any baseline — the hook CI uses for coverage-style rows (the tracker
overhead and admission-saturation rows): a missing required row is a hard
failure even under ``--warn-only``, because it means the gate that row
carries never executed.

Usage::

    python scripts/bench_diff.py RESULTS.json BASELINE.json [BASELINE2.json ...]
        [--threshold 1.5] [--warn-only] [--require-compiled]
        [--require-rows name1,name2,...]
"""
from __future__ import annotations

import argparse
import json
import sys

# backends whose timings certify a perf gate; anything else (cpu interpret
# mode, or a report too old to carry the field) is labelled uncertified
COMPILED_BACKENDS = ("tpu", "gpu", "cuda", "rocm")


def certified_backend(report: dict) -> bool:
    return str(report.get("backend", "unknown")).lower() in COMPILED_BACKENDS


def _rows(report: dict, only_modules=None) -> dict:
    out = {}
    for key, mod in report.get("modules", {}).items():
        if only_modules is not None and key not in only_modules:
            continue
        for r in mod.get("rows", []):
            out[r["name"]] = r
    return out


def diff(current: dict, baseline: dict, threshold: float):
    # restrict the run to the module families the baseline covers, so one
    # combined run can be diffed against several per-family baselines
    fams = set(baseline.get("modules", {}))
    cur, base = _rows(current, fams), _rows(baseline)
    regressions, notes = [], []
    for name, b in base.items():
        c = cur.get(name)
        if c is None:
            regressions.append(f"{name}: present in baseline but missing from run")
            continue
        b_us, c_us = b["us_per_call"], c["us_per_call"]
        ratio = c_us / b_us if b_us > 0 else float("inf")
        line = f"{name}: {c_us:.1f}us vs baseline {b_us:.1f}us ({ratio:.2f}x)"
        if ratio > threshold:
            regressions.append(line)
        else:
            notes.append(line)
    for name in cur.keys() - base.keys():
        notes.append(f"{name}: new row (no baseline)")
    return regressions, notes


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("results", help="fresh benchmarks/run.py --json output")
    ap.add_argument("baselines", nargs="+", help="baseline JSON file(s)")
    ap.add_argument("--threshold", type=float, default=1.5,
                    help="slowdown ratio that counts as a regression")
    ap.add_argument("--warn-only", action="store_true",
                    help="report regressions but exit 0 (CPU/interpret CI)")
    ap.add_argument("--require-compiled", action="store_true",
                    help="fail unless the results were produced by a "
                         "compiled backend (tpu/gpu) — the certified perf "
                         "gate; overrides --warn-only")
    ap.add_argument("--require-rows", default="",
                    help="comma-separated row names the run must contain; "
                         "a missing row fails even under --warn-only")
    args = ap.parse_args()

    with open(args.results) as f:
        current = json.load(f)
    backend = str(current.get("backend", "unknown"))
    certified = certified_backend(current)
    tag = "" if certified else " [uncertified: compiled-only gate]"
    if not certified:
        print(f"[bench-diff] backend={backend}: interpret-mode timings — "
              f"every row below is uncertified (compiled-only gate)")
    all_regressions = []
    for path in args.baselines:
        with open(path) as f:
            baseline = json.load(f)
        regressions, notes = diff(current, baseline, args.threshold)
        print(f"[bench-diff] vs {path}: {len(regressions)} regression(s), "
              f"{len(notes)} row(s) in range{tag}")
        for line in notes:
            print(f"[bench-diff]   ok   {line}{tag}")
        for line in regressions:
            print(f"[bench-diff]   SLOW {line}{tag}", file=sys.stderr)
        all_regressions += regressions
    required = [n for n in args.require_rows.split(",") if n]
    if required:
        present = _rows(current)
        missing = [n for n in required if n not in present]
        for n in required:
            if n in present:
                print(f"[bench-diff]   ok   {n}: required row present")
        for n in missing:
            print(f"[bench-diff]   MISSING required row {n}: its gate never "
                  f"ran", file=sys.stderr)
        if missing:
            raise SystemExit(3)
    if args.require_compiled and not certified:
        print(f"[bench-diff] FAIL: --require-compiled but results backend "
              f"is {backend!r} (need one of {', '.join(COMPILED_BACKENDS)})",
              file=sys.stderr)
        raise SystemExit(2)
    if all_regressions and not args.warn_only:
        raise SystemExit(1)
    if all_regressions:
        print(f"[bench-diff] {len(all_regressions)} regression(s) "
              "(warn-only mode, not failing)")


if __name__ == "__main__":
    main()
