"""Oracle execution-layer benchmark: labelling throughput and dedup ratio of
the vectorized flat-index cache vs. the legacy per-tuple dict cache, across
request batch sizes.

The request stream models BAS traffic: many small-to-large batches drawn with
replacement from a skewed pool (pilot resampling + top-up rounds revisit the
same high-weight tuples), so cache hits and within-batch duplicates are
common — exactly the regime the batched layer is built for.

Rows: ``oracle_{cache}_b{batch}`` with labels/sec and the achieved dedup
ratio.  Run via ``python -m benchmarks.run --only oracle`` (``--smoke`` for
the reduced CI profile).

CI gate: every test-matrix leg runs this module through ``scripts/ci.sh``
(and the smoke-bench job uploads its JSON rows); the in-module assertion —
the vectorized cache must never label more tuples than the legacy dict cache
— plus any runtime error fails CI, so regressions in the oracle hot path are
visible.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.oracle import Oracle

from .common import row


class _LegacyDictOracle(Oracle):
    """The pre-batching cache: tuple-keyed Python dict, per-row round trips.
    Kept here (not in the library) purely as the benchmark baseline."""

    def __init__(self, n: int):
        super().__init__()
        self._dict: dict = {}
        self.n = n

    def _label(self, idx: np.ndarray) -> np.ndarray:
        return (idx.sum(axis=1) % 2).astype(np.float64)

    def label(self, idx: np.ndarray) -> np.ndarray:  # legacy semantics
        idx = np.asarray(idx)
        if idx.ndim == 1:
            idx = idx[:, None]
        self.requests += idx.shape[0]
        keys = [tuple(int(v) for v in r) for r in idx]
        missing = [i for i, k in enumerate(keys) if k not in self._dict]
        if missing:
            labels = self._label(idx[missing])
            for j, i in enumerate(missing):
                self._dict[keys[i]] = float(labels[j])
            self.calls += len(missing)
        return np.array([self._dict[k] for k in keys], np.float64)


class _VectorOracle(Oracle):
    def _label(self, idx: np.ndarray) -> np.ndarray:
        return (idx.sum(axis=1) % 2).astype(np.float64)


def _request_stream(n_side: int, n_requests: int, batch: int, rng):
    """Skewed (quadratic-tilt) tuple draws with replacement: repeated batches
    revisit hot tuples, like pilot + main-stage BAS sampling."""
    hot = (rng.random((n_requests, batch, 2)) ** 6 * n_side).astype(np.int64)
    return list(hot)


def run(fast: bool = True, smoke: bool = False):
    rows = []
    rng = np.random.default_rng(0)
    if smoke:                        # CI profile: smallest signal-bearing run
        n_side, n_requests, batches = 1000, 12, (256, 1024)
    elif fast:
        n_side, n_requests, batches = 2000, 24, (256, 2048)
    else:
        n_side, n_requests, batches = 20000, 64, (64, 512, 4096)
    for batch in batches:
        stream = _request_stream(n_side, n_requests, batch, rng)
        total = n_requests * batch

        legacy = _LegacyDictOracle(n_side)
        t0 = time.perf_counter()
        for req in stream:
            legacy.label(req)
        dt_legacy = time.perf_counter() - t0

        vec = _VectorOracle()
        vec.bind_sizes((n_side, n_side))
        t0 = time.perf_counter()
        for req in stream:
            vec.label(req)
        dt_vec = time.perf_counter() - t0

        assert vec.calls <= legacy.calls  # vectorized dedupes within-batch too
        rows.append(row(
            f"oracle_dict_b{batch}", dt_legacy / total,
            f"labels_per_s={total / max(dt_legacy, 1e-12):.0f}",
        ))
        rows.append(row(
            f"oracle_vec_b{batch}", dt_vec / total,
            f"labels_per_s={total / max(dt_vec, 1e-12):.0f};"
            f"dedup={vec.dedup_ratio:.3f};speedup={dt_legacy / max(dt_vec, 1e-12):.1f}x",
        ))
    return rows
