"""Fig. 14 / Appendix A: CPU-phase latency decomposition of BAS (similarity,
stratification, pilot, allocation, execution, resampling CI) — the speedup of
the fused single-sweep stratification vs the paper's sort and vs the retired
two-pass kernel schedule — and the dense-vs-streaming crossover sweep that
calibrates the memory-aware dispatcher (``repro.core.dispatch``).

Run via ``python -m benchmarks.run --only latency`` (``--full`` for
paper-scale table sizes).  CI diffs the ``--json`` output against
``benchmarks/baselines/BENCH_latency.json`` warn-only (see
``scripts/bench_diff.py``)."""
from __future__ import annotations

import time


from repro.core import Agg, Query, choose_path, dense_weight_bytes, run_bas
from repro.core.bas_streaming import run_bas_streaming
from repro.core.similarity import pair_weights
from repro.core.stratify import stratify_dense, stratify_streaming
from repro.core.types import BASConfig
from repro.data import make_clustered_tables

from .common import row


def run(fast: bool = True, smoke: bool = False):
    rows = []
    n = 300 if smoke else 600 if fast else 2000
    ds = make_clustered_tables(n, n, n_entities=n, noise=0.4, seed=23)
    q = Query(spec=ds.spec(), agg=Agg.COUNT, oracle=ds.oracle(),
              budget=max(n * n // 40, 2000))
    res = run_bas(q, seed=0)
    t = res.telemetry.timings
    total = t["total_s"]
    for phase in ("similarity_s", "stratify_s", "pilot_s", "allocate_s",
                  "execute_s", "ci_s"):
        rows.append(row(f"fig14_{phase[:-2]}", t[phase],
                        f"{t[phase] / total:.3f}"))
    rows.append(row("fig14_total", total, f"{total:.3f}s"))

    # sort-based (paper) vs two-pass kernel vs fused single-sweep
    # stratification at scale
    w = pair_weights(ds.emb1, ds.emb2).reshape(-1)
    cfg = BASConfig()
    t0 = time.perf_counter()
    stratify_dense(w, 0.2, q.budget, cfg)
    dt_sort = time.perf_counter() - t0
    t0 = time.perf_counter()
    two = stratify_streaming(ds.emb1, ds.emb2, 0.2, q.budget, cfg,
                             use_kernel=True, use_sweep=False)
    dt_two = time.perf_counter() - t0
    t0 = time.perf_counter()
    one = stratify_streaming(ds.emb1, ds.emb2, 0.2, q.budget, cfg,
                             use_kernel=True, use_sweep=True)
    dt_sweep = time.perf_counter() - t0
    assert (one.order == two.order).all(), "sweep strata diverged from two-pass"
    rows.append(row("fig14_stratify_sort", dt_sort, f"{dt_sort*1e3:.1f}ms"))
    rows.append(row("fig14_stratify_twopass_kernel", dt_two,
                    f"speedup_vs_sort_x={dt_sort / max(dt_two, 1e-9):.2f}"))
    rows.append(row("fig14_stratify_sweep_kernel", dt_sweep,
                    f"sweep_vs_twopass_x={dt_two / max(dt_sweep, 1e-9):.2f}"))
    rows.extend(walk_setup_rows(fast, smoke))
    rows.extend(crossover_sweep(fast, smoke))
    return rows


def walk_setup_rows(fast: bool = True, smoke: bool = False):
    """Walk-setup latency (per-edge row sums + chain total weight for the
    WWJ sampler), separated from sampling.

    ``walk_setup_twopass`` is the retired schedule: two standalone f64
    passes over the cross product after stratification.  The fused sweep
    emits the same statistics inline (one-pass chain statistics, see
    docs/kernels.md), so a cold fused query's walk setup is just a read of
    the sweep output (``walk_setup_fused_cold``, measured end-to-end inside
    a streaming query) and a warm-index query hydrates them from the
    artifact with ZERO passes (``walk_setup_warm_index``, gated >= 5x
    faster than the two-pass recomputation)."""
    import numpy as np

    from repro.core.index import build_index
    from repro.core.similarity import chain_total_weight, edge_row_sums

    rows = []
    n = 300 if smoke else 600 if fast else 2000
    ds = make_clustered_tables(n, n, n_entities=n, noise=0.4, seed=23)
    embs = [np.asarray(ds.emb1, np.float32), np.asarray(ds.emb2, np.float32)]

    t0 = time.perf_counter()
    rs_ref = edge_row_sums(embs)
    total_ref = chain_total_weight(embs)
    dt_two = time.perf_counter() - t0

    # cold fused query: walk setup reads the statistics the stratification
    # sweep already emitted — timed end-to-end by the streaming pipeline
    res = run_bas_streaming(
        Query(spec=ds.spec(), agg=Agg.COUNT, oracle=ds.oracle(),
              budget=max(n * n // 40, 2000)), seed=0)
    dt_cold = res.telemetry.timings["walk_setup_s"]

    art = build_index(embs, n_bins=512)   # one cold sweep, not timed here
    t0 = time.perf_counter()
    info = art.sweep_info()
    rs, total = info.row_sums, info.total_weight
    dt_warm = time.perf_counter() - t0
    assert rs is not None and total is not None
    np.testing.assert_allclose(rs[0], rs_ref[0], rtol=1e-6)
    assert abs(total - total_ref) <= 1e-6 * total_ref
    warm_x = dt_two / max(dt_warm, 1e-9)
    cold_x = dt_two / max(dt_cold, 1e-9)
    assert warm_x >= 5.0, (
        f"warm-index walk setup only {warm_x:.1f}x vs two-pass recompute"
    )
    rows.append(row("walk_setup_twopass", dt_two,
                    "edge_row_sums+chain_total_weight"))
    rows.append(row("walk_setup_fused_cold", dt_cold,
                    f"twopass_over_fused_x={cold_x:.1f}"))
    rows.append(row("walk_setup_warm_index", dt_warm,
                    f"twopass_over_warm_x={warm_x:.1f}"))
    rows.append(row("fig14_walk_setup", dt_cold,
                    "streaming-query walk-setup phase"))
    return rows


def crossover_sweep(fast: bool = True, smoke: bool = False):
    """Dense vs streaming end-to-end latency across problem sizes.

    Emits one dense and one streaming row per size plus the dispatcher's
    choice under the default cap, so ``BASConfig.max_dense_weight_bytes``
    can be tuned from data instead of guesswork.  The streaming rows run
    the fused single-sweep stratification (the default)."""
    rows = []
    sizes = ([150, 300] if smoke else [150, 300, 600] if fast
             else [300, 600, 1200, 2400])
    for n in sizes:
        ds = make_clustered_tables(n, n, n_entities=max(n, 64), noise=0.4,
                                   seed=29)
        budget = max(n * n // 40, 2000)
        spec = ds.spec()
        t0 = time.perf_counter()
        run_bas(Query(spec=spec, agg=Agg.COUNT, oracle=ds.oracle(),
                      budget=budget), seed=0)
        dt_dense = time.perf_counter() - t0
        t0 = time.perf_counter()
        run_bas_streaming(Query(spec=spec, agg=Agg.COUNT, oracle=ds.oracle(),
                                budget=budget), seed=0)
        dt_stream = time.perf_counter() - t0
        mb = dense_weight_bytes(spec) / 2**20
        rows.append(row(f"crossover_dense_n{n}", dt_dense,
                        f"flat_weights_mb={mb:.1f}"))
        rows.append(row(
            f"crossover_streaming_n{n}", dt_stream,
            f"dense_over_streaming_x={dt_dense / max(dt_stream, 1e-9):.2f},"
            f"auto_path={choose_path(spec)}",
        ))
    return rows
