"""Fig. 9: join-order optimisation — plan costs (true-cardinality execution
cost) for plans chosen with BAS vs UNIFORM vs WWJ cardinality estimates, and
the worst plan as the regret reference.

Run via ``python -m benchmarks.run --only planner`` (``--full`` for
paper-scale repetition counts).  Reporting only — no CI gate."""
from __future__ import annotations

import time

import numpy as np

from repro.core import (
    bas_cardinality_provider,
    dp_chain_plan,
    plan_cost_under_truth,
    uniform_cardinality_provider,
)
from repro.core.oracle import PairChainOracle
from repro.data import make_chain_dataset

from .common import row


def _true_card(ds):
    def card(lo, hi):
        prod = None
        for e in range(lo, hi):
            m = ds.edge_truth[e].astype(np.float64)
            prod = m if prod is None else prod @ m
        return float(prod.sum()) if prod is not None else 0.0

    return card


def _all_plans(lo, hi):
    from repro.core.planner import Plan

    if lo == hi:
        yield Plan(lo, hi)
        return
    for mid in range(lo, hi):
        for l in _all_plans(lo, mid):
            for r in _all_plans(mid + 1, hi):
                yield Plan(lo, hi, l, r)


def run(fast: bool = True):
    rows = []
    # skewed 4-way chain: one edge is dense, so order matters a lot
    ds = make_chain_dataset([80, 12, 70, 15], d=24, n_entities=10, noise=0.35, seed=9)
    sizes = [e.shape[0] for e in ds.embeddings]
    tc = _true_card(ds)

    def oracle_factory(lo, hi):
        return PairChainOracle(ds.edge_truth[lo:hi])

    t0 = time.perf_counter()
    card_bas = bas_cardinality_provider(ds.spec(), oracle_factory, 600, seed=0)
    plan_bas = dp_chain_plan(4, sizes, card_bas)
    dt_bas = time.perf_counter() - t0
    t0 = time.perf_counter()
    card_uni = uniform_cardinality_provider(ds.spec(), oracle_factory, 600, seed=0)
    plan_uni = dp_chain_plan(4, sizes, card_uni)
    dt_uni = time.perf_counter() - t0

    cost_bas = plan_cost_under_truth(plan_bas, sizes, tc)
    cost_uni = plan_cost_under_truth(plan_uni, sizes, tc)
    all_costs = [plan_cost_under_truth(p, sizes, tc) for p in _all_plans(0, 3)]
    best, worst = min(all_costs), max(all_costs)
    rows.append(row("fig9_cost_bas_plan", dt_bas, f"{cost_bas:.0f}"))
    rows.append(row("fig9_cost_uniform_plan", dt_uni, f"{cost_uni:.0f}"))
    rows.append(row("fig9_cost_optimal_plan", 0.0, f"{best:.0f}"))
    rows.append(row("fig9_cost_worst_plan", 0.0, f"{worst:.0f}"))
    rows.append(row("fig9_bas_regret_x", 0.0, f"{cost_bas / best:.2f}"))
    rows.append(row("fig9_uniform_regret_x", 0.0, f"{cost_uni / best:.2f}"))
    rows.append(row("fig9_bas_order", 0.0, f"\"{plan_bas.order_str()}\""))
    return rows
