"""Fig. 10: adaptive allocation vs fixed blocking ratios.  Reported as
relative RMSE *improvement over WWJ* for: adaptive BAS, the best fixed ratio
(approx optimal), and the worst fixed ratio.

Run via ``python -m benchmarks.run --only allocation`` (``--full`` for
paper-scale repetition counts).  Reporting only — no CI gate."""
from __future__ import annotations

import numpy as np

from repro.core import Agg, BASConfig, Query, run_bas, run_wwj
from repro.core.allocate import Allocation
from repro.data import make_syn_scores

from .common import rel_rmse, repeat_method, row, truth_of


def _fixed_ratio_bas(q, seed, weights, ratio, cfg):
    """BAS with a *fixed* blocking ratio: block the top-`ratio` share of the
    max blocking regime regardless of pilot variance (ablation arm)."""
    from repro.core import bas as bas_mod
    from repro.core import allocate as alloc_mod

    orig = alloc_mod.argmin_beta

    def forced(sigma2, weight_sums, sizes, b2, exact_max_k=16):
        k = len(sigma2) - 1
        cost, beta = 0, []
        for i in range(1, k + 1):
            if cost + sizes[i] <= ratio * b2:
                beta.append(i)
                cost += int(sizes[i])
        mask = np.zeros(k + 1, bool)
        mask[beta] = True
        return Allocation(
            beta=np.array(beta, np.int64),
            n_per_stratum=alloc_mod.budget_assign(b2, weight_sums, sizes, mask),
            est_mse=float("nan"),
        )

    alloc_mod.argmin_beta = forced
    bas_mod.alloc_mod.argmin_beta = forced
    try:
        return bas_mod.run_bas(q, cfg, seed=seed, weights=weights)
    finally:
        alloc_mod.argmin_beta = orig
        bas_mod.alloc_mod.argmin_beta = orig


def run(fast: bool = True):
    n_rep = 12 if fast else 100
    rows = []
    ds = make_syn_scores(350, 350, selectivity=4e-3, fnr=0.1, fpr=0.25, seed=3)
    w = ds.weights_override
    truth = truth_of(ds, Agg.COUNT)
    budget = 6000
    cfg = BASConfig(alpha=0.5)
    mk = lambda: Query(spec=ds.spec(), agg=Agg.COUNT, oracle=ds.oracle(), budget=budget)  # noqa: E731

    ests_w, _, dt_w = repeat_method(mk, lambda q, s: run_wwj(q, seed=s, weights=w), n_rep)
    rmse_wwj = rel_rmse(ests_w, truth)
    rows.append(row("fig10_wwj_rmse", dt_w, f"{rmse_wwj:.4f}"))

    ests_a, _, dt_a = repeat_method(
        mk, lambda q, s: run_bas(q, cfg, seed=s, weights=w), n_rep
    )
    rmse_adapt = rel_rmse(ests_a, truth)
    improv_adapt = 1.0 - rmse_adapt / rmse_wwj
    rows.append(row("fig10_bas_adaptive_improvement", dt_a, f"{improv_adapt:.3f}"))

    fixed = {}
    for ratio in (0.1, 0.2, 0.3, 0.4, 0.5):
        ests, _, dt = repeat_method(
            mk, lambda q, s: _fixed_ratio_bas(q, s, w, ratio, cfg), n_rep
        )
        fixed[ratio] = rel_rmse(ests, truth)
        rows.append(row(f"fig10_bas_fixed{int(ratio*100)}_improvement", dt,
                        f"{1.0 - fixed[ratio] / rmse_wwj:.3f}"))
    best = 1.0 - min(fixed.values()) / rmse_wwj
    worst = 1.0 - max(fixed.values()) / rmse_wwj
    rows.append(row("fig10_gap_to_optimal", 0.0, f"{best - improv_adapt:.3f}"))
    rows.append(row("fig10_worst_fixed_improvement", 0.0, f"{worst:.3f}"))
    return rows
