"""Persistent stratification index: warm-query speedup + delta maintenance
cost (``core.index`` / ``checkpoint.index_io``).

Rows:

* ``index_query_cold`` — full streaming stratification (the fused sweep +
  threshold + collection), what every query paid before the index existed;
* ``index_query_warm`` — the same stratification hydrating a resident
  :class:`~repro.core.index.IndexArtifact`.  **Gate** (asserted): warm must
  be >= 5x faster than cold — the whole point of build-once/query-many;
* ``index_load_mmap`` — save + mmap-load + hydrate from disk (the serving
  cold-start path: file-open cost, not a table read);
* ``index_append_delta`` — :func:`~repro.core.index.append_rows` for a
  small row delta vs ``index_rebuild_full`` — a cold rebuild of the grown
  tables.  **Gate** (asserted): the append costs at most half the rebuild
  (the sweep it runs is ``delta/(n+delta)`` of the rebuild's, so well under
  half even with fixed overheads) — maintenance is proportional to the
  delta, never the table.

Strata equality between the cold and warm paths is asserted on every
measured repetition, so the speedup numbers can never come from computing
something different.  Run via ``python -m benchmarks.run --only index``;
the CI index job uploads the ``--json`` artifact next to the built index.
"""
from __future__ import annotations

import tempfile
import time

import numpy as np

from repro.core import BASConfig, build_index
from repro.core.similarity import normalize
from repro.core.stratify import stratify_streaming

from .common import row


def _tables(n1: int, n2: int, d: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return (
        normalize(rng.standard_normal((n1, d))).astype(np.float32),
        normalize(rng.standard_normal((n2, d))).astype(np.float32),
    )


def _time(fn, reps: int):
    fn()                                   # warmup (jit, page cache)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    return (time.perf_counter() - t0) / reps, out


def run(fast: bool = True, smoke: bool = False):
    rows = []
    n = 384 if smoke else (768 if fast else 2048)
    delta = max(n // 16, 8)
    n_bins = 1024
    budget = 4 * n
    cfg = BASConfig()
    reps = 3 if smoke else 5
    e1, e2 = _tables(n, n, 32, seed=0)

    art = build_index([e1, e2], n_bins=n_bins, exponent=cfg.weight_exponent,
                      floor=cfg.weight_floor, use_kernel=cfg.use_kernel)

    def strat_cold():
        return stratify_streaming(e1, e2, cfg.alpha, budget, cfg,
                                  n_bins=n_bins, use_kernel=cfg.use_kernel)

    def strat_warm():
        return stratify_streaming(e1, e2, cfg.alpha, budget, cfg,
                                  n_bins=n_bins, artifact=art)

    dt_cold, s_cold = _time(strat_cold, reps)
    dt_warm, s_warm = _time(strat_warm, reps)
    assert np.array_equal(s_cold.order, s_warm.order), (
        "hydrated stratification diverged from the fresh sweep"
    )
    speedup = dt_cold / max(dt_warm, 1e-12)
    assert speedup >= 5.0, (
        f"warm stratify only {speedup:.1f}x faster than cold sweep "
        f"({dt_warm*1e3:.1f}ms vs {dt_cold*1e3:.1f}ms)"
    )
    rows.append(row("index_query_cold", dt_cold,
                    f"n={n};kernel={art.kernel}"))
    rows.append(row("index_query_warm", dt_warm,
                    f"warm_speedup_x={speedup:.1f}"))

    # serving cold start: artifact save + mmap load + hydrate
    from repro.checkpoint.index_io import load_index, save_index

    with tempfile.TemporaryDirectory() as root:
        save_index(root, art)

        def load_hydrate():
            return load_index(root, art.key).sweep_info()

        dt_load, info = _time(load_hydrate, reps)
        assert np.array_equal(np.asarray(info.counts), art.counts)
        rows.append(row("index_load_mmap", dt_load,
                        f"bytes={art.nbytes}"))

    # delta maintenance: append `delta` rows to the right table vs a cold
    # rebuild of the grown pair — the delta sweep is n x delta instead of
    # n x (n + delta)
    from repro.core import append_rows

    extra = _tables(delta, delta, 32, seed=7)[0]
    grown = [e1, np.concatenate([e2, extra])]

    dt_append, art2 = _time(
        lambda: append_rows(art, 1, extra, use_kernel=cfg.use_kernel), reps)
    dt_rebuild, art_full = _time(
        lambda: build_index(grown, n_bins=n_bins,
                            exponent=cfg.weight_exponent,
                            floor=cfg.weight_floor,
                            use_kernel=cfg.use_kernel), reps)
    assert np.array_equal(art2.block_counts, art_full.block_counts), (
        "incremental append diverged from full recompute"
    )
    frac = dt_append / max(dt_rebuild, 1e-12)
    assert frac <= 0.5, (
        f"append of {delta}/{n + delta} rows cost {frac:.2f}x a full "
        f"rebuild — maintenance is not proportional to the delta"
    )
    rows.append(row("index_append_delta", dt_append,
                    f"delta_rows={delta};delta_blocks="
                    f"{art2.stats['last_delta_blocks']}"))
    rows.append(row("index_rebuild_full", dt_rebuild,
                    f"append_cost_frac={frac:.3f}"))
    return rows
