"""Fig. 13: sensitivity of BAS to the maximum blocking ratio alpha (13a) and
the weight exponent (13b).  BAS should fluctuate mildly and consistently beat
UNIFORM/WWJ.

Run via ``python -m benchmarks.run --only sensitivity`` (``--full`` for
paper-scale repetition counts).  Reporting only — no CI gate."""
from __future__ import annotations

from repro.core import Agg, BASConfig, Query, run_bas, run_uniform, run_wwj
from repro.data import dataset_registry

from .common import rel_rmse, repeat_method, row, truth_of


def run(fast: bool = True):
    n_rep = 10 if fast else 100
    scale = 0.3 if fast else 1.0
    rows = []
    ds = dataset_registry(scale=scale)["flickr30k"]()
    truth = truth_of(ds, Agg.COUNT)
    budget = max(int(ds.spec().n_tuples * 0.04), 2000)
    mk = lambda: Query(spec=ds.spec(), agg=Agg.COUNT, oracle=ds.oracle(), budget=budget)  # noqa: E731

    for alpha in (0.1, 0.2, 0.3):
        cfg = BASConfig(alpha=alpha)
        ests, _, dt = repeat_method(mk, lambda q, s: run_bas(q, cfg, seed=s), n_rep)
        rows.append(row(f"fig13a_alpha{int(alpha*100)}_bas_rmse", dt,
                        f"{rel_rmse(ests, truth):.4f}"))
    ests_u, _, dt_u = repeat_method(mk, lambda q, s: run_uniform(q, seed=s), n_rep)
    rows.append(row("fig13a_uniform_rmse", dt_u, f"{rel_rmse(ests_u, truth):.4f}"))

    ds2 = dataset_registry(scale=scale)["company"]()
    truth2 = truth_of(ds2, Agg.COUNT)
    budget2 = max(int(ds2.spec().n_tuples * 0.04), 2000)
    mk2 = lambda: Query(spec=ds2.spec(), agg=Agg.COUNT, oracle=ds2.oracle(), budget=budget2)  # noqa: E731
    for expo in (0.5, 1.0, 2.0):
        cfg = BASConfig(weight_exponent=expo)
        ests_b, _, dt_b = repeat_method(mk2, lambda q, s: run_bas(q, cfg, seed=s), n_rep)
        ests_w, _, dt_w = repeat_method(
            mk2, lambda q, s: run_wwj(q, cfg, seed=s), n_rep
        )
        rows.append(row(f"fig13b_exp{expo:g}_bas_rmse", dt_b,
                        f"{rel_rmse(ests_b, truth2):.4f}"))
        rows.append(row(f"fig13b_exp{expo:g}_wwj_rmse", dt_w,
                        f"{rel_rmse(ests_w, truth2):.4f}"))
    return rows
