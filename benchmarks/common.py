"""Shared benchmark harness: repetition runner, RMSE/error-ratio metrics,
CSV row emission (name, us_per_call, derived)."""
from __future__ import annotations

import time

import numpy as np

from repro.core import Agg


def truth_of(ds, agg: Agg, g=None) -> float:
    t = ds.truth_flat()
    if agg is Agg.COUNT:
        return float(t.sum())
    import numpy as np

    from repro.core.similarity import flat_to_tuples

    idx = np.nonzero(t > 0)[0]
    tup = flat_to_tuples(idx, ds.spec().sizes)
    vals = g(tup) if g is not None else np.ones(len(idx))
    if agg is Agg.SUM:
        return float(vals.sum())
    if agg is Agg.AVG:
        return float(vals.mean())
    if agg is Agg.MAX:
        return float(vals.max())
    if agg is Agg.MIN:
        return float(vals.min())
    return float(np.median(vals))


def repeat_method(make_query, run, n_rep: int, seed0: int = 0):
    """Runs `run(query, seed)` n_rep times on fresh queries/oracles.
    Returns (estimates, results, seconds_per_call)."""
    ests, results = [], []
    t0 = time.perf_counter()
    for r in range(n_rep):
        q = make_query()
        res = run(q, seed0 + r)
        ests.append(res.estimate)
        results.append(res)
    dt = (time.perf_counter() - t0) / max(n_rep, 1)
    return np.array(ests), results, dt


def rel_rmse(estimates: np.ndarray, truth: float) -> float:
    estimates = np.asarray(estimates, np.float64)
    estimates = estimates[np.isfinite(estimates)]
    if len(estimates) == 0 or truth == 0:
        return float("nan")
    return float(np.sqrt(np.mean((estimates - truth) ** 2)) / abs(truth))


def error_ratio_p95(results: list, truth: float) -> float:
    ratios = [r.error_ratio(truth) for r in results]
    return float(np.quantile(ratios, 0.95))


def coverage(results: list, truth: float) -> float:
    return float(np.mean([r.ci.contains(truth) for r in results]))


def row(name: str, seconds_per_call: float, derived) -> str:
    return f"{name},{seconds_per_call * 1e6:.1f},{derived}"
