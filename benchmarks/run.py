"""Benchmark harness entry: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Default is the fast profile (CI
runtime); ``--full`` uses paper-scale repetition counts.  ``--only rmse``
filters modules.
"""
from __future__ import annotations

import argparse
import sys
import time

MODULES = {
    "guarantees": "benchmarks.bench_guarantees",    # Fig 2/5/6
    "rmse": "benchmarks.bench_rmse",                # Fig 7
    "selection": "benchmarks.bench_selection",      # Fig 8
    "planner": "benchmarks.bench_planner",          # Fig 9
    "allocation": "benchmarks.bench_allocation",    # Fig 10
    "noise": "benchmarks.bench_noise",              # Fig 12
    "sensitivity": "benchmarks.bench_sensitivity",  # Fig 13
    "latency": "benchmarks.bench_latency",          # Fig 14 / App A
    "kernels": "benchmarks.bench_kernels",          # Pallas vs ref
    "oracle": "benchmarks.bench_oracle",            # batched oracle layer
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale reps")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke profile: overrides --full and passes "
                         "smoke=True to modules that support a reduced run")
    ap.add_argument("--only", default=None, help="comma-separated module keys")
    args = ap.parse_args()
    if args.smoke:
        args.full = False
    keys = list(MODULES) if not args.only else args.only.split(",")
    print("name,us_per_call,derived")
    failures = 0
    for key in keys:
        import importlib

        t0 = time.time()
        try:
            import inspect

            mod = importlib.import_module(MODULES[key])
            kwargs = {"fast": not args.full}
            if args.smoke and "smoke" in inspect.signature(mod.run).parameters:
                kwargs["smoke"] = True
            rows = mod.run(**kwargs)
            for r in rows:
                print(r, flush=True)
            print(f"# {key} done in {time.time()-t0:.1f}s", file=sys.stderr)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"# {key} FAILED: {type(e).__name__}: {e}", file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
