"""Benchmark harness entry: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Default is the fast profile (CI
runtime); ``--full`` uses paper-scale repetition counts; ``--smoke`` is the
reduced CI profile.  ``--only rmse`` filters modules.  ``--json PATH``
additionally writes the rows (parsed into objects) plus run metadata to a
JSON file — the artifact CI uploads.  ``--list`` prints each module's key
and one-line summary (the first line of its docstring) without running
anything.
"""
from __future__ import annotations

import argparse
import json
import platform
import sys
import time

MODULES = {
    "guarantees": "benchmarks.bench_guarantees",    # Fig 2/5/6
    "rmse": "benchmarks.bench_rmse",                # Fig 7
    "selection": "benchmarks.bench_selection",      # Fig 8
    "planner": "benchmarks.bench_planner",          # Fig 9
    "allocation": "benchmarks.bench_allocation",    # Fig 10
    "noise": "benchmarks.bench_noise",              # Fig 12
    "sensitivity": "benchmarks.bench_sensitivity",  # Fig 13
    "latency": "benchmarks.bench_latency",          # Fig 14 / App A
    "kernels": "benchmarks.bench_kernels",          # Pallas vs ref
    "oracle": "benchmarks.bench_oracle",            # batched oracle layer
    "service": "benchmarks.bench_service",          # async oracle service
    "index": "benchmarks.bench_index",              # persistent strat index
    "label_store": "benchmarks.bench_label_store",  # charge-once label cache
    "cascade": "benchmarks.bench_cascade",          # multi-fidelity cascade
}


def _parse_row(line: str) -> dict:
    """``name,us_per_call,derived`` -> object; derived ``k=v;...`` pairs are
    expanded so the JSON artifact is queryable without string parsing."""
    name, us, derived = line.split(",", 2)
    out: dict = {"name": name, "us_per_call": float(us)}
    for part in derived.split(";"):
        if "=" in part:
            k, v = part.split("=", 1)
            try:
                out[k] = float(v.rstrip("x"))
            except ValueError:
                out[k] = v
        elif part:
            out["derived"] = part
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale reps")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke profile: overrides --full and passes "
                         "smoke=True to modules that support a reduced run")
    ap.add_argument("--only", default=None, help="comma-separated module keys")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows + metadata to this JSON file")
    ap.add_argument("--list", action="store_true",
                    help="print each module's key and one-line summary, "
                         "then exit")
    args = ap.parse_args()
    if args.list:
        import importlib

        width = max(len(k) for k in MODULES)
        for key, modname in MODULES.items():
            doc = importlib.import_module(modname).__doc__ or ""
            first = doc.strip().splitlines()[0] if doc.strip() else "(no doc)"
            print(f"{key:<{width}}  {first}")
        return
    if args.smoke:
        args.full = False
    keys = list(MODULES) if not args.only else args.only.split(",")
    print("name,us_per_call,derived")
    failures = []
    try:
        import jax

        backend = jax.default_backend()
    except Exception:  # noqa: BLE001 — metadata only, never fail the run
        backend = "unknown"
    report: dict = {
        "profile": ("smoke" if args.smoke else "full" if args.full else "fast"),
        "python": platform.python_version(),
        "platform": platform.platform(),
        "backend": backend,
        "modules": {},
    }
    for key in keys:
        import importlib

        t0 = time.time()
        try:
            import inspect

            mod = importlib.import_module(MODULES[key])
            kwargs = {"fast": not args.full}
            if args.smoke and "smoke" in inspect.signature(mod.run).parameters:
                kwargs["smoke"] = True
            rows = mod.run(**kwargs)
            for r in rows:
                print(r, flush=True)
            report["modules"][key] = {
                "seconds": round(time.time() - t0, 2),
                "rows": [_parse_row(r) for r in rows],
            }
            print(f"# {key} done in {time.time()-t0:.1f}s", file=sys.stderr)
        except Exception as e:  # noqa: BLE001
            failures.append(key)
            report["modules"][key] = {"error": f"{type(e).__name__}: {e}"}
            print(f"# {key} FAILED: {type(e).__name__}: {e}", file=sys.stderr)
    if args.json:
        report["ok"] = not failures
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2)
        print(f"# wrote {args.json}", file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
