"""Fig. 8: selection queries with recall guarantees — precision of BAS
selection vs a SUPG-style importance-sampling threshold baseline; Top-K heavy
hitters precision.

Run via ``python -m benchmarks.run --only selection`` (``--full`` for
paper-scale repetition counts).  Reporting only — no CI gate."""
from __future__ import annotations

import numpy as np

from repro.core import Agg, Query, run_bas_selection, run_topk_heavy_hitters
from repro.core.similarity import chain_weights
from repro.core.types import JoinSpec
from repro.core.oracle import ArrayOracle
from repro.core.wander import flat_sample
from repro.data import make_clustered_tables

from .common import row


def _supg_baseline(query, recall_target, weights, seed):
    """SUPG-style: importance sample, estimate the score threshold achieving
    the recall target, output everything above it (no blocking regime)."""
    rng = np.random.default_rng(seed)
    query.oracle.set_budget(query.budget)
    pos, q = flat_sample(weights, query.budget, rng)
    from repro.core.similarity import flat_to_tuples

    o = query.oracle.label(flat_to_tuples(pos, query.spec.sizes))
    ht = o / q
    total = ht.sum()
    m = o > 0
    v = weights[pos][m]
    wht = (1.0 / q[m])
    order = np.argsort(v)[::-1]
    frac = np.cumsum(wht[order]) / max(total, 1e-12)
    # conservative slack like the BAS path
    var = np.var(ht, ddof=1) / len(ht) if len(ht) > 1 else 0.0
    slack = np.sqrt(var) * len(ht) / max(total, 1e-12)
    j = np.nonzero(frac + slack >= recall_target)[0]
    tau = float(v[order][j[0]]) if len(j) else 0.0
    return np.nonzero(weights >= tau)[0]


def run(fast: bool = True):
    n_rep = 6 if fast else 50
    rows = []
    ds = make_clustered_tables(300, 300, n_entities=450, noise=0.4, seed=17)
    truth = ds.truth.reshape(-1)
    w = chain_weights([ds.emb1, ds.emb2])
    budget = 8000
    recall_target = 0.9

    prec_bas, prec_supg, rec_bas, rec_supg = [], [], [], []
    import time

    t0 = time.perf_counter()
    for s in range(n_rep):
        q1 = Query(spec=ds.spec(), agg=Agg.COUNT, oracle=ds.oracle(), budget=budget)
        res = run_bas_selection(q1, recall_target, seed=s, weights=w)
        sel = np.zeros(len(truth), bool)
        sel[res.selected_flat] = True
        prec_bas.append(truth[sel].mean() if sel.any() else 0.0)
        rec_bas.append(truth[sel].sum() / max(truth.sum(), 1))

        q2 = Query(spec=ds.spec(), agg=Agg.COUNT, oracle=ds.oracle(), budget=budget)
        sel2_idx = _supg_baseline(q2, recall_target, w, s)
        sel2 = np.zeros(len(truth), bool)
        sel2[sel2_idx] = True
        prec_supg.append(truth[sel2].mean() if sel2.any() else 0.0)
        rec_supg.append(truth[sel2].sum() / max(truth.sum(), 1))
    dt = (time.perf_counter() - t0) / n_rep / 2
    rows.append(row("fig8a_bas_precision", dt, f"{np.mean(prec_bas):.3f}"))
    rows.append(row("fig8a_supg_precision", dt, f"{np.mean(prec_supg):.3f}"))
    rows.append(row("fig8a_bas_recall", dt, f"{np.mean(rec_bas):.3f}"))
    rows.append(row("fig8a_supg_recall", dt, f"{np.mean(rec_supg):.3f}"))

    # Fig 8b: Top-K heavy hitters
    rng = np.random.default_rng(5)
    n1, n2 = 400, 50
    truth_m = np.zeros((n1, n2), np.int8)
    hot = [3, 17, 41]
    for j in range(n2):
        p = 0.25 if j in hot else 0.01
        truth_m[:, j] = rng.random(n1) < p
    base = rng.standard_normal((n2, 16)).astype(np.float32)
    emb1 = rng.standard_normal((n1, 16)).astype(np.float32)
    for j in range(n2):
        m = truth_m[:, j] > 0
        emb1[m] = base[j] + 0.5 * rng.standard_normal((int(m.sum()), 16))
    from repro.core.similarity import normalize

    spec = JoinSpec(embeddings=[normalize(emb1), normalize(base)])
    hits = []
    t0 = time.perf_counter()
    for s in range(n_rep):
        q = Query(spec=spec, agg=Agg.COUNT, oracle=ArrayOracle(truth_m), budget=6000)
        out = run_topk_heavy_hitters(q, 3, lambda t: t[:, 1], n2, seed=s)
        hits.append(len(set(out["top"].tolist()) & set(hot)) / 3.0)
    dt = (time.perf_counter() - t0) / n_rep
    rows.append(row("fig8b_topk_precision", dt, f"{np.mean(hits):.3f}"))
    return rows
