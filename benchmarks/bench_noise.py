"""Fig. 12: sensitivity to embedding quality — Syn(FNR, FPR) grid.  BAS must
dominate BLOCKING at high FNR and WWJ at high FPR.

Run via ``python -m benchmarks.run --only noise`` (``--full`` for paper-scale
repetition counts).  Reporting only — no CI gate."""
from __future__ import annotations


from repro.core import Agg, Query, calibrate_threshold, run_bas, run_blocking, run_wwj
from repro.data import make_syn_scores

from .common import rel_rmse, repeat_method, row, truth_of


def run(fast: bool = True):
    n_rep = 10 if fast else 100
    rows = []
    for fnr, fpr in ((0.0, 0.0), (0.3, 0.0), (0.0, 0.3), (0.3, 0.3), (0.5, 0.5)):
        ds = make_syn_scores(300, 300, selectivity=4e-3, fnr=fnr, fpr=fpr, seed=11)
        val = make_syn_scores(300, 300, selectivity=4e-3, fnr=fnr, fpr=fpr, seed=12)
        w = ds.weights_override
        tau = calibrate_threshold(val.weights_override, val.truth_flat(), 0.9)
        truth = truth_of(ds, Agg.COUNT)
        mk = lambda: Query(spec=ds.spec(), agg=Agg.COUNT, oracle=ds.oracle(), budget=5000)  # noqa: E731
        tag = f"fn{int(fnr*100)}_fp{int(fpr*100)}"
        out = {}
        for m, fn in {
            "blocking": lambda q, s: run_blocking(q, tau, seed=s, weights=w),
            "wwj": lambda q, s: run_wwj(q, seed=s, weights=w),
            "bas": lambda q, s: run_bas(q, seed=s, weights=w),
        }.items():
            ests, _, dt = repeat_method(mk, fn, n_rep)
            out[m] = rel_rmse(ests, truth)
            rows.append(row(f"fig12_{tag}_{m}_rmse", dt, f"{out[m]:.4f}"))
        rows.append(row(f"fig12_{tag}_bas_vs_best_baseline", 0.0,
                        f"{min(out['blocking'], out['wwj']) / max(out['bas'], 1e-9):.2f}"))
    return rows
