"""Kernel micro-benchmarks: Pallas (interpret on CPU — structural check) vs
pure-jnp reference, wall time + agreement.  On TPU the same entry points run
compiled.

Run via ``python -m benchmarks.run --only kernels``.  Reporting only — no CI
gate (kernel/reference agreement is asserted by ``tests/test_kernels.py``)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.similarity import normalize

from .common import row


def _time(fn, *args, reps=3):
    fn(*args)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps, out


def run(fast: bool = True):
    rows = []
    rng = np.random.default_rng(0)

    # sim_hist
    from repro.kernels.sim_hist.kernel import sim_hist_pallas
    from repro.kernels.sim_hist.ref import sim_hist_ref

    e1 = jnp.asarray(normalize(rng.standard_normal((512, 64))))
    e2 = jnp.asarray(normalize(rng.standard_normal((512, 64))))
    dt_k, out_k = _time(lambda a, b: sim_hist_pallas(a, b, n_bins=512, bm=128,
                                                     bn=128, interpret=True), e1, e2)
    dt_r, out_r = _time(lambda a, b: sim_hist_ref(a, b, n_bins=512), e1, e2)
    agree = bool((np.asarray(out_k) == np.asarray(out_r)).all())
    rows.append(row("kernel_sim_hist_pallas", dt_k, f"agree={agree}"))
    rows.append(row("kernel_sim_hist_ref", dt_r, f"ratio={dt_k/dt_r:.1f}"))

    # sim_hist with the per-row scale operand (k-way chain-prefix weights)
    scale = jnp.asarray(rng.random(512), jnp.float32)
    dt_k, out_k = _time(lambda a, b, s: sim_hist_pallas(a, b, s[:, None],
                                                        n_bins=512, bm=128,
                                                        bn=128, interpret=True),
                        e1, e2, scale)
    dt_r, out_r = _time(lambda a, b, s: sim_hist_ref(a, b, n_bins=512, scale=s),
                        e1, e2, scale)
    agree = bool((np.asarray(out_k) == np.asarray(out_r)).all())
    rows.append(row("kernel_sim_hist_scaled_pallas", dt_k, f"agree={agree}"))
    rows.append(row("kernel_sim_hist_scaled_ref", dt_r, f"ratio={dt_k/dt_r:.1f}"))

    # sim_topk
    from repro.kernels.sim_topk.kernel import sim_topk_pallas
    from repro.kernels.sim_topk.ref import sim_topk_ref

    dt_k, (vk, ik) = _time(lambda a, b: sim_topk_pallas(a, b, k=8, bm=128, bn=128,
                                                        interpret=True), e1, e2)
    dt_r, (vr, ir) = _time(lambda a, b: sim_topk_ref(a, b, k=8), e1, e2)
    agree = bool(np.allclose(np.asarray(vk), np.asarray(vr), atol=1e-5))
    rows.append(row("kernel_sim_topk_pallas", dt_k, f"agree={agree}"))
    rows.append(row("kernel_sim_topk_ref", dt_r, f"ratio={dt_k/dt_r:.1f}"))

    # flash_attention
    from repro.kernels.flash_attention.kernel import flash_attention_pallas
    from repro.kernels.flash_attention.ref import flash_attention_ref

    q = jnp.asarray(rng.standard_normal((1, 4, 256, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 256, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, 256, 64)), jnp.float32)
    dt_k, ok = _time(lambda *a: flash_attention_pallas(*a, causal=True, bq=64,
                                                       bkv=64, interpret=True), q, k, v)
    dt_r, orf = _time(lambda *a: flash_attention_ref(*a, causal=True), q, k, v)
    agree = bool(np.allclose(np.asarray(ok), np.asarray(orf), atol=2e-4))
    rows.append(row("kernel_flash_attention_pallas", dt_k, f"agree={agree}"))
    rows.append(row("kernel_flash_attention_ref", dt_r, f"ratio={dt_k/dt_r:.1f}"))

    # rwkv6_scan
    from repro.kernels.rwkv6_scan.kernel import rwkv6_scan_pallas
    from repro.kernels.rwkv6_scan.ref import rwkv6_scan_ref

    r = jnp.asarray(rng.standard_normal((1, 4, 128, 32)), jnp.float32)
    kk = jnp.asarray(rng.standard_normal((1, 4, 128, 32)) * 0.3, jnp.float32)
    vv = jnp.asarray(rng.standard_normal((1, 4, 128, 32)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.8, 0.999, (1, 4, 128, 32)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((4, 32)) * 0.1, jnp.float32)
    dt_k, ok = _time(lambda *a: rwkv6_scan_pallas(*a, ct=32, interpret=True),
                     r, kk, vv, w, u)
    dt_r, orf = _time(rwkv6_scan_ref, r, kk, vv, w, u)
    agree = bool(np.allclose(np.asarray(ok), np.asarray(orf), atol=1e-3))
    rows.append(row("kernel_rwkv6_scan_pallas", dt_k, f"agree={agree}"))
    rows.append(row("kernel_rwkv6_scan_ref", dt_r, f"ratio={dt_k/dt_r:.1f}"))

    # rglru_scan
    from repro.kernels.rglru_scan.kernel import rglru_scan_pallas
    from repro.kernels.rglru_scan.ref import rglru_scan_ref

    a = jnp.asarray(rng.uniform(0.8, 0.999, (2, 256, 256)), jnp.float32)
    g = jnp.asarray(rng.standard_normal((2, 256, 256)) * 0.1, jnp.float32)
    dt_k, ok = _time(lambda *x: rglru_scan_pallas(*x, ct=64, br=256, interpret=True),
                     a, g)
    dt_r, orf = _time(rglru_scan_ref, a, g)
    agree = bool(np.allclose(np.asarray(ok), np.asarray(orf), atol=1e-3))
    rows.append(row("kernel_rglru_scan_pallas", dt_k, f"agree={agree}"))
    rows.append(row("kernel_rglru_scan_ref", dt_r, f"ratio={dt_k/dt_r:.1f}"))
    return rows
