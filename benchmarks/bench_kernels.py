"""Kernel micro-benchmarks: Pallas (interpret on CPU — structural check) vs
pure-jnp reference, wall time + agreement.  On TPU the same entry points run
compiled.

The ``kernel_sim_sweep_*`` rows compare the fused single-sweep pass against
the sequential sim_hist + sim_topk schedule at matched shapes: compiled (TPU)
runs must clear >= 1.8x (the sweep halves the MXU passes); interpret-mode
runs only assert agreement and report the measured ratio (the CPU interpreter
is epilogue-bound, so the dot saving barely shows).

Run via ``python -m benchmarks.run --only kernels``.  CI diffs the ``--json``
output against ``benchmarks/baselines/BENCH_kernels.json`` warn-only (see
``scripts/bench_diff.py``); kernel/reference agreement is asserted here and
in ``tests/test_kernels.py``."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.similarity import normalize

from .common import row


def _time(fn, *args, reps=3):
    jax.block_until_ready(fn(*args))  # warmup/compile, fully retired
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps, out


def run(fast: bool = True, smoke: bool = False):
    rows = []
    rng = np.random.default_rng(0)
    n = 256 if smoke else 512

    # sim_hist
    from repro.kernels.sim_hist.kernel import sim_hist_pallas
    from repro.kernels.sim_hist.ref import sim_hist_ref

    e1 = jnp.asarray(normalize(rng.standard_normal((n, 64))))
    e2 = jnp.asarray(normalize(rng.standard_normal((n, 64))))
    dt_k, out_k = _time(lambda a, b: sim_hist_pallas(a, b, n_bins=512, bm=128,
                                                     bn=128, interpret=True), e1, e2)
    dt_r, out_r = _time(lambda a, b: sim_hist_ref(a, b, n_bins=512), e1, e2)
    agree = bool((np.asarray(out_k) == np.asarray(out_r)).all())
    rows.append(row("kernel_sim_hist_pallas", dt_k, f"agree={agree}"))
    rows.append(row("kernel_sim_hist_ref", dt_r, f"ratio={dt_k/dt_r:.1f}"))

    # sim_hist with the per-row scale operand (k-way chain-prefix weights)
    scale = jnp.asarray(rng.random(n), jnp.float32)
    dt_k, out_k = _time(lambda a, b, s: sim_hist_pallas(a, b, s[:, None],
                                                        n_bins=512, bm=128,
                                                        bn=128, interpret=True),
                        e1, e2, scale)
    dt_r, out_r = _time(lambda a, b, s: sim_hist_ref(a, b, n_bins=512, scale=s),
                        e1, e2, scale)
    agree = bool((np.asarray(out_k) == np.asarray(out_r)).all())
    rows.append(row("kernel_sim_hist_scaled_pallas", dt_k, f"agree={agree}"))
    rows.append(row("kernel_sim_hist_scaled_ref", dt_r, f"ratio={dt_k/dt_r:.1f}"))

    # sim_topk
    from repro.kernels.sim_topk.kernel import sim_topk_pallas
    from repro.kernels.sim_topk.ref import sim_topk_ref

    dt_k, (vk, ik) = _time(lambda a, b: sim_topk_pallas(a, b, k=8, bm=128, bn=128,
                                                        interpret=True), e1, e2)
    dt_r, (vr, ir) = _time(lambda a, b: sim_topk_ref(a, b, k=8), e1, e2)
    agree = bool(np.allclose(np.asarray(vk), np.asarray(vr), atol=1e-5))
    rows.append(row("kernel_sim_topk_pallas", dt_k, f"agree={agree}"))
    rows.append(row("kernel_sim_topk_ref", dt_r, f"ratio={dt_k/dt_r:.1f}"))

    # sim_sweep: the fused single pass vs the sequential two-kernel schedule
    # at matched shapes (one blocked E1@E2^T instead of two)
    from repro.kernels.sim_sweep.kernel import sim_sweep_pallas

    interpret = jax.default_backend() != "tpu"

    def fused(a, b):
        return sim_sweep_pallas(a, b, n_bins=512, k=8, bm=128, bn=128,
                                interpret=interpret)

    def sequential(a, b):
        return (
            sim_hist_pallas(a, b, n_bins=512, bm=128, bn=128,
                            interpret=interpret),
            sim_topk_pallas(a, b, k=8, bm=128, bn=128, interpret=interpret),
        )

    dt_f, (bc, vf, jf, rs_f) = _time(fused, e1, e2)
    dt_s, (hist, (vs, js)) = _time(sequential, e1, e2)
    agree = bool(
        np.array_equal(np.asarray(bc).sum(axis=0), np.asarray(hist))
        and np.array_equal(np.asarray(vf), np.asarray(vs))
        and np.array_equal(np.asarray(jf), np.asarray(js))
    )
    assert agree, "fused sweep disagrees with the sequential two-kernel path"
    speedup = dt_s / dt_f
    if not interpret:
        assert speedup >= 1.8, (
            f"compiled fused sweep only {speedup:.2f}x vs sequential"
        )
    rows.append(row("kernel_sim_sweep_fused", dt_f, f"agree={agree}"))
    rows.append(row("kernel_sim_sweep_sequential", dt_s,
                    f"fused_speedup_x={speedup:.2f}"))

    # one-pass chain statistics: the fused sweep already emitted the walk
    # row sums above for free — compare against the retired schedule that
    # ran the sweep and then two standalone f64 passes for walk setup
    from repro.core.similarity import chain_total_weight, edge_row_sums_raw

    e1_np, e2_np = np.asarray(e1), np.asarray(e2)

    def sweep_plus_two_pass(a, b):
        out = sim_sweep_pallas(a, b, n_bins=512, k=8, bm=128, bn=128,
                               interpret=interpret)
        rs = edge_row_sums_raw([e1_np, e2_np])
        total = chain_total_weight([e1_np, e2_np])
        return out, rs, total

    dt_two, (_, rs_ref, total_ref) = _time(sweep_plus_two_pass, e1, e2)
    rs_fused = np.asarray(rs_f)[:, 0].astype(np.float64)
    np.testing.assert_allclose(rs_fused, rs_ref[0], rtol=1e-6)
    assert abs(float(rs_fused.sum()) - total_ref) <= 1e-6 * total_ref
    rowsum_speedup = dt_two / dt_f
    if not interpret:
        assert rowsum_speedup >= 1.5, (
            f"compiled fused-with-rowsums only {rowsum_speedup:.2f}x vs "
            "sweep plus two standalone passes"
        )
    rows.append(row("kernel_sweep_fused_rowsums", dt_f,
                    "sums_rel_err<=1e-6"))
    rows.append(row("kernel_sweep_plus_two_pass", dt_two,
                    f"fused_speedup_x={rowsum_speedup:.2f}"))

    # low-precision fast paths of the same fused pass
    for precision, dtype in (("bf16", jnp.bfloat16),):
        dt_l, _ = _time(
            lambda a, b: sim_sweep_pallas(a, b, n_bins=512, k=8, bm=128,
                                          bn=128, interpret=interpret,
                                          compute_dtype=dtype), e1, e2)
        rows.append(row(f"kernel_sim_sweep_{precision}", dt_l,
                        f"fp32_over_{precision}_x={dt_f/dt_l:.2f}"))

    # flash_attention
    from repro.kernels.flash_attention.kernel import flash_attention_pallas
    from repro.kernels.flash_attention.ref import flash_attention_ref

    q = jnp.asarray(rng.standard_normal((1, 4, 256, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 256, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, 256, 64)), jnp.float32)
    dt_k, ok = _time(lambda *a: flash_attention_pallas(*a, causal=True, bq=64,
                                                       bkv=64, interpret=True), q, k, v)
    dt_r, orf = _time(lambda *a: flash_attention_ref(*a, causal=True), q, k, v)
    agree = bool(np.allclose(np.asarray(ok), np.asarray(orf), atol=2e-4))
    rows.append(row("kernel_flash_attention_pallas", dt_k, f"agree={agree}"))
    rows.append(row("kernel_flash_attention_ref", dt_r, f"ratio={dt_k/dt_r:.1f}"))

    # rwkv6_scan
    from repro.kernels.rwkv6_scan.kernel import rwkv6_scan_pallas
    from repro.kernels.rwkv6_scan.ref import rwkv6_scan_ref

    r = jnp.asarray(rng.standard_normal((1, 4, 128, 32)), jnp.float32)
    kk = jnp.asarray(rng.standard_normal((1, 4, 128, 32)) * 0.3, jnp.float32)
    vv = jnp.asarray(rng.standard_normal((1, 4, 128, 32)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.8, 0.999, (1, 4, 128, 32)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((4, 32)) * 0.1, jnp.float32)
    dt_k, ok = _time(lambda *a: rwkv6_scan_pallas(*a, ct=32, interpret=True),
                     r, kk, vv, w, u)
    dt_r, orf = _time(rwkv6_scan_ref, r, kk, vv, w, u)
    agree = bool(np.allclose(np.asarray(ok), np.asarray(orf), atol=1e-3))
    rows.append(row("kernel_rwkv6_scan_pallas", dt_k, f"agree={agree}"))
    rows.append(row("kernel_rwkv6_scan_ref", dt_r, f"ratio={dt_k/dt_r:.1f}"))

    # rglru_scan
    from repro.kernels.rglru_scan.kernel import rglru_scan_pallas
    from repro.kernels.rglru_scan.ref import rglru_scan_ref

    a = jnp.asarray(rng.uniform(0.8, 0.999, (2, 256, 256)), jnp.float32)
    g = jnp.asarray(rng.standard_normal((2, 256, 256)) * 0.1, jnp.float32)
    dt_k, ok = _time(lambda *x: rglru_scan_pallas(*x, ct=64, br=256, interpret=True),
                     a, g)
    dt_r, orf = _time(rglru_scan_ref, a, g)
    agree = bool(np.allclose(np.asarray(ok), np.asarray(orf), atol=1e-3))
    rows.append(row("kernel_rglru_scan_pallas", dt_k, f"agree={agree}"))
    rows.append(row("kernel_rglru_scan_ref", dt_r, f"ratio={dt_k/dt_r:.1f}"))
    return rows
