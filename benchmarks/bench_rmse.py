"""Fig. 7: end-to-end relative RMSE of BAS vs UNIFORM / BLOCKING / WWJ /
ABAE / BLAZEIT across the dataset suite (paper-workload analogs, a Syn
stress case, and a multi-way chain join).

Run via ``python -m benchmarks.run --only rmse`` (``--full`` for paper-scale
repetition counts).  Reporting only — no CI gate."""
from __future__ import annotations

import numpy as np

from repro.core import (
    Agg,
    Query,
    run_abae,
    run_bas,
    run_blazeit,
    run_blocking,
    run_uniform,
    run_wwj,
)
from repro.core.similarity import chain_weights
from repro.data import dataset_registry, make_chain_dataset, make_syn_scores

from .common import rel_rmse, repeat_method, row, truth_of


def _bench_dataset(name, ds, budget, n_rep, rows, agg=Agg.COUNT, g=None):
    w = ds.weights_override if getattr(ds, "weights_override", None) is not None \
        else chain_weights(ds.spec().embeddings)
    truth = truth_of(ds, agg, g)
    if truth == 0:
        return
    tau = float(np.quantile(w, 0.995))
    mk = lambda: Query(spec=ds.spec(), agg=agg, oracle=ds.oracle(), budget=budget, g=g)  # noqa: E731
    methods = {
        "uniform": lambda q, s: run_uniform(q, seed=s),
        "blocking": lambda q, s: run_blocking(q, tau, seed=s, weights=w),
        "wwj": lambda q, s: run_wwj(q, seed=s, weights=w),
        "abae": lambda q, s: run_abae(q, seed=s, weights=w),
        "blazeit": lambda q, s: run_blazeit(q, seed=s, weights=w),
        "bas": lambda q, s: run_bas(q, seed=s, weights=w),
    }
    rmses = {}
    for m, fn in methods.items():
        ests, _, dt = repeat_method(mk, fn, n_rep)
        rmses[m] = rel_rmse(ests, truth)
        rows.append(row(f"fig7_{name}_{m}_rmse", dt, f"{rmses[m]:.4f}"))
    best_base = min(v for k, v in rmses.items() if k != "bas" and np.isfinite(v))
    if rmses["bas"] <= 1e-9 and best_base <= 1e-9:
        impr = 1.0
    else:
        impr = best_base / max(rmses["bas"], best_base * 1e-3, 1e-9)
    rows.append(row(f"fig7_{name}_bas_improvement_x", 0.0, f"{impr:.2f}"))


def run(fast: bool = True):
    n_rep = 12 if fast else 100
    scale = 0.35 if fast else 1.0
    budget_frac = 0.04
    rows = []
    for name, mk_ds in dataset_registry(scale=scale).items():
        ds = mk_ds()
        budget = max(int(ds.spec().n_tuples * budget_frac), 2000)
        _bench_dataset(name, ds, budget, n_rep, rows)

    # Syn stress case with both failure modes
    ds = make_syn_scores(300, 300, selectivity=3e-3, fnr=0.2, fpr=0.2, seed=5)
    _bench_dataset("syn_fn20_fp20", ds, 5000, n_rep, rows)

    # AVG on an attribute (veri-style transit time)
    reg = dataset_registry(scale=scale)
    ds = reg["veri"]()
    g_col2 = ds.columns2["ts"]
    g_col1 = ds.columns1["ts"]
    g = lambda idx: g_col2[idx[:, 1]] - g_col1[idx[:, 0]]  # noqa: E731
    _bench_dataset("veri_avg", ds, max(int(ds.spec().n_tuples * 0.05), 2000),
                   n_rep, rows, agg=Agg.AVG, g=g)

    # 3-way chain join (Ecomm-Q10 analog): BAS vs UNIFORM vs WWJ
    chain = make_chain_dataset([60, 50, 55], d=24, n_entities=20, noise=0.35, seed=7)
    w = chain_weights(chain.embeddings)
    truth = float(chain.truth_flat().sum())
    if truth > 0:
        mk = lambda: Query(spec=chain.spec(), agg=Agg.COUNT, oracle=chain.oracle(), budget=8000)  # noqa: E731
        for m, fn in {
            "uniform": lambda q, s: run_uniform(q, seed=s),
            "wwj": lambda q, s: run_wwj(q, seed=s),
            "bas": lambda q, s: run_bas(q, seed=s, weights=w),
        }.items():
            ests, _, dt = repeat_method(mk, fn, n_rep)
            rows.append(row(f"fig7_chain3_{m}_rmse", dt,
                            f"{rel_rmse(ests, truth):.4f}"))
    return rows
