"""Oracle serving-substrate benchmark: cross-query coalescing throughput and
query latency of :class:`repro.serve.oracle_service.OracleService` vs. the
serial PR 2 path (each query sync-flushing straight into the scorer), plus
the loopback-TCP transport path (:mod:`repro.serve.transport`) vs. both.

Workload: C identical-shape BAS COUNT queries (C in {1, 4, 16}) over one
clustered-pair join, every query labelling through ONE shared scorer —
the paper's serving scenario, where the expensive resource is the served
match model.  The scorer models a device-bound backend exactly the way
``PairScorer`` behaves: every invocation pays a bucket-padded batch (rows
rounded up to ``pad_to``) of real GEMM compute, so per-flush tail padding
and per-call launches are where a serial multi-query deployment loses
throughput.  The serial path runs the C queries one after another with local
flushes; the service path attaches all C oracles to one ``OracleService``
and runs them on C threads, so pilot/blocking/top-up rounds from different
queries fuse into shared super-batches.

The TCP rows run the same fleet as client threads that each hold a
:class:`~repro.serve.transport.RemoteOracle` over a loopback connection to an
in-process :class:`~repro.serve.transport.OracleServiceServer` — measuring
exactly what multi-host dispatch adds on top of the in-process service:
framing, one round trip per flush, and per-connection handler threads.

Rows: ``service_{serial|async|tcp}_q{C}`` with labels/sec plus p50/p99
per-query latency; async/tcp rows add the speedup over serial and the
window/backend-call counts.  ``service_index_{cold|warm}`` runs repeat
streaming queries through a service-resident
:class:`~repro.core.index.IndexStore` and surfaces the index counters the
service's unified ``snapshot()`` carries (``index_store.warm_hits`` /
``index_store.build_ms`` / ``index_store.delta_blocks``).
``service_tracker_{off,on}_q16`` re-runs the 16-query fleet with a live
:class:`~repro.obs.JsonlTracker` and gates its hot-path overhead;
``service_admission_saturated`` saturates a rate-limited backend and gates
deadline-based admission control.  Run via
``python -m benchmarks.run --only service`` (``--json`` for the artifact CI
uploads; the tracker arm also writes ``bench-tracker.jsonl`` — path override
``REPRO_BENCH_TRACKER`` — which CI uploads alongside it).

CI gates (asserted here, exercised by the workflow's smoke-bench job with
``--smoke``): (a) the in-process service reaches >= 2x serial labels/sec at
16 concurrent queries; (b) loopback TCP stays within 1.5x of the in-process
service's labels/sec at 16 queries while still >= 2x serial, with estimates
bit-identical to the serial path; (c) tracker-enabled serving loses <= 5%
labels/sec vs. tracker-off at 16 concurrent queries; (d) under a saturated
queue, admission control keeps the in-deadline class's p99 <= 2x its
unsaturated p99 while shed flushes raise typed retryable rejections with
zero ledger charges.  The speedups are structural — coalescing divides the
padded-row and launch counts — so they are machine-independent as long as
scorer compute dominates, which this profile is sized for.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import Agg, BASConfig, ModelOracle, Query, run_bas
from repro.data import make_clustered_tables
from repro.serve.oracle_service import OracleService, serve_queries
from repro.serve.transport import (OracleServiceServer, RemoteOracle,
                                   scorer_group)

from .common import row


class PaddedDeviceScorer:
    """Pair scorer modelling a served accelerator backend: every call pads its
    batch to a multiple of ``pad_to`` rows (PairScorer's bucket padding) and
    runs a small real MLP over the padded block, so cost per call is
    launch + ceil(n / pad_to) * pad_to rows of GEMM — the regime where
    cross-query batching wins.  Scores are deterministic per pair."""

    def __init__(self, emb1: np.ndarray, emb2: np.ndarray, hidden: int = 1024,
                 depth: int = 4, pad_to: int = 1024, seed: int = 7):
        rng = np.random.default_rng(seed)
        d = emb1.shape[1]
        self.emb1 = np.asarray(emb1, np.float32)
        self.emb2 = np.asarray(emb2, np.float32)
        self.w_in = (rng.standard_normal((d, hidden)) / np.sqrt(d)).astype(
            np.float32
        )
        self.w = [
            (rng.standard_normal((hidden, hidden)) / np.sqrt(hidden)).astype(
                np.float32
            )
            for _ in range(depth)
        ]
        self.pad_to = int(pad_to)
        self.calls = 0
        self.rows_padded = 0

    def __call__(self, idx: np.ndarray) -> np.ndarray:
        n = len(idx)
        pad = -(-max(n, 1) // self.pad_to) * self.pad_to
        x = np.zeros((pad, self.w_in.shape[0]), np.float32)
        x[:n] = self.emb1[idx[:, 0]] * self.emb2[idx[:, 1]]
        x = np.tanh(x @ self.w_in)
        for w in self.w:
            x = np.tanh(x @ w)
        self.calls += 1
        self.rows_padded += pad
        return 1.0 / (1.0 + np.exp(-4.0 * np.asarray(x[:n, 0], np.float64)))


def _run_fleet(ds, scorer, weights, n_queries: int, budget: int,
               cfg: BASConfig, service: bool, workers: int,
               max_wait_ms: float, tracker=None):
    """Run ``n_queries`` BAS queries labelling through ``scorer``; returns
    (total oracle calls, per-query latencies, wall seconds, service snapshot).

    ``weights`` is the precomputed chain-weight array shared by every query
    (read-only) — same-spec queries share the similarity index in a serving
    deployment, which keeps this benchmark about the oracle path."""
    spec = ds.spec()
    oracles = [ModelOracle(scorer, threshold=0.5) for _ in range(n_queries)]
    queries = [
        Query(spec=spec, agg=Agg.COUNT, oracle=o, budget=budget)
        for o in oracles
    ]
    lat = np.zeros(n_queries)

    def job(i: int):
        t0 = time.perf_counter()
        res = run_bas(queries[i], cfg, seed=100 + i, weights=weights)
        lat[i] = time.perf_counter() - t0
        return res

    if not service:
        t0 = time.perf_counter()
        results = [job(i) for i in range(n_queries)]
        wall = time.perf_counter() - t0
        return queries, results, lat, wall, {}

    # workers=1 here: the scorer pads each call, so sharding a super-batch
    # into thread workers re-pads every shard — a loss for one shared
    # in-process backend (the thread pool pays off for multi-replica or
    # GIL-bound backends; covered in tests/test_oracle_service.py)
    with OracleService(workers=workers, max_wait_ms=max_wait_ms,
                       min_shard=4096, tracker=tracker) as svc:
        svc.attach(*oracles)

        def served(i: int):
            try:
                return job(i)
            finally:
                svc.detach(oracles[i])   # don't make windows wait on done queries

        t0 = time.perf_counter()
        results = serve_queries(svc, [lambda i=i: served(i) for i in range(n_queries)])
        wall = time.perf_counter() - t0
        stats = svc.snapshot()
    return queries, results, lat, wall, stats


def _run_fleet_tcp(ds, scorer, weights, n_queries: int, budget: int,
                   cfg: BASConfig, max_wait_ms: float):
    """The multi-host path on loopback: every query is a client thread with
    its own :class:`RemoteOracle` connection into one in-process TCP server;
    the server's service coalesces EXEC segments across connections exactly
    as the in-process path coalesces flushes across attached oracles."""
    spec = ds.spec()
    with OracleServiceServer({"bench": scorer_group(scorer, threshold=0.5)},
                             workers=1, max_wait_ms=max_wait_ms,
                             min_shard=4096) as server:
        oracles = [RemoteOracle(server.address, "bench")
                   for _ in range(n_queries)]
        queries = [
            Query(spec=spec, agg=Agg.COUNT, oracle=o, budget=budget)
            for o in oracles
        ]
        lat = np.zeros(n_queries)

        def job(i: int):
            t0 = time.perf_counter()
            try:
                return run_bas(queries[i], cfg, seed=100 + i, weights=weights)
            finally:
                lat[i] = time.perf_counter() - t0
                oracles[i].close()   # don't make windows wait on done clients

        t0 = time.perf_counter()
        results = serve_queries(
            server.service, [lambda i=i: job(i) for i in range(n_queries)]
        )
        wall = time.perf_counter() - t0
        stats = server.service.snapshot()
    return queries, results, lat, wall, stats


def _tracker_overhead_rows(ds, scorer, weights, budget, cfg):
    """``service_tracker_{off,on}_q16``: the 16-query async fleet with the
    default :class:`NoopTracker` vs. a live :class:`JsonlTracker` — the
    instrumented hot path (window assembly timing, per-shard latency,
    per-class flush histograms, JSONL emission) must cost <= 5% labels/sec.
    Arms interleave and take best-of-2 so the gate measures the tracker, not
    scheduler noise; the JSONL file is the artifact CI's smoke-bench uploads
    (override the path with ``REPRO_BENCH_TRACKER``)."""
    import os

    from repro.obs import JsonlTracker

    path = os.environ.get("REPRO_BENCH_TRACKER", "bench-tracker.jsonl")
    if os.path.exists(path):
        os.remove(path)
    best = {"off": 0.0, "on": 0.0}
    snap_on = {}
    for _ in range(2):                      # best-of-2, interleaved arms
        for arm in ("off", "on"):
            tracker = JsonlTracker(path) if arm == "on" else None
            qs, results, _, wall, snap = _run_fleet(
                ds, scorer, weights, 16, budget, cfg, service=True,
                workers=1, max_wait_ms=8.0, tracker=tracker,
            )
            assert all(np.isfinite(r.estimate) for r in results)
            labels = sum(q.oracle.calls for q in qs)
            best[arm] = max(best[arm], labels / max(wall, 1e-9))
            if tracker is not None:
                snap_on = snap
                tracker.close()
    # the instrumented run actually recorded the hot-path series
    assert "service.window.assembly_ms.p50" in snap_on, snap_on
    assert "service.shard.local_ms.p99" in snap_on, snap_on
    assert os.path.getsize(path) > 0, f"tracker JSONL {path} is empty"
    overhead = 1.0 - best["on"] / max(best["off"], 1e-9)
    assert overhead <= 0.05, (
        f"tracker-enabled service lost {overhead * 100:.1f}% labels/sec at 16 "
        f"concurrent queries (> 5%): instrumentation leaked into the hot path"
    )
    return [
        row("service_tracker_off_q16", 1.0 / max(best["off"], 1e-9),
            f"labels_per_s={best['off']:.0f}"),
        row("service_tracker_on_q16", 1.0 / max(best["on"], 1e-9),
            f"labels_per_s={best['on']:.0f};"
            f"overhead={overhead * 100:.1f}%;"
            f"jsonl={path}"),
    ]


def _admission_saturated_row(smoke: bool):
    """``service_admission_saturated``: a rate-limited backend (sleep-bound at
    1000 rows/s) serving one deadline-class client while bulk raw segments
    saturate the queue.  Flushes the predicted wait would blow past the
    deadline are shed with typed retryable :class:`AdmissionRejected` and
    zero ledger movement; admitted (in-deadline) flushes keep p99 <= 2x the
    unsaturated p99 — the acceptance gate for deadline-based admission."""
    from repro.core import FnOracle
    from repro.serve.oracle_service import AdmissionRejected

    def slow_fn(idx):
        time.sleep(len(idx) * 1e-3)         # deterministic 1000 rows/s
        return (idx.sum(axis=1) % 2).astype(np.float64)

    side = 1 << 20
    seq = {"n": 0}

    def fresh_idx(n):                       # never-repeating pairs: no cache
        base = np.arange(seq["n"], seq["n"] + n, dtype=np.int64)
        seq["n"] += n
        return np.stack([base % side, (base * 7 + 1) % side], axis=1)

    n_unsat, n_bulk, bulk_rows, n_admit = (
        (8, 2, 800, 6) if smoke else (12, 3, 1200, 10)
    )
    rt = FnOracle(slow_fn)
    rt.bind_sizes((side, side))
    rejections = 0
    with OracleService(workers=1, max_wait_ms=4.0,
                       min_shard=1 << 30) as svc:
        svc.attach(rt)
        unsat = []
        for _ in range(n_unsat):
            idx = fresh_idx(40)
            t0 = time.perf_counter()
            rt.label(idx)
            unsat.append(time.perf_counter() - t0)
        p99_unsat = float(np.quantile(unsat, 0.99))
        # a deadline the unsaturated path clears with room and the saturated
        # queue cannot: admitted waits stay bounded by it
        deadline_ms = 1.5 * p99_unsat * 1e3
        svc.attach(rt, deadline_ms=deadline_ms, query_class="rt")

        bulk_futs = [svc.submit_raw("bulk", slow_fn, fresh_idx(bulk_rows))
                     for _ in range(n_bulk)]
        admitted = []
        t_end = time.monotonic() + 60.0
        while len(admitted) < n_admit and time.monotonic() < t_end:
            idx = fresh_idx(40)
            calls_before, charged_before = rt.calls, rt.charged
            t0 = time.perf_counter()
            try:
                rt.label(idx)
            except AdmissionRejected as e:
                assert e.retryable is True
                assert rt.calls == calls_before      # shed = zero charge
                assert rt.charged == charged_before
                rejections += 1
                time.sleep(0.02)
            else:
                admitted.append(time.perf_counter() - t0)
        for fut in bulk_futs:
            fut.result()
        snap = svc.snapshot()
    assert len(admitted) >= n_admit, "saturated queue never drained"
    assert rejections >= 1, "saturation never shed an over-deadline flush"
    assert snap["service.admission.rejected"] == float(rejections)
    p99_admitted = float(np.quantile(admitted, 0.99))
    assert p99_admitted <= 2.0 * p99_unsat, (
        f"in-deadline-class p99 {p99_admitted * 1e3:.0f}ms exceeds 2x the "
        f"unsaturated p99 {p99_unsat * 1e3:.0f}ms: admission control is not "
        f"protecting admitted flushes"
    )
    return row(
        "service_admission_saturated", p99_admitted,
        f"p99_unsat_ms={p99_unsat * 1e3:.0f};"
        f"p99_admitted_ms={p99_admitted * 1e3:.0f};"
        f"deadline_ms={deadline_ms:.0f};"
        f"rejected={rejections};"
        f"shed_charges=0",
    )


def run(fast: bool = True, smoke: bool = False):
    rows = []
    if smoke:
        n_side, budget, levels = 128, 400, (1, 4, 16)
    elif fast:
        n_side, budget, levels = 128, 500, (1, 4, 16)
    else:
        n_side, budget, levels = 384, 2000, (1, 4, 16, 64)
    cfg = BASConfig(n_bootstrap=20)
    ds = make_clustered_tables(n_side, n_side, n_entities=2 * n_side,
                               noise=0.4, seed=0)
    scorer = PaddedDeviceScorer(ds.spec().embeddings[0],
                                ds.spec().embeddings[1])
    from repro.core.similarity import chain_weights

    weights = chain_weights(ds.spec().embeddings, cfg.weight_exponent,
                            cfg.weight_floor)
    speedups = {}
    tcp_ratios = {}
    for c in levels:
        qs, results, lat_s, wall_serial, _ = _run_fleet(
            ds, scorer, weights, c, budget, cfg, service=False, workers=0,
            max_wait_ms=0,
        )
        serial_estimates = [r.estimate for r in results]
        labels = sum(q.oracle.calls for q in qs)
        assert all(np.isfinite(r.estimate) for r in results)
        rows.append(row(
            f"service_serial_q{c}", wall_serial / max(labels, 1),
            f"labels_per_s={labels / max(wall_serial, 1e-9):.0f};"
            f"p50_ms={np.quantile(lat_s, 0.5) * 1e3:.0f};"
            f"p99_ms={np.quantile(lat_s, 0.99) * 1e3:.0f}",
        ))
        qs, results, lat_a, wall_async, stats = _run_fleet(
            ds, scorer, weights, c, budget, cfg, service=True, workers=1,
            max_wait_ms=8.0,
        )
        labels_a = sum(q.oracle.calls for q in qs)
        assert all(np.isfinite(r.estimate) for r in results)
        speedup = (labels_a / max(wall_async, 1e-9)) / max(
            labels / max(wall_serial, 1e-9), 1e-9
        )
        speedups[c] = speedup
        rows.append(row(
            f"service_async_q{c}", wall_async / max(labels_a, 1),
            f"labels_per_s={labels_a / max(wall_async, 1e-9):.0f};"
            f"speedup={speedup:.2f}x;"
            f"p50_ms={np.quantile(lat_a, 0.5) * 1e3:.0f};"
            f"p99_ms={np.quantile(lat_a, 0.99) * 1e3:.0f};"
            f"windows={stats['service.windows']:.0f};"
            f"segments_per_window={stats['service.segments_per_window']:.2f};"
            f"fill_recent={stats['service.window.fill_ratio_recent']:.3f};"
            f"backend_calls={stats['service.backend_calls']:.0f}",
        ))
        # windows get extra grace over the in-process 8ms: each client's next
        # flush arrives a round trip + client-side commit later, so the same
        # deadline would fragment windows the in-process path keeps whole
        qs, results, lat_t, wall_tcp, stats = _run_fleet_tcp(
            ds, scorer, weights, c, budget, cfg, max_wait_ms=16.0,
        )
        labels_t = sum(q.oracle.calls for q in qs)
        # multi-host dispatch changes where labelling runs, not what a query
        # computes: loopback TCP must reproduce the serial estimates exactly
        assert [r.estimate for r in results] == serial_estimates, (
            "TCP-path estimates diverged from serial execution"
        )
        tcp_speedup = (labels_t / max(wall_tcp, 1e-9)) / max(
            labels / max(wall_serial, 1e-9), 1e-9
        )
        tcp_ratios[c] = (labels_a / max(wall_async, 1e-9)) / max(
            labels_t / max(wall_tcp, 1e-9), 1e-9
        )
        speedups[(c, "tcp")] = tcp_speedup
        rows.append(row(
            f"service_tcp_q{c}", wall_tcp / max(labels_t, 1),
            f"labels_per_s={labels_t / max(wall_tcp, 1e-9):.0f};"
            f"speedup={tcp_speedup:.2f}x;"
            f"vs_inproc={tcp_ratios[c]:.2f}x;"
            f"p50_ms={np.quantile(lat_t, 0.5) * 1e3:.0f};"
            f"p99_ms={np.quantile(lat_t, 0.99) * 1e3:.0f};"
            f"windows={stats['service.windows']:.0f};"
            f"segments_per_window={stats['service.segments_per_window']:.2f};"
            f"backend_calls={stats['service.backend_calls']:.0f}",
        ))
    # --- index-aware serving ------------------------------------------------
    # Repeat streaming queries through a service-resident IndexStore: the
    # first query builds the stratification artifact (index_miss/index_build),
    # every later one hydrates it (index_hit) — the service's stats() now
    # carries the store counters, which is what these rows surface.
    from repro.core import IndexStore
    from repro.core.bas_streaming import run_bas_streaming

    store = IndexStore(max_bytes=1 << 28)
    with OracleService(workers=1, max_wait_ms=4.0, min_shard=4096,
                       index_store=store) as svc:

        def served_query(seed: int):
            # fresh oracle per run: ModelOracle sampling state carries across
            # runs, and this comparison is about the index, not oracle reuse
            oracle = ModelOracle(scorer, threshold=0.5)
            svc.attach(oracle)
            q = Query(spec=ds.spec(), agg=Agg.COUNT, oracle=oracle,
                      budget=budget)
            t0 = time.perf_counter()
            try:
                return (run_bas_streaming(q, cfg, seed=seed,
                                          index_store=store),
                        time.perf_counter() - t0)
            finally:
                svc.detach(oracle)

        res_cold, t_cold = served_query(100)
        res_warm, t_warm = served_query(100)
        # hydration must not change what the query computes
        assert res_warm.estimate == res_cold.estimate, (
            "index-hydrated streaming estimate diverged from the cold build"
        )
        stats = svc.snapshot()
    assert stats["index_store.misses"] == 1.0, stats
    assert stats["index_store.warm_hits"] == 1.0, stats
    rows.append(row(
        "service_index_cold", t_cold,
        f"index_miss={stats['index_store.misses']:.0f};"
        f"index_build={stats['index_store.builds']:.0f};"
        f"index_build_ms={stats['index_store.build_ms']:.1f}",
    ))
    rows.append(row(
        "service_index_warm", t_warm,
        f"index_hit={stats['index_store.warm_hits']:.0f};"
        f"index_bytes={stats['index_store.bytes']:.0f};"
        f"delta_blocks={stats['index_store.delta_blocks']:.0f}",
    ))

    rows.extend(_tracker_overhead_rows(ds, scorer, weights, budget, cfg))
    rows.append(_admission_saturated_row(smoke))

    if 16 in speedups:
        # acceptance headline: cross-query coalescing must at least halve the
        # serial path's cost at 16 concurrent queries
        assert speedups[16] >= 2.0, (
            f"service speedup at 16 concurrent queries is {speedups[16]:.2f}x "
            f"(< 2x): cross-query coalescing regressed"
        )
        # and the transport must not eat the win: loopback TCP within 1.5x of
        # the in-process service, still >= 2x over serial
        assert tcp_ratios[16] <= 1.5, (
            f"loopback TCP is {tcp_ratios[16]:.2f}x slower than the "
            f"in-process service at 16 queries (> 1.5x): transport overhead "
            f"regressed"
        )
        assert speedups[(16, "tcp")] >= 2.0, (
            f"TCP service speedup at 16 concurrent queries is "
            f"{speedups[(16, 'tcp')]:.2f}x (< 2x)"
        )
    return rows
