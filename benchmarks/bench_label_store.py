"""Shared label store benchmark: charge-once caching throughput on a
repeat-query workload vs. the store-less service path, plus the BAS-level
correctness contract (bit-identical estimates, bounded total charges).

Workload: Q clients x R rounds against one served
:class:`~benchmarks.bench_service.PaddedDeviceScorer`.  Every client's pair
set is 50% *hot* pairs (shared by all clients) and 50% pairs unique to that
client, and each round re-issues the same query through a **fresh**
:class:`~repro.core.ModelOracle` — the serving fleet's steady state, where
dashboards and repeated analytical queries hit the same hot table pairs but
every query carries its own cache and ledger.  Without a store the backend
executes R*Q*n rows; with one it executes each distinct pair once —
(Q+1)*n/2 rows — so the structural speedup at the default profile is ~5x
while every query still *acquires* exactly the same labels (``calls`` is
identical; the discount lands on ``charged``).

Rows: ``label_store_{off|on}_q{Q}`` with labels/sec (acquired labels per
wall second — the numerator is identical in both arms, so the ratio is pure
store win), plus the store's hit/charge counters; ``label_store_bas_repeat``
runs full BAS queries through a stored service and surfaces the repeat
query's (zero) charge.  Run via ``python -m benchmarks.run --only
label_store``.

CI gates (asserted here, exercised by the workflow's smoke-bench job with
``--smoke``): (a) the stored service reaches >= 3x the store-less path's
labels/sec on the repeat workload; (b) labels and BAS estimates are
bit-identical to store-less execution; (c) summed ledger charges equal the
store's distinct-pair count — the charge-once bound.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import Agg, BASConfig, ModelOracle, Query, run_bas
from repro.data import make_clustered_tables
from repro.serve.label_store import LabelStore
from repro.serve.oracle_service import OracleService, serve_queries

from .bench_service import PaddedDeviceScorer
from .common import row


def _pair_sets(n_side: int, n_clients: int, n_pairs: int, seed: int = 5):
    """Per-client pair arrays: the first half is one hot set every client
    shares, the second half is unique to the client."""
    rng = np.random.default_rng(seed)

    def draw(n):
        return np.unique(
            rng.integers(0, n_side, size=(2 * n, 2)), axis=0
        )[:n]

    hot = draw(n_pairs // 2)
    return [np.concatenate([hot, draw(n_pairs // 2)]) for _ in range(n_clients)]


def _run_arm(scorer, sizes, pair_sets, rounds: int, store):
    """Q concurrent labelling clients per round, fresh oracles each round;
    returns (wall_s, acquired, charged, per-client label arrays, snapshot)."""
    calls = charged = 0
    wall = 0.0
    labels = None
    with OracleService(workers=1, max_wait_ms=50.0, min_shard=1 << 30,
                       label_store=store) as svc:
        for _ in range(rounds):
            oracles = [ModelOracle(scorer, threshold=0.5, name="bench")
                       for _ in pair_sets]
            for o in oracles:
                o.bind_sizes(sizes)
            svc.attach(*oracles)

            def job(i):
                try:
                    return oracles[i].label(pair_sets[i])
                finally:
                    svc.detach(oracles[i])

            t0 = time.perf_counter()
            labels = serve_queries(
                svc, [lambda i=i: job(i) for i in range(len(pair_sets))]
            )
            wall += time.perf_counter() - t0
            calls += sum(o.calls for o in oracles)
            charged += sum(o.charged for o in oracles)
        stats = svc.snapshot()
    return wall, calls, charged, labels, stats


def run(fast: bool = True, smoke: bool = False):
    rows = []
    if smoke:
        n_side, n_clients, n_pairs, rounds, budget = 512, 4, 512, 3, 300
    elif fast:
        n_side, n_clients, n_pairs, rounds, budget = 1024, 6, 1024, 3, 500
    else:
        n_side, n_clients, n_pairs, rounds, budget = 2048, 8, 2048, 4, 1500
    rng = np.random.default_rng(0)
    emb = [rng.standard_normal((n_side, 32)).astype(np.float32)
           for _ in range(2)]
    pair_sets = _pair_sets(n_side, n_clients, n_pairs)
    unique_pairs = len(np.unique(np.concatenate(pair_sets), axis=0))

    # --- throughput: store-less vs stored service on the repeat workload ----
    scorer_off = PaddedDeviceScorer(emb[0], emb[1], hidden=256, depth=2,
                                    pad_to=512)
    wall_off, calls_off, charged_off, labels_off, _ = _run_arm(
        scorer_off, (n_side, n_side), pair_sets, rounds, store=None,
    )
    assert charged_off == calls_off          # without a store, charged==calls
    rate_off = calls_off / max(wall_off, 1e-9)
    rows.append(row(
        f"label_store_off_q{n_clients}", wall_off / max(calls_off, 1),
        f"labels_per_s={rate_off:.0f};rounds={rounds};"
        f"rows_executed={scorer_off.rows_padded}",
    ))

    scorer_on = PaddedDeviceScorer(emb[0], emb[1], hidden=256, depth=2,
                                   pad_to=512)
    store = LabelStore()
    wall_on, calls_on, charged_on, labels_on, stats = _run_arm(
        scorer_on, (n_side, n_side), pair_sets, rounds, store=store,
    )
    assert calls_on == calls_off             # same labels acquired...
    for a, b in zip(labels_off, labels_on):  # ...and bit-identical
        np.testing.assert_array_equal(a, b)
    # the charge-once bound: total charges == distinct pairs ever labelled
    assert charged_on == stats["label_store.entries"] <= unique_pairs, (
        charged_on, stats["label_store.entries"], unique_pairs,
    )
    rate_on = calls_on / max(wall_on, 1e-9)
    speedup = rate_on / max(rate_off, 1e-9)
    rows.append(row(
        f"label_store_on_q{n_clients}", wall_on / max(calls_on, 1),
        f"labels_per_s={rate_on:.0f};speedup={speedup:.2f}x;"
        f"hit_rate={stats['label_store.hit_rate']:.2f};"
        f"charged={charged_on};charge_saved={calls_on - charged_on};"
        f"rows_executed={scorer_on.rows_padded}",
    ))

    # --- full BAS queries: estimates bit-identical, repeats charge zero -----
    ds = make_clustered_tables(96, 96, n_entities=150, noise=0.4, seed=3)
    bas_scorer = PaddedDeviceScorer(ds.spec().embeddings[0],
                                    ds.spec().embeddings[1],
                                    hidden=128, depth=2, pad_to=256)
    cfg = BASConfig(n_bootstrap=20)

    def fresh_query():
        return Query(spec=ds.spec(), agg=Agg.COUNT,
                     oracle=ModelOracle(bas_scorer, threshold=0.5,
                                        name="bas"),
                     budget=budget)

    ref_q = fresh_query()
    ref = run_bas(ref_q, cfg, seed=17)
    bas_store = LabelStore()
    with OracleService(workers=1, max_wait_ms=1.0, min_shard=1 << 30,
                       label_store=bas_store) as svc:
        q1, q2 = fresh_query(), fresh_query()
        for q in (q1, q2):
            svc.attach(q.oracle)
            t0 = time.perf_counter()
            res = run_bas(q, cfg, seed=17)
            t_run = time.perf_counter() - t0
            svc.detach(q.oracle)
            assert res.estimate == ref.estimate, (
                "store-served BAS estimate diverged from serial execution"
            )
            assert res.ci.lo == ref.ci.lo and res.ci.hi == ref.ci.hi
            assert q.oracle.calls == ref_q.oracle.calls
    assert q1.oracle.charged == ref_q.oracle.calls   # first requester pays
    assert q2.oracle.charged == 0                    # the repeat rides free
    assert (q1.oracle.charged + q2.oracle.charged
            == bas_store.snapshot()["label_store.entries"])
    rows.append(row(
        "label_store_bas_repeat", t_run,
        f"charged={q2.oracle.charged};"
        f"store_hits={q2.oracle.store_hits};"
        f"bit_identical=True",
    ))

    # acceptance headline: charge-once caching must at least triple the
    # repeat workload's labels/sec over the store-less service path
    assert speedup >= 3.0, (
        f"label store speedup is {speedup:.2f}x (< 3x) on the "
        f"{n_clients}-client x {rounds}-round repeat workload: "
        f"charge-once caching regressed"
    )
    return rows
