"""Fig. 2/5/6: statistical guarantees.  A valid 95% CI requires the 95th
percentile of |err| / CI-half-width <= 1.  BLOCKING violates this (bias with
shrinking CI); BAS stays valid, including at tiny budgets and pilot sizes.

Run via ``python -m benchmarks.run --only guarantees`` (``--full`` for
paper-scale repetition counts).  Reporting only — no CI gate (CI *validity*
itself is asserted by the statistical tests in ``tests/``)."""
from __future__ import annotations


from repro.core import (
    Agg,
    BASConfig,
    Query,
    calibrate_threshold,
    run_bas,
    run_blocking,
)
from repro.data import make_syn_scores

from .common import coverage, error_ratio_p95, repeat_method, row, truth_of


def run(fast: bool = True):
    n_rep = 20 if fast else 100
    n = 300 if fast else 600
    rows = []
    ds = make_syn_scores(n, n, selectivity=5e-3, fnr=0.05, fpr=0.1, seed=1)
    val = make_syn_scores(n, n, selectivity=5e-3, fnr=0.05, fpr=0.1, seed=2)
    tau = calibrate_threshold(val.weights_override, val.truth_flat(), 0.9)
    truth = truth_of(ds, Agg.COUNT)
    w = ds.weights_override

    for budget in (2000, 8000, 20000):
        mk = lambda: Query(spec=ds.spec(), agg=Agg.COUNT, oracle=ds.oracle(), budget=budget)  # noqa: E731
        ests_b, res_b, dt_b = repeat_method(
            mk, lambda q, s: run_blocking(q, tau, seed=s, weights=w), n_rep
        )
        ests_a, res_a, dt_a = repeat_method(
            mk, lambda q, s: run_bas(q, seed=s, weights=w), n_rep
        )
        rows.append(row(f"fig5_error_ratio_p95_blocking_b{budget}", dt_b,
                        f"{error_ratio_p95(res_b, truth):.2f}"))
        rows.append(row(f"fig5_error_ratio_p95_bas_b{budget}", dt_a,
                        f"{error_ratio_p95(res_a, truth):.2f}"))
        rows.append(row(f"fig5_coverage_bas_b{budget}", dt_a,
                        f"{coverage(res_a, truth):.2f}"))

    # Fig 6 left: tiny budget validity
    mk = lambda: Query(spec=ds.spec(), agg=Agg.COUNT, oracle=ds.oracle(), budget=1000)  # noqa: E731
    _, res, dt = repeat_method(mk, lambda q, s: run_bas(q, seed=s, weights=w), n_rep)
    rows.append(row("fig6_error_ratio_p95_bas_b1000", dt,
                    f"{error_ratio_p95(res, truth):.2f}"))
    # Fig 6 right: pilot-size insensitivity
    for pf in (0.02, 0.1, 0.3):
        cfg = BASConfig(pilot_fraction=pf)
        _, res, dt = repeat_method(
            mk, lambda q, s: run_bas(q, cfg, seed=s, weights=w), n_rep
        )
        rows.append(row(f"fig6_error_ratio_p95_bas_pilot{pf:g}", dt,
                        f"{error_ratio_p95(res, truth):.2f}"))

    # Fig 5 other aggregates (SUM / AVG) on an attribute column
    g_col = ds.columns1["value"]
    g = lambda idx: g_col[idx[:, 0]]  # noqa: E731
    for agg in (Agg.SUM, Agg.AVG):
        truth_g = truth_of(ds, agg, g)
        mk = lambda: Query(spec=ds.spec(), agg=agg, oracle=ds.oracle(), budget=8000, g=g)  # noqa: E731, B023
        _, res, dt = repeat_method(mk, lambda q, s: run_bas(q, seed=s, weights=w), n_rep)
        rows.append(row(f"fig5_error_ratio_p95_bas_{agg.value}", dt,
                        f"{error_ratio_p95(res, truth_g):.2f}"))
        rows.append(row(f"fig5_coverage_bas_{agg.value}", dt,
                        f"{coverage(res, truth_g):.2f}"))
    return rows
