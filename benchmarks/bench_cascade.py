"""Multi-fidelity cascade: expensive-oracle calls saved at matched RMSE.

A cheap proxy (here: a score-threshold classifier over the join's similarity
scores) labels every sampled pair; the expensive oracle only prices the
proxy's mistakes through the HT-corrected difference regime in
``core/cascade.py``.  This benchmark runs the cascade at a fraction of the
plain-BAS oracle budget and gates on the paper-level claim: **>= 2x fewer
expensive oracle calls without giving up accuracy** (cascade RMSE within
10% of plain BAS at its larger budget).

The gate asserts inside ``run`` and the summary row
(``cascade_oracle_calls_saved``) is declared via ``--require-rows`` in CI,
so the check cannot silently stop executing.
"""
from __future__ import annotations

import numpy as np

from repro.core import Agg, ArrayOracle, Query, run_bas, run_bas_cascade
from repro.data import make_syn_scores

from .common import coverage, rel_rmse, repeat_method, row, truth_of

# Budgets chosen so the cascade's expensive budget is 2.5x smaller; the
# gate below checks the *realised* ledgers, not these nominal numbers.
BUDGET_CASCADE = 320
BUDGET_PLAIN = 800
PROXY_TAU = 0.7   # score threshold for the cheap classifier


def run(fast: bool = True):
    # 30 reps keeps the 3s runtime while holding the RMSE-ratio gate well
    # clear of replicate noise, so the smoke profile runs the same count
    n_rep = 30 if fast else 100
    ds = make_syn_scores(96, 96, selectivity=0.02, fnr=0.02, fpr=0.01,
                         seed=3)
    truth = truth_of(ds, Agg.COUNT)
    w = ds.weights_override
    # The proxy errs exactly where a real cheap model errs: near its
    # decision boundary, i.e. in mid-score (well-sampled) regions — the
    # regime the correction estimator prices efficiently.
    proxy_labels = (w.reshape(96, 96) >= PROXY_TAU).astype(np.float64)

    def mk_cascade():
        return Query(spec=ds.spec(), agg=Agg.COUNT, oracle=ds.oracle(),
                     budget=BUDGET_CASCADE, proxy=ArrayOracle(proxy_labels))

    def mk_plain():
        return Query(spec=ds.spec(), agg=Agg.COUNT, oracle=ds.oracle(),
                     budget=BUDGET_PLAIN)

    calls_c: list[int] = []
    calls_p: list[int] = []

    def run_cascade(q, s):
        res = run_bas_cascade(q, seed=s, weights=w, path="dense")
        calls_c.append(q.oracle.calls)
        return res

    def run_plain(q, s):
        res = run_bas(q, seed=s, weights=w)
        calls_p.append(q.oracle.calls)
        return res

    ests_c, res_c, dt_c = repeat_method(mk_cascade, run_cascade, n_rep)
    ests_p, res_p, dt_p = repeat_method(mk_plain, run_plain, n_rep)

    rmse_c = rel_rmse(ests_c, truth)
    rmse_p = rel_rmse(ests_p, truth)
    mean_calls_c = float(np.mean(calls_c))
    mean_calls_p = float(np.mean(calls_p))
    saved = mean_calls_p / mean_calls_c

    rows = [
        row(f"cascade_rmse_b{BUDGET_CASCADE}", dt_c,
            f"rmse={rmse_c:.4f};coverage={coverage(res_c, truth):.2f};"
            f"oracle_calls={mean_calls_c:.0f}"),
        row(f"bas_rmse_b{BUDGET_PLAIN}", dt_p,
            f"rmse={rmse_p:.4f};coverage={coverage(res_p, truth):.2f};"
            f"oracle_calls={mean_calls_p:.0f}"),
        row("cascade_oracle_calls_saved", dt_c,
            f"saved={saved:.2f}x;rmse_ratio={rmse_c / rmse_p:.2f}"),
    ]
    # The acceptance gate: >= 2x fewer expensive calls at matched accuracy.
    assert saved >= 2.0, (
        f"cascade saved only {saved:.2f}x expensive oracle calls "
        f"({mean_calls_c:.0f} vs {mean_calls_p:.0f})"
    )
    assert rmse_c <= 1.1 * rmse_p, (
        f"cascade rmse {rmse_c:.4f} not matched to plain BAS {rmse_p:.4f} "
        f"at {saved:.2f}x fewer oracle calls"
    )
    return rows
