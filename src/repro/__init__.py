"""JoinML-X: approximate analytical join queries over unstructured data,
with statistical guarantees, on multi-pod TPU meshes (JAX + Pallas)."""

__version__ = "1.0.0"
