"""Logical-axis sharding rules (MaxText/t5x style) with divisibility fallback.

Model code annotates parameters and activations with *logical* axis names
("batch", "embed", "heads", "mlp", "vocab", "expert", ...).  A rule table maps
logical names to mesh axes.  A logical dim is sharded on its mesh axis only if
the dim size is divisible by the axis size — otherwise it falls back to the
next rule or replication (e.g. qwen2's 12 heads stay replicated on a 16-way
"model" axis while its d_ff=8960 shards).

Activations are annotated through :func:`shard_activation`, which is a no-op
outside a sharding context — so the same model code runs in single-device
smoke tests and in the 512-chip dry-run.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> mesh axis (or tuple of mesh axes, or list of candidate
# mesh-axis assignments tried in order).
Rules = dict

# Default training rules: FSDP over (pod, data), tensor parallel over model.
TRAIN_RULES: Rules = {
    "batch": ("pod", "data"),
    "seq": None,
    "kv_seq": None,  # attention K/V stay seq-replicated even under SP
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "vocab": "model",
    "expert": "model",
    "expert_mlp": None,
    "capacity": None,
    "data_group": ("pod", "data"),  # MoE dispatch group = one per batch shard
    "layers": None,
    "fsdp": ("pod", "data"),   # weight-shard axis for FSDP
    "rnn": "model",
    "conv": None,
    "frames": None,
    # parameter logical axes (see repro.models.partition)
    "model_dim": "model",
}

# Serving rules: batch over data; weights 2D-sharded (model x data) so even
# the 235B MoE fits per-chip HBM without FSDP gathers of full layers.
SERVE_RULES: Rules = {
    **TRAIN_RULES,
    "batch": ("pod", "data"),
    "fsdp": "data",
}

# Decode adds KV-cache sequence sharding over the model axis (decode
# activations have seq=1, which falls back to replicated automatically).
DECODE_RULES: Rules = {
    **SERVE_RULES,
    "seq": "model",
    "frames": "model",
}

# §Perf variants -------------------------------------------------------------
# Sequence parallelism: residual-stream activations sharded over the model
# axis between blocks (XLA turns the TP all-reduces into reduce-scatter +
# all-gather pairs around the sharded region).
TRAIN_RULES_SP: Rules = {**TRAIN_RULES, "seq": "model"}

# Decode without 2D weight sharding (small models: no per-layer weight
# collectives; weights must fit per-chip on the model axis alone).
DECODE_RULES_1D: Rules = {**DECODE_RULES, "fsdp": None}


class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: Optional[Rules] = None


_CTX = _Ctx()


@contextlib.contextmanager
def sharding_context(mesh: Mesh, rules: Rules):
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh, _CTX.rules = mesh, rules
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def active() -> bool:
    return _CTX.mesh is not None


def num_batch_shards() -> int:
    """How many ways the batch is sharded under the active rules (1 outside a
    sharding context).  Model code uses this to keep data-local operations
    (e.g. MoE dispatch sort) from acquiring global semantics."""
    if not active():
        return 1
    target = _CTX.rules.get("batch")
    if target is None:
        return 1
    axes = _mesh_axes_for(_CTX.mesh, target)
    out = 1
    for a in axes:
        out *= _CTX.mesh.shape[a]
    return out


def _axis_size(mesh: Mesh, axis: Union[str, tuple]) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


def _mesh_axes_for(mesh: Mesh, axis) -> tuple:
    """Filter a rule target down to axes present in the mesh."""
    if axis is None:
        return ()
    axes = axis if isinstance(axis, tuple) else (axis,)
    return tuple(a for a in axes if a in mesh.shape)


def spec_for(
    logical: Sequence[Optional[str]],
    shape: Sequence[int],
    rules: Optional[Rules] = None,
    mesh: Optional[Mesh] = None,
) -> P:
    """PartitionSpec for a value with given logical axes and shape, applying
    the divisibility fallback per dimension and never reusing a mesh axis."""
    mesh = mesh or _CTX.mesh
    rules = rules or _CTX.rules
    assert mesh is not None and rules is not None, "no sharding context"
    used: set = set()
    parts = []
    for name, dim in zip(logical, shape):
        assigned = None
        if name is not None and name in rules:
            target = rules[name]
            candidates = target if isinstance(target, list) else [target]
            for cand in candidates:
                axes = _mesh_axes_for(mesh, cand)
                axes = tuple(a for a in axes if a not in used)
                if not axes:
                    continue
                size = 1
                for a in axes:
                    size *= mesh.shape[a]
                if size > 1 and dim % size == 0:
                    assigned = axes if len(axes) > 1 else axes[0]
                    used.update(axes)
                    break
        parts.append(assigned)
    return P(*parts)


def sharding_for(logical, shape, mesh=None, rules=None) -> NamedSharding:
    mesh = mesh or _CTX.mesh
    return NamedSharding(mesh, spec_for(logical, shape, rules, mesh))


def shard_activation(x: jax.Array, logical: Sequence[Optional[str]]):
    """Annotate an intermediate with a sharding constraint (no-op outside a
    sharding context)."""
    if not active():
        return x
    spec = spec_for(logical, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(_CTX.mesh, spec))


def _shard_map_fn():
    """Version-tolerant shard_map entry point (jax.shard_map when present,
    jax.experimental.shard_map.shard_map otherwise)."""
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        return fn
    from jax.experimental.shard_map import shard_map

    return shard_map


def mesh_batch_axes(mesh: "Mesh", rules: Optional[Rules] = None) -> tuple:
    """Mesh axes the batch dimension maps to under ``rules`` (no context
    needed — used by the serving layer to size device-sharded score batches)."""
    rules = rules or SERVE_RULES
    return _mesh_axes_for(mesh, rules.get("batch"))


def mesh_batch_shards(mesh: "Mesh", rules: Optional[Rules] = None) -> int:
    """How many ways a batch dimension is sharded on ``mesh`` under ``rules``."""
    out = 1
    for a in mesh_batch_axes(mesh, rules):
        out *= mesh.shape[a]
    return out


def data_parallel(fn, mesh: "Mesh", rules: Optional[Rules] = None):
    """Wrap ``fn(params, batch)`` in a data-parallel ``shard_map``: params are
    replicated, the leading (batch) dimension of every ``batch`` leaf — and of
    the output — is sharded over the rules' batch axes.  Callers must pad the
    batch dim to a multiple of :func:`mesh_batch_shards`.  Identity when the
    rules give the mesh no batch axis (e.g. a model-only mesh)."""
    axes = mesh_batch_axes(mesh, rules)
    if not axes:
        return fn
    spec = P(axes if len(axes) > 1 else axes[0])
    sm = _shard_map_fn()
    try:
        return sm(fn, mesh=mesh, in_specs=(P(), spec), out_specs=spec,
                  check_rep=False)
    except TypeError:  # newer jax renamed/removed check_rep
        return sm(fn, mesh=mesh, in_specs=(P(), spec), out_specs=spec)


def tree_shardings(specs_tree, shapes_tree, mesh=None, rules=None):
    """Map a tree of logical-axis tuples + shapes to NamedShardings."""
    mesh = mesh or _CTX.mesh
    rules = rules or _CTX.rules
    return jax.tree.map(
        lambda logical, shape: sharding_for(logical, shape, mesh, rules),
        specs_tree,
        shapes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x
        ),
    )
