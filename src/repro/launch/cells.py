"""Dry-run cell definitions: (architecture x input shape) -> jittable step,
input ShapeDtypeStructs with shardings, and roofline trip-count hints.

Shapes (assigned): train_4k (train_step), prefill_32k (forward),
decode_32k / long_500k (serve_step: one token against a KV cache/state).
``long_500k`` requires sub-quadratic sequence mixing and is skipped for pure
full-attention architectures (documented in DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config
from repro.models import init_cache, init_params, forward, decode_step
from repro.models.config import ModelConfig
from repro.models.partition import param_logical_axes
from repro.launch.sharding import (
    DECODE_RULES,
    SERVE_RULES,
    TRAIN_RULES,
    sharding_for,
    sharding_context,
)
from repro.train import OptimizerConfig, init_opt_state, make_train_step

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

DEFAULT_MICROBATCHES = 8


def cell_supported(cfg: ModelConfig, shape_name: str) -> tuple[bool, str]:
    if shape_name == "long_500k" and not cfg.supports_long_context:
        return False, "needs sub-quadratic attention (pure full-attention arch)"
    return True, ""


def all_cells():
    out = []
    for arch in ARCHS:
        for shape in SHAPES:
            out.append((arch, shape))
    return out


# ----------------------------------------------------------------------------
# logical axes for batch inputs and caches
# ----------------------------------------------------------------------------

def _cache_logical_axes(cache) -> dict:
    base = {
        "k": ("batch", "seq", "kv_heads", "head_dim"),
        "v": ("batch", "seq", "kv_heads", "head_dim"),
        "xk": ("batch", "frames", "kv_heads", "head_dim"),
        "xv": ("batch", "frames", "kv_heads", "head_dim"),
        "s": ("batch", "heads", None, None),
        "last_time": ("batch", "embed"),
        "last_chan": ("batch", "embed"),
        "h": ("batch", "rnn"),
        "conv": ("batch", None, "rnn"),
        "window": (),
    }
    import jax.tree_util as jtu

    def spec(path, leaf):
        keys = [p.key for p in path if hasattr(p, "key")]
        name = keys[-1] if keys else ""
        if name.startswith("l") and name.endswith("_k"):
            name = "k"
        if name.startswith("l") and name.endswith("_v"):
            name = "v"
        b = base.get(name, (None,) * getattr(leaf, "ndim", 0))
        extra = getattr(leaf, "ndim", 0) - len(b)
        if extra < 0:
            b = b[-leaf.ndim:] if leaf.ndim else ()
            extra = 0
        return (None,) * extra + tuple(b)

    flat, treedef = jtu.tree_flatten_with_path(cache)
    return jtu.tree_unflatten(treedef, [spec(p, l) for p, l in flat])


def _sds(shape, dtype, logical, mesh, rules):
    return jax.ShapeDtypeStruct(
        shape, dtype, sharding=sharding_for(logical, shape, mesh, rules)
    )


def _tree_sds(shapes_tree, logical_tree, mesh, rules):
    is_spec = lambda x: isinstance(x, tuple) and all(  # noqa: E731
        isinstance(e, (str, type(None))) for e in x
    )
    return jax.tree.map(
        lambda sds, logical: jax.ShapeDtypeStruct(
            sds.shape, sds.dtype,
            sharding=sharding_for(logical, sds.shape, mesh, rules),
        ),
        shapes_tree,
        logical_tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )


def input_specs(cfg: ModelConfig, shape_name: str, mesh, rules) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell —
    weak-type-correct, shardable, no device allocation."""
    sh = SHAPES[shape_name]
    b, s = sh["batch"], sh["seq"]
    batch: dict = {}
    if sh["kind"] in ("train", "prefill"):
        s_text = s - (cfg.num_patches if cfg.num_patches else 0)
        batch["tokens"] = _sds((b, s_text), jnp.int32, ("batch", None), mesh, rules)
        if cfg.family == "encdec":
            batch["frames"] = _sds(
                (b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16,
                ("batch", None, None), mesh, rules,
            )
        if cfg.num_patches:
            batch["patches"] = _sds(
                (b, cfg.num_patches, cfg.d_model), jnp.bfloat16,
                ("batch", None, None), mesh, rules,
            )
    else:
        batch["tokens"] = _sds((b, 1), jnp.int32, ("batch", None), mesh, rules)
    return batch


@dataclasses.dataclass
class Cell:
    arch: str
    shape_name: str
    cfg: ModelConfig
    fn: object              # jittable callable
    args: tuple             # ShapeDtypeStructs (sharded)
    trip_hints: dict
    rules: dict
    num_microbatches: int = 1

    @property
    def kind(self):
        return SHAPES[self.shape_name]["kind"]


def _trip_hints(cfg: ModelConfig, shape_name: str, num_micro: int) -> dict:
    sh = SHAPES[shape_name]
    s = sh["seq"]
    kind = sh["kind"]
    hints: dict = {"accum_scan": num_micro}
    if cfg.family == "hybrid":
        hints["layers_scan"] = cfg.num_layers // len(cfg.pattern)
    elif cfg.family == "encdec":
        hints["layers_scan"] = cfg.num_layers
        hints["encoder_scan"] = cfg.encoder_layers
    else:
        hints["layers_scan"] = cfg.num_layers
    if kind in ("train", "prefill"):
        s_text = s - (cfg.num_patches or 0)
        qc = cfg.attn_q_chunk
        hints["attn_q_scan"] = max(math.ceil(s / qc), 1)
        if cfg.family == "encdec":
            hints["enc&attn_q_scan"] = max(math.ceil(cfg.encoder_seq / qc), 1)
        hints["rwkv_time_scan"] = s
        hints["rglru_time_scan"] = s
    else:
        hints["attn_q_scan"] = 1
        hints["rwkv_time_scan"] = 1
        hints["rglru_time_scan"] = 1
    return hints


def build_cell(
    arch: str,
    shape_name: str,
    mesh,
    rules_override: Optional[dict] = None,
    num_microbatches: Optional[int] = None,
    cfg_overrides: Optional[dict] = None,
) -> Cell:
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    ok, why = cell_supported(cfg, shape_name)
    if not ok:
        raise ValueError(f"{arch} x {shape_name} unsupported: {why}")
    sh = SHAPES[shape_name]
    kind = sh["kind"]

    if kind == "train":
        rules = rules_override or TRAIN_RULES
    elif kind == "prefill":
        rules = rules_override or SERVE_RULES
    else:
        rules = rules_override or DECODE_RULES

    n_micro = num_microbatches or (DEFAULT_MICROBATCHES if kind == "train" else 1)

    # abstract params (+ opt state) with shardings
    params_shape = jax.eval_shape(functools.partial(init_params, cfg), jax.random.key(0))
    p_axes = param_logical_axes(params_shape)
    params_sds = _tree_sds(params_shape, p_axes, mesh, rules)
    batch = input_specs(cfg, shape_name, mesh, rules)

    if kind == "train":
        opt_shape = jax.eval_shape(init_opt_state, params_shape)
        opt_axes = {
            "m": p_axes, "v": p_axes, "step": (),
        }
        opt_sds = _tree_sds(opt_shape, opt_axes, mesh, rules)
        step = make_train_step(cfg, OptimizerConfig(), num_microbatches=n_micro)
        args = (params_sds, opt_sds, batch)
        fn = step
    elif kind == "prefill":
        fn = functools.partial(forward, cfg)
        args = (params_sds, batch)
    else:
        cache_shape = jax.eval_shape(lambda: init_cache(cfg, sh["batch"], sh["seq"]))
        c_axes = _cache_logical_axes(cache_shape)
        cache_sds = _tree_sds(cache_shape, c_axes, mesh, rules)
        fn = functools.partial(decode_step, cfg)
        pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
        args = (params_sds, cache_sds, batch["tokens"], pos_sds)

    return Cell(
        arch=arch, shape_name=shape_name, cfg=cfg, fn=fn, args=args,
        trip_hints=_trip_hints(cfg, shape_name, n_micro), rules=rules,
        num_microbatches=n_micro,
    )


def lower_cell(cell: Cell, mesh):
    with sharding_context(mesh, cell.rules):
        lowered = jax.jit(cell.fn).lower(*cell.args)
    return lowered
