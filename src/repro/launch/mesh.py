"""Production mesh construction.

Single pod: 16 x 16 = 256 chips, axes ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips, axes ("pod", "data", "model") — the
"pod" axis composes with "data" for batch/FSDP sharding so adding pods widens
the outer axis (elastic scaling reshards checkpoints, see repro.checkpoint).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_host_mesh(model: int = 1):
    """Debug mesh over whatever devices exist (tests, examples)."""
    n = len(jax.devices())
    data = n // model
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )
