"""Production mesh construction.

Single pod: 16 x 16 = 256 chips, axes ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips, axes ("pod", "data", "model") — the
"pod" axis composes with "data" for batch/FSDP sharding so adding pods widens
the outer axis (elastic scaling reshards checkpoints, see repro.checkpoint).
"""
from __future__ import annotations

import jax


def make_mesh(shape, axes, **kwargs):
    """Version-tolerant ``jax.make_mesh``.

    Newer JAX accepts (and some idioms pass) ``axis_types``; older releases
    expose neither ``jax.sharding.AxisType`` nor the keyword.  Always request
    Auto axes when the installed JAX supports them, otherwise fall back to the
    plain call (Auto is the default there anyway).
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(
                shape, axes, axis_types=(axis_type.Auto,) * len(axes), **kwargs
            )
        except TypeError:  # make_mesh predates the axis_types kwarg
            pass
    return jax.make_mesh(shape, axes, **kwargs)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Debug mesh over whatever devices exist (tests, examples)."""
    n = len(jax.devices())
    data = n // model
    return make_mesh((data, model), ("data", "model"))
