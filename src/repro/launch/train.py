"""Training launcher: wires the full substrate (loader, train step, async
checkpointing, preemption, stragglers) for a given --arch on the host devices
(the dry-run exercises the production mesh; this driver actually steps).

    PYTHONPATH=src python -m repro.launch.train --arch joinml-oracle \
        --steps 200 --batch 16 [--resume] [--ckpt DIR]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="joinml-oracle")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--smoke-config", action="store_true", default=True)
    ap.add_argument("--grad-compression", default="none",
                    choices=("none", "bf16", "int8"))
    args = ap.parse_args()

    from repro.checkpoint.checkpoint import AsyncCheckpointer, restore_latest
    from repro.configs import get_config, get_smoke_config
    from repro.data.pipeline import ByteTokenizer, ShardedLoader
    from repro.models import init_params
    from repro.runtime.fault_tolerance import (
        PreemptionHandler,
        StragglerMonitor,
    )
    from repro.train import OptimizerConfig, init_opt_state, make_train_step

    tok = ByteTokenizer()
    cfg = (get_smoke_config(args.arch, vocab_size=tok.vocab_size)
           if args.smoke_config else get_config(args.arch))
    print(f"[train] {cfg.name}: {cfg.param_count()/1e6:.1f}M params "
          f"({len(jax.devices())} devices)")
    params = init_params(cfg, jax.random.key(0))
    opt = init_opt_state(params)
    ocfg = OptimizerConfig(peak_lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                           decay_steps=args.steps,
                           grad_compression=args.grad_compression)
    step_fn = jax.jit(make_train_step(cfg, ocfg, args.microbatches))

    def batch_fn(rng):
        b = {"tokens": rng.integers(0, cfg.vocab_size, (args.batch, args.seq))}
        if cfg.family == "encdec":
            b["frames"] = rng.standard_normal(
                (args.batch, cfg.encoder_seq, cfg.d_model)
            ).astype(np.float32)
        if cfg.num_patches:
            b["patches"] = rng.standard_normal(
                (args.batch, cfg.num_patches, cfg.d_model)
            ).astype(np.float32)
        return b

    loader = ShardedLoader(batch_fn, args.batch, seed=13)
    ckpt = AsyncCheckpointer(args.ckpt, keep_last=2)
    preempt = PreemptionHandler()
    preempt.install()
    mon = StragglerMonitor()

    restored, manifest = restore_latest(args.ckpt, {"params": params, "opt": opt})
    start = 0
    if restored is not None:
        params, opt = restored["params"], restored["opt"]
        start = int(manifest["step"])
        print(f"[train] resumed at step {start}")

    for _ in range(start, args.steps):
        t0 = time.time()
        step, batch = next(loader)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt, m = step_fn(params, opt, batch)
        mon.record(step, time.time() - t0)
        if step % 20 == 0:
            print(f"[train] step {step} loss={float(m['loss']):.4f} "
                  f"lr={float(m['lr']):.2e}")
        if (step + 1) % args.ckpt_every == 0 or preempt.preempted:
            ckpt.save(step + 1, {"params": params, "opt": opt})
        if preempt.preempted:
            print("[train] preempted; checkpoint saved, exiting")
            break
    ckpt.save(args.steps, {"params": params, "opt": opt})
    ckpt.wait()
    loader.close()
    print(f"[train] done; stragglers flagged: {len(mon.reports)}")


if __name__ == "__main__":
    main()
