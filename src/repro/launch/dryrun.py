import os

_DUMP_DIR = f"/tmp/repro_xla_dump_{os.getpid()}"
# The dry-run (and ONLY the dry-run) needs 512 placeholder devices, plus an
# HLO pass dump: the CPU backend's float normalization rewrites bf16 buffers
# to f32 in the final executable, so roofline byte/collective terms are read
# from the post-SPMD-partitioning module (true dtypes, per-device shapes).
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    f"--xla_dump_to={_DUMP_DIR} "
    "--xla_dump_hlo_pass_re=spmd-partitioning"
)

"""Multi-pod dry-run: lower + compile every (architecture x input shape) cell
on the production mesh (16x16 single pod and 2x16x16 multi-pod), record
memory_analysis / cost_analysis, and derive the roofline terms from the
optimized HLO (repro.roofline).

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out experiments/dryrun]
  python -m repro.launch.dryrun --all --both-meshes
"""
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import gzip  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             save_hlo: bool = False, rules_name: str = None,
             num_microbatches: int = None, cfg_overrides: dict = None,
             tag: str = "") -> dict:
    from repro.configs import get_config
    from repro.launch import cells as C
    from repro.launch.mesh import make_production_mesh
    from repro.roofline import hw
    from repro.roofline.hlo_analysis import analyze
    from repro.roofline.report import model_flops

    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "rules": rules_name or "default", "status": "?", "tag": tag,
        "cfg_overrides": cfg_overrides or {},
        "num_microbatches": num_microbatches,
    }
    cfg = get_config(arch)
    ok, why = C.cell_supported(cfg, shape_name)
    if not ok:
        rec.update(status="skipped", reason=why)
        _save(rec, out_dir)
        return rec
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        n_chips = int(len(mesh.devices.reshape(-1)))
        rules = _resolve_rules(rules_name)
        t0 = time.time()
        cell = C.build_cell(
            arch, shape_name, mesh, rules_override=rules,
            num_microbatches=num_microbatches, cfg_overrides=cfg_overrides,
        )
        lowered = C.lower_cell(cell, mesh)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        hlo = _grab_spmd_hlo() or compiled.as_text()
        cost = analyze(hlo, cell.trip_hints)
        # kernel-adjusted: attention score traffic is VMEM-resident in the
        # validated Pallas flash kernel (see roofline.hlo_analysis docstring)
        cost_adj = analyze(hlo, cell.trip_hints, vmem_scopes=("attn_q_scan",))

        sh = C.SHAPES[shape_name]
        mf = model_flops(cfg, sh)
        compute_s = cost.flops / hw.PEAK_FLOPS_BF16
        memory_s = cost.bytes / hw.HBM_BW
        collective_s = cost.collective_bytes / hw.ICI_BW
        dominant = max(
            ("compute", compute_s), ("memory", memory_s),
            ("collective", collective_s), key=lambda kv: kv[1],
        )[0]
        rec.update(
            status="ok",
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            memory=dict(
                argument_bytes=ma.argument_size_in_bytes,
                output_bytes=ma.output_size_in_bytes,
                temp_bytes=ma.temp_size_in_bytes,
                total_bytes=(ma.argument_size_in_bytes + ma.temp_size_in_bytes),
                hbm_fraction=round(
                    (ma.argument_size_in_bytes + ma.temp_size_in_bytes)
                    / hw.HBM_BYTES, 3),
            ),
            xla_cost=dict(
                flops=ca.get("flops", 0.0),
                bytes=ca.get("bytes accessed", 0.0),
            ),
            hlo_flops=cost.flops,
            hlo_bytes=cost.bytes,
            collective_bytes=cost.collective_bytes,
            collective_ops=cost.collective_ops,
            unresolved_whiles=cost.unresolved_whiles[:8],
            roofline=dict(
                compute_s=compute_s,
                memory_s=memory_s,
                collective_s=collective_s,
                dominant=dominant,
                bound_s=max(compute_s, memory_s, collective_s),
            ),
            roofline_kernel_adj=dict(
                compute_s=cost_adj.flops / hw.PEAK_FLOPS_BF16,
                memory_s=cost_adj.bytes / hw.HBM_BW,
                collective_s=cost_adj.collective_bytes / hw.ICI_BW,
            ),
            model_flops=mf,
            model_flops_per_chip=mf / n_chips,
            useful_compute_ratio=(mf / n_chips) / cost.flops if cost.flops else 0.0,
            trip_hints=cell.trip_hints,
            n_chips=n_chips,
        )
        if save_hlo:
            fn = os.path.join(out_dir, f"{_slug(arch)}_{shape_name}_{mesh_name}.hlo.gz")
            with gzip.open(fn, "wt") as f:
                f.write(hlo)
    except Exception as e:  # noqa: BLE001
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    _save(rec, out_dir)
    return rec


def _grab_spmd_hlo():
    """Return (and consume) the newest post-SPMD-partitioning pass dump."""
    import glob

    files = glob.glob(os.path.join(_DUMP_DIR, "*after_spmd-partitioning*"))
    if not files:
        return None
    newest = max(files, key=os.path.getmtime)
    with open(newest) as f:
        text = f.read()
    for fn in files:  # keep the dump dir from growing across cells
        try:
            os.remove(fn)
        except OSError:
            pass
    return text


def _resolve_rules(name):
    if not name or name == "default":
        return None
    from repro.launch import sharding as S

    return getattr(S, name)


def _slug(arch):
    return arch.replace(".", "_").replace("/", "_")


def _save(rec, out_dir):
    os.makedirs(out_dir, exist_ok=True)
    suffix = "" if rec.get("rules", "default") == "default" else f"_{rec['rules']}"
    if rec.get("tag"):
        suffix += f"_{rec['tag']}"
    fn = os.path.join(
        out_dir, f"{_slug(rec['arch'])}_{rec['shape']}_{rec['mesh']}{suffix}.json"
    )
    with open(fn, "w") as f:
        json.dump(rec, f, indent=1, default=float)


def _parse_cfg(kvs):
    out = {}
    for kv in kvs or []:
        k, v = kv.split("=", 1)
        if v in ("True", "true"):
            v = True
        elif v in ("False", "false"):
            v = False
        else:
            try:
                v = int(v)
            except ValueError:
                try:
                    v = float(v)
                except ValueError:
                    pass
        out[k] = v
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--rules", default=None, help="sharding rule set name")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--cfg", action="append", default=None,
                    help="model-config override key=value (repeatable)")
    ap.add_argument("--tag", default="", help="variant tag for output files")
    args = ap.parse_args()
    cfg_overrides = _parse_cfg(args.cfg)

    from repro.launch import cells as C

    if args.all:
        todo = C.all_cells()
    else:
        assert args.arch and args.shape, "--arch and --shape or --all"
        todo = [(args.arch, args.shape)]
    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    n_ok = n_skip = n_err = 0
    for arch, shape in todo:
        for mp in meshes:
            rec = run_cell(arch, shape, mp, args.out, args.save_hlo, args.rules,
                           args.microbatches, cfg_overrides, args.tag)
            tag = rec["status"]
            if tag == "ok":
                n_ok += 1
                r = rec["roofline"]
                print(
                    f"[ok]   {arch:24s} {shape:12s} {rec['mesh']:8s} "
                    f"compile={rec['compile_s']:7.1f}s "
                    f"C={r['compute_s']:.3e} M={r['memory_s']:.3e} "
                    f"X={r['collective_s']:.3e} dom={r['dominant']:10s} "
                    f"mem/chip={rec['memory']['total_bytes']/2**30:.2f}GiB",
                    flush=True,
                )
            elif tag == "skipped":
                n_skip += 1
                print(f"[skip] {arch:24s} {shape:12s} {rec['mesh']:8s} {rec['reason']}",
                      flush=True)
            else:
                n_err += 1
                print(f"[ERR]  {arch:24s} {shape:12s} {rec['mesh']:8s} {rec['error']}",
                      flush=True)
    print(f"done: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
