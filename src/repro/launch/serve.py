"""Serving launcher: continuous-batching decode or batched pair scoring
(the Oracle endpoint) for a given --arch on the host devices.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --mode decode --requests 8
    PYTHONPATH=src python -m repro.launch.serve --arch joinml-oracle \
        --mode score --pairs 64
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--mode", choices=("decode", "score"), default="decode")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--pairs", type=int, default=64)
    ap.add_argument("--batch-slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--shard", action="store_true",
                    help="data-parallel pair scoring over all host devices")
    args = ap.parse_args()

    from repro.configs import get_smoke_config
    from repro.data.pipeline import ByteTokenizer, pair_example
    from repro.models import init_params
    from repro.serve.serve_loop import ContinuousBatcher, PairScorer, Request

    tok = ByteTokenizer()
    cfg = get_smoke_config(args.arch, vocab_size=tok.vocab_size)
    params = init_params(cfg, jax.random.key(0))
    print(f"[serve] {cfg.name} ({cfg.param_count()/1e6:.1f}M) mode={args.mode}")

    if args.mode == "decode":
        cb = ContinuousBatcher(cfg, params, batch_size=args.batch_slots,
                               max_len=128, eos_id=tok.EOS)
        rng = np.random.default_rng(0)
        for i in range(args.requests):
            cb.submit(Request(
                uid=i,
                prompt=np.array([tok.BOS] + tok.encode(f"req {i}: ")[:12], np.int32),
                max_new_tokens=args.max_new,
            ))
        t0 = time.time()
        done = cb.run_until_done()
        dt = time.time() - t0
        toks = sum(len(r.out_tokens) for r in done)
        print(f"[serve] {len(done)} requests, {toks} tokens, {dt:.2f}s "
              f"({toks/max(dt,1e-9):.1f} tok/s)")
    else:
        records = [f"entity {i % 16} record {i}" for i in range(64)]

        def tok_pair(pair):
            t, _ = pair_example(tok, records[pair[0]], records[pair[1]], None, 48)
            return t[t != tok.PAD]

        mesh = None
        if args.shard:
            from repro.launch.mesh import make_host_mesh

            mesh = make_host_mesh()
            print(f"[serve] sharding batch over mesh {dict(mesh.shape)}")
        scorer = PairScorer(cfg, params, tok_pair, tok.YES, tok.NO, max_len=48,
                            batch_size=16, mesh=mesh)
        rng = np.random.default_rng(0)
        pairs = rng.integers(0, 64, size=(args.pairs, 2))
        t0 = time.time()
        p = scorer.score(pairs)
        dt = time.time() - t0
        print(f"[serve] scored {len(pairs)} pairs in {dt:.2f}s "
              f"({len(pairs)/max(dt,1e-9):.1f} pairs/s, "
              f"{scorer.forward_batches} device batches), mean={p.mean():.3f}")


if __name__ == "__main__":
    main()
