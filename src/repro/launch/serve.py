"""Serving launcher: continuous-batching decode, batched pair scoring (the
Oracle endpoint), the in-process multi-query oracle service, or one role of
a multi-host serving fleet, for a given --arch on the host devices.

In-process modes::

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --mode decode --requests 8
    PYTHONPATH=src python -m repro.launch.serve --arch joinml-oracle \
        --mode score --pairs 64
    PYTHONPATH=src python -m repro.launch.serve --arch joinml-oracle \
        --mode service --queries 4 --budget 300

Multi-host modes (see docs/serving.md for the topology)::

    # host A: a worker (serves its scorer over TCP, no downstream)
    ... serve --mode worker --port 7432
    # host B: the front server; shards super-batches over itself + host A
    ... serve --mode server --port 7431 --worker-hosts hostA:7432
    # any host: a client process running BAS queries against the fleet
    ... serve --mode client --connect hostB:7431 --queries 4 --budget 300

``--mode service`` runs concurrent BAS queries against ONE served scorer
through an :class:`repro.serve.oracle_service.OracleService`: each query's
pilot/blocking/top-up flushes coalesce across queries into super-batches,
and with ``--shard`` every super-batch additionally shards its batch
dimension over the host mesh (``launch.sharding.data_parallel``).
``--mode server|worker`` expose exactly that machinery over TCP
(:class:`repro.serve.transport.OracleServiceServer`); ``--mode client``
runs the same BAS queries through :class:`repro.serve.transport.RemoteOracle`
— plan/commit stay client-side, only labelling crosses the network.
``--label-store-mb``/``--label-store-root`` give the service/server/worker
modes a shared cross-query label store (charge-once oracle caching, see
``repro.serve.label_store``); shutdown prints window fill/dedup ratios and
the store hit rate from the unified ``snapshot()`` surface.  ``--tracker
memory|jsonl`` attaches a :mod:`repro.obs` metrics tracker (JSON-lines
output via ``--tracker-out``), and ``--deadline-ms`` puts the service-mode
queries under deadline-based admission control (docs/serving.md).

Index maintenance modes (no model; see ``repro.core.index``)::

    # one cold sweep -> content-addressed artifact under --index-root
    ... serve --mode build-index --index-root runs/index --n-side 256
    # append rows to one table, version-bumped delta maintenance
    ... serve --mode refresh-index --index-root runs/index \
        --append-rows 32 --append-table 1

``--mode build-index`` builds a persistent stratification index (one fused
sweep) over ``--tables`` (comma-separated ``.npy`` embedding files) or the
synthetic demo pair, and saves it atomically.  ``--mode refresh-index``
loads the newest stored version and applies incremental ``append_rows``
maintenance — cost proportional to the appended rows, version bumped so
stale readers detect drift.  Services point an
:class:`repro.core.index.IndexStore` at the same ``--index-root`` to serve
warm queries from these artifacts.
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def _make_scorer(args, cfg, params, tok, records, batch_size: int):
    """Shared scorer construction for the score/service modes: record-pair
    tokenizer + optional data-parallel mesh sharding (--shard)."""
    from repro.data.pipeline import pair_example
    from repro.serve.serve_loop import PairScorer

    def tok_pair(pair):
        t, _ = pair_example(tok, records[pair[0]], records[pair[1]], None, 48)
        return t[t != tok.PAD]

    mesh = None
    if args.shard:
        from repro.launch.mesh import make_host_mesh

        mesh = make_host_mesh()
        print(f"[serve] sharding score batches over mesh {dict(mesh.shape)}")
    return PairScorer(cfg, params, tok_pair, tok.YES, tok.NO, max_len=48,
                      batch_size=batch_size, mesh=mesh)


def _run_client(args) -> None:
    """``--mode client``: BAS queries against a remote serving fleet.  Builds
    the same synthetic join the demo server scores (seeded, so every process
    agrees on table sizes), runs ``--queries`` concurrent queries through
    per-query :class:`RemoteOracle`\\ s, and prints estimates + latency."""
    from repro.core import Agg, BASConfig, Query, run_bas
    from repro.data import make_clustered_tables
    from repro.serve.oracle_service import serve_queries
    from repro.serve.transport import RemoteOracle, parse_address

    address = parse_address(args.connect)
    n = args.n_side
    ds = make_clustered_tables(n, n, n_entities=max(2 * n // 3, 4),
                               noise=0.4, seed=0)
    oracles = [RemoteOracle(address, args.group) for _ in range(args.queries)]
    queries = [Query(spec=ds.spec(), agg=Agg.COUNT, oracle=o,
                     budget=args.budget) for o in oracles]
    lat = np.zeros(args.queries)

    def job(i: int):
        t0 = time.time()
        try:
            return run_bas(queries[i], BASConfig(n_bootstrap=100), seed=i)
        finally:
            lat[i] = time.time() - t0
            oracles[i].close()       # free the server's window bookkeeping

    t0 = time.time()
    results = serve_queries(None, [lambda i=i: job(i)
                                   for i in range(args.queries)])
    dt = time.time() - t0
    labels = sum(o.calls for o in oracles)
    reconnects = sum(o.conn.reconnects for o in oracles)
    print(f"[client] {args.queries} queries against "
          f"{address[0]}:{address[1]}, {labels} labels in {dt:.2f}s "
          f"({labels/max(dt,1e-9):.1f} labels/s, {reconnects} reconnects); "
          f"p50={np.quantile(lat, 0.5)*1e3:.0f}ms "
          f"p99={np.quantile(lat, 0.99)*1e3:.0f}ms")
    for i, r in enumerate(results):
        print(f"[client]   q{i}: estimate={r.estimate:.1f} "
              f"ci=[{r.ci.lo:.1f}, {r.ci.hi:.1f}] calls={oracles[i].calls}")


def _index_tables(args) -> list:
    """Embedding tables for the index modes: ``--tables a.npy,b.npy`` or the
    same seeded synthetic pair the demo server scores."""
    if args.tables:
        return [np.load(p.strip()) for p in args.tables.split(",")]
    from repro.data import make_clustered_tables

    n = args.n_side
    ds = make_clustered_tables(n, n, n_entities=max(2 * n // 3, 4),
                               noise=0.4, seed=0)
    return [np.asarray(e, np.float32) for e in ds.spec().embeddings]


def _run_build_index(args) -> None:
    """``--mode build-index``: one cold sweep -> saved artifact."""
    from repro.checkpoint.index_io import save_index
    from repro.core.index import build_index

    embs = _index_tables(args)
    t0 = time.time()
    art = build_index(embs, n_bins=args.bins, precision=args.precision)
    path = save_index(args.index_root, art)
    print(f"[index] built key={art.key[:16]}... v{art.version} over tables "
          f"{art.sizes} in {time.time()-t0:.2f}s "
          f"(kernel={art.kernel}, {art.nbytes/1e6:.1f} MB) -> {path}")


def _run_refresh_index(args) -> None:
    """``--mode refresh-index``: incremental append maintenance on the
    newest stored version (delta-proportional cost, version bump)."""
    from repro.checkpoint.index_io import list_indexes, load_index, save_index
    from repro.core.index import append_rows
    from repro.core.similarity import normalize

    key = args.key
    if not key:
        stored = list_indexes(args.index_root)
        if not stored:
            raise SystemExit(f"[index] nothing stored under {args.index_root}")
        # newest lineage: append_rows re-keys (content-addressing) but keeps
        # bumping version, so the highest version is the latest refresh
        key = max(stored, key=lambda s: s["version"])["key"]
    art = load_index(args.index_root, key)
    if args.append_file:
        new_rows = np.load(args.append_file)
    else:
        rng = np.random.default_rng(art.version)
        d = art.embeddings[args.append_table].shape[1]
        new_rows = normalize(rng.standard_normal((args.append_rows, d)))
    t0 = time.time()
    art2 = append_rows(art, args.append_table, new_rows)
    path = save_index(args.index_root, art2)
    print(f"[index] refreshed key={art.key[:16]}... -> {art2.key[:16]}... "
          f"v{art.version}->v{art2.version}: +{len(new_rows)} rows on table "
          f"{args.append_table}, {art2.stats['last_delta_blocks']} delta "
          f"tile(s) in {time.time()-t0:.2f}s -> {path}")


def _make_label_store(args):
    """Optional service-resident :class:`repro.serve.label_store.LabelStore`
    for the service/server/worker modes: ``--label-store-mb 0`` (the default)
    disables it; ``--label-store-root`` additionally persists stable segments
    across restarts."""
    if not args.label_store_mb and not args.label_store_root:
        return None
    from repro.serve.label_store import LabelStore

    store = LabelStore(max_bytes=int((args.label_store_mb or 256) * 2**20),
                       root=args.label_store_root or None)
    where = args.label_store_root or "memory-only"
    print(f"[serve] label store: {args.label_store_mb or 256} MB budget, "
          f"root={where}, {store.loads} segment(s) hydrated")
    return store


def _make_tracker(args):
    """Tracker for the service/server/worker modes: ``--tracker none`` (the
    default, zero-cost hooks), ``memory`` (in-process snapshot), or ``jsonl``
    (append every signal to ``--tracker-out``)."""
    from repro.obs import make_tracker

    tracker = make_tracker(args.tracker,
                           path=args.tracker_out or "tracker.jsonl")
    if args.tracker == "jsonl":
        print(f"[serve] tracker: jsonl -> {tracker.path}")
    return tracker


def _start_metrics(args, *sources):
    """``--metrics-port N``: start the OpenMetrics ``/metrics`` endpoint
    over the given ``snapshot()`` sources (0, the default, disables it).
    Returns the running :class:`repro.obs.MetricsExporter` or ``None``."""
    if not getattr(args, "metrics_port", 0):
        return None
    from repro.obs import MetricsExporter

    exp = MetricsExporter(list(sources), host=args.host,
                          port=args.metrics_port).start()
    host, port = exp.address
    print(f"[serve] metrics: http://{host}:{port}/metrics")
    return exp


def _print_service_stats(role: str, snap: dict) -> None:
    """Shutdown observability lines shared by the fleet and service modes —
    read exclusively from the unified ``snapshot()`` surface.  The *_recent
    ratios are last-N window means (steady state), unlike the lifetime
    ratios that average warmup in forever."""
    charges_saved = (snap.get("label_store.shared", 0.0)
                     + snap.get("label_store.hits", 0.0))
    print(f"[{role}] windows: "
          f"fill={snap.get('service.window.fill_ratio', 0.0):.2f} "
          f"(recent={snap.get('service.window.fill_ratio_recent', 0.0):.2f}) "
          f"dedup={snap.get('service.window.dedup_ratio', 0.0):.2f} "
          f"(recent={snap.get('service.window.dedup_ratio_recent', 0.0):.2f}); "
          f"store: hit_rate={snap.get('label_store.hit_rate', 0.0):.2f} "
          f"charges_saved={charges_saved:.0f}")
    if snap.get("service.admission.rejected") or snap.get(
            "service.worker.deaths"):
        print(f"[{role}] admission: "
              f"rejected={snap.get('service.admission.rejected', 0.0):.0f} "
              f"rate={snap.get('service.rate_rows_per_s', 0.0):.0f} rows/s; "
              f"workers: deaths={snap.get('service.worker.deaths', 0.0):.0f} "
              f"rejoins={snap.get('service.worker.rejoins', 0.0):.0f}")
    for line in _service_class_lines(snap):
        print(f"[{role}] {line}")


def _service_class_lines(snap: dict) -> list[str]:
    """One line per deadline/query class seen by the service: flush-latency
    histogram percentiles (``service.class.<name>.flush_ms.*``, written by a
    tracker) and the class's own admission EWMA
    (``service.class.<name>.rate_rows_per_s``)."""
    classes: set[str] = set()
    for key in snap:
        if key.startswith("service.class."):
            rest = key[len("service.class."):]
            classes.add(rest.rsplit(".", 1)[0].split(".")[0])
    lines = []
    for qc in sorted(classes):
        prefix = f"service.class.{qc}"
        parts = [f"class {qc!r}:"]
        if f"{prefix}.flush_ms.count" in snap:
            parts.append(
                f"flushes={snap[f'{prefix}.flush_ms.count']:.0f} "
                f"p50={snap.get(f'{prefix}.flush_ms.p50', 0.0):.1f}ms "
                f"p99={snap.get(f'{prefix}.flush_ms.p99', 0.0):.1f}ms"
            )
        if f"{prefix}.rate_rows_per_s" in snap:
            parts.append(
                f"rate={snap[f'{prefix}.rate_rows_per_s']:.0f} rows/s"
            )
        if len(parts) > 1:
            lines.append(" ".join(parts))
    return lines


def _run_fleet_role(args, scorer) -> None:
    """``--mode server|worker``: expose the scorer over TCP.  A worker is a
    server with no downstream hosts; ``--worker-hosts`` turns a server into
    the fleet front that shards super-batches across hosts."""
    from repro.serve.transport import (OracleServiceServer, parse_address,
                                       scorer_group)

    role = args.mode
    tracker = _make_tracker(args)
    server = OracleServiceServer(
        {args.group: scorer_group(scorer, threshold=0.5)},
        host=args.host, port=args.port,
        workers=args.workers, max_wait_ms=8.0,
        label_store=_make_label_store(args),
        tracker=tracker,
    )
    host, port = server.address
    print(f"[{role}] group {args.group!r} listening on {host}:{port}")
    metrics = _start_metrics(args, server.service.snapshot)
    for spec in (args.worker_hosts.split(",") if args.worker_hosts else []):
        w = server.register_worker(parse_address(spec))
        print(f"[{role}] registered worker {w.address[0]}:{w.address[1]} "
              f"groups={sorted(w.groups)}")
    try:
        deadline = time.time() + args.duration if args.duration else None
        while deadline is None or time.time() < deadline:
            time.sleep(0.2)
    except KeyboardInterrupt:
        pass
    finally:
        snap = server.service.snapshot()
        if metrics is not None:
            metrics.stop()
        server.close()
        tracker.close()
        print(f"[{role}] shut down; {snap['service.windows']:.0f} windows, "
              f"{snap['service.rows_labelled']:.0f} rows labelled, "
              f"{snap['service.remote_shards']:.0f} remote shards")
        _print_service_stats(role, snap)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--mode",
                    choices=("decode", "score", "service",
                             "server", "client", "worker",
                             "build-index", "refresh-index"),
                    default="decode")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--pairs", type=int, default=64)
    ap.add_argument("--batch-slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--queries", type=int, default=4,
                    help="service/client mode: concurrent BAS queries")
    ap.add_argument("--budget", type=int, default=300,
                    help="service/client mode: oracle budget per query")
    ap.add_argument("--workers", type=int, default=1,
                    help="service/server/worker mode: scorer worker threads")
    ap.add_argument("--shard", action="store_true",
                    help="data-parallel pair scoring over all host devices")
    ap.add_argument("--host", default="127.0.0.1",
                    help="server/worker mode: bind address")
    ap.add_argument("--port", type=int, default=0,
                    help="server/worker mode: bind port (0 = ephemeral)")
    ap.add_argument("--connect", default="127.0.0.1:7431",
                    help="client mode: front server host:port")
    ap.add_argument("--worker-hosts", default="",
                    help="server mode: comma-separated worker host:port list")
    ap.add_argument("--group", default="default",
                    help="server/worker/client mode: wire group name")
    ap.add_argument("--label-store-mb", type=float, default=0.0,
                    help="service/server/worker mode: shared label store "
                         "memory budget in MB (0 = disabled)")
    ap.add_argument("--label-store-root", default="",
                    help="service/server/worker mode: persist stable label "
                         "store segments under this directory")
    ap.add_argument("--tracker", choices=("none", "memory", "jsonl"),
                    default="none",
                    help="service/server/worker mode: metrics tracker "
                         "(repro.obs) — none keeps the zero-cost hooks")
    ap.add_argument("--metrics-port", type=int, default=0,
                    help="service/server/worker mode: serve the unified "
                         "snapshot as OpenMetrics on this port (0=off)")
    ap.add_argument("--tracker-out", default="",
                    help="jsonl tracker output path (default tracker.jsonl)")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="service mode: declare a deadline class for the "
                         "queries — flushes are shed with AdmissionRejected "
                         "when the queue predicts a miss (0 = no deadline)")
    ap.add_argument("--n-side", type=int, default=48,
                    help="server/client mode: synthetic table side length")
    ap.add_argument("--duration", type=float, default=0.0,
                    help="server/worker mode: seconds to serve (0 = forever)")
    ap.add_argument("--index-root", default="runs/index",
                    help="build-index/refresh-index mode: artifact store dir")
    ap.add_argument("--tables", default="",
                    help="build-index mode: comma-separated .npy embedding "
                         "files (default: synthetic --n-side pair)")
    ap.add_argument("--bins", type=int, default=4096,
                    help="build-index mode: sweep histogram bins")
    ap.add_argument("--precision", default="fp32",
                    help="build-index mode: sweep precision "
                         "(fp32 | bf16 | int8)")
    ap.add_argument("--key", default="",
                    help="refresh-index mode: content key (default: newest "
                         "stored index)")
    ap.add_argument("--append-rows", type=int, default=32,
                    help="refresh-index mode: synthetic rows to append")
    ap.add_argument("--append-table", type=int, default=1, choices=(0, 1),
                    help="refresh-index mode: table receiving the rows")
    ap.add_argument("--append-file", default="",
                    help="refresh-index mode: .npy of rows to append "
                         "(overrides --append-rows)")
    args = ap.parse_args()

    if args.mode == "client":
        # the client holds no model — plan/commit are local, labelling is
        # remote — so skip scorer construction entirely
        _run_client(args)
        return
    if args.mode == "build-index":
        _run_build_index(args)
        return
    if args.mode == "refresh-index":
        _run_refresh_index(args)
        return

    import jax

    from repro.configs import get_smoke_config
    from repro.data.pipeline import ByteTokenizer
    from repro.models import init_params
    from repro.serve.serve_loop import ContinuousBatcher, Request

    tok = ByteTokenizer()
    cfg = get_smoke_config(args.arch, vocab_size=tok.vocab_size)
    params = init_params(cfg, jax.random.key(0))
    print(f"[serve] {cfg.name} ({cfg.param_count()/1e6:.1f}M) mode={args.mode}")

    if args.mode == "decode":
        cb = ContinuousBatcher(cfg, params, batch_size=args.batch_slots,
                               max_len=128, eos_id=tok.EOS)
        rng = np.random.default_rng(0)
        for i in range(args.requests):
            cb.submit(Request(
                uid=i,
                prompt=np.array([tok.BOS] + tok.encode(f"req {i}: ")[:12], np.int32),
                max_new_tokens=args.max_new,
            ))
        t0 = time.time()
        done = cb.run_until_done()
        dt = time.time() - t0
        toks = sum(len(r.out_tokens) for r in done)
        print(f"[serve] {len(done)} requests, {toks} tokens, {dt:.2f}s "
              f"({toks/max(dt,1e-9):.1f} tok/s)")
    elif args.mode in ("server", "worker"):
        n_side = args.n_side
        records = [f"entity record {i:03d}" for i in range(n_side)]
        scorer = _make_scorer(args, cfg, params, tok, records, batch_size=32)
        _run_fleet_role(args, scorer)
    elif args.mode == "service":
        from repro.core import Agg, BASConfig, ModelOracle, Query, run_bas
        from repro.data import make_clustered_tables
        from repro.serve.oracle_service import OracleService, serve_queries

        n_side = 48
        ds = make_clustered_tables(n_side, n_side, n_entities=64, noise=0.4,
                                   seed=0)
        records = [f"entity record {i:03d}" for i in range(n_side)]
        scorer = _make_scorer(args, cfg, params, tok, records, batch_size=32)
        cfg_bas = BASConfig(n_bootstrap=100)
        # named oracles share one LabelStore segment group (an unnamed
        # ModelOracle's group is process-local and can never be persisted)
        oracles = [ModelOracle(scorer, threshold=0.5, name=args.group)
                   for _ in range(args.queries)]
        queries = [
            Query(spec=ds.spec(), agg=Agg.COUNT, oracle=o, budget=args.budget)
            for o in oracles
        ]
        lat = np.zeros(args.queries)
        tracker = _make_tracker(args)
        shed = [0]
        with OracleService(workers=args.workers, max_wait_ms=8.0,
                           label_store=_make_label_store(args),
                           tracker=tracker) as svc:
            from repro.serve.oracle_service import AdmissionRejected

            metrics = _start_metrics(args, svc.snapshot)
            svc.attach(*oracles,
                       deadline_ms=args.deadline_ms or None)

            def job(i: int):
                t0 = time.time()
                try:
                    while True:
                        try:
                            return run_bas(queries[i], cfg_bas, seed=i)
                        except AdmissionRejected as e:
                            # typed + retryable: ledger untouched, cache kept,
                            # so re-running the (deterministic) query is safe
                            shed[0] += 1
                            time.sleep(min(e.predicted_ms, 1e3) / 1e3)
                finally:
                    lat[i] = time.time() - t0
                    svc.detach(oracles[i])

            t0 = time.time()
            results = serve_queries(
                svc, [lambda i=i: job(i) for i in range(args.queries)]
            )
            dt = time.time() - t0
            snap = svc.snapshot()
            if metrics is not None:
                metrics.stop()
        tracker.close()
        labels = sum(o.calls for o in oracles)
        print(f"[serve] {args.queries} concurrent queries, {labels} oracle "
              f"labels in {dt:.2f}s ({labels/max(dt,1e-9):.1f} labels/s, "
              f"{scorer.forward_batches} device batches)")
        print(f"[serve] p50={np.quantile(lat, 0.5)*1e3:.0f}ms "
              f"p99={np.quantile(lat, 0.99)*1e3:.0f}ms per query; "
              f"service: {snap['service.windows']:.0f} windows, "
              f"{snap['service.segments_per_window']:.2f} flushes/window"
              + (f"; {shed[0]} flush(es) shed and retried" if shed[0] else ""))
        _print_service_stats("serve", snap)
        for i, r in enumerate(results):
            print(f"[serve]   q{i}: estimate={r.estimate:.1f} "
                  f"ci=[{r.ci.lo:.1f}, {r.ci.hi:.1f}] "
                  f"calls={oracles[i].calls}")
    else:
        records = [f"entity {i % 16} record {i}" for i in range(64)]
        scorer = _make_scorer(args, cfg, params, tok, records, batch_size=16)
        rng = np.random.default_rng(0)
        pairs = rng.integers(0, 64, size=(args.pairs, 2))
        t0 = time.time()
        p = scorer.score(pairs)
        dt = time.time() - t0
        print(f"[serve] scored {len(pairs)} pairs in {dt:.2f}s "
              f"({len(pairs)/max(dt,1e-9):.1f} pairs/s, "
              f"{scorer.forward_batches} device batches), mean={p.mean():.3f}")


if __name__ == "__main__":
    main()
