"""Serving launcher: continuous-batching decode, batched pair scoring (the
Oracle endpoint), or the full multi-query oracle service for a given --arch
on the host devices.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --mode decode --requests 8
    PYTHONPATH=src python -m repro.launch.serve --arch joinml-oracle \
        --mode score --pairs 64
    PYTHONPATH=src python -m repro.launch.serve --arch joinml-oracle \
        --mode service --queries 4 --budget 300

``--mode service`` runs concurrent BAS queries against ONE served scorer
through an :class:`repro.serve.oracle_service.OracleService`: each query's
pilot/blocking/top-up flushes coalesce across queries into super-batches,
and with ``--shard`` every super-batch additionally shards its batch
dimension over the host mesh (``launch.sharding.data_parallel``).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np


def _make_scorer(args, cfg, params, tok, records, batch_size: int):
    """Shared scorer construction for the score/service modes: record-pair
    tokenizer + optional data-parallel mesh sharding (--shard)."""
    from repro.data.pipeline import pair_example
    from repro.serve.serve_loop import PairScorer

    def tok_pair(pair):
        t, _ = pair_example(tok, records[pair[0]], records[pair[1]], None, 48)
        return t[t != tok.PAD]

    mesh = None
    if args.shard:
        from repro.launch.mesh import make_host_mesh

        mesh = make_host_mesh()
        print(f"[serve] sharding score batches over mesh {dict(mesh.shape)}")
    return PairScorer(cfg, params, tok_pair, tok.YES, tok.NO, max_len=48,
                      batch_size=batch_size, mesh=mesh)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--mode", choices=("decode", "score", "service"),
                    default="decode")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--pairs", type=int, default=64)
    ap.add_argument("--batch-slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--queries", type=int, default=4,
                    help="service mode: number of concurrent BAS queries")
    ap.add_argument("--budget", type=int, default=300,
                    help="service mode: oracle budget per query")
    ap.add_argument("--workers", type=int, default=1,
                    help="service mode: scorer worker threads")
    ap.add_argument("--shard", action="store_true",
                    help="data-parallel pair scoring over all host devices")
    args = ap.parse_args()

    from repro.configs import get_smoke_config
    from repro.data.pipeline import ByteTokenizer
    from repro.models import init_params
    from repro.serve.serve_loop import ContinuousBatcher, Request

    tok = ByteTokenizer()
    cfg = get_smoke_config(args.arch, vocab_size=tok.vocab_size)
    params = init_params(cfg, jax.random.key(0))
    print(f"[serve] {cfg.name} ({cfg.param_count()/1e6:.1f}M) mode={args.mode}")

    if args.mode == "decode":
        cb = ContinuousBatcher(cfg, params, batch_size=args.batch_slots,
                               max_len=128, eos_id=tok.EOS)
        rng = np.random.default_rng(0)
        for i in range(args.requests):
            cb.submit(Request(
                uid=i,
                prompt=np.array([tok.BOS] + tok.encode(f"req {i}: ")[:12], np.int32),
                max_new_tokens=args.max_new,
            ))
        t0 = time.time()
        done = cb.run_until_done()
        dt = time.time() - t0
        toks = sum(len(r.out_tokens) for r in done)
        print(f"[serve] {len(done)} requests, {toks} tokens, {dt:.2f}s "
              f"({toks/max(dt,1e-9):.1f} tok/s)")
    elif args.mode == "service":
        from repro.core import Agg, BASConfig, ModelOracle, Query, run_bas
        from repro.data import make_clustered_tables
        from repro.serve.oracle_service import OracleService, serve_queries

        n_side = 48
        ds = make_clustered_tables(n_side, n_side, n_entities=64, noise=0.4,
                                   seed=0)
        records = [f"entity record {i:03d}" for i in range(n_side)]
        scorer = _make_scorer(args, cfg, params, tok, records, batch_size=32)
        cfg_bas = BASConfig(n_bootstrap=100)
        oracles = [ModelOracle(scorer, threshold=0.5)
                   for _ in range(args.queries)]
        queries = [
            Query(spec=ds.spec(), agg=Agg.COUNT, oracle=o, budget=args.budget)
            for o in oracles
        ]
        lat = np.zeros(args.queries)
        with OracleService(workers=args.workers, max_wait_ms=8.0) as svc:
            svc.attach(*oracles)

            def job(i: int):
                t0 = time.time()
                try:
                    return run_bas(queries[i], cfg_bas, seed=i)
                finally:
                    lat[i] = time.time() - t0
                    svc.detach(oracles[i])

            t0 = time.time()
            results = serve_queries(
                svc, [lambda i=i: job(i) for i in range(args.queries)]
            )
            dt = time.time() - t0
            stats = svc.stats()
        labels = sum(o.calls for o in oracles)
        print(f"[serve] {args.queries} concurrent queries, {labels} oracle "
              f"labels in {dt:.2f}s ({labels/max(dt,1e-9):.1f} labels/s, "
              f"{scorer.forward_batches} device batches)")
        print(f"[serve] p50={np.quantile(lat, 0.5)*1e3:.0f}ms "
              f"p99={np.quantile(lat, 0.99)*1e3:.0f}ms per query; "
              f"service: {stats['windows']} windows, "
              f"{stats['segments_per_window']} flushes/window")
        for i, r in enumerate(results):
            print(f"[serve]   q{i}: estimate={r.estimate:.1f} "
                  f"ci=[{r.ci.lo:.1f}, {r.ci.hi:.1f}] "
                  f"calls={oracles[i].calls}")
    else:
        records = [f"entity {i % 16} record {i}" for i in range(64)]
        scorer = _make_scorer(args, cfg, params, tok, records, batch_size=16)
        rng = np.random.default_rng(0)
        pairs = rng.integers(0, 64, size=(args.pairs, 2))
        t0 = time.time()
        p = scorer.score(pairs)
        dt = time.time() - t0
        print(f"[serve] scored {len(pairs)} pairs in {dt:.2f}s "
              f"({len(pairs)/max(dt,1e-9):.1f} pairs/s, "
              f"{scorer.forward_batches} device batches), mean={p.mean():.3f}")


if __name__ == "__main__":
    main()
