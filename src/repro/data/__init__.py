from .synthetic import (  # noqa: F401
    ChainDataset,
    PairDataset,
    dataset_registry,
    make_chain_dataset,
    make_clustered_tables,
    make_syn_scores,
)
