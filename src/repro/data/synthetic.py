"""Synthetic dataset suite mirroring the paper's evaluation data (App. C).

Two generators:

* :func:`make_clustered_tables` — embedding-realistic datasets: records are
  noisy copies of latent entity vectors; two records match iff they share an
  entity.  Noise controls embedding quality (FP/FN rates emerge naturally,
  like Company/Quora/VeRi).  Presets below mirror the paper's workloads at
  test scale.
* :func:`make_syn_scores` — the paper's Syn(FNR, FPR) stress test: scores
  sampled from Beta(5, 0.5) for matches and Beta(0.5, 5) for non-matches
  (following SUPG [37]), with score distributions *inverted* for controlled
  fractions of pairs to inject exact false-negative / false-positive rates.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.oracle import ArrayOracle, PairChainOracle
from repro.core.similarity import normalize
from repro.core.types import JoinSpec


@dataclasses.dataclass
class PairDataset:
    name: str
    emb1: np.ndarray
    emb2: np.ndarray
    truth: np.ndarray                    # (n1, n2) in {0,1}
    columns1: dict = dataclasses.field(default_factory=dict)
    columns2: dict = dataclasses.field(default_factory=dict)
    weights_override: Optional[np.ndarray] = None  # flat scores (Syn datasets)

    @property
    def selectivity(self) -> float:
        return float(self.truth.mean())

    def spec(self) -> JoinSpec:
        return JoinSpec(embeddings=[self.emb1, self.emb2])

    def oracle(self) -> ArrayOracle:
        return ArrayOracle(self.truth)

    def truth_flat(self) -> np.ndarray:
        return self.truth.reshape(-1).astype(np.float64)


def make_clustered_tables(
    n1: int,
    n2: int,
    d: int = 64,
    n_entities: int = 512,
    noise: float = 0.35,
    seed: int = 0,
    self_join: bool = False,
    name: str = "clustered",
    n_groups: int = 0,
) -> PairDataset:
    """``n_groups > 0`` arranges entities into semantic groups (e.g. companies
    of the same industry, vehicles of the same model): same-group non-matches
    have high embedding similarity — the false-positive failure mode the
    paper attributes to dense embeddings (§7.6)."""
    rng = np.random.default_rng(seed)
    ents = rng.standard_normal((n_entities, d)).astype(np.float32)
    if n_groups > 0:
        groups = rng.standard_normal((n_groups, d)).astype(np.float32)
        g_of_e = rng.integers(0, n_groups, size=n_entities)
        ents = 1.2 * groups[g_of_e] + 0.7 * ents
    e1_ids = rng.integers(0, n_entities, size=n1)
    e2_ids = e1_ids if self_join and n1 == n2 else rng.integers(0, n_entities, size=n2)
    emb1 = ents[e1_ids] + noise * rng.standard_normal((n1, d)).astype(np.float32)
    emb2 = ents[e2_ids] + noise * rng.standard_normal((n2, d)).astype(np.float32)
    truth = (e1_ids[:, None] == e2_ids[None, :]).astype(np.int8)
    if self_join:
        np.fill_diagonal(truth, 0)  # a record is not a paraphrase of itself
    cols1 = {
        "char_len": rng.lognormal(4.0, 0.6, size=n1),
        "value": rng.lognormal(2.0, 1.0, size=n1),
        "ts": np.sort(rng.uniform(0, 1e4, size=n1)),
        "n_answers": rng.poisson(3.0, size=n1).astype(np.float64) + 1.0,
    }
    cols2 = {
        "char_len": rng.lognormal(4.0, 0.6, size=n2),
        "value": rng.lognormal(2.0, 1.0, size=n2),
        "ts": np.sort(rng.uniform(0, 1e4, size=n2)) + 50.0,
        "n_answers": rng.poisson(3.0, size=n2).astype(np.float64) + 1.0,
    }
    return PairDataset(
        name=name,
        emb1=normalize(emb1),
        emb2=normalize(emb2),
        truth=truth,
        columns1=cols1,
        columns2=cols2,
    )


def make_syn_scores(
    n1: int = 1000,
    n2: int = 1000,
    selectivity: float = 1e-3,
    fnr: float = 0.0,
    fpr: float = 0.0,
    seed: int = 0,
) -> PairDataset:
    """Paper's Syn(FNR, FPR): ground truth by selectivity; scores from
    Beta(5,.5) (matches) / Beta(.5,5) (non-matches); a ``fnr`` fraction of
    matches and ``fpr`` fraction of non-matches get their score distribution
    inverted.  Embeddings are placeholders — use ``weights_override``."""
    rng = np.random.default_rng(seed)
    n = n1 * n2
    truth = (rng.random(n) < selectivity).astype(np.int8)
    pos = truth == 1
    scores = np.empty(n, np.float64)
    n_pos = int(pos.sum())
    n_neg = n - n_pos
    scores[pos] = rng.beta(5.0, 0.5, size=n_pos)
    scores[~pos] = rng.beta(0.5, 5.0, size=n_neg)
    # inject controlled failures
    flip_pos = pos & (rng.random(n) < fnr)       # matches that look unrelated
    flip_neg = (~pos) & (rng.random(n) < fpr)    # non-matches that look related
    scores[flip_pos] = rng.beta(0.5, 5.0, size=int(flip_pos.sum()))
    scores[flip_neg] = rng.beta(5.0, 0.5, size=int(flip_neg.sum()))
    d = 8
    emb = rng.standard_normal((n1, d)).astype(np.float32)
    emb2 = rng.standard_normal((n2, d)).astype(np.float32)
    rngv = np.random.default_rng(seed + 1)
    return PairDataset(
        name=f"syn_fn{fnr:g}_fp{fpr:g}",
        emb1=normalize(emb),
        emb2=normalize(emb2),
        truth=truth.reshape(n1, n2),
        columns1={"value": rngv.lognormal(2.0, 1.0, size=n1)},
        columns2={"value": rngv.lognormal(2.0, 1.0, size=n2)},
        weights_override=np.maximum(scores, 1e-6),
    )


@dataclasses.dataclass
class ChainDataset:
    name: str
    embeddings: list
    edge_truth: list  # per-edge (N_i, N_{i+1}) {0,1} matrices

    def spec(self) -> JoinSpec:
        return JoinSpec(embeddings=self.embeddings)

    def oracle(self) -> PairChainOracle:
        return PairChainOracle(self.edge_truth)

    def truth_flat(self) -> np.ndarray:
        """Dense ground truth over the chain cross product (tests only)."""
        sizes = [e.shape[0] for e in self.embeddings]
        t = np.ones((1,), np.float64)
        for i, m in enumerate(self.edge_truth):
            if i == 0:
                t = m.astype(np.float64).reshape(-1)
            else:
                t = (t.reshape(-1, sizes[i])[:, :, None] * m[None, :, :]).reshape(-1)
        return t


def make_chain_dataset(
    sizes: list[int],
    d: int = 32,
    n_entities: int = 64,
    noise: float = 0.3,
    seed: int = 0,
    name: str = "chain",
) -> ChainDataset:
    """k-table chain join (paper's Company-Scale / Ecomm-Q10/Q11 analogs):
    records share latent entities; consecutive tables match on same entity."""
    rng = np.random.default_rng(seed)
    ents = rng.standard_normal((n_entities, d)).astype(np.float32)
    ids = [rng.integers(0, n_entities, size=n) for n in sizes]
    embs = [
        normalize(ents[i] + noise * rng.standard_normal((len(i), d)).astype(np.float32))
        for i in ids
    ]
    edges = [
        (ids[j][:, None] == ids[j + 1][None, :]).astype(np.int8)
        for j in range(len(sizes) - 1)
    ]
    return ChainDataset(name=name, embeddings=embs, edge_truth=edges)


# ---------------------------------------------------------------------------
# Paper-workload presets (test-scale analogs; selectivity/modality noted).
# ---------------------------------------------------------------------------

def dataset_registry(scale: float = 1.0, seed: int = 0) -> dict:
    s = lambda n: max(int(n * scale), 64)  # noqa: E731
    return {
        # Entity resolution, low selectivity; industry-grouped FPs (Company)
        "company": lambda: make_clustered_tables(
            s(1200), s(1200), d=64, n_entities=s(4000), noise=1.0, seed=seed,
            n_groups=max(s(4000) // 80, 4), name="company"),
        # Self-join paraphrase detection, very low selectivity (Quora-like)
        "quora": lambda: make_clustered_tables(
            s(1500), s(1500), d=64, n_entities=s(1200), noise=0.8, seed=seed + 1,
            n_groups=max(s(1200) // 12, 4), self_join=True, name="quora"),
        # Duplicate posts with noisier text (Webmasters-like)
        "webmasters": lambda: make_clustered_tables(
            s(1000), s(1000), d=64, n_entities=s(800), noise=1.2, seed=seed + 2,
            n_groups=max(s(800) // 16, 4), name="webmasters"),
        # Small query set vs large gallery (Roxford-like)
        "roxford": lambda: make_clustered_tables(
            s(70), s(4000), d=64, n_entities=s(200), noise=0.9, seed=seed + 3,
            n_groups=max(s(200) // 10, 4), name="roxford"),
        # Vehicle re-id: same-model vehicles are hard negatives (VeRi-like)
        "veri": lambda: make_clustered_tables(
            s(800), s(1000), d=64, n_entities=s(150), noise=1.0, seed=seed + 4,
            n_groups=max(s(150) // 10, 4), name="veri"),
        # Cross-modal retrieval (Flickr30K-like): noisy alignment
        "flickr30k": lambda: make_clustered_tables(
            s(600), s(3000), d=64, n_entities=s(550), noise=1.3, seed=seed + 5,
            n_groups=max(s(550) // 11, 4), name="flickr30k"),
        # High-selectivity review matching (Movie-Q5-like)
        "movie": lambda: make_clustered_tables(
            s(400), s(400), d=64, n_entities=4, noise=0.9, seed=seed + 6,
            n_groups=2, name="movie"),
    }
