"""Host data pipeline for Oracle training/serving.

* :class:`ByteTokenizer` — reversible byte-level tokenizer with specials.
* :func:`make_entity_corpus` — synthetic record corpus with latent entities
  (noisy string variants), the learnable analog of the paper's EM datasets:
  the Oracle LM is trained to answer whether two records denote one entity.
* :func:`pair_example` — serializes a record pair into the pair-scoring
  prompt  ``[BOS] r1 [SEP] r2 [SCORE] -> {YES|NO}`` (Narayan et al. style).
* :class:`ShardedLoader` — deterministic per-host batch shards with
  background prefetch; the batch at step s is a pure function of (seed, s)
  so restarts resume the exact stream (fault tolerance).
"""
from __future__ import annotations

import queue
import string
import threading
from typing import Optional

import numpy as np


class ByteTokenizer:
    PAD, BOS, EOS, SEP, SCORE, YES, NO = 0, 1, 2, 3, 4, 5, 6
    N_SPECIAL = 8

    @property
    def vocab_size(self) -> int:
        return 256 + self.N_SPECIAL

    def encode(self, text: str) -> list:
        return [b + self.N_SPECIAL for b in text.encode("utf-8")]

    def decode(self, ids) -> str:
        return bytes(
            int(i) - self.N_SPECIAL for i in ids if int(i) >= self.N_SPECIAL
        ).decode("utf-8", errors="replace")


_WORDS = (
    "data systems corp labs global tech media group solutions net "
    "works dynamics micro quantum logic apex vertex nova prime delta"
).split()


def make_entity_corpus(
    n_entities: int = 64,
    records_per_entity: int = 4,
    noise: float = 0.1,
    seed: int = 0,
) -> tuple[list, np.ndarray]:
    """Returns (records, entity_ids): noisy string variants per entity."""
    rng = np.random.default_rng(seed)
    records, ids = [], []
    for e in range(n_entities):
        base = " ".join(rng.choice(_WORDS, size=3)) + f" {e % 97}"
        for _ in range(records_per_entity):
            chars = list(base)
            for i in range(len(chars)):
                if rng.random() < noise:
                    chars[i] = rng.choice(list(string.ascii_lowercase))
            records.append("".join(chars))
            ids.append(e)
    return records, np.array(ids)


def pair_example(
    tok: ByteTokenizer, r1: str, r2: str, label: Optional[int], max_len: int
) -> tuple[np.ndarray, np.ndarray]:
    """Returns (tokens, loss_mask).  Label token is the final position."""
    ids = (
        [tok.BOS]
        + tok.encode(r1)[: max_len // 2 - 3]
        + [tok.SEP]
        + tok.encode(r2)[: max_len // 2 - 3]
        + [tok.SCORE]
    )
    mask = [0.0] * len(ids)
    if label is not None:
        ids.append(tok.YES if label else tok.NO)
        mask.append(1.0)
    ids = ids[:max_len]
    mask = mask[:max_len]
    pad = max_len - len(ids)
    return (
        np.array(ids + [tok.PAD] * pad, np.int32),
        np.array(mask + [0.0] * pad, np.float32),
    )


def make_pair_batch(
    tok: ByteTokenizer,
    records: list,
    entity_ids: np.ndarray,
    batch: int,
    max_len: int,
    rng: np.random.Generator,
    positive_fraction: float = 0.5,
):
    """Balanced labelled pair batch for Oracle training."""
    n = len(records)
    by_entity: dict = {}
    for i, e in enumerate(entity_ids):
        by_entity.setdefault(int(e), []).append(i)
    multi = [e for e, v in by_entity.items() if len(v) >= 2]
    toks = np.zeros((batch, max_len), np.int32)
    masks = np.zeros((batch, max_len), np.float32)
    labels = np.zeros((batch,), np.int32)
    for b in range(batch):
        if rng.random() < positive_fraction and multi:
            e = multi[rng.integers(len(multi))]
            i, j = rng.choice(by_entity[e], size=2, replace=False)
            label = 1
        else:
            i, j = rng.integers(n), rng.integers(n)
            label = int(entity_ids[i] == entity_ids[j])
        toks[b], masks[b] = pair_example(tok, records[i], records[j], label, max_len)
        labels[b] = label
    return {"tokens": toks, "loss_mask": masks, "labels": labels}


class ShardedLoader:
    """Deterministic, restartable, host-sharded loader with prefetch.

    ``batch_fn(rng) -> dict of np arrays (global_batch, ...)``; each host
    slices its contiguous shard [host_id * per_host : (host_id+1) * per_host].
    """

    def __init__(
        self,
        batch_fn,
        global_batch: int,
        num_hosts: int = 1,
        host_id: int = 0,
        seed: int = 0,
        start_step: int = 0,
        prefetch: int = 2,
    ):
        assert global_batch % num_hosts == 0
        self.batch_fn = batch_fn
        self.per_host = global_batch // num_hosts
        self.host_id = host_id
        self.seed = seed
        self.step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _make(self, step: int):
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, step]))
        full = self.batch_fn(rng)
        lo = self.host_id * self.per_host
        hi = lo + self.per_host
        return jax_tree_slice(full, lo, hi)

    def _work(self):
        step = self.step
        while not self._stop.is_set():
            try:
                self._q.put((step, self._make(step)), timeout=0.2)
                step += 1
            except queue.Full:
                continue

    def __next__(self):
        step, batch = self._q.get()
        self.step = step + 1
        return step, batch

    def close(self):
        self._stop.set()


def jax_tree_slice(tree, lo, hi):
    import jax

    return jax.tree.map(lambda x: x[lo:hi], tree)
