"""Shared in-kernel histogram epilogues for the similarity kernels.

TPUs have no scatter-add, so binning a block of scores means comparing every
element against bin ids.  The naive epilogue (the original ``sim_hist`` one)
does O(n_bins) VPU compares per element — chunked over bins, it is the kernel
bottleneck at high bin counts.  The fast epilogue here decomposes the bin
index ``idx = hi * lane + lo`` and one-hots the two halves separately::

    counts[hi, lo] = sum_e 1[hi_e == hi] * 1[lo_e == lo]
                   = (OH_hi @ OH_lo^T)[hi, lo]

so each element pays O(n_bins/lane + lane) compares on the VPU (e.g. 32 + 128
instead of 4096) and the O(n_bins)-per-element combine runs as a matmul on
the MXU.  Counts stay exact: the f32 accumulator represents integers up to
2**24 and a block contributes at most bm*bn <= 2**16 per bin.

Both epilogues return the full (n_bins,) counts of one block as a value; the
caller accumulates into its output ref.  ``plan_bins`` picks the fast path
when the shapes decompose cleanly and falls back to the chunked-compare scan
otherwise.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

LANE = 128  # TPU lane width: natural `lo` radix for the two-level split


def plan_bins(n_bins: int, n_elems: int, bin_chunk: int,
              max_elem_chunk: int = 2048):
    """Static (host-side) epilogue plan: ``("fast", lane, elem_chunk)`` when
    the two-level decomposition applies, else ``("scan", bin_chunk, 0)``."""
    lane = LANE if n_bins % LANE == 0 else (n_bins if n_bins <= LANE else 0)
    elem_chunk = math.gcd(n_elems, max_elem_chunk)
    if lane and elem_chunk >= 8:
        return ("fast", lane, elem_chunk)
    assert n_bins % bin_chunk == 0
    return ("scan", bin_chunk, 0)


def bin_counts_fast(idx, n_bins: int, lane: int, elem_chunk: int):
    """(bm, bn) int32 bin indices -> (n_bins,) int32 counts via the
    two-level one-hot + MXU combine."""
    flat = idx.reshape(1, -1)                    # stay 2D for TPU layouts
    n_elems = flat.shape[1]
    n_hi = n_bins // lane
    hi = flat // lane
    lo = flat - hi * lane
    iota_hi = jax.lax.broadcasted_iota(jnp.int32, (n_hi, elem_chunk), 0)
    iota_lo = jax.lax.broadcasted_iota(jnp.int32, (lane, elem_chunk), 0)

    def body(c, acc):
        hs = jax.lax.dynamic_slice(hi, (0, c * elem_chunk), (1, elem_chunk))
        ls = jax.lax.dynamic_slice(lo, (0, c * elem_chunk), (1, elem_chunk))
        oh_hi = (hs == iota_hi).astype(jnp.float32)   # (n_hi, ec)
        oh_lo = (ls == iota_lo).astype(jnp.float32)   # (lane, ec)
        return acc + jax.lax.dot_general(
            oh_hi, oh_lo, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    acc = jax.lax.fori_loop(
        0, n_elems // elem_chunk, body, jnp.zeros((n_hi, lane), jnp.float32)
    )
    return acc.astype(jnp.int32).reshape(n_bins)


def bin_counts_scan(idx, n_bins: int, bin_chunk: int):
    """Fallback epilogue: chunked one-hot compare over bins (O(n_bins)
    compares per element) for bin counts that don't decompose."""
    flat = idx.reshape(1, -1)

    def body(c, acc):
        bins = c * bin_chunk + jax.lax.broadcasted_iota(
            jnp.int32, (bin_chunk, 1), 0
        )
        hits = (flat == bins).astype(jnp.int32).sum(axis=1)  # (bin_chunk,)
        return jax.lax.dynamic_update_slice(acc, hits, (c * bin_chunk,))

    return jax.lax.fori_loop(
        0, n_bins // bin_chunk, body, jnp.zeros((n_bins,), jnp.int32)
    )


def bin_counts(idx, n_bins: int, plan):
    """Dispatch on a :func:`plan_bins` plan (static under jit)."""
    kind, a, b = plan
    if kind == "fast":
        return bin_counts_fast(idx, n_bins, a, b)
    return bin_counts_scan(idx, n_bins, a)
