"""Shared host-side padding helpers for the similarity kernels.

``sim_hist`` and ``sim_sweep`` pad inputs to block multiples and subtract the
padded-pair contributions from their histograms afterwards.  The two
corrections MUST stay bit-identical — the single-sweep stratifier's
fp32 bit-identity guarantee (sweep vs two-pass strata) rests on it — so both
ops import these helpers instead of carrying copies.
"""
from __future__ import annotations

import numpy as np


def pad_rows(e: np.ndarray, mult: int) -> tuple[np.ndarray, int]:
    """Zero-pad rows to a multiple of ``mult``; returns (padded, n_padded)."""
    n = e.shape[0]
    pad = (-n) % mult
    if pad:
        e = np.concatenate([e, np.zeros((pad, e.shape[1]), e.dtype)], axis=0)
    return e, pad


def remove_pad_counts(
    block_counts: np.ndarray,
    scale: np.ndarray,
    p1: int,
    p2: int,
    padded_cols_total: int,
    n_bins: int,
    exponent: float,
    floor: float,
    bm: int,
) -> None:
    """Subtract padded-pair histogram contributions, in place.

    Padded left rows carry scale 0 (weight 0 -> bin 0) across the full
    padded width and always sit in the last row block; real rows pair with
    each padded column at weight ``scale_i * floor**exponent``.
    ``block_counts`` is (n_blocks, n_bins); pass a (1, n_bins) view with
    ``bm >= len(scale)`` for a global histogram.
    """
    if p1:
        block_counts[-1, 0] -= p1 * padded_cols_total
    if p2:
        wpad = scale.astype(np.float64) * (floor**exponent)
        fb = np.clip((wpad * n_bins).astype(np.int64), 0, n_bins - 1)
        np.subtract.at(block_counts, (np.arange(len(scale)) // bm, fb), p2)
