"""Pure-jnp oracle for the fused sim_sweep kernel."""
import jax
import jax.numpy as jnp

from .kernel import comp_block_sum


def sim_sweep_ref(e1, e2, n_bins=4096, exponent=1.0, floor=1e-3, k=8,
                  bm=None, scale=None, v=None, rs_exponent=None):
    """Returns (block_counts (M/bm, n_bins) i32, vals (M, k) f32,
    idx (M, k) i32, row_sums (M, 1) f32) — the same quadruple as
    ``sim_sweep_pallas``.  Row sums use the same compensated pairwise
    reduction as the kernel (here over the full width in one block), so the
    oracle matches both the kernel and a float64 reference to ~1 ulp."""
    m = e1.shape[0]
    bm = m if bm is None else bm
    scores = jnp.dot(
        e1.astype(jnp.float32), e2.astype(jnp.float32).T,
        preferred_element_type=jnp.float32,
    )
    base = jnp.maximum(jnp.clip(scores, 0.0, 1.0), floor)
    w = base if exponent == 1.0 else base**exponent
    if scale is not None:
        w = w * scale.reshape(-1, 1).astype(jnp.float32)
    idx = jnp.clip((w * n_bins).astype(jnp.int32), 0, n_bins - 1)
    blk = jnp.arange(m, dtype=jnp.int32) // bm
    bc = jnp.zeros((m // bm, n_bins), jnp.int32).at[
        jnp.broadcast_to(blk[:, None], idx.shape).reshape(-1),
        idx.reshape(-1),
    ].add(1)
    vals, top_i = jax.lax.top_k(jnp.clip(scores, 0.0, 1.0), k)
    rs_exp = exponent if rs_exponent is None else rs_exponent
    wr = base if rs_exp == 1.0 else base**rs_exp
    if v is not None:
        wr = wr * v.reshape(1, -1).astype(jnp.float32)
    hi, lo = comp_block_sum(wr)
    return bc, vals, top_i.astype(jnp.int32), hi + lo
