"""Pure-jnp oracle for the fused sim_sweep kernel."""
import jax
import jax.numpy as jnp


def sim_sweep_ref(e1, e2, n_bins=4096, exponent=1.0, floor=1e-3, k=8,
                  bm=None, scale=None):
    """Returns (block_counts (M/bm, n_bins) i32, vals (M, k) f32,
    idx (M, k) i32) — the same triple as ``sim_sweep_pallas``."""
    m = e1.shape[0]
    bm = m if bm is None else bm
    scores = jnp.dot(
        e1.astype(jnp.float32), e2.astype(jnp.float32).T,
        preferred_element_type=jnp.float32,
    )
    w = jnp.clip(scores, 0.0, 1.0)
    w = jnp.maximum(w, floor)
    if exponent != 1.0:
        w = w**exponent
    if scale is not None:
        w = w * scale.reshape(-1, 1).astype(jnp.float32)
    idx = jnp.clip((w * n_bins).astype(jnp.int32), 0, n_bins - 1)
    blk = jnp.arange(m, dtype=jnp.int32) // bm
    bc = jnp.zeros((m // bm, n_bins), jnp.int32).at[
        jnp.broadcast_to(blk[:, None], idx.shape).reshape(-1),
        idx.reshape(-1),
    ].add(1)
    vals, top_i = jax.lax.top_k(jnp.clip(scores, 0.0, 1.0), k)
    return bc, vals, top_i.astype(jnp.int32)
