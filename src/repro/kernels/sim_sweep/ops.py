"""Public op: fused single-sweep stratification pass with numpy in/out.

One blocked pass over ``E1 @ E2^T`` yields everything the streaming
stratifier needs: the global weight histogram (exact integer column sum of
the per-block tiles), per-(row-block, bin) count tiles for targeted rescans,
the per-left-row top-k similar right rows for blocking-regime collection,
and compensated per-row walk sums (the wandering-join proposal normaliser —
see ``repro.core.bas_streaming``).  Padding corrections for the counts are
the shared ``repro.kernels.padding`` helpers (the same ones ``sim_hist``
applies, so the fp32 sweep stays bit-identical to the two-kernel path); the
walk sums need none because the backward vector is zero in padded columns.

``precision`` selects the compute path: ``"fp32"`` (default, bit-identical
to the sequential sim_hist + sim_topk pair), ``"bf16"`` (bf16 MXU inputs,
f32 accumulation), or ``"int8"`` (per-row symmetric quantisation via
``repro.core.similarity.quantize_rows_int8``, int32 MXU accumulation).

Chain callers sweep many left blocks against one fixed right table: build a
:class:`PreparedRight` once with :func:`prepare_right` and pass it as
``right=`` so padding/quantisation/upload of the right side happen once, not
per prefix block.

Block shapes route through :mod:`repro.kernels.autotune` on compiled
backends (the tuned (bm, bn) schedule is cached on disk next to the index
store); on CPU/interpret the historical power-of-two defaults are used
unchanged.
"""
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .. import autotune
from ..padding import pad_rows, remove_pad_counts
from .kernel import sim_sweep_pallas, sim_sweep_q_pallas
from .ref import sim_sweep_ref  # noqa: F401  (oracle for tests/benchmarks)

PRECISIONS = ("fp32", "bf16", "int8")


def _pow2_block(block, n):
    return min(block, max(8, 1 << (n - 1).bit_length()))


class PreparedRight(NamedTuple):
    """Right table, padded (and quantised for int8) once for many sweeps."""

    n2: int
    bn: int
    p2: int
    precision: str
    e2p: jax.Array            # padded f32 embeddings (device)
    q2: Optional[jax.Array]   # int8 path only
    rs2: Optional[jax.Array]  # int8 path only


class SweepOut(NamedTuple):
    counts: np.ndarray        # (n_bins,) int64, padding-corrected
    edges: np.ndarray         # (n_bins + 1,) bin edges over [0, 1]
    block_counts: np.ndarray  # (ceil(n1/block_rows), n_bins) int64
    block_rows: int           # left rows per count tile
    vals: np.ndarray          # (n1, k) f32 clipped top-k scores
    idx: np.ndarray           # (n1, k) i32 right-row indices
    valid: np.ndarray         # (n1, k) bool — False for padded-column hits
    row_sums: np.ndarray      # (n1,) f64 compensated walk sums


def prepare_right(e2, block=256, precision="fp32",
                  n1_hint: Optional[int] = None) -> PreparedRight:
    assert precision in PRECISIONS, precision
    e2 = np.asarray(e2, np.float32)
    n2 = e2.shape[0]
    bn = _pow2_block(block, n2)
    sched = autotune.schedule("sim_sweep", n1_hint or n2, n2, e2.shape[1],
                              precision)
    if sched is not None:
        bn = _pow2_block(sched[1], n2)
    e2p, p2 = pad_rows(e2, bn)
    q2 = rs2 = None
    if precision == "int8":
        from repro.core.similarity import quantize_rows_int8

        q2np, rs2np = quantize_rows_int8(e2p)
        q2, rs2 = jnp.asarray(q2np), jnp.asarray(rs2np)
    return PreparedRight(n2=n2, bn=bn, p2=p2, precision=precision,
                         e2p=jnp.asarray(e2p), q2=q2, rs2=rs2)


def sim_sweep(e1, e2=None, n_bins=4096, exponent=1.0, floor=1e-3, k=8,
              block=256, interpret=None, scale=None, precision="fp32",
              right: Optional[PreparedRight] = None, back_v=None,
              rs_exponent=None) -> SweepOut:
    """``back_v`` (optional, (n2,) f32) is the backward chain vector applied
    inside the walk sums; ``rs_exponent`` (optional) overrides the weight
    power for the sums only (chain sweeps bin at ``exponent * root`` but
    need the raw full-exponent edge weight in the walk sums)."""
    assert precision in PRECISIONS, precision
    e1 = np.asarray(e1, np.float32)
    n1 = e1.shape[0]
    if right is None:
        assert e2 is not None, "pass e2 or a PreparedRight"
        right = prepare_right(e2, block, precision, n1_hint=n1)
    assert right.precision == precision, (right.precision, precision)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n2 = right.n2
    bm = _pow2_block(block, n1)
    sched = autotune.schedule("sim_sweep", n1, n2, e1.shape[1], precision)
    if sched is not None:
        bm = _pow2_block(sched[0], n1)
    bn = right.bn
    e1p, p1 = pad_rows(e1, bm)
    s = np.ones(n1, np.float32) if scale is None else np.asarray(scale, np.float32)
    sp = np.concatenate([s, np.zeros(p1, np.float32)]) if p1 else s
    # backward vector, zero-padded so padded right columns drop out of the
    # walk sums with no host-side correction
    vp = np.zeros(right.e2p.shape[0], np.float32)
    vp[:n2] = 1.0 if back_v is None else np.asarray(back_v, np.float32)
    kk = min(k, bn)
    common = dict(n_bins=n_bins, exponent=exponent, rs_exponent=rs_exponent,
                  floor=floor, k=kk, bm=bm, bn=bn, interpret=interpret)
    if precision == "int8":
        from repro.core.similarity import quantize_rows_int8

        q1, rs1 = quantize_rows_int8(e1p)
        bc, vals, idx, rs = sim_sweep_q_pallas(
            jnp.asarray(q1), right.q2, jnp.asarray(rs1), right.rs2,
            jnp.asarray(sp), jnp.asarray(vp), **common,
        )
    else:
        dtype = jnp.bfloat16 if precision == "bf16" else jnp.float32
        bc, vals, idx, rs = sim_sweep_pallas(
            jnp.asarray(e1p), right.e2p, jnp.asarray(sp), jnp.asarray(vp),
            compute_dtype=dtype, **common,
        )
    bc = np.asarray(bc).astype(np.int64)
    remove_pad_counts(bc, s, p1, right.p2, right.e2p.shape[0], n_bins,
                      exponent, floor, bm)
    counts = bc.sum(axis=0)
    edges = np.linspace(0.0, 1.0, n_bins + 1)
    vals = np.asarray(vals)[:n1]
    idx = np.asarray(idx)[:n1]
    row_sums = np.asarray(rs)[:n1, 0].astype(np.float64)
    return SweepOut(
        counts=counts, edges=edges, block_counts=bc, block_rows=bm,
        vals=vals, idx=idx, valid=idx < n2, row_sums=row_sums,
    )
