"""Single-sweep stratification kernel: histogram + top-k + per-block bins.

The streaming stratifier (``repro.core.stratify``) used to pay the blocked
``E1 @ E2^T`` product twice — once for the weight histogram (``sim_hist``)
that sets the top-m threshold, once for the per-row top-k (``sim_topk``) that
collects the blocking regime — and a third partial time when over-threshold
rows had to be rescanned.  This kernel emits everything the stratifier needs
from **one** pass over the product:

* per-(row-block, bin) count tiles — the global histogram is their exact
  integer column sum, and the tiles tell the collector/sampler which row
  blocks contain over-threshold mass so rescans touch only those blocks;
* the running per-row top-k of the raw clipped similarity (bit-identical
  semantics to ``sim_topk``: k static, maintained by k extract-max passes).

The histogram half bins the *sampling weight* ``max(clip(s,0,1), floor) **
exponent * scale`` (``scale`` is the per-left-row chain-prefix weight for
k-way joins, exactly as in ``sim_hist``); the top-k half ranks the raw
clipped score, which is monotone in the weight for any fixed row.

Precision paths (static ``compute_dtype``): fp32 casts inputs to f32 before
the MXU (bit-identical to the sim_hist/sim_topk pair); bf16 feeds the MXU
bf16 inputs with f32 accumulation; the int8 variant (``sim_sweep_q_pallas``)
takes per-row-quantised int8 embeddings + scales, accumulates in int32 on
the MXU and rescales to f32 scores.

Grid: (M/bm, N/bn); the N dimension iterates sequentially (TPU grid order),
the count tile and top-k scratch are initialised at j == 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..binning import bin_counts, plan_bins

NEG = -1e30


def _fused_epilogue(scores, s, bc_ref, vals_ref, idx_ref, run_v, run_i, *,
                    n_bins, exponent, floor, k, bn, n_blocks, plan):
    """Shared histogram + top-k epilogue over one (bm, bn) score block."""
    j = pl.program_id(1)

    # ---- histogram half: sampling-weight transform + per-block bin counts
    w = jnp.clip(scores, 0.0, 1.0)
    w = jnp.maximum(w, floor)
    if exponent != 1.0:
        w = w**exponent
    w = w * s.astype(jnp.float32)  # (bm, 1) prefix weights broadcast
    idx = jnp.clip((w * n_bins).astype(jnp.int32), 0, n_bins - 1)
    bc_ref[...] = bc_ref[...] + bin_counts(idx, n_bins, plan).reshape(1, n_bins)

    # ---- top-k half: raw clipped scores, identical math to sim_topk
    sc = jnp.clip(scores, 0.0, 1.0)
    bm = sc.shape[0]
    col = j * bn + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 1)

    cand_v = jnp.concatenate([run_v[...], sc], axis=1)       # (bm, k+bn)
    cand_i = jnp.concatenate([run_i[...], col], axis=1)
    width = k + bn
    iota = jax.lax.broadcasted_iota(jnp.int32, (bm, width), 1)

    new_v = jnp.full((bm, k), NEG, jnp.float32)
    new_i = jnp.zeros((bm, k), jnp.int32)
    for t in range(k):  # k extract-max passes (k is static and small)
        m = jnp.max(cand_v, axis=1)                           # (bm,)
        am = jnp.argmax(cand_v, axis=1).astype(jnp.int32)     # (bm,)
        sel = iota == am[:, None]
        picked_i = jnp.sum(jnp.where(sel, cand_i, 0), axis=1)
        new_v = new_v.at[:, t].set(m)
        new_i = new_i.at[:, t].set(picked_i)
        cand_v = jnp.where(sel, NEG, cand_v)

    run_v[...] = new_v
    run_i[...] = new_i

    @pl.when(j == n_blocks - 1)
    def _emit():
        vals_ref[...] = new_v
        idx_ref[...] = new_i


def _init(bc_ref, run_v, run_i):
    @pl.when(pl.program_id(1) == 0)
    def _():
        bc_ref[...] = jnp.zeros_like(bc_ref)
        run_v[...] = jnp.full_like(run_v, NEG)
        run_i[...] = jnp.zeros_like(run_i)


def _kernel(e1_ref, e2_ref, s_ref, bc_ref, vals_ref, idx_ref, run_v, run_i, *,
            n_bins, exponent, floor, k, bn, n_blocks, plan, compute_dtype):
    _init(bc_ref, run_v, run_i)
    e1 = e1_ref[...].astype(compute_dtype)
    e2 = e2_ref[...].astype(compute_dtype)
    scores = jax.lax.dot_general(
        e1, e2, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    _fused_epilogue(
        scores, s_ref[...], bc_ref, vals_ref, idx_ref, run_v, run_i,
        n_bins=n_bins, exponent=exponent, floor=floor, k=k, bn=bn,
        n_blocks=n_blocks, plan=plan,
    )


def _kernel_q(q1_ref, q2_ref, s_ref, rs1_ref, rs2_ref, bc_ref, vals_ref,
              idx_ref, run_v, run_i, *, n_bins, exponent, floor, k, bn,
              n_blocks, plan):
    _init(bc_ref, run_v, run_i)
    acc = jax.lax.dot_general(
        q1_ref[...], q2_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    scores = acc.astype(jnp.float32) * rs1_ref[...] * rs2_ref[...]
    _fused_epilogue(
        scores, s_ref[...], bc_ref, vals_ref, idx_ref, run_v, run_i,
        n_bins=n_bins, exponent=exponent, floor=floor, k=k, bn=bn,
        n_blocks=n_blocks, plan=plan,
    )


def _out_shapes(m, n_bins, k, bm):
    return (
        [
            pl.BlockSpec((1, n_bins), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
        ],
        [
            jax.ShapeDtypeStruct((m // bm, n_bins), jnp.int32),
            jax.ShapeDtypeStruct((m, k), jnp.float32),
            jax.ShapeDtypeStruct((m, k), jnp.int32),
        ],
        [
            pltpu.VMEM((bm, k), jnp.float32),
            pltpu.VMEM((bm, k), jnp.int32),
        ],
    )


@functools.partial(
    jax.jit,
    static_argnames=("n_bins", "exponent", "floor", "k", "bm", "bn",
                     "bin_chunk", "interpret", "compute_dtype"),
)
def sim_sweep_pallas(
    e1: jax.Array,
    e2: jax.Array,
    scale: jax.Array | None = None,
    n_bins: int = 4096,
    exponent: float = 1.0,
    floor: float = 1e-3,
    k: int = 8,
    bm: int = 256,
    bn: int = 256,
    bin_chunk: int = 128,
    interpret: bool = True,
    compute_dtype=jnp.float32,
):
    """Fused pass: returns (block_counts (M/bm, n_bins) i32, vals (M, k) f32,
    idx (M, k) i32).  The global histogram is ``block_counts.sum(axis=0)``."""
    m, d = e1.shape
    n, _ = e2.shape
    assert m % bm == 0 and n % bn == 0, "pad inputs to block multiples"
    assert k <= bn
    plan = plan_bins(n_bins, bm * bn, bin_chunk)
    if scale is None:
        scale = jnp.ones((m, 1), jnp.float32)
    else:
        scale = scale.reshape(m, 1).astype(jnp.float32)
    grid = (m // bm, n // bn)
    out_specs, out_shape, scratch = _out_shapes(m, n_bins, k, bm)
    return pl.pallas_call(
        functools.partial(
            _kernel, n_bins=n_bins, exponent=exponent, floor=floor, k=k,
            bn=bn, n_blocks=n // bn, plan=plan, compute_dtype=compute_dtype,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch,
        interpret=interpret,
    )(e1, e2, scale)


@functools.partial(
    jax.jit,
    static_argnames=("n_bins", "exponent", "floor", "k", "bm", "bn",
                     "bin_chunk", "interpret"),
)
def sim_sweep_q_pallas(
    q1: jax.Array,
    q2: jax.Array,
    rs1: jax.Array,
    rs2: jax.Array,
    scale: jax.Array | None = None,
    n_bins: int = 4096,
    exponent: float = 1.0,
    floor: float = 1e-3,
    k: int = 8,
    bm: int = 256,
    bn: int = 256,
    bin_chunk: int = 128,
    interpret: bool = True,
):
    """int8 fast path: ``scores = (q1 @ q2^T) * rs1 * rs2^T`` with int32 MXU
    accumulation.  ``rs1`` is (M, 1) and ``rs2`` is (1, N) f32 row scales."""
    m, d = q1.shape
    n, _ = q2.shape
    assert m % bm == 0 and n % bn == 0, "pad inputs to block multiples"
    assert k <= bn
    plan = plan_bins(n_bins, bm * bn, bin_chunk)
    if scale is None:
        scale = jnp.ones((m, 1), jnp.float32)
    else:
        scale = scale.reshape(m, 1).astype(jnp.float32)
    grid = (m // bm, n // bn)
    out_specs, out_shape, scratch = _out_shapes(m, n_bins, k, bm)
    return pl.pallas_call(
        functools.partial(
            _kernel_q, n_bins=n_bins, exponent=exponent, floor=floor, k=k,
            bn=bn, n_blocks=n // bn, plan=plan,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch,
        interpret=interpret,
    )(q1, q2, scale, rs1.reshape(m, 1), rs2.reshape(1, n))
