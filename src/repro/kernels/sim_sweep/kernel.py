"""Single-sweep stratification kernel: histogram + top-k + per-block bins
+ compensated walk row-sums.

The streaming stratifier (``repro.core.stratify``) used to pay the blocked
``E1 @ E2^T`` product twice — once for the weight histogram (``sim_hist``)
that sets the top-m threshold, once for the per-row top-k (``sim_topk``) that
collects the blocking regime — and a third partial time when over-threshold
rows had to be rescanned.  This kernel emits everything the stratifier needs
from **one** pass over the product:

* per-(row-block, bin) count tiles — the global histogram is their exact
  integer column sum, and the tiles tell the collector/sampler which row
  blocks contain over-threshold mass so rescans touch only those blocks;
* the running per-row top-k of the raw clipped similarity (bit-identical
  semantics to ``sim_topk``: k static, maintained by k extract-max passes);
* per-left-row walk sums ``row_sums[i] = sum_c base(i,c)**rs_exponent *
  v[c]`` — the wandering-join proposal normaliser (and, via the backward
  vector ``v``, the chain-total-weight contraction) that previously cost a
  second full pass in numpy.

The histogram half bins the *sampling weight* ``max(clip(s,0,1), floor) **
exponent * scale`` (``scale`` is the per-left-row chain-prefix weight for
k-way joins, exactly as in ``sim_hist``); the top-k half ranks the raw
clipped score, which is monotone in the weight for any fixed row.  The
walk-sum half applies the same clip/floor transform at an independent static
power ``rs_exponent`` (chain sweeps bin the geometric-mean weight at
``exponent * root`` but need the raw full-exponent edge weight in the sums).

Walk sums are accumulated with **compensated f32 arithmetic**: each (bm, bn)
block is reduced by an error-free pairwise tree that carries (hi, lo) pairs
through branch-free Knuth two-sum steps, and the cross-block running total
lives in two VMEM scratch vectors (sum, compensation).  The result matches a
float64 reference to ~1 ulp of f32 (|rel err| ~1e-7) regardless of the
column count or magnitude spread — naive sequential f32 accumulation loses
several digits at these reduction lengths (see ``tests/test_chain_stats``).

Precision paths (static ``compute_dtype``): fp32 casts inputs to f32 before
the MXU (bit-identical to the sim_hist/sim_topk pair); bf16 feeds the MXU
bf16 inputs with f32 accumulation; the int8 variant (``sim_sweep_q_pallas``)
takes per-row-quantised int8 embeddings + scales, accumulates in int32 on
the MXU and rescales to f32 scores.

Grid: (M/bm, N/bn); the N dimension iterates sequentially (TPU grid order),
the count tile, top-k and walk-sum scratch are initialised at j == 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..binning import bin_counts, plan_bins

NEG = -1e30


def two_sum(a, b):
    """Branch-free error-free transform (Knuth): a + b == s + err exactly."""
    s = a + b
    bv = s - a
    err = (a - (s - bv)) + (b - bv)
    return s, err


def comp_block_sum(x):
    """Error-free pairwise reduction along axis 1: returns (hi, lo) column
    vectors with ``sum(x, axis=1) == hi + lo`` to ~1 ulp.  The tree halves
    the width each level, carrying per-lane compensation terms, so the whole
    reduction stays vectorised on the VPU (log2(width) levels)."""
    hi = x
    lo = jnp.zeros_like(x)
    while hi.shape[1] > 1:
        if hi.shape[1] % 2:  # pad one zero column so the halves line up
            hi = jnp.concatenate([hi, jnp.zeros_like(hi[:, :1])], axis=1)
            lo = jnp.concatenate([lo, jnp.zeros_like(lo[:, :1])], axis=1)
        half = hi.shape[1] // 2
        s, e = two_sum(hi[:, :half], hi[:, half:])
        lo = lo[:, :half] + lo[:, half:] + e
        hi = s
    return hi, lo


def _fused_epilogue(scores, s, v, bc_ref, vals_ref, idx_ref, rs_ref, run_v,
                    run_i, rs_hi, rs_lo, *, n_bins, exponent, rs_exponent,
                    floor, k, bn, n_blocks, plan):
    """Shared histogram + top-k + walk-sum epilogue over one (bm, bn) block."""
    j = pl.program_id(1)

    # ---- histogram half: sampling-weight transform + per-block bin counts
    base = jnp.maximum(jnp.clip(scores, 0.0, 1.0), floor)
    w = base if exponent == 1.0 else base**exponent
    w = w * s.astype(jnp.float32)  # (bm, 1) prefix weights broadcast
    idx = jnp.clip((w * n_bins).astype(jnp.int32), 0, n_bins - 1)
    bc_ref[...] = bc_ref[...] + bin_counts(idx, n_bins, plan).reshape(1, n_bins)

    # ---- walk-sum half: compensated accumulation of the raw edge weight
    # times the backward vector.  Padded columns carry v == 0 and vanish, so
    # unlike the histogram no host-side padding correction is needed.
    wr = base if rs_exponent == 1.0 else base**rs_exponent
    wr = wr * v.astype(jnp.float32)  # (1, bn) backward vector broadcast
    blk_hi, blk_lo = comp_block_sum(wr)
    acc_hi, acc_err = two_sum(rs_hi[...], blk_hi)
    rs_hi[...] = acc_hi
    rs_lo[...] = rs_lo[...] + (blk_lo + acc_err)

    # ---- top-k half: raw clipped scores, identical math to sim_topk
    sc = jnp.clip(scores, 0.0, 1.0)
    bm = sc.shape[0]
    col = j * bn + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 1)

    cand_v = jnp.concatenate([run_v[...], sc], axis=1)       # (bm, k+bn)
    cand_i = jnp.concatenate([run_i[...], col], axis=1)
    width = k + bn
    iota = jax.lax.broadcasted_iota(jnp.int32, (bm, width), 1)

    new_v = jnp.full((bm, k), NEG, jnp.float32)
    new_i = jnp.zeros((bm, k), jnp.int32)
    for t in range(k):  # k extract-max passes (k is static and small)
        m = jnp.max(cand_v, axis=1)                           # (bm,)
        am = jnp.argmax(cand_v, axis=1).astype(jnp.int32)     # (bm,)
        sel = iota == am[:, None]
        picked_i = jnp.sum(jnp.where(sel, cand_i, 0), axis=1)
        new_v = new_v.at[:, t].set(m)
        new_i = new_i.at[:, t].set(picked_i)
        cand_v = jnp.where(sel, NEG, cand_v)

    run_v[...] = new_v
    run_i[...] = new_i

    @pl.when(j == n_blocks - 1)
    def _emit():
        vals_ref[...] = new_v
        idx_ref[...] = new_i
        rs_ref[...] = rs_hi[...] + rs_lo[...]


def _init(bc_ref, run_v, run_i, rs_hi, rs_lo):
    @pl.when(pl.program_id(1) == 0)
    def _():
        bc_ref[...] = jnp.zeros_like(bc_ref)
        run_v[...] = jnp.full_like(run_v, NEG)
        run_i[...] = jnp.zeros_like(run_i)
        rs_hi[...] = jnp.zeros_like(rs_hi)
        rs_lo[...] = jnp.zeros_like(rs_lo)


def _kernel(e1_ref, e2_ref, s_ref, v_ref, bc_ref, vals_ref, idx_ref, rs_ref,
            run_v, run_i, rs_hi, rs_lo, *, n_bins, exponent, rs_exponent,
            floor, k, bn, n_blocks, plan, compute_dtype):
    _init(bc_ref, run_v, run_i, rs_hi, rs_lo)
    e1 = e1_ref[...].astype(compute_dtype)
    e2 = e2_ref[...].astype(compute_dtype)
    scores = jax.lax.dot_general(
        e1, e2, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    _fused_epilogue(
        scores, s_ref[...], v_ref[...], bc_ref, vals_ref, idx_ref, rs_ref,
        run_v, run_i, rs_hi, rs_lo, n_bins=n_bins, exponent=exponent,
        rs_exponent=rs_exponent, floor=floor, k=k, bn=bn, n_blocks=n_blocks,
        plan=plan,
    )


def _kernel_q(q1_ref, q2_ref, s_ref, rs1_ref, rs2_ref, v_ref, bc_ref,
              vals_ref, idx_ref, rs_ref, run_v, run_i, rs_hi, rs_lo, *,
              n_bins, exponent, rs_exponent, floor, k, bn, n_blocks, plan):
    _init(bc_ref, run_v, run_i, rs_hi, rs_lo)
    acc = jax.lax.dot_general(
        q1_ref[...], q2_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    scores = acc.astype(jnp.float32) * rs1_ref[...] * rs2_ref[...]
    _fused_epilogue(
        scores, s_ref[...], v_ref[...], bc_ref, vals_ref, idx_ref, rs_ref,
        run_v, run_i, rs_hi, rs_lo, n_bins=n_bins, exponent=exponent,
        rs_exponent=rs_exponent, floor=floor, k=k, bn=bn, n_blocks=n_blocks,
        plan=plan,
    )


def _out_shapes(m, n_bins, k, bm):
    return (
        [
            pl.BlockSpec((1, n_bins), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
        ],
        [
            jax.ShapeDtypeStruct((m // bm, n_bins), jnp.int32),
            jax.ShapeDtypeStruct((m, k), jnp.float32),
            jax.ShapeDtypeStruct((m, k), jnp.int32),
            jax.ShapeDtypeStruct((m, 1), jnp.float32),
        ],
        [
            pltpu.VMEM((bm, k), jnp.float32),
            pltpu.VMEM((bm, k), jnp.int32),
            pltpu.VMEM((bm, 1), jnp.float32),
            pltpu.VMEM((bm, 1), jnp.float32),
        ],
    )


@functools.partial(
    jax.jit,
    static_argnames=("n_bins", "exponent", "rs_exponent", "floor", "k", "bm",
                     "bn", "bin_chunk", "interpret", "compute_dtype"),
)
def sim_sweep_pallas(
    e1: jax.Array,
    e2: jax.Array,
    scale: jax.Array | None = None,
    v: jax.Array | None = None,
    n_bins: int = 4096,
    exponent: float = 1.0,
    rs_exponent: float | None = None,
    floor: float = 1e-3,
    k: int = 8,
    bm: int = 256,
    bn: int = 256,
    bin_chunk: int = 128,
    interpret: bool = True,
    compute_dtype=jnp.float32,
):
    """Fused pass: returns (block_counts (M/bm, n_bins) i32, vals (M, k) f32,
    idx (M, k) i32, row_sums (M, 1) f32).  The global histogram is
    ``block_counts.sum(axis=0)``; ``row_sums`` is the compensated
    ``sum_c base**rs_exponent * v`` walk sum (``rs_exponent`` defaults to
    ``exponent``, ``v`` to ones — pass zeros in padded columns)."""
    m, d = e1.shape
    n, _ = e2.shape
    assert m % bm == 0 and n % bn == 0, "pad inputs to block multiples"
    assert k <= bn
    plan = plan_bins(n_bins, bm * bn, bin_chunk)
    if scale is None:
        scale = jnp.ones((m, 1), jnp.float32)
    else:
        scale = scale.reshape(m, 1).astype(jnp.float32)
    if v is None:
        v = jnp.ones((1, n), jnp.float32)
    else:
        v = v.reshape(1, n).astype(jnp.float32)
    rs_exp = exponent if rs_exponent is None else rs_exponent
    grid = (m // bm, n // bn)
    out_specs, out_shape, scratch = _out_shapes(m, n_bins, k, bm)
    return pl.pallas_call(
        functools.partial(
            _kernel, n_bins=n_bins, exponent=exponent, rs_exponent=rs_exp,
            floor=floor, k=k, bn=bn, n_blocks=n // bn, plan=plan,
            compute_dtype=compute_dtype,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch,
        interpret=interpret,
    )(e1, e2, scale, v)


@functools.partial(
    jax.jit,
    static_argnames=("n_bins", "exponent", "rs_exponent", "floor", "k", "bm",
                     "bn", "bin_chunk", "interpret"),
)
def sim_sweep_q_pallas(
    q1: jax.Array,
    q2: jax.Array,
    rs1: jax.Array,
    rs2: jax.Array,
    scale: jax.Array | None = None,
    v: jax.Array | None = None,
    n_bins: int = 4096,
    exponent: float = 1.0,
    rs_exponent: float | None = None,
    floor: float = 1e-3,
    k: int = 8,
    bm: int = 256,
    bn: int = 256,
    bin_chunk: int = 128,
    interpret: bool = True,
):
    """int8 fast path: ``scores = (q1 @ q2^T) * rs1 * rs2^T`` with int32 MXU
    accumulation.  ``rs1`` is (M, 1) and ``rs2`` is (1, N) f32 row scales."""
    m, d = q1.shape
    n, _ = q2.shape
    assert m % bm == 0 and n % bn == 0, "pad inputs to block multiples"
    assert k <= bn
    plan = plan_bins(n_bins, bm * bn, bin_chunk)
    if scale is None:
        scale = jnp.ones((m, 1), jnp.float32)
    else:
        scale = scale.reshape(m, 1).astype(jnp.float32)
    if v is None:
        v = jnp.ones((1, n), jnp.float32)
    else:
        v = v.reshape(1, n).astype(jnp.float32)
    rs_exp = exponent if rs_exponent is None else rs_exponent
    grid = (m // bm, n // bn)
    out_specs, out_shape, scratch = _out_shapes(m, n_bins, k, bm)
    return pl.pallas_call(
        functools.partial(
            _kernel_q, n_bins=n_bins, exponent=exponent, rs_exponent=rs_exp,
            floor=floor, k=k, bn=bn, n_blocks=n // bn, plan=plan,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch,
        interpret=interpret,
    )(q1, q2, scale, rs1.reshape(m, 1), rs2.reshape(1, n), v)
