from .ops import SweepOut, sim_sweep  # noqa: F401
