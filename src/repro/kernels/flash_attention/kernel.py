"""Blocked online-softmax (Flash) GQA attention Pallas kernel.

Used for the Oracle transformer forward (the pairwise-evaluation hot spot the
paper pays for by the token).  Grid (B*Hq, Sq/bq, Skv/bkv) with running
(m, l, acc) in VMEM scratch; the KV block index_map folds the GQA group so
K/V are read once per kv-head.  Causal + sliding-window masking by absolute
positions.  VMEM working set per program: bq*d + bkv*d + bq*bkv scores.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: int, bq: int, bkv: int,
            n_kv_blocks: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)   # (bq, d)
    k = k_ref[0].astype(jnp.float32)   # (bkv, d)
    v = v_ref[0].astype(jnp.float32)   # (bkv, d)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                            # (bq, bkv)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
    k_pos = kj * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    mask = jnp.ones((bq, bkv), jnp.bool_)
    if causal:
        mask = q_pos >= k_pos
    if window > 0:
        mask = jnp.logical_and(mask, q_pos - k_pos < window)
    s = jnp.where(mask, s, NEG)

    m_prev = m_scr[...]                  # (bq, 1)
    m_cur = jnp.maximum(m_prev[:, 0], s.max(axis=1))[:, None]
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur)
    l_cur = l_scr[...] * alpha + p.sum(axis=1)[:, None]
    acc = acc_scr[...] * alpha + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32
    )
    m_scr[...] = m_cur
    l_scr[...] = l_cur
    acc_scr[...] = acc

    @pl.when(kj == n_kv_blocks - 1)
    def _emit():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "bq", "bkv", "interpret"),
)
def flash_attention_pallas(
    q: jax.Array,   # (B, Hq, Sq, d)
    k: jax.Array,   # (B, Hkv, Skv, d)
    v: jax.Array,   # (B, Hkv, Skv, d)
    causal: bool = True,
    window: int = 0,
    bq: int = 128,
    bkv: int = 128,
    interpret: bool = True,
) -> jax.Array:
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert sq % bq == 0 and skv % bkv == 0
    group = hq // hkv
    scale = d**-0.5
    grid = (b * hq, sq // bq, skv // bkv)

    def q_map(bh, i, j):
        return (bh, i, 0)

    def kv_map(bh, i, j):
        # fold batch*q-head back to batch*kv-head
        return ((bh // hq) * hkv + (bh % hq) // group, j, 0)

    qr = q.reshape(b * hq, sq, d)
    kr = k.reshape(b * hkv, skv, d)
    vr = v.reshape(b * hkv, skv, d)
    out = pl.pallas_call(
        functools.partial(
            _kernel, scale=scale, causal=causal, window=window, bq=bq,
            bkv=bkv, n_kv_blocks=skv // bkv,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), q_map),
            pl.BlockSpec((1, bkv, d), kv_map),
            pl.BlockSpec((1, bkv, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, d), q_map),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, hq, sq, d)
