"""Public op: fused GQA flash attention (interpret on CPU, compiled on TPU)."""
import jax

from .kernel import flash_attention_pallas
from .ref import flash_attention_ref  # noqa: F401


def flash_attention(q, k, v, causal=True, window=0, bq=128, bkv=128,
                    interpret=None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    bq = min(bq, q.shape[2])
    bkv = min(bkv, k.shape[2])
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, bq=bq, bkv=bkv,
        interpret=interpret,
    )
