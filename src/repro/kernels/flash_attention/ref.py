"""Pure-jnp oracle: exact softmax attention with causal/window masks."""
import jax.numpy as jnp


def flash_attention_ref(q, k, v, causal=True, window=0):
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    qf = q.astype(jnp.float32).reshape(b, hkv, g, sq, d)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bngsd,bntd->bngst", qf, kf) * d**-0.5
    sq_, skv = s.shape[-2], s.shape[-1]
    qp = jnp.arange(sq_)[:, None]
    kp = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq_, skv), bool)
    if causal:
        mask = qp >= kp
    if window > 0:
        mask = mask & (qp - kp < window)
    s = jnp.where(mask, s, -1e30)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    o = jnp.einsum("bngst,bntd->bngsd", p, vf)
    return o.reshape(b, hq, sq, d).astype(q.dtype)
