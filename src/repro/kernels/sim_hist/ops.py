"""Public op: fused similarity histogram with numpy in/out for the core
stratifier.  Uses the Pallas kernel (interpret on CPU, compiled on TPU) and
pads inputs to block multiples."""
import jax
import jax.numpy as jnp
import numpy as np

from .kernel import sim_hist_pallas
from .ref import sim_hist_ref  # noqa: F401  (oracle for tests/benchmarks)


def _pad(e, mult):
    n = e.shape[0]
    pad = (-n) % mult
    if pad:
        e = np.concatenate([e, np.zeros((pad, e.shape[1]), e.dtype)], axis=0)
    return e, pad


def sim_hist(e1, e2, n_bins=4096, exponent=1.0, floor=1e-3, block=256,
             interpret=None):
    """Returns (counts[n_bins], edges[n_bins+1]); histogram of pair weights.

    Padding rows produce weight exactly ``floor`` (zero similarity); their
    counts are subtracted from the floor bin afterwards.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    e1 = np.asarray(e1, np.float32)
    e2 = np.asarray(e2, np.float32)
    n1, n2 = e1.shape[0], e2.shape[0]
    bm = min(block, max(8, 1 << (n1 - 1).bit_length()))
    bn = min(block, max(8, 1 << (n2 - 1).bit_length()))
    e1p, p1 = _pad(e1, bm)
    e2p, p2 = _pad(e2, bn)
    counts = np.asarray(
        sim_hist_pallas(
            jnp.asarray(e1p), jnp.asarray(e2p), n_bins=n_bins,
            exponent=exponent, floor=floor, bm=bm, bn=bn, interpret=interpret,
        )
    ).astype(np.int64)
    # remove padded-pair contributions (they land in the floor bin)
    n_pad_pairs = e1p.shape[0] * e2p.shape[0] - n1 * n2
    if n_pad_pairs:
        fb = min(int((floor**exponent) * n_bins), n_bins - 1)
        counts[fb] -= n_pad_pairs
    edges = np.linspace(0.0, 1.0, n_bins + 1)
    return counts, edges
