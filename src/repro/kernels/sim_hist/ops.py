"""Public op: fused similarity histogram with numpy in/out for the core
stratifier.  Uses the Pallas kernel (interpret on CPU, compiled on TPU) and
pads inputs to block multiples.  The optional ``scale`` vector (per-left-row
multiplier, e.g. chain-prefix weights) turns the pair histogram into a chain
weight histogram — see ``repro.core.stratify``."""
import jax
import jax.numpy as jnp
import numpy as np

from .. import autotune
from ..padding import pad_rows, remove_pad_counts
from .kernel import sim_hist_pallas
from .ref import sim_hist_ref  # noqa: F401  (oracle for tests/benchmarks)


def sim_hist(e1, e2, n_bins=4096, exponent=1.0, floor=1e-3, block=256,
             interpret=None, scale=None):
    """Returns (counts[n_bins], edges[n_bins+1]); histogram of (optionally
    row-scaled) pair weights.

    Padded left rows get scale 0 (weight 0 -> bin 0); padded right columns
    pair with real rows at weight ``scale_i * floor**exponent``.  Both
    contributions are computed exactly on the host and subtracted
    (``repro.kernels.padding`` — shared with ``sim_sweep`` so the two stay
    bit-identical).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    e1 = np.asarray(e1, np.float32)
    e2 = np.asarray(e2, np.float32)
    n1, n2 = e1.shape[0], e2.shape[0]
    bm = min(block, max(8, 1 << (n1 - 1).bit_length()))
    bn = min(block, max(8, 1 << (n2 - 1).bit_length()))
    sched = autotune.schedule("sim_hist", n1, n2, e1.shape[1])
    if sched is not None:  # tuned block shapes on compiled backends only
        bm = min(sched[0], max(8, 1 << (n1 - 1).bit_length()))
        bn = min(sched[1], max(8, 1 << (n2 - 1).bit_length()))
    e1p, p1 = pad_rows(e1, bm)
    e2p, p2 = pad_rows(e2, bn)
    s = np.ones(n1, np.float32) if scale is None else np.asarray(scale, np.float32)
    sp = np.concatenate([s, np.zeros(p1, np.float32)]) if p1 else s
    counts = np.asarray(
        sim_hist_pallas(
            jnp.asarray(e1p), jnp.asarray(e2p), jnp.asarray(sp), n_bins=n_bins,
            exponent=exponent, floor=floor, bm=bm, bn=bn, interpret=interpret,
        )
    ).astype(np.int64)
    # remove padded-pair contributions (one global "block": bm >= n1)
    remove_pad_counts(counts.reshape(1, -1), s, p1, p2, e2p.shape[0], n_bins,
                      exponent, floor, bm=max(n1, 1))
    edges = np.linspace(0.0, 1.0, n_bins + 1)
    return counts, edges
