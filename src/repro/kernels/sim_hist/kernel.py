"""Fused cosine-similarity + histogram Pallas kernel.

The paper stratifies the cross product by sorting all N1*N2 similarity scores
(its profiled CPU hot spot, App. A).  TPU-native redesign: one pass of blocked
``E1 @ E2^T`` on the MXU with an in-VMEM histogram epilogue — the score matrix
is never materialised in HBM (O(n_bins) output), and the strata thresholds are
read off the histogram CDF (see ``repro.core.stratify``).

The optional per-left-row ``scale`` operand generalises the kernel to k-way
chain joins: the streaming stratifier enumerates the chain's *prefix* space in
blocks and passes the accumulated prefix chain weight as the scale, so the
kernel histograms ``scale_i * w(i, j)`` — the full chain weight — while still
never materialising anything bigger than one (bm, bn) block.

Grid: (M/bm, N/bn), sequential on TPU so the histogram accumulates safely in
the output block (same output block mapped to every program).

The binning epilogue is shared with ``sim_sweep`` (``repro.kernels.binning``):
a two-level one-hot + MXU combine replaces the original O(n_bins)-compares-
per-element chunked scan whenever the bin count decomposes (scan fallback
otherwise).  Counts are bit-identical either way.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..binning import bin_counts, plan_bins


def _kernel(e1_ref, e2_ref, s_ref, out_ref, *, n_bins: int, exponent: float,
            floor: float, plan):
    i, j = pl.program_id(0), pl.program_id(1)

    @pl.when((i == 0) & (j == 0))
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    e1 = e1_ref[...].astype(jnp.float32)
    e2 = e2_ref[...].astype(jnp.float32)
    scores = jax.lax.dot_general(
        e1, e2, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    w = jnp.clip(scores, 0.0, 1.0)
    w = jnp.maximum(w, floor)
    if exponent != 1.0:
        w = w**exponent
    w = w * s_ref[...].astype(jnp.float32)  # (bm, 1) prefix weights broadcast
    idx = jnp.clip((w * n_bins).astype(jnp.int32), 0, n_bins - 1)
    out_ref[...] = out_ref[...] + bin_counts(idx, n_bins, plan)


@functools.partial(
    jax.jit,
    static_argnames=("n_bins", "exponent", "floor", "bm", "bn", "bin_chunk",
                     "interpret"),
)
def sim_hist_pallas(
    e1: jax.Array,
    e2: jax.Array,
    scale: jax.Array | None = None,
    n_bins: int = 4096,
    exponent: float = 1.0,
    floor: float = 1e-3,
    bm: int = 256,
    bn: int = 256,
    bin_chunk: int = 128,
    interpret: bool = True,
) -> jax.Array:
    m, d = e1.shape
    n, _ = e2.shape
    assert m % bm == 0 and n % bn == 0, "pad inputs to block multiples"
    plan = plan_bins(n_bins, bm * bn, bin_chunk)
    if scale is None:
        scale = jnp.ones((m, 1), jnp.float32)
    else:
        scale = scale.reshape(m, 1).astype(jnp.float32)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        functools.partial(
            _kernel, n_bins=n_bins, exponent=exponent, floor=floor, plan=plan,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((n_bins,), lambda i, j: (0,)),
        out_shape=jax.ShapeDtypeStruct((n_bins,), jnp.int32),
        interpret=interpret,
    )(e1, e2, scale)
