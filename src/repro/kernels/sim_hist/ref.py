"""Pure-jnp oracle for the sim_hist kernel."""
import jax.numpy as jnp


def sim_hist_ref(e1, e2, n_bins=4096, exponent=1.0, floor=1e-3, scale=None):
    scores = jnp.dot(
        e1.astype(jnp.float32), e2.astype(jnp.float32).T,
        preferred_element_type=jnp.float32,
    )
    w = jnp.clip(scores, 0.0, 1.0)
    w = jnp.maximum(w, floor)
    if exponent != 1.0:
        w = w**exponent
    if scale is not None:
        w = w * scale.reshape(-1, 1).astype(jnp.float32)
    idx = jnp.clip((w * n_bins).astype(jnp.int32), 0, n_bins - 1)
    return jnp.zeros((n_bins,), jnp.int32).at[idx.reshape(-1)].add(1)
