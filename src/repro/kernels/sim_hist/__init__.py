from .ops import sim_hist  # noqa: F401
