"""Public op: per-left-row top-k similar right rows (NN blocking)."""
import jax
import jax.numpy as jnp
import numpy as np

from .kernel import sim_topk_pallas
from .ref import sim_topk_ref  # noqa: F401


def sim_topk(e1, e2, k=8, block=256, interpret=None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    e1 = np.asarray(e1, np.float32)
    e2 = np.asarray(e2, np.float32)
    n1, n2 = e1.shape[0], e2.shape[0]
    bm = min(block, max(8, 1 << (n1 - 1).bit_length()))
    bn = min(block, max(8, 1 << (n2 - 1).bit_length()))
    p1, p2 = (-n1) % bm, (-n2) % bn
    if p1:
        e1 = np.concatenate([e1, np.zeros((p1, e1.shape[1]), e1.dtype)])
    if p2:
        e2 = np.concatenate([e2, np.full((p2, e2.shape[1]), 0.0, e2.dtype)])
    vals, idx = sim_topk_pallas(
        jnp.asarray(e1), jnp.asarray(e2), k=min(k, bn), bm=bm, bn=bn,
        interpret=interpret,
    )
    vals, idx = np.asarray(vals)[:n1], np.asarray(idx)[:n1]
    # drop hits pointing at padded right rows (score 0 ties)
    valid = idx < n2
    return vals, idx, valid
