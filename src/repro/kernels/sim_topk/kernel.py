"""Fused similarity + per-row top-k Pallas kernel (nearest-neighbour blocking).

The paper's out-of-memory fallback joins each left record with its top-b'
right records (§5.3, NN-based blocking).  TPU-native: blocked ``E1 @ E2^T``
with a running top-k held in VMEM scratch across the N-block grid dimension —
k static, maintained by k extract-max passes (vector ops only, no sort).

Grid: (M/bm, N/bn); the N dimension iterates sequentially (TPU grid order) so
the scratch carries the running (values, indices) for the current row block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(e1_ref, e2_ref, vals_ref, idx_ref, run_v, run_i, *, k: int,
            bn: int, n_blocks: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        run_v[...] = jnp.full_like(run_v, NEG)
        run_i[...] = jnp.zeros_like(run_i)

    e1 = e1_ref[...].astype(jnp.float32)
    e2 = e2_ref[...].astype(jnp.float32)
    scores = jax.lax.dot_general(
        e1, e2, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    scores = jnp.clip(scores, 0.0, 1.0)
    bm = scores.shape[0]
    col = j * bn + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 1)

    cand_v = jnp.concatenate([run_v[...], scores], axis=1)    # (bm, k+bn)
    cand_i = jnp.concatenate([run_i[...], col], axis=1)
    width = k + bn
    iota = jax.lax.broadcasted_iota(jnp.int32, (bm, width), 1)

    new_v = jnp.full((bm, k), NEG, jnp.float32)
    new_i = jnp.zeros((bm, k), jnp.int32)
    for t in range(k):  # k extract-max passes (k is static and small)
        m = jnp.max(cand_v, axis=1)                            # (bm,)
        am = jnp.argmax(cand_v, axis=1).astype(jnp.int32)      # (bm,)
        sel = iota == am[:, None]
        picked_i = jnp.sum(jnp.where(sel, cand_i, 0), axis=1)
        new_v = new_v.at[:, t].set(m)
        new_i = new_i.at[:, t].set(picked_i)
        cand_v = jnp.where(sel, NEG, cand_v)

    run_v[...] = new_v
    run_i[...] = new_i

    @pl.when(j == n_blocks - 1)
    def _emit():
        vals_ref[...] = new_v
        idx_ref[...] = new_i


@functools.partial(
    jax.jit, static_argnames=("k", "bm", "bn", "interpret")
)
def sim_topk_pallas(
    e1: jax.Array,
    e2: jax.Array,
    k: int = 8,
    bm: int = 256,
    bn: int = 256,
    interpret: bool = True,
):
    m, d = e1.shape
    n, _ = e2.shape
    assert m % bm == 0 and n % bn == 0
    assert k <= bn
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        functools.partial(_kernel, k=k, bn=bn, n_blocks=n // bn),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, k), jnp.float32),
            jax.ShapeDtypeStruct((m, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bm, k), jnp.float32),
            pltpu.VMEM((bm, k), jnp.int32),
        ],
        interpret=interpret,
    )(e1, e2)
