"""Pure-jnp oracle for sim_topk."""
import jax
import jax.numpy as jnp


def sim_topk_ref(e1, e2, k=8):
    scores = jnp.clip(
        jnp.dot(e1.astype(jnp.float32), e2.astype(jnp.float32).T), 0.0, 1.0
    )
    vals, idx = jax.lax.top_k(scores, k)
    return vals, idx.astype(jnp.int32)
