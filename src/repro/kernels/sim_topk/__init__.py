from .ops import sim_topk  # noqa: F401
