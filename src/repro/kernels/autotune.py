"""Block-shape autotuner for the fused similarity kernels.

The sim_sweep/sim_hist grids are parameterised by a (row-block, col-block)
schedule.  The historical defaults (256, 256) are sensible on one TPU
generation but leave throughput on the table on others — and hardcoding a
single shape makes per-accelerator perf gates (``bench_diff
--require-compiled``) brittle.  This module measures a small candidate set
on **first compiled use** per (op, backend, device kind, dtype, shape
bucket), caches the winner in memory and on disk, and the ops route their
block choice through :func:`schedule`.

Behaviour contract:

* On non-compiled backends (CPU / interpret mode) :func:`schedule` returns
  ``None`` immediately — zero measurement, zero behaviour change, so CI and
  the numerics tests never depend on tuning.
* Shapes are bucketed to powers of two; one measurement serves every shape
  in the bucket.
* The disk cache is a single JSON file (``autotune.json``), written
  atomically next to the index store when one is configured
  (:meth:`repro.core.index.IndexStore`), so tuned schedules survive process
  restarts and ship with the index artifacts they accelerate.
* Measurement failures (OOM on an exotic candidate, unsupported shape) are
  swallowed per-candidate; if every candidate fails the op falls back to
  its built-in defaults.

The module deliberately avoids importing jax at module scope so that
configuring the cache path from the (jax-free) index layer stays cheap.
"""
from __future__ import annotations

import json
import os
import threading
from typing import Optional

# (row-block, col-block) candidates, all power-of-two so the ops' padding
# math and the kernel's pairwise reduction stay exact
CANDIDATES = ((256, 256), (128, 256), (256, 128), (512, 256), (256, 512))
COMPILED_BACKENDS = ("tpu", "gpu", "cuda", "rocm")

_lock = threading.Lock()
_memory: dict[str, tuple[int, int]] = {}
_path: Optional[str] = None
_loaded = False


def configure(path: Optional[str]) -> None:
    """Point the disk cache at ``path`` (a JSON file).  Existing entries are
    merged into the in-memory cache lazily on first :func:`schedule` call."""
    global _path, _loaded
    with _lock:
        _path = os.fspath(path) if path is not None else None
        _loaded = False


def reset() -> None:
    """Drop the in-memory cache and disk path (tests)."""
    global _path, _loaded
    with _lock:
        _memory.clear()
        _path = None
        _loaded = False


def cache_info() -> dict[str, tuple[int, int]]:
    with _lock:
        return dict(_memory)


def _bucket(x: int) -> int:
    return max(8, 1 << (max(int(x), 1) - 1).bit_length())


def _key(op: str, backend: str, device_kind: str, precision: str,
         m: int, n: int, d: int) -> str:
    return (f"{op}/{backend}/{device_kind}/{precision}/"
            f"{_bucket(m)}x{_bucket(n)}x{_bucket(d)}")


def _load_locked() -> None:
    global _loaded
    if _loaded:
        return
    _loaded = True
    if _path is None or not os.path.exists(_path):
        return
    try:
        with open(_path) as f:
            disk = json.load(f)
        for k, bmn in disk.items():
            _memory.setdefault(k, (int(bmn[0]), int(bmn[1])))
    except (OSError, ValueError, TypeError, IndexError):
        pass  # corrupt cache: re-measure and overwrite


def _save_locked() -> None:
    if _path is None:
        return
    try:
        os.makedirs(os.path.dirname(_path) or ".", exist_ok=True)
        tmp = f"{_path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump({k: list(v) for k, v in sorted(_memory.items())}, f,
                      indent=1)
        os.replace(tmp, _path)
    except OSError:
        pass  # cache is best-effort; never fail the sweep over it


def _time_candidate(op: str, m: int, n: int, d: int, precision: str,
                    bm: int, bn: int) -> float:
    """Wall-time one schedule on synthetic data at the bucket shape (one
    warmup + compile, then best of two timed reps)."""
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    rng = np.random.default_rng(0)
    e1 = jnp.asarray(rng.standard_normal((m, d)), jnp.float32)
    e2 = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    if op == "sim_hist":
        from .sim_hist.kernel import sim_hist_pallas

        def run():
            return sim_hist_pallas(e1, e2, bm=bm, bn=bn, interpret=False)
    else:
        from .sim_sweep.kernel import sim_sweep_pallas

        dtype = jnp.bfloat16 if precision == "bf16" else jnp.float32

        def run():
            return sim_sweep_pallas(e1, e2, bm=bm, bn=bn, interpret=False,
                                    compute_dtype=dtype)

    jax.block_until_ready(run())  # compile + warmup
    best = float("inf")
    for _ in range(2):
        t0 = time.perf_counter()
        jax.block_until_ready(run())
        best = min(best, time.perf_counter() - t0)
    return best


def _measure(op: str, m: int, n: int, d: int, precision: str,
             candidates) -> Optional[tuple[int, int]]:
    """Return the fastest feasible (bm, bn) candidate, or None."""
    best = None
    best_t = float("inf")
    for bm, bn in candidates:
        try:
            t = _time_candidate(op, m, n, d, precision, bm, bn)
        except Exception:  # OOM / unsupported shape: skip this candidate
            continue
        if t < best_t:
            best, best_t = (bm, bn), t
    return best


def schedule(op: str, m: int, n: int, d: int, precision: str = "fp32",
             backend: Optional[str] = None) -> Optional[tuple[int, int]]:
    """Tuned (row-block, col-block) for ``op`` at this shape bucket, or
    ``None`` when not on a compiled backend (callers keep their defaults).
    First compiled use per bucket measures :data:`CANDIDATES` and persists
    the winner."""
    if backend is None:
        import jax

        backend = jax.default_backend()
    if backend not in COMPILED_BACKENDS:
        return None
    try:
        import jax

        device_kind = jax.devices(backend)[0].device_kind.replace(" ", "_")
    except Exception:
        device_kind = backend
    key = _key(op, backend, device_kind, precision, m, n, d)
    with _lock:
        _load_locked()
        if key in _memory:
            return _memory[key]
    bm_cap, bn_cap = _bucket(m), _bucket(n)
    cands = [(bm, bn) for bm, bn in CANDIDATES if bm <= bm_cap and bn <= bn_cap]
    if not cands:
        cands = [(min(CANDIDATES[0][0], bm_cap), min(CANDIDATES[0][1], bn_cap))]
    won = _measure(op, _bucket(m), _bucket(n), _bucket(d), precision, cands)
    if won is None:
        return None
    with _lock:
        _memory[key] = won
        _save_locked()
    return won
