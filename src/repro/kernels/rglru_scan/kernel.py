"""RG-LRU (Griffin) gated linear recurrence Pallas kernel.

    h_t = a_t * h_{t-1} + g_t          (elementwise, diagonal recurrence)

TPU adaptation: channels tile the lane dimension (block br), the hidden state
h stays in VMEM scratch across the sequential time-chunk grid dimension, and
each chunk runs an in-register associative prefix:  within a chunk of length
ct we compute cumulative products A_t = prod a and prefix sums of g/A via a
log2(ct) Blelloch-style doubling loop — O(ct log ct) vector work instead of a
serial ct-step chain, which keeps the VPU busy at long sequence lengths.

Grid: (B, R/br, T/ct); time iterates sequentially carrying h.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, g_ref, o_ref, h_scr, *, ct: int, br: int):
    tj = pl.program_id(2)

    @pl.when(tj == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = a_ref[0].astype(jnp.float32)  # (ct, br)
    g = g_ref[0].astype(jnp.float32)

    # inclusive scan of h_t = a_t h_{t-1} + g_t via operator doubling:
    # pairs (A, G) compose as (A2*A1, A2*G1 + G2).
    A, G = a, g
    shift = 1
    while shift < ct:
        A_prev = jnp.roll(A, shift, axis=0)
        G_prev = jnp.roll(G, shift, axis=0)
        t_idx = jax.lax.broadcasted_iota(jnp.int32, (ct, br), 0)
        valid = t_idx >= shift
        G = jnp.where(valid, A * G_prev + G, G)
        A = jnp.where(valid, A * A_prev, A)
        shift *= 2
    h0 = h_scr[...]                     # (1, br)
    hs = A * h0 + G                     # (ct, br)
    h_scr[...] = hs[ct - 1 :, :]
    o_ref[0] = hs.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("ct", "br", "interpret"))
def rglru_scan_pallas(
    a: jax.Array,   # (B, T, R) decay in (0,1)
    g: jax.Array,   # (B, T, R) gated input
    ct: int = 128,
    br: int = 512,
    interpret: bool = True,
) -> jax.Array:
    b, t, r = a.shape
    assert t % ct == 0 and r % br == 0
    grid = (b, r // br, t // ct)

    def x_map(bi, ri, tj):
        return (bi, tj, ri)

    out = pl.pallas_call(
        functools.partial(_kernel, ct=ct, br=br),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, ct, br), x_map),
            pl.BlockSpec((1, ct, br), x_map),
        ],
        out_specs=pl.BlockSpec((1, ct, br), x_map),
        out_shape=jax.ShapeDtypeStruct((b, t, r), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, br), jnp.float32)],
        interpret=interpret,
    )(a, g)
    return out
