"""Public op: chunked RG-LRU scan."""
import jax

from .kernel import rglru_scan_pallas
from .ref import rglru_scan_ref  # noqa: F401


def rglru_scan(a, g, ct=128, br=512, interpret=None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, t, r = a.shape
    ct = min(ct, t)
    while t % ct:
        ct -= 1
    br = min(br, r)
    while r % br:
        br -= 1
    return rglru_scan_pallas(a, g, ct=ct, br=br, interpret=interpret)
