"""Pure-jnp oracle: RG-LRU diagonal recurrence via lax.scan."""
import jax
import jax.numpy as jnp


def rglru_scan_ref(a, g):
    """a, g: (B, T, R).  h_t = a_t h_{t-1} + g_t, h_0 = 0.  Returns (B,T,R)."""
    af = a.astype(jnp.float32)
    gf = g.astype(jnp.float32)

    def step(h, inp):
        at, gt = inp
        h = at * h + gt
        return h, h

    _, hs = jax.lax.scan(step, jnp.zeros(af.shape[::2], jnp.float32)[ :, :],
                         (af.swapaxes(0, 1), gf.swapaxes(0, 1)))
    return hs.swapaxes(0, 1)
