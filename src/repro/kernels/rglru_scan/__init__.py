from .ops import rglru_scan  # noqa: F401
