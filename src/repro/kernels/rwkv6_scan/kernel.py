"""RWKV6 (Finch) recurrence Pallas kernel — chunked over time.

Recurrence per head (state S in R^{dk x dv}):

    out_t = r_t @ (S + u * (k_t v_t^T))
    S    <- diag(w_t) S + k_t v_t^T

TPU adaptation: the state lives in VMEM scratch across the time-chunk grid
dimension; each program processes a (ct, hd) chunk of r/k/v/w for one
(batch*head), stepping through the chunk with a fori_loop of rank-1 updates.
HBM traffic is O(T*hd) per head instead of the O(T*hd^2) a naive scan
materialising per-step states would move.

Grid: (B*H, T/ct); time dimension iterates sequentially carrying S.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_scr, *, ct: int,
            hd: int, n_t_blocks: int):
    tj = pl.program_id(1)

    @pl.when(tj == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    r = r_ref[0].astype(jnp.float32)   # (ct, hd)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)  # (1, hd); u.T broadcasts over v-cols

    def step(t, carry):
        s, out = carry
        kt = jax.lax.dynamic_slice(k, (t, 0), (1, hd))   # (1, hd)
        vt = jax.lax.dynamic_slice(v, (t, 0), (1, hd))
        rt = jax.lax.dynamic_slice(r, (t, 0), (1, hd))
        wt = jax.lax.dynamic_slice(w, (t, 0), (1, hd))
        kv = kt.T @ vt                                    # (hd, hd)
        ot = rt @ (s + u.T * kv)                          # (1, hd)
        s = wt.T * s + kv
        out = jax.lax.dynamic_update_slice(out, ot, (t, 0))
        return s, out

    s0 = s_scr[...]
    s_fin, out = jax.lax.fori_loop(
        0, ct, step, (s0, jnp.zeros((ct, hd), jnp.float32))
    )
    s_scr[...] = s_fin
    o_ref[0] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("ct", "interpret"))
def rwkv6_scan_pallas(
    r: jax.Array,   # (B, H, T, hd)
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,   # decay in (0,1)
    u: jax.Array,   # (H, hd) bonus
    ct: int = 64,
    interpret: bool = True,
) -> jax.Array:
    b, h, t, hd = r.shape
    assert t % ct == 0
    grid = (b * h, t // ct)

    def x_map(bh, tj):
        return (bh, tj, 0)

    def u_map(bh, tj):
        return (bh % h, 0)

    rr = r.reshape(b * h, t, hd)
    out = pl.pallas_call(
        functools.partial(_kernel, ct=ct, hd=hd, n_t_blocks=t // ct),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, ct, hd), x_map),
            pl.BlockSpec((1, ct, hd), x_map),
            pl.BlockSpec((1, ct, hd), x_map),
            pl.BlockSpec((1, ct, hd), x_map),
            pl.BlockSpec((1, hd), u_map),
        ],
        out_specs=pl.BlockSpec((1, ct, hd), x_map),
        out_shape=jax.ShapeDtypeStruct((b * h, t, hd), jnp.float32),
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(rr, k.reshape(b * h, t, hd), v.reshape(b * h, t, hd),
      w.reshape(b * h, t, hd), u)
    return out.reshape(b, h, t, hd)
