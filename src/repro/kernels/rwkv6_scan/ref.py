"""Pure-jnp oracle: RWKV6 recurrence via lax.scan (matches
repro.models.recurrent.rwkv_time_mix inner loop)."""
import jax
import jax.numpy as jnp


def rwkv6_scan_ref(r, k, v, w, u):
    """r,k,v,w: (B, H, T, hd); u: (H, hd).  Returns (B, H, T, hd) f32."""
    rf, kf, vf, wf = (x.astype(jnp.float32) for x in (r, k, v, w))
    b, h, t, hd = rf.shape
    s0 = jnp.zeros((b, h, hd, hd), jnp.float32)

    def step(s, inp):
        rt, kt, vt, wt = inp  # (B, H, hd)
        kv = kt[..., :, None] * vt[..., None, :]
        out = jnp.einsum("bhk,bhkv->bhv", rt, s + u[None, :, :, None] * kv)
        s = wt[..., :, None] * s + kv
        return s, out

    xs = tuple(x.transpose(2, 0, 1, 3) for x in (rf, kf, vf, wf))
    _, outs = jax.lax.scan(step, s0, xs)
    return outs.transpose(1, 2, 0, 3)
