"""Public op: chunked RWKV6 recurrence."""
import jax

from .kernel import rwkv6_scan_pallas
from .ref import rwkv6_scan_ref  # noqa: F401


def rwkv6_scan(r, k, v, w, u, ct=64, interpret=None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    t = r.shape[2]
    ct = min(ct, t)
    while t % ct:
        ct -= 1
    return rwkv6_scan_pallas(r, k, v, w, u, ct=ct, interpret=interpret)
