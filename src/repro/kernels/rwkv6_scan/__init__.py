from .ops import rwkv6_scan  # noqa: F401
