from .optimizer import (  # noqa: F401
    OptimizerConfig,
    adamw_update,
    clip_by_global_norm,
    compress_grads,
    global_norm,
    init_opt_state,
    lr_schedule,
)
from .train_loop import make_train_step  # noqa: F401
