"""Train-step factory: microbatched (gradient-accumulation) loss/grad with
remat, mixed precision, optional gradient compression, and the AdamW update —
one jittable function for the launcher and the dry-run.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import loss_fn
from repro.models.config import ModelConfig
from .optimizer import OptimizerConfig, adamw_update, compress_grads


def _split_microbatches(batch: dict, n: int):
    def split(x):
        b = x.shape[0]
        assert b % n == 0, f"batch {b} not divisible by microbatches {n}"
        return x.reshape(n, b // n, *x.shape[1:])

    return jax.tree.map(split, batch)


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: Optional[OptimizerConfig] = None,
    num_microbatches: int = 1,
):
    opt_cfg = opt_cfg or OptimizerConfig()

    def grads_of(params, batch):
        loss, grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, batch))(params)
        return loss, grads

    def train_step(params, opt_state, batch):
        if num_microbatches > 1:
            micro = _split_microbatches(batch, num_microbatches)

            def accum(carry, mb):
                loss_acc, g_acc = carry
                loss, grads = grads_of(params, mb)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), g_acc, grads
                )
                return (loss_acc + loss, g_acc), ()

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            with jax.named_scope("accum_scan"):
                (loss_sum, grads), _ = jax.lax.scan(
                    accum, (jnp.zeros((), jnp.float32), zeros), micro
                )
            loss = loss_sum / num_microbatches
            grads = jax.tree.map(lambda g: g / num_microbatches, grads)
        else:
            loss, grads = grads_of(params, batch)
        grads = compress_grads(grads, opt_cfg.grad_compression)
        params, opt_state, stats = adamw_update(params, grads, opt_state, opt_cfg)
        metrics = {"loss": loss, **stats}
        return params, opt_state, metrics

    return train_step


__all__ = ["make_train_step", "init_opt_state", "OptimizerConfig"]
