"""Manual data-parallel train step via shard_map with *compressed* gradient
all-reduce.

Under pure-jit SPMD the gradient reduction is implicit, so casting gradients
after the fact cannot shrink the collective (measured in EXPERIMENTS.md
§Perf).  This variant owns the reduction: per-shard gradients are quantised
(int8 symmetric per-leaf, or bf16) *before* ``jax.lax.psum``, cutting
DP-gradient collective bytes 4× (int8) / 2× (bf16) at the cost of bounded
quantisation error — the gradient-compression trick of distributed
optimisation, done where it actually changes the wire format.

Scope: pure DP over the batch axes (the model is replicated inside the
shard_map; combine with TP by nesting meshes — left explicit and simple
here, with tests on a host mesh).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.models import loss_fn
from repro.models.config import ModelConfig
from .optimizer import OptimizerConfig, adamw_update


def _quantise_psum(g, axes, mode: str):
    """psum with on-the-wire compression."""
    if mode == "bf16":
        return jax.lax.psum(g.astype(jnp.bfloat16), axes).astype(jnp.float32)
    if mode == "int8":
        gf = g.astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
        # shared scale so the reduced value is exact w.r.t. the quantised terms
        scale = jax.lax.pmax(scale, axes)
        q = jnp.clip(jnp.round(gf / scale), -127.0, 127.0).astype(jnp.int8)
        # int8 would overflow when summed across N shards; widen to int32 on
        # the wire (still 2x smaller than f32, 4x smaller per-element payload
        # than f32 when links pack int8 lanes; we model int32 conservatively)
        s = jax.lax.psum(q.astype(jnp.int32), axes)
        return s.astype(jnp.float32) * scale
    return jax.lax.psum(g, axes)


def make_manual_dp_train_step(
    cfg: ModelConfig,
    mesh,
    opt_cfg: Optional[OptimizerConfig] = None,
    dp_axes: tuple = ("data",),
):
    """Returns step(params, opt_state, batch) with replicated params and
    batch sharded over ``dp_axes``; gradient reduction is an explicit,
    optionally compressed psum."""
    opt_cfg = opt_cfg or OptimizerConfig()
    mode = opt_cfg.grad_compression

    batch_spec = P(dp_axes if len(dp_axes) > 1 else dp_axes[0])

    def shard_fn(params, batch):
        loss, grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, batch))(params)
        n = 1
        for a in dp_axes:
            n *= mesh.shape[a]
        grads = jax.tree.map(
            lambda g: _quantise_psum(g, dp_axes, mode) / n, grads
        )
        loss = jax.lax.psum(loss, dp_axes) / n
        return loss, grads

    sharded = shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=(P(), batch_spec),   # prefix specs: params replicated,
        out_specs=(P(), P()),         # batch leaves sharded on dim 0
        check_rep=False,
    )

    @jax.jit
    def step(params, opt_state, batch):
        loss, grads = sharded(params, batch)
        params, opt_state, stats = adamw_update(params, grads, opt_state, opt_cfg)
        return params, opt_state, {"loss": loss, **stats}

    return step
