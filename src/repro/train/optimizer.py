"""Optimizers in pure JAX: AdamW with warmup+cosine schedule and global-norm
clipping.  Moments are float32; params stay in the model dtype (bf16) — the
standard memory/precision trade at scale (10 bytes/param optimizer state).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    # gradient compression applied before the (implicit) data-parallel
    # all-reduce: "none" | "bf16" | "int8" (stochastic-free symmetric quant)
    grad_compression: str = "none"


def lr_schedule(step, cfg: OptimizerConfig):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.decay_steps - cfg.warmup_steps, 1
    )
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog)
    )
    return cfg.peak_lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def compress_grads(grads, mode: str):
    """Lossy gradient compression (distributed-optimization trick): reduces
    the bytes the data-parallel all-reduce moves.  int8 uses per-tensor
    symmetric scaling; both modes round-trip back to f32 for the update."""
    if mode == "none":
        return grads
    if mode == "bf16":
        return jax.tree.map(
            lambda g: g.astype(jnp.bfloat16).astype(jnp.float32), grads
        )
    if mode == "int8":
        def q(g):
            gf = g.astype(jnp.float32)
            scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
            qg = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
            return qg.astype(jnp.float32) * scale

        return jax.tree.map(q, grads)
    raise ValueError(mode)


def adamw_update(params, grads, state, cfg: OptimizerConfig):
    """One AdamW step.  grads: same tree as params (any float dtype)."""
    step = state["step"] + 1
    lr = lr_schedule(step, cfg)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    b1, b2 = cfg.b1, cfg.b2

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * gf
        v = b2 * v + (1 - b2) * gf * gf
        mh = m / (1 - b1 ** step.astype(jnp.float32))
        vh = v / (1 - b2 ** step.astype(jnp.float32))
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {
        "lr": lr, "grad_norm": gnorm,
    }
