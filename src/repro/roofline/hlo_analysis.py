"""Optimized-HLO text analyzer: FLOPs, HBM-byte and collective-byte totals
per device, with while-loop trip-count expansion.

Why not ``compiled.cost_analysis()``: XLA counts a while body ONCE, so any
scan-over-layers model is undercounted by ~num_layers.  This analyzer builds
the computation call graph from the HLO text and recurses through fusions,
calls and whiles; a while's trip count comes from *hints* — the innermost
``jax.named_scope`` name appearing in the while op's metadata
(``layers_scan``, ``accum_scan``, ``attn_q_scan``, ``rwkv_time_scan``,
``rglru_time_scan`` — all scans the model code owns are named).

Byte accounting is a traffic proxy:
  * dot/convolution — operand + result bytes (weight/activation reads are the
    true MXU-side traffic; sliced weights are counted via their slice, not
    the full stacked array);
  * fusions and other materialising ops — 2 x result bytes (one write + one
    read by the consumer), with an in-place-stacking correction: a fusion
    inside a while body whose result's leading dim equals the trip count and
    whose result type matches an operand is a dynamic-update-slice
    accumulator and is counted once per loop, not per iteration;
  * collectives — standard per-device cost factors (all-reduce 2x, rest 1x).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
_OPCODE_RE = re.compile(r"^((?:\([^)]*\)|[\w\[\],{}]+)+)\s+([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_METADATA_RE = re.compile(r'op_name="([^"]*)"')
_CALL_ATTR_RE = re.compile(r"(calls|to_apply|body|condition)=%?([\w.\-]+)")

COLLECTIVES = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
    "all-reduce-start": 2.0,
    "all-gather-start": 1.0,
    "collective-permute-start": 1.0,
}

_SKIP_BYTES = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "iota", "broadcast", "reshape",
}


def _shapes_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> list:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    result_type: str
    operands: list
    attrs: str
    op_name: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: list


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_ops: dict = dataclasses.field(default_factory=dict)
    unresolved_whiles: list = dataclasses.field(default_factory=list)

    def __add__(self, o):
        co = dict(self.collective_ops)
        for k, v in o.collective_ops.items():
            co[k] = co.get(k, 0.0) + v
        return Cost(
            self.flops + o.flops,
            self.bytes + o.bytes,
            self.collective_bytes + o.collective_bytes,
            co,
            self.unresolved_whiles + o.unresolved_whiles,
        )

    def scaled(self, f: float):
        return Cost(
            self.flops * f, self.bytes * f, self.collective_bytes * f,
            {k: v * f for k, v in self.collective_ops.items()},
            self.unresolved_whiles,
        )


def parse_hlo(text: str) -> dict:
    """HLO module text -> {computation name: Computation}."""
    comps: dict = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if not line:
            continue
        if not line.startswith(" "):  # computation header or close
            m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\(|=)", line)
            if m and "{" in line:
                cur = Computation(m.group(1), [])
                comps[cur.name] = cur
                if line.startswith("ENTRY"):
                    comps["__entry__"] = cur
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        mo = _OPCODE_RE.match(rest)
        if not mo:
            continue
        result_type, opcode = mo.group(1), mo.group(2)
        # operands are inside the first (...) after the opcode
        depth, start, end = 0, rest.find(opcode + "(") + len(opcode), None
        for i in range(start, len(rest)):
            if rest[i] == "(":
                depth += 1
            elif rest[i] == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        args = rest[start + 1 : end] if end else ""
        attrs = rest[end + 1 :] if end else ""
        md = _METADATA_RE.search(rest)
        cur.ops.append(
            Op(
                name=name,
                opcode=opcode,
                result_type=result_type,
                operands=_OPERAND_RE.findall(args),
                attrs=attrs,
                op_name=md.group(1) if md else "",
            )
        )
    return comps


def _dot_flops(op: Op, symtab: dict) -> float:
    out_elems = 1
    for d in _shape_dims(op.result_type):
        out_elems *= d
    # contraction size from lhs operand shape
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
    if not m or not op.operands:
        return 2.0 * out_elems  # degenerate
    lhs = symtab.get(op.operands[0])
    if lhs is None:
        return 2.0 * out_elems
    dims = _shape_dims(lhs.result_type)
    k = 1
    for idx in m.group(1).split(","):
        if idx and int(idx) < len(dims):
            k *= dims[int(idx)]
    return 2.0 * out_elems * k


def _while_scope_from_body(body: Optional[Computation]) -> str:
    """Reconstruct a while op's scope when the op itself has no metadata
    (newer XLA drops op_name on hoisted/cloned while ops).  The body ops still
    carry full scope paths like ``.../jvp(layers_scan)/while/body/...``; the
    while's own scope is their longest common prefix cut at its *last*
    ``/while`` segment: body ops are named inside the loop's body scope
    (``<loop scope>/while/body/...``), so with nested scans the deepest
    common ``/while/body`` level identifies this loop — e.g. a layers-scan
    while inside an accum-scan has body ops all prefixed
    ``.../accum_scan/while/body/jvp(layers_scan)/while/body/`` and must
    resolve to ``jvp(layers_scan)``, not ``accum_scan``."""
    if body is None:
        return ""
    names = [op.op_name for op in body.ops if op.op_name]
    if not names:
        return ""
    prefix = names[0]
    for n in names[1:]:
        while not n.startswith(prefix):
            prefix = prefix[:-1]
            if not prefix:
                return ""
    cut = prefix.rfind("/while")
    return prefix[:cut] if cut >= 0 else prefix


def _innermost_hint(op_name: str, hints: dict) -> Optional[float]:
    """Most specific matching hint.  Keys may be compound ("a&b"): every part
    must appear in the op_name; specificity = number of parts, ties broken by
    the innermost (right-most) occurrence of the last part."""
    best, best_rank = None, (-1, -1)
    for key, val in hints.items():
        parts = key.split("&")
        if not all(p in op_name for p in parts):
            continue
        rank = (len(parts), op_name.rfind(parts[-1]))
        if rank > best_rank:
            best, best_rank = float(val), rank
    return best


def analyze(
    text: str,
    trip_hints: Optional[dict] = None,
    vmem_scopes: tuple = (),
) -> Cost:
    """Per-device cost of the entry computation with while expansion.

    ``vmem_scopes``: named scopes whose *intermediate* results are VMEM-
    resident in the fused Pallas kernel (e.g. ``attn_q_scan`` for flash
    attention — the score/softmax tensors never touch HBM on device).  Ops in
    those scopes contribute dot-operand bytes (the K/V streaming the kernel
    really does) but not fusion-result bytes.  This is the kernel-adjusted
    memory model used in §Perf; the unadjusted numbers are the XLA-lowerable
    baseline.
    """
    trip_hints = trip_hints or {}
    comps = parse_hlo(text)
    entry = comps.get("__entry__")
    if entry is None:
        raise ValueError("no ENTRY computation found in HLO text")
    memo: dict = {}

    def in_vmem_scope(op_name: str) -> bool:
        return any(s in op_name for s in vmem_scopes)

    def comp_cost(comp: Computation, trip_ctx: float) -> Cost:
        key = (comp.name, trip_ctx)
        if key in memo:
            return memo[key]
        memo[key] = Cost()  # cycle guard
        symtab = {op.name: op for op in comp.ops}
        total = Cost()
        for op in comp.ops:
            oc = op.opcode
            called = dict(_CALL_ATTR_RE.findall(op.attrs))
            if oc == "while":
                body = comps.get(called.get("body", ""))
                cond = comps.get(called.get("condition", ""))
                scope = op.op_name or _while_scope_from_body(body)
                trip = _innermost_hint(scope, trip_hints)
                if trip is None:
                    trip = 1.0
                    total.unresolved_whiles.append(scope or op.name)
                inner = Cost()
                if body:
                    inner = inner + comp_cost(body, trip)
                if cond:
                    inner = inner + comp_cost(cond, trip)
                total = total + inner.scaled(trip)
                continue
            if oc in ("fusion", "call", "conditional", "custom-call"):
                # inner dot flops + collectives; bytes at the fusion boundary
                for attr_name, cname in _CALL_ATTR_RE.findall(op.attrs):
                    sub = comps.get(cname)
                    if sub is not None and oc != "custom-call":
                        sc = comp_cost(sub, 1.0)
                        total = total + Cost(flops=sc.flops,
                                             collective_bytes=sc.collective_bytes,
                                             collective_ops=sc.collective_ops)
                op_bytes = 2.0 * _shapes_bytes(op.result_type)
                if in_vmem_scope(op.op_name):
                    op_bytes = 0.0
                # in-place scan-stacking accumulator: counted once per loop
                dims = _shape_dims(op.result_type)
                same_as_operand = any(
                    symtab[o].result_type == op.result_type
                    for o in op.operands if o in symtab
                )
                if (
                    op_bytes and trip_ctx > 1.0
                    and same_as_operand
                    and dims
                    and abs(dims[0] - trip_ctx) < 0.5
                ):
                    op_bytes /= trip_ctx
                total = total + Cost(bytes=op_bytes)
                continue
            if oc in ("dot", "convolution"):
                fl = _dot_flops(op, symtab)
                if in_vmem_scope(op.op_name):
                    # kernel streams operands from HBM; score results stay in VMEM
                    op_bytes = sum(
                        _shapes_bytes(symtab[o].result_type)
                        for o in op.operands if o in symtab
                    )
                else:
                    op_bytes = _shapes_bytes(op.result_type) + sum(
                        _shapes_bytes(symtab[o].result_type)
                        for o in op.operands if o in symtab
                    )
                total = total + Cost(flops=fl, bytes=op_bytes)
                continue
            if oc in COLLECTIVES:
                size = _shapes_bytes(op.result_type)
                if oc.startswith("reduce-scatter") and op.operands:
                    o0 = symtab.get(op.operands[0])
                    if o0:
                        size = _shapes_bytes(o0.result_type)
                cb = size * COLLECTIVES[oc]
                total = total + Cost(
                    bytes=size, collective_bytes=cb, collective_ops={oc: cb}
                )
                continue
            if oc in _SKIP_BYTES or oc.endswith("-done"):
                continue
            if in_vmem_scope(op.op_name):
                continue
            op_bytes = 2.0 * _shapes_bytes(op.result_type)
            dims = _shape_dims(op.result_type)
            same_as_operand = any(
                symtab[o].result_type == op.result_type
                for o in op.operands if o in symtab
            )
            if (
                trip_ctx > 1.0 and same_as_operand and dims
                and abs(dims[0] - trip_ctx) < 0.5
            ):
                op_bytes /= trip_ctx
            total = total + Cost(bytes=op_bytes)
        memo[key] = total
        return total

    return comp_cost(entry, 1.0)
