from . import hw  # noqa: F401
from .hlo_analysis import analyze, parse_hlo  # noqa: F401
from .report import load_records, model_flops, roofline_fraction, roofline_table  # noqa: F401
