"""Roofline report helpers: analytic MODEL_FLOPS and table generation from
dry-run JSON records."""
from __future__ import annotations

import glob
import json
import os

from repro.models.config import ModelConfig
from . import hw


def model_flops(cfg: ModelConfig, shape: dict) -> float:
    """Analytic useful FLOPs per step: 6*N*D for training, 2*N*D for prefill,
    2*N*B for one decode token (N = active params for MoE)."""
    n = cfg.active_param_count()
    if shape["kind"] == "train":
        tokens = shape["batch"] * shape["seq"]
        return 6.0 * n * tokens
    if shape["kind"] == "prefill":
        tokens = shape["batch"] * shape["seq"]
        return 2.0 * n * tokens
    return 2.0 * n * shape["batch"]  # decode: one token per sequence


def load_records(out_dir: str) -> list:
    recs = []
    for fn in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(fn) as f:
            recs.append(json.load(f))
    return recs


def roofline_table(recs: list, mesh: str = "16x16") -> str:
    """Markdown roofline table (single-pod records per the assignment)."""
    rows = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "MODEL_FLOPS/chip | useful ratio | mem/chip GiB |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if (r.get("mesh") != mesh or r.get("rules", "default") != "default"
                or r.get("tag")):
            continue
        if r["status"] == "skipped":
            rows.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | skipped: "
                f"{r['reason']} | — | — | — |"
            )
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | | |")
            continue
        rf = r["roofline"]
        rows.append(
            "| {arch} | {shape} | {c:.3e} | {m:.3e} | {x:.3e} | {dom} | "
            "{mf:.3e} | {ur:.2f} | {mem:.2f} |".format(
                arch=r["arch"], shape=r["shape"], c=rf["compute_s"],
                m=rf["memory_s"], x=rf["collective_s"], dom=rf["dominant"],
                mf=r["model_flops_per_chip"], ur=r["useful_compute_ratio"],
                mem=r["memory"]["total_bytes"] / 2**30,
            )
        )
    return "\n".join(rows)


def roofline_fraction(rec: dict) -> float:
    """Achieved fraction of the compute roofline: useful model FLOPs per chip
    over (bound time x peak).  This is the MFU-style score the perf loop
    drives up."""
    if rec.get("status") != "ok":
        return 0.0
    bound = rec["roofline"]["bound_s"]
    if bound <= 0:
        return 0.0
    return rec["model_flops_per_chip"] / (bound * hw.PEAK_FLOPS_BF16)
