"""Fault-tolerance runtime: retry/backoff, preemption handling, straggler
detection, and the restartable training driver glue.

At 1000+ nodes the failure model is: (a) preemption signals (evictions),
(b) hard node loss (job restarts from the latest atomic checkpoint, possibly
with a different device count — checkpoint restore reshards), (c) stragglers
(slow hosts detected from step-time outliers; the hook evicts/repairs).
This module implements the host-side machinery and is exercised by unit
tests with simulated failures.
"""
from __future__ import annotations

import dataclasses
import signal
import threading
import time
from typing import Callable, Optional

import numpy as np


def retry_with_backoff(
    fn: Callable,
    max_attempts: int = 5,
    base_delay: float = 0.05,
    retryable: tuple = (RuntimeError, OSError),
    on_retry: Optional[Callable] = None,
):
    """Run fn() with exponential backoff on transient failures."""
    attempt = 0
    while True:
        try:
            return fn()
        except retryable as e:  # noqa: PERF203
            attempt += 1
            if attempt >= max_attempts:
                raise
            if on_retry:
                on_retry(attempt, e)
            time.sleep(base_delay * (2 ** (attempt - 1)))


class PreemptionHandler:
    """Latches SIGTERM/SIGINT so the step loop can checkpoint and exit
    cleanly.  ``install()`` is idempotent; tests trigger via ``simulate()``."""

    def __init__(self):
        self._flag = threading.Event()
        self._installed = False

    def install(self):
        if self._installed:
            return
        try:
            signal.signal(signal.SIGTERM, lambda *_: self._flag.set())
            self._installed = True
        except ValueError:
            pass  # not on main thread (tests)

    def simulate(self):
        self._flag.set()

    @property
    def preempted(self) -> bool:
        return self._flag.is_set()


@dataclasses.dataclass
class StragglerReport:
    step: int
    step_time: float
    median: float
    ratio: float


class StragglerMonitor:
    """Tracks step times; flags steps slower than ``threshold`` x running
    median.  On a real fleet the callback triggers host eviction / hot-spare
    swap; here it feeds metrics + tests."""

    def __init__(self, threshold: float = 3.0, window: int = 32,
                 callback: Optional[Callable[[StragglerReport], None]] = None):
        self.threshold = threshold
        self.window = window
        self.callback = callback
        self.times: list = []
        self.reports: list = []

    def record(self, step: int, step_time: float):
        self.times.append(step_time)
        hist = self.times[-self.window:]
        med = float(np.median(hist[:-1])) if len(hist) > 1 else step_time
        if med > 0 and step_time > self.threshold * med:
            rep = StragglerReport(step, step_time, med, step_time / med)
            self.reports.append(rep)
            if self.callback:
                self.callback(rep)


class DeterministicSkipper:
    """Deterministic data-order resume: batch at step s is a pure function of
    (seed, s), so restarting from a checkpoint at step s replays the exact
    stream without storing loader state."""

    def __init__(self, seed: int):
        self.seed = seed

    def batch_rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(np.random.SeedSequence([self.seed, step]))
