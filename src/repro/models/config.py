"""Model configuration for the Oracle/embedder substrate.

One config per assigned architecture (see ``repro.configs``); reduced configs
drive the CPU smoke tests, full configs are exercised only via the dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // num_heads

    # attention
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    window: int = 0                 # >0: sliding-window (local) attention
    causal: bool = True

    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_capacity_factor: float = 1.25

    # encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 1500         # precomputed audio frames (stub frontend)

    # hybrid (recurrentgemma): layer pattern, e.g. ("rec", "rec", "attn")
    block_pattern: Tuple[str, ...] = ()
    rnn_width: int = 0              # RG-LRU width (0 -> d_model)
    conv_width: int = 4

    # vlm (pixtral): number of precomputed patch embeddings per sample
    num_patches: int = 0

    # ssm (rwkv6)
    rwkv_head_dim: int = 64
    rwkv_decay_lora: int = 64

    # misc
    norm_eps: float = 1e-5
    act: str = "silu"               # mlp activation: silu -> SwiGLU, gelu -> GeGLU/MLP
    tied_embeddings: bool = False
    dtype: str = "bfloat16"
    remat: bool = True              # activation checkpointing on the layer scan
    attn_q_chunk: int = 512         # q-block size for chunked (flash-style) attention
    scan_layers: bool = True
    # §Perf levers (defaults = paper-faithful straightforward baseline):
    bf16_backward: bool = False     # gradient dtype barriers at the CE and at
                                    # the attention f32-softmax boundary, so
                                    # the whole backward runs in bf16 instead
                                    # of f32 (halves dgrad bytes/collectives)
    remat_policy: str = "full"      # "full" | "dots" (save matmul outputs)

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.family == "hybrid" and self.rnn_width == 0:
            object.__setattr__(self, "rnn_width", self.d_model)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def has_positional_cache(self) -> bool:
        """Decode cache addressed by absolute position (full per-position KV
        rows), so a serving slot can be rewound to position 0 for mid-flight
        admission.  Recurrent state (ssm) and the hybrid ring buffer are not
        rewindable — their batchers must gate admission instead."""
        return self.family not in ("ssm", "hybrid")

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic sequence mixing -> can run the long_500k cell."""
        return self.family in ("ssm", "hybrid")

    @property
    def pattern(self) -> Tuple[str, ...]:
        if self.family == "hybrid" and self.block_pattern:
            return self.block_pattern
        if self.family == "moe":
            return ("moe",)
        if self.family == "ssm":
            return ("rwkv",)
        return ("dense",)

    def layer_types(self) -> list:
        """Concrete per-layer block types of the decoder stack."""
        pat = self.pattern
        return [pat[i % len(pat)] for i in range(self.num_layers)]

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS = 6ND)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.head_dim
        nq, nkv = self.num_heads, self.num_kv_heads
        attn = d * hd * (nq + 2 * nkv) + nq * hd * d
        dense_mlp = 3 * d * ff if self.act == "silu" or self.act == "geglu" else 2 * d * ff
        total = 0
        for t in self.layer_types():
            if t == "dense":
                total += attn + dense_mlp + 2 * d
            elif t == "moe":
                total += attn + self.num_experts * 3 * d * ff + d * self.num_experts + 2 * d
            elif t == "attn":  # hybrid local-attention block
                total += attn + 3 * d * ff + 2 * d
            elif t == "rec":   # RG-LRU block
                r = self.rnn_width
                total += 2 * d * r + r * self.conv_width + 2 * r * r + 2 * r + r * d
                total += 3 * d * ff + 2 * d
            elif t == "rwkv":
                total += 6 * d * d + 2 * d * self.rwkv_decay_lora * 0 + d * self.rwkv_decay_lora + self.rwkv_decay_lora * d
                total += d * ff + ff * d + d * d + 2 * d  # channel mix
        total += v * d * (1 if self.tied_embeddings else 2)
        if self.family == "encdec":
            enc_layer = attn + 2 * d * ff + 2 * d
            total += self.encoder_layers * enc_layer
            total += self.num_layers * (attn + d)  # decoder cross-attention
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k experts only)."""
        if self.family != "moe":
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        inactive = (self.num_experts - self.num_experts_per_tok) * 3 * d * ff
        return int(self.param_count() - self.num_layers * inactive)


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Shrink a config for CPU smoke tests, preserving the family topology."""
    small = dict(
        num_layers=min(cfg.num_layers, 2 if not cfg.block_pattern else len(cfg.pattern)),
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 4) if cfg.num_kv_heads > 1 else 1,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        num_experts=min(cfg.num_experts, 8) if cfg.num_experts else 0,
        num_experts_per_tok=min(cfg.num_experts_per_tok, 2) if cfg.num_experts else 0,
        # dropless at smoke scale so decode-vs-forward consistency holds
        moe_capacity_factor=8.0 if cfg.num_experts else cfg.moe_capacity_factor,
        encoder_layers=min(cfg.encoder_layers, 2),
        encoder_seq=32 if cfg.family == "encdec" else cfg.encoder_seq,
        rnn_width=64 if cfg.family == "hybrid" else 0,
        window=min(cfg.window, 16) if cfg.window else 0,
        num_patches=8 if cfg.num_patches else 0,
        rwkv_head_dim=16,
        rwkv_decay_lora=8,
        attn_q_chunk=16,
        remat=False,
    )
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
