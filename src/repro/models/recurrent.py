"""Recurrent sequence mixers: RWKV6 (Finch) time/channel mix and the RG-LRU
(RecurrentGemma/Griffin) block.

Both are written as lax.scan recurrences over time (``rwkv_time_scan`` /
``rglru_time_scan`` named scopes for the roofline analyzer).  The Pallas
kernels in ``repro.kernels.rwkv6_scan`` / ``rglru_scan`` implement the
chunked TPU-native versions; these jnp forms are their lowering-compatible
references and the decode path.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.sharding import shard_activation
from .config import ModelConfig
from .layers import dense_init, dtype_of


# ----------------------------------------------------------------------------
# RWKV6
# ----------------------------------------------------------------------------

def rwkv_params(key, cfg: ModelConfig):
    dt = dtype_of(cfg)
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    h = d // hd
    lora = cfg.rwkv_decay_lora
    ks = jax.random.split(key, 12)
    return {
        "time": {
            "mu_r": jnp.full((d,), 0.5, dt),
            "mu_k": jnp.full((d,), 0.5, dt),
            "mu_v": jnp.full((d,), 0.5, dt),
            "mu_g": jnp.full((d,), 0.5, dt),
            "mu_w": jnp.full((d,), 0.5, dt),
            "w_r": dense_init(ks[0], (d, d), dt),
            "w_k": dense_init(ks[1], (d, d), dt),
            "w_v": dense_init(ks[2], (d, d), dt),
            "w_g": dense_init(ks[3], (d, d), dt),
            "w_o": dense_init(ks[4], (d, d), dt),
            # data-dependent decay (Finch): w = exp(-exp(w0 + tanh(x A) B))
            "decay_w0": jnp.full((d,), -6.0, jnp.float32),
            "decay_a": dense_init(ks[5], (d, lora), dt),
            "decay_b": dense_init(ks[6], (lora, d), dt, scale=0.01),
            "bonus_u": dense_init(ks[7], (h, hd), jnp.float32, scale=0.1),
            "ln_x": jnp.ones((d,), jnp.float32),
        },
        "channel": {
            "mu_k": jnp.full((d,), 0.5, dt),
            "mu_r": jnp.full((d,), 0.5, dt),
            "w_k": dense_init(ks[8], (d, cfg.d_ff), dt),
            "w_v": dense_init(ks[9], (cfg.d_ff, d), dt),
            "w_r": dense_init(ks[10], (d, d), dt),
        },
    }


def _token_shift(x, last):
    """x: (B, T, d); last: (B, d) value preceding x[:, 0]."""
    prev = jnp.concatenate([last[:, None, :], x[:, :-1, :]], axis=1)
    return prev


def rwkv_time_mix(p, cfg: ModelConfig, x, state, last_x):
    """RWKV6 attention substitute.

    x: (B, T, d); state: (B, H, hd, hd) f32; last_x: (B, d).
    Returns (out, new_state, new_last_x).
    """
    b, t, d = x.shape
    hd = cfg.rwkv_head_dim
    h = d // hd
    prev = _token_shift(x, last_x)

    def mix(mu):
        return x + (prev - x) * mu

    r = (mix(p["mu_r"]) @ p["w_r"]).reshape(b, t, h, hd)
    k = (mix(p["mu_k"]) @ p["w_k"]).reshape(b, t, h, hd)
    v = (mix(p["mu_v"]) @ p["w_v"]).reshape(b, t, h, hd)
    g = jax.nn.silu(mix(p["mu_g"]) @ p["w_g"])
    xw = mix(p["mu_w"])
    dec = p["decay_w0"] + jnp.tanh(xw @ p["decay_a"]) @ p["decay_b"]
    w = jnp.exp(-jnp.exp(dec.astype(jnp.float32))).reshape(b, t, h, hd)
    u = p["bonus_u"]  # (H, hd)

    rf = r.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    def step(s, inp):
        rt, kt, vt, wt = inp  # (B, H, hd) each
        kv = kt[..., :, None] * vt[..., None, :]          # (B, H, hd, hd)
        out = jnp.einsum("bhk,bhkv->bhv", rt, s + u[None, :, :, None] * kv)
        s = wt[..., :, None] * s + kv
        return s, out

    with jax.named_scope("rwkv_time_scan"):
        state, outs = jax.lax.scan(
            step,
            state,
            (
                rf.swapaxes(0, 1),
                kf.swapaxes(0, 1),
                vf.swapaxes(0, 1),
                w.swapaxes(0, 1),
            ),
        )
    # outs: (T, B, H, hd) -> (B, T, d)
    out = outs.swapaxes(0, 1).reshape(b, t, d)
    # per-head group norm (ln_x)
    out = out.reshape(b, t, h, hd)
    mu_ = out.mean(-1, keepdims=True)
    var = out.var(-1, keepdims=True)
    out = (out - mu_) * jax.lax.rsqrt(var + 1e-5)
    out = out.reshape(b, t, d) * p["ln_x"]
    out = (out.astype(x.dtype) * g) @ p["w_o"]
    return out, state, x[:, -1, :]


def rwkv_channel_mix(p, cfg: ModelConfig, x, last_x):
    prev = _token_shift(x, last_x)
    xk = x + (prev - x) * p["mu_k"]
    xr = x + (prev - x) * p["mu_r"]
    k = jnp.square(jax.nn.relu(xk @ p["w_k"]))
    k = shard_activation(k, ("batch", "seq", "mlp"))
    return jax.nn.sigmoid(xr @ p["w_r"]) * (k @ p["w_v"]), x[:, -1, :]


def rwkv_state_init(cfg: ModelConfig, batch: int):
    hd = cfg.rwkv_head_dim
    h = cfg.d_model // hd
    return {
        "s": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "last_time": jnp.zeros((batch, cfg.d_model), jnp.dtype(cfg.dtype)),
        "last_chan": jnp.zeros((batch, cfg.d_model), jnp.dtype(cfg.dtype)),
    }


# ----------------------------------------------------------------------------
# RG-LRU (Griffin / RecurrentGemma)
# ----------------------------------------------------------------------------

RG_LRU_C = 8.0


def rglru_params(key, cfg: ModelConfig):
    dt = dtype_of(cfg)
    d, r = cfg.d_model, cfg.rnn_width
    ks = jax.random.split(key, 6)
    return {
        "w_x": dense_init(ks[0], (d, r), dt),
        "w_y": dense_init(ks[1], (d, r), dt),
        "conv_w": dense_init(ks[2], (cfg.conv_width, r), dt, scale=0.5),
        "conv_b": jnp.zeros((r,), dt),
        "w_gate_a": dense_init(ks[3], (r, r), dt),
        "b_gate_a": jnp.zeros((r,), jnp.float32),
        "w_gate_x": dense_init(ks[4], (r, r), dt),
        "b_gate_x": jnp.zeros((r,), jnp.float32),
        "lambda": jnp.asarray(
            np.linspace(0.65, 0.999, r).astype(np.float32)
        ),  # resolved to Lambda via softplus-параметrisation below
        "w_o": dense_init(ks[5], (r, d), dt),
    }


def _causal_conv(x, conv_w, conv_b, conv_state=None):
    """Depthwise causal conv via shifted adds (no conv HLO).

    x: (B, T, r); conv_w: (W, r); conv_state: (B, W-1, r) previous inputs.
    Returns (out, new_conv_state)."""
    b, t, r = x.shape
    w = conv_w.shape[0]
    if conv_state is None:
        conv_state = jnp.zeros((b, w - 1, r), x.dtype)
    ext = jnp.concatenate([conv_state, x], axis=1)  # (B, T+W-1, r)
    out = jnp.zeros_like(x)
    for i in range(w):
        out = out + ext[:, i : i + t, :] * conv_w[w - 1 - i]
    new_state = ext[:, -(w - 1) :, :] if w > 1 else conv_state
    return out + conv_b, new_state


def rglru_mix(p, cfg: ModelConfig, x, h0, conv_state):
    """Griffin recurrent block.

    x: (B, T, d); h0: (B, r) f32; conv_state: (B, W-1, r).
    Returns (out, h_T, new_conv_state)."""
    b, t, d = x.shape
    y = jax.nn.gelu(x @ p["w_y"])
    u = x @ p["w_x"]
    u = shard_activation(u, ("batch", "seq", "rnn"))
    u, conv_state = _causal_conv(u, p["conv_w"], p["conv_b"], conv_state)
    rg = jax.nn.sigmoid((u @ p["w_gate_a"]).astype(jnp.float32) + p["b_gate_a"])
    ig = jax.nn.sigmoid((u @ p["w_gate_x"]).astype(jnp.float32) + p["b_gate_x"])
    log_a = -RG_LRU_C * jax.nn.softplus(p["lambda"]) * rg  # (B, T, r) f32
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (
        ig * u.astype(jnp.float32)
    )

    def step(h, inp):
        at, gt = inp
        h = at * h + gt
        return h, h

    with jax.named_scope("rglru_time_scan"):
        h_t, hs = jax.lax.scan(step, h0, (a.swapaxes(0, 1), gated.swapaxes(0, 1)))
    hs = hs.swapaxes(0, 1).astype(x.dtype)  # (B, T, r)
    out = (y * hs) @ p["w_o"]
    return out, h_t, conv_state


def rglru_state_init(cfg: ModelConfig, batch: int):
    return {
        "h": jnp.zeros((batch, cfg.rnn_width), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.rnn_width), jnp.dtype(cfg.dtype)),
    }
