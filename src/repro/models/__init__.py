from .config import ModelConfig, reduced  # noqa: F401
from .model import (  # noqa: F401
    decode_step,
    forward,
    init_cache,
    init_params,
    loss_fn,
    prefill,
)
from .partition import param_logical_axes, param_shardings  # noqa: F401
