"""Parameter partitioning: logical axes per parameter leaf, derived from the
leaf's path and rank (t5x-style path rules) so the spec never drifts from the
param tree structure.

Logical names used on params:
  "fsdp"      — dim sharded over the FSDP axes (pod, data) in training rules
  "model_dim" — dim sharded over the tensor-parallel "model" axis
  "vocab"     — vocabulary dim ("model" axis)
  "expert"    — MoE expert dim ("model" axis, expert parallelism)
"""
from __future__ import annotations

import jax

# (key name) -> base logical axes (without any stacked-layer leading dims)
_RULES = {
    "embed": ("vocab", "fsdp"),
    "head": ("fsdp", "vocab"),
    "patch_proj": ("fsdp", "model_dim"),
    # attention
    "wq": ("fsdp", "model_dim"),
    "wk": ("fsdp", "model_dim"),
    "wv": ("fsdp", "model_dim"),
    "wo": ("model_dim", "fsdp"),
    "bq": ("model_dim",),
    "bk": ("model_dim",),
    "bv": ("model_dim",),
    # mlp
    "w_gate": ("fsdp", "model_dim"),
    "w_up": ("fsdp", "model_dim"),
    "w_down": ("model_dim", "fsdp"),
    "b_up": ("model_dim",),
    "b_down": (None,),
    # moe (rank-3 leaves resolved below)
    "router": (None, "expert"),
    # rwkv time mix
    "w_r": ("fsdp", "model_dim"),
    "w_k": ("fsdp", "model_dim"),
    "w_v": ("model_dim", "fsdp"),
    "w_g": ("fsdp", "model_dim"),
    "w_o": ("model_dim", "fsdp"),
    "decay_a": ("fsdp", None),
    "decay_b": (None, "fsdp"),
    "bonus_u": (None, None),
    # rglru
    "w_x": ("fsdp", "model_dim"),
    "w_y": ("fsdp", "model_dim"),
    "conv_w": (None, "model_dim"),
    "conv_b": ("model_dim",),
    "w_gate_a": ("fsdp", "model_dim"),
    "b_gate_a": ("model_dim",),
    "w_gate_x": ("fsdp", "model_dim"),
    "b_gate_x": ("model_dim",),
    "lambda": ("model_dim",),
}

# Expert weights: EP over "model" on the expert dim; the ff dim shards over
# the FSDP axes *without* per-layer gathers (each device keeps its ff slice
# and the down-proj contraction partial-sums) — gathering full expert
# tensors per layer would move ~5 GB/layer for the 235B MoE.
_MOE_RULES = {
    "w_gate": ("expert", None, "fsdp"),
    "w_up": ("expert", None, "fsdp"),
    "w_down": ("expert", "fsdp", None),
}


def _leaf_spec(path: tuple, leaf) -> tuple:
    keys = [p.key for p in path if hasattr(p, "key")]
    name = keys[-1] if keys else ""
    in_moe = "moe" in keys
    if in_moe and name in _MOE_RULES:
        base = _MOE_RULES[name]
    elif name in _RULES:
        base = _RULES[name]
    else:
        base = (None,) * leaf.ndim  # norms, scalars, mus
    extra = leaf.ndim - len(base)
    if extra < 0:  # e.g. tied/unstacked variant; truncate from the left
        base = base[-leaf.ndim:]
        extra = 0
    return (None,) * extra + tuple(base)


def param_logical_axes(params) -> dict:
    """Tree of logical-axis tuples matching ``params``."""
    flat = jax.tree_util.tree_flatten_with_path(params)
    paths_and_leaves, treedef = flat
    specs = [_leaf_spec(p, l) for p, l in paths_and_leaves]
    return jax.tree_util.tree_unflatten(treedef, specs)


def param_shardings(params_shape, mesh, rules):
    """NamedShardings for a tree of ShapeDtypeStructs (or arrays)."""
    from repro.launch.sharding import sharding_for

    axes = param_logical_axes(params_shape)
    return jax.tree.map(
        lambda spec, leaf: sharding_for(spec, leaf.shape, mesh, rules),
        axes,
        params_shape,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(e, (str, type(None))) for e in x),
    )
