"""Model assembly: decoder LMs (dense / MoE / VLM), RWKV6, RecurrentGemma-style
hybrids, and the Whisper-style encoder-decoder — all with scan-over-layers so
the HLO is O(1) in depth, with a uniform interface:

    init_params(cfg, key)                          -> params
    forward(cfg, params, batch)                    -> logits        (train/prefill)
    loss_fn(cfg, params, batch)                    -> scalar loss   (next-token CE)
    init_cache(cfg, batch, max_len)                -> cache
    decode_step(cfg, params, cache, tokens, pos)   -> (logits, cache)

``batch`` is a dict: {"tokens": (B, S)} plus, per modality,
{"frames": (B, T_enc, d)} (audio stub) or {"patches": (B, P, d)} (vision stub).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.sharding import shard_activation
from .config import ModelConfig
from .layers import (
    attention_params,
    chunked_attention,
    decode_attention,
    dense_init,
    dtype_of,
    mlp,
    mlp_params,
    moe_mlp,
    moe_params,
    rms_norm,
)
from .recurrent import (
    rglru_mix,
    rglru_params,
    rglru_state_init,
    rwkv_channel_mix,
    rwkv_params,
    rwkv_state_init,
    rwkv_time_mix,
)


# ----------------------------------------------------------------------------
# per-layer init / apply
# ----------------------------------------------------------------------------

def _layer_params(key, cfg: ModelConfig, kind: str):
    dt = dtype_of(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p = {"ln1": jnp.zeros((d,), jnp.float32), "ln2": jnp.zeros((d,), jnp.float32)}
    if kind == "dense":
        p["attn"] = attention_params(ks[0], cfg)
        p["mlp"] = mlp_params(ks[1], cfg)
    elif kind == "moe":
        p["attn"] = attention_params(ks[0], cfg)
        p["moe"] = moe_params(ks[1], cfg)
    elif kind == "attn":  # hybrid local-attention block
        p["attn"] = attention_params(ks[0], cfg)
        p["mlp"] = mlp_params(ks[1], cfg)
    elif kind == "rec":
        p["rec"] = rglru_params(ks[0], cfg)
        p["mlp"] = mlp_params(ks[1], cfg)
    elif kind == "rwkv":
        p.update(rwkv_params(ks[0], cfg))
    elif kind == "enc":
        p["attn"] = attention_params(ks[0], cfg, bias=False)
        p["mlp"] = mlp_params(ks[1], cfg)
    elif kind == "dec":  # decoder layer with cross-attention
        p["attn"] = attention_params(ks[0], cfg, bias=False)
        p["xattn"] = attention_params(ks[1], cfg, bias=False)
        p["lnx"] = jnp.zeros((d,), jnp.float32)
        p["mlp"] = mlp_params(ks[2], cfg)
    else:
        raise ValueError(kind)
    return p


def _apply_layer(cfg: ModelConfig, kind: str, p, x, positions, state=None,
                 enc_out=None, enc_positions=None):
    """Full-sequence layer application.  Returns (x, new_state)."""
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    new_state = state
    if kind in ("dense", "moe", "attn", "enc", "dec"):
        window = cfg.window
        causal = cfg.causal and kind != "enc"
        a = chunked_attention(
            p["attn"], cfg, h, positions, causal=causal, window=window,
            use_rope=(cfg.family != "encdec"),
        )
        x = x + a
        if kind == "dec":
            hx = rms_norm(x, p["lnx"], cfg.norm_eps)
            xa = chunked_attention(
                p["xattn"], cfg, hx, positions, kv_x=enc_out,
                kv_positions=enc_positions, causal=False, use_rope=False,
            )
            x = x + xa
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        if kind == "moe":
            x = x + moe_mlp(p["moe"], cfg, h2)
        else:
            x = x + mlp(p["mlp"], cfg, h2)
    elif kind == "rec":
        out, h_t, conv = rglru_mix(p["rec"], cfg, h, state["h"], state["conv"])
        x = x + out
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + mlp(p["mlp"], cfg, h2)
        new_state = {"h": h_t, "conv": conv}
    elif kind == "rwkv":
        out, s, last_t = rwkv_time_mix(p["time"], cfg, h, state["s"], state["last_time"])
        x = x + out
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        out2, last_c = rwkv_channel_mix(p["channel"], cfg, h2, state["last_chan"])
        x = x + out2
        new_state = {"s": s, "last_time": last_t, "last_chan": last_c}
    else:
        raise ValueError(kind)
    x = shard_activation(x, ("batch", "seq", "embed"))
    return x, new_state


# ----------------------------------------------------------------------------
# params
# ----------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key) -> dict:
    dt = dtype_of(cfg)
    keys = jax.random.split(key, 8)
    params: dict = {
        "embed": dense_init(keys[0], (cfg.vocab_size, cfg.d_model), dt, scale=0.02),
        "ln_f": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    if not cfg.tied_embeddings:
        params["head"] = dense_init(keys[1], (cfg.d_model, cfg.vocab_size), dt)

    types = cfg.layer_types()
    if cfg.family == "hybrid":
        pat = cfg.pattern
        nb = cfg.num_layers // len(pat)
        tail = types[nb * len(pat):]

        def init_block(k):
            ks = jax.random.split(k, len(pat))
            return {f"l{i}_{kind}": _layer_params(ks[i], cfg, kind)
                    for i, kind in enumerate(pat)}

        params["blocks"] = jax.vmap(init_block)(jax.random.split(keys[2], nb))
        params["tail"] = [
            _layer_params(k, cfg, kind)
            for k, kind in zip(jax.random.split(keys[3], max(len(tail), 1)), tail)
        ]
    elif cfg.family == "encdec":
        params["enc"] = jax.vmap(lambda k: _layer_params(k, cfg, "enc"))(
            jax.random.split(keys[2], cfg.encoder_layers)
        )
        params["layers"] = jax.vmap(lambda k: _layer_params(k, cfg, "dec"))(
            jax.random.split(keys[3], cfg.num_layers)
        )
        params["ln_enc"] = jnp.zeros((cfg.d_model,), jnp.float32)
    else:
        kind = types[0]
        params["layers"] = jax.vmap(lambda k: _layer_params(k, cfg, kind))(
            jax.random.split(keys[2], cfg.num_layers)
        )
    if cfg.num_patches:
        params["patch_proj"] = dense_init(keys[4], (cfg.d_model, cfg.d_model), dt)
    return params


# ----------------------------------------------------------------------------
# forward (train / prefill)
# ----------------------------------------------------------------------------

def _sinusoidal(seq: int, d: int):
    pos = np.arange(seq)[:, None]
    i = np.arange(d // 2)[None, :]
    ang = pos / (10000 ** (2 * i / d))
    return jnp.asarray(
        np.concatenate([np.sin(ang), np.cos(ang)], axis=-1), jnp.float32
    )


def _embed_inputs(cfg: ModelConfig, params, batch):
    """Token (+ modality stub) embedding -> (x, positions)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = params["embed"][tokens]
    if cfg.num_patches and "patches" in batch:
        patches = batch["patches"].astype(x.dtype) @ params["patch_proj"]
        x = jnp.concatenate([patches, x], axis=1)
    positions = jnp.broadcast_to(jnp.arange(x.shape[1])[None, :], (b, x.shape[1]))
    x = shard_activation(x, ("batch", "seq", "embed"))
    return x, positions


def _run_stack(cfg, stacked, x, positions, kind, enc_out=None, enc_positions=None,
               init_state_fn=None, scope="layers_scan"):
    """Scan over a stacked layer tree; heterogeneous state threaded through."""
    b = x.shape[0]

    def body(carry, layer_p):
        h = carry
        if init_state_fn is not None:
            st = init_state_fn(cfg, b)
        else:
            st = None
        h, _ = _apply_layer(cfg, kind, layer_p, h, positions, state=st,
                            enc_out=enc_out, enc_positions=enc_positions)
        return h, ()

    fn = body
    if cfg.remat:
        policy = (
            jax.checkpoint_policies.checkpoint_dots
            if cfg.remat_policy == "dots" else None
        )
        fn = jax.checkpoint(body, prevent_cse=False, policy=policy)
    with jax.named_scope(scope):
        x, _ = jax.lax.scan(fn, x, stacked)
    return x


def forward(cfg: ModelConfig, params, batch) -> jax.Array:
    """Full-sequence forward -> logits (B, S_total, V)."""
    if cfg.family == "encdec":
        return _forward_encdec(cfg, params, batch)
    x, positions = _embed_inputs(cfg, params, batch)
    kind = cfg.layer_types()[0]
    if cfg.family == "hybrid":
        x = _forward_hybrid(cfg, params, x, positions)
    elif cfg.family == "ssm":
        x = _run_stack(cfg, params["layers"], x, positions, "rwkv",
                       init_state_fn=rwkv_state_init)
    else:
        x = _run_stack(cfg, params["layers"], x, positions, kind)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    head = params["embed"].T if cfg.tied_embeddings else params["head"]
    logits = x @ head
    return shard_activation(logits, ("batch", "seq", "vocab"))


def _forward_hybrid(cfg: ModelConfig, params, x, positions):
    pat = cfg.pattern
    b = x.shape[0]

    def block_body(carry, block_p):
        h = carry
        for i, kind in enumerate(pat):
            st = rglru_state_init(cfg, b) if kind == "rec" else None
            h, _ = _apply_layer(cfg, kind, block_p[f"l{i}_{kind}"], h, positions, state=st)
        return h, ()

    if cfg.remat:
        policy = (
            jax.checkpoint_policies.checkpoint_dots
            if cfg.remat_policy == "dots" else None
        )
        fn = jax.checkpoint(block_body, prevent_cse=False, policy=policy)
    else:
        fn = block_body
    with jax.named_scope("layers_scan"):
        x, _ = jax.lax.scan(fn, x, params["blocks"])
    types = cfg.layer_types()
    tail = types[(cfg.num_layers // len(pat)) * len(pat):]
    for p, kind in zip(params["tail"], tail):
        st = rglru_state_init(cfg, b) if kind == "rec" else None
        x, _ = _apply_layer(cfg, kind, p, x, positions, state=st)
    return x


def _forward_encdec(cfg: ModelConfig, params, batch):
    frames = batch["frames"]  # (B, T_enc, d) precomputed conv-frontend output
    b, t_enc, _ = frames.shape
    enc_x = frames.astype(dtype_of(cfg)) + _sinusoidal(t_enc, cfg.d_model).astype(
        dtype_of(cfg)
    )
    enc_pos = jnp.broadcast_to(jnp.arange(t_enc)[None, :], (b, t_enc))
    with jax.named_scope("enc"):
        enc_x = _run_stack(cfg, params["enc"], enc_x, enc_pos, "enc",
                           scope="encoder_scan")
    enc_x = rms_norm(enc_x, params["ln_enc"], cfg.norm_eps)

    tokens = batch["tokens"]
    s = tokens.shape[1]
    x = params["embed"][tokens] + _sinusoidal(s, cfg.d_model).astype(dtype_of(cfg))
    pos = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    x = _run_stack(cfg, params["layers"], x, pos, "dec", enc_out=enc_x,
                   enc_positions=enc_pos)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    head = params["embed"].T if cfg.tied_embeddings else params["head"]
    return x @ head


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _ce_bf16(logits, targets, _dt):
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]


def _ce_bf16_fwd(logits, targets, _dt):
    return _ce_bf16(logits, targets, _dt), (logits, targets)


def _ce_bf16_bwd(_dt, res, g):
    logits, targets = res
    # softmax recomputed in f32; the cotangent leaving the CE is cast to the
    # model dtype so the entire transformer backward runs in bf16 (the f32
    # upcast of a straightforward CE otherwise poisons every dgrad/collective)
    p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=jnp.float32)
    d = (p - onehot) * g[..., None]
    return (d.astype(_dt), None)


_ce_bf16.defvjp(_ce_bf16_fwd, _ce_bf16_bwd)


def loss_fn(cfg: ModelConfig, params, batch) -> jax.Array:
    """Next-token cross entropy over the text positions."""
    logits = forward(cfg, params, batch)
    tokens = batch["tokens"]
    if cfg.num_patches and "patches" in batch:
        logits = logits[:, batch["patches"].shape[1]:, :]
    targets = tokens[:, 1:]
    logits = logits[:, :-1, :]
    if cfg.bf16_backward:
        nll = _ce_bf16(logits, targets, logits.dtype)
    else:
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask")
    if mask is not None:
        mask = mask[:, 1:]
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()


# ----------------------------------------------------------------------------
# decode (serving)
# ----------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    dt = dtype_of(cfg)
    nkv, hd = cfg.num_kv_heads, cfg.head_dim
    if cfg.family == "ssm":
        st = rwkv_state_init(cfg, batch)
        return {
            "state": jax.tree.map(
                lambda a: jnp.broadcast_to(a, (cfg.num_layers,) + a.shape), st
            )
        }
    if cfg.family == "hybrid":
        pat = cfg.pattern
        nb = cfg.num_layers // len(pat)
        w = min(cfg.window if cfg.window else max_len, max_len)
        block = {}
        for i, kind in enumerate(pat):
            if kind == "rec":
                st = rglru_state_init(cfg, batch)
                block[f"l{i}_state"] = jax.tree.map(
                    lambda a: jnp.broadcast_to(a, (nb,) + a.shape), st
                )
            else:
                block[f"l{i}_k"] = jnp.zeros((nb, batch, w, nkv, hd), dt)
                block[f"l{i}_v"] = jnp.zeros((nb, batch, w, nkv, hd), dt)
        tail_types = cfg.layer_types()[nb * len(pat):]
        tail = []
        for kind in tail_types:
            if kind == "rec":
                tail.append({"state": rglru_state_init(cfg, batch)})
            else:
                tail.append({
                    "k": jnp.zeros((batch, w, nkv, hd), dt),
                    "v": jnp.zeros((batch, w, nkv, hd), dt),
                })
        return {"blocks": block, "tail": tail}
    cache = {
        "k": jnp.zeros((cfg.num_layers, batch, max_len, nkv, hd), dt),
        "v": jnp.zeros((cfg.num_layers, batch, max_len, nkv, hd), dt),
    }
    if cfg.family == "encdec":
        # encoder seq padded to a sharding-friendly multiple; the decode
        # cross-attention masks positions >= cfg.encoder_seq
        t_enc = -(-cfg.encoder_seq // 64) * 64
        cache["xk"] = jnp.zeros((cfg.num_layers, batch, t_enc, nkv, hd), dt)
        cache["xv"] = jnp.zeros((cfg.num_layers, batch, t_enc, nkv, hd), dt)
    return cache


def _decode_layer_attn(cfg, p, x, k_cache, v_cache, position, window=0,
                       use_rope=True, xk=None, xv=None):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    a, k_cache, v_cache = decode_attention(
        p["attn"], cfg, h, k_cache, v_cache, position, window=window,
        use_rope=use_rope,
    )
    x = x + a
    if xk is not None:  # cross-attention over precomputed encoder KV
        hx = rms_norm(x, p["lnx"], cfg.norm_eps)
        b = hx.shape[0]
        q = (hx @ p["xattn"]["wq"]).reshape(b, 1, cfg.num_heads, cfg.head_dim)
        from .layers import _grouped_out, _grouped_scores

        scores = _grouped_scores(q, xk) * cfg.head_dim**-0.5
        valid = jnp.arange(xk.shape[1]) < cfg.encoder_seq  # mask cache padding
        scores = jnp.where(valid[None, None, None, None, :], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        out = _grouped_out(probs, xv).reshape(b, 1, -1) @ p["xattn"]["wo"]
        x = x + out
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        x = x + moe_mlp(p["moe"], cfg, h2)
    else:
        x = x + mlp(p["mlp"], cfg, h2)
    return x, k_cache, v_cache


def decode_step(cfg: ModelConfig, params, cache: dict, tokens, position):
    """One decode step.  tokens: (B, 1) int32; position: scalar int32 (same
    for the whole batch) or, when ``cfg.has_positional_cache``, (B,) int32
    per-slot positions — continuous batching passes the latter so slots
    admitted mid-flight rewind to position 0 without attending to a previous
    occupant's stale KV entries.  Families without a positional cache only
    support the scalar form; their batcher gates admission instead.
    Returns (logits (B, V), cache)."""
    x = params["embed"][tokens]
    b = x.shape[0]

    if cfg.family == "ssm":
        def body(carry, inp):
            h = carry
            layer_p, st = inp
            h, new_st = _apply_layer(cfg, "rwkv", layer_p, h, None, state=st)
            return h, new_st

        with jax.named_scope("layers_scan"):
            x, new_states = jax.lax.scan(body, x, (params["layers"], cache["state"]))
        cache = {"state": new_states}
    elif cfg.family == "hybrid":
        x, cache = _decode_hybrid(cfg, params, cache, x, position)
    elif cfg.family == "encdec":
        def body(carry, inp):
            h = carry
            layer_p, kc, vc, xk, xv = inp
            h, kc, vc = _decode_layer_attn(
                cfg, layer_p, h, kc, vc, position, use_rope=False, xk=xk, xv=xv)
            return h, (kc, vc)

        with jax.named_scope("layers_scan"):
            x, (nk, nv) = jax.lax.scan(
                body, x, (params["layers"], cache["k"], cache["v"], cache["xk"], cache["xv"]))
        cache = dict(cache, k=nk, v=nv)
    else:
        def body(carry, inp):
            h = carry
            layer_p, kc, vc = inp
            h, kc, vc = _decode_layer_attn(cfg, layer_p, h, kc, vc, position)
            return h, (kc, vc)

        with jax.named_scope("layers_scan"):
            x, (nk, nv) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
        cache = dict(cache, k=nk, v=nv)

    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    head = params["embed"].T if cfg.tied_embeddings else params["head"]
    logits = (x @ head)[:, 0, :]
    return logits, cache


def _decode_hybrid(cfg, params, cache, x, position):
    pat = cfg.pattern
    attn_i = next(i for i, k in enumerate(pat) if k == "attn")
    w = cache["blocks"][f"l{attn_i}_k"].shape[2]

    def block_body(carry, inp):
        h = carry
        block_p, block_c = inp
        new_c = {}
        for i, kind in enumerate(pat):
            p = block_p[f"l{i}_{kind}"]
            if kind == "rec":
                st = block_c[f"l{i}_state"]
                h, new_st = _apply_layer(cfg, "rec", p, h, None, state=st)
                new_c[f"l{i}_state"] = new_st
            else:
                kc, vc = block_c[f"l{i}_k"], block_c[f"l{i}_v"]
                hh = rms_norm(h, p["ln1"], cfg.norm_eps)
                a, kc, vc = _ring_decode_attention(cfg, p["attn"], hh, kc, vc, position, w)
                h = h + a
                h2 = rms_norm(h, p["ln2"], cfg.norm_eps)
                h = h + mlp(p["mlp"], cfg, h2)
                new_c[f"l{i}_k"], new_c[f"l{i}_v"] = kc, vc
        return h, new_c

    with jax.named_scope("layers_scan"):
        x, new_blocks = jax.lax.scan(block_body, x, (params["blocks"], cache["blocks"]))
    new_tail = []
    types = cfg.layer_types()
    nb = cfg.num_layers // len(pat)
    tail_types = types[nb * len(pat):]
    for p, kind, c in zip(params["tail"], tail_types, cache["tail"]):
        if kind == "rec":
            x, st = _apply_layer(cfg, "rec", p, x, None, state=c["state"])
            new_tail.append({"state": st})
        else:
            hh = rms_norm(x, p["ln1"], cfg.norm_eps)
            a, kc, vc = _ring_decode_attention(cfg, p["attn"], hh, c["k"], c["v"], position, w)
            x = x + a
            h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
            x = x + mlp(p["mlp"], cfg, h2)
            new_tail.append({"k": kc, "v": vc})
    return x, {"blocks": new_blocks, "tail": new_tail}


def _ring_decode_attention(cfg, p, x, k_cache, v_cache, position, w):
    """Sliding-window decode with a ring-buffer KV cache of size w."""
    from .layers import _grouped_out, _grouped_scores, _qkv, apply_rope

    b = x.shape[0]
    q, k, v = _qkv(p, cfg, x)
    pos = jnp.full((b, 1), position, jnp.int32)
    q = apply_rope(q.swapaxes(1, 2), pos[:, None, :], cfg.rope_theta).swapaxes(1, 2)
    k = apply_rope(k.swapaxes(1, 2), pos[:, None, :], cfg.rope_theta).swapaxes(1, 2)
    slot = position % w
    k_cache = jax.lax.dynamic_update_slice(k_cache, k.astype(k_cache.dtype), (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v.astype(v_cache.dtype), (0, slot, 0, 0))
    # absolute position stored in each ring slot
    idx = jnp.arange(w)
    slot_pos = position - ((position - idx) % w)
    valid = (slot_pos <= position) & (slot_pos > position - w) & (slot_pos >= 0)
    scores = _grouped_scores(q, k_cache) * cfg.head_dim**-0.5
    scores = jnp.where(valid[None, None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = _grouped_out(probs, v_cache).reshape(b, 1, -1) @ p["wo"]
    return out, k_cache, v_cache


def prefill(cfg: ModelConfig, params, batch, max_len: int):
    """Prefill: full forward + populate the KV cache for subsequent decode.

    For attention families the cache is rebuilt by re-projecting K/V per layer
    (cheap relative to the forward); recurrent families return final states.
    Returns (logits, cache).  Used by the serving layer; the dry-run lowers
    ``forward`` for prefill cells (the logits are what serving samples from).
    """
    logits = forward(cfg, params, batch)
    cache = init_cache(cfg, batch["tokens"].shape[0], max_len)
    return logits, cache
