"""Model building blocks: norms, RoPE, chunked (flash-style) GQA attention,
SwiGLU/GeGLU MLPs, and Switch-style MoE with sort-based capacity dispatch.

Everything is functional JAX over dict param trees.  Activation sharding is
annotated with logical axis names (see ``repro.launch.sharding``) so the same
code runs on one CPU device and on the 512-chip production mesh.

Scans that the roofline analyzer must expand are wrapped in
``jax.named_scope`` with stable names:  ``layers_scan`` (trip = num_layers),
``attn_q_scan`` (trip = seq / q_chunk), ``rwkv_time_scan`` / ``rglru_time_scan``
(trip = seq).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.sharding import shard_activation
from .config import ModelConfig


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ----------------------------------------------------------------------------
# init helpers
# ----------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    scale = scale if scale is not None else 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def grad_cast(x, dt):
    """Identity whose cotangent is cast to ``dt`` — a gradient dtype barrier.
    Placed where an f32 compute island (softmax) meets the bf16 stream, it
    keeps the f32 from propagating through the whole backward pass."""
    return x


def _grad_cast_fwd(x, dt):
    return x, None


def _grad_cast_bwd(dt, _res, g):
    return (g.astype(dt),)


grad_cast.defvjp(_grad_cast_fwd, _grad_cast_bwd)


# ----------------------------------------------------------------------------
# norms
# ----------------------------------------------------------------------------

def rms_norm(x, weight, eps):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + weight.astype(jnp.float32))).astype(dt)


def layer_norm(x, weight, bias, eps):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


# ----------------------------------------------------------------------------
# RoPE
# ----------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., seq, head_dim); positions: (..., seq) int32."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta), jnp.float32)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------------
# attention
# ----------------------------------------------------------------------------

def attention_params(key, cfg: ModelConfig, bias: Optional[bool] = None):
    dt = dtype_of(cfg)
    d, hd = cfg.d_model, cfg.head_dim
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    bias = cfg.qkv_bias if bias is None else bias
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, nq * hd), dt),
        "wk": dense_init(ks[1], (d, nkv * hd), dt),
        "wv": dense_init(ks[2], (d, nkv * hd), dt),
        "wo": dense_init(ks[3], (nq * hd, d), dt),
    }
    if bias:
        p["bq"] = jnp.zeros((nq * hd,), dt)
        p["bk"] = jnp.zeros((nkv * hd,), dt)
        p["bv"] = jnp.zeros((nkv * hd,), dt)
    return p


def _qkv(p, cfg, x, kv_x=None):
    """Project to (B, S, n, hd) heads."""
    b, s, _ = x.shape
    kv_x = x if kv_x is None else kv_x
    skv = kv_x.shape[1]
    q = x @ p["wq"]
    k = kv_x @ p["wk"]
    v = kv_x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, cfg.num_heads, cfg.head_dim)
    k = k.reshape(b, skv, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(b, skv, cfg.num_kv_heads, cfg.head_dim)
    return q, k, v


def _grouped_scores(q, k):
    """q: (B, S, nq, hd), k: (B, T, nkv, hd) -> scores (B, nkv, G, S, T)
    without materialising repeated KV heads."""
    b, s, nq, hd = q.shape
    nkv = k.shape[2]
    g = nq // nkv
    qg = q.reshape(b, s, nkv, g, hd)
    return jnp.einsum(
        "bsngh,btnh->bngst", qg, k, preferred_element_type=jnp.float32
    )


def _grouped_out(probs, v):
    """probs: (B, nkv, G, S, T), v: (B, T, nkv, hd) -> (B, S, nq, hd)."""
    b, nkv, g, s, t = probs.shape
    out = jnp.einsum("bngst,btnh->bsngh", probs.astype(v.dtype), v)
    return out.reshape(b, s, nkv * g, v.shape[-1])


def chunked_attention(
    p,
    cfg: ModelConfig,
    x,
    positions,
    kv_x=None,
    kv_positions=None,
    causal: bool = True,
    window: int = 0,
    use_rope: bool = True,
):
    """Full-sequence attention, scanned over q chunks so the peak score
    buffer is (B, H, q_chunk, T) — the memory shape FlashAttention gives on
    TPU (the Pallas kernel in ``repro.kernels.flash_attention`` is the
    on-device fused version; this is the XLA-lowerable equivalent)."""
    b, s, _ = x.shape
    q, k, v = _qkv(p, cfg, x, kv_x)
    t = k.shape[1]
    kv_positions = positions if kv_positions is None else kv_positions
    if use_rope:
        q = apply_rope(q.swapaxes(1, 2), positions[:, None, :], cfg.rope_theta).swapaxes(1, 2)
        k = apply_rope(k.swapaxes(1, 2), kv_positions[:, None, :], cfg.rope_theta).swapaxes(1, 2)
    # kv_seq is a distinct logical axis: sequence-parallel rules shard the
    # residual "seq" but K/V must stay seq-replicated for attention
    k = shard_activation(k, ("batch", "kv_seq", "kv_heads", "head_dim"))
    v = shard_activation(v, ("batch", "kv_seq", "kv_heads", "head_dim"))
    if cfg.bf16_backward:
        # dtype barrier: the f32 softmax island otherwise leaks f32 cotangents
        # into every layer's backward (2x dgrad bytes and collectives)
        dt = x.dtype
        q, k, v = grad_cast(q, dt), grad_cast(k, dt), grad_cast(v, dt)
    scale = cfg.head_dim**-0.5

    qc = max(min(cfg.attn_q_chunk, s), 1)
    n_chunks = (s + qc - 1) // qc
    pad = n_chunks * qc - s
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        positions = jnp.pad(positions, ((0, 0), (0, pad)))
    qs = q.reshape(b, n_chunks, qc, cfg.num_heads, cfg.head_dim)
    pos_chunks = positions.reshape(b, n_chunks, qc)

    def body(carry, inp):
        qi, pi = inp  # (B, qc, nq, hd), (B, qc)
        scores = _grouped_scores(qi, k) * scale  # (B, nkv, G, qc, T) f32
        mask = jnp.ones((), jnp.bool_)
        if causal:
            mask = pi[:, None, None, :, None] >= kv_positions[:, None, None, None, :]
        if window > 0:
            wmask = pi[:, None, None, :, None] - kv_positions[:, None, None, None, :] < window
            mask = jnp.logical_and(mask, wmask)
        if causal or window > 0:
            scores = jnp.where(mask, scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        out = _grouped_out(probs, v)  # (B, qc, nq, hd)
        return carry, out

    with jax.named_scope("attn_q_scan"):
        _, outs = jax.lax.scan(
            body, (), (qs.swapaxes(0, 1), pos_chunks.swapaxes(0, 1))
        )
    out = outs.swapaxes(0, 1).reshape(b, n_chunks * qc, cfg.num_heads, cfg.head_dim)
    out = out[:, :s]
    out = shard_activation(out, ("batch", "seq", "heads", "head_dim"))
    return out.reshape(b, s, -1) @ p["wo"]


def decode_attention(p, cfg: ModelConfig, x, cache_k, cache_v, position,
                     window: int = 0, use_rope: bool = True):
    """Single-token decode: append to the KV cache and attend over it.

    x: (B, 1, d); cache_k/v: (B, T_max, nkv, hd); position: scalar int32 (all
    rows at the same step) or (B,) int32 per-slot positions (continuous
    batching with mid-flight admission: each slot writes its KV at its own
    position and masks strictly by it, so a freshly admitted request never
    attends to a previous occupant's stale cache entries).
    Returns (out, new_cache_k, new_cache_v).
    """
    b = x.shape[0]
    q, k, v = _qkv(p, cfg, x)
    position = jnp.asarray(position, jnp.int32)
    per_slot = position.ndim == 1
    pos_b = position if per_slot else jnp.full((b,), position, jnp.int32)
    pos = pos_b[:, None]
    if use_rope:
        q = apply_rope(q.swapaxes(1, 2), pos[:, None, :], cfg.rope_theta).swapaxes(1, 2)
        k = apply_rope(k.swapaxes(1, 2), pos[:, None, :], cfg.rope_theta).swapaxes(1, 2)
    if per_slot:
        cache_k = cache_k.at[jnp.arange(b), pos_b].set(k[:, 0].astype(cache_k.dtype))
        cache_v = cache_v.at[jnp.arange(b), pos_b].set(v[:, 0].astype(cache_v.dtype))
    else:
        cache_k = jax.lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype), (0, position, 0, 0))
        cache_v = jax.lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype), (0, position, 0, 0))
    t = cache_k.shape[1]
    kv_pos = jnp.arange(t)[None, :]
    scores = _grouped_scores(q, cache_k) * cfg.head_dim**-0.5  # (B,nkv,G,1,T)
    mask = kv_pos[:, None, None, None, :] <= pos_b[:, None, None, None, None]
    if window > 0:
        mask = jnp.logical_and(
            mask, kv_pos[:, None, None, None, :] > pos_b[:, None, None, None, None] - window
        )
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = _grouped_out(probs, cache_v)
    out = out.reshape(b, 1, -1) @ p["wo"]
    return out, cache_k, cache_v


# ----------------------------------------------------------------------------
# MLPs
# ----------------------------------------------------------------------------

def mlp_params(key, cfg: ModelConfig, d_ff: Optional[int] = None):
    dt = dtype_of(cfg)
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.act in ("silu", "geglu"):
        return {
            "w_gate": dense_init(ks[0], (d, ff), dt),
            "w_up": dense_init(ks[1], (d, ff), dt),
            "w_down": dense_init(ks[2], (ff, d), dt),
        }
    return {  # plain 2-matrix MLP (whisper)
        "w_up": dense_init(ks[0], (d, ff), dt),
        "b_up": jnp.zeros((ff,), dt),
        "w_down": dense_init(ks[1], (ff, d), dt),
        "b_down": jnp.zeros((d,), dt),
    }


def mlp(p, cfg: ModelConfig, x):
    if "w_gate" in p:
        act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
        h = act(x @ p["w_gate"]) * (x @ p["w_up"])
        h = shard_activation(h, ("batch", "seq", "mlp"))
        return h @ p["w_down"]
    h = jax.nn.gelu(x @ p["w_up"] + p["b_up"])
    h = shard_activation(h, ("batch", "seq", "mlp"))
    return h @ p["w_down"] + p["b_down"]


# ----------------------------------------------------------------------------
# MoE: top-k routing + sort-based capacity dispatch (Switch/GShard on TPU)
# ----------------------------------------------------------------------------

def moe_params(key, cfg: ModelConfig):
    dt = dtype_of(cfg)
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d, e), jnp.float32),
        "w_gate": dense_init(ks[1], (e, d, ff), dt),
        "w_up": dense_init(ks[2], (e, d, ff), dt),
        "w_down": dense_init(ks[3], (e, ff, d), dt),
    }


def moe_mlp(p, cfg: ModelConfig, x):
    """x: (B, S, d).  Tokens are routed to top-k experts; dispatch goes through
    a (G, E, C, d) capacity buffer where G is the number of *batch shards* —
    every sort / gather / scatter carries the sharded leading group dimension,
    so dispatch stays shard-local (a global argsort would force XLA to
    all-gather the whole token set per layer).  Expert GEMMs contract across
    groups with the EP-sharded weights; overflow beyond capacity is dropped
    per group (standard Switch behaviour)."""
    from repro.launch.sharding import num_batch_shards

    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    g = num_batch_shards()
    if b % g != 0:
        g = 1
    t = (b // g) * s                                   # tokens per group
    xt = x.reshape(g, t, d)
    logits = xt.astype(jnp.float32) @ p["router"]      # (G, T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)             # (G, T, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    cap = int(np.ceil(t * k / e * cfg.moe_capacity_factor))
    cap = max(cap, 1)
    # pin dispatch intermediates to group-sharding only: without constraints
    # SPMD is free to shard the token axis over "model", which turns every
    # local sort/gather/scatter into masked-gather + all-reduce
    xt = shard_activation(xt, ("data_group", None, "embed"))
    flat_e = top_e.reshape(g, t * k)
    flat_e = shard_activation(flat_e, ("data_group", None))
    flat_tok = jnp.broadcast_to(
        jnp.repeat(jnp.arange(t), k)[None, :], (g, t * k)
    )
    flat_w = top_p.reshape(g, t * k)
    order = jnp.argsort(flat_e, axis=-1)               # group by expert, per shard
    e_sorted = jnp.take_along_axis(flat_e, order, axis=-1)
    tok_sorted = jnp.take_along_axis(flat_tok, order, axis=-1)
    w_sorted = jnp.take_along_axis(flat_w, order, axis=-1)
    tok_sorted = shard_activation(tok_sorted, ("data_group", None))
    # position within expert block, per group
    pos = jnp.broadcast_to(jnp.arange(t * k)[None, :], (g, t * k))
    start = jax.vmap(lambda es: jnp.searchsorted(es, jnp.arange(e)))(e_sorted)
    pos_in_e = pos - jnp.take_along_axis(start, e_sorted, axis=-1)
    keep = pos_in_e < cap
    slot = jnp.where(keep, e_sorted * cap + pos_in_e, e * cap)  # drop -> scratch

    # row-gather via vmapped integer indexing: take_along_axis would broadcast
    # the index to (T*k, d) u32 — terabytes of index traffic at 235B scale
    gathered = jax.vmap(lambda xg, idx: xg[idx])(xt, tok_sorted)  # (G,T*k,d)
    gathered = shard_activation(gathered, ("data_group", None, "embed"))
    buf = jnp.zeros((g, e * cap + 1, d), x.dtype)
    buf = jax.vmap(lambda bf, sl, xv: bf.at[sl].set(xv))(buf, slot, gathered)
    buf = buf[:, : e * cap].reshape(g, e, cap, d)
    buf = shard_activation(buf, ("data_group", "expert", "capacity", "embed"))

    act = jax.nn.silu if cfg.act in ("silu", "geglu") else jax.nn.gelu
    h = act(jnp.einsum("gecd,edf->gecf", buf, p["w_gate"])) * jnp.einsum(
        "gecd,edf->gecf", buf, p["w_up"]
    )
    h = shard_activation(h, ("data_group", "expert", "capacity", "expert_mlp"))
    out_buf = jnp.einsum("gecf,efd->gecd", h, p["w_down"])
    out_buf = shard_activation(out_buf, ("data_group", "expert", "capacity", "embed"))

    out_flat = out_buf.reshape(g, e * cap, d)
    out_flat = shard_activation(out_flat, ("data_group", None, "embed"))
    picked = jax.vmap(lambda og, idx: og[idx])(
        out_flat, jnp.minimum(slot, e * cap - 1)
    )
    contrib = jnp.where(keep[..., None], picked, 0.0) * w_sorted[..., None].astype(
        x.dtype
    )
    out = jnp.zeros((g, t, d), x.dtype)
    out = jax.vmap(lambda o, tk, c: o.at[tk].add(c))(out, tok_sorted, contrib)
    out = shard_activation(out, ("data_group", None, "embed"))
    return out.reshape(b, s, d)
