"""Memory-aware BAS engine dispatcher.

The dense path (``bas.run_bas``) materialises the flat chain-weight array —
(N1*...*Nk,) float64 — which is the fastest route while it fits in memory but
silently pays for the full cross product when it does not.  The streaming
path (``bas_streaming.run_bas_streaming``) keeps O(sum N_i + alpha*b) memory
at higher constant cost (two streamed similarity passes, walk+rejection D_0
sampling).  ``run_auto`` estimates the dense footprint from the
:class:`~repro.core.types.JoinSpec` alone and routes accordingly:

    dense      iff  n_tuples * 8 bytes <= cfg.max_dense_weight_bytes
    streaming  otherwise

The crossover constant is data-driven: ``benchmarks/bench_latency.py`` emits
dense-vs-streaming latency across problem sizes so the cap can be tuned per
deployment.  Both paths share the estimator assembly
(``bas.run_stratified_pipeline``), so estimates and CIs are statistically
interchangeable — dispatch is purely a resource decision.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.obs import DispatchTelemetry

from .bas import run_bas
from .bas_streaming import run_bas_streaming
from .types import Agg, BASConfig, JoinSpec, Query, QueryResult

_WEIGHT_BYTES = np.dtype(np.float64).itemsize


def dense_weight_bytes(spec: JoinSpec) -> int:
    """Bytes the dense path would allocate for the flat chain weights."""
    return spec.n_tuples * _WEIGHT_BYTES


def choose_path(spec: JoinSpec, cfg: Optional[BASConfig] = None) -> str:
    """'dense' | 'streaming' for a join spec under the configured memory cap."""
    cfg = cfg or BASConfig()
    return (
        "dense" if dense_weight_bytes(spec) <= cfg.max_dense_weight_bytes
        else "streaming"
    )


def run_auto(
    query: Query,
    cfg: Optional[BASConfig] = None,
    seed: int = 0,
    n_bins: int = 4096,
    index_store=None,
) -> QueryResult:
    """Execute BAS on whichever path the memory model selects.

    With an :class:`~repro.core.index.IndexStore`, a *fresh* resident
    artifact for the query's tables overrides the memory model: the query
    routes through the streaming path hydrating the stored sweep
    (``path="streaming-index"``) — a lookup instead of the dominant
    stratification pass.  A streaming-routed miss builds through the store
    (once; concurrent queries on the same tables share the build), so the
    next query hits.  Dense-routed misses stay dense: the store only wins
    once an artifact exists (built by a prior streaming query or the
    ``build-index`` launcher).

    The decision is recorded in ``result.telemetry.dispatch`` so callers
    (and the crossover benchmark) can audit it.

    ``cfg.cascade`` layers the multi-fidelity cascade (``core/cascade.py``)
    on top of the same memory decision: linear aggregates route through
    ``run_bas_cascade`` on the chosen regime (``path="cascade-dense"`` /
    ``"cascade-streaming"``); non-linear aggregates have no difference
    decomposition and fall through to plain BAS.
    """
    cfg = cfg or BASConfig()
    footprint = dense_weight_bytes(query.spec)
    path = choose_path(query.spec, cfg)
    artifact = None
    if index_store is not None:
        embeddings = [np.asarray(e, np.float32)
                      for e in query.spec.embeddings]
        artifact = index_store.lookup(
            embeddings, n_bins=n_bins, exponent=cfg.weight_exponent,
            floor=cfg.weight_floor, precision=cfg.sweep_precision,
        )
        if artifact is not None:
            path = "streaming-index"
    if cfg.cascade and query.agg in (Agg.COUNT, Agg.SUM, Agg.AVG):
        from .cascade import run_bas_cascade   # lazy: cascade imports us

        regime = "dense" if path == "dense" else "streaming"
        res = run_bas_cascade(
            query, cfg, seed=seed, path=regime, n_bins=n_bins,
            artifact=artifact,
            index_store=index_store if artifact is None else None,
        )
        path = f"cascade-{path}"
    elif path == "dense":
        res = run_bas(query, cfg, seed=seed)
    else:
        res = run_bas_streaming(
            query, cfg, seed=seed, n_bins=n_bins, artifact=artifact,
            index_store=index_store if artifact is None else None,
        )
    res.telemetry.dispatch = DispatchTelemetry(
        path=path,
        dense_weight_bytes=footprint,
        max_dense_weight_bytes=cfg.max_dense_weight_bytes,
        n_tuples=query.spec.n_tuples,
        sweep=cfg.use_sweep,
        sweep_precision=cfg.sweep_precision,
        index_store=index_store is not None,
    )
    return res
