"""Combined blocking+sampling estimators (paper §5.2 Eq. 1-3, §5.3 extensions).

A *stratum sample* carries, per sampled tuple: the Oracle label ``o``, the
aggregated value ``g`` and the (within-stratum, exact) sampling probability
``q``.  Horvitz-Thompson per-stratum totals::

    SUM_i-hat   = mean(g * o / q)
    COUNT_i-hat = mean(o / q)

are unbiased for the stratum totals; blocked strata contribute exact totals.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class StratumSample:
    o: np.ndarray          # (n,) oracle labels in {0,1}
    g: np.ndarray          # (n,) attribute values
    q: np.ndarray          # (n,) within-stratum sampling probabilities
    size: int              # |D_i|

    def __post_init__(self):
        self.o = np.asarray(self.o, np.float64)
        self.g = np.asarray(self.g, np.float64)
        self.q = np.asarray(self.q, np.float64)

    @property
    def n(self) -> int:
        return len(self.o)

    def sum_terms(self) -> np.ndarray:
        return self.g * self.o / self.q

    def count_terms(self) -> np.ndarray:
        return self.o / self.q

    def merge(self, other: "StratumSample") -> "StratumSample":
        assert self.size == other.size
        return StratumSample(
            o=np.concatenate([self.o, other.o]),
            g=np.concatenate([self.g, other.g]),
            q=np.concatenate([self.q, other.q]),
            size=self.size,
        )


@dataclasses.dataclass
class BlockedRegime:
    o: np.ndarray
    g: np.ndarray

    @property
    def count(self) -> float:
        return float(np.sum(self.o))

    @property
    def sum(self) -> float:
        return float(np.sum(self.g * self.o))


def _mean_var(x: np.ndarray) -> tuple[float, float]:
    m = float(np.mean(x)) if len(x) else 0.0
    v = float(np.var(x, ddof=1)) if len(x) > 1 else 0.0
    return m, v


def combined_sum(
    samples: list[StratumSample], blocked: BlockedRegime
) -> tuple[float, float]:
    """SUM-hat = SUM_b + sum_i mean(sum_terms_i); returns (estimate, var)."""
    est = blocked.sum
    var = 0.0
    for s in samples:
        m, v = _mean_var(s.sum_terms())
        est += m
        var += v / max(s.n, 1)
    return est, var


def combined_count(
    samples: list[StratumSample], blocked: BlockedRegime
) -> tuple[float, float]:
    est = blocked.count
    var = 0.0
    for s in samples:
        m, v = _mean_var(s.count_terms())
        est += m
        var += v / max(s.n, 1)
    return est, var


def combined_avg(
    samples: list[StratumSample],
    blocked: BlockedRegime,
    bias_correction: bool = True,
) -> tuple[float, float]:
    """Ratio estimator (Eq. 2) with Taylor bias correction (Eq. 3).

    Returns (estimate, var) where var is the delta-method variance of the
    ratio (paper §5.3 "Handling AVG").
    """
    s_hat, s_var = combined_sum(samples, blocked)
    c_hat, c_var = combined_count(samples, blocked)
    if c_hat <= 0:
        return 0.0, float("inf")
    avg = s_hat / c_hat
    if bias_correction and c_hat > 0:
        # Eq. (3): relative bias ~= Var[COUNT-hat] / COUNT-hat^2 (estimator
        # variance, already O(1/n)); clip to keep the correction sane when the
        # pilot variance estimate is noisy.
        corr = 1.0 - min(max(c_var / (c_hat**2), -0.5), 0.5)
        avg = avg * corr
    # delta-method variance; the cross-covariance term is computed from the
    # paired per-stratum terms (SUM and COUNT share samples).
    cov = 0.0
    for s in samples:
        st = s.sum_terms()
        ct = s.count_terms()
        if s.n > 1:
            cov += float(np.cov(st, ct, ddof=1)[0, 1]) / s.n
    var = (avg**2) * (
        s_var / max(s_hat**2, 1e-300)
        + c_var / max(c_hat**2, 1e-300)
        - 2.0 * cov / max(s_hat * c_hat, 1e-300)
    )
    return float(avg), float(max(var, 0.0))


def combined_extreme(
    samples: list[StratumSample], blocked: BlockedRegime, mode: str
) -> float:
    """MAX/MIN-hat = extreme over all *observed* matching values (paper §5.3)."""
    vals = []
    bm = blocked.o > 0
    if bm.any():
        vals.append(blocked.g[bm])
    for s in samples:
        m = s.o > 0
        if m.any():
            vals.append(s.g[m])
    if not vals:
        return float("nan")
    allv = np.concatenate(vals)
    return float(allv.max() if mode == "max" else allv.min())


def combined_cdf_median(
    samples: list[StratumSample], blocked: BlockedRegime
) -> float:
    """MEDIAN via the combined weighted CDF (paper §5.3 "Handling MEDIAN").

    Each blocked matching tuple contributes weight 1; each sampled matching
    tuple contributes its HT weight 1 / (n_i * q) — the estimated number of
    tuples it represents.
    """
    vals, wts = [], []
    bm = blocked.o > 0
    if bm.any():
        vals.append(blocked.g[bm])
        wts.append(np.ones(int(bm.sum()), np.float64))
    for s in samples:
        m = s.o > 0
        if m.any():
            vals.append(s.g[m])
            wts.append(1.0 / (s.n * s.q[m]))
    if not vals:
        return float("nan")
    v = np.concatenate(vals)
    w = np.concatenate(wts)
    order = np.argsort(v)
    v, w = v[order], w[order]
    c = np.cumsum(w)
    total = c[-1]
    pos = int(np.searchsorted(c, 0.5 * total))
    return float(v[min(pos, len(v) - 1)])


def weighted_quantile(
    values: np.ndarray, weights: np.ndarray, qs: np.ndarray
) -> np.ndarray:
    order = np.argsort(values)
    v, w = np.asarray(values)[order], np.asarray(weights)[order]
    c = np.cumsum(w)
    total = c[-1] if len(c) else 1.0
    out = []
    for q in np.atleast_1d(qs):
        pos = int(np.searchsorted(c, q * total))
        out.append(float(v[min(pos, len(v) - 1)]) if len(v) else float("nan"))
    return np.array(out)
