"""Bootstrap-t confidence intervals (paper §5.3 "CI via Resampling", App. B.1).

The merged pilot+main sample is not i.i.d. across strata, so CLT CIs are
invalid; bootstrap-t resampling *within each stratum* (the sampling design)
estimates the distribution of the studentised statistic

    t_j = (AGG_j-hat - AGG-hat) / sigma_j-hat

and uses its empirical percentiles:  CI = [mu - t_hi * s, mu - t_lo * s].
Blocked strata are constants and contribute no resampling variance.

Numerics: HT terms can be O(1e8); per-stratum terms are centred before
resampling (the t statistic is shift-invariant per stratum), which keeps the
reductions well-conditioned.
"""
from __future__ import annotations

import numpy as np

from .estimators import BlockedRegime, StratumSample, combined_avg, combined_count, combined_sum
from .types import Agg, ConfidenceInterval


def _resample_matrix(rng: np.random.Generator, n_boot: int, n: int) -> np.ndarray:
    return rng.integers(0, n, size=(n_boot, n))


def bootstrap_t_ci(
    samples: list[StratumSample],
    blocked: BlockedRegime,
    agg: Agg,
    p: float,
    n_boot: int,
    rng: np.random.Generator,
) -> tuple[float, ConfidenceInterval]:
    """Returns (point estimate, bootstrap-t CI)."""
    if agg is Agg.SUM:
        est, var = combined_sum(samples, blocked)
    elif agg is Agg.COUNT:
        est, var = combined_count(samples, blocked)
    elif agg is Agg.AVG:
        est, var = combined_avg(samples, blocked)
    else:
        raise ValueError(f"bootstrap-t only defined for linear aggs, got {agg}")
    sigma = float(np.sqrt(max(var, 0.0)))

    usable = [s for s in samples if s.n > 1]
    if not usable or sigma == 0.0:
        return est, ConfidenceInterval(est, est, p)

    # Per-resample per-stratum (mean shift, variance) for SUM / COUNT terms.
    sum_shift = np.zeros(n_boot)
    cnt_shift = np.zeros(n_boot)
    var_sum = np.zeros(n_boot)
    var_cnt = np.zeros(n_boot)
    cov_sc = np.zeros(n_boot)
    base_sum = blocked.sum
    base_cnt = blocked.count
    for s in usable:
        st = s.sum_terms()
        ct = s.count_terms()
        base_sum += float(st.mean())
        base_cnt += float(ct.mean())
        stc = st - st.mean()
        ctc = ct - ct.mean()
        ridx = _resample_matrix(rng, n_boot, s.n)
        rs = stc[ridx]
        rc = ctc[ridx]
        ms = rs.mean(axis=1)
        mc = rc.mean(axis=1)
        sum_shift += ms
        cnt_shift += mc
        vs = rs.var(axis=1, ddof=1) / s.n
        vc = rc.var(axis=1, ddof=1) / s.n
        var_sum += vs
        var_cnt += vc
        cov_sc += ((rs - ms[:, None]) * (rc - mc[:, None])).sum(axis=1) / (
            (s.n - 1) * s.n
        )
    for s in samples:
        if s.n == 1:  # single-sample strata: add their point mass, no variance
            base_sum += float(s.sum_terms().mean())
            base_cnt += float(s.count_terms().mean())

    if agg is Agg.SUM:
        est_j = base_sum + sum_shift
        sig_j = np.sqrt(np.maximum(var_sum, 0.0))
        base = base_sum
    elif agg is Agg.COUNT:
        est_j = base_cnt + cnt_shift
        sig_j = np.sqrt(np.maximum(var_cnt, 0.0))
        base = base_cnt
    else:  # AVG ratio per resample + delta-method sigma per resample
        sum_j = base_sum + sum_shift
        cnt_j = base_cnt + cnt_shift
        cnt_j = np.where(np.abs(cnt_j) < 1e-12, np.nan, cnt_j)
        est_j = sum_j / cnt_j
        base = base_sum / base_cnt if base_cnt != 0 else np.nan
        with np.errstate(invalid="ignore", divide="ignore"):
            sig_j = np.abs(est_j) * np.sqrt(
                np.maximum(
                    var_sum / sum_j**2 + var_cnt / cnt_j**2 - 2 * cov_sc / (sum_j * cnt_j),
                    0.0,
                )
            )

    with np.errstate(invalid="ignore", divide="ignore"):
        t = (est_j - base) / sig_j
    t = t[np.isfinite(t)]
    if len(t) < 10:
        return est, ConfidenceInterval(est - 10 * sigma, est + 10 * sigma, p)
    lo_q, hi_q = (1.0 - p) / 2.0, 1.0 - (1.0 - p) / 2.0
    t_lo = float(np.quantile(t, lo_q))
    t_hi = float(np.quantile(t, hi_q))
    ci = ConfidenceInterval(est - t_hi * sigma, est - t_lo * sigma, p)
    return est, ci
