"""Embedding similarity and sampling weights (paper §2, §5.1).

All similarity math is JAX (jit-compiled, shardable); the outputs the
statistical layer needs (weight vectors, sums) are returned as float64 numpy
for numerically robust aggregation.

Weight convention: embeddings are unit-normalised, so ``E1 @ E2.T`` is the
cosine similarity.  The paper treats similarity as an (approximate) match
probability, so we map it to a strictly positive weight::

    w = max(clip(cos, 0, 1), floor) ** exponent

The floor keeps every tuple reachable (a zero sampling probability would break
unbiasedness for false negatives — the exact failure mode of blocking the
paper is fixing); the exponent reproduces the Fig. 13b sensitivity knob.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


def normalize(emb: np.ndarray) -> np.ndarray:
    emb = np.asarray(emb, dtype=np.float32)
    norm = np.linalg.norm(emb, axis=-1, keepdims=True)
    return emb / np.maximum(norm, 1e-12)


@functools.partial(jax.jit, static_argnames=("exponent", "floor"))
def _pair_weights_jax(e1, e2, exponent: float, floor: float):
    sim = jnp.dot(e1, e2.T, preferred_element_type=jnp.float32)
    sim = jnp.clip(sim, 0.0, 1.0)
    w = jnp.maximum(sim, floor)
    if exponent != 1.0:
        w = w**exponent
    return w


def pair_weights(
    e1: np.ndarray,
    e2: np.ndarray,
    exponent: float = 1.0,
    floor: float = 1e-3,
    block: int = 8192,
) -> np.ndarray:
    """(N1, N2) sampling weights.  Blocked to bound peak memory."""
    e1 = np.asarray(e1, np.float32)
    e2 = np.asarray(e2, np.float32)
    n1 = e1.shape[0]
    if n1 <= block:
        return np.asarray(_pair_weights_jax(e1, e2, exponent, floor), np.float64)
    out = np.empty((n1, e2.shape[0]), np.float64)
    for s in range(0, n1, block):
        out[s : s + block] = np.asarray(
            _pair_weights_jax(e1[s : s + block], e2, exponent, floor), np.float64
        )
    return out


def chain_weights(
    embeddings: list[np.ndarray],
    exponent: float = 1.0,
    floor: float = 1e-3,
) -> np.ndarray:
    """Flattened (N1*...*Nk,) weights: product of consecutive pair weights.

    Paper Alg. 2 line 4: W(t) = prod_j sim(E(t_j), E(t_{j+1})).  Dense path —
    only used when the cross product fits in memory; the streaming/NN path in
    ``stratify.py`` covers the rest.
    """
    sizes = [e.shape[0] for e in embeddings]
    w = np.ones((1,), np.float64)
    # w has shape (prod(sizes[:i+1]),) after step i
    for i in range(len(embeddings) - 1):
        pw = pair_weights(embeddings[i], embeddings[i + 1], exponent, floor)
        if i == 0:
            w = pw.reshape(-1)
        else:
            # w: (prod(sizes[:i+1]),) indexed by (..., t_i); extend with t_{i+1}
            w = (w.reshape(-1, sizes[i])[:, :, None] * pw[None, :, :]).reshape(-1)
    return w


def quantize_rows_int8(emb: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Per-row symmetric int8 quantisation: ``emb ~= q * row_scale``.

    Used by the ``sim_sweep`` int8 fast path — scores reconstruct as
    ``(q1 @ q2^T) * rs1_i * rs2_j`` with exact int32 MXU accumulation, so
    the only error is the per-element rounding of the embeddings themselves
    (<= 0.5 * row_scale, i.e. ~0.4% of the row absmax).  All-zero rows
    (e.g. block padding) quantise to zeros with scale 0.
    """
    emb = np.asarray(emb, np.float32)
    absmax = np.abs(emb).max(axis=1, keepdims=True)
    row_scale = absmax / 127.0
    q = np.where(
        absmax > 0, np.rint(emb / np.maximum(row_scale, 1e-30)), 0.0
    ).astype(np.int8)
    return q, row_scale.astype(np.float32)


def dequantize_rows_int8(q: np.ndarray, row_scale: np.ndarray) -> np.ndarray:
    """Inverse of :func:`quantize_rows_int8` (up to rounding)."""
    return q.astype(np.float32) * np.asarray(row_scale, np.float32).reshape(-1, 1)


def weight_of_score(
    s: np.ndarray, exponent: float = 1.0, floor: float = 1e-3
) -> np.ndarray:
    """The score -> sampling-weight transform (single source of truth —
    stratification thresholds and sampling probabilities must agree)."""
    w = np.clip(s, 0.0, 1.0)
    w = np.maximum(w, floor)
    return w**exponent if exponent != 1.0 else w


def aligned_pair_weights(
    e1: np.ndarray,
    e2: np.ndarray,
    i: np.ndarray,
    j: np.ndarray,
    exponent: float = 1.0,
    floor: float = 1e-3,
) -> np.ndarray:
    """Elementwise weights for aligned index vectors (no cross block)."""
    sims = np.einsum("nd,nd->n", e1[i].astype(np.float64), e2[j].astype(np.float64))
    return weight_of_score(sims, exponent, floor)


def chain_tuple_weights(
    embeddings: list,
    idx: np.ndarray,
    exponent: float = 1.0,
    floor: float = 1e-3,
) -> np.ndarray:
    """Chain weights W(t) = prod_j w_j(t_j, t_{j+1}) for explicit (n, k)
    tuples — O(n * k * d), never touches the cross product."""
    idx = np.asarray(idx)
    w = np.ones(idx.shape[0], np.float64)
    for j in range(len(embeddings) - 1):
        w *= aligned_pair_weights(
            embeddings[j], embeddings[j + 1], idx[:, j], idx[:, j + 1],
            exponent, floor,
        )
    return w


# Pass accounting for the standalone walk-statistic recomputations below.
# The fused sweep (repro.core.stratify.sweep_pass*) emits row sums and the
# chain total in the same blocked pass as the histogram, so a streaming
# query that goes through the sweep — or hydrates a warm IndexArtifact —
# should never land here; tests assert these counters stay flat on those
# paths (see tests/test_chain_stats.py).
PASS_COUNTS: dict[str, int] = {"edge_row_sums": 0, "chain_total_weight": 0}


def edge_row_sums_raw(
    embeddings: list,
    exponent: float = 1.0,
    floor: float = 1e-3,
    block: int = 4096,
) -> list:
    """:func:`edge_row_sums` without the pass accounting — for internal
    callers (the fused sweep) that only touch cheap prefix edges."""
    out = []
    for j in range(len(embeddings) - 1):
        e1, e2 = embeddings[j], embeddings[j + 1]
        r = np.zeros(e1.shape[0], np.float64)
        for s in range(0, e1.shape[0], block):
            r[s : s + block] = pair_weights(
                e1[s : s + block], e2, exponent, floor
            ).sum(axis=1)
        out.append(r)
    return out


def edge_row_sums(
    embeddings: list,
    exponent: float = 1.0,
    floor: float = 1e-3,
    block: int = 4096,
) -> list:
    """Per-edge row sums r_j[i] = sum_t w_j(i, t), streamed in O(block * N)
    memory.  These normalise the WWJ walk distribution p(t) =
    (1/N1) * prod_j w_j(t_j, t_{j+1}) / r_j(t_j)."""
    PASS_COUNTS["edge_row_sums"] += 1
    return edge_row_sums_raw(embeddings, exponent, floor, block)


def chain_total_weight(
    embeddings: list,
    exponent: float = 1.0,
    floor: float = 1e-3,
    block: int = 4096,
) -> float:
    """sum over the full cross product of prod_j w_j — via the backward
    matrix-vector chain v_j = W_j v_{j+1}, streamed (O(max N) memory)."""
    PASS_COUNTS["chain_total_weight"] += 1
    v = np.ones(embeddings[-1].shape[0], np.float64)
    for j in range(len(embeddings) - 2, -1, -1):
        e1, e2 = embeddings[j], embeddings[j + 1]
        nxt = np.zeros(e1.shape[0], np.float64)
        for s in range(0, e1.shape[0], block):
            nxt[s : s + block] = pair_weights(
                e1[s : s + block], e2, exponent, floor
            ) @ v
        v = nxt
    return float(v.sum())


def flat_to_tuples(flat_idx: np.ndarray, sizes: tuple) -> np.ndarray:
    """(n,) flat cross-product indices -> (n, k) per-table indices."""
    return np.stack(np.unravel_index(np.asarray(flat_idx), sizes), axis=1).astype(
        np.int64
    )


def tuples_to_flat(idx: np.ndarray, sizes: tuple) -> np.ndarray:
    return np.ravel_multi_index(tuple(idx[:, j] for j in range(idx.shape[1])), sizes)
