"""Oracle interfaces + budget ledger.

The Oracle is the expensive pairwise (k-tuple-wise) labeller (paper §2).  Every
implementation routes through :class:`BudgetLedger`, which (a) enforces the
user-facing guarantee "the Oracle will not be executed on more than b tuples"
and (b) caches results so pilot-stage labels are reused in the main stage for
free (paper §5.3: "to avoid applying Oracle on the same data tuples twice, we
cache the Oracle results").
"""
from __future__ import annotations

import abc
from typing import Callable, Optional

import numpy as np


class BudgetExceeded(RuntimeError):
    pass


class Oracle(abc.ABC):
    """Labels k-tuples.  ``idx`` is an (n, k) int array of per-table indices."""

    def __init__(self):
        self._cache: dict = {}
        self.calls = 0          # unique tuples actually labelled
        self.requests = 0       # total tuples requested (incl. cache hits)
        self.budget: Optional[int] = None

    def set_budget(self, budget: Optional[int]) -> None:
        self.budget = budget

    @abc.abstractmethod
    def _label(self, idx: np.ndarray) -> np.ndarray:
        """Raw labelling; returns float array in {0.0, 1.0} of shape (n,)."""

    def label(self, idx: np.ndarray) -> np.ndarray:
        idx = np.asarray(idx)
        if idx.ndim == 1:
            idx = idx[:, None]
        n = idx.shape[0]
        self.requests += n
        keys = [tuple(int(v) for v in row) for row in idx]
        missing = [i for i, k in enumerate(keys) if k not in self._cache]
        if missing:
            if self.budget is not None and self.calls + len(missing) > self.budget:
                raise BudgetExceeded(
                    f"oracle budget {self.budget} exceeded: "
                    f"{self.calls} used, {len(missing)} new requested"
                )
            new_idx = idx[missing]
            new_labels = np.asarray(self._label(new_idx), dtype=np.float64)
            for j, i in enumerate(missing):
                self._cache[keys[i]] = float(new_labels[j])
            self.calls += len(missing)
        return np.array([self._cache[k] for k in keys], dtype=np.float64)

    @property
    def remaining(self) -> Optional[int]:
        return None if self.budget is None else self.budget - self.calls

    def reset(self) -> None:
        self._cache.clear()
        self.calls = 0
        self.requests = 0


class ArrayOracle(Oracle):
    """Ground-truth labels from a dense k-dim {0,1} array (tests/benchmarks)."""

    def __init__(self, truth: np.ndarray):
        super().__init__()
        self.truth = np.asarray(truth)

    def _label(self, idx: np.ndarray) -> np.ndarray:
        return self.truth[tuple(idx[:, j] for j in range(idx.shape[1]))].astype(
            np.float64
        )


class FnOracle(Oracle):
    """Labels via an arbitrary vectorised callable (e.g. pairwise chain rule)."""

    def __init__(self, fn: Callable[[np.ndarray], np.ndarray]):
        super().__init__()
        self.fn = fn

    def _label(self, idx: np.ndarray) -> np.ndarray:
        return np.asarray(self.fn(idx), dtype=np.float64)


class PairChainOracle(Oracle):
    """k-way chain-join Oracle from per-edge pair label matrices.

    A k-tuple matches iff every consecutive pair matches — the semantics the
    paper uses for its multi-way joins (Company-Scale, Ecomm-Q10/Q11).
    """

    def __init__(self, edge_truth: list[np.ndarray]):
        super().__init__()
        self.edge_truth = [np.asarray(m) for m in edge_truth]

    def _label(self, idx: np.ndarray) -> np.ndarray:
        out = np.ones(idx.shape[0], dtype=np.float64)
        for e, m in enumerate(self.edge_truth):
            out *= m[idx[:, e], idx[:, e + 1]].astype(np.float64)
        return out


class ModelOracle(Oracle):
    """Oracle backed by a served model: scorer(idx) -> probability, thresholded.

    ``scorer`` is expected to be the serving stack's batched pair scorer (see
    ``repro.serve``); this class only adds the ledger semantics.
    """

    def __init__(self, scorer: Callable[[np.ndarray], np.ndarray], threshold: float = 0.5):
        super().__init__()
        self.scorer = scorer
        self.threshold = threshold

    def _label(self, idx: np.ndarray) -> np.ndarray:
        probs = np.asarray(self.scorer(idx), dtype=np.float64)
        return (probs >= self.threshold).astype(np.float64)
