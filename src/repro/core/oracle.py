"""Oracle interfaces, budget ledger, and the batched execution layer.

The Oracle is the expensive pairwise (k-tuple-wise) labeller (paper §2).
Every implementation routes through the ledger semantics implemented here,
which (a) enforce the user-facing guarantee "the Oracle will not be executed
on more than b tuples" and (b) cache results so pilot-stage labels are reused
in the main stage for free (paper §5.3: "to avoid applying Oracle on the same
data tuples twice, we cache the Oracle results").

Cache layout
------------
Results are cached under *flat* cross-product indices: a (n, k) tuple-index
array is encoded to a (n,) int64 key vector (``tuples_to_flat`` when the
per-table sizes are bound via :meth:`Oracle.bind_sizes`, a fixed bit-packing
otherwise) and looked up against a **sorted** key array with
``np.searchsorted`` — no Python dict, no per-tuple round trips.  The query
pipelines bind sizes from ``query.spec.sizes`` before labelling anything, so
keys are stable across all stages of a query.

Batch / flush lifecycle
-----------------------
Callers never issue per-call-site model batches; they accumulate requests and
flush once per pipeline stage::

    batch = OracleBatch(oracle)
    h1 = batch.submit(tuples_a)      # (n1, k) — nothing is labelled yet
    h2 = batch.submit(tuples_b)      # (n2, k)
    batch.flush()                    # one _label() over the deduped union
    h1.labels, h2.labels             # per-request results, original order

``flush()`` is atomic with respect to the ledger: it dedupes the pending keys
against each other *and* against the cache, charges the budget once for the
unique uncached tuples, and only then issues a single ``_label`` call and
merges the results.  If the charge would exceed the budget,
:class:`BudgetExceeded` is raised *before* any labelling or cache mutation —
a failed flush leaves the Oracle exactly as it was.  ``Oracle.label`` is
sugar for a one-request batch, so ad-hoc callers keep the old interface.

Async mode
----------
When an :class:`repro.serve.oracle_service.OracleService` is attached to the
Oracle (``service.attach(oracle)``), ``flush_async()`` hands the deduped
pending set to the service and returns a ``concurrent.futures.Future``; the
service micro-batches requests **across queries**, executes them on its
scorer-worker pool, and resolves the request handles with exactly the
semantics of a local flush (same dedup, same atomic ledger charge, same
retryability on failure).  Without a service, ``flush_async()`` degrades to
an already-completed future around a local flush, so pipeline stages can
uniformly submit-then-await.  ``flush()`` stays the synchronous entry point
and routes through the service when one is attached — callers never need to
know which mode they are in.

Counters: ``requests`` counts every tuple submitted (cache hits included),
``calls`` counts unique tuples actually labelled (what the budget meters),
``batches`` counts flushes that labelled at least one new tuple — a
well-batched query keeps ``batches`` at O(pipeline stages) regardless of the
number of strata.  For a local flush that is exactly the number of backend
``_label`` invocations; under an attached service, cross-query fusion and
worker sharding make the true backend-call count differ (see
``OracleService.stats()["backend_calls"]``).

Charge-once accounting (shared label store)
-------------------------------------------
When the attached service carries a :class:`repro.serve.label_store
.LabelStore`, some of a flush's unique uncached keys are served from the
communal store instead of a backend execution.  Those keys still advance
``calls`` — the counter that paces the BAS pipeline and meters the
user-facing budget guarantee — so sampling decisions and estimates are
bit-identical to serial execution.  What changes is who *pays*: ``charged``
counts the keys this oracle's own flushes executed on a backend (the real
ledger spend), and ``store_hits``/``store_charge_saved`` count the keys
served communally.  Without a store ``charged == calls``; with one, the
workload-wide sum of ``charged`` equals the store's unique-miss count —
each distinct pair is charged exactly once, to its first requester.
"""
from __future__ import annotations

import abc
import dataclasses
import struct
from concurrent.futures import Future
from typing import Callable, Optional, Sequence

import numpy as np


class BudgetExceeded(RuntimeError):
    pass


# Marker for service-group keys built from id(...) — equality works within
# the process (coalescing, store segments), but the key is meaningless in
# another process, so the shared label store never persists such segments.
PROCESS_LOCAL = "#process-local"


# ---- wire payloads ----------------------------------------------------------
#
# The multi-host transport (repro.serve.transport) ships pre-planned label
# work between processes: a client plans a flush against its *own* cache and
# ledger, sends only the unique uncached tuple indices, and commits locally
# when the labels come back.  These two dataclasses are the payloads — pure
# numpy/struct encodings with a fixed little-endian layout, so the framing
# layer stays a dumb byte pipe and core/ carries the schema.  docs/serving.md
# documents the byte layout as part of the protocol spec.

_REQ_HDR = struct.Struct("<QIHH")   # request_id, n_rows, n_cols, group_len
_RES_HDR = struct.Struct("<QII")    # request_id, n_rows, error_len


@dataclasses.dataclass
class LabelRequest:
    """One pre-planned labelling segment: ``idx`` is the (n, k) int64 tuple
    indices to label through the server-side group ``group``.  The sender has
    already deduped against its cache and checked its budget — the server
    only executes."""

    group: str
    idx: np.ndarray
    request_id: int = 0

    def to_bytes(self) -> bytes:
        idx = np.ascontiguousarray(np.asarray(self.idx, dtype="<i8"))
        if idx.ndim != 2:
            raise ValueError(f"LabelRequest.idx must be (n, k), got {idx.shape}")
        group = self.group.encode("utf-8")
        hdr = _REQ_HDR.pack(self.request_id, idx.shape[0], idx.shape[1],
                            len(group))
        return hdr + group + idx.tobytes()

    @classmethod
    def from_bytes(cls, buf: bytes) -> "LabelRequest":
        request_id, n, k, glen = _REQ_HDR.unpack_from(buf, 0)
        off = _REQ_HDR.size
        group = buf[off:off + glen].decode("utf-8")
        off += glen
        want = n * k * 8
        raw = buf[off:off + want]
        if len(raw) != want:
            raise ValueError(
                f"LabelRequest payload truncated: {len(raw)} != {want} bytes"
            )
        idx = np.frombuffer(raw, dtype="<i8").reshape(n, k).astype(np.int64)
        return cls(group=group, idx=idx, request_id=request_id)


@dataclasses.dataclass
class LabelResult:
    """The server's reply to one :class:`LabelRequest`: either ``labels``
    (float64, aligned with the request's rows) or a non-empty ``error``
    string (``"ErrorType: message"``).  An errored result carries no rows."""

    request_id: int = 0
    labels: Optional[np.ndarray] = None
    error: str = ""

    @property
    def ok(self) -> bool:
        return not self.error

    def to_bytes(self) -> bytes:
        err = self.error.encode("utf-8")
        if err:
            return _RES_HDR.pack(self.request_id, 0, len(err)) + err
        labels = np.ascontiguousarray(np.asarray(self.labels, dtype="<f8"))
        if labels.ndim != 1:
            raise ValueError(
                f"LabelResult.labels must be (n,), got {labels.shape}"
            )
        hdr = _RES_HDR.pack(self.request_id, len(labels), 0)
        return hdr + labels.tobytes()

    @classmethod
    def from_bytes(cls, buf: bytes) -> "LabelResult":
        request_id, n, elen = _RES_HDR.unpack_from(buf, 0)
        off = _RES_HDR.size
        if elen:
            return cls(request_id=request_id,
                       error=buf[off:off + elen].decode("utf-8"))
        raw = buf[off:off + n * 8]
        if len(raw) != n * 8:
            raise ValueError(
                f"LabelResult payload truncated: {len(raw)} != {n * 8} bytes"
            )
        labels = np.frombuffer(raw, dtype="<f8").astype(np.float64)
        return cls(request_id=request_id, labels=labels)


class Oracle(abc.ABC):
    """Labels k-tuples.  ``idx`` is an (n, k) int array of per-table indices."""

    def __init__(self):
        self._keys = np.empty(0, np.int64)    # sorted flat cache keys
        self._vals = np.empty(0, np.float64)  # labels aligned with _keys
        self._sizes: Optional[tuple] = None   # bound per-table sizes
        self._pack: Optional[tuple] = None    # fallback encoding (k, bit width)
        self.calls = 0          # unique tuples acquired (budget pacing)
        self.requests = 0       # total tuples requested (incl. cache hits)
        self.batches = 0        # backend _label invocations
        self.charged = 0        # unique tuples this oracle paid to execute
        self.store_hits = 0     # unique tuples served by a shared LabelStore
        self.store_charge_saved = 0   # ledger charges avoided via the store
        self.budget: Optional[int] = None
        self.service = None     # attached OracleService (None = local flushes)

    def set_budget(self, budget: Optional[int]) -> None:
        self.budget = budget

    # ---- key encoding ------------------------------------------------------

    def bind_sizes(self, sizes: Sequence[int]) -> None:
        """Bind the per-table sizes so cache keys are exact flat indices.

        Rebinding with different sizes re-keys any cached entries (decode with
        the old encoding, encode with the new), so a long-lived Oracle can
        serve queries over different join specs without losing its cache.
        """
        sizes = tuple(int(s) for s in sizes)
        if self._sizes == sizes:
            return
        if len(self._keys):
            # validate + re-encode under the old state, then commit atomically
            # (a failed rebind must not leave keys in a mixed encoding)
            idx = self._decode(self._keys)
            if idx.shape[1] != len(sizes):
                raise ValueError(
                    f"bind_sizes: cache holds {idx.shape[1]}-tuples, "
                    f"got {len(sizes)} sizes"
                )
            if any(idx[:, j].max(initial=0) >= sizes[j] for j in range(idx.shape[1])):
                raise ValueError("bind_sizes: cached tuples exceed new sizes")
            keys = np.ravel_multi_index(
                tuple(idx[:, j] for j in range(idx.shape[1])), sizes
            ).astype(np.int64)
            order = np.argsort(keys, kind="stable")
            self._keys, self._vals = keys[order], self._vals[order]
        self._sizes, self._pack = sizes, None

    def _encode(self, idx: np.ndarray) -> np.ndarray:
        """(n, k) tuple indices -> (n,) int64 flat keys."""
        k = idx.shape[1]
        if self._sizes is not None:
            if len(self._sizes) != k:
                raise ValueError(
                    f"oracle bound to {len(self._sizes)} tables, got {k}-tuples"
                )
            return np.ravel_multi_index(
                tuple(idx[:, j] for j in range(k)), self._sizes
            ).astype(np.int64)
        # unbound fallback: fixed-width bit packing (stable across requests)
        if self._pack is None:
            self._pack = (k, 63 // k)
        elif self._pack[0] != k:
            raise ValueError(
                f"oracle cache packs {self._pack[0]}-tuples, got {k}-tuples"
            )
        _, bits = self._pack
        if idx.size and int(idx.max()) >= (1 << bits):
            raise ValueError(
                f"tuple index {int(idx.max())} does not fit the unbound "
                f"{bits}-bit key packing for k={k}; call oracle.bind_sizes()"
            )
        keys = np.zeros(idx.shape[0], np.int64)
        for j in range(k):
            keys = (keys << bits) | idx[:, j].astype(np.int64)
        return keys

    def _decode(self, keys: np.ndarray) -> np.ndarray:
        """(n,) flat keys -> (n, k) tuple indices (inverse of _encode)."""
        if self._sizes is not None:
            return np.stack(
                np.unravel_index(keys, self._sizes), axis=1
            ).astype(np.int64)
        k, bits = self._pack
        mask = (1 << bits) - 1
        cols = [(keys >> (bits * (k - 1 - j))) & mask for j in range(k)]
        return np.stack(cols, axis=1).astype(np.int64)

    # ---- labelling ---------------------------------------------------------

    @abc.abstractmethod
    def _label(self, idx: np.ndarray) -> np.ndarray:
        """Raw labelling; returns float array in {0.0, 1.0} of shape (n,)."""

    def label(self, idx: np.ndarray) -> np.ndarray:
        """One-request batch: submit + flush + return labels."""
        batch = OracleBatch(self)
        handle = batch.submit(idx)
        batch.flush()
        return handle.labels

    def service_group(self):
        """Coalescing key: flushes from oracles with *equal* keys may be fused
        into one backend execution by an attached service.  Two oracles share
        a key only when ``_label`` is the same pure function of the tuple
        indices for both (same backend model, same table bindings).  The
        default is per-instance (no cross-oracle fusion, but requests still
        micro-batch into the same service window and shard over its worker
        pool); :class:`ModelOracle` keys on its shared scorer.  id()-based
        keys carry the :data:`PROCESS_LOCAL` marker so the shared label
        store knows they cannot be persisted across restarts."""
        return (PROCESS_LOCAL, "oracle", id(self))

    def lookup(self, keys: np.ndarray) -> np.ndarray:
        """Cached labels for already-resolved keys (keys must all be cached)."""
        pos = np.searchsorted(self._keys, keys)
        return self._vals[pos]

    def _cached_mask(self, keys: np.ndarray) -> np.ndarray:
        pos = np.searchsorted(self._keys, keys)
        in_range = pos < len(self._keys)
        hit = np.zeros(len(keys), bool)
        hit[in_range] = self._keys[pos[in_range]] == keys[in_range]
        return hit

    def _merge(self, keys: np.ndarray, vals: np.ndarray) -> None:
        """Insert new (key, label) pairs, keeping the cache sorted."""
        merged_k = np.concatenate([self._keys, keys])
        merged_v = np.concatenate([self._vals, vals])
        order = np.argsort(merged_k, kind="stable")
        self._keys, self._vals = merged_k[order], merged_v[order]

    @property
    def remaining(self) -> Optional[int]:
        return None if self.budget is None else self.budget - self.calls

    @property
    def dedup_ratio(self) -> float:
        """Fraction of requested labels served without a backend execution."""
        if self.requests == 0:
            return 0.0
        return 1.0 - self.calls / self.requests

    def stats(self) -> dict:
        return {
            "calls": self.calls,
            "requests": self.requests,
            "batches": self.batches,
            "charged": self.charged,
            "store_hits": self.store_hits,
            "store_charge_saved": self.store_charge_saved,
            "dedup_ratio": round(self.dedup_ratio, 4),
        }

    def reset(self) -> None:
        self._keys = np.empty(0, np.int64)
        self._vals = np.empty(0, np.float64)
        self.calls = 0
        self.requests = 0
        self.batches = 0
        self.charged = 0
        self.store_hits = 0
        self.store_charge_saved = 0


def plan_requests(
    oracle: Oracle,
    requests: Sequence["OracleRequest"],
    extra_planned: Optional[np.ndarray] = None,
) -> tuple:
    """Plan a flush without mutating anything: encode every request, dedupe
    against the cache (and against ``extra_planned`` — keys another flush in
    the same service window has already claimed for this oracle), and check
    the budget.  Returns ``(keys_list, n_requested, new_keys)``; raises
    :class:`BudgetExceeded` if labelling ``new_keys`` would overrun.

    This is THE flush-planning algorithm: ``OracleBatch._flush_local`` and
    ``OracleService`` both call it, so local and served execution cannot
    drift apart semantically."""
    keys_list = [oracle._encode(r.idx) for r in requests]
    all_keys = (np.concatenate(keys_list) if keys_list
                else np.empty(0, np.int64))
    hit = oracle._cached_mask(all_keys)
    new_keys = np.unique(all_keys[~hit])
    already = 0
    if extra_planned is not None and len(extra_planned):
        new_keys = np.setdiff1d(new_keys, extra_planned, assume_unique=False)
        already = len(extra_planned)
    if len(new_keys) and oracle.budget is not None and (
            oracle.calls + already + len(new_keys) > oracle.budget):
        used = f"{oracle.calls} used"
        if already:
            used += f" (+{already} planned this window)"
        raise BudgetExceeded(
            f"oracle budget {oracle.budget} exceeded: {used}, "
            f"{len(new_keys)} new requested"
        )
    return keys_list, len(all_keys), new_keys


def commit_requests(
    oracle: Oracle,
    requests: Sequence["OracleRequest"],
    keys_list: list,
    n_requested: int,
    new_keys: np.ndarray,
    new_vals: Optional[np.ndarray],
    store_keys: Optional[np.ndarray] = None,
    store_vals: Optional[np.ndarray] = None,
) -> None:
    """Commit an executed flush: merge the fresh labels into the cache,
    charge the ledger atomically, and resolve every request handle.  The
    counterpart of :func:`plan_requests`, shared by local and served flushes;
    callers invoke it only after the backend execution succeeded.

    ``store_keys``/``store_vals`` are the store-consultation phase's output:
    keys of this flush served from a shared :class:`repro.serve.label_store
    .LabelStore` instead of a backend execution.  They merge into the cache
    and advance ``calls`` exactly like executed keys (so budget pacing — and
    therefore every estimate — is bit-identical to serial execution), but
    the ledger charge lands on ``store_hits``/``store_charge_saved`` rather
    than ``charged``: the store's first requester already paid."""
    n_store = len(store_keys) if store_keys is not None else 0
    if len(new_keys):
        oracle._merge(new_keys, new_vals)
        oracle.charged += len(new_keys)
        oracle.batches += 1
    if n_store:
        oracle._merge(store_keys, store_vals)
        oracle.store_hits += n_store
        oracle.store_charge_saved += n_store
    oracle.calls += len(new_keys) + n_store
    oracle.requests += n_requested
    for r, keys in zip(requests, keys_list):
        r._labels = oracle.lookup(keys)


class OracleRequest:
    """Handle returned by :meth:`OracleBatch.submit`; ``labels`` is populated
    by the owning batch's ``flush()``."""

    __slots__ = ("idx", "_labels")

    def __init__(self, idx: np.ndarray):
        self.idx = idx
        self._labels: Optional[np.ndarray] = None

    @property
    def labels(self) -> np.ndarray:
        if self._labels is None:
            raise RuntimeError("OracleBatch not flushed yet")
        return self._labels


class OracleBatch:
    """Request accumulator: coalesces many call sites into one ledger charge
    and one backend batch (see module docstring for the lifecycle)."""

    def __init__(self, oracle: Oracle):
        self.oracle = oracle
        self._pending: list[OracleRequest] = []

    def submit(self, idx: np.ndarray) -> OracleRequest:
        idx = np.asarray(idx)
        if idx.ndim == 1:
            idx = idx[:, None]
        req = OracleRequest(idx)
        self._pending.append(req)
        return req

    def flush(self) -> None:
        """Dedupe all pending requests, charge the ledger once, label once.

        Atomic: if the flush fails — :class:`BudgetExceeded` or a backend
        error from ``_label`` — nothing is mutated (no cache entries, no
        counters) and the requests stay pending, so the same batch can be
        retried after raising the budget or recovering the backend.  Keys
        are encoded at flush time, so a ``bind_sizes`` rebind between submit
        and flush is safe.

        An **empty** pending set is a guaranteed no-op: no backend call, no
        budget charge (even when the budget is already exhausted), and no
        counter movement.  With a service attached, routes through
        :meth:`flush_async` so concurrent queries coalesce."""
        self.flush_async().result()

    def flush_async(self) -> Future:
        """Submit-then-await entry point: returns a future that resolves
        (to ``None``) once every pending request's ``labels`` is populated.

        With a service attached to the oracle, the deduped pending set is
        enqueued into the service's micro-batching window and labelled on its
        worker pool alongside other queries' flushes; otherwise the flush
        runs locally (synchronously) and the returned future is already
        done.  Failures (:class:`BudgetExceeded`, backend errors) surface at
        ``.result()``; the requests stay pending in either mode, so the same
        batch can be retried."""
        if self.oracle.service is not None and self._pending:
            return self.oracle.service.submit(self)
        fut: Future = Future()
        try:
            self._flush_local()
        except BaseException as e:  # surfaced at .result(), like the service
            fut.set_exception(e)
        else:
            fut.set_result(None)
        return fut

    def _flush_local(self) -> None:
        """The synchronous flush: plan against the cache, execute, commit.
        Any failure before the commit leaves the oracle and the pending set
        exactly as they were."""
        if not self._pending:
            return
        o = self.oracle
        keys_list, n_requested, new_keys = plan_requests(o, self._pending)
        new_vals = None
        if len(new_keys):
            new_vals = np.asarray(o._label(o._decode(new_keys)), np.float64)
        pending, self._pending = self._pending, []
        commit_requests(o, pending, keys_list, n_requested, new_keys, new_vals)


class ArrayOracle(Oracle):
    """Ground-truth labels from a dense k-dim {0,1} array (tests/benchmarks)."""

    def __init__(self, truth: np.ndarray):
        super().__init__()
        self.truth = np.asarray(truth)
        self.bind_sizes(self.truth.shape)

    def _label(self, idx: np.ndarray) -> np.ndarray:
        return self.truth[tuple(idx[:, j] for j in range(idx.shape[1]))].astype(
            np.float64
        )


class FnOracle(Oracle):
    """Labels via an arbitrary vectorised callable (e.g. pairwise chain rule)."""

    def __init__(self, fn: Callable[[np.ndarray], np.ndarray]):
        super().__init__()
        self.fn = fn

    def _label(self, idx: np.ndarray) -> np.ndarray:
        return np.asarray(self.fn(idx), dtype=np.float64)


class PairChainOracle(Oracle):
    """k-way chain-join Oracle from per-edge pair label matrices.

    A k-tuple matches iff every consecutive pair matches — the semantics the
    paper uses for its multi-way joins (Company-Scale, Ecomm-Q10/Q11).
    """

    def __init__(self, edge_truth: list[np.ndarray]):
        super().__init__()
        self.edge_truth = [np.asarray(m) for m in edge_truth]
        self.bind_sizes(
            tuple(m.shape[0] for m in self.edge_truth)
            + (self.edge_truth[-1].shape[1],)
        )

    def _label(self, idx: np.ndarray) -> np.ndarray:
        out = np.ones(idx.shape[0], dtype=np.float64)
        for e, m in enumerate(self.edge_truth):
            out *= m[idx[:, e], idx[:, e + 1]].astype(np.float64)
        return out


class ModelOracle(Oracle):
    """Oracle backed by a served model: scorer(idx) -> probability, thresholded.

    ``scorer`` is the serving stack's batched pair scorer — either a
    :class:`repro.serve.serve_loop.PairScorer` instance or any vectorised
    callable; this class only adds the ledger semantics.  Because callers
    route through :class:`OracleBatch`, the scorer receives each pipeline
    stage's deduped union as one large request and applies its own device
    batching/sharding internally.

    ``name`` optionally gives the scorer a *stable* identity: named oracles
    fuse (and share label-store segments) by name rather than by object id,
    so their segments survive a service restart when the store persists to
    disk.  Naming is a contract — every oracle sharing a name must score
    through the same model weights.
    """

    def __init__(self, scorer, threshold: float = 0.5,
                 name: Optional[str] = None):
        super().__init__()
        self.scorer = scorer.score if hasattr(scorer, "score") else scorer
        self.threshold = threshold
        self.name = name

    def _label(self, idx: np.ndarray) -> np.ndarray:
        probs = np.asarray(self.scorer(idx), dtype=np.float64)
        return (probs >= self.threshold).astype(np.float64)

    def service_group(self):
        """Fuse with every oracle scoring through the same served model at the
        same threshold: concurrent queries against one scorer become one
        super-batch per service window.  Named oracles key on the name (a
        stable, persistable identity); unnamed ones key on the scorer
        *object* — for a bound ``scorer.score`` the owning instance, via
        ``__self__``, since each attribute access creates a fresh
        bound-method object whose id would never match across oracles."""
        if self.name is not None:
            return ("scorer", str(self.name), float(self.threshold))
        backend = getattr(self.scorer, "__self__", self.scorer)
        return (PROCESS_LOCAL, "scorer", id(backend), float(self.threshold))
