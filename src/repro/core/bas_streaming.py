"""BAS without materialising the cross product (paper §5.3, the
"cross product cannot fit into memory" regime) — k-way chain joins.

Differences from the dense path (``bas.run_bas``):

* stratification uses the histogram threshold
  (``stratify.stratify_streaming_chain``, backed by the fused single-sweep
  ``sim_sweep`` Pallas kernel with a blocked numpy fallback) — O(bins)
  memory, **one** streaming pass over prefix blocks emitting histogram +
  per-block count tiles + per-row top-k; collection reads the top-k and
  rescans only blocks the tiles flag.  The chain weight factorises as
  prefix-weight x last-edge pair weight, so the kernel's per-row ``scale``
  operand carries the prefix chain weight and nothing bigger than one block
  is materialised.  ``cfg.sweep_precision`` opts into the bf16/int8 MXU
  fast path (tolerance-gated, see ``stratify.sweep_pass``); the fp32
  default bins bit-identically to the retired two-pass schedule, and its
  fused walk statistics (row sums / chain total, compensated f32) agree
  with the f64 recomputation to ~1 ulp — so estimates match the two-pass
  path to ~1e-7 relative, with zero extra passes over the product;
* the minimum sampling regime D_0 is sampled by **walk + rejection**: WWJ
  walk proposals from the full-space distribution
  p(t) = (1/N1) * prod_j w_j(t_j, t_{j+1}) / r_j(t_j)
  are rejected if they fall in the blocking regime; accepted tuples have
  exact probability p(s) / (1 - P(top)), where P(top) = sum of full-space
  probabilities over the collected top set (computable from the streamed
  per-edge row sums) — so Horvitz-Thompson stays exact for any chain length;
* per-stratum weights are recomputed by gathering only the stratum's tuples
  (``similarity.chain_tuple_weights``, O(n * k * d)).

Estimator assembly (pilot, MSE-optimal blocking allocation, execution,
bootstrap-t CIs, and the MIN/MAX/MEDIAN extensions) is the *same code* as the
dense path: ``bas.run_stratified_pipeline`` over a ``StratifiedSpace`` whose
callbacks never touch the cross product.  That shared pipeline submits each
stage's labelling asynchronously (submit-then-await), so streaming queries
attached to an :class:`repro.serve.oracle_service.OracleService` coalesce
their pilot/blocking/top-up rounds with concurrent queries exactly like
dense ones.

Memory: O(sum_i N_i + alpha*b + b + bins) — never O(N1*...*Nk).  The engine
front-end picks this path automatically when the dense flat-weight footprint
exceeds ``BASConfig.max_dense_weight_bytes`` (see ``dispatch.run_auto``).
"""
from __future__ import annotations

import time
from typing import Optional

import numpy as np

from .bas import StratifiedSpace, StratumDraw, run_exact, run_stratified_pipeline
from .similarity import (
    aligned_pair_weights,
    chain_total_weight,
    chain_tuple_weights,
    edge_row_sums,
    flat_to_tuples,
    tuples_to_flat,
)
from .stratify import stratify_streaming_chain
from .types import BASConfig, Query, QueryResult
from .wander import flat_sample, walk_sample


def _walk_rejection_sample(
    embeddings: list,
    sizes: tuple,
    top_set: set,
    n: int,
    cfg: BASConfig,
    rng: np.random.Generator,
    max_rounds: int = 50,
):
    """Sample n tuples from D_0 with exact probabilities: k-way WWJ walk
    proposals, rejected when they land in the blocking regime.  Returns
    ((m, k) tuples, (m,) full-space walk probabilities), m <= n."""
    k = len(embeddings)
    out_idx = np.empty((n, k), np.int64)
    out_p = np.empty(n, np.float64)
    got = 0
    for _ in range(max_rounds):
        need = n - got
        if need <= 0:
            break
        m = max(int(need * 1.3) + 16, 32)
        ws = walk_sample(embeddings, m, rng, cfg.weight_exponent, cfg.weight_floor)
        flat = tuples_to_flat(ws.idx, sizes)
        keep = np.fromiter((f not in top_set for f in flat), bool, len(flat))
        take = min(int(keep.sum()), need)
        out_idx[got : got + take] = ws.idx[keep][:take]
        out_p[got : got + take] = ws.prob[keep][:take]
        got += take
    return out_idx[:got], out_p[:got]


def build_streaming_space(
    query: Query,
    cfg: BASConfig,
    rng: np.random.Generator,
    timings: dict,
    n_bins: int = 4096,
    use_kernel: Optional[bool] = None,
    use_sweep: Optional[bool] = None,
    precision: Optional[str] = None,
    artifact=None,
    index_store=None,
) -> tuple:
    """Stage 1 of the streaming path: histogram stratification + the
    walk+rejection D_0 sampler, packaged as a :class:`StratifiedSpace`.
    Returns ``(space, extra_detail)`` — the extra detail carries the
    streaming-specific keys (``p_top``, ``use_kernel``) the caller merges
    into its pipeline detail dict.  Shared by ``run_bas_streaming`` and the
    cascade estimator so both spend stage 1 identically."""
    if use_kernel is None:
        use_kernel = cfg.use_kernel
    if use_sweep is None:
        use_sweep = cfg.use_sweep
    if precision is None:
        precision = cfg.sweep_precision

    embeddings = [np.asarray(e, np.float32) for e in query.spec.embeddings]
    sizes_spec = tuple(e.shape[0] for e in embeddings)
    exp, floor = cfg.weight_exponent, cfg.weight_floor

    # ---- streaming stratification (single fused sweep) -------------------
    t0 = time.perf_counter()
    index_hit = None
    index_build_ms = None
    if artifact is None and index_store is not None:
        artifact, index_hit = index_store.get_or_build(
            embeddings, n_bins=n_bins, exponent=exp, floor=floor,
            precision=precision, use_kernel=use_kernel,
        )
        if not index_hit:
            index_build_ms = (time.perf_counter() - t0) * 1e3
    elif artifact is not None:
        index_hit = True
    strat = stratify_streaming_chain(
        embeddings, cfg.alpha, query.budget, cfg, n_bins=n_bins,
        use_kernel=use_kernel, use_sweep=use_sweep, precision=precision,
        artifact=artifact,
    )
    k = strat.num_strata
    sizes = strat.stratum_sizes()
    top_set = set(strat.order.tolist())
    timings["stratify_s"] = time.perf_counter() - t0
    # the opt-in low-precision sweep also hands its collected weights to the
    # samplers (HT stays exact: q is computed from the weights actually
    # sampled with); the fp32 default recomputes them in f64 so estimates
    # stay bit-identical to the two-pass schedule
    lowp = (
        strat.sweep is not None and strat.sweep.precision != "fp32"
        and strat.order_weights is not None
    )

    # ---- full-space sampling distribution pieces for D_0 rejection -------
    # Walk setup (row sums + chain total weight) consumes the statistics the
    # fused sweep emitted alongside the histogram — or, on a warm index,
    # hydrates them from the artifact — so no second pass over the cross
    # product is ever launched here.  Only the two-pass baseline
    # (use_sweep=False) and low-precision sweeps (which withhold their sums,
    # see stratify.SweepInfo) fall back to the standalone recomputation.
    t0 = time.perf_counter()
    fused = strat.sweep is not None and strat.sweep.row_sums is not None
    if fused:
        row_sums = strat.sweep.row_sums
        total_weight = strat.sweep.total_weight
    else:
        row_sums = edge_row_sums(embeddings, exp, floor)
        total_weight = chain_total_weight(embeddings, exp, floor)
    timings["walk_setup_s"] = time.perf_counter() - t0
    tup_top = flat_to_tuples(strat.order, sizes_spec)
    # one pass over the edges gives both the top-set chain weights and the
    # full-space walk probabilities p(t) = (1/N1) prod_j w_j / r_j
    top_w = np.ones(len(tup_top), np.float64)
    p = np.full(len(tup_top), 1.0 / sizes_spec[0], np.float64)
    for j in range(len(embeddings) - 1):
        w_j = aligned_pair_weights(
            embeddings[j], embeddings[j + 1], tup_top[:, j], tup_top[:, j + 1],
            exp, floor,
        )
        top_w *= w_j
        p *= w_j / row_sums[j][tup_top[:, j]]
    p_top = float(p.sum())

    per_tup = [None] + [
        flat_to_tuples(strat.stratum_indices(i), sizes_spec)
        for i in range(1, k + 1)
    ]
    if lowp:
        per_w = [None] + [strat.stratum_weights(i) for i in range(1, k + 1)]
    else:
        per_w = [None] + [
            chain_tuple_weights(embeddings, t, exp, floor) for t in per_tup[1:]
        ]
    weight_sums = np.zeros(k + 1, np.float64)
    weight_sums[0] = max(total_weight - float(top_w.sum()), 0.0)
    for i in range(1, k + 1):
        weight_sums[i] = float(per_w[i].sum())
    timings["similarity_s"] = time.perf_counter() - t0

    def sample_stratum(i: int, n: int) -> StratumDraw:
        if i == 0:
            tup, pw = _walk_rejection_sample(
                embeddings, sizes_spec, top_set, n, cfg, rng
            )
            q = pw / max(1.0 - p_top, 1e-12)  # exact prob within D_0
        else:
            pos, q = flat_sample(per_w[i], n, rng, cfg.defensive_mix)
            tup = per_tup[i][pos]
        return StratumDraw(tup=tup, q=q, size=int(sizes[i]))

    meta = {"path": "sweep" if strat.sweep is not None else "two-pass",
            "walk_setup": "fused" if fused else "recompute"}
    if strat.sweep is not None:
        meta.update(
            kernel=strat.sweep.kernel, precision=strat.sweep.precision,
            **strat.sweep.stats,
        )
    if artifact is not None:
        meta["path"] = "index"
        meta["index_hit"] = bool(index_hit)
        meta["index_version"] = artifact.version
        meta["delta_blocks"] = int(artifact.stats.get("delta_blocks", 0))
        if index_build_ms is not None:
            meta["index_build_ms"] = round(index_build_ms, 2)
    space = StratifiedSpace(
        sizes=sizes,
        weight_sums=weight_sums,
        sample_stratum=sample_stratum,
        stratum_tuples=lambda i: per_tup[i],
        meta=meta,
    )
    return space, {"p_top": p_top, "use_kernel": use_kernel}


def run_bas_streaming(
    query: Query,
    cfg: Optional[BASConfig] = None,
    seed: int = 0,
    n_bins: int = 4096,
    use_kernel: Optional[bool] = None,
    use_sweep: Optional[bool] = None,
    precision: Optional[str] = None,
    artifact=None,
    index_store=None,
) -> QueryResult:
    """k-way streaming BAS.  Same estimator/CI machinery as the dense path
    (all aggregates); the cross product is never materialised.

    ``artifact`` (:class:`repro.core.index.IndexArtifact`) stratifies from
    a persisted sweep instead of recomputing it — bit-identical at fp32.
    ``index_store`` (:class:`repro.core.index.IndexStore`) resolves the
    artifact by content key, building (once, shared across concurrent
    queries) on miss; ignored when ``artifact`` is given.  Either way the
    index accounting lands in ``QueryResult.detail["stratify"]``
    (``index_hit``, ``index_build_ms``, ``delta_blocks``,
    ``index_version``)."""
    cfg = cfg or BASConfig()
    rng = np.random.default_rng(seed)
    t_start = time.perf_counter()
    timings: dict = {}

    query.oracle.set_budget(query.budget)
    query.oracle.bind_sizes(query.spec.sizes)
    if query.budget >= query.spec.n_tuples:
        return run_exact(query)

    space, extra = build_streaming_space(
        query, cfg, rng, timings, n_bins=n_bins, use_kernel=use_kernel,
        use_sweep=use_sweep, precision=precision, artifact=artifact,
        index_store=index_store,
    )
    return run_stratified_pipeline(
        query, cfg, rng, space, {"mode": "bas_streaming", **extra},
        timings, t_start,
    )
