"""BAS without materialising the cross product (paper §5.3, the
"cross product cannot fit into memory" regime).

Differences from the dense path (``bas.run_bas``):

* stratification uses the histogram threshold (``stratify_streaming``, backed
  by the fused ``sim_hist`` Pallas kernel) — O(bins) memory, two streaming
  passes;
* the minimum sampling regime D_0 is sampled by **walk + rejection**: WWJ
  walk proposals from the full-space distribution p(i,j) = (1/N1) w_ij / r_i
  are rejected if they fall in the blocking regime; accepted tuples have
  exact probability p(s) / (1 - P(top)), where P(top) = sum of full-space
  probabilities over the collected top set (computable from the streamed row
  sums) — so Horvitz-Thompson stays exact;
* per-stratum weights are recomputed by gathering only the stratum's pairs.

Memory: O(N1 + N2 + alpha*b + b) — never O(N1*N2).
"""
from __future__ import annotations

import time
from typing import Optional

import numpy as np

from . import allocate as alloc_mod
from .bootstrap import bootstrap_t_ci
from .estimators import BlockedRegime, StratumSample, combined_count, combined_sum
from .similarity import flat_to_tuples, pair_weights
from .stratify import stratify_streaming
from .types import Agg, BASConfig, Query, QueryResult
from .wander import flat_sample


def _pairwise_w(e1, e2, i, j, cfg):
    """Elementwise weights for aligned index vectors (no cross block)."""
    sims = np.einsum("nd,nd->n", e1[i].astype(np.float64), e2[j].astype(np.float64))
    w = np.clip(sims, 0.0, 1.0)
    w = np.maximum(w, cfg.weight_floor)
    if cfg.weight_exponent != 1.0:
        w = w**cfg.weight_exponent
    return w


def _walk_rejection_sample(e1, e2, row_sums, top_set, n, cfg, rng, max_rounds=50):
    """Sample n tuples from D_0 with exact probabilities (walk + rejection)."""
    n1, n2 = e1.shape[0], e2.shape[0]
    total_rows = row_sums.sum()
    out_idx = np.empty(n, np.int64)
    out_p = np.empty(n, np.float64)
    got = 0
    for _ in range(max_rounds):
        need = n - got
        if need <= 0:
            break
        m = max(int(need * 1.3) + 16, 32)
        i = rng.integers(0, n1, size=m)
        # categorical over row i's weights, streamed per unique row block
        w_rows = pair_weights(e1[i], e2, cfg.weight_exponent, cfg.weight_floor)
        cdf = np.cumsum(w_rows, axis=1)
        tot = cdf[:, -1]
        u = rng.random(m) * tot
        j = np.minimum((cdf < u[:, None]).sum(axis=1), n2 - 1)
        flat = i.astype(np.int64) * n2 + j
        p = (1.0 / n1) * w_rows[np.arange(m), j] / tot
        keep = np.array([f not in top_set for f in flat])
        k = int(keep.sum())
        take = min(k, need)
        out_idx[got : got + take] = flat[keep][:take]
        out_p[got : got + take] = p[keep][:take]
        got += take
    if got < n:
        out_idx, out_p = out_idx[:got], out_p[:got]
    return out_idx, out_p


def run_bas_streaming(
    query: Query,
    cfg: Optional[BASConfig] = None,
    seed: int = 0,
    n_bins: int = 4096,
    use_kernel: bool = True,
) -> QueryResult:
    """Two-table streaming BAS.  Same estimator/CI machinery as the dense
    path; supports COUNT/SUM/AVG."""
    assert query.spec.k == 2, "streaming path covers two-table joins"
    cfg = cfg or BASConfig()
    rng = np.random.default_rng(seed)
    query.oracle.set_budget(query.budget)
    e1 = np.asarray(query.spec.embeddings[0], np.float32)
    e2 = np.asarray(query.spec.embeddings[1], np.float32)
    n1, n2 = e1.shape[0], e2.shape[0]
    t0 = time.perf_counter()

    b = query.budget
    b1 = max(int(round(cfg.pilot_fraction * b)), 8)

    strat = stratify_streaming(e1, e2, cfg.alpha, b, cfg, n_bins=n_bins,
                               use_kernel=use_kernel)
    k = strat.num_strata
    sizes = strat.stratum_sizes()
    top_set = set(strat.order.tolist())

    # full-space sampling distribution pieces for D_0 rejection sampling
    row_sums = np.zeros(n1, np.float64)
    B = 4096
    for s in range(0, n1, B):
        row_sums[s : s + B] = pair_weights(
            e1[s : s + B], e2, cfg.weight_exponent, cfg.weight_floor
        ).sum(axis=1)
    top_i = strat.order // n2
    top_j = strat.order % n2
    top_w = _pairwise_w(e1, e2, top_i, top_j, cfg)
    p_top = float(((1.0 / n1) * top_w / row_sums[top_i]).sum())

    per_idx = [None] + [strat.stratum_indices(i) for i in range(1, k + 1)]
    per_w = [None] + [
        _pairwise_w(e1, e2, ix // n2, ix % n2, cfg) for ix in per_idx[1:]
    ]
    weight_sums = np.zeros(k + 1, np.float64)
    weight_sums[0] = max(row_sums.sum() - top_w.sum(), 0.0)
    for i in range(1, k + 1):
        weight_sums[i] = per_w[i].sum()

    def sample_stratum(i, n):
        if i == 0:
            idx, p = _walk_rejection_sample(e1, e2, row_sums, top_set, n, cfg, rng)
            q = p / max(1.0 - p_top, 1e-12)   # exact prob within D_0
        else:
            pos, q = flat_sample(per_w[i], n, rng, cfg.defensive_mix)
            idx = per_idx[i][pos]
        tup = flat_to_tuples(idx, (n1, n2))
        o = query.oracle.label(tup)
        g = query.attr()(tup)
        return StratumSample(o=o, g=g, q=q, size=int(sizes[i]))

    # ---- pilot ---------------------------------------------------------
    shares = weight_sums / max(weight_sums.sum(), 1e-300)
    n_pilot = np.maximum((shares * b1).astype(np.int64), 2)
    while n_pilot.sum() > b1 and n_pilot.max() > 2:
        n_pilot[np.argmax(n_pilot)] -= 1
    samples = [None] * (k + 1)
    for i in range(k + 1):
        if sizes[i] > 0:
            samples[i] = sample_stratum(i, int(n_pilot[i]))
    sigma2 = np.zeros(k + 1)
    for i, s in enumerate(samples):
        if s is not None and s.n > 1:
            t = s.sum_terms() if query.agg is not Agg.COUNT else s.count_terms()
            sigma2[i] = float(np.var(t, ddof=1))

    # ---- allocate + execute --------------------------------------------
    b2_eff = b - query.oracle.calls
    allocation = alloc_mod.argmin_beta(sigma2, weight_sums, sizes, b2_eff,
                                       cfg.exact_beta_max_k)
    beta = set(int(x) for x in allocation.beta)
    blocked_o, blocked_g = [], []
    for i in sorted(beta):
        tup = flat_to_tuples(per_idx[i], (n1, n2))
        blocked_o.append(query.oracle.label(tup))
        blocked_g.append(query.attr()(tup))
    blocked = BlockedRegime(
        o=np.concatenate(blocked_o) if blocked_o else np.zeros(0),
        g=np.concatenate(blocked_g) if blocked_g else np.zeros(0),
    )
    sampled_ids = [i for i in range(k + 1) if i not in beta and sizes[i] > 0]
    remaining = b - query.oracle.calls
    if remaining > 2 * max(len(sampled_ids), 1):
        w_s = np.array([weight_sums[i] for i in sampled_ids])
        share = w_s / max(w_s.sum(), 1e-300)
        n_main = np.maximum((share * remaining).astype(np.int64), 1)
        while n_main.sum() > remaining:
            n_main[np.argmax(n_main)] -= 1
        for j, i in enumerate(sampled_ids):
            if n_main[j] > 0:
                new = sample_stratum(i, int(n_main[j]))
                samples[i] = new if samples[i] is None else samples[i].merge(new)

    live = [samples[i] for i in range(k + 1)
            if i not in beta and samples[i] is not None]
    est, ci = bootstrap_t_ci(live, blocked, query.agg, query.confidence,
                             cfg.n_bootstrap, rng)
    return QueryResult(
        estimate=float(est), ci=ci, oracle_calls=query.oracle.calls,
        detail={"mode": "bas_streaming", "beta": sorted(beta),
                "num_strata": k, "p_top": p_top,
                "total_s": time.perf_counter() - t0},
    )
