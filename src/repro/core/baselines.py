"""Baselines the paper evaluates against (§7.1).

* UNIFORM       — uniform sampling over the cross product, CLT CI.
* BLOCKING      — Alg. 2: threshold-filtered candidate set, sample if needed.
                  The threshold is calibrated on a validation split to include
                  90% of validation positives (the paper's Ditto-proxy setup).
* WWJ           — Alg. 3: weighted wander join (importance sampling), CLT CI.
* ABAE          — stratified sampling with Neyman-style two-stage allocation
                  treating the join condition as an ML predicate [38].
* BLAZEIT       — uniform sampling + control variates with the similarity
                  score as the (free) proxy variable [35].
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from .oracle import OracleBatch
from .similarity import chain_weights, flat_to_tuples
from .types import Agg, BASConfig, ConfidenceInterval, Query, QueryResult
from .wander import clt_ci, flat_sample, ht_terms, walk_sample


def _finalize(query: Query, total_mean: float, ci: ConfidenceInterval, n_space: int,
              detail: dict) -> QueryResult:
    return QueryResult(
        estimate=total_mean, ci=ci, oracle_calls=query.oracle.calls,
        detail={**detail, "oracle": query.oracle.stats()},
    )


def run_uniform(query: Query, seed: int = 0) -> QueryResult:
    rng = np.random.default_rng(seed)
    query.oracle.set_budget(query.budget)
    query.oracle.bind_sizes(query.spec.sizes)
    n_space = query.spec.n_tuples
    n = min(query.budget, n_space)
    flat = rng.integers(0, n_space, size=n)
    tup = flat_to_tuples(flat, query.spec.sizes)
    o = query.oracle.label(tup)
    g = query.attr()(tup)
    if query.agg is Agg.COUNT:
        x = o * n_space
    elif query.agg is Agg.SUM:
        x = g * o * n_space
    elif query.agg is Agg.AVG:
        s, s_ci = clt_ci(g * o, query.confidence)
        c, _ = clt_ci(o, query.confidence)
        if c <= 0:
            return _finalize(query, 0.0, ConfidenceInterval(-np.inf, np.inf, query.confidence), n_space, {"mode": "uniform"})
        est = s / c
        # delta-method CI for the ratio
        sv = np.var(g * o, ddof=1) / n
        cv = np.var(o, ddof=1) / n
        cov = np.cov(g * o, o, ddof=1)[0, 1] / n
        var = est**2 * (sv / s**2 + cv / c**2 - 2 * cov / (s * c))
        from scipy import stats

        z = stats.norm.ppf(0.5 + query.confidence / 2)
        half = z * np.sqrt(max(var, 0.0))
        return _finalize(
            query, float(est),
            ConfidenceInterval(float(est - half), float(est + half), query.confidence),
            n_space, {"mode": "uniform"},
        )
    else:
        m = o > 0
        vals = g[m]
        est = float(vals.max()) if (query.agg is Agg.MAX and m.any()) else (
            float(vals.min()) if (query.agg is Agg.MIN and m.any()) else float("nan")
        )
        return _finalize(query, est, ConfidenceInterval(est, est, query.confidence),
                         n_space, {"mode": "uniform"})
    mu, ci = clt_ci(x, query.confidence)
    return _finalize(query, mu, ci, n_space, {"mode": "uniform"})


def run_wwj(query: Query, cfg: Optional[BASConfig] = None, seed: int = 0,
            weights: Optional[np.ndarray] = None) -> QueryResult:
    """Standalone Weighted Wander Join (Alg. 3).

    With ``weights`` (flat scores over the cross product, e.g. the Syn
    datasets) WWJ samples the statistically equivalent flat importance
    distribution instead of per-step walks."""
    cfg = cfg or BASConfig()
    rng = np.random.default_rng(seed)
    query.oracle.set_budget(query.budget)
    query.oracle.bind_sizes(query.spec.sizes)
    n = query.budget
    if weights is not None:
        pos, p = flat_sample(np.asarray(weights, np.float64), n, rng)
        from .wander import WalkSample

        ws = WalkSample(idx=flat_to_tuples(pos, query.spec.sizes), prob=p)
    else:
        ws = walk_sample(
            [np.asarray(e) for e in query.spec.embeddings],
            n, rng, cfg.weight_exponent, cfg.weight_floor,
        )
    o = query.oracle.label(ws.idx)
    g = query.attr()(ws.idx)
    if query.agg is Agg.COUNT:
        x = ht_terms(o, ws.prob)
    elif query.agg is Agg.SUM:
        x = ht_terms(g * o, ws.prob)
    elif query.agg is Agg.AVG:
        xs = ht_terms(g * o, ws.prob)
        xc = ht_terms(o, ws.prob)
        s, c = xs.mean(), xc.mean()
        if c <= 0:
            return _finalize(query, 0.0, ConfidenceInterval(-np.inf, np.inf, query.confidence), 0, {"mode": "wwj"})
        est = s / c
        sv, cv = np.var(xs, ddof=1) / n, np.var(xc, ddof=1) / n
        cov = np.cov(xs, xc, ddof=1)[0, 1] / n
        var = est**2 * (sv / s**2 + cv / c**2 - 2 * cov / (s * c))
        from scipy import stats

        z = stats.norm.ppf(0.5 + query.confidence / 2)
        half = z * np.sqrt(max(var, 0.0))
        return _finalize(query, float(est),
                         ConfidenceInterval(float(est - half), float(est + half), query.confidence),
                         0, {"mode": "wwj"})
    else:
        m = o > 0
        vals = g[m]
        est = float(vals.max()) if (query.agg is Agg.MAX and m.any()) else (
            float(vals.min()) if (query.agg is Agg.MIN and m.any()) else float("nan"))
        return _finalize(query, est, ConfidenceInterval(est, est, query.confidence), 0, {"mode": "wwj"})
    mu, ci = clt_ci(x, query.confidence)
    return _finalize(query, mu, ci, 0, {"mode": "wwj"})


def calibrate_threshold(
    val_weights: np.ndarray, val_labels: np.ndarray, target_recall: float = 0.9
) -> float:
    """Blocking threshold including ``target_recall`` of validation positives."""
    pos = val_weights[val_labels > 0]
    if len(pos) == 0:
        return 0.0
    return float(np.quantile(pos, 1.0 - target_recall))


def run_blocking(
    query: Query,
    threshold: float,
    cfg: Optional[BASConfig] = None,
    seed: int = 0,
    weights: Optional[np.ndarray] = None,
) -> QueryResult:
    """Alg. 2: embedding-based blocking with a predefined Oracle budget.

    Biased by construction (false negatives below tau are never corrected) —
    the failure mode Figures 2/5 demonstrate.
    """
    cfg = cfg or BASConfig()
    rng = np.random.default_rng(seed)
    query.oracle.set_budget(query.budget)
    query.oracle.bind_sizes(query.spec.sizes)
    if weights is None:
        weights = chain_weights(query.spec.embeddings, cfg.weight_exponent, cfg.weight_floor)
    cand = np.nonzero(weights >= threshold)[0]
    n_cand = len(cand)
    from scipy import stats

    z = stats.norm.ppf(0.5 + query.confidence / 2)
    if n_cand <= query.budget:
        tup = flat_to_tuples(cand, query.spec.sizes)
        o = query.oracle.label(tup)
        g = query.attr()(tup)
        if query.agg is Agg.COUNT:
            est = float(o.sum())
        elif query.agg is Agg.SUM:
            est = float((g * o).sum())
        else:
            est = float((g * o).sum() / max(o.sum(), 1e-12))
        return _finalize(query, est, ConfidenceInterval(est, est, query.confidence),
                         n_cand, {"mode": "blocking", "n_candidates": n_cand})
    sel = rng.choice(n_cand, size=query.budget, replace=False)
    tup = flat_to_tuples(cand[sel], query.spec.sizes)
    o = query.oracle.label(tup)
    g = query.attr()(tup)
    n = query.budget
    if query.agg is Agg.COUNT:
        x = o * n_cand
    elif query.agg is Agg.SUM:
        x = g * o * n_cand
    else:
        s, c = float((g * o).mean()), float(o.mean())
        est = s / max(c, 1e-12)
        var = np.var(g * o - est * o, ddof=1) / n / max(c, 1e-12) ** 2
        half = z * np.sqrt(max(var, 0.0))
        return _finalize(query, est, ConfidenceInterval(est - half, est + half, query.confidence),
                         n_cand, {"mode": "blocking", "n_candidates": n_cand})
    mu, ci = clt_ci(x, query.confidence)
    return _finalize(query, mu, ci, n_cand, {"mode": "blocking", "n_candidates": n_cand})


def run_abae(query: Query, cfg: Optional[BASConfig] = None, seed: int = 0,
             weights: Optional[np.ndarray] = None) -> QueryResult:
    """ABAE-style stratified sampling [38]: stratify the *whole* space by proxy
    score, pilot for per-stratum std, Neyman allocation n_i ∝ |D_i| sigma_i,
    uniform sampling within strata (no importance weighting, no blocking)."""
    cfg = cfg or BASConfig()
    rng = np.random.default_rng(seed)
    query.oracle.set_budget(query.budget)
    query.oracle.bind_sizes(query.spec.sizes)
    if weights is None:
        weights = chain_weights(query.spec.embeddings, cfg.weight_exponent, cfg.weight_floor)
    n_space = query.spec.n_tuples
    k = 5
    qs = np.quantile(weights, np.linspace(0, 1, k + 1)[1:-1])
    stratum_of = np.searchsorted(qs, weights)
    b1 = max(int(0.3 * query.budget), 2 * k)
    b2 = query.budget - b1
    samples, sizes = [], []
    sig = np.zeros(k)
    per_idx = [np.nonzero(stratum_of == i)[0] for i in range(k)]
    pilot_per = max(b1 // k, 2)
    # pilot: one coalesced Oracle batch across all strata
    pilot_batch = OracleBatch(query.oracle)
    pilot_reqs: list = []
    for i in range(k):
        if len(per_idx[i]) == 0:
            pilot_reqs.append(None)
            continue
        sel = rng.integers(0, len(per_idx[i]), size=min(pilot_per, b1))
        tup = flat_to_tuples(per_idx[i][sel], query.spec.sizes)
        pilot_reqs.append((tup, pilot_batch.submit(tup)))
    pilot_batch.flush_async().result()   # await: service coalesces pilots
    pilot_data = []
    for i in range(k):
        if pilot_reqs[i] is None:
            pilot_data.append((np.zeros(0), np.zeros(0)))
            continue
        tup, h = pilot_reqs[i]
        o = h.labels
        g = query.attr()(tup)
        v = g * o if query.agg in (Agg.SUM, Agg.AVG) else o
        sig[i] = np.std(v, ddof=1) if len(v) > 1 else 0.0
        pilot_data.append((o, g))
    sizes = np.array([len(ix) for ix in per_idx], np.float64)
    alloc = sizes * sig
    alloc = alloc / max(alloc.sum(), 1e-300) * b2
    # main: one coalesced Oracle batch across all strata
    main_batch = OracleBatch(query.oracle)
    main_reqs: list = [None] * k
    for i in range(k):
        if len(per_idx[i]) == 0:
            continue
        n_i = int(alloc[i])
        if n_i > 0:
            sel = rng.integers(0, len(per_idx[i]), size=n_i)
            tup = flat_to_tuples(per_idx[i][sel], query.spec.sizes)
            main_reqs[i] = (tup, main_batch.submit(tup))
    main_batch.flush_async().result()
    est, var = 0.0, 0.0
    est_c, var_c = 0.0, 0.0
    for i in range(k):
        if len(per_idx[i]) == 0:
            continue
        o, g = pilot_data[i]
        if main_reqs[i] is not None:
            tup, h = main_reqs[i]
            o = np.concatenate([o, h.labels])
            g = np.concatenate([g, query.attr()(tup)])
        if len(o) == 0:
            continue
        v = g * o if query.agg in (Agg.SUM, Agg.AVG) else o
        est += sizes[i] * v.mean()
        var += sizes[i] ** 2 * (np.var(v, ddof=1) / len(v) if len(v) > 1 else 0.0)
        est_c += sizes[i] * o.mean()
        var_c += sizes[i] ** 2 * (np.var(o, ddof=1) / len(o) if len(o) > 1 else 0.0)
    from scipy import stats

    z = stats.norm.ppf(0.5 + query.confidence / 2)
    if query.agg is Agg.AVG:
        if est_c <= 0:
            return _finalize(query, 0.0, ConfidenceInterval(-np.inf, np.inf, query.confidence), n_space, {"mode": "abae"})
        r = est / est_c
        var_r = r**2 * (var / max(est**2, 1e-300) + var_c / max(est_c**2, 1e-300))
        half = z * np.sqrt(max(var_r, 0.0))
        return _finalize(query, float(r), ConfidenceInterval(r - half, r + half, query.confidence), n_space, {"mode": "abae"})
    half = z * np.sqrt(max(var, 0.0))
    return _finalize(query, float(est), ConfidenceInterval(est - half, est + half, query.confidence),
                     n_space, {"mode": "abae"})


def run_blazeit(query: Query, cfg: Optional[BASConfig] = None, seed: int = 0,
                weights: Optional[np.ndarray] = None) -> QueryResult:
    """BlazeIt-style control variates [35]: uniform sample, similarity score as
    control variate with known population mean."""
    cfg = cfg or BASConfig()
    rng = np.random.default_rng(seed)
    query.oracle.set_budget(query.budget)
    query.oracle.bind_sizes(query.spec.sizes)
    if weights is None:
        weights = chain_weights(query.spec.embeddings, cfg.weight_exponent, cfg.weight_floor)
    n_space = query.spec.n_tuples
    n = min(query.budget, n_space)
    flat = rng.integers(0, n_space, size=n)
    tup = flat_to_tuples(flat, query.spec.sizes)
    o = query.oracle.label(tup)
    g = query.attr()(tup)
    w = weights[flat]
    w_mean = float(weights.mean())
    y = (g * o if query.agg in (Agg.SUM, Agg.AVG) else o) * 1.0
    if np.var(w) > 0:
        c = float(np.cov(y, w, ddof=1)[0, 1] / np.var(w, ddof=1))
    else:
        c = 0.0
    adj = y - c * (w - w_mean)
    if query.agg is Agg.AVG:
        oc = o - (float(np.cov(o, w, ddof=1)[0, 1] / np.var(w, ddof=1)) if np.var(w) > 0 else 0.0) * (w - w_mean)
        s, cc = adj.mean(), oc.mean()
        if cc <= 0:
            return _finalize(query, 0.0, ConfidenceInterval(-np.inf, np.inf, query.confidence), n_space, {"mode": "blazeit"})
        est = s / cc
        var = est**2 * (np.var(adj, ddof=1) / n / s**2 + np.var(oc, ddof=1) / n / cc**2)
        from scipy import stats

        z = stats.norm.ppf(0.5 + query.confidence / 2)
        half = z * np.sqrt(max(var, 0.0))
        return _finalize(query, float(est), ConfidenceInterval(est - half, est + half, query.confidence), n_space, {"mode": "blazeit"})
    mu, ci = clt_ci(adj * n_space, query.confidence)
    return _finalize(query, mu, ci, n_space, {"mode": "blazeit"})
