"""Blocking-augmented Sampling — the paper's main contribution (§5.2-5.3, Alg. 4).

Pipeline (dense path; the streaming path swaps stage 1 for the histogram
stratifier, see ``stratify.py``):

1. *Stratify*: top alpha*b pairs by weight -> K equal strata D_1..D_K
   (max blocking regime); everything else is D_0 (min sampling regime).
2. *Pilot* (budget b1): WWJ-sample every stratum ∝ weight, estimate
   per-stratum sampling variance of the agg-linearised HT terms.
3. *Allocate*: beta* = argmin estimated MSE (allocate.py).
4. *Execute* (budget b2): Oracle everything in blocked strata; WWJ-sample the
   rest with BudgetAssign sizes; merge with pilot samples (same within-stratum
   distribution -> poolable); optional top-up rounds spend budget freed by the
   Oracle cache.
5. *Estimate + CI*: combined estimators (estimators.py) and bootstrap-t
   (bootstrap.py).

Stages 2-5 are shared with the streaming path: :func:`run_stratified_pipeline`
takes a :class:`StratifiedSpace` (per-stratum sizes, weight masses and two
callbacks — sample a stratum, enumerate a blocked stratum's tuples) and runs
pilot / allocation / execution / estimation identically for both regimes.
``run_bas`` here wires the dense closures (materialised flat weights);
``bas_streaming.run_bas_streaming`` wires the walk+rejection / gathered-pair
closures.  Dispatch between the two is memory-aware: ``dispatch.run_auto``
routes to this dense path only when the (N1*...*Nk,) float64 flat weight
array fits under ``BASConfig.max_dense_weight_bytes``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import numpy as np

from . import allocate as alloc_mod
from .bootstrap import bootstrap_t_ci
from .estimators import (
    BlockedRegime,
    StratumSample,
    combined_cdf_median,
    combined_count,
    combined_extreme,
    combined_sum,
)
from .oracle import OracleBatch
from .similarity import chain_weights, flat_to_tuples
from .stratify import Stratification, stratify_dense
from .types import Agg, BASConfig, ConfidenceInterval, Query, QueryResult
from .wander import flat_sample


@dataclasses.dataclass
class StratumDraw:
    """A within-stratum sample *before* labelling: the pipeline coalesces all
    draws of a stage into one :class:`~repro.core.oracle.OracleBatch` flush,
    so sampling closures never talk to the Oracle themselves."""

    tup: np.ndarray    # (n, k) tuple indices
    q: np.ndarray      # (n,) exact within-stratum sampling probabilities
    size: int          # |D_i|


def _draw_stratum(
    weights: np.ndarray,
    flat_idx: np.ndarray,
    n: int,
    query: Query,
    rng: np.random.Generator,
    defensive_mix: float = 0.0,
) -> StratumDraw:
    """WWJ within-stratum sampling: prob ∝ weight (plus a defensive uniform
    component), HT prob = exact normalised q."""
    w = weights[flat_idx]
    pos, q = flat_sample(w, n, rng, defensive_mix)
    chosen = flat_idx[pos]
    tup = flat_to_tuples(chosen, query.spec.sizes)
    return StratumDraw(tup=tup, q=q, size=len(flat_idx))


def _label_draws(
    query: Query, draws: list
) -> list:
    """Materialise StratumSamples from draws with ONE coalesced Oracle batch
    (dedup across strata/stages, single ledger charge, single backend call).

    Submit-then-await: the flush is submitted asynchronously and the cheap
    g(.) evaluation overlaps the labelling; with an attached OracleService
    the await is where concurrent queries' pilot/main rounds coalesce into
    shared super-batches."""
    batch = OracleBatch(query.oracle)
    handles = [None if d is None else batch.submit(d.tup) for d in draws]
    fut = batch.flush_async()
    g = query.attr()
    gs = [None if d is None else g(d.tup) for d in draws]
    fut.result()
    return [
        None if d is None else StratumSample(
            o=h.labels, g=gv, q=d.q, size=d.size
        )
        for d, h, gv in zip(draws, handles, gs)
    ]


def _linearised_variance(s: StratumSample, agg: Agg, ratio: float, count_hat: float) -> float:
    """Pilot variance of the agg-appropriate linearised HT terms."""
    if agg is Agg.COUNT:
        t = s.count_terms()
    elif agg in (Agg.SUM, Agg.MEDIAN, Agg.MIN, Agg.MAX):
        t = s.sum_terms()
    else:  # AVG: influence function (s_t - R*c_t) / C
        c = max(count_hat, 1e-12)
        t = (s.sum_terms() - ratio * s.count_terms()) / c
    return float(np.var(t, ddof=1)) if len(t) > 1 else 0.0


def _stratum_flat_indices(strat: Stratification, weights: np.ndarray):
    """Returns list of per-stratum flat index arrays for strata 0..K.
    D_0 is represented lazily as a boolean complement mask for memory."""
    per = [None]  # D_0 handled via mask
    for i in range(1, strat.num_strata + 1):
        per.append(strat.stratum_indices(i))
    return per


def run_exact(query: Query) -> QueryResult:
    """Label everything (only valid when budget >= |D|)."""
    query.oracle.bind_sizes(query.spec.sizes)
    n = query.spec.n_tuples
    tup = flat_to_tuples(np.arange(n), query.spec.sizes)
    o = query.oracle.label(tup)
    g = query.attr()(tup)
    blocked = BlockedRegime(o=o, g=g)
    if query.agg is Agg.COUNT:
        est = blocked.count
    elif query.agg is Agg.SUM:
        est = blocked.sum
    elif query.agg is Agg.AVG:
        est = blocked.sum / max(blocked.count, 1e-12)
    elif query.agg in (Agg.MIN, Agg.MAX):
        est = combined_extreme([], blocked, query.agg.value)
    else:
        est = combined_cdf_median([], blocked)
    return QueryResult(
        estimate=float(est),
        ci=ConfidenceInterval(float(est), float(est), query.confidence),
        oracle_calls=query.oracle.calls,
        detail={"mode": "exact", "oracle": query.oracle.stats()},
    )


# ----------------------------------------------------------------------------
# Shared stages 2-5: pilot -> allocate -> execute -> estimate/CI.
# ----------------------------------------------------------------------------

@dataclasses.dataclass
class StratifiedSpace:
    """Everything the estimator assembly needs to know about a stratified
    join space, independent of whether the cross product is materialised.

    ``sample_stratum(i, n)`` draws n tuples from stratum i with exact
    within-stratum probabilities and returns a :class:`StratumDraw` — no
    labels: the pipeline batches all labelling through the Oracle's batch
    API.  ``stratum_tuples(i)`` enumerates stratum i's (n_i, k) tuple indices
    for blocking (only ever called for i >= 1 — D_0 cannot be blocked).
    ``meta`` records how the space was stratified (e.g. the single-sweep
    pass/rescan stats) and is surfaced in ``QueryResult.detail``."""

    sizes: np.ndarray          # (K+1,) |D_0..D_K|
    weight_sums: np.ndarray    # (K+1,) total sampling weight per stratum
    sample_stratum: Callable[[int, int], StratumDraw]
    stratum_tuples: Callable[[int], np.ndarray]
    meta: dict = dataclasses.field(default_factory=dict)


def run_stratified_pipeline(
    query: Query,
    cfg: BASConfig,
    rng: np.random.Generator,
    space: StratifiedSpace,
    detail: dict,
    timings: dict,
    t_start: float,
) -> QueryResult:
    """Alg. 4 lines 6-17 on an abstract stratified space (shared by the dense
    and streaming BAS paths)."""
    sizes, weight_sums = space.sizes, space.weight_sums
    k = len(sizes) - 1
    b = query.budget
    b1 = max(int(round(cfg.pilot_fraction * b)), 8)

    # ---- stage 1: pilot ---------------------------------------------------
    t0 = time.perf_counter()
    shares = weight_sums / max(weight_sums.sum(), 1e-300)
    n_pilot = np.maximum((shares * b1).astype(np.int64), 2)
    while n_pilot.sum() > b1 and n_pilot.max() > 2:
        n_pilot[np.argmax(n_pilot)] -= 1

    pilot_draws: list[Optional[StratumDraw]] = [None] * (k + 1)
    for i in range(k + 1):
        if sizes[i] > 0:
            pilot_draws[i] = space.sample_stratum(i, int(n_pilot[i]))
    samples: list[Optional[StratumSample]] = _label_draws(query, pilot_draws)

    live = [s for s in samples if s is not None]
    c_hat, _ = combined_count(live, BlockedRegime(np.zeros(0), np.zeros(0)))
    s_hat, _ = combined_sum(live, BlockedRegime(np.zeros(0), np.zeros(0)))
    ratio = s_hat / c_hat if c_hat > 0 else 0.0
    sigma2 = np.zeros(k + 1, np.float64)
    for i in range(k + 1):
        if samples[i] is not None:
            sigma2[i] = _linearised_variance(samples[i], query.agg, ratio, c_hat)
    timings["pilot_s"] = time.perf_counter() - t0

    # ---- allocation -------------------------------------------------------
    t0 = time.perf_counter()
    b2_eff = query.budget - query.oracle.calls
    if query.agg in (Agg.MIN, Agg.MAX):
        allocation = _allocate_extreme(samples, sizes, weight_sums, b2_eff, query.agg)
    else:
        allocation = alloc_mod.argmin_beta(
            sigma2, weight_sums, sizes, b2_eff, cfg.exact_beta_max_k
        )
    beta = set(int(i) for i in allocation.beta)
    timings["allocate_s"] = time.perf_counter() - t0

    # ---- stage 2: blocking + sampling -------------------------------------
    t0 = time.perf_counter()
    # submit-then-await: the blocking-regime labelling runs on the oracle
    # backend (or service) while g(.) is evaluated for the same tuples here
    block_batch = OracleBatch(query.oracle)
    beta_tuples = [(i, space.stratum_tuples(i)) for i in sorted(beta)]
    beta_handles = [block_batch.submit(tup) for _, tup in beta_tuples]
    block_fut = block_batch.flush_async()
    g_fn = query.attr()
    blocked_g = [g_fn(tup) for _, tup in beta_tuples]
    block_fut.result()
    blocked_o = [h.labels for h in beta_handles]
    blocked = BlockedRegime(
        o=np.concatenate(blocked_o) if blocked_o else np.zeros(0),
        g=np.concatenate(blocked_g) if blocked_g else np.zeros(0),
    )

    sampled_ids = [i for i in range(k + 1) if i not in beta and sizes[i] > 0]
    rounds = 0
    while rounds < 4:
        remaining = query.budget - query.oracle.calls
        if remaining < 2 * max(len(sampled_ids), 1):
            break
        w_s = np.array([weight_sums[i] for i in sampled_ids])
        share = w_s / max(w_s.sum(), 1e-300)
        n_main = np.maximum((share * remaining).astype(np.int64), 1)
        while n_main.sum() > remaining:
            n_main[np.argmax(n_main)] -= 1
        before = query.oracle.calls
        round_draws: list[Optional[StratumDraw]] = [None] * (k + 1)
        for j, i in enumerate(sampled_ids):
            if n_main[j] <= 0:
                continue
            round_draws[i] = space.sample_stratum(i, int(n_main[j]))
        round_samples = _label_draws(query, round_draws)
        for i in sampled_ids:
            new = round_samples[i]
            if new is not None:
                samples[i] = new if samples[i] is None else samples[i].merge(new)
        rounds += 1
        if query.oracle.calls == before:  # everything cached; budget cannot move
            break
    timings["execute_s"] = time.perf_counter() - t0

    # ---- estimate + CI ----------------------------------------------------
    t0 = time.perf_counter()
    live = [samples[i] for i in range(k + 1) if i not in beta and samples[i] is not None]
    if query.agg in (Agg.COUNT, Agg.SUM, Agg.AVG):
        est, ci = bootstrap_t_ci(
            live, blocked, query.agg, query.confidence, cfg.n_bootstrap, rng
        )
    elif query.agg in (Agg.MIN, Agg.MAX):
        est = combined_extreme(live, blocked, query.agg.value)
        gb = query.g_bounds
        if query.agg is Agg.MAX:
            hi = gb[1] if gb else est
            ci = ConfidenceInterval(est, hi, query.confidence)
        else:
            lo = gb[0] if gb else est
            ci = ConfidenceInterval(lo, est, query.confidence)
    elif query.agg is Agg.MEDIAN:
        est = combined_cdf_median(live, blocked)
        ci = _bootstrap_median_ci(live, blocked, query.confidence, cfg.n_bootstrap, rng)
    else:
        raise ValueError(query.agg)
    timings["ci_s"] = time.perf_counter() - t0
    timings["total_s"] = time.perf_counter() - t_start

    return QueryResult(
        estimate=float(est),
        ci=ci,
        oracle_calls=query.oracle.calls,
        detail={
            **detail,
            **({"stratify": space.meta} if space.meta else {}),
            "beta": sorted(beta),
            "num_strata": k,
            "stratum_sizes": sizes.tolist(),
            "pilot_n": n_pilot.tolist(),
            "est_mse": allocation.est_mse,
            "timings": timings,
            "oracle": query.oracle.stats(),
        },
    )


def build_dense_space(
    query: Query,
    cfg: BASConfig,
    rng: np.random.Generator,
    timings: dict,
    weights: Optional[np.ndarray] = None,
) -> StratifiedSpace:
    """Stage 1 of the dense path: materialised chain weights + sorted-top
    stratification, packaged as a :class:`StratifiedSpace`.  Shared by
    ``run_bas`` and the cascade estimator (``cascade.run_bas_cascade``), so
    both regimes stratify identically and differ only in how the pipeline
    spends the Oracle budget."""
    # ---- similarity + stratification -------------------------------------
    t0 = time.perf_counter()
    if weights is None:
        weights = chain_weights(
            query.spec.embeddings, cfg.weight_exponent, cfg.weight_floor
        )
    timings["similarity_s"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    strat = stratify_dense(weights, cfg.alpha, query.budget, cfg)
    k = strat.num_strata
    sizes = strat.stratum_sizes()
    per_idx = _stratum_flat_indices(strat, weights)
    top_sum = float(weights[strat.order].sum())
    total_sum = float(weights.sum())
    weight_sums = np.empty(k + 1, np.float64)
    weight_sums[0] = max(total_sum - top_sum, 0.0)
    for i in range(1, k + 1):
        weight_sums[i] = float(weights[per_idx[i]].sum())
    # D_0 sampling weights: zero out the blocking regime
    w0 = np.array(weights, np.float64, copy=True)
    w0[strat.order] = 0.0
    timings["stratify_s"] = time.perf_counter() - t0

    def sample_stratum(i: int, n: int) -> StratumDraw:
        if i == 0:
            pos, q = flat_sample(w0, n, rng, cfg.defensive_mix)
            tup = flat_to_tuples(pos, query.spec.sizes)
            return StratumDraw(tup=tup, q=q, size=int(sizes[0]))
        return _draw_stratum(weights, per_idx[i], n, query, rng, cfg.defensive_mix)

    return StratifiedSpace(
        sizes=sizes,
        weight_sums=weight_sums,
        sample_stratum=sample_stratum,
        stratum_tuples=lambda i: flat_to_tuples(per_idx[i], query.spec.sizes),
        meta={"path": "dense-sort"},
    )


def run_bas(
    query: Query,
    cfg: Optional[BASConfig] = None,
    seed: int = 0,
    weights: Optional[np.ndarray] = None,
) -> QueryResult:
    cfg = cfg or BASConfig()
    rng = np.random.default_rng(seed)
    t_start = time.perf_counter()
    timings: dict = {}

    query.oracle.set_budget(query.budget)
    query.oracle.bind_sizes(query.spec.sizes)
    n_total = query.spec.n_tuples
    if query.budget >= n_total:
        return run_exact(query)

    space = build_dense_space(query, cfg, rng, timings, weights)
    return run_stratified_pipeline(
        query, cfg, rng, space, {"mode": "bas"}, timings, t_start
    )


def _bootstrap_median_ci(samples, blocked, p, n_boot, rng):
    """Percentile bootstrap on the combined weighted-CDF median (paper notes
    MEDIAN is Hadamard differentiable so the bootstrap is valid)."""
    meds = []
    for _ in range(min(n_boot, 400)):
        rs = []
        for s in samples:
            ridx = rng.integers(0, s.n, size=s.n)
            rs.append(StratumSample(o=s.o[ridx], g=s.g[ridx], q=s.q[ridx], size=s.size))
        meds.append(combined_cdf_median(rs, blocked))
    meds = np.array([m for m in meds if np.isfinite(m)])
    if len(meds) < 10:
        m = combined_cdf_median(samples, blocked)
        return ConfidenceInterval(m, m, p)
    lo = float(np.quantile(meds, (1 - p) / 2))
    hi = float(np.quantile(meds, 1 - (1 - p) / 2))
    return ConfidenceInterval(lo, hi, p)


def _allocate_extreme(samples, sizes, weight_sums, b2, agg):
    """MIN/MAX allocation (paper §5.3): block the strata most likely to contain
    the extreme.  Exceedance score per stratum = exponential-tail estimate of
    P(value beyond current observed extreme) from pilot positives."""
    k = len(sizes) - 1
    sign = 1.0 if agg is Agg.MAX else -1.0
    observed = [
        sign * s.g[s.o > 0] for s in samples if s is not None and (s.o > 0).any()
    ]
    cur = max((float(v.max()) for v in observed), default=-np.inf)
    scores = np.zeros(k + 1)
    for i in range(1, k + 1):
        s = samples[i]
        if s is None:
            continue
        v = sign * s.g[s.o > 0]
        if len(v) == 0:
            continue
        mu = float(v.mean())
        scale = float(v.std(ddof=1)) if len(v) > 1 else abs(mu) + 1.0
        scale = max(scale, 1e-9)
        # exponential tail: P(X > cur) ~ exp(-(cur - mu)/scale)
        scores[i] = np.exp(-max(cur - mu, 0.0) / scale) * sizes[i]
    order = np.argsort(scores[1:])[::-1] + 1
    beta, cost = [], 0
    for i in order:
        if scores[i] <= 0:
            break
        if cost + sizes[i] <= b2 * 0.9:  # keep some budget for sampling
            beta.append(int(i))
            cost += int(sizes[i])
    mask = np.zeros(k + 1, bool)
    mask[beta] = True
    return alloc_mod.Allocation(
        beta=np.array(sorted(beta), np.int64),
        n_per_stratum=alloc_mod.budget_assign(b2, weight_sums, sizes, mask),
        est_mse=float("nan"),
    )
