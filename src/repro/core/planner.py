"""Join-order optimisation from approximate COUNT estimates (paper §7.4).

For a k-table chain join the cross-product-free plan space is exactly the set
of contiguous-interval parenthesisations, so DPccp [60] reduces to interval
DP.  Cost model (paper's setting): executing a join of intermediates of
cardinalities |L| and |R| costs |L| * |R| Oracle probes; intermediate
cardinalities come from a cardinality provider — BAS COUNT with a small
budget, UNIFORM COUNT, WWJ COUNT, or the ground truth (for regret reporting).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional


from .types import Agg, BASConfig, JoinSpec, Query
from .oracle import Oracle


@dataclasses.dataclass
class Plan:
    """Binary join tree over tables [i..j]."""
    lo: int
    hi: int
    left: Optional["Plan"] = None
    right: Optional["Plan"] = None
    cost: float = 0.0

    def order_str(self) -> str:
        if self.left is None:
            return f"T{self.lo}"
        return f"({self.left.order_str()} ⋈ {self.right.order_str()})"


CardFn = Callable[[int, int], float]  # (lo, hi) inclusive -> |join(T_lo..T_hi)|


def dp_chain_plan(k: int, sizes: list[int], card: CardFn) -> Plan:
    """Interval DP (DPccp on a chain).  cost(plan) = sum over internal joins of
    |left| * |right| (the Oracle probes to form the join)."""
    best: dict[tuple, Plan] = {}
    for i in range(k):
        best[(i, i)] = Plan(i, i, cost=0.0)

    def cardinality(lo, hi):
        return float(sizes[lo]) if lo == hi else max(float(card(lo, hi)), 1.0)

    for span in range(1, k):
        for lo in range(0, k - span):
            hi = lo + span
            best_plan = None
            for mid in range(lo, hi):
                l, r = best[(lo, mid)], best[(mid + 1, hi)]
                cost = l.cost + r.cost + cardinality(lo, mid) * cardinality(mid + 1, hi)
                if best_plan is None or cost < best_plan.cost:
                    best_plan = Plan(lo, hi, l, r, cost)
            best[(lo, hi)] = best_plan
    return best[(0, k - 1)]


def plan_cost_under_truth(plan: Plan, sizes: list[int], true_card: CardFn) -> float:
    """Re-cost a plan under ground-truth cardinalities (regret evaluation)."""
    if plan.left is None:
        return 0.0

    def cardinality(lo, hi):
        return float(sizes[lo]) if lo == hi else max(float(true_card(lo, hi)), 1.0)

    return (
        plan_cost_under_truth(plan.left, sizes, true_card)
        + plan_cost_under_truth(plan.right, sizes, true_card)
        + cardinality(plan.left.lo, plan.left.hi)
        * cardinality(plan.right.lo, plan.right.hi)
    )


def bas_cardinality_provider(
    spec: JoinSpec,
    oracle_factory: Callable[[int, int], Oracle],
    budget_per_subjoin: int,
    cfg: Optional[BASConfig] = None,
    seed: int = 0,
) -> CardFn:
    """Cardinality of each contiguous sub-join via a BAS COUNT query.

    ``oracle_factory(lo, hi)`` must return an Oracle labelling tuples of
    tables lo..hi (inclusive).
    """
    from .bas import run_bas

    cfg = cfg or BASConfig()
    cache: dict[tuple, float] = {}

    def card(lo: int, hi: int) -> float:
        key = (lo, hi)
        if key not in cache:
            sub = JoinSpec(embeddings=list(spec.embeddings[lo : hi + 1]))
            q = Query(
                spec=sub, agg=Agg.COUNT, oracle=oracle_factory(lo, hi),
                budget=budget_per_subjoin, confidence=0.95,
            )
            res = run_bas(q, cfg, seed=seed + lo * 31 + hi)
            cache[key] = max(res.estimate, 0.0)
        return cache[key]

    return card


def uniform_cardinality_provider(
    spec: JoinSpec,
    oracle_factory: Callable[[int, int], Oracle],
    budget_per_subjoin: int,
    seed: int = 0,
) -> CardFn:
    from .baselines import run_uniform

    cache: dict[tuple, float] = {}

    def card(lo: int, hi: int) -> float:
        key = (lo, hi)
        if key not in cache:
            sub = JoinSpec(embeddings=list(spec.embeddings[lo : hi + 1]))
            q = Query(
                spec=sub, agg=Agg.COUNT, oracle=oracle_factory(lo, hi),
                budget=budget_per_subjoin, confidence=0.95,
            )
            cache[key] = max(run_uniform(q, seed=seed + lo * 31 + hi).estimate, 0.0)
        return cache[key]

    return card
