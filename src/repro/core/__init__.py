"""JoinML-X core: the paper's algorithms (WWJ, BAS) and query engine."""
from repro.obs import QueryTelemetry  # noqa: F401 — QueryResult.telemetry type
from .types import (  # noqa: F401
    Agg,
    BASConfig,
    ConfidenceInterval,
    JoinSpec,
    Query,
    QueryResult,
    constant_attr,
)
from .oracle import (  # noqa: F401
    ArrayOracle,
    FnOracle,
    LabelRequest,
    LabelResult,
    ModelOracle,
    Oracle,
    OracleBatch,
    OracleRequest,
    PairChainOracle,
)
from .bas import run_bas, run_exact, run_stratified_pipeline  # noqa: F401
from .bas_streaming import run_bas_streaming  # noqa: F401
from .cascade import (  # noqa: F401
    SimilarityProxyOracle,
    run_bas_cascade,
    similarity_proxy,
)
from .dispatch import choose_path, dense_weight_bytes, run_auto  # noqa: F401
from .index import (  # noqa: F401
    IndexArtifact,
    IndexStore,
    append_rows,
    artifact_key,
    build_index,
    table_fingerprint,
)
from .baselines import (  # noqa: F401
    calibrate_threshold,
    run_abae,
    run_blazeit,
    run_blocking,
    run_uniform,
    run_wwj,
)
from .selection import (  # noqa: F401
    run_bas_groupby,
    run_bas_selection,
    run_topk_heavy_hitters,
)
from .engine import Catalog, JoinMLEngine, Table, parse_query  # noqa: F401
from .planner import (  # noqa: F401
    bas_cardinality_provider,
    dp_chain_plan,
    plan_cost_under_truth,
    uniform_cardinality_provider,
)
