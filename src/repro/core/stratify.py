"""Stratification of the cross product (paper Alg. 4 lines 1-5).

Two paths:

* **dense/exact** — materialised flat weights, one argsort; strata are
  contiguous index ranges of the descending order.  Used when the cross
  product fits in memory (paper's own prototype does the same with SortDesc).
* **streaming/histogram** — TPU-native redesign (DESIGN.md §3): a blocked
  similarity matmul fused with a histogram (Pallas kernel ``sim_hist``; jnp
  fallback here) yields the global score distribution in O(bins) memory; the
  top-m threshold is the histogram CDF quantile and a second pass collects the
  indices above it.  This replaces the paper's O(N^2 log N^2) sort with two
  O(N^2) streaming passes and never materialises the cross product.

k-way chains (``stratify_streaming_chain``): the chain weight factorises as
prefix-weight x last-edge pair weight, so both streaming passes enumerate the
*prefix* cross product in blocks and hand the accumulated prefix weight to the
``sim_hist`` kernel as a per-row scale.  Histogram resolution: chain weights
are products of k-1 terms and concentrate near zero on a linear [0, 1] grid,
so the histogram bins the geometric-mean weight W**(1/(k-1)) (a monotone
transform — identical to the raw weight at k=2); the top-m threshold maps back
as thr**(k-1).  The two-pass memory stays O(N + bins + block*Nk + m).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .types import BASConfig


@dataclasses.dataclass
class Stratification:
    """Strata over a flat pair space.

    ``order``: flat indices sorted by weight descending (top region only for
    streaming mode — then ``order`` covers exactly the maximum blocking
    regime and ``rest_mask`` identifies D_0 implicitly).
    ``bounds``: (K+1,) ints; stratum i (1-indexed as in the paper) is
    ``order[bounds[i-1]:bounds[i]]``.  D_0 is everything not in ``order[:bounds[-1]]``.
    """

    order: np.ndarray
    bounds: np.ndarray
    n_total: int

    @property
    def num_strata(self) -> int:
        return len(self.bounds) - 1

    def stratum_indices(self, i: int) -> np.ndarray:
        """Flat indices of stratum i in {1..K}."""
        assert 1 <= i <= self.num_strata
        return self.order[self.bounds[i - 1] : self.bounds[i]]

    def stratum_sizes(self) -> np.ndarray:
        """Sizes of [D_0, D_1, ..., D_K]."""
        top = np.diff(self.bounds)
        d0 = self.n_total - int(self.bounds[-1])
        return np.concatenate([[d0], top]).astype(np.int64)

    def blocking_regime_size(self) -> int:
        return int(self.bounds[-1])

    def d0_mask(self, n: int) -> np.ndarray:
        m = np.ones(n, dtype=bool)
        m[self.order[: self.bounds[-1]]] = False
        return m


def auto_num_strata(alpha: float, budget: int, cfg: BASConfig) -> int:
    """Paper §5.3/§5.5: K s.t. each stratum gets >= ~1000 Oracle budget,
    clamped to [min_strata, max_strata]."""
    k = int(alpha * budget) // cfg.budget_per_stratum
    return int(np.clip(k, cfg.min_strata, cfg.max_strata))


def stratify_dense(
    weights: np.ndarray, alpha: float, budget: int, cfg: BASConfig
) -> Stratification:
    """Exact stratification by sorting flat weights descending."""
    weights = np.asarray(weights).reshape(-1)
    n = weights.shape[0]
    m = min(int(round(alpha * budget)), n)
    k = auto_num_strata(alpha, budget, cfg)
    k = max(1, min(k, m)) if m > 0 else 0
    if m == 0:
        return Stratification(
            order=np.empty((0,), np.int64), bounds=np.zeros((1,), np.int64), n_total=n
        )
    # argpartition for top-m then sort only those (O(n + m log m))
    if m < n:
        top = np.argpartition(weights, n - m)[n - m :]
    else:
        top = np.arange(n)
    top = top[np.argsort(weights[top])[::-1]]
    bounds = np.round(np.linspace(0, m, k + 1)).astype(np.int64)
    return Stratification(order=top.astype(np.int64), bounds=bounds, n_total=n)


# ----------------------------------------------------------------------------
# Streaming/histogram path (sim_hist Pallas kernel with jnp/numpy fallback).
# ----------------------------------------------------------------------------

def _kernel_hist(e1, e2, n_bins, exponent, floor, scale=None):
    """Fused-kernel histogram, or None when Pallas is unavailable/broken —
    the caller falls back to the blocked numpy path.  Missing Pallas
    (ImportError) degrades silently; any other kernel failure is a real bug
    and is surfaced as a warning so it cannot hide behind the fallback."""
    try:
        from repro.kernels.sim_hist import ops as sim_hist_ops
    except ImportError:
        return None
    try:
        return sim_hist_ops.sim_hist(
            e1, e2, n_bins, exponent, floor, scale=scale
        )
    except Exception as e:
        import warnings

        warnings.warn(f"sim_hist kernel failed ({e!r}); using jnp fallback")
        return None


def _prefix_chain_weights(embeddings, start, stop, exponent, floor):
    """Chain weights of prefix tuples [start, stop) in the row-major flat
    order of the *prefix* cross product (all tables but the last).  Returns
    (weights, last_prefix_table_indices)."""
    from .similarity import chain_tuple_weights, flat_to_tuples

    prefix_sizes = tuple(e.shape[0] for e in embeddings[:-1])
    flat = np.arange(start, stop, dtype=np.int64)
    tup = flat_to_tuples(flat, prefix_sizes)
    if len(prefix_sizes) == 1:
        return np.ones(len(flat), np.float64), tup[:, -1]
    wp = chain_tuple_weights(embeddings[:-1], tup, exponent, floor)
    return wp, tup[:, -1]


def weight_histogram(
    e1: np.ndarray,
    e2: np.ndarray,
    n_bins: int = 4096,
    exponent: float = 1.0,
    floor: float = 1e-3,
    block: int = 4096,
    use_kernel: bool = False,
) -> tuple[np.ndarray, np.ndarray]:
    """Histogram of pair weights over the (never materialised) cross product.

    Returns (counts[n_bins], edges[n_bins+1]) with edges spanning [0, 1].
    """
    from .similarity import pair_weights  # local import to avoid cycle

    if use_kernel:
        out = _kernel_hist(e1, e2, n_bins, exponent, floor)
        if out is not None:
            return out

    edges = np.linspace(0.0, 1.0, n_bins + 1)
    counts = np.zeros(n_bins, np.int64)
    n1 = e1.shape[0]
    for s in range(0, n1, block):
        w = pair_weights(e1[s : s + block], e2, exponent, floor)
        c, _ = np.histogram(w, bins=edges)
        counts += c
    return counts, edges


def chain_weight_histogram(
    embeddings: list,
    n_bins: int = 4096,
    exponent: float = 1.0,
    floor: float = 1e-3,
    block: int = 4096,
    use_kernel: bool = False,
) -> tuple[np.ndarray, np.ndarray]:
    """Histogram of the geometric-mean chain weight W(t)**(1/(k-1)) over the
    full k-way cross product, streamed over prefix blocks (O(block * Nk)
    peak memory).  At k=2 this is exactly ``weight_histogram``."""
    from .similarity import pair_weights

    k = len(embeddings)
    if k == 2:
        return weight_histogram(
            embeddings[0], embeddings[1], n_bins, exponent, floor, block,
            use_kernel,
        )
    root = 1.0 / (k - 1)
    e_prev, e_last = embeddings[-2], embeddings[-1]
    n_prefix = 1
    for e in embeddings[:-1]:
        n_prefix *= e.shape[0]
    edges = np.linspace(0.0, 1.0, n_bins + 1)
    counts = np.zeros(n_bins, np.int64)
    for s in range(0, n_prefix, block):
        wp, i_last = _prefix_chain_weights(
            embeddings, s, min(s + block, n_prefix), exponent, floor
        )
        done = False
        if use_kernel:
            # kernel computes max(clip(sim), floor)**(e*root) * scale —
            # exactly (wp * w_last)**root when scale = wp**root
            out = _kernel_hist(
                e_prev[i_last], e_last, n_bins, exponent * root, floor,
                scale=wp**root,
            )
            if out is not None:
                counts += out[0]
                done = True
        if not done:
            w = pair_weights(e_prev[i_last], e_last, exponent, floor)
            v = (wp[:, None] * w) ** root
            c, _ = np.histogram(v, bins=edges)
            counts += c
    return counts, edges


def threshold_for_top_m(counts: np.ndarray, edges: np.ndarray, m: int) -> float:
    """Largest bin edge t such that #weights >= t is >= m (CDF from the top)."""
    csum = np.cumsum(counts[::-1])[::-1]  # csum[i] = #weights in bins >= i
    ok = np.nonzero(csum >= m)[0]
    if len(ok) == 0:
        return float(edges[0])
    return float(edges[ok[-1]])


def _collect_top_pairs_topk(e1, e2, threshold, exponent, floor):
    """sim_topk-kernel-assisted over-threshold collection for two tables.

    Per-row top-k candidates from the fused kernel; any row whose k-th
    candidate still clears the threshold may have been truncated and is
    rescanned exactly.  Returns (flat_idx, weights) or None when the kernel
    is unavailable or the candidate count would not pay off."""
    from .similarity import pair_weights, weight_of_score

    n1, n2 = e1.shape[0], e2.shape[0]
    try:
        from repro.kernels.sim_topk.ops import sim_topk
    except ImportError:
        return None
    try:
        vals, idx, valid = sim_topk(e1, e2, k=min(64, n2))
    except Exception as e:
        import warnings

        warnings.warn(f"sim_topk kernel failed ({e!r}); using dense scan")
        return None
    kk = vals.shape[1]
    w_vals = weight_of_score(np.asarray(vals, np.float64), exponent, floor)
    keep = (w_vals >= threshold) & valid
    if kk < n2:  # a row's hits may have been truncated at kk candidates
        saturated = np.nonzero(w_vals[:, -1] >= threshold)[0]
    else:
        saturated = np.empty(0, np.int64)
    if len(saturated) > n1 // 4:
        return None  # threshold too deep for k candidates; dense scan is cheaper
    keep[saturated] = False
    r, c = np.nonzero(keep)
    flat = [r.astype(np.int64) * n2 + idx[r, c]]
    wts = [w_vals[r, c]]
    if len(saturated):
        w = pair_weights(e1[saturated], e2, exponent, floor)
        rr, cc = np.nonzero(w >= threshold)
        flat.append(saturated[rr].astype(np.int64) * n2 + cc)
        wts.append(w[rr, cc])
    return np.concatenate(flat), np.concatenate(wts)


def collect_top(
    e1: np.ndarray,
    e2: np.ndarray,
    threshold: float,
    m_cap: int,
    exponent: float = 1.0,
    floor: float = 1e-3,
    block: int = 4096,
    use_kernel: bool = False,
) -> np.ndarray:
    """Second streaming pass: flat indices of pairs with weight >= threshold,
    sorted by weight descending, truncated to m_cap."""
    from .similarity import pair_weights

    n1, n2 = e1.shape[0], e2.shape[0]
    if use_kernel and m_cap < 16 * n1:
        out = _collect_top_pairs_topk(e1, e2, threshold, exponent, floor)
        if out is not None:
            idx, w = out
            order = np.argsort(w)[::-1][:m_cap]
            return idx[order]
    idx_chunks, w_chunks = [], []
    for s in range(0, n1, block):
        w = pair_weights(e1[s : s + block], e2, exponent, floor)
        r, c = np.nonzero(w >= threshold)
        idx_chunks.append(((r + s).astype(np.int64) * n2 + c))
        w_chunks.append(w[r, c])
    idx = np.concatenate(idx_chunks) if idx_chunks else np.empty(0, np.int64)
    w = np.concatenate(w_chunks) if w_chunks else np.empty(0, np.float64)
    order = np.argsort(w)[::-1][:m_cap]
    return idx[order]


def collect_top_chain(
    embeddings: list,
    threshold_root: float,
    m_cap: int,
    exponent: float = 1.0,
    floor: float = 1e-3,
    block: int = 4096,
    use_kernel: bool = False,
) -> np.ndarray:
    """Flat indices (over the full k-way cross product, row-major) of tuples
    whose geometric-mean chain weight clears ``threshold_root``, sorted by
    chain weight descending, truncated to m_cap."""
    from .similarity import pair_weights

    k = len(embeddings)
    if k == 2:
        return collect_top(
            embeddings[0], embeddings[1], threshold_root, m_cap, exponent,
            floor, block, use_kernel,
        )
    thr_w = threshold_root ** (k - 1)  # back to raw chain-weight space
    e_prev, e_last = embeddings[-2], embeddings[-1]
    n_last = e_last.shape[0]
    n_prefix = 1
    for e in embeddings[:-1]:
        n_prefix *= e.shape[0]
    idx_chunks, w_chunks = [], []
    for s in range(0, n_prefix, block):
        wp, i_last = _prefix_chain_weights(
            embeddings, s, min(s + block, n_prefix), exponent, floor
        )
        w = wp[:, None] * pair_weights(e_prev[i_last], e_last, exponent, floor)
        r, c = np.nonzero(w >= thr_w)
        idx_chunks.append((r + s).astype(np.int64) * n_last + c)
        w_chunks.append(w[r, c])
    idx = np.concatenate(idx_chunks) if idx_chunks else np.empty(0, np.int64)
    w = np.concatenate(w_chunks) if w_chunks else np.empty(0, np.float64)
    order = np.argsort(w)[::-1][:m_cap]
    return idx[order]


def stratify_streaming_chain(
    embeddings: list,
    alpha: float,
    budget: int,
    cfg: BASConfig,
    n_bins: int = 4096,
    use_kernel: bool = False,
) -> Stratification:
    """Histogram-thresholded stratification of a k-way chain; equal-size
    strata like the dense path but the threshold (hence membership at the
    boundary) is bin-resolution approximate.  Strata remain exactly
    equal-sized; only *which* borderline tuples land in D_K vs D_0 can differ
    — the estimator stays unbiased because stratum membership is
    deterministic given the data."""
    n = 1
    for e in embeddings:
        n *= e.shape[0]
    m = min(int(round(alpha * budget)), n)
    k = auto_num_strata(alpha, budget, cfg)
    k = max(1, min(k, m)) if m > 0 else 0
    if m == 0:
        return Stratification(np.empty(0, np.int64), np.zeros(1, np.int64), n)
    counts, edges = chain_weight_histogram(
        embeddings, n_bins, cfg.weight_exponent, cfg.weight_floor,
        use_kernel=use_kernel,
    )
    thr = threshold_for_top_m(counts, edges, m)
    order = collect_top_chain(
        embeddings, thr, m, cfg.weight_exponent, cfg.weight_floor,
        use_kernel=use_kernel,
    )
    m_eff = len(order)
    k = max(1, min(k, m_eff))
    bounds = np.round(np.linspace(0, m_eff, k + 1)).astype(np.int64)
    return Stratification(order=order, bounds=bounds, n_total=n)


def stratify_streaming(
    e1: np.ndarray,
    e2: np.ndarray,
    alpha: float,
    budget: int,
    cfg: BASConfig,
    n_bins: int = 4096,
    use_kernel: bool = False,
) -> Stratification:
    """Two-table wrapper of :func:`stratify_streaming_chain`."""
    return stratify_streaming_chain(
        [e1, e2], alpha, budget, cfg, n_bins=n_bins, use_kernel=use_kernel
    )
