"""Stratification of the cross product (paper Alg. 4 lines 1-5).

Two paths:

* **dense/exact** — materialised flat weights, one argsort; strata are
  contiguous index ranges of the descending order.  Used when the cross
  product fits in memory (paper's own prototype does the same with SortDesc).
* **streaming/histogram** — TPU-native redesign (DESIGN.md §3): a blocked
  similarity matmul fused with a histogram (Pallas kernel ``sim_hist``; jnp
  fallback here) yields the global score distribution in O(bins) memory; the
  top-m threshold is the histogram CDF quantile and a second pass collects the
  indices above it.  This replaces the paper's O(N^2 log N^2) sort with two
  O(N^2) streaming passes and never materialises the cross product.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .types import BASConfig


@dataclasses.dataclass
class Stratification:
    """Strata over a flat pair space.

    ``order``: flat indices sorted by weight descending (top region only for
    streaming mode — then ``order`` covers exactly the maximum blocking
    regime and ``rest_mask`` identifies D_0 implicitly).
    ``bounds``: (K+1,) ints; stratum i (1-indexed as in the paper) is
    ``order[bounds[i-1]:bounds[i]]``.  D_0 is everything not in ``order[:bounds[-1]]``.
    """

    order: np.ndarray
    bounds: np.ndarray
    n_total: int

    @property
    def num_strata(self) -> int:
        return len(self.bounds) - 1

    def stratum_indices(self, i: int) -> np.ndarray:
        """Flat indices of stratum i in {1..K}."""
        assert 1 <= i <= self.num_strata
        return self.order[self.bounds[i - 1] : self.bounds[i]]

    def stratum_sizes(self) -> np.ndarray:
        """Sizes of [D_0, D_1, ..., D_K]."""
        top = np.diff(self.bounds)
        d0 = self.n_total - int(self.bounds[-1])
        return np.concatenate([[d0], top]).astype(np.int64)

    def blocking_regime_size(self) -> int:
        return int(self.bounds[-1])

    def d0_mask(self, n: int) -> np.ndarray:
        m = np.ones(n, dtype=bool)
        m[self.order[: self.bounds[-1]]] = False
        return m


def auto_num_strata(alpha: float, budget: int, cfg: BASConfig) -> int:
    """Paper §5.3/§5.5: K s.t. each stratum gets >= ~1000 Oracle budget,
    clamped to [min_strata, max_strata]."""
    k = int(alpha * budget) // cfg.budget_per_stratum
    return int(np.clip(k, cfg.min_strata, cfg.max_strata))


def stratify_dense(
    weights: np.ndarray, alpha: float, budget: int, cfg: BASConfig
) -> Stratification:
    """Exact stratification by sorting flat weights descending."""
    weights = np.asarray(weights).reshape(-1)
    n = weights.shape[0]
    m = min(int(round(alpha * budget)), n)
    k = auto_num_strata(alpha, budget, cfg)
    k = max(1, min(k, m)) if m > 0 else 0
    if m == 0:
        return Stratification(
            order=np.empty((0,), np.int64), bounds=np.zeros((1,), np.int64), n_total=n
        )
    # argpartition for top-m then sort only those (O(n + m log m))
    if m < n:
        top = np.argpartition(weights, n - m)[n - m :]
    else:
        top = np.arange(n)
    top = top[np.argsort(weights[top])[::-1]]
    bounds = np.round(np.linspace(0, m, k + 1)).astype(np.int64)
    return Stratification(order=top.astype(np.int64), bounds=bounds, n_total=n)


# ----------------------------------------------------------------------------
# Streaming/histogram path (jnp fallback of the sim_hist Pallas kernel).
# ----------------------------------------------------------------------------

def weight_histogram(
    e1: np.ndarray,
    e2: np.ndarray,
    n_bins: int = 4096,
    exponent: float = 1.0,
    floor: float = 1e-3,
    block: int = 4096,
    use_kernel: bool = False,
) -> tuple[np.ndarray, np.ndarray]:
    """Histogram of pair weights over the (never materialised) cross product.

    Returns (counts[n_bins], edges[n_bins+1]) with edges spanning [0, 1].
    """
    from .similarity import pair_weights  # local import to avoid cycle

    if use_kernel:
        from repro.kernels.sim_hist import ops as sim_hist_ops

        return sim_hist_ops.sim_hist(e1, e2, n_bins, exponent, floor)

    edges = np.linspace(0.0, 1.0, n_bins + 1)
    counts = np.zeros(n_bins, np.int64)
    n1 = e1.shape[0]
    for s in range(0, n1, block):
        w = pair_weights(e1[s : s + block], e2, exponent, floor)
        c, _ = np.histogram(w, bins=edges)
        counts += c
    return counts, edges


def threshold_for_top_m(counts: np.ndarray, edges: np.ndarray, m: int) -> float:
    """Largest bin edge t such that #weights >= t is >= m (CDF from the top)."""
    csum = np.cumsum(counts[::-1])[::-1]  # csum[i] = #weights in bins >= i
    ok = np.nonzero(csum >= m)[0]
    if len(ok) == 0:
        return float(edges[0])
    return float(edges[ok[-1]])


def collect_top(
    e1: np.ndarray,
    e2: np.ndarray,
    threshold: float,
    m_cap: int,
    exponent: float = 1.0,
    floor: float = 1e-3,
    block: int = 4096,
) -> np.ndarray:
    """Second streaming pass: flat indices of pairs with weight >= threshold,
    sorted by weight descending, truncated to m_cap."""
    from .similarity import pair_weights

    n1, n2 = e1.shape[0], e2.shape[0]
    idx_chunks, w_chunks = [], []
    for s in range(0, n1, block):
        w = pair_weights(e1[s : s + block], e2, exponent, floor)
        r, c = np.nonzero(w >= threshold)
        idx_chunks.append(((r + s).astype(np.int64) * n2 + c))
        w_chunks.append(w[r, c])
    idx = np.concatenate(idx_chunks) if idx_chunks else np.empty(0, np.int64)
    w = np.concatenate(w_chunks) if w_chunks else np.empty(0, np.float64)
    order = np.argsort(w)[::-1][:m_cap]
    return idx[order]


def stratify_streaming(
    e1: np.ndarray,
    e2: np.ndarray,
    alpha: float,
    budget: int,
    cfg: BASConfig,
    n_bins: int = 4096,
    use_kernel: bool = False,
) -> Stratification:
    """Histogram-thresholded stratification; equal-size strata like the dense
    path but the threshold (hence membership at the boundary) is bin-resolution
    approximate.  Strata remain exactly equal-sized; only *which* borderline
    pairs land in D_K vs D_0 can differ — the estimator stays unbiased because
    stratum membership is deterministic given the data."""
    n = e1.shape[0] * e2.shape[0]
    m = min(int(round(alpha * budget)), n)
    k = auto_num_strata(alpha, budget, cfg)
    k = max(1, min(k, m)) if m > 0 else 0
    if m == 0:
        return Stratification(np.empty(0, np.int64), np.zeros(1, np.int64), n)
    counts, edges = weight_histogram(
        e1, e2, n_bins, cfg.weight_exponent, cfg.weight_floor, use_kernel=use_kernel
    )
    thr = threshold_for_top_m(counts, edges, m)
    order = collect_top(e1, e2, thr, m, cfg.weight_exponent, cfg.weight_floor)
    m_eff = len(order)
    k = max(1, min(k, m_eff))
    bounds = np.round(np.linspace(0, m_eff, k + 1)).astype(np.int64)
    return Stratification(order=order, bounds=bounds, n_total=n)
