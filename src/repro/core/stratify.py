"""Stratification of the cross product (paper Alg. 4 lines 1-5).

Two paths:

* **dense/exact** — materialised flat weights, one argsort; strata are
  contiguous index ranges of the descending order.  Used when the cross
  product fits in memory (paper's own prototype does the same with SortDesc).
* **streaming/single-sweep** — TPU-native redesign (docs/kernels.md): **one**
  blocked pass of ``E1 @ E2^T`` (Pallas kernel ``sim_sweep``; blocked
  numpy fallback here) emits the global weight histogram, per-(row-block,
  bin) count tiles, and the per-row top-k.  The top-m threshold is the
  histogram CDF quantile; collection reads the top-k candidates and rescans
  only the row blocks whose count tiles prove over-threshold mass — so the
  paper's O(N^2 log N^2) sort becomes ~one O(N^2) streaming pass, and the
  cross product is never materialised.  (The two-pass histogram-then-collect
  path is kept behind ``use_sweep=False`` as the bit-identical baseline.)

k-way chains (``stratify_streaming_chain``): the chain weight factorises as
prefix-weight x last-edge pair weight, so the sweep enumerates the chain's
*prefix* space in blocks and hands the accumulated prefix weight to the
kernel as a per-row scale.  Histogram resolution: chain weights are products
of k-1 terms and concentrate near zero on a linear [0, 1] grid, so the
histogram bins the geometric-mean weight W**(1/(k-1)) (a monotone transform —
identical to the raw weight at k=2); the top-m threshold maps back as
thr**(k-1).  Memory stays O(N + bins + block*Nk + m).

Precision: the sweep runs fp32 by default (bit-identical to the two-pass
path).  ``precision="bf16"``/``"int8"`` (see
``configs.joinml_embedder.EMBEDDING_PRECISIONS``) opt into the low-precision
MXU fast path; the first row block is re-binned at fp32 and the sweep falls
back to fp32 when the CDF deviation exceeds the configured tolerance.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .types import BASConfig


@dataclasses.dataclass
class Stratification:
    """Strata over a flat pair space.

    ``order``: flat indices sorted by weight descending (top region only for
    streaming mode — then ``order`` covers exactly the maximum blocking
    regime and ``rest_mask`` identifies D_0 implicitly).
    ``bounds``: (K+1,) ints; stratum i (1-indexed as in the paper) is
    ``order[bounds[i-1]:bounds[i]]``.  D_0 is everything not in ``order[:bounds[-1]]``.
    ``order_weights``: sampling weights aligned with ``order`` when the
    streaming collector produced them (f64; None on the dense path).
    ``sweep``: the :class:`SweepInfo` that stratified this space, when the
    single-sweep path ran (None otherwise) — samplers consume its count
    tiles and stats.
    """

    order: np.ndarray
    bounds: np.ndarray
    n_total: int
    order_weights: Optional[np.ndarray] = None
    sweep: Optional["SweepInfo"] = None

    @property
    def num_strata(self) -> int:
        return len(self.bounds) - 1

    def stratum_indices(self, i: int) -> np.ndarray:
        """Flat indices of stratum i in {1..K}."""
        assert 1 <= i <= self.num_strata
        return self.order[self.bounds[i - 1] : self.bounds[i]]

    def stratum_weights(self, i: int) -> Optional[np.ndarray]:
        """Collector-produced weights of stratum i, if available."""
        if self.order_weights is None:
            return None
        return self.order_weights[self.bounds[i - 1] : self.bounds[i]]

    def stratum_sizes(self) -> np.ndarray:
        """Sizes of [D_0, D_1, ..., D_K]."""
        top = np.diff(self.bounds)
        d0 = self.n_total - int(self.bounds[-1])
        return np.concatenate([[d0], top]).astype(np.int64)

    def blocking_regime_size(self) -> int:
        return int(self.bounds[-1])

    def d0_mask(self, n: int) -> np.ndarray:
        m = np.ones(n, dtype=bool)
        m[self.order[: self.bounds[-1]]] = False
        return m


def auto_num_strata(alpha: float, budget: int, cfg: BASConfig) -> int:
    """Paper §5.3/§5.5: K s.t. each stratum gets >= ~1000 Oracle budget,
    clamped to [min_strata, max_strata]."""
    k = int(alpha * budget) // cfg.budget_per_stratum
    return int(np.clip(k, cfg.min_strata, cfg.max_strata))


def stratify_dense(
    weights: np.ndarray, alpha: float, budget: int, cfg: BASConfig
) -> Stratification:
    """Exact stratification by sorting flat weights descending."""
    weights = np.asarray(weights).reshape(-1)
    n = weights.shape[0]
    m = min(int(round(alpha * budget)), n)
    k = auto_num_strata(alpha, budget, cfg)
    k = max(1, min(k, m)) if m > 0 else 0
    if m == 0:
        return Stratification(
            order=np.empty((0,), np.int64), bounds=np.zeros((1,), np.int64), n_total=n
        )
    # argpartition for top-m then sort only those (O(n + m log m))
    if m < n:
        top = np.argpartition(weights, n - m)[n - m :]
    else:
        top = np.arange(n)
    top = top[np.argsort(weights[top])[::-1]]
    bounds = np.round(np.linspace(0, m, k + 1)).astype(np.int64)
    return Stratification(order=top.astype(np.int64), bounds=bounds, n_total=n)


# ----------------------------------------------------------------------------
# Single-sweep streaming path (sim_sweep Pallas kernel with numpy fallback).
# ----------------------------------------------------------------------------

# Per-row candidate budget of the sweep's top-k output.  The top-k collection
# path only engages when the blocking regime averages < 16 pairs per left row
# (see collect_top), so 32 gives 2x headroom; rows that saturate it get one
# raised-k retry and an exact rescan after that (_collect_from_topk) — no
# pair is ever dropped at the cap.
TOPK_CANDIDATES = 32


@dataclasses.dataclass
class SweepInfo:
    """Everything one fused pass over the (never materialised) product
    yields: the global histogram, per-(row-block, bin) count tiles at
    ``block_rows`` left/prefix-row granularity, (two-table kernel path
    only) the per-row top-k candidates, and the walk statistics
    (``row_sums`` per edge + chain ``total_weight``) the streaming sampler
    needs for its proposal normalisation — fused into the same pass, so
    walk setup never re-reads the cross product.  ``stats`` accumulates
    collection bookkeeping (blocks rescanned vs proven empty, retry
    counts) that the BAS engines surface in ``QueryResult.detail``.

    ``row_sums``/``total_weight`` are only attached when the sweep ran at
    effective fp32 (kernel compensated accumulation, or the f64 numpy
    fallback) — low-precision sweeps leave them ``None`` so consumers
    recompute exactly rather than inherit bf16/int8 error into the
    Horvitz–Thompson weights."""

    counts: np.ndarray
    edges: np.ndarray
    block_counts: np.ndarray
    block_rows: int
    topk: Optional[tuple]       # (vals, idx, valid) or None
    kernel: bool
    precision: str
    stats: dict = dataclasses.field(default_factory=dict)
    row_sums: Optional[list] = None     # per-edge (n_j,) f64 walk sums
    total_weight: Optional[float] = None

    @property
    def n_bins(self) -> int:
        return len(self.counts)

    def threshold_bin(self, threshold: float) -> int:
        """Bin index of a histogram-edge threshold."""
        return int(np.clip(round(threshold * self.n_bins), 0, self.n_bins))

    def blocks_over(self, threshold: float, margin: Optional[int] = None) -> np.ndarray:
        """Boolean mask over row blocks that may hold weight >= threshold.

        ``margin`` bins of slack absorb binning-precision mismatch between
        the sweep (f32 scores) and host rescans (f64 transform of f32
        matmuls); low-precision sweeps get a wider default margin."""
        if margin is None:
            margin = 2 if self.precision == "fp32" else max(2, self.n_bins // 64)
        lo = max(self.threshold_bin(threshold) - margin, 0)
        return self.block_counts[:, lo:].sum(axis=1) > 0

    def rescan_starts(self, threshold: float, n_rows: int) -> tuple[list, int]:
        """Row offsets of the blocks a >= threshold rescan must touch (and
        the block stride), skipping blocks the count tiles prove empty;
        records the skip accounting in ``stats``."""
        over = self.blocks_over(threshold)
        starts = [
            b * self.block_rows for b in np.nonzero(over)[0]
            if b * self.block_rows < n_rows
        ]
        self.stats["blocks_total"] = int(len(over))
        self.stats["blocks_rescanned"] = int(len(starts))
        return starts, self.block_rows


def _kernel_op(module: str, attr: str, *args, **kwargs):
    """Call a Pallas op with the shared degradation policy: missing Pallas
    (ImportError) degrades silently to the caller's fallback; any other
    failure is a real bug and is surfaced as a warning so it cannot hide
    behind the fallback.  Returns the op's result, or None to fall back."""
    import importlib

    try:
        mod = importlib.import_module(module)
    except ImportError:
        return None
    try:
        return getattr(mod, attr)(*args, **kwargs)
    except Exception as e:
        import warnings

        warnings.warn(f"{module}.{attr} failed ({e!r}); using fallback")
        return None


def _kernel_sweep(e1, e2, n_bins, exponent, floor, scale=None,
                  precision="fp32", k_top=TOPK_CANDIDATES, right=None,
                  rs_exponent=None, block=None):
    """Fused-kernel sweep, or None -> blocked numpy fallback."""
    kwargs = dict(k=k_top, scale=scale, precision=precision, right=right,
                  rs_exponent=rs_exponent)
    if block is not None:
        kwargs["block"] = block
    return _kernel_op(
        "repro.kernels.sim_sweep.ops", "sim_sweep", e1, e2, n_bins, exponent,
        floor, **kwargs,
    )


def _prepare_sweep_right(e2, precision, n1_hint=None):
    """Padded/quantised right table for repeated chain sweeps, or None when
    the kernel layer is unavailable."""
    return _kernel_op(
        "repro.kernels.sim_sweep.ops", "prepare_right", e2,
        precision=precision, n1_hint=n1_hint,
    )


def _warn_lowp_unavailable(precision):
    import warnings

    warnings.warn(
        f"{precision} sweep requested but the Pallas kernel path is "
        "unavailable; the numpy fallback computes fp32"
    )


def _kernel_hist(e1, e2, n_bins, exponent, floor, scale=None):
    """Two-pass baseline: fused-kernel histogram, or None -> jnp fallback."""
    return _kernel_op(
        "repro.kernels.sim_hist.ops", "sim_hist", e1, e2, n_bins, exponent,
        floor, scale=scale,
    )


def _precision_tolerance(precision: str, tolerance: Optional[float]) -> Optional[float]:
    """Validate a sweep precision against the embedder's export table and
    resolve the CDF-shift tolerance (explicit value wins)."""
    from repro.configs.joinml_embedder import EMBEDDING_PRECISIONS

    if precision not in EMBEDDING_PRECISIONS:
        raise ValueError(
            f"unknown sweep precision {precision!r}; "
            f"expected one of {sorted(EMBEDDING_PRECISIONS)}"
        )
    if tolerance is not None:
        return tolerance
    return EMBEDDING_PRECISIONS[precision].max_cdf_shift or None


def _binned_counts(w: np.ndarray, n_bins: int) -> np.ndarray:
    """Host-side floor-binning matching the kernel's bin assignment."""
    idx = np.clip((np.asarray(w) * n_bins).astype(np.int64), 0, n_bins - 1)
    return np.bincount(idx.reshape(-1), minlength=n_bins).astype(np.int64)


def _lowp_cdf_dev(ref_counts: np.ndarray, lowp_counts: np.ndarray) -> float:
    """Sup-distance between two normalised histogram CDFs."""
    mass = max(float(ref_counts.sum()), 1.0)
    dev = np.abs(np.cumsum(ref_counts) - np.cumsum(lowp_counts)) / mass
    return float(dev.max())


def sweep_pass(
    e1: np.ndarray,
    e2: np.ndarray,
    n_bins: int = 4096,
    exponent: float = 1.0,
    floor: float = 1e-3,
    block: int = 4096,
    use_kernel: bool = False,
    precision: str = "fp32",
    tolerance: Optional[float] = None,
    k_top: int = TOPK_CANDIDATES,
    artifact=None,
    kernel_block: Optional[int] = None,
) -> SweepInfo:
    """One pass over the two-table product: histogram + count tiles + top-k.

    ``kernel_block`` caps the kernel path's row-block (tile stride) — index
    maintenance passes the artifact's ``block_rows`` so delta tiles nest
    into the stored ones even after the table outgrows its original
    power-of-two bucket.

    ``k_top`` sizes the top-k output; callers that know collection will go
    dense (m_cap >= 16 * n1) pass 1 to skip the extract-max cost.  The
    numpy fallback makes the same single pass in ``block``-row chunks
    (np.histogram per chunk gives the count tiles for free); it has no
    top-k output, so collection rescans — but only the blocks the tiles
    flag.  Low-precision sweeps are tolerance-checked: the first row block
    is re-binned at fp32 and the whole sweep falls back to fp32 when the
    CDF deviation exceeds ``tolerance``.

    ``artifact`` (a :class:`repro.core.index.IndexArtifact`) skips the pass
    entirely and hydrates the stored sweep instead — bit-identical at fp32
    because the artifact is a prior pass's output; the artifact must cover
    exactly these tables and this binning config (checked).
    """
    from .similarity import pair_weights  # local import to avoid cycle

    if artifact is not None:
        artifact.check(sizes=(e1.shape[0], e2.shape[0]), n_bins=n_bins,
                       exponent=exponent, floor=floor)
        return artifact.sweep_info()
    tolerance = _precision_tolerance(precision, tolerance)
    if use_kernel:
        out = _kernel_sweep(e1, e2, n_bins, exponent, floor,
                            precision=precision, k_top=k_top,
                            block=kernel_block)
        if out is not None:
            info = SweepInfo(
                counts=out.counts, edges=out.edges,
                block_counts=out.block_counts, block_rows=out.block_rows,
                topk=(out.vals, out.idx, out.valid) if k_top >= 2 else None,
                kernel=True, precision=precision,
            )
            if precision == "fp32":
                # compensated fused walk sums (~1 f32 ulp of the f64
                # reference); lowp sums would leak quantisation error into
                # the HT weights, so those paths recompute instead
                info.row_sums = [out.row_sums]
                info.total_weight = float(out.row_sums.sum())
            if precision != "fp32":
                rows = min(info.block_rows, e1.shape[0])
                ref = _binned_counts(pair_weights(e1[:rows], e2, exponent, floor), n_bins)
                dev = _lowp_cdf_dev(ref, info.block_counts[0])
                info.stats["lowp_cdf_dev"] = dev
                if tolerance is not None and dev > tolerance:
                    import warnings

                    warnings.warn(
                        f"{precision} sweep CDF deviation {dev:.4f} exceeds "
                        f"tolerance {tolerance:.4f}; falling back to fp32"
                    )
                    info = sweep_pass(
                        e1, e2, n_bins, exponent, floor, block, use_kernel,
                        precision="fp32", k_top=k_top,
                        kernel_block=kernel_block,
                    )
                    info.stats["lowp_fallback"] = dev
            return info

    if precision != "fp32":
        _warn_lowp_unavailable(precision)
    edges = np.linspace(0.0, 1.0, n_bins + 1)
    n1 = e1.shape[0]
    tiles = []
    sums = []
    for s in range(0, n1, block):
        w = pair_weights(e1[s : s + block], e2, exponent, floor)
        c, _ = np.histogram(w, bins=edges)
        tiles.append(c.astype(np.int64))
        sums.append(w.sum(axis=1))  # f64: the walk sums come free here
    bc = np.stack(tiles) if tiles else np.zeros((1, n_bins), np.int64)
    row_sums = np.concatenate(sums) if sums else np.zeros(0, np.float64)
    return SweepInfo(
        counts=bc.sum(axis=0), edges=edges, block_counts=bc, block_rows=block,
        topk=None, kernel=False, precision="fp32",
        row_sums=[row_sums], total_weight=float(row_sums.sum()),
    )


def _prefix_chain_weights(embeddings, start, stop, exponent, floor):
    """Chain weights of prefix tuples [start, stop) in the row-major flat
    order of the *prefix* cross product (all tables but the last).  Returns
    (weights, last_prefix_table_indices)."""
    from .similarity import chain_tuple_weights, flat_to_tuples

    prefix_sizes = tuple(e.shape[0] for e in embeddings[:-1])
    flat = np.arange(start, stop, dtype=np.int64)
    tup = flat_to_tuples(flat, prefix_sizes)
    if len(prefix_sizes) == 1:
        return np.ones(len(flat), np.float64), tup[:, -1]
    wp = chain_tuple_weights(embeddings[:-1], tup, exponent, floor)
    return wp, tup[:, -1]


def sweep_pass_chain(
    embeddings: list,
    n_bins: int = 4096,
    exponent: float = 1.0,
    floor: float = 1e-3,
    block: int = 4096,
    use_kernel: bool = False,
    precision: str = "fp32",
    tolerance: Optional[float] = None,
    k_top: int = TOPK_CANDIDATES,
    artifact=None,
) -> SweepInfo:
    """k-way chain sweep: the geometric-mean chain weight W(t)**(1/(k-1)) is
    histogrammed over prefix blocks; each prefix block contributes one
    count tile, so chain collection can skip prefix blocks with no
    over-threshold mass.  At k=2 this is exactly :func:`sweep_pass`.
    ``artifact`` hydrates a stored sweep instead of computing (see
    :func:`sweep_pass`)."""
    from .similarity import pair_weights

    k = len(embeddings)
    if k == 2:
        return sweep_pass(
            embeddings[0], embeddings[1], n_bins, exponent, floor, block,
            use_kernel, precision, tolerance, k_top=k_top, artifact=artifact,
        )
    if artifact is not None:
        artifact.check(sizes=tuple(e.shape[0] for e in embeddings),
                       n_bins=n_bins, exponent=exponent, floor=floor)
        return artifact.sweep_info()
    tolerance = _precision_tolerance(precision, tolerance)
    root = 1.0 / (k - 1)
    e_prev, e_last = embeddings[-2], embeddings[-1]
    n_prefix = 1
    for e in embeddings[:-1]:
        n_prefix *= e.shape[0]
    edges = np.linspace(0.0, 1.0, n_bins + 1)
    tiles = []
    kernel_ok = use_kernel
    kernel_tiles = 0
    lowp_dev = None
    # walk statistics, fused into the same prefix sweeps: the last-edge row
    # sums r[i] = sum_c w_last(i, c) (every i in the last prefix table is
    # visited as i_last cycles the prefix cross product, duplicates rewrite
    # identical values) and the chain total sum_t wp(t) * r[i_last(t)]
    r_last = np.zeros(e_prev.shape[0], np.float64)
    total = 0.0
    right = None  # right table padded/quantised once, swept per prefix block
    if kernel_ok:
        right = _prepare_sweep_right(e_last, precision,
                                     n1_hint=min(block, n_prefix))
        kernel_ok = right is not None
    if not kernel_ok and precision != "fp32":
        _warn_lowp_unavailable(precision)
    for s in range(0, n_prefix, block):
        wp, i_last = _prefix_chain_weights(
            embeddings, s, min(s + block, n_prefix), exponent, floor
        )
        tile = None
        rs_blk = None
        if kernel_ok:
            # kernel bins max(clip(sim), floor)**(e*root) * scale —
            # exactly (wp * w_last)**root when scale = wp**root; the walk
            # sums ride along at the raw full exponent (rs_exponent)
            out = _kernel_sweep(
                e_prev[i_last], None, n_bins, exponent * root, floor,
                scale=wp**root, precision=precision, k_top=1, right=right,
                rs_exponent=exponent,
            )
            if out is None:
                kernel_ok = False
            else:
                tile = out.counts
                rs_blk = out.row_sums
                kernel_tiles += 1
                if precision != "fp32" and s == 0:
                    w = pair_weights(e_prev[i_last], e_last, exponent * root, floor)
                    ref = _binned_counts(wp[:, None] ** root * w, n_bins)
                    dev = lowp_dev = _lowp_cdf_dev(ref, tile)
                    if tolerance is not None and dev > tolerance:
                        import warnings

                        warnings.warn(
                            f"{precision} chain sweep CDF deviation {dev:.4f} "
                            f"exceeds tolerance {tolerance:.4f}; using fp32"
                        )
                        info = sweep_pass_chain(
                            embeddings, n_bins, exponent, floor, block,
                            use_kernel, precision="fp32",
                        )
                        info.stats["lowp_fallback"] = dev
                        return info
        if tile is None:
            w = pair_weights(e_prev[i_last], e_last, exponent, floor)
            rs_blk = w.sum(axis=1)
            v = (wp[:, None] * w) ** root
            c, _ = np.histogram(v, bins=edges)
            tile = c.astype(np.int64)
        total += float(wp @ rs_blk)
        r_last[i_last] = rs_blk
        tiles.append(tile)
    bc = np.stack(tiles) if tiles else np.zeros((1, n_bins), np.int64)
    # the precision label drives blocks_over's safety margin: any tile binned
    # at low precision makes the whole sweep low-precision for that purpose,
    # even if the kernel died mid-loop and later tiles are fp32
    used_lowp = kernel_tiles > 0 and precision != "fp32"
    info = SweepInfo(
        counts=bc.sum(axis=0), edges=edges, block_counts=bc, block_rows=block,
        topk=None, kernel=kernel_ok,
        precision=precision if used_lowp else "fp32",
    )
    if used_lowp and lowp_dev is not None:
        info.stats["lowp_cdf_dev"] = lowp_dev
    if kernel_tiles and not kernel_ok:
        info.stats["kernel_tiles"] = kernel_tiles
        info.stats["numpy_tiles"] = len(tiles) - kernel_tiles
    if not used_lowp:
        # earlier edges are small inter-table products (already paid inside
        # the prefix tuple weights); only the last cross-product edge was
        # ever expensive, and its sums were fused above
        from .similarity import edge_row_sums_raw

        info.row_sums = edge_row_sums_raw(embeddings[:-1], exponent,
                                          floor) + [r_last]
        info.total_weight = total
    return info


def weight_histogram(
    e1: np.ndarray,
    e2: np.ndarray,
    n_bins: int = 4096,
    exponent: float = 1.0,
    floor: float = 1e-3,
    block: int = 4096,
    use_kernel: bool = False,
) -> tuple[np.ndarray, np.ndarray]:
    """Two-pass baseline, pass 1: histogram of pair weights over the (never
    materialised) cross product.  Returns (counts[n_bins], edges[n_bins+1])
    with edges spanning [0, 1]."""
    from .similarity import pair_weights  # local import to avoid cycle

    if use_kernel:
        out = _kernel_hist(e1, e2, n_bins, exponent, floor)
        if out is not None:
            return out

    edges = np.linspace(0.0, 1.0, n_bins + 1)
    counts = np.zeros(n_bins, np.int64)
    n1 = e1.shape[0]
    for s in range(0, n1, block):
        w = pair_weights(e1[s : s + block], e2, exponent, floor)
        c, _ = np.histogram(w, bins=edges)
        counts += c
    return counts, edges


def chain_weight_histogram(
    embeddings: list,
    n_bins: int = 4096,
    exponent: float = 1.0,
    floor: float = 1e-3,
    block: int = 4096,
    use_kernel: bool = False,
) -> tuple[np.ndarray, np.ndarray]:
    """Two-pass baseline, pass 1 for k-way chains: histogram of the
    geometric-mean chain weight W(t)**(1/(k-1)), streamed over prefix blocks
    (O(block * Nk) peak memory).  At k=2 this is ``weight_histogram``."""
    from .similarity import pair_weights

    k = len(embeddings)
    if k == 2:
        return weight_histogram(
            embeddings[0], embeddings[1], n_bins, exponent, floor, block,
            use_kernel,
        )
    root = 1.0 / (k - 1)
    e_prev, e_last = embeddings[-2], embeddings[-1]
    n_prefix = 1
    for e in embeddings[:-1]:
        n_prefix *= e.shape[0]
    edges = np.linspace(0.0, 1.0, n_bins + 1)
    counts = np.zeros(n_bins, np.int64)
    for s in range(0, n_prefix, block):
        wp, i_last = _prefix_chain_weights(
            embeddings, s, min(s + block, n_prefix), exponent, floor
        )
        done = False
        if use_kernel:
            out = _kernel_hist(
                e_prev[i_last], e_last, n_bins, exponent * root, floor,
                scale=wp**root,
            )
            if out is not None:
                counts += out[0]
                done = True
        if not done:
            w = pair_weights(e_prev[i_last], e_last, exponent, floor)
            v = (wp[:, None] * w) ** root
            c, _ = np.histogram(v, bins=edges)
            counts += c
    return counts, edges


def threshold_for_top_m(counts: np.ndarray, edges: np.ndarray, m: int) -> float:
    """Largest bin edge t such that #weights >= t is >= m (CDF from the top).

    Edge cases: ``m <= 0`` returns the top edge (collect nothing below the
    maximum representable weight); ``m`` at or beyond the total mass — or an
    all-empty histogram — returns the bottom edge (collect everything)."""
    if m <= 0:
        return float(edges[-1])
    csum = np.cumsum(counts[::-1])[::-1]  # csum[i] = #weights in bins >= i
    ok = np.nonzero(csum >= m)[0]
    if len(ok) == 0:
        return float(edges[0])
    return float(edges[ok[-1]])


def _try_sim_topk(e1, e2, k):
    """sim_topk kernel call, or None -> dense scan."""
    return _kernel_op("repro.kernels.sim_topk.ops", "sim_topk", e1, e2, k=k)


def _collect_from_topk(e1, e2, vals, idx, valid, threshold, exponent, floor,
                       stats=None):
    """Over-threshold collection from per-row top-k candidates.

    Any row whose last candidate still clears the threshold may have been
    truncated at the candidate budget; truncated rows get ONE retry at 4x
    the budget (``sim_topk`` with a raised k) and rows that saturate even
    that are rescanned exactly — so no pair is ever silently dropped and
    the full product is never rescanned.  Returns (flat_idx, weights)."""
    from .similarity import pair_weights, weight_of_score

    n1, n2 = e1.shape[0], e2.shape[0]
    kk = vals.shape[1]
    w_vals = weight_of_score(np.asarray(vals, np.float64), exponent, floor)
    keep = (w_vals >= threshold) & valid
    if kk < n2:  # a row's hits may have been truncated at kk candidates
        saturated = np.nonzero(w_vals[:, -1] >= threshold)[0]
    else:
        saturated = np.empty(0, np.int64)
    keep[saturated] = False
    r, c = np.nonzero(keep)
    flat = [r.astype(np.int64) * n2 + idx[r, c]]
    wts = [w_vals[r, c]]
    if len(saturated):
        k2 = min(max(4 * kk, 128), n2)
        # a deep threshold saturates most rows; the retry would likely
        # saturate too, so go straight to the exact rescan
        retry_pays = len(saturated) <= n1 // 4
        out = _try_sim_topk(e1[saturated], e2, k2) if k2 > kk and retry_pays else None
        if out is not None:
            v2, i2, valid2 = out
            w2 = weight_of_score(np.asarray(v2, np.float64), exponent, floor)
            keep2 = (w2 >= threshold) & valid2
            if v2.shape[1] < n2:
                still = np.nonzero(w2[:, -1] >= threshold)[0]
            else:
                still = np.empty(0, np.int64)
            keep2[still] = False
            r2, c2 = np.nonzero(keep2)
            flat.append(saturated[r2].astype(np.int64) * n2 + i2[r2, c2])
            wts.append(w2[r2, c2])
            if stats is not None:
                stats["topk_retry_rows"] = int(len(saturated))
            saturated = saturated[still]
        if stats is not None:
            stats["dense_rescan_rows"] = int(len(saturated))
        if len(saturated):
            w = pair_weights(e1[saturated], e2, exponent, floor)
            rr, cc = np.nonzero(w >= threshold)
            flat.append(saturated[rr].astype(np.int64) * n2 + cc)
            wts.append(w[rr, cc])
    return np.concatenate(flat), np.concatenate(wts)


def _collect_top_pairs_topk(e1, e2, threshold, exponent, floor, stats=None):
    """Two-pass baseline: run sim_topk now, then collect (see
    :func:`_collect_from_topk`).  None when the kernel is unavailable."""
    out = _try_sim_topk(e1, e2, k=min(TOPK_CANDIDATES, e2.shape[0]))
    if out is None:
        return None
    vals, idx, valid = out
    return _collect_from_topk(
        e1, e2, vals, idx, valid, threshold, exponent, floor, stats=stats
    )


def collect_top(
    e1: np.ndarray,
    e2: np.ndarray,
    threshold: float,
    m_cap: int,
    exponent: float = 1.0,
    floor: float = 1e-3,
    block: int = 4096,
    use_kernel: bool = False,
    sweep: Optional[SweepInfo] = None,
    return_weights: bool = False,
):
    """Collect flat indices of pairs with weight >= threshold, sorted by
    weight descending, truncated to m_cap.

    With a :class:`SweepInfo` the candidates come straight from the sweep's
    top-k output (no second kernel pass) and any rescan — truncated rows,
    or the whole collection on the fallback path — touches only the row
    blocks whose count tiles show over-threshold mass."""
    from .similarity import pair_weights

    n1, n2 = e1.shape[0], e2.shape[0]
    stats = sweep.stats if sweep is not None else None
    if m_cap < 16 * n1:
        out = None
        if sweep is not None and sweep.topk is not None:
            vals, idx, valid = sweep.topk
            out = _collect_from_topk(
                e1, e2, vals, idx, valid, threshold, exponent, floor,
                stats=stats,
            )
        elif use_kernel:
            out = _collect_top_pairs_topk(e1, e2, threshold, exponent, floor,
                                          stats=stats)
        if out is not None:
            idx, w = out
            order = np.argsort(w)[::-1][:m_cap]
            if return_weights:
                return idx[order], w[order]
            return idx[order]

    idx_chunks, w_chunks = [], []
    if sweep is not None:
        starts, step = sweep.rescan_starts(threshold, n1)
    else:
        starts, step = list(range(0, n1, block)), block
    for s in starts:
        w = pair_weights(e1[s : s + step], e2, exponent, floor)
        r, c = np.nonzero(w >= threshold)
        idx_chunks.append(((r + s).astype(np.int64) * n2 + c))
        w_chunks.append(w[r, c])
    idx = np.concatenate(idx_chunks) if idx_chunks else np.empty(0, np.int64)
    w = np.concatenate(w_chunks) if w_chunks else np.empty(0, np.float64)
    order = np.argsort(w)[::-1][:m_cap]
    if return_weights:
        return idx[order], w[order]
    return idx[order]


def collect_top_chain(
    embeddings: list,
    threshold_root: float,
    m_cap: int,
    exponent: float = 1.0,
    floor: float = 1e-3,
    block: int = 4096,
    use_kernel: bool = False,
    sweep: Optional[SweepInfo] = None,
    return_weights: bool = False,
):
    """Flat indices (over the full k-way cross product, row-major) of tuples
    whose geometric-mean chain weight clears ``threshold_root``, sorted by
    chain weight descending, truncated to m_cap.  With a chain sweep, prefix
    blocks whose count tiles show no over-threshold mass are skipped."""
    from .similarity import pair_weights

    k = len(embeddings)
    if k == 2:
        return collect_top(
            embeddings[0], embeddings[1], threshold_root, m_cap, exponent,
            floor, block, use_kernel, sweep=sweep, return_weights=return_weights,
        )
    thr_w = threshold_root ** (k - 1)  # back to raw chain-weight space
    e_prev, e_last = embeddings[-2], embeddings[-1]
    n_last = e_last.shape[0]
    n_prefix = 1
    for e in embeddings[:-1]:
        n_prefix *= e.shape[0]
    if sweep is not None:
        starts, step = sweep.rescan_starts(threshold_root, n_prefix)
    else:
        starts, step = list(range(0, n_prefix, block)), block
    idx_chunks, w_chunks = [], []
    for s in starts:
        wp, i_last = _prefix_chain_weights(
            embeddings, s, min(s + step, n_prefix), exponent, floor
        )
        w = wp[:, None] * pair_weights(e_prev[i_last], e_last, exponent, floor)
        r, c = np.nonzero(w >= thr_w)
        idx_chunks.append((r + s).astype(np.int64) * n_last + c)
        w_chunks.append(w[r, c])
    idx = np.concatenate(idx_chunks) if idx_chunks else np.empty(0, np.int64)
    w = np.concatenate(w_chunks) if w_chunks else np.empty(0, np.float64)
    order = np.argsort(w)[::-1][:m_cap]
    if return_weights:
        return idx[order], w[order]
    return idx[order]


def stratify_streaming_chain(
    embeddings: list,
    alpha: float,
    budget: int,
    cfg: BASConfig,
    n_bins: int = 4096,
    use_kernel: bool = False,
    use_sweep: Optional[bool] = None,
    precision: Optional[str] = None,
    artifact=None,
) -> Stratification:
    """Histogram-thresholded stratification of a k-way chain; equal-size
    strata like the dense path but the threshold (hence membership at the
    boundary) is bin-resolution approximate.  Strata remain exactly
    equal-sized; only *which* borderline tuples land in D_K vs D_0 can differ
    — the estimator stays unbiased because stratum membership is
    deterministic given the data.

    ``use_sweep`` (default from ``cfg.use_sweep``) runs the fused
    single-sweep path; ``use_sweep=False`` keeps the two-pass
    histogram-then-collect baseline, which is bit-identical at fp32.
    ``precision`` opts the sweep into the bf16/int8 fast path (default from
    ``cfg.sweep_precision``), tolerance-gated via ``cfg.sweep_tolerance``.
    ``artifact`` (:class:`repro.core.index.IndexArtifact`) hydrates a
    persisted sweep instead of computing one — threshold selection and
    collection run unchanged against the loaded tiles/top-k."""
    if use_sweep is None:
        use_sweep = cfg.use_sweep
    if precision is None:
        precision = cfg.sweep_precision
    n = 1
    for e in embeddings:
        n *= e.shape[0]
    m = min(int(round(alpha * budget)), n)
    k = auto_num_strata(alpha, budget, cfg)
    k = max(1, min(k, m)) if m > 0 else 0
    if m == 0:
        return Stratification(np.empty(0, np.int64), np.zeros(1, np.int64), n)
    sweep = None
    if artifact is not None:
        sweep = sweep_pass_chain(
            embeddings, n_bins, cfg.weight_exponent, cfg.weight_floor,
            artifact=artifact,
        )
        counts, edges = sweep.counts, sweep.edges
    elif use_sweep:
        # collection only consults the top-k when the blocking regime is
        # sparse per row (see collect_top); otherwise skip its epilogue cost
        n1 = embeddings[0].shape[0]
        k_top = TOPK_CANDIDATES if (len(embeddings) == 2 and m < 16 * n1) else 1
        sweep = sweep_pass_chain(
            embeddings, n_bins, cfg.weight_exponent, cfg.weight_floor,
            use_kernel=use_kernel, precision=precision,
            tolerance=cfg.sweep_tolerance, k_top=k_top,
        )
        counts, edges = sweep.counts, sweep.edges
    else:
        counts, edges = chain_weight_histogram(
            embeddings, n_bins, cfg.weight_exponent, cfg.weight_floor,
            use_kernel=use_kernel,
        )
    thr = threshold_for_top_m(counts, edges, m)
    order, order_w = collect_top_chain(
        embeddings, thr, m, cfg.weight_exponent, cfg.weight_floor,
        use_kernel=use_kernel, sweep=sweep, return_weights=True,
    )
    m_eff = len(order)
    k = max(1, min(k, m_eff))
    bounds = np.round(np.linspace(0, m_eff, k + 1)).astype(np.int64)
    return Stratification(
        order=order, bounds=bounds, n_total=n, order_weights=order_w,
        sweep=sweep,
    )


def stratify_streaming(
    e1: np.ndarray,
    e2: np.ndarray,
    alpha: float,
    budget: int,
    cfg: BASConfig,
    n_bins: int = 4096,
    use_kernel: bool = False,
    use_sweep: Optional[bool] = None,
    precision: Optional[str] = None,
    artifact=None,
) -> Stratification:
    """Two-table wrapper of :func:`stratify_streaming_chain`."""
    return stratify_streaming_chain(
        [e1, e2], alpha, budget, cfg, n_bins=n_bins, use_kernel=use_kernel,
        use_sweep=use_sweep, precision=precision, artifact=artifact,
    )
