"""Multi-fidelity oracle cascade with a guarantee-preserving correction.

The paper splits the cross product into regimes by embedding failure mode
and spends the Oracle budget where it matters; this module lifts that move
one level up the model stack.  A cheap *proxy* oracle (a thresholded
similarity score, a small distilled scorer, or the bf16/int8 fast path of
the served model) labels broadly, and the expensive Oracle pays only for a
difference-estimator correction — the two-regime tradition of "Joins on
Samples" composed with BAS stratification:

    AGG-hat = blocked + sum_i [ mean(g * p / q)        (proxy regime)
                              + mean(g * (o - p) / q) ] (correction regime)

Per sampled stratum, two *independent* within-stratum samples are drawn
from the same exact distribution ``q``:

* the **proxy sample** (``cascade_proxy_factor * b`` cheap rows, split
  ∝ weight mass): every row labelled only by the proxy, giving a
  low-variance HT estimate of the proxy total;
* the **correction sample** (the expensive budget ``b``): every row
  labelled by *both* oracles, HT-estimating the proxy's total signed error
  ``sum g * (o - p)``.

Each is an unbiased HT estimator of its regime's total, so their sum is
unbiased for the stratum total regardless of proxy quality — a perfect
proxy drives the correction terms (and their variance) to zero, a garbage
proxy degrades to plain-BAS-order variance, never to bias.  Both samples
are plain :class:`~repro.core.estimators.StratumSample` objects (the
correction sample simply carries ``o - p`` in the label slot), so the
variance formula and CI assembly are *exactly* the existing machinery:
``combined_sum``/``combined_count``/``combined_avg`` over the
pseudo-stratum list and within-stratum bootstrap-t resampling
(``bootstrap.bootstrap_t_ci``).  Guarantees are preserved by construction.

Budget semantics: the §2 contract ("the Oracle is executed on at most ``b``
tuples") binds the *expensive* oracle only — its ledger paces pilot,
blocking, and correction rounds exactly like plain BAS.  The proxy runs on
its own unmetered ledger (``QueryResult.detail["cascade"]`` reports both).

Pipeline (mirrors ``bas.run_stratified_pipeline`` stage for stage):

1. *Stratify*: the dense or streaming stage-1 builder — shared code
   (``bas.build_dense_space`` / ``bas_streaming.build_streaming_space``).
2. *Pilot* (expensive budget ``b1``): sample every stratum ∝ weight, label
   with both oracles, estimate the per-stratum variance of the linearised
   *correction* terms (the disagreement signal).
3. *Allocate*: ``allocate.argmin_beta`` on the correction variances — the
   expensive oracle blocks the strata where the proxy is untrustworthy and
   cheap sampling cannot fix it.
4. *Execute*: blocked strata are oracle-labelled exhaustively; sampled
   strata get the proxy sample plus correction top-up rounds whose
   per-stratum split follows a defensive Neyman rule on the pilot
   disagreement variances (the "spend the oracle where the correction
   needs it" step).
5. *Estimate + CI*: bootstrap-t over the proxy + correction pseudo-strata.

Serving integration: the proxy is a distinct :class:`~repro.core.oracle`
instance, so its :meth:`~repro.core.oracle.Oracle.service_group` key never
collides with the expensive oracle's — through an
:class:`~repro.serve.oracle_service.OracleService` the two stages
super-batch *independently* per window, and shared
:class:`~repro.serve.label_store.LabelStore` segments (keyed by group +
encoding) keep proxy and oracle labels separate by construction.  A proxy
built by :func:`similarity_proxy` carries a content-fingerprinted group
name, so concurrent queries over the same tables fuse their proxy traffic
and may share stored proxy labels safely.
"""
from __future__ import annotations

import time
from typing import Optional

import numpy as np

from . import allocate as alloc_mod
from .bas import (
    StratifiedSpace,
    StratumDraw,
    _linearised_variance,
    build_dense_space,
    run_bas,
    run_exact,
)
from .bootstrap import bootstrap_t_ci
from .estimators import BlockedRegime, StratumSample, combined_count, combined_sum
from .oracle import FnOracle, Oracle, OracleBatch
from .similarity import chain_tuple_weights
from .types import Agg, BASConfig, JoinSpec, Query, QueryResult


class SimilarityProxyOracle(FnOracle):
    """The embedding proxy as an Oracle: label = chain weight >= threshold.

    ``name`` pins a *stable* service group (``("scorer", "sim-proxy:<fp>",
    threshold)``): proxies for the same tables fuse into one super-batch per
    service window and may share label-store segments — safe because the
    fingerprint binds the name to the embedding content."""

    def __init__(self, fn, threshold: float, name: Optional[str] = None):
        super().__init__(fn)
        self.threshold = float(threshold)
        self.name = name

    def service_group(self):
        if self.name is not None:
            return ("scorer", f"sim-proxy:{self.name}", self.threshold)
        return super().service_group()


def similarity_proxy(
    spec: JoinSpec,
    cfg: Optional[BASConfig] = None,
    threshold: Optional[float] = None,
) -> SimilarityProxyOracle:
    """The zero-extra-model proxy: thresholded chain similarity weight.

    This is the paper's cheap signal reused as a labelling stage — the same
    ``w = max(clip(cos, 0, 1), floor) ** exponent`` weights that drive
    sampling, thresholded into a {0,1} proxy label.  O(n * k * d) per batch,
    no model call."""
    cfg = cfg or BASConfig()
    tau = cfg.cascade_proxy_threshold if threshold is None else float(threshold)
    embeddings = [np.asarray(e, np.float32) for e in spec.embeddings]
    exp, floor = cfg.weight_exponent, cfg.weight_floor

    def fn(idx: np.ndarray) -> np.ndarray:
        w = chain_tuple_weights(embeddings, idx, exp, floor)
        return (w >= tau ** (len(embeddings) - 1)).astype(np.float64)

    import hashlib

    h = hashlib.sha256()
    for e in embeddings:
        h.update(str(e.shape).encode())
        h.update(np.ascontiguousarray(e[:: max(len(e) // 8, 1)]).tobytes())
    return SimilarityProxyOracle(fn, tau, name=h.hexdigest()[:16])


def _label_both(query: Query, proxy: Oracle, draws: list) -> tuple:
    """Label one stage's draws with BOTH oracles: one coalesced batch per
    fidelity (distinct service groups — through a service the two flushes
    land in the same window but super-batch independently), submit-then-await
    with the cheap g(.) evaluation overlapping both.

    Returns ``(corr_samples, o_list, p_list)`` where ``corr_samples[i]`` is
    the correction pseudo-sample (label slot = ``o - p``)."""
    ob, pb = OracleBatch(query.oracle), OracleBatch(proxy)
    oh = [None if d is None else ob.submit(d.tup) for d in draws]
    ph = [None if d is None else pb.submit(d.tup) for d in draws]
    fo, fp = ob.flush_async(), pb.flush_async()
    g = query.attr()
    gs = [None if d is None else g(d.tup) for d in draws]
    fo.result()
    fp.result()
    corr, o_list, p_list = [], [], []
    for d, ho, hp, gv in zip(draws, oh, ph, gs):
        if d is None:
            corr.append(None)
            o_list.append(None)
            p_list.append(None)
            continue
        o, p = ho.labels, hp.labels
        corr.append(StratumSample(o=o - p, g=gv, q=d.q, size=d.size))
        o_list.append(o)
        p_list.append(p)
    return corr, o_list, p_list


def _label_proxy(proxy: Oracle, query: Query, draws: list) -> list:
    """Proxy-only labelling of one stage's draws (one coalesced batch)."""
    batch = OracleBatch(proxy)
    handles = [None if d is None else batch.submit(d.tup) for d in draws]
    fut = batch.flush_async()
    g = query.attr()
    gs = [None if d is None else g(d.tup) for d in draws]
    fut.result()
    return [
        None if d is None else StratumSample(o=h.labels, g=gv, q=d.q, size=d.size)
        for d, h, gv in zip(draws, handles, gs)
    ]


def _split_budget(total: int, shares: np.ndarray, floor_n: int = 1) -> np.ndarray:
    """Split ``total`` rows ∝ shares with a per-stratum floor, trimmed so the
    split never exceeds the total (same discipline as the pilot split in
    ``run_stratified_pipeline``)."""
    n = np.maximum((shares * total).astype(np.int64), floor_n)
    while n.sum() > total and n.max() > floor_n:
        n[np.argmax(n)] -= 1
    return n


def run_cascade_pipeline(
    query: Query,
    proxy: Oracle,
    cfg: BASConfig,
    rng: np.random.Generator,
    space: StratifiedSpace,
    detail: dict,
    timings: dict,
    t_start: float,
) -> QueryResult:
    """Stages 2-5 of the cascade on an abstract stratified space (dense and
    streaming regimes share this code exactly like plain BAS shares
    ``run_stratified_pipeline``)."""
    sizes, weight_sums = space.sizes, space.weight_sums
    k = len(sizes) - 1
    b = query.budget
    b1 = max(int(round(cfg.pilot_fraction * b)), 8)

    # ---- stage 1: pilot (both fidelities on the same draws) ---------------
    t0 = time.perf_counter()
    shares = weight_sums / max(weight_sums.sum(), 1e-300)
    n_pilot = _split_budget(b1, shares, floor_n=2)
    pilot_draws: list[Optional[StratumDraw]] = [None] * (k + 1)
    for i in range(k + 1):
        if sizes[i] > 0:
            pilot_draws[i] = space.sample_stratum(i, int(n_pilot[i]))
    corr, o_list, p_list = _label_both(query, proxy, pilot_draws)

    # linearisation constants (AVG influence function) from the pilot's
    # expensive labels; the pilot's proxy labels feed the disagreement stats
    pilot_plain = [
        StratumSample(o=o, g=corr[i].g, q=corr[i].q, size=corr[i].size)
        for i, o in enumerate(o_list) if o is not None
    ]
    zero = BlockedRegime(np.zeros(0), np.zeros(0))
    c_hat, _ = combined_count(pilot_plain, zero)
    s_hat, _ = combined_sum(pilot_plain, zero)
    ratio = s_hat / c_hat if c_hat > 0 else 0.0
    sigma2 = np.zeros(k + 1, np.float64)
    for i in range(k + 1):
        if corr[i] is not None:
            sigma2[i] = _linearised_variance(corr[i], query.agg, ratio, c_hat)
    n_dis = sum(len(o) for o in o_list if o is not None)
    disagree = sum(
        float(np.abs(o - p).sum())
        for o, p in zip(o_list, p_list) if o is not None
    ) / max(n_dis, 1)
    timings["pilot_s"] = time.perf_counter() - t0

    # ---- allocation on the correction variances ---------------------------
    t0 = time.perf_counter()
    b2_eff = b - query.oracle.calls
    allocation = alloc_mod.argmin_beta(
        sigma2, weight_sums, sizes, b2_eff, cfg.exact_beta_max_k
    )
    beta = set(int(i) for i in allocation.beta)
    timings["allocate_s"] = time.perf_counter() - t0

    # ---- stage 2: blocking + proxy sample + correction rounds -------------
    t0 = time.perf_counter()
    block_batch = OracleBatch(query.oracle)
    beta_tuples = [(i, space.stratum_tuples(i)) for i in sorted(beta)]
    beta_handles = [block_batch.submit(tup) for _, tup in beta_tuples]
    block_fut = block_batch.flush_async()
    g_fn = query.attr()
    blocked_g = [g_fn(tup) for _, tup in beta_tuples]
    block_fut.result()
    blocked = BlockedRegime(
        o=np.concatenate([h.labels for h in beta_handles])
        if beta_handles else np.zeros(0),
        g=np.concatenate(blocked_g) if blocked_g else np.zeros(0),
    )

    sampled_ids = [i for i in range(k + 1) if i not in beta and sizes[i] > 0]
    w_s = np.array([weight_sums[i] for i in sampled_ids])
    w_share = w_s / max(w_s.sum(), 1e-300)

    # proxy regime: a large cheap sample, split ∝ weight mass (disjoint from
    # the correction sample — the two pseudo-strata must stay independent)
    proxy_samples: list[Optional[StratumSample]] = [None] * (k + 1)
    n_proxy_total = int(cfg.cascade_proxy_factor * b)
    if sampled_ids and n_proxy_total > 0:
        n_proxy = _split_budget(n_proxy_total, w_share, floor_n=2)
        proxy_draws: list[Optional[StratumDraw]] = [None] * (k + 1)
        for j, i in enumerate(sampled_ids):
            proxy_draws[i] = space.sample_stratum(i, int(n_proxy[j]))
        proxy_samples = _label_proxy(proxy, query, proxy_draws)

    # correction regime: defensive Neyman split on the pilot disagreement
    # variances — n_i ∝ sqrt(sigma2_i), mixed with the weight share so a
    # stratum whose pilot saw no disagreement still gets a trickle (the
    # pilot variance estimate is noisy, not a certificate)
    root = np.array([np.sqrt(max(sigma2[i], 0.0)) for i in sampled_ids])
    if root.sum() > 0:
        c_share = 0.8 * root / root.sum() + 0.2 * w_share
    else:
        c_share = w_share
    rounds = 0
    while rounds < 4 and sampled_ids:
        remaining = b - query.oracle.calls
        if remaining < 2 * len(sampled_ids):
            break
        n_main = _split_budget(remaining, c_share, floor_n=1)
        before = query.oracle.calls
        round_draws: list[Optional[StratumDraw]] = [None] * (k + 1)
        for j, i in enumerate(sampled_ids):
            if n_main[j] > 0:
                round_draws[i] = space.sample_stratum(i, int(n_main[j]))
        round_corr, _, _ = _label_both(query, proxy, round_draws)
        for i in sampled_ids:
            new = round_corr[i]
            if new is not None:
                corr[i] = new if corr[i] is None else corr[i].merge(new)
        rounds += 1
        if query.oracle.calls == before:   # fully cached; budget cannot move
            break
    timings["execute_s"] = time.perf_counter() - t0

    # ---- estimate + CI: proxy + correction pseudo-strata ------------------
    t0 = time.perf_counter()
    live = [proxy_samples[i] for i in sampled_ids
            if proxy_samples[i] is not None]
    corr_live = [corr[i] for i in sampled_ids if corr[i] is not None]
    live += corr_live
    est, ci = bootstrap_t_ci(
        live, blocked, query.agg, query.confidence, cfg.n_bootstrap, rng
    )
    timings["ci_s"] = time.perf_counter() - t0
    timings["total_s"] = time.perf_counter() - t_start

    proxy_rows = sum(
        s.n for s in (proxy_samples[i] for i in sampled_ids) if s is not None
    )
    return QueryResult(
        estimate=float(est),
        ci=ci,
        oracle_calls=query.oracle.calls,
        detail={
            **detail,
            **({"stratify": space.meta} if space.meta else {}),
            "beta": sorted(beta),
            "num_strata": k,
            "stratum_sizes": sizes.tolist(),
            "pilot_n": n_pilot.tolist(),
            "est_mse": allocation.est_mse,
            "timings": timings,
            "oracle": query.oracle.stats(),
            "cascade": {
                "proxy_calls": proxy.calls,
                "proxy_requests": proxy.requests,
                "oracle_calls": query.oracle.calls,
                "proxy_rows": int(proxy_rows),
                "correction_rows": int(sum(s.n for s in corr_live)),
                "disagreement_rate": float(disagree),
                "proxy_group": repr(proxy.service_group()),
                "oracle_group": repr(query.oracle.service_group()),
            },
        },
    )


def run_bas_cascade(
    query: Query,
    cfg: Optional[BASConfig] = None,
    seed: int = 0,
    proxy: Optional[Oracle] = None,
    weights: Optional[np.ndarray] = None,
    path: Optional[str] = None,
    n_bins: int = 4096,
    artifact=None,
    index_store=None,
) -> QueryResult:
    """Two-stage cascade BAS.  ``proxy`` (or ``query.proxy``) is the cheap
    oracle; defaults to the thresholded-similarity proxy.  ``path`` forces
    the stage-1 regime (``"dense"`` | ``"streaming"``); by default the same
    memory model as ``dispatch.run_auto`` decides.  Non-linear aggregates
    (MIN/MAX/MEDIAN) have no difference decomposition and fall back to plain
    BAS on the chosen path."""
    cfg = cfg or BASConfig()
    rng = np.random.default_rng(seed)
    t_start = time.perf_counter()
    timings: dict = {}

    query.oracle.set_budget(query.budget)
    query.oracle.bind_sizes(query.spec.sizes)
    if query.budget >= query.spec.n_tuples:
        return run_exact(query)

    from .dispatch import choose_path

    if path is None:
        path = choose_path(query.spec, cfg)
    if query.agg not in (Agg.COUNT, Agg.SUM, Agg.AVG):
        if path == "dense":
            return run_bas(query, cfg, seed=seed, weights=weights)
        from .bas_streaming import run_bas_streaming

        return run_bas_streaming(
            query, cfg, seed=seed, n_bins=n_bins, artifact=artifact,
            index_store=index_store,
        )

    proxy = proxy if proxy is not None else query.proxy
    if proxy is None:
        proxy = similarity_proxy(query.spec, cfg)
    proxy.set_budget(None)          # the §2 budget binds the expensive oracle
    proxy.bind_sizes(query.spec.sizes)
    # through a service, route the proxy stage too (its own group + class) so
    # proxy traffic super-batches independently and lands in the per-class
    # telemetry; a plain local oracle keeps the proxy local as well
    svc = getattr(query.oracle, "service", None)
    attached = False
    if svc is not None and getattr(proxy, "service", None) is None:
        svc.attach(proxy, query_class="cascade-proxy")
        attached = True

    try:
        if path == "dense":
            space = build_dense_space(query, cfg, rng, timings, weights)
            detail = {"mode": "bas-cascade"}
        else:
            from .bas_streaming import build_streaming_space

            space, extra = build_streaming_space(
                query, cfg, rng, timings, n_bins=n_bins, artifact=artifact,
                index_store=index_store,
            )
            detail = {"mode": "bas-cascade", **extra}
        return run_cascade_pipeline(
            query, proxy, cfg, rng, space, detail, timings, t_start
        )
    finally:
        if attached:
            svc.detach(proxy)


__all__ = [
    "SimilarityProxyOracle",
    "run_bas_cascade",
    "run_cascade_pipeline",
    "similarity_proxy",
]
