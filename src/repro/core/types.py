"""Core datatypes for the JoinML-X query engine.

The vocabulary follows the paper: a *join spec* is a chain join over k tables of
unstructured records, each record represented by a unit-normalised embedding
vector.  The *Oracle* labels k-tuples (expensive); *similarity* scores are the
cheap proxy.  A query asks for an aggregate over the joined tuples with an
Oracle budget ``b`` and a CI coverage probability ``p``.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Callable, Optional, Sequence

import numpy as np


class Agg(enum.Enum):
    COUNT = "count"
    SUM = "sum"
    AVG = "avg"
    MIN = "min"
    MAX = "max"
    MEDIAN = "median"


@dataclasses.dataclass(frozen=True)
class ConfidenceInterval:
    lo: float
    hi: float
    p: float  # nominal coverage

    @property
    def width(self) -> float:
        return float(self.hi - self.lo)

    def contains(self, value: float) -> bool:
        return bool(self.lo <= value <= self.hi)


class QueryResult:
    """One query's answer plus typed execution telemetry.

    ``telemetry`` (a :class:`repro.obs.QueryTelemetry`) is the source of
    truth for everything the pipeline recorded — which path ran, timings,
    ledger counters, index/store accounting.  The legacy ``detail`` dict is
    kept as a deprecated write-through *view* of that tree: constructing with
    ``detail={...}`` parses into the tree, and ``result.detail[...]`` reads
    and writes through it, so pre-redesign callers keep working.
    """

    __slots__ = ("estimate", "ci", "oracle_calls", "telemetry")

    def __init__(self, estimate: float, ci: ConfidenceInterval,
                 oracle_calls: int, detail: Optional[dict] = None,
                 telemetry: Optional["QueryTelemetry"] = None):  # noqa: F821
        from repro.obs.telemetry import QueryTelemetry

        self.estimate = estimate
        self.ci = ci
        self.oracle_calls = oracle_calls
        if telemetry is None:
            telemetry = QueryTelemetry.from_detail(detail)
        elif detail:
            raise TypeError("pass either detail= or telemetry=, not both")
        self.telemetry = telemetry

    @property
    def detail(self) -> "TelemetryView":  # noqa: F821 (repro.obs.telemetry)
        """Deprecated dict view of :attr:`telemetry` (reads/writes through)."""
        from repro.obs.telemetry import TelemetryView, _warn_detail_deprecated

        _warn_detail_deprecated()
        return TelemetryView(self.telemetry)

    def __repr__(self) -> str:
        return (f"QueryResult(estimate={self.estimate!r}, ci={self.ci!r}, "
                f"oracle_calls={self.oracle_calls!r})")

    def error_ratio(self, truth: float) -> float:
        """Paper §7.2 metric: |mu_hat - mu| / (CI half width)."""
        half = self.ci.width / 2.0
        if half <= 0:
            return float("inf") if abs(self.estimate - truth) > 0 else 0.0
        return abs(self.estimate - truth) / half


@dataclasses.dataclass(frozen=True)
class BASConfig:
    """Hyper-parameters of Blocking-augmented Sampling (paper Alg. 4 / §5.5)."""

    alpha: float = 0.2            # maximum blocking ratio (top alpha*b pairs)
    pilot_fraction: float = 0.2   # b1 = pilot_fraction * b, b2 = rest
    min_strata: int = 5           # paper §5.5: enforce K >= 5 for small budgets
    max_strata: int = 64
    budget_per_stratum: int = 1000  # paper: auto-K so each stratum gets >= 1000
    weight_exponent: float = 1.0  # Fig. 13b: sampling weight = sim ** exponent
    weight_floor: float = 1e-3    # defensive-mixture floor: keeps every tuple
                                  # reachable at feasible budgets (a 1e-6 floor
                                  # is "unbiased" but its HT tail is unsampleable,
                                  # silently reintroducing the FN bias of blocking)
    n_bootstrap: int = 1000       # paper: 1000 resamples
    exact_beta_max_k: int = 16    # exhaustive subset search limit for beta*
    avg_bias_correction: bool = True  # Eq. (3) Taylor correction
    max_dense_weight_bytes: int = 256 * 2**20
                                  # engine dispatch threshold: the dense BAS
                                  # path materialises an (N1*...*Nk,) float64
                                  # chain-weight array; when that footprint
                                  # exceeds this cap, run_auto routes to the
                                  # streaming path (O(N + alpha*b) memory)
    use_kernel: bool = True       # streaming stratification: use the fused
                                  # Pallas kernels (falls back to blocked
                                  # jnp/numpy when unavailable)
    use_sweep: bool = True        # fuse the stratification passes into ONE
                                  # sim_sweep kernel launch (histogram +
                                  # top-k + per-block count tiles); False
                                  # keeps the two-pass sim_hist + sim_topk
                                  # schedule (bit-identical at fp32)
    sweep_precision: str = "fp32"  # opt-in low-precision sweep: "bf16"
                                  # (bf16 MXU inputs, f32 accumulation) or
                                  # "int8" (per-row-quantised embeddings,
                                  # int32 accumulation); only the strata
                                  # boundaries move — HT estimates stay
                                  # unbiased (membership is deterministic)
    sweep_tolerance: Optional[float] = None
                                  # max CDF shift tolerated from a
                                  # low-precision sweep before it falls back
                                  # to fp32; None uses the documented
                                  # per-precision default from
                                  # configs.joinml_embedder.EMBEDDING_PRECISIONS
    defensive_mix: float = 0.2    # within-stratum sampling = (1-mix)*importance
                                  # + mix*uniform (Hesterberg defensive IS):
                                  # caps HT weights at |D_i|/mix, bounding the
                                  # variance blow-up when false negatives hide
                                  # at near-floor similarity (beyond-paper)
    cascade: bool = False         # multi-fidelity cascade (core/cascade.py):
                                  # a cheap proxy oracle labels broadly, the
                                  # expensive Oracle pays only for the
                                  # difference-estimator correction; run_auto
                                  # routes through it for linear aggregates
                                  # when a proxy is available
    cascade_proxy_factor: float = 4.0
                                  # proxy-stage sample rows per unit of
                                  # (expensive) oracle budget: the proxy term
                                  # is HT-estimated from factor*b cheap draws
    cascade_proxy_threshold: float = 0.5
                                  # default similarity-proxy decision
                                  # threshold on the chain weight (used when
                                  # no explicit proxy oracle is supplied)


@dataclasses.dataclass
class JoinSpec:
    """A chain join over ``k`` tables.

    embeddings: per-table (N_i, d) unit-normalised float arrays.  Consecutive
    tables must share embedding dimensionality (chain-join semantics).
    """

    embeddings: Sequence[np.ndarray]

    def __post_init__(self):
        assert len(self.embeddings) >= 2, "need at least two tables"

    @property
    def k(self) -> int:
        return len(self.embeddings)

    @property
    def sizes(self) -> tuple:
        return tuple(int(e.shape[0]) for e in self.embeddings)

    @property
    def n_tuples(self) -> int:
        out = 1
        for n in self.sizes:
            out *= n
        return out


# g(.) — attribute to aggregate over; receives (n, k) int32 tuple indices.
AttrFn = Callable[[np.ndarray], np.ndarray]


def constant_attr(value: float = 1.0) -> AttrFn:
    def g(idx: np.ndarray) -> np.ndarray:
        return np.full((idx.shape[0],), value, dtype=np.float64)

    return g


@dataclasses.dataclass
class Query:
    spec: JoinSpec
    agg: Agg
    oracle: "Oracle"                     # noqa: F821 (core.oracle)
    g: Optional[AttrFn] = None           # defaults to COUNT semantics
    budget: int = 10000
    confidence: float = 0.95
    group_fn: Optional[AttrFn] = None    # GroupBy: maps tuples -> int group id
    n_groups: int = 0
    g_bounds: Optional[tuple] = None     # (lo, hi) data-wide bounds of g, used
                                         # for MIN/MAX CIs (paper §5.3)
    proxy: Optional["Oracle"] = None     # noqa: F821 — cheap proxy oracle for
                                         # the multi-fidelity cascade
                                         # (core/cascade.py); its calls are
                                         # NOT charged against ``budget``

    def attr(self) -> AttrFn:
        return self.g if self.g is not None else constant_attr(1.0)
