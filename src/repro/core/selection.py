"""BAS for selection queries with recall guarantees (paper §5.4, Lemma 5.1)
and Top-K heavy hitters.

Selection semantics (SUPG [37]): output T' such that
P[|T ∩ T'| / |T| >= gamma] >= p.  The score of a pair is its similarity; the
output is {blocked positives} ∪ {pairs with score >= tau_s}.  BAS improves
precision by labelling the blocking regime exactly, which lets tau_s rise:
the sampling regime only needs recall

    gamma_s >= gamma - (1 - gamma) * COUNT_b / UB(COUNT_s)   (Lemma 5.1)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .oracle import OracleBatch
from .similarity import chain_weights, flat_to_tuples
from .stratify import stratify_dense
from .types import BASConfig, Query
from .wander import flat_sample


def upper_bound(mu: float, var: float, n: int, p: float) -> float:
    """UB(mu, sigma^2, b, p) from Lemma 5.1 (normal-approximation bound)."""
    if n <= 0:
        return float("inf")
    return mu + np.sqrt(max(var, 0.0)) * np.sqrt(2.0 * np.log(2.0 / (1.0 - p)))


@dataclasses.dataclass
class SelectionResult:
    selected_flat: np.ndarray
    tau_s: float
    oracle_calls: int
    detail: dict


def run_bas_selection(
    query: Query,
    recall_target: float,
    cfg: Optional[BASConfig] = None,
    seed: int = 0,
    weights: Optional[np.ndarray] = None,
) -> SelectionResult:
    """Two-table selection with recall guarantee.

    1. stratify; pilot-sample strata for per-stratum COUNT estimates;
    2. block the strata that maximise COUNT_b per budget (greedy by estimated
       positive density — the arg-max of Lemma 5.1's bound);
    3. translate gamma -> gamma_s; estimate the score threshold tau_s whose
       sampling-regime recall is >= gamma_s with confidence p (importance-
       weighted quantile of positive scores, conservative side).
    """
    cfg = cfg or BASConfig()
    rng = np.random.default_rng(seed)
    query.oracle.set_budget(query.budget)
    query.oracle.bind_sizes(query.spec.sizes)
    if weights is None:
        weights = chain_weights(query.spec.embeddings, cfg.weight_exponent, cfg.weight_floor)
    b = query.budget
    b1 = max(int(round(cfg.pilot_fraction * b)), 8)
    strat = stratify_dense(weights, cfg.alpha, b, cfg)
    k = strat.num_strata
    sizes = strat.stratum_sizes()
    per_idx = [None] + [strat.stratum_indices(i) for i in range(1, k + 1)]
    w0 = np.array(weights, np.float64, copy=True)
    w0[strat.order] = 0.0

    # ---- pilot: estimated positive count + variance per stratum ----------
    count_hat = np.zeros(k + 1)
    count_var = np.zeros(k + 1)
    pilot_scores, pilot_labels, pilot_q, pilot_sid = [], [], [], []
    n_pilot = max(b1 // (k + 1), 2)
    pilot_batch = OracleBatch(query.oracle)
    pilot_draws = []  # (i, pos, q, handle): one coalesced flush for the pilot
    for i in range(k + 1):
        if i == 0:
            if sizes[0] == 0 or w0.sum() <= 0:
                continue
            pos, q = flat_sample(w0, n_pilot, rng)
        else:
            if len(per_idx[i]) == 0:
                continue
            p_, q = flat_sample(weights[per_idx[i]], n_pilot, rng)
            pos = per_idx[i][p_]
        tup = flat_to_tuples(pos, query.spec.sizes)
        pilot_draws.append((i, pos, q, pilot_batch.submit(tup)))
    pilot_batch.flush_async().result()   # await: service coalesces pilots
    for i, pos, q, h in pilot_draws:
        o = h.labels
        t = o / q
        count_hat[i] = t.mean()
        count_var[i] = np.var(t, ddof=1) / n_pilot if n_pilot > 1 else 0.0
        pilot_scores.append(weights[pos])
        pilot_labels.append(o)
        pilot_q.append(q)
        pilot_sid.append(np.full(len(o), i))

    # ---- block highest-density strata within remaining budget -------------
    remaining = b - query.oracle.calls
    density = np.zeros(k + 1)
    for i in range(1, k + 1):
        if sizes[i] > 0:
            density[i] = count_hat[i] / sizes[i]
    order = np.argsort(density[1:])[::-1] + 1
    beta, cost = [], 0
    for i in order:
        if density[i] <= 0:
            break
        if cost + sizes[i] <= 0.8 * remaining:
            beta.append(int(i))
            cost += int(sizes[i])
    blocked_pos_flat = []
    count_b = 0.0
    block_batch = OracleBatch(query.oracle)
    block_handles = [
        block_batch.submit(flat_to_tuples(per_idx[i], query.spec.sizes))
        for i in beta
    ]
    block_batch.flush_async().result()
    for i, h in zip(beta, block_handles):
        o = h.labels
        count_b += float(o.sum())
        blocked_pos_flat.append(per_idx[i][o > 0])

    # ---- main sampling round over non-blocked strata ----------------------
    remaining = b - query.oracle.calls
    sampled_ids = [i for i in range(k + 1) if i not in beta and sizes[i] > 0]
    scores, labels, qs = (
        [np.concatenate(pilot_scores)] if pilot_scores else [],
        [np.concatenate(pilot_labels)] if pilot_labels else [],
        [np.concatenate(pilot_q)] if pilot_q else [],
    )
    sids = [np.concatenate(pilot_sid)] if pilot_sid else []
    if remaining > len(sampled_ids) and sampled_ids:
        per = remaining // len(sampled_ids)
        main_batch = OracleBatch(query.oracle)
        main_draws = []  # (i, pos, q, handle)
        for i in sampled_ids:
            if i == 0:
                if w0.sum() <= 0:
                    continue
                pos, q = flat_sample(w0, per, rng)
            else:
                p_, q = flat_sample(weights[per_idx[i]], per, rng)
                pos = per_idx[i][p_]
            tup = flat_to_tuples(pos, query.spec.sizes)
            main_draws.append((i, pos, q, main_batch.submit(tup)))
        main_batch.flush_async().result()
        for i, pos, q, h in main_draws:
            o = h.labels
            scores.append(weights[pos])
            labels.append(o)
            qs.append(q)
            sids.append(np.full(len(o), i))
    sc = np.concatenate(scores) if scores else np.zeros(0)
    lb = np.concatenate(labels) if labels else np.zeros(0)
    qq = np.concatenate(qs) if qs else np.ones(0)
    sid = np.concatenate(sids) if sids else np.zeros(0)
    keep = ~np.isin(sid, list(beta))  # pilot samples of now-blocked strata drop out
    sc, lb, qq = sc[keep], lb[keep], qq[keep]

    # COUNT_s estimate over the sampling regime (importance weighted)
    ht = lb / qq
    count_s = float(ht.mean()) if len(ht) else 0.0
    var_s = float(np.var(ht, ddof=1) / len(ht)) if len(ht) > 1 else 0.0
    ub = upper_bound(count_s, var_s, len(ht), query.confidence)
    gamma_s = recall_target - (1 - recall_target) * count_b / max(ub, 1e-12)
    gamma_s = min(max(gamma_s, 0.0), 1.0)

    # tau_s: importance-weighted quantile of positive scores such that the
    # weighted mass of positives above tau_s >= gamma_s (conservative: lower
    # confidence bound via Waudby-Smith-style normal approx on the mass).
    pos_m = lb > 0
    if pos_m.sum() == 0 or gamma_s <= 0:
        tau_s = 0.0 if gamma_s > 0 else float("inf")
    else:
        v = sc[pos_m]
        w_ht = (1.0 / qq[pos_m])
        order_v = np.argsort(v)[::-1]  # descending score
        v_sorted = v[order_v]
        mass = np.cumsum(w_ht[order_v])
        total = float(ht.sum())
        # add slack ∝ estimator std to be conservative
        slack = np.sqrt(max(var_s, 0.0)) * len(ht) / max(total, 1e-12)
        frac = mass / max(total, 1e-12) + slack
        j = np.nonzero(frac >= gamma_s)[0]
        tau_s = float(v_sorted[j[0]]) if len(j) else 0.0

    selected = [np.nonzero((weights >= tau_s) & (w0 > 0))[0]] + blocked_pos_flat
    # strata not blocked but inside the blocking regime: include via threshold
    for i in sampled_ids:
        if i == 0:
            continue
        m = weights[per_idx[i]] >= tau_s
        selected.append(per_idx[i][m])
    sel = np.unique(np.concatenate(selected)) if selected else np.zeros(0, np.int64)
    return SelectionResult(
        selected_flat=sel,
        tau_s=tau_s,
        oracle_calls=query.oracle.calls,
        detail={"beta": beta, "count_b": count_b, "gamma_s": gamma_s,
                "count_s": count_s, "oracle": query.oracle.stats()},
    )


def run_bas_groupby(
    query: Query,
    group_fn,
    n_groups: int,
    cfg: Optional[BASConfig] = None,
    seed: int = 0,
    weights: Optional[np.ndarray] = None,
) -> dict:
    """GroupBy COUNT (paper §5.3 "Handling GroupBy"): per-group combined
    estimates from one BAS execution; blocking prioritises strata with high
    densities of small ("hard-to-estimate") groups via the heavy-hitter
    machinery; simultaneous CIs are Bonferroni-adjusted bootstrap intervals."""
    out = run_topk_heavy_hitters(
        query, k_top=n_groups, entity_fn=group_fn, n_entities=n_groups,
        cfg=cfg, seed=seed, weights=weights,
    )
    return {
        "counts": out["counts"],
        "ci_lo": out["ci_lo"],
        "ci_hi": out["ci_hi"],
        "oracle_calls": out["oracle_calls"],
    }


def run_topk_heavy_hitters(
    query: Query,
    k_top: int,
    entity_fn,
    n_entities: int,
    cfg: Optional[BASConfig] = None,
    seed: int = 0,
    weights: Optional[np.ndarray] = None,
) -> dict:
    """Top-K heavy hitters (paper §5.4): per-entity COUNT via the combined
    estimator; return K entities with largest estimates + simultaneous
    bootstrap CIs (Bonferroni over candidates near the boundary)."""

    cfg = cfg or BASConfig()
    rng = np.random.default_rng(seed)
    query.oracle.set_budget(query.budget)
    query.oracle.bind_sizes(query.spec.sizes)
    if weights is None:
        weights = chain_weights(query.spec.embeddings, cfg.weight_exponent, cfg.weight_floor)
    b = query.budget
    strat = stratify_dense(weights, cfg.alpha, b, cfg)
    kk = strat.num_strata
    sizes = strat.stratum_sizes()
    per_idx = [None] + [strat.stratum_indices(i) for i in range(1, kk + 1)]
    w0 = np.array(weights, np.float64, copy=True)
    w0[strat.order] = 0.0
    # block the top strata (highest similarity first) within half the budget,
    # sample the rest ∝ weight
    beta, cost = [], 0
    for i in range(1, kk + 1):
        if cost + sizes[i] <= 0.5 * b:
            beta.append(i)
            cost += int(sizes[i])
    counts = np.zeros(n_entities)
    n_boot = 200
    boot = np.zeros((n_boot, n_entities))
    blocked_counts = np.zeros(n_entities)
    block_batch = OracleBatch(query.oracle)
    block_tups = [flat_to_tuples(per_idx[i], query.spec.sizes) for i in beta]
    block_handles = [block_batch.submit(tup) for tup in block_tups]
    block_fut = block_batch.flush_async()
    ents = [entity_fn(tup).astype(np.int64) for tup in block_tups]
    block_fut.result()                   # entity ids computed during labelling
    for ent, h in zip(ents, block_handles):
        o = h.labels
        np.add.at(blocked_counts, ent[o > 0], 1.0)
    counts += blocked_counts
    remaining = b - query.oracle.calls
    sampled_ids = [i for i in range(kk + 1) if i not in beta and sizes[i] > 0]
    main_batch = OracleBatch(query.oracle)
    main_draws = []  # (tup, q, n_i, handle)
    for i in sampled_ids:
        n_i = remaining // max(len(sampled_ids), 1)
        if n_i < 2:
            continue
        if i == 0:
            if w0.sum() <= 0:
                continue
            pos, q = flat_sample(w0, n_i, rng)
        else:
            p_, q = flat_sample(weights[per_idx[i]], n_i, rng)
            pos = per_idx[i][p_]
        tup = flat_to_tuples(pos, query.spec.sizes)
        # bootstrap indices drawn here to keep the rng stream identical to the
        # pre-batching (label-inside-the-loop) execution order
        ridx = rng.integers(0, n_i, size=(200, n_i))
        main_draws.append((tup, q, n_i, ridx, main_batch.submit(tup)))
    main_batch.flush_async().result()
    for tup, q, n_i, ridx, h in main_draws:
        o = h.labels
        ent = entity_fn(tup).astype(np.int64)
        ht = o / q / n_i
        np.add.at(counts, ent, ht)
        for j in range(200):
            np.add.at(boot[j], ent[ridx[j]], ht[ridx[j]])
    order = np.argsort(counts)[::-1]
    top = order[:k_top]
    # simultaneous percentile CIs: bootstrap of the sampled contribution plus
    # the (exact, constant) blocked contribution; Bonferroni over n_entities.
    a = (1.0 - query.confidence) / max(n_entities, 1)
    boot_total = boot + blocked_counts[None, :]
    ci_lo = np.quantile(boot_total, a / 2, axis=0)
    ci_hi = np.quantile(boot_total, 1 - a / 2, axis=0)
    return {
        "top": top,
        "counts": counts,
        "ci_lo": ci_lo,
        "ci_hi": ci_hi,
        "oracle_calls": query.oracle.calls,
        "oracle": query.oracle.stats(),
        "beta": beta,
    }
