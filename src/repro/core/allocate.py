"""Adaptive budget allocation (paper §5.3, Alg. 4 lines 6-11 + Appendix B.1).

Given pilot estimates sigma_i^2 of per-stratum sampling variance, find the
subset beta of strata {1..K} to *block* (Oracle everything) minimising the
estimated MSE of the combined estimator:

    MSE(beta) = sum_{i not in beta} sigma_i^2 / n_i(beta)
    n_i(beta) = (b2 - sum_{j in beta} |D_j|) * W_i / sum_{j not in beta} W_j

D_0 (the minimum sampling regime) can never be blocked.  The paper solves the
arg-min with unspecified "iterative methods"; we provide an exact vectorised
subset enumeration for K <= exact_max_k and a greedy + single-swap local
search beyond (tests cross-check the two on small K).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class Allocation:
    beta: np.ndarray          # sorted int array of blocked strata in {1..K}
    n_per_stratum: np.ndarray  # (K+1,) budgets for strata 0..K (blocked: |D_i|)
    est_mse: float


def budget_assign(
    b2: int,
    weight_sums: np.ndarray,   # (K+1,) total weight of strata 0..K
    sizes: np.ndarray,         # (K+1,) sizes of strata 0..K
    beta_mask: np.ndarray,     # (K+1,) bool; beta_mask[0] must be False
) -> np.ndarray:
    """Alg. 4 BudgetAssign: remaining budget split ∝ stratum weight mass."""
    blocked_cost = sizes[beta_mask].sum()
    rem = max(float(b2) - float(blocked_cost), 0.0)
    w = np.where(beta_mask, 0.0, weight_sums.astype(np.float64))
    denom = w.sum()
    n = np.zeros_like(w)
    if denom > 0:
        n = rem * w / denom
    n[beta_mask] = sizes[beta_mask]
    return n


def estimate_mse(
    sigma2: np.ndarray, weight_sums: np.ndarray, sizes: np.ndarray,
    beta_mask: np.ndarray, b2: int,
) -> float:
    """Estimated MSE of the combined SUM estimator for allocation beta."""
    n = budget_assign(b2, weight_sums, sizes, beta_mask)
    sampled = ~beta_mask
    ni = n[sampled]
    if np.any(ni < 1.0):
        return float("inf")  # infeasible: a sampled stratum got no budget
    return float(np.sum(sigma2[sampled] / ni))


def _eval_many(sigma2, weight_sums, sizes, masks, b2):
    """Vectorised estimate_mse over (M, K+1) bool masks."""
    sizes = sizes.astype(np.float64)
    w = np.where(masks, 0.0, weight_sums[None, :].astype(np.float64))
    blocked_cost = (sizes[None, :] * masks).sum(axis=1)
    rem = np.maximum(float(b2) - blocked_cost, 0.0)
    denom = w.sum(axis=1)
    # n_i for sampled strata
    with np.errstate(divide="ignore", invalid="ignore"):
        n = rem[:, None] * w / np.where(denom[:, None] > 0, denom[:, None], 1.0)
        contrib = np.where(masks, 0.0, sigma2[None, :] / np.where(n > 0, n, np.nan))
    mse = contrib.sum(axis=1)
    infeasible = np.any((~masks) & (n < 1.0), axis=1) | (denom <= 0)
    mse = np.where(infeasible | np.isnan(mse), np.inf, mse)
    return mse


def argmin_beta(
    sigma2: np.ndarray,
    weight_sums: np.ndarray,
    sizes: np.ndarray,
    b2: int,
    exact_max_k: int = 16,
) -> Allocation:
    """Find beta minimising estimated MSE.  Inputs indexed 0..K (D_0 first)."""
    k = len(sigma2) - 1
    sigma2 = np.asarray(sigma2, np.float64)
    weight_sums = np.asarray(weight_sums, np.float64)
    sizes = np.asarray(sizes, np.int64)

    def mask_from_beta(beta_set):
        m = np.zeros(k + 1, dtype=bool)
        for i in beta_set:
            m[i] = True
        return m

    if k <= exact_max_k:
        n_sub = 1 << k
        subsets = np.arange(n_sub, dtype=np.uint32)
        masks = np.zeros((n_sub, k + 1), dtype=bool)
        for i in range(1, k + 1):
            masks[:, i] = (subsets >> (i - 1)) & 1
        # drop infeasible (blocked cost > b2)
        mse = _eval_many(sigma2, weight_sums, sizes, masks, b2)
        best = int(np.argmin(mse))
        beta = np.nonzero(masks[best][1:])[0] + 1
        return Allocation(
            beta=beta.astype(np.int64),
            n_per_stratum=budget_assign(b2, weight_sums, sizes, masks[best]),
            est_mse=float(mse[best]),
        )

    # Greedy forward selection + single-swap local search.
    current = set()
    cur_mask = mask_from_beta(current)
    cur_mse = estimate_mse(sigma2, weight_sums, sizes, cur_mask, b2)
    improved = True
    while improved:
        improved = False
        candidates = []
        for i in range(1, k + 1):
            if i not in current:
                candidates.append(current | {i})
        for i in list(current):
            candidates.append(current - {i})
            for j in range(1, k + 1):
                if j not in current:
                    candidates.append((current - {i}) | {j})
        if not candidates:
            break
        masks = np.stack([mask_from_beta(c) for c in candidates])
        mses = _eval_many(sigma2, weight_sums, sizes, masks, b2)
        best = int(np.argmin(mses))
        if mses[best] < cur_mse - 1e-12:
            current = set(np.nonzero(masks[best][1:])[0] + 1)
            cur_mse = float(mses[best])
            cur_mask = masks[best]
            improved = True
    return Allocation(
        beta=np.array(sorted(current), np.int64),
        n_per_stratum=budget_assign(b2, weight_sums, sizes, cur_mask),
        est_mse=float(cur_mse),
    )
