"""JoinML query front-end (paper Fig. 1 syntax).

Parses::

    SELECT {AVG|SUM|COUNT|MIN|MAX|MEDIAN}(expr)
    FROM t1 JOIN t2 [JOIN t3 ...]
    ON NL('...') [AND ...]
    ORACLE BUDGET b WITH PROBABILITY p

into a :class:`repro.core.types.Query` against a registered catalog of tables
(embeddings + attribute columns) and an Oracle, then executes it with the
selected algorithm (BAS by default).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Callable, Optional, Union

import numpy as np

from . import baselines, bas, bas_streaming, dispatch
from .oracle import Oracle
from .types import Agg, AttrFn, BASConfig, JoinSpec, Query, QueryResult


@dataclasses.dataclass
class Table:
    name: str
    embeddings: np.ndarray                 # (N, d) unit-normalised
    columns: dict[str, np.ndarray] = dataclasses.field(default_factory=dict)

    @property
    def n(self) -> int:
        return int(self.embeddings.shape[0])


class Catalog:
    def __init__(self):
        self.tables: dict[str, Table] = {}

    def register(self, table: Table) -> None:
        self.tables[table.name] = table

    def __getitem__(self, name: str) -> Table:
        return self.tables[name]


_NL_RE = r"NL\s*\(\s*'[^']*'\s*\)"
_QUERY_RE = re.compile(
    r"SELECT\s+(?P<agg>AVG|SUM|COUNT|MIN|MAX|MEDIAN)\s*\(\s*(?P<expr>[^)]*)\s*\)\s+"
    r"FROM\s+(?P<tables>.+?)\s+ON\s+"
    rf"(?P<on>{_NL_RE}(?:\s+AND\s+{_NL_RE})*)"
    r"(?:\s+ORACLE\s+BUDGET\s+(?P<budget>\d+))?"
    r"(?:\s+WITH\s+PROBABILITY\s+(?P<prob>[\d.]+))?\s*;?\s*$",
    re.IGNORECASE | re.DOTALL,
)
_NL_EXTRACT_RE = re.compile(r"NL\s*\(\s*'([^']*)'\s*\)", re.IGNORECASE)


@dataclasses.dataclass
class ParsedQuery:
    agg: Agg
    expr: str
    table_names: list[str]
    nl_conditions: list[str]   # one per join edge (or a single conjoint one)
    budget: Optional[int]
    confidence: Optional[float]

    @property
    def nl_condition(self) -> str:
        """First (or only) predicate — kept for single-predicate callers."""
        return self.nl_conditions[0]


def parse_query(sql: str) -> ParsedQuery:
    """Parse ``... ON NL('...') [AND NL('...') ...]`` — a conjunction carries
    one predicate per join edge (k tables -> k-1 edges), matching the paper's
    multi-way chain-join syntax; a single predicate applies to every edge."""
    m = _QUERY_RE.match(" ".join(sql.split()))
    if not m:
        raise ValueError(f"cannot parse JoinML query: {sql!r}")
    names = [
        t.strip() for t in re.split(r"\s+JOIN\s+", m.group("tables"), flags=re.I)
    ]
    conditions = _NL_EXTRACT_RE.findall(m.group("on"))
    if len(conditions) not in (1, len(names) - 1):
        raise ValueError(
            f"{len(conditions)} NL predicates for {len(names)} tables: a "
            f"conjunction must supply one predicate per join edge "
            f"({len(names) - 1}) or a single predicate for all edges"
        )
    return ParsedQuery(
        agg=Agg[m.group("agg").upper()],
        expr=m.group("expr").strip(),
        table_names=names,
        nl_conditions=conditions,
        budget=int(m.group("budget")) if m.group("budget") else None,
        confidence=float(m.group("prob")) if m.group("prob") else None,
    )


def _compile_expr(expr: str, tables: list[Table]) -> Optional[AttrFn]:
    """Compile the aggregate expression into g(idx).

    Supports '*', 'k' (constant), 'tN.col', 'tA.col - tB.col',
    'ABS(tA.col - tB.col)'.  Table refs are by name or alias position.
    """
    expr = expr.strip()
    if expr in ("*", "", "1"):
        return None
    name_to_pos = {t.name: i for i, t in enumerate(tables)}

    def col(ref: str) -> tuple[int, np.ndarray]:
        tname, cname = ref.strip().split(".")
        pos = name_to_pos[tname]
        return pos, tables[pos].columns[cname]

    m = re.match(r"ABS\s*\(\s*(.+)\s*\)\s*$", expr, re.I)
    absolute = False
    if m:
        absolute = True
        expr = m.group(1)
    m = re.match(r"([\w.]+)\s*-\s*([\w.]+)\s*$", expr)
    if m:
        (p1, c1), (p2, c2) = col(m.group(1)), col(m.group(2))

        def g(idx: np.ndarray) -> np.ndarray:
            v = c1[idx[:, p1]] - c2[idx[:, p2]]
            return np.abs(v) if absolute else v

        return g
    m = re.match(r"([\w.]+)$", expr)
    if m and "." in expr:
        p1, c1 = col(expr)

        def g(idx: np.ndarray) -> np.ndarray:
            v = c1[idx[:, p1]].astype(np.float64)
            return np.abs(v) if absolute else v

        return g
    raise ValueError(f"unsupported aggregate expression: {expr!r}")


class JoinMLEngine:
    """Executes JoinML queries.  ``oracle_factory(nl_condition, table_names)``
    supplies the Oracle for a given join predicate (e.g. a ModelOracle bound to
    the serving stack, or an ArrayOracle in tests).  ``nl_condition`` is a
    single string for one predicate, or the list of per-edge predicates when
    the query conjoins ``NL('...') AND NL('...')`` (one per join edge).

    ``index_store`` (:class:`repro.core.index.IndexStore`) makes repeat and
    concurrent queries on the same registered tables stratify from one
    persistent sweep artifact: ``method="auto"`` routes through a fresh
    resident artifact when one exists, and ``method="bas-streaming"``
    resolves (building on first miss) through the store.

    ``proxy_factory`` (same signature as ``oracle_factory``) supplies the
    cheap proxy oracle for the multi-fidelity cascade
    (``method="bas-cascade"`` or ``cfg.cascade``); without one, the cascade
    falls back to the thresholded-similarity proxy
    (:func:`repro.core.cascade.similarity_proxy`)."""

    def __init__(
        self,
        catalog: Catalog,
        oracle_factory: Callable[[Union[str, list[str]], list[str]], Oracle],
        cfg: Optional[BASConfig] = None,
        index_store=None,
        proxy_factory: Optional[
            Callable[[Union[str, list[str]], list[str]], Oracle]
        ] = None,
    ):
        self.catalog = catalog
        self.oracle_factory = oracle_factory
        self.cfg = cfg or BASConfig()
        self.index_store = index_store
        self.proxy_factory = proxy_factory

    def build(self, sql: str, budget: Optional[int] = None,
              confidence: Optional[float] = None) -> Query:
        pq = parse_query(sql)
        tables = [self.catalog[n] for n in pq.table_names]
        spec = JoinSpec(embeddings=[t.embeddings for t in tables])
        g = _compile_expr(pq.expr, tables)
        nl = (pq.nl_conditions if len(pq.nl_conditions) > 1
              else pq.nl_conditions[0])
        return Query(
            spec=spec,
            agg=pq.agg,
            oracle=self.oracle_factory(nl, pq.table_names),
            g=g,
            budget=budget or pq.budget or 10000,
            confidence=confidence or pq.confidence or 0.95,
            proxy=(self.proxy_factory(nl, pq.table_names)
                   if self.proxy_factory is not None else None),
        )

    def execute(self, sql: str, method: str = "auto", seed: int = 0,
                budget: Optional[int] = None,
                confidence: Optional[float] = None) -> QueryResult:
        """Execute a JoinML query.  ``method="auto"`` (default) routes BAS
        through the memory-aware dispatcher: dense when the flat chain-weight
        array fits under ``cfg.max_dense_weight_bytes``, streaming otherwise.
        ``"bas"`` / ``"bas-streaming"`` force a path explicitly."""
        q = self.build(sql, budget, confidence)
        if method == "auto":
            return dispatch.run_auto(q, self.cfg, seed=seed,
                                     index_store=self.index_store)
        if method == "bas":
            return bas.run_bas(q, self.cfg, seed=seed)
        if method == "bas-streaming":
            return bas_streaming.run_bas_streaming(
                q, self.cfg, seed=seed, index_store=self.index_store
            )
        if method == "bas-cascade":
            from . import cascade

            return cascade.run_bas_cascade(
                q, self.cfg, seed=seed, index_store=self.index_store
            )
        if method == "wwj":
            return baselines.run_wwj(q, self.cfg, seed=seed)
        if method == "uniform":
            return baselines.run_uniform(q, seed=seed)
        if method == "abae":
            return baselines.run_abae(q, self.cfg, seed=seed)
        if method == "blazeit":
            return baselines.run_blazeit(q, self.cfg, seed=seed)
        raise ValueError(f"unknown method {method!r}")
