"""Persistent stratification index: build-once/query-many sweep artifacts.

The stratification sweep — one blocked pass over ``E1 @ E2^T`` (see
``stratify.sweep_pass``) — is a pure function of (tables, embedder config,
binning), yet it dominates query latency and is recomputed from scratch on
every query, including repeat and concurrent queries on the same hot table
pair.  This module turns the sweep's outputs into a reusable **index
artifact**:

* :class:`IndexArtifact` — everything a query needs to stratify without
  touching the cross product: the embeddings, the global weight histogram,
  the per-(row-block, bin) count tiles, the per-row top-k candidates, and
  the binning/precision metadata, under a **content-addressed key** (SHA-256
  over the table fingerprints + embedder/binning config).  Hydrating it
  (:meth:`IndexArtifact.sweep_info`) yields a
  :class:`~repro.core.stratify.SweepInfo` that the threshold / collection /
  rescan machinery consumes unchanged — bit-identical at fp32 to a freshly
  computed sweep, because the artifact *is* that sweep's output.
* :func:`build_index` — one cold sweep (the same ``sweep_pass_chain`` the
  per-query path runs, with the full top-k budget so any later query shape
  can use it).
* :func:`append_rows` — **incremental maintenance**: appending rows to
  either table sweeps only the new row/column blocks and composes the count
  tiles by exact integer addition (the tiles are histograms, so disjoint
  row regions add; new columns add per tile), merges the per-row top-k, and
  bumps the artifact ``version`` so stale readers detect drift.  Cost is
  proportional to the delta, never the table.
* :class:`IndexStore` — a service-resident LRU (bounded by memory budget)
  mapping content keys to loaded artifacts, so concurrent queries through
  ``OracleService`` / ``JoinMLEngine`` share one artifact per table pair.
  Misses fall through to an on-disk root (``checkpoint.index_io``) before
  building.

Persistence (atomic save / mmap load) lives in
``repro.checkpoint.index_io``; the engine integration (``method="auto"``
routing through a fresh artifact) in ``core.dispatch``.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import threading
import time
from collections import OrderedDict
from concurrent.futures import Future
from typing import Optional

import numpy as np

from .stratify import TOPK_CANDIDATES, SweepInfo, sweep_pass, sweep_pass_chain

INDEX_FORMAT = 2   # bump when the artifact/on-disk layout changes
# format history:
#   1 — counts/edges/block_counts/embeddings/topk
#   2 — + per-edge walk row_sums and chain total_weight (one-pass chain
#       statistics: warm queries sample without re-reading the product)


def table_fingerprint(emb: np.ndarray) -> str:
    """Content hash of one table's embeddings (shape + f32 bytes).  The
    sweep consumes float32, so fingerprinting the f32 view makes the key
    insensitive to the caller's incidental dtype."""
    arr = np.ascontiguousarray(np.asarray(emb, np.float32))
    h = hashlib.sha256()
    h.update(str(arr.shape).encode())
    h.update(arr.tobytes())
    return h.hexdigest()


def artifact_key(
    embeddings: list,
    n_bins: int,
    exponent: float,
    floor: float,
    precision: str = "fp32",
) -> str:
    """Content-addressed identity of a sweep artifact: the table
    fingerprints plus everything that changes the tiles' *values*
    (binning resolution, weight transform, requested sweep precision).
    Execution details that only change the layout (kernel vs fallback,
    block size, top-k width) are deliberately excluded — they never change
    what a hydrated query computes, only how much a rescan can skip."""
    payload = {
        "format": INDEX_FORMAT,
        "tables": [table_fingerprint(e) for e in embeddings],
        "n_bins": int(n_bins),
        "exponent": float(exponent),
        "floor": float(floor),
        "precision": str(precision),
    }
    return hashlib.sha256(
        json.dumps(payload, sort_keys=True).encode()
    ).hexdigest()


@dataclasses.dataclass
class IndexArtifact:
    """A stored sweep: stratification inputs for one (tables, embedder,
    binning) identity.  Arrays may be disk mmaps (read-only) — every
    consumer treats them as immutable; maintenance returns a new artifact.

    ``precision`` is the *effective* tile precision (what the sweep
    actually binned at — the fallback path computes fp32 even when a low
    precision was requested); ``precision_requested`` is what the key was
    derived from, so repeat queries with the same config keep hitting."""

    key: str
    version: int
    sizes: tuple               # per-table row counts
    n_bins: int
    exponent: float
    floor: float
    precision: str             # effective tile precision
    precision_requested: str   # key component
    kernel: bool               # built through the Pallas sweep kernel
    block_rows: int
    counts: np.ndarray         # (n_bins,) i64 — exact column sum of tiles
    edges: np.ndarray          # (n_bins + 1,)
    block_counts: np.ndarray   # (n_blocks, n_bins) i64
    embeddings: list           # per-table (N_i, d) f32
    topk_vals: Optional[np.ndarray] = None   # (N1, k) f32 clipped scores
    topk_idx: Optional[np.ndarray] = None    # (N1, k) i32 right-row indices
    topk_valid: Optional[np.ndarray] = None  # (N1, k) bool
    row_sums: Optional[list] = None          # per-edge (N_j,) f64 walk sums
    total_weight: Optional[float] = None     # chain total sum_t prod_j w_j
    stats: dict = dataclasses.field(default_factory=dict)

    @property
    def n_tables(self) -> int:
        return len(self.sizes)

    @property
    def nbytes(self) -> int:
        arrays = [self.counts, self.edges, self.block_counts, *self.embeddings]
        if self.topk_vals is not None:
            arrays += [self.topk_vals, self.topk_idx, self.topk_valid]
        if self.row_sums is not None:
            arrays += list(self.row_sums)
        return int(sum(a.nbytes for a in arrays))

    def check(self, sizes=None, n_bins=None, exponent=None, floor=None):
        """Raise if the artifact cannot serve the given stratify config."""
        if sizes is not None and tuple(sizes) != tuple(self.sizes):
            raise ValueError(
                f"index artifact covers tables {self.sizes}, query has "
                f"{tuple(sizes)} — refresh the index (append_rows) first"
            )
        for name, got, want in (
            ("n_bins", n_bins, self.n_bins),
            ("exponent", exponent, self.exponent),
            ("floor", floor, self.floor),
        ):
            if got is not None and got != want:
                raise ValueError(
                    f"index artifact {name}={want} incompatible with "
                    f"requested {name}={got}"
                )

    def sweep_info(self) -> SweepInfo:
        """Hydrate a fresh :class:`SweepInfo` (the stats dict is per-query
        mutable state, so every hydration gets its own)."""
        topk = None
        if self.topk_vals is not None:
            topk = (self.topk_vals, self.topk_idx, self.topk_valid)
        stats = dict(self.stats.get("sweep", {}))
        stats["index_version"] = self.version
        return SweepInfo(
            counts=self.counts, edges=self.edges,
            block_counts=self.block_counts, block_rows=self.block_rows,
            topk=topk, kernel=self.kernel, precision=self.precision,
            stats=stats, row_sums=self.row_sums,
            total_weight=self.total_weight,
        )


def build_index(
    embeddings: list,
    n_bins: int = 4096,
    exponent: float = 1.0,
    floor: float = 1e-3,
    block: int = 4096,
    use_kernel: bool = True,
    precision: str = "fp32",
    tolerance: Optional[float] = None,
) -> IndexArtifact:
    """One cold sweep over the (chain) product, packaged as an artifact.

    Built with the full per-row top-k budget (``TOPK_CANDIDATES``) so any
    later query can hydrate regardless of its blocking-regime size; queries
    whose regime is dense simply ignore the top-k — exactly as the fresh
    path ignores it by sweeping with ``k_top=1``.
    """
    embeddings = [np.ascontiguousarray(np.asarray(e, np.float32))
                  for e in embeddings]
    t0 = time.perf_counter()
    info = sweep_pass_chain(
        embeddings, n_bins, exponent, floor, block=block,
        use_kernel=use_kernel, precision=precision, tolerance=tolerance,
        k_top=TOPK_CANDIDATES,
    )
    build_s = time.perf_counter() - t0
    vals = idx = valid = None
    if info.topk is not None:
        vals, idx, valid = (np.asarray(a) for a in info.topk)
    return IndexArtifact(
        key=artifact_key(embeddings, n_bins, exponent, floor, precision),
        version=1,
        sizes=tuple(int(e.shape[0]) for e in embeddings),
        n_bins=n_bins,
        exponent=float(exponent),
        floor=float(floor),
        precision=info.precision,
        precision_requested=precision,
        kernel=info.kernel,
        block_rows=info.block_rows,
        counts=np.asarray(info.counts, np.int64),
        edges=np.asarray(info.edges),
        block_counts=np.asarray(info.block_counts, np.int64),
        embeddings=embeddings,
        topk_vals=vals, topk_idx=idx, topk_valid=valid,
        row_sums=info.row_sums, total_weight=info.total_weight,
        stats={"build_s": build_s, "appends": 0, "delta_blocks": 0,
               "delta_rows": 0, "sweep": dict(info.stats)},
    )


def _regroup_tiles(bc: np.ndarray, from_rows: int, to_rows: int) -> np.ndarray:
    """Re-aggregate count tiles from a finer uniform row stride to a coarser
    one (exact integer addition; strides must nest)."""
    if from_rows == to_rows:
        return np.asarray(bc, np.int64)
    if to_rows % from_rows != 0:
        raise ValueError(f"tile strides do not nest: {from_rows} -> {to_rows}")
    factor = to_rows // from_rows
    cuts = np.arange(0, bc.shape[0], factor)
    return np.add.reduceat(np.asarray(bc, np.int64), cuts, axis=0)


def _sweep_rows(e_rows, e2, art: IndexArtifact, use_kernel: bool,
                k_top: int) -> SweepInfo:
    """Sweep a row region against the full right table under the artifact's
    binning config.  Low-precision tiles must come from the kernel path
    (the numpy fallback computes fp32, which would silently mix precisions
    inside one artifact); tolerance inf disables the lowp re-check — the
    build already certified this table pair."""
    info = sweep_pass(
        e_rows, e2, art.n_bins, art.exponent, art.floor,
        block=art.block_rows, use_kernel=use_kernel, precision=art.precision,
        tolerance=float("inf"), k_top=k_top, kernel_block=art.block_rows,
    )
    if art.precision != "fp32" and info.precision != art.precision:
        raise RuntimeError(
            f"cannot maintain a {art.precision} index without the sweep "
            "kernel path — rebuild at fp32 or restore the kernel"
        )
    return info


def append_rows(
    art: IndexArtifact,
    table: int,
    new_rows: np.ndarray,
    use_kernel: bool = True,
) -> IndexArtifact:
    """Incrementally maintain a two-table artifact after appending
    ``new_rows`` to table ``table`` (0 = left/rows, 1 = right/columns).
    Returns a NEW artifact (version bumped, key re-derived from the grown
    tables); the input artifact — possibly a read-only mmap — is untouched.

    Exactness: the count tiles are integer histograms, so

    * **left append** re-sweeps only the row region from the last aligned
      block boundary down (the one partial tile plus the new rows) and
      concatenates the new tiles — every untouched tile is byte-identical
      to a full recompute's;
    * **right append** sweeps the full left table against only the new
      columns and adds the delta tiles tile-wise (disjoint column ranges
      of a histogram add exactly); the per-row top-k merges the stored
      candidates with the delta's (ties break toward the lower column
      index, matching the kernel's argmax-first extract-max).

    Both are proportional to the delta, never to the table
    (``benchmarks/bench_index.py`` gates this).
    """
    if art.n_tables != 2:
        raise NotImplementedError(
            "incremental maintenance covers two-table artifacts; rebuild "
            "chain indexes with build_index"
        )
    if table not in (0, 1):
        raise ValueError(f"table must be 0 or 1, got {table}")
    new_rows = np.ascontiguousarray(np.asarray(new_rows, np.float32))
    if new_rows.ndim != 2 or new_rows.shape[1] != art.embeddings[table].shape[1]:
        raise ValueError(
            f"new rows {new_rows.shape} do not extend table {table} "
            f"{art.embeddings[table].shape}"
        )
    e1, e2 = (np.asarray(e, np.float32) for e in art.embeddings)
    br = art.block_rows
    stats = dict(art.stats)
    stats["appends"] = int(stats.get("appends", 0)) + 1
    stats["delta_rows"] = int(stats.get("delta_rows", 0)) + len(new_rows)
    has_topk = art.topk_vals is not None

    if table == 0:
        n1_old = e1.shape[0]
        e1_new = np.ascontiguousarray(np.concatenate([e1, new_rows]))
        # recompute from the last aligned block boundary: at most one
        # existing (partial) tile is replaced, the rest are appended.  Each
        # br-row chunk is swept separately and its global histogram IS that
        # region's tile (the chunk may internally tile finer; counts is the
        # exact integer sum of its sub-tiles).
        start = (n1_old // br) * br
        tiles, tops, region_sums = [], [], []
        for cs in range(start, e1_new.shape[0], br):
            info = _sweep_rows(e1_new[cs : cs + br], e2, art, use_kernel,
                               k_top=TOPK_CANDIDATES if has_topk else 1)
            tiles.append(np.asarray(info.counts, np.int64))
            tops.append(info.topk)
            region_sums.append(None if info.row_sums is None
                               else info.row_sums[0])
        block_counts = np.concatenate(
            [np.asarray(art.block_counts[: start // br], np.int64),
             np.stack(tiles)]
        )
        delta_blocks = len(tiles)
        topk_vals = topk_idx = topk_valid = None
        if has_topk and all(t is not None for t in tops):
            tail_v = np.concatenate([np.asarray(t[0]) for t in tops])
            tail_i = np.concatenate([np.asarray(t[1]) for t in tops])
            tail_ok = np.concatenate([np.asarray(t[2]) for t in tops])
            # rows [start, n1_old) were re-swept inside the region; their
            # fresh top-k equals the stored one, so either slice works —
            # keep the stored prefix and take only genuinely new rows
            keep = n1_old - start
            topk_vals = np.concatenate(
                [np.asarray(art.topk_vals[:n1_old]), tail_v[keep:]]
            )
            topk_idx = np.concatenate(
                [np.asarray(art.topk_idx[:n1_old]), tail_i[keep:]]
            )
            topk_valid = np.concatenate(
                [np.asarray(art.topk_valid[:n1_old]), tail_ok[keep:]]
            )
        row_sums = total_weight = None
        if art.row_sums is not None and all(s is not None for s in region_sums):
            # new left rows add their own walk sums; the re-swept overlap
            # [start, n1_old) is replaced by its (deterministically equal)
            # recomputation — total updated in O(delta), never re-reduced
            old_rs = np.asarray(art.row_sums[0], np.float64)
            tail_rs = np.concatenate(region_sums)
            row_sums = [np.concatenate([old_rs[:start], tail_rs])]
            total_weight = float(
                art.total_weight - old_rs[start:].sum() + tail_rs.sum()
            )
        embeddings = [e1_new, e2]
    else:
        n2_old = e2.shape[0]
        e2_new = np.ascontiguousarray(np.concatenate([e2, new_rows]))
        info = _sweep_rows(e1, new_rows, art, use_kernel,
                           k_top=TOPK_CANDIDATES if has_topk else 1)
        delta = _regroup_tiles(info.block_counts, info.block_rows, br)
        if delta.shape != art.block_counts.shape:
            raise RuntimeError(
                f"delta tiles {delta.shape} misaligned with index tiles "
                f"{art.block_counts.shape}"
            )
        block_counts = np.asarray(art.block_counts, np.int64) + delta
        delta_blocks = int(delta.shape[0])
        topk_vals = topk_idx = topk_valid = None
        if has_topk and info.topk is not None:
            topk_vals, topk_idx, topk_valid = _merge_topk(
                (art.topk_vals, art.topk_idx, art.topk_valid),
                info.topk, n2_old, e2_new.shape[0],
            )
        row_sums = total_weight = None
        if art.row_sums is not None and info.row_sums is not None:
            # the delta sweep's sums are each left row's mass over the new
            # columns only — elementwise add, O(N1) like the delta tiles
            delta_rs = np.asarray(info.row_sums[0], np.float64)
            row_sums = [np.asarray(art.row_sums[0], np.float64) + delta_rs]
            total_weight = float(art.total_weight + delta_rs.sum())
        embeddings = [e1, e2_new]

    stats["delta_blocks"] = int(stats.get("delta_blocks", 0)) + delta_blocks
    stats["last_delta_blocks"] = delta_blocks
    return IndexArtifact(
        key=artifact_key(embeddings, art.n_bins, art.exponent, art.floor,
                         art.precision_requested),
        version=art.version + 1,
        sizes=tuple(int(e.shape[0]) for e in embeddings),
        n_bins=art.n_bins, exponent=art.exponent, floor=art.floor,
        precision=art.precision,
        precision_requested=art.precision_requested,
        kernel=art.kernel, block_rows=br,
        counts=block_counts.sum(axis=0),
        edges=np.asarray(art.edges),
        block_counts=block_counts,
        embeddings=embeddings,
        topk_vals=topk_vals, topk_idx=topk_idx, topk_valid=topk_valid,
        row_sums=row_sums, total_weight=total_weight,
        stats=stats,
    )


def _merge_topk(old: tuple, new: tuple, n2_old: int, n2_total: int) -> tuple:
    """Per-row merge of stored top-k with a new-columns top-k (delta column
    indices shifted by ``n2_old``).  Invalid slots are neutralised to
    ``(-1, n2_total)`` so they sort last and stay invalid; ties break
    toward the lower column index (the kernel's argmax-first convention)."""
    ov, oi, ok = (np.asarray(a) for a in old)
    nv, ni, nk = (np.asarray(a) for a in new)
    vals = np.concatenate(
        [np.where(ok, ov, -1.0), np.where(nk, nv, -1.0)], axis=1
    ).astype(np.float32)
    idx = np.concatenate(
        [np.where(ok, oi.astype(np.int64), n2_total),
         np.where(nk, ni.astype(np.int64) + n2_old, n2_total)], axis=1
    )
    k = ov.shape[1]
    order = np.lexsort((idx, -vals.astype(np.float64)), axis=-1)[:, :k]
    rows = np.arange(vals.shape[0])[:, None]
    vals_m, idx_m = vals[rows, order], idx[rows, order]
    valid = idx_m < n2_total
    return (
        np.where(valid, vals_m, 0.0).astype(np.float32),
        np.where(valid, idx_m, n2_total).astype(np.int32),
        valid,
    )


# ----------------------------------------------------------------------------
# Service-resident store: one loaded artifact per hot table pair.
# ----------------------------------------------------------------------------


class IndexStore:
    """Thread-safe LRU of :class:`IndexArtifact`\\ s keyed by content
    address, bounded by ``max_bytes``.  Concurrent first queries on the
    same key share one build (per-key future); distinct keys build in
    parallel.  With ``root`` set, a memory miss tries the on-disk store
    (``checkpoint.index_io``, mmap load) before paying a cold sweep.
    """

    def __init__(self, max_bytes: int = 1 << 30, root: Optional[str] = None,
                 tracker=None):
        from repro.obs import NULL_TRACKER

        self.max_bytes = int(max_bytes)
        self.root = root
        if root is not None:
            # tuned kernel block schedules live next to the artifacts they
            # accelerate (configure() only records the path — no jax import,
            # no measurement until a compiled sweep actually runs)
            from repro.kernels import autotune

            autotune.configure(os.path.join(os.fspath(root), "autotune.json"))
        self.tracker = tracker if tracker is not None else NULL_TRACKER
        self._lock = threading.Lock()
        self._entries: "OrderedDict[str, Future]" = OrderedDict()
        self._sizes: dict = {}
        self.hits = 0
        self.misses = 0
        self.builds = 0
        self.loads = 0
        self.evictions = 0
        self.build_ms = 0.0
        self.delta_blocks = 0

    # ---- lookups -----------------------------------------------------------

    def key_for(self, embeddings, n_bins=4096, exponent=1.0, floor=1e-3,
                precision="fp32") -> str:
        return artifact_key(embeddings, n_bins, exponent, floor, precision)

    def lookup(self, embeddings, **params) -> Optional[IndexArtifact]:
        """A *fresh* resident artifact for these exact tables, or None —
        never builds, never counts a miss.  Freshness is structural: the
        content key is derived from the live embeddings, so a stale
        (pre-append) artifact simply no longer matches."""
        key = self.key_for(embeddings, **params)
        with self._lock:
            fut = self._entries.get(key)
            if fut is None or not fut.done() or fut.exception() is not None:
                return None
            self._entries.move_to_end(key)
            self.hits += 1
            return fut.result()

    def get_or_build(
        self,
        embeddings,
        n_bins: int = 4096,
        exponent: float = 1.0,
        floor: float = 1e-3,
        precision: str = "fp32",
        use_kernel: bool = True,
        block: int = 4096,
    ) -> tuple:
        """Returns ``(artifact, hit)``.  ``hit`` is True when the artifact
        was already resident — including waiting on another query's
        in-flight build of the same key."""
        key = artifact_key(embeddings, n_bins, exponent, floor, precision)
        with self._lock:
            fut = self._entries.get(key)
            if fut is not None:
                self._entries.move_to_end(key)
                self.hits += 1
                owner = False
            else:
                fut = Future()
                self._entries[key] = fut
                self.misses += 1
                owner = True
        if not owner:
            return fut.result(), True
        try:
            art = self._load_from_root(key)
            if art is None:
                t0 = time.perf_counter()
                art = build_index(
                    embeddings, n_bins=n_bins, exponent=exponent, floor=floor,
                    block=block, use_kernel=use_kernel, precision=precision,
                )
                dt_ms = (time.perf_counter() - t0) * 1e3
                with self._lock:
                    self.builds += 1
                    self.build_ms += dt_ms
        except BaseException as e:
            with self._lock:
                self._entries.pop(key, None)
            fut.set_exception(e)
            raise
        fut.set_result(art)
        self._admit(key, art)
        return art, False

    def add(self, art: IndexArtifact) -> None:
        """Insert an externally built/refreshed artifact (e.g. after
        :func:`append_rows`), accounting its delta in the store counters."""
        fut = Future()
        fut.set_result(art)
        with self._lock:
            self._entries[art.key] = fut
            self._entries.move_to_end(art.key)
            self.delta_blocks += int(art.stats.get("last_delta_blocks", 0))
        self._admit(art.key, art)

    # ---- internals ---------------------------------------------------------

    def _load_from_root(self, key: str) -> Optional[IndexArtifact]:
        if self.root is None:
            return None
        from repro.checkpoint.index_io import load_index

        try:
            art = load_index(self.root, key=key)
        except FileNotFoundError:
            return None
        with self._lock:
            self.loads += 1
        return art

    def _admit(self, key: str, art: IndexArtifact) -> None:
        with self._lock:
            self._sizes[key] = art.nbytes
            total = sum(self._sizes.values())
            for old_key in list(self._entries):
                if total <= self.max_bytes:
                    break
                if old_key == key:
                    continue            # never evict what we just admitted
                fut = self._entries[old_key]
                if not fut.done():
                    continue            # never evict an in-flight build
                del self._entries[old_key]
                total -= self._sizes.pop(old_key, 0)
                self.evictions += 1
                self.tracker.count("index_store.evictions")

    # ---- observability -----------------------------------------------------

    @property
    def bytes_resident(self) -> int:
        with self._lock:
            return sum(self._sizes.values())

    def stats(self) -> dict:
        with self._lock:
            return {
                "index_hit": self.hits,
                "index_miss": self.misses,
                "index_build": self.builds,
                "index_load": self.loads,
                "index_evict": self.evictions,
                "index_build_ms": round(self.build_ms, 2),
                "index_bytes": sum(self._sizes.values()),
                "delta_blocks": self.delta_blocks,
            }

    def snapshot(self) -> dict[str, float]:
        """Unified stats surface: ``index_store.*`` namespaced floats."""
        stats = self.stats()
        return {
            "index_store.warm_hits": float(stats["index_hit"]),
            "index_store.misses": float(stats["index_miss"]),
            "index_store.builds": float(stats["index_build"]),
            "index_store.loads": float(stats["index_load"]),
            "index_store.evictions": float(stats["index_evict"]),
            "index_store.build_ms": float(stats["index_build_ms"]),
            "index_store.bytes": float(stats["index_bytes"]),
            "index_store.delta_blocks": float(stats["delta_blocks"]),
        }
