"""Weighted Wander Join (paper §5.1, Alg. 3).

WWJ = Wander Join with an *approximate* index: every random-walk step samples
the next record with probability proportional to embedding similarity, and a
Horvitz-Thompson correction (importance sampling over the cross product)
keeps the estimator unbiased.

Two samplers:

* :func:`walk_sample` — the faithful per-step random walk for k tables.  Cost
  O(n * sum_i N_i), never touches the cross product (paper's complexity
  argument, §5.1).
* :func:`flat_sample` — categorical over an explicit weight vector; used for
  within-stratum sampling in BAS (Alg. 4 ``WeightedSample(D_i, n_i, W)``) on
  the dense path.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .similarity import pair_weights
from .types import ConfidenceInterval


@dataclasses.dataclass
class WalkSample:
    idx: np.ndarray    # (n, k) tuple indices
    prob: np.ndarray   # (n,) sampling probability of each tuple (exact)


def _categorical_rows(w: np.ndarray, rng: np.random.Generator) -> tuple[np.ndarray, np.ndarray]:
    """Per-row categorical sample.  Returns (choice, prob_of_choice)."""
    totals = w.sum(axis=1, keepdims=True)
    cdf = np.cumsum(w, axis=1) / totals
    u = rng.random((w.shape[0], 1))
    choice = (cdf < u).sum(axis=1)
    choice = np.minimum(choice, w.shape[1] - 1)
    prob = np.take_along_axis(w, choice[:, None], axis=1)[:, 0] / totals[:, 0]
    return choice.astype(np.int64), prob


def walk_sample(
    embeddings: list[np.ndarray],
    n: int,
    rng: np.random.Generator,
    exponent: float = 1.0,
    floor: float = 1e-3,
    chunk: int = 4096,
) -> WalkSample:
    """n independent WWJ random walks over a k-table chain."""
    k = len(embeddings)
    n1 = embeddings[0].shape[0]
    idx = np.empty((n, k), np.int64)
    prob = np.full((n,), 1.0 / n1, np.float64)
    idx[:, 0] = rng.integers(0, n1, size=n)
    for step in range(k - 1):
        for s in range(0, n, chunk):
            cur = idx[s : s + chunk, step]
            w = pair_weights(
                embeddings[step][cur], embeddings[step + 1], exponent, floor
            )
            nxt, p = _categorical_rows(w, rng)
            idx[s : s + chunk, step + 1] = nxt
            prob[s : s + chunk] *= p
    return WalkSample(idx=idx, prob=prob)


def flat_sample(
    weights: np.ndarray, n: int, rng: np.random.Generator,
    defensive_mix: float = 0.0,
) -> tuple[np.ndarray, np.ndarray]:
    """Sample n positions from ``weights`` (with replacement) with probability
    proportional to weight.  Returns (positions, normalised probabilities).

    ``defensive_mix`` in (0, 1) mixes a uniform component over the *support*
    (weight > 0) into the proposal — defensive importance sampling: the HT
    weight is then bounded by |support| / mix, trading a little efficiency on
    clean weights for bounded variance when the weights are misleading."""
    w = np.asarray(weights, np.float64)
    total = w.sum()
    if total <= 0 or len(w) == 0:
        raise ValueError("cannot sample from empty/zero weights")
    p = w / total
    if defensive_mix > 0.0:
        support = (w > 0).astype(np.float64)
        p = (1.0 - defensive_mix) * p + defensive_mix * support / support.sum()
    cdf = np.cumsum(p)
    cdf[-1] = 1.0
    pos = np.searchsorted(cdf, rng.random(n), side="right")
    pos = np.minimum(pos, len(w) - 1)
    return pos.astype(np.int64), p[pos]


# ----------------------------------------------------------------------------
# Standalone WWJ estimator (Alg. 3): the paper's sampling-only method.
# ----------------------------------------------------------------------------

def ht_terms(values: np.ndarray, probs: np.ndarray) -> np.ndarray:
    """Horvitz-Thompson terms x_i = v_i / p_i; mean over them is unbiased for
    the population total when p is the exact sampling distribution."""
    return np.asarray(values, np.float64) / np.asarray(probs, np.float64)


def clt_ci(x: np.ndarray, p: float) -> tuple[float, ConfidenceInterval]:
    """Normal-approximation CI on the mean of HT terms (Alg. 3 lines 9-10)."""
    from scipy import stats

    x = np.asarray(x, np.float64)
    mu = float(x.mean())
    if len(x) < 2:
        return mu, ConfidenceInterval(-np.inf, np.inf, p)
    se = float(x.std(ddof=1) / np.sqrt(len(x)))
    z = float(stats.norm.ppf(0.5 + p / 2.0))
    return mu, ConfidenceInterval(mu - z * se, mu + z * se, p)
