"""Atomic save / load for the shared label store (``serve.label_store``).

Layout — one directory per segment, addressed by the sha256 of the segment
key's canonical JSON form::

    <root>/<digest>/
        meta.json       # format, canonical key, entry count, dtypes
        keys.npy        # sorted int64 flat tuple keys
        vals.npy        # float64 labels aligned with keys

Guarantees mirror ``checkpoint.index_io`` (the stratification index store
this sits alongside):

  * atomic — written to ``<root>/.tmp_<digest>`` then ``os.replace``'d, so a
    crash mid-save never leaves a partially written segment visible;
  * self-verifying — ``meta.json`` records the canonical key and the entry
    count; :func:`load_segments` cross-checks the digest, the count, and the
    dtypes and raises ``ValueError`` instead of hydrating garbage;
  * pure numpy — no jax import, so a restarting service hydrates its hot
    labels without initialising an accelerator runtime.

Only *stable* segment keys are stored (``label_store.persistable_key``):
tuples of str/int/float/bool, e.g. a named scorer group
``("scorer", "default", 0.5)`` or a wire group ``("wire", "default")`` plus
its encoding.  id()-derived process-local keys never reach this module.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil

import numpy as np

LABEL_STORE_FORMAT = 1


def canonical_key(key) -> list:
    """Segment key (nested tuples) -> the JSON-stable nested-list form."""
    if isinstance(key, (tuple, list)):
        return [canonical_key(k) for k in key]
    return key


def _tuplify(obj):
    if isinstance(obj, list):
        return tuple(_tuplify(o) for o in obj)
    return obj


def segment_digest(key) -> str:
    blob = json.dumps(canonical_key(key), separators=(",", ":"))
    return hashlib.sha256(blob.encode("utf-8")).hexdigest()


def save_segment(root: str, key, keys: np.ndarray,
                 vals: np.ndarray) -> str:
    """Atomic save of one segment (overwrites any previous version of the
    same key).  Returns the final directory."""
    keys = np.ascontiguousarray(np.asarray(keys, np.int64))
    vals = np.ascontiguousarray(np.asarray(vals, np.float64))
    if keys.shape != vals.shape:
        raise ValueError(
            f"segment arrays misaligned: {keys.shape} keys, {vals.shape} vals"
        )
    digest = segment_digest(key)
    os.makedirs(root, exist_ok=True)
    tmp = os.path.join(root, f".tmp_{digest}")
    final = os.path.join(root, digest)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    np.save(os.path.join(tmp, "keys.npy"), keys)
    np.save(os.path.join(tmp, "vals.npy"), vals)
    meta = {
        "format": LABEL_STORE_FORMAT,
        "key": canonical_key(key),
        "count": int(len(keys)),
    }
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def load_segments(root: str) -> list:
    """Every stored segment as ``(key, keys, vals)`` (arrays mmapped
    read-only — the store copies on first merge).  Raises ``ValueError`` on
    format mismatch, digest mismatch, or truncated arrays."""
    out = []
    if not os.path.isdir(root):
        return out
    for d in sorted(os.listdir(root)):
        path = os.path.join(root, d)
        meta_path = os.path.join(path, "meta.json")
        if d.startswith(".") or not os.path.isfile(meta_path):
            continue
        with open(meta_path) as f:
            meta = json.load(f)
        if meta.get("format") != LABEL_STORE_FORMAT:
            raise ValueError(
                f"{path}: label store format {meta.get('format')} != "
                f"{LABEL_STORE_FORMAT}"
            )
        key = _tuplify(meta["key"])
        if segment_digest(key) != d:
            raise ValueError(
                f"{path}: stored key does not hash to its directory name "
                f"— misplaced segment"
            )
        keys = np.load(os.path.join(path, "keys.npy"), mmap_mode="r")
        vals = np.load(os.path.join(path, "vals.npy"), mmap_mode="r")
        if len(keys) != meta["count"] or len(vals) != meta["count"]:
            raise ValueError(
                f"{path}: arrays hold {len(keys)}/{len(vals)} entries, "
                f"manifest says {meta['count']}"
            )
        if keys.dtype != np.int64 or vals.dtype != np.float64:
            raise ValueError(
                f"{path}: dtypes {keys.dtype}/{vals.dtype}, expected "
                f"int64/float64"
            )
        out.append((key, keys, vals))
    return out


__all__ = ["LABEL_STORE_FORMAT", "canonical_key", "segment_digest",
           "save_segment", "load_segments"]
