"""Atomic save / mmap load for stratification index artifacts.

Layout (one directory per content key, one subdirectory per version)::

    <root>/<key>/v_00000001/
        meta.json         # scalar fields, stats, array manifest, format
        counts.npy
        edges.npy
        block_counts.npy
        emb_0.npy ... emb_{k-1}.npy
        topk_vals.npy topk_idx.npy topk_valid.npy   # two-table kernel builds
        row_sums_0.npy ... row_sums_{k-2}.npy       # fp32-effective builds

Guarantees:
  * atomic — written to ``<key>/.tmp_<version>`` then ``os.replace``'d (the
    same crash/preemption posture as ``checkpoint.save``), so a partially
    written artifact is never visible;
  * zero-copy read — arrays load with ``np.load(mmap_mode="r")``: opening an
    index touches only ``meta.json``; tile/top-k/embedding pages fault in as
    queries consume them, so a warm query's load cost is file-open, not a
    table read;
  * self-verifying — ``meta.json`` records the content key and the array
    manifest; :func:`load_index` cross-checks both and raises ``ValueError``
    on truncated or mixed-up directories instead of hydrating garbage;
  * versioned — ``append_rows`` bumps ``IndexArtifact.version``;
    :func:`save_index` writes each version to its own subdirectory and
    :func:`load_index` picks the newest by default, so a reader holding an
    old mmap keeps a consistent snapshot while a refresh lands next to it.

Unlike ``checkpoint.checkpoint`` this module is pure numpy (no jax import):
the serving store and the ``build-index`` launcher load artifacts without
initialising an accelerator runtime.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Optional

import numpy as np

from repro.core.index import INDEX_FORMAT, IndexArtifact

_SCALARS = ("key", "version", "n_bins", "exponent", "floor", "precision",
            "precision_requested", "kernel", "block_rows")
_TOPK = ("topk_vals", "topk_idx", "topk_valid")


def _version_dirs(key_dir: str) -> dict:
    """{version: path} of complete (manifest-bearing) version directories."""
    if not os.path.isdir(key_dir):
        return {}
    out = {}
    for d in os.listdir(key_dir):
        if d.startswith("v_") and d[2:].isdigit() and os.path.isfile(
            os.path.join(key_dir, d, "meta.json")
        ):
            out[int(d[2:])] = os.path.join(key_dir, d)
    return out


def save_index(root: str, art: IndexArtifact, keep_last: int = 2) -> str:
    """Atomic save of one artifact version.  Returns the final directory.
    Old versions beyond ``keep_last`` are pruned (0 keeps everything)."""
    key_dir = os.path.join(root, art.key)
    os.makedirs(key_dir, exist_ok=True)
    tmp = os.path.join(key_dir, f".tmp_{art.version:08d}")
    final = os.path.join(key_dir, f"v_{art.version:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    arrays = {"counts": np.asarray(art.counts),
              "edges": np.asarray(art.edges),
              "block_counts": np.asarray(art.block_counts)}
    for i, e in enumerate(art.embeddings):
        arrays[f"emb_{i}"] = np.asarray(e)
    if art.topk_vals is not None:
        arrays["topk_vals"] = np.asarray(art.topk_vals)
        arrays["topk_idx"] = np.asarray(art.topk_idx)
        arrays["topk_valid"] = np.asarray(art.topk_valid)
    if art.row_sums is not None:
        for j, rs in enumerate(art.row_sums):
            arrays[f"row_sums_{j}"] = np.asarray(rs, np.float64)

    manifest = {}
    for name, arr in arrays.items():
        np.save(os.path.join(tmp, f"{name}.npy"), arr)
        manifest[name] = {"shape": list(arr.shape), "dtype": str(arr.dtype)}
    meta = {s: getattr(art, s) for s in _SCALARS}
    meta.update(
        format=INDEX_FORMAT,
        sizes=list(art.sizes),
        n_tables=len(art.embeddings),
        total_weight=art.total_weight,
        stats=art.stats,
        arrays=manifest,
    )
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)

    if keep_last > 0:
        versions = _version_dirs(key_dir)
        for v in sorted(versions)[:-keep_last]:
            shutil.rmtree(versions[v], ignore_errors=True)
    return final


def latest_version(root: str, key: str) -> Optional[int]:
    versions = _version_dirs(os.path.join(root, key))
    return max(versions) if versions else None


def list_indexes(root: str) -> list:
    """[{key, version, sizes, n_bins, precision}] of every stored artifact
    (newest version per key), sorted by key."""
    out = []
    if not os.path.isdir(root):
        return out
    for key in sorted(os.listdir(root)):
        versions = _version_dirs(os.path.join(root, key))
        if not versions:
            continue
        with open(os.path.join(versions[max(versions)], "meta.json")) as f:
            meta = json.load(f)
        out.append({
            "key": key, "version": max(versions),
            "sizes": tuple(meta["sizes"]), "n_bins": meta["n_bins"],
            "precision": meta["precision"],
        })
    return out


def load_index(root: str, key: str, version: Optional[int] = None,
               mmap: bool = True) -> IndexArtifact:
    """Load one artifact (newest version by default), arrays mmapped
    read-only.  Raises ``FileNotFoundError`` when the key/version is not
    stored, ``ValueError`` when the directory is corrupt (missing arrays,
    manifest/file shape mismatch, or a key that does not match its
    directory)."""
    if version is None:
        version = latest_version(root, key)
        if version is None:
            raise FileNotFoundError(f"no index stored under {root}/{key}")
    path = os.path.join(root, key, f"v_{version:08d}")
    meta_path = os.path.join(path, "meta.json")
    if not os.path.isfile(meta_path):
        raise FileNotFoundError(f"no index version at {path}")
    with open(meta_path) as f:
        meta = json.load(f)
    if meta.get("format") != INDEX_FORMAT:
        raise ValueError(
            f"{path}: index format {meta.get('format')} != {INDEX_FORMAT}"
        )
    if meta["key"] != key:
        raise ValueError(
            f"{path}: stored key {meta['key'][:12]}... does not match "
            f"directory {key[:12]}... — misplaced artifact"
        )

    mode = "r" if mmap else None

    def arr(name):
        fn = os.path.join(path, f"{name}.npy")
        if not os.path.isfile(fn):
            raise ValueError(f"{path}: missing array {name}.npy")
        a = np.load(fn, mmap_mode=mode)
        want = meta["arrays"].get(name)
        if want is None or list(a.shape) != want["shape"] or \
                str(a.dtype) != want["dtype"]:
            raise ValueError(
                f"{path}: array {name} is {a.shape}/{a.dtype}, manifest "
                f"says {want}"
            )
        return a

    embeddings = [arr(f"emb_{i}") for i in range(meta["n_tables"])]
    topk = {n: (arr(n) if n in meta["arrays"] else None) for n in _TOPK}
    row_sums = None
    if "row_sums_0" in meta["arrays"]:
        row_sums = [arr(f"row_sums_{j}")
                    for j in range(meta["n_tables"] - 1)]
    return IndexArtifact(
        key=meta["key"], version=meta["version"],
        sizes=tuple(meta["sizes"]), n_bins=meta["n_bins"],
        exponent=meta["exponent"], floor=meta["floor"],
        precision=meta["precision"],
        precision_requested=meta["precision_requested"],
        kernel=meta["kernel"], block_rows=meta["block_rows"],
        counts=arr("counts"), edges=arr("edges"),
        block_counts=arr("block_counts"),
        embeddings=embeddings,
        topk_vals=topk["topk_vals"], topk_idx=topk["topk_idx"],
        topk_valid=topk["topk_valid"],
        row_sums=row_sums,
        total_weight=meta.get("total_weight"),
        stats=meta.get("stats", {}),
    )
