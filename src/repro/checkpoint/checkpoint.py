"""Sharded, atomic, async checkpointing with cross-mesh restore.

Layout (one directory per step)::

    <root>/step_00000420/
        manifest.json     # tree structure, shapes/dtypes, mesh + spec info
        leaf_00000.npy    # one file per leaf (np.save; bf16 via ml_dtypes)
        ...

Guarantees:
  * atomic — written to ``<root>/.tmp_<step>`` then os.replace'd, so a
    partially written checkpoint is never visible (crash/preemption safe);
  * elastic — restore() device_puts into *whatever* mesh/shardings the new
    job uses, so pod count or parallelism layout can change between runs;
  * async — save_async() snapshots to host then writes on a worker thread,
    keeping the accelerator step loop running (fault-tolerance posture).

At 1000+ nodes each host writes only its addressable shards; this single
process implementation keeps the same manifest format and restore path.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Optional

import jax
import numpy as np


def _flatten(tree):
    return jax.tree_util.tree_flatten_with_path(tree)


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return "/".join(out)


def save(root: str, step: int, tree, extra: Optional[dict] = None) -> str:
    """Synchronous atomic save.  Returns the final checkpoint directory."""
    os.makedirs(root, exist_ok=True)
    tmp = os.path.join(root, f".tmp_{step:08d}")
    final = os.path.join(root, f"step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    (paths_leaves, treedef) = _flatten(tree)
    manifest = {
        "step": step,
        "extra": extra or {},
        "leaves": [],
    }
    for i, (path, leaf) in enumerate(paths_leaves):
        arr = np.asarray(jax.device_get(leaf))
        logical_dtype = str(arr.dtype)
        if arr.dtype.kind == "V" or logical_dtype not in np.sctypeDict:
            # extension dtypes (bfloat16, float8...) -> byte-view for np.save
            arr = arr.view(np.dtype(f"u{arr.dtype.itemsize}"))
        fn = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fn), arr)
        manifest["leaves"].append(
            {
                "path": _path_str(path),
                "file": fn,
                "shape": list(arr.shape),
                "dtype": logical_dtype,
            }
        )
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


class AsyncCheckpointer:
    """Snapshots device arrays to host synchronously, writes on a thread."""

    def __init__(self, root: str, keep_last: int = 3):
        self.root = root
        self.keep_last = keep_last
        self._thread: Optional[threading.Thread] = None
        self.last_saved: Optional[int] = None

    def save(self, step: int, tree, extra: Optional[dict] = None):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            save(self.root, step, host_tree, extra)
            self.last_saved = step
            cleanup(self.root, self.keep_last)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(root: str) -> Optional[int]:
    if not os.path.isdir(root):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(root)
        if d.startswith("step_") and os.path.isfile(os.path.join(root, d, "manifest.json"))
    ]
    return max(steps) if steps else None


def cleanup(root: str, keep_last: int):
    steps = sorted(
        d for d in os.listdir(root)
        if d.startswith("step_") and os.path.isfile(os.path.join(root, d, "manifest.json"))
    )
    for d in steps[:-keep_last] if keep_last > 0 else []:
        shutil.rmtree(os.path.join(root, d), ignore_errors=True)


def restore(root: str, step: int, target_tree, shardings=None):
    """Restore into the structure of ``target_tree`` (arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching tree of
    NamedShardings for the *current* mesh — this is the elastic-resharding
    path (the checkpoint carries no device layout)."""
    ckpt = os.path.join(root, f"step_{step:08d}")
    with open(os.path.join(ckpt, "manifest.json")) as f:
        manifest = json.load(f)
    by_path = {l["path"]: l for l in manifest["leaves"]}
    (paths_leaves, treedef) = _flatten(target_tree)
    shard_leaves = None
    if shardings is not None:
        shard_leaves = treedef.flatten_up_to(shardings)
    out = []
    for i, (path, leaf) in enumerate(paths_leaves):
        key = _path_str(path)
        if key not in by_path:
            raise KeyError(f"checkpoint missing leaf {key}")
        entry = by_path[key]
        arr = np.load(os.path.join(ckpt, entry["file"]))
        if str(arr.dtype) != entry["dtype"]:
            import ml_dtypes  # noqa: F401 -- jax dep; registers extension dtypes

            arr = arr.view(np.dtype(entry["dtype"]))
        expect = tuple(getattr(leaf, "shape", arr.shape))
        if tuple(arr.shape) != expect:
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != {expect}")
        if shard_leaves is not None:
            out.append(jax.device_put(arr, shard_leaves[i]))
        else:
            out.append(jax.numpy.asarray(arr))
    return treedef.unflatten(out), manifest


def restore_latest(root: str, target_tree, shardings=None):
    step = latest_step(root)
    if step is None:
        return None, None
    return restore(root, step, target_tree, shardings)
