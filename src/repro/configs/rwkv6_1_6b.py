"""RWKV6 (Finch) 1.6B [arXiv:2404.05892]: attention-free, data-dependent decay."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b", family="ssm",
    num_layers=24, d_model=2048, num_heads=0, num_kv_heads=0, head_dim=64,
    d_ff=7168, vocab_size=65536,
    rwkv_head_dim=64, rwkv_decay_lora=64, act="silu",
)
