"""The paper's embedding model: a small encoder-style LM whose mean-pooled
hidden state is the record embedding (MiniLM-scale), plus the precision
contract for exporting those embeddings into the similarity kernels."""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="joinml-embedder", family="dense",
    num_layers=6, d_model=384, num_heads=6, num_kv_heads=6, head_dim=64,
    d_ff=1536, vocab_size=32768, tied_embeddings=True, causal=False, act="silu",
)


@dataclasses.dataclass(frozen=True)
class EmbeddingPrecision:
    """How embeddings enter the similarity sweep (``kernels/sim_sweep``).

    ``max_cdf_shift`` is the documented tolerance: the largest sup-distance
    between the low-precision and fp32 weight-histogram CDFs the
    stratifier accepts before falling back to fp32 (0.0 means exact — no
    check needed).  These bounds are asserted by
    ``tests/test_core_stratify.py``."""

    name: str
    dtype: str            # on-wire dtype of the exported embeddings
    per_row_scale: bool   # True when a (N, 1) f32 dequant scale rides along
    max_cdf_shift: float


# Export targets for the sweep's precision fast path.  fp32 is the exact
# default; bf16 feeds the MXU half-precision inputs with f32 accumulation;
# int8 ships per-row symmetric quantisation (see
# ``repro.core.similarity.quantize_rows_int8``) with int32 accumulation.
EMBEDDING_PRECISIONS = {
    "fp32": EmbeddingPrecision("fp32", "float32", False, 0.0),
    "bf16": EmbeddingPrecision("bf16", "bfloat16", False, 0.02),
    "int8": EmbeddingPrecision("int8", "int8", True, 0.02),
}
