"""The paper's embedding model: a small encoder-style LM whose mean-pooled
hidden state is the record embedding (MiniLM-scale)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="joinml-embedder", family="dense",
    num_layers=6, d_model=384, num_heads=6, num_kv_heads=6, head_dim=64,
    d_ff=1536, vocab_size=32768, tied_embeddings=True, causal=False, act="silu",
)
