"""Architecture config registry: the 10 assigned architectures + the paper's
own small Oracle/embedder models.  ``get_config(name)`` returns the full
config; ``get_smoke_config(name)`` the reduced CPU-testable variant.
"""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig, reduced

ARCHS = [
    "qwen2-1.5b",
    "mistral-nemo-12b",
    "llama3.2-1b",
    "llama3-8b",
    "olmoe-1b-7b",
    "qwen3-moe-235b-a22b",
    "whisper-medium",
    "rwkv6-1.6b",
    "pixtral-12b",
    "recurrentgemma-9b",
]

_MODULES = {
    "qwen2-1.5b": "qwen2_1_5b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "llama3.2-1b": "llama3_2_1b",
    "llama3-8b": "llama3_8b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "whisper-medium": "whisper_medium",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "pixtral-12b": "pixtral_12b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "joinml-oracle": "joinml_oracle",
    "joinml-embedder": "joinml_embedder",
}


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def get_smoke_config(name: str, **overrides) -> ModelConfig:
    return reduced(get_config(name), **overrides)
