"""RecurrentGemma-9B [arXiv:2402.19427]: RG-LRU + local attention, pattern
(rec, rec, attn); MQA (kv=1), window 2048."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1, head_dim=256,
    d_ff=12288, vocab_size=256000,
    block_pattern=("rec", "rec", "attn"), rnn_width=4096, conv_width=4,
    window=2048, act="geglu",
)
