"""The paper's Oracle as a small pair-scoring LM (~100M): scores whether two
serialized records satisfy the join condition (entity-match prompt style)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="joinml-oracle", family="dense",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=12, head_dim=64,
    d_ff=3072, vocab_size=32768, tied_embeddings=True, act="silu",
)
