"""Llama-3.2-1B [hf:meta-llama/Llama-3.2-1B]: small llama3 (GQA kv=8)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b", family="dense",
    num_layers=16, d_model=2048, num_heads=32, num_kv_heads=8, head_dim=64,
    d_ff=8192, vocab_size=128256,
    rope_theta=5e5, tied_embeddings=True, act="silu",
)
