"""Whisper-medium [arXiv:2212.04356]: encoder-decoder; conv frontend stubbed —
input_specs provides precomputed (B, 1500, d) frame embeddings."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="encdec",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16, head_dim=64,
    d_ff=4096, vocab_size=51865,
    encoder_layers=24, encoder_seq=1500, act="gelu_mlp",
)
