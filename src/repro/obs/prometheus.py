"""OpenMetrics (Prometheus) exposition over the ``snapshot()`` protocol.

Everything in the serving plane that keeps metrics already exposes one flat
``{dotted.name: float}`` dict — :meth:`Tracker.snapshot`,
:meth:`OracleService.snapshot`, the index/label stores.  This module turns
any number of such sources into an OpenMetrics text exposition and serves
it on a stdlib HTTP endpoint, so a Prometheus scraper can point at a
running service with zero new dependencies:

>>> exp = MetricsExporter([svc.snapshot], port=9464)   # doctest: +SKIP
>>> exp.start()                                         # doctest: +SKIP
... # curl http://localhost:9464/metrics
>>> exp.stop()                                          # doctest: +SKIP

``launch/serve.py --metrics-port N`` wires this up for service mode.

Rendering contract (:func:`render_openmetrics`):

- dotted snapshot names mangle to metric names (``service.window.fill_ratio``
  -> ``repro_service_window_fill_ratio``): every char outside
  ``[a-zA-Z0-9_:]`` becomes ``_``, and a leading digit is prefixed;
- every sample is exported as an untyped ``gauge`` (snapshots are
  point-in-time floats; counters are monotone gauges to a scraper);
- name clashes after mangling merge (last source wins, exactly like
  :func:`repro.obs.merge_snapshots`), non-finite values are dropped, and
  the body ends with the mandatory ``# EOF`` terminator.
"""
from __future__ import annotations

import re
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Callable, Iterable, Optional

__all__ = ["CONTENT_TYPE", "MetricsExporter", "render_openmetrics"]

CONTENT_TYPE = "application/openmetrics-text; version=1.0.0; charset=utf-8"

_MANGLE = re.compile(r"[^a-zA-Z0-9_:]")


def _metric_name(name: str, prefix: str) -> str:
    out = _MANGLE.sub("_", f"{prefix}_{name}" if prefix else name)
    if out and out[0].isdigit():
        out = "_" + out
    return out


def render_openmetrics(snapshot: dict, prefix: str = "repro") -> str:
    """Render one flat snapshot dict as an OpenMetrics text exposition."""
    lines: list[str] = []
    seen: dict[str, float] = {}
    for name, value in snapshot.items():
        try:
            val = float(value)
        except (TypeError, ValueError):
            continue
        if val != val or val in (float("inf"), float("-inf")):
            continue
        seen[_metric_name(str(name), prefix)] = val
    for name in sorted(seen):
        lines.append(f"# TYPE {name} gauge")
        lines.append(f"{name} {seen[name]!r}")
    lines.append("# EOF")
    return "\n".join(lines) + "\n"


class MetricsExporter:
    """A daemon-threaded ``/metrics`` endpoint over snapshot sources.

    ``sources`` is a list of zero-arg callables each returning a flat
    ``{name: float}`` dict (e.g. ``tracker.snapshot`` or
    ``service.snapshot``); they are called fresh on every scrape and merged
    left-to-right.  A source that raises is skipped for that scrape — a
    wedged store must not take down the metrics endpoint."""

    def __init__(self, sources: Iterable[Callable[[], dict]],
                 host: str = "127.0.0.1", port: int = 0,
                 prefix: str = "repro"):
        self.sources = list(sources)
        self.prefix = prefix
        self._httpd = ThreadingHTTPServer((host, port), self._handler())
        self._httpd.daemon_threads = True
        self._thread: Optional[threading.Thread] = None

    @property
    def address(self) -> tuple[str, int]:
        return self._httpd.server_address[:2]

    def render(self) -> str:
        """One merged exposition across all sources (scrape body)."""
        merged: dict = {}
        for src in self.sources:
            try:
                merged.update(src())
            except BaseException:  # noqa: BLE001 — skip a failing source
                continue
        return render_openmetrics(merged, prefix=self.prefix)

    def _handler(self):
        exporter = self

        class Handler(BaseHTTPRequestHandler):
            def do_GET(self):  # noqa: N802 — BaseHTTPRequestHandler API
                if self.path.split("?")[0] not in ("/metrics", "/"):
                    self.send_error(404)
                    return
                body = exporter.render().encode("utf-8")
                self.send_response(200)
                self.send_header("Content-Type", CONTENT_TYPE)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *args):  # silence per-scrape stderr noise
                pass

        return Handler

    def start(self) -> "MetricsExporter":
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="metrics-exporter",
            daemon=True,
        )
        self._thread.start()
        return self

    def stop(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None

    def __enter__(self) -> "MetricsExporter":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
