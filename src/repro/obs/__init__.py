"""Observability for the serving data plane: pluggable metric trackers and
the typed per-query telemetry tree.

- :mod:`repro.obs.tracker` — the :class:`Tracker` protocol plus noop,
  in-memory, and JSON-lines implementations (counters, gauges, streaming
  p50/p99 histograms with bounded memory).
- :mod:`repro.obs.telemetry` — :class:`QueryTelemetry`, the typed successor
  to ``QueryResult.detail``, with a deprecation-shimmed dict view.
- :mod:`repro.obs.prometheus` — OpenMetrics text rendering of any
  ``snapshot()`` dict plus a stdlib HTTP ``/metrics`` exporter.
"""
from .prometheus import MetricsExporter, render_openmetrics
from .telemetry import (
    CascadeTelemetry,
    DispatchTelemetry,
    IndexTelemetry,
    OracleTelemetry,
    QueryTelemetry,
    StoreTelemetry,
    StratifyTelemetry,
    TelemetryView,
)
from .tracker import (
    NULL_TRACKER,
    InMemoryTracker,
    JsonlTracker,
    NoopTracker,
    StreamingHistogram,
    Tracker,
    make_tracker,
    merge_snapshots,
)

__all__ = [
    "CascadeTelemetry",
    "DispatchTelemetry",
    "IndexTelemetry",
    "InMemoryTracker",
    "JsonlTracker",
    "MetricsExporter",
    "NULL_TRACKER",
    "NoopTracker",
    "OracleTelemetry",
    "QueryTelemetry",
    "StoreTelemetry",
    "StratifyTelemetry",
    "StreamingHistogram",
    "TelemetryView",
    "Tracker",
    "make_tracker",
    "merge_snapshots",
    "render_openmetrics",
]
