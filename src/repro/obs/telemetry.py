"""Typed per-query telemetry: the structured successor to ``QueryResult.detail``.

Historically every pipeline stage appended free-form keys to a nested dict
(``detail["oracle"]["store_hits"]``, ``detail["stratify"]["index_hit"]``, ...),
so consumers had to know each producer's private spelling.
:class:`QueryTelemetry` replaces that with a small dataclass tree — ``oracle``,
``store``, ``stratify``, ``index``, and ``dispatch`` sections with stable field
names — while :class:`TelemetryView` keeps the old dict shape alive as a
deprecation-shimmed *view*: reads materialise from the typed tree and writes
parse back into it, so pre-existing callers (and tests) work unchanged.

Variable-shape producer payloads (per-kernel sweep statistics, baseline-mode
extras) land in ``extra`` dicts on the owning section rather than being lost,
so the round trip ``QueryTelemetry.from_detail(d).as_detail() == d`` holds for
every dict the pipelines emit.
"""
from __future__ import annotations

import dataclasses
import warnings
from collections.abc import MutableMapping
from typing import Any, Optional


@dataclasses.dataclass
class OracleTelemetry:
    """Ledger counters from :meth:`repro.core.oracle.Oracle.stats`."""

    calls: int = 0
    requests: int = 0
    batches: int = 0
    charged: int = 0
    dedup_ratio: float = 0.0
    extra: dict[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class StoreTelemetry:
    """Shared label-store effect on this query's ledger."""

    hits: int = 0            # legacy ``oracle.store_hits``
    charge_saved: int = 0    # legacy ``oracle.store_charge_saved``


@dataclasses.dataclass
class StratifyTelemetry:
    """Which stratification path ran and its kernel/sweep statistics."""

    path: str = ""           # dense-sort | sweep | two-pass | index
    extra: dict[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class IndexTelemetry:
    """Persistent stratification-index accounting (PR 6)."""

    hit: bool = False
    version: int = 0
    delta_blocks: int = 0
    build_ms: Optional[float] = None   # only set when this query built


@dataclasses.dataclass
class DispatchTelemetry:
    """The auto-dispatch decision (``run_auto``) and its inputs."""

    path: str = ""
    dense_weight_bytes: int = 0
    max_dense_weight_bytes: int = 0
    n_tuples: int = 0
    sweep: bool = True
    sweep_precision: str = "fp32"
    index_store: bool = False
    extra: dict[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class CascadeTelemetry:
    """Per-stage counters of the multi-fidelity cascade (``core/cascade.py``).

    ``proxy_*`` is the cheap unmetered stage, ``oracle_calls`` the expensive
    ledger the §2 budget binds; ``*_group`` record the distinct
    ``service_group()`` keys the two stages super-batch under."""

    proxy_calls: int = 0
    proxy_requests: int = 0
    oracle_calls: int = 0
    proxy_rows: int = 0
    correction_rows: int = 0
    disagreement_rate: float = 0.0
    proxy_group: str = ""
    oracle_group: str = ""
    extra: dict[str, Any] = dataclasses.field(default_factory=dict)


_INDEX_KEYS = ("index_hit", "index_version", "delta_blocks", "index_build_ms")
_SCALAR_FIELDS = ("beta", "num_strata", "stratum_sizes", "pilot_n", "est_mse")


@dataclasses.dataclass
class QueryTelemetry:
    """Typed telemetry for one query execution.

    Sections are ``None`` when the corresponding stage did not run (e.g.
    ``stratify`` on an exact scan, ``index`` without an index store); the
    legacy dict view omits absent sections so ``"stratify" in res.detail``
    keeps meaning what it always did.
    """

    mode: str = ""
    oracle: Optional[OracleTelemetry] = None
    store: Optional[StoreTelemetry] = None
    stratify: Optional[StratifyTelemetry] = None
    index: Optional[IndexTelemetry] = None
    dispatch: Optional[DispatchTelemetry] = None
    cascade: Optional[CascadeTelemetry] = None
    beta: Optional[list] = None
    num_strata: Optional[int] = None
    stratum_sizes: Optional[list] = None
    pilot_n: Optional[list] = None
    est_mse: Optional[float] = None
    timings: dict[str, float] = dataclasses.field(default_factory=dict)
    extra: dict[str, Any] = dataclasses.field(default_factory=dict)

    # ------------------------------------------------------------------ parse
    @classmethod
    def from_detail(cls, detail: dict | None) -> "QueryTelemetry":
        """Parse a legacy ``QueryResult.detail`` dict into the typed tree."""
        t = cls()
        for key, value in (detail or {}).items():
            t._set_legacy(key, value)
        return t

    def _set_legacy(self, key: str, value) -> None:
        if key == "mode":
            self.mode = str(value)
        elif key == "oracle" and isinstance(value, dict):
            self._parse_oracle(value)
        elif key == "stratify" and isinstance(value, dict):
            self._parse_stratify(value)
        elif key == "dispatch" and isinstance(value, dict):
            self._parse_dispatch(value)
        elif key == "cascade" and isinstance(value, dict):
            self._parse_cascade(value)
        elif key == "timings" and isinstance(value, dict):
            self.timings = dict(value)
        elif key in _SCALAR_FIELDS:
            setattr(self, key, value)
        else:
            self.extra[key] = value

    def _parse_oracle(self, stats: dict) -> None:
        stats = dict(stats)
        if "store_hits" in stats or "store_charge_saved" in stats:
            self.store = StoreTelemetry(
                hits=int(stats.pop("store_hits", 0)),
                charge_saved=int(stats.pop("store_charge_saved", 0)),
            )
        known = {f.name for f in dataclasses.fields(OracleTelemetry)} - {"extra"}
        self.oracle = OracleTelemetry(
            **{k: stats.pop(k) for k in list(stats) if k in known},
            extra=stats,
        )

    def _parse_stratify(self, meta: dict) -> None:
        meta = dict(meta)
        if "index_hit" in meta:
            self.index = IndexTelemetry(
                hit=bool(meta.pop("index_hit")),
                version=int(meta.pop("index_version", 0)),
                delta_blocks=int(meta.pop("delta_blocks", 0)),
                build_ms=meta.pop("index_build_ms", None),
            )
        self.stratify = StratifyTelemetry(path=str(meta.pop("path", "")),
                                          extra=meta)

    def _parse_dispatch(self, d: dict) -> None:
        d = dict(d)
        known = {f.name for f in dataclasses.fields(DispatchTelemetry)} - {"extra"}
        self.dispatch = DispatchTelemetry(
            **{k: d.pop(k) for k in list(d) if k in known},
            extra=d,
        )

    def _parse_cascade(self, d: dict) -> None:
        d = dict(d)
        known = {f.name for f in dataclasses.fields(CascadeTelemetry)} - {"extra"}
        self.cascade = CascadeTelemetry(
            **{k: d.pop(k) for k in list(d) if k in known},
            extra=d,
        )

    # ------------------------------------------------------------ materialise
    def as_detail(self) -> dict:
        """The legacy nested-dict shape, rebuilt from the typed tree."""
        d: dict[str, Any] = {}
        if self.mode:
            d["mode"] = self.mode
        d.update(self.extra)
        if self.stratify is not None:
            meta: dict[str, Any] = {"path": self.stratify.path}
            meta.update(self.stratify.extra)
            if self.index is not None:
                meta["index_hit"] = self.index.hit
                meta["index_version"] = self.index.version
                meta["delta_blocks"] = self.index.delta_blocks
                if self.index.build_ms is not None:
                    meta["index_build_ms"] = self.index.build_ms
            d["stratify"] = meta
        for name in _SCALAR_FIELDS:
            value = getattr(self, name)
            if value is not None:
                d[name] = value
        if self.timings:
            d["timings"] = self.timings
        if self.oracle is not None:
            stats: dict[str, Any] = {
                "calls": self.oracle.calls,
                "requests": self.oracle.requests,
                "batches": self.oracle.batches,
                "charged": self.oracle.charged,
            }
            if self.store is not None:
                stats["store_hits"] = self.store.hits
                stats["store_charge_saved"] = self.store.charge_saved
            stats["dedup_ratio"] = self.oracle.dedup_ratio
            stats.update(self.oracle.extra)
            d["oracle"] = stats
        if self.dispatch is not None:
            dd = {f.name: getattr(self.dispatch, f.name)
                  for f in dataclasses.fields(DispatchTelemetry)
                  if f.name != "extra"}
            dd.update(self.dispatch.extra)
            d["dispatch"] = dd
        if self.cascade is not None:
            cc = {f.name: getattr(self.cascade, f.name)
                  for f in dataclasses.fields(CascadeTelemetry)
                  if f.name != "extra"}
            cc.update(self.cascade.extra)
            d["cascade"] = cc
        return d


_warned = False


def _warn_detail_deprecated() -> None:
    global _warned
    if not _warned:
        _warned = True
        warnings.warn(
            "QueryResult.detail is deprecated; use the typed "
            "QueryResult.telemetry tree (repro.obs.QueryTelemetry) instead",
            DeprecationWarning, stacklevel=3,
        )


class TelemetryView(MutableMapping):
    """Dict-shaped, write-through view over a :class:`QueryTelemetry`.

    Reads materialise the legacy nested shape from the typed tree; top-level
    writes (``view["dispatch"] = {...}``) parse back into it.  Nested values
    are returned as plain dicts — mutate through a top-level assignment, or
    better, through ``result.telemetry`` directly.
    """

    __slots__ = ("_t",)

    def __init__(self, telemetry: QueryTelemetry):
        self._t = telemetry

    def __getitem__(self, key: str):
        d = self._t.as_detail()
        return d[key]

    def __setitem__(self, key: str, value) -> None:
        self.__delitem__(key) if key in self else None
        self._t._set_legacy(key, value)

    def __delitem__(self, key: str) -> None:
        t = self._t
        if key == "mode":
            t.mode = ""
        elif key == "oracle":
            t.oracle = t.store = None
        elif key == "stratify":
            t.stratify = t.index = None
        elif key == "dispatch":
            t.dispatch = None
        elif key == "cascade":
            t.cascade = None
        elif key == "timings":
            t.timings = {}
        elif key in _SCALAR_FIELDS:
            setattr(t, key, None)
        elif key in t.extra:
            del t.extra[key]
        else:
            raise KeyError(key)

    def __iter__(self):
        return iter(self._t.as_detail())

    def __len__(self) -> int:
        return len(self._t.as_detail())

    def __repr__(self) -> str:
        return f"TelemetryView({self._t.as_detail()!r})"
