"""Pluggable metric trackers for the serving data plane.

The serving substrate (``OracleService``, the TCP transport, the label and
index stores) emits three kinds of signals: monotone **counters** (windows
dispatched, reconnects, admission rejections), point-in-time **gauges**
(in-flight request depth), and latency/ratio **observations** that need
quantiles (window assembly latency, per-host shard latency, per-class
end-to-end flush latency).  A :class:`Tracker` receives all three through a
small protocol — ``count`` / ``gauge`` / ``observe`` / ``event`` — and folds
them into one flat ``snapshot() -> dict[str, float]`` with namespaced dotted
keys (``service.window.fill``, ``transport.rtt_ms.p99``, ...).

Three implementations ship here:

- :class:`NoopTracker` — the default everywhere; every hook is a no-op so
  uninstrumented paths pay one virtual call and nothing else.
- :class:`InMemoryTracker` — thread-safe dicts of counters/gauges plus
  :class:`StreamingHistogram` per observed series: bounded memory (a ring of
  the last-N observations) with lifetime count/sum/min/max, so ``p50``/``p99``
  reflect steady state rather than warmup.
- :class:`JsonlTracker` — an :class:`InMemoryTracker` that additionally
  appends one JSON object per signal to a file; CI uploads this as the
  smoke-bench artifact.

Observations are wall-clock agnostic: callers time with
``time.perf_counter()`` and pass milliseconds (suffix the series ``_ms``) or
dimensionless ratios.  All trackers are safe to share across the dispatcher,
worker-pool, health-check, and client threads.
"""
from __future__ import annotations

import json
import math
import threading
import time
from typing import Iterable, Protocol, runtime_checkable


class StreamingHistogram:
    """Streaming quantile sketch with bounded memory.

    Keeps lifetime ``count``/``total``/``min``/``max`` plus a ring buffer of
    the last ``window`` observations; quantiles are computed over the ring, so
    ``p50``/``p99`` track the *recent* distribution (steady state) while
    ``mean`` stays lifetime.  Not thread-safe on its own — the owning tracker
    serialises access.
    """

    __slots__ = ("window", "count", "total", "vmin", "vmax", "_ring", "_pos")

    def __init__(self, window: int = 512):
        if window <= 0:
            raise ValueError(f"window must be positive, got {window}")
        self.window = window
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf
        self._ring: list[float] = []
        self._pos = 0

    def observe(self, value: float) -> None:
        value = float(value)
        self.count += 1
        self.total += value
        if value < self.vmin:
            self.vmin = value
        if value > self.vmax:
            self.vmax = value
        if len(self._ring) < self.window:
            self._ring.append(value)
        else:
            self._ring[self._pos] = value
            self._pos = (self._pos + 1) % self.window

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        """Quantile over the retained window (nearest-rank interpolation)."""
        if not self._ring:
            return 0.0
        vals = sorted(self._ring)
        idx = q * (len(vals) - 1)
        lo = int(idx)
        hi = min(lo + 1, len(vals) - 1)
        frac = idx - lo
        return vals[lo] * (1.0 - frac) + vals[hi] * frac

    def recent_mean(self) -> float:
        """Mean over the retained window only (the last-N observations)."""
        if not self._ring:
            return 0.0
        return sum(self._ring) / len(self._ring)

    def snapshot(self, name: str) -> dict[str, float]:
        if not self.count:
            return {}
        return {
            f"{name}.count": float(self.count),
            f"{name}.mean": self.mean,
            f"{name}.p50": self.quantile(0.50),
            f"{name}.p99": self.quantile(0.99),
            f"{name}.max": self.vmax,
        }


@runtime_checkable
class Tracker(Protocol):
    """What the serving layers require of a metrics sink.

    Implementations must be thread-safe: the dispatcher, pool workers, the
    health-check thread, and client threads all emit concurrently.
    """

    def count(self, name: str, value: int = 1) -> None:
        """Add ``value`` to the monotone counter ``name``."""

    def gauge(self, name: str, value: float) -> None:
        """Set the point-in-time gauge ``name``."""

    def observe(self, name: str, value: float) -> None:
        """Record one sample of the distribution series ``name``."""

    def event(self, name: str, **fields) -> None:
        """Record a discrete occurrence (worker death/rejoin, reconnect)."""

    def snapshot(self) -> dict[str, float]:
        """Flat ``{dotted.name: value}`` view of everything recorded."""

    def close(self) -> None:
        """Flush and release any underlying resources."""


class NoopTracker:
    """Default tracker: every hook is a no-op (the uninstrumented fast path)."""

    def count(self, name: str, value: int = 1) -> None:
        pass

    def gauge(self, name: str, value: float) -> None:
        pass

    def observe(self, name: str, value: float) -> None:
        pass

    def event(self, name: str, **fields) -> None:
        pass

    def snapshot(self) -> dict[str, float]:
        return {}

    def close(self) -> None:
        pass


NULL_TRACKER = NoopTracker()


class InMemoryTracker:
    """Thread-safe in-process tracker: counters, gauges, and one bounded
    :class:`StreamingHistogram` per observed series."""

    def __init__(self, window: int = 512):
        self._lock = threading.Lock()
        self._window = window
        self._counters: dict[str, float] = {}
        self._gauges: dict[str, float] = {}
        self._hists: dict[str, StreamingHistogram] = {}
        self._events: dict[str, int] = {}

    def count(self, name: str, value: int = 1) -> None:
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + value

    def gauge(self, name: str, value: float) -> None:
        with self._lock:
            self._gauges[name] = float(value)

    def observe(self, name: str, value: float) -> None:
        with self._lock:
            hist = self._hists.get(name)
            if hist is None:
                hist = self._hists[name] = StreamingHistogram(self._window)
            hist.observe(value)

    def event(self, name: str, **fields) -> None:
        with self._lock:
            self._events[name] = self._events.get(name, 0) + 1

    def histogram(self, name: str) -> StreamingHistogram | None:
        """The live histogram for ``name`` (None if never observed)."""
        with self._lock:
            return self._hists.get(name)

    def snapshot(self) -> dict[str, float]:
        with self._lock:
            out: dict[str, float] = dict(self._counters)
            out.update(self._gauges)
            for name, n in self._events.items():
                out[f"{name}.events"] = float(n)
            for name, hist in self._hists.items():
                out.update(hist.snapshot(name))
        return out

    def close(self) -> None:
        pass


class JsonlTracker(InMemoryTracker):
    """An :class:`InMemoryTracker` that also appends one JSON object per
    signal to ``path`` — the artifact CI's smoke-bench job uploads.

    Lines are ``{"ts": epoch_s, "kind": count|gauge|observe|event,
    "name": ..., "value": ...}`` plus any event fields; ``snapshot`` rows are
    not written (re-derive them from the stream or call :meth:`snapshot`).
    """

    def __init__(self, path, window: int = 512, flush_every: int = 64):
        super().__init__(window=window)
        self._path = str(path)
        self._file = open(self._path, "a", encoding="utf-8")
        self._flush_every = max(1, flush_every)
        self._written = 0
        self._io_lock = threading.Lock()

    @property
    def path(self) -> str:
        return self._path

    def _emit(self, kind: str, name: str, value, fields: dict | None = None):
        rec = {"ts": time.time(), "kind": kind, "name": name, "value": value}
        if fields:
            rec.update(fields)
        line = json.dumps(rec, default=str)
        with self._io_lock:
            if self._file.closed:
                return
            self._file.write(line + "\n")
            self._written += 1
            if self._written % self._flush_every == 0:
                self._file.flush()

    def count(self, name: str, value: int = 1) -> None:
        super().count(name, value)
        self._emit("count", name, value)

    def gauge(self, name: str, value: float) -> None:
        super().gauge(name, value)
        self._emit("gauge", name, float(value))

    def observe(self, name: str, value: float) -> None:
        super().observe(name, value)
        self._emit("observe", name, float(value))

    def event(self, name: str, **fields) -> None:
        super().event(name, **fields)
        self._emit("event", name, 1, fields)

    def close(self) -> None:
        with self._io_lock:
            if not self._file.closed:
                self._file.flush()
                self._file.close()


def make_tracker(kind: str, path=None, window: int = 512):
    """Factory used by launchers/benches: ``none`` | ``memory`` | ``jsonl``."""
    if kind in (None, "", "none", "noop"):
        return NoopTracker()
    if kind == "memory":
        return InMemoryTracker(window=window)
    if kind == "jsonl":
        if path is None:
            raise ValueError("jsonl tracker requires an output path")
        return JsonlTracker(path, window=window)
    raise ValueError(f"unknown tracker kind {kind!r}")


def merge_snapshots(*parts: Iterable[tuple[str, float]] | dict) -> dict[str, float]:
    """Merge snapshot dicts left-to-right (later parts win on key clashes)."""
    out: dict[str, float] = {}
    for part in parts:
        if part:
            out.update(part)
    return out
