"""Async oracle serving substrate: cross-query coalescing between
``OracleBatch.flush()`` and the scorer-worker pool.

Why
---
The paper's cost model makes the ML Oracle the dominant expense, so the
serving layer must keep the scorer saturated.  The batched execution layer
(``repro.core.oracle``) already coalesces each *query's* labelling into a
handful of flushes — but concurrent queries still serialize on one scorer,
and every flush blocks its query until the backend returns.  This module
turns the oracle layer from a per-query library into a shared serving
subsystem: one :class:`OracleService` feeds any number of concurrent queries.

Architecture
------------
::

    query 1 ── OracleBatch.flush_async() ──┐          (request queue)
    query 2 ── OracleBatch.flush_async() ──┼──►  ┌────────────────────┐
      ...                                  │     │  dispatcher thread  │
    query N ── OracleBatch.flush_async() ──┘     │  window assembly:   │
                                                 │  size- & deadline-  │
                 future.result() ◄── per-client  │  triggered flush    │
                 (labels resolved,   routing     └─────────┬──────────┘
                  ledger charged                           │ super-batch
                  atomically)                              ▼ (grouped by
                                                 ┌────────────────────┐
                                                 │  scorer worker pool │
                                                 │  shard 0 … shard W  │
                                                 │  (threads; each     │
                                                 │  scorer may itself  │
                                                 │  be mesh-sharded    │
                                                 │  via data_parallel) │
                                                 └────────────────────┘

* **Clients** are ordinary :class:`~repro.core.oracle.OracleBatch` objects.
  ``service.attach(oracle)`` routes that oracle's flushes here;
  ``flush_async()`` enqueues the pending request set and returns a future.
  Each query keeps its own Oracle (cache + budget ledger) — the service
  never mixes ledgers.
* The **dispatcher** assembles micro-batch *windows*: a window opens when the
  first flush arrives and closes when (a) the accumulated rows reach
  ``max_batch``, (b) ``max_wait_ms`` elapses, or (c) every attached client
  already has a flush in the window (nobody left to wait for).  A single
  attached client dispatches immediately — solo queries pay no windowing
  latency.
* Each window's segments are **planned sequentially in arrival order** with
  exactly the local-flush semantics: encode at flush time, dedup against the
  client's cache (and against earlier same-oracle segments in the window),
  check the budget.  Planning failures (:class:`BudgetExceeded`, encode
  errors) complete only that client's future; its requests return to the
  batch so the flush can be retried — one query's exhaustion never poisons
  another's batch.
* Planned rows are grouped by :meth:`Oracle.service_group` — oracles scoring
  through the same served model fuse into one **super-batch** per window —
  and each group is sharded over the worker pool.  Workers are threads (the
  backends release the GIL in numpy/XLA); each worker executes shards via
  the group's own ``_label``, and a :class:`~repro.serve.serve_loop.PairScorer`
  backend constructed with ``mesh=`` additionally shards every shard's batch
  dimension over the device mesh via ``launch.sharding.data_parallel`` —
  thread workers scale across hosts' independent scorers, the mesh path
  scales across one host's devices.  A backend error fails exactly the
  segments of that group (retryable), leaving other groups' results intact.
* **Commit** happens after execution, per segment in arrival order: merge the
  new labels into the client's cache, charge its ledger atomically, resolve
  the request handles, complete the future.

Remaining for multi-host dispatch (see ROADMAP "Serving architecture"): a
network transport in front of ``submit`` and a worker pool spanning hosts;
the window/plan/commit machinery here is transport-agnostic.
"""
from __future__ import annotations

import dataclasses
import threading
import time
import weakref
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Callable, Optional

import numpy as np

from repro.core.oracle import (
    Oracle,
    OracleBatch,
    commit_requests,
    plan_requests,
)


@dataclasses.dataclass
class _Segment:
    """One enqueued flush: a client batch's pending set plus its future."""

    batch: OracleBatch
    oracle: Oracle
    requests: list
    future: Future
    rows: int

    def fail(self, exc: BaseException) -> None:
        """Complete exceptionally and hand the requests back to the batch so
        the same flush can be retried (mirrors local-flush atomicity)."""
        self.batch._pending = self.requests + self.batch._pending
        self.future.set_exception(exc)


@dataclasses.dataclass
class _Plan:
    """A successfully planned segment, ready for group execution."""

    seg: _Segment
    keys_list: list            # per-request encoded keys
    n_requested: int           # total rows incl. cache hits
    new_keys: np.ndarray       # unique uncached keys this segment labels
    new_idx: np.ndarray        # decoded (n_new, k) tuple indices
    vals: Optional[np.ndarray] = None   # labels for new_keys (set by execute)


class OracleService:
    """Micro-batching request broker between OracleBatch clients and a pool
    of scorer workers (module docstring has the full architecture).

    Parameters
    ----------
    workers:
        Worker threads sharding each super-batch.  Shards run the group's
        vectorised ``_label`` concurrently; backends must be pure per row
        (true for every Oracle here — labels are per-tuple).
    max_batch:
        Row-count window trigger: a window dispatches as soon as its
        accumulated request rows reach this.
    max_wait_ms:
        Deadline window trigger: maximum time the dispatcher waits after the
        first flush of a window for more clients to arrive.
    min_shard:
        Smallest shard worth its own worker; groups below ``2 * min_shard``
        rows execute unsharded (sharding a padded scorer batch too finely
        wastes pad rows).
    """

    def __init__(self, workers: int = 1, max_batch: int = 8192,
                 max_wait_ms: float = 4.0, min_shard: int = 256):
        self.workers = max(int(workers), 1)
        self.max_batch = int(max_batch)
        self.max_wait_s = float(max_wait_ms) / 1e3
        self.min_shard = max(int(min_shard), 1)
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._queue: list[_Segment] = []
        # weak: an attached oracle that is dropped without detach must not
        # stall window assembly (or alias a recycled address) forever
        self._clients: "weakref.WeakSet[Oracle]" = weakref.WeakSet()
        self._closed = False
        self._pool: Optional[ThreadPoolExecutor] = (
            ThreadPoolExecutor(max_workers=self.workers,
                               thread_name_prefix="oracle-worker")
            if self.workers > 1 else None
        )
        # observability (read via stats(); written only by the dispatcher)
        self.windows = 0
        self.segments = 0
        self.backend_calls = 0
        self.rows_requested = 0
        self.rows_labelled = 0
        self._dispatcher = threading.Thread(
            target=self._run, name="oracle-service", daemon=True
        )
        self._dispatcher.start()

    # ---- client lifecycle --------------------------------------------------

    def attach(self, *oracles: Oracle) -> "OracleService":
        """Route the oracles' flushes through this service.  The attached set
        also drives window assembly: a window closes early once every
        attached client has a flush in it."""
        with self._cv:
            if self._closed:
                raise RuntimeError("OracleService is closed")
            for o in oracles:
                o.service = self
                self._clients.add(o)
        return self

    def detach(self, *oracles: Oracle) -> None:
        """Return the oracles to local (synchronous) flushing.  Detaching
        finished queries keeps windows from waiting on clients that will
        never flush again."""
        with self._cv:
            for o in oracles:
                if o.service is self:
                    o.service = None
                self._clients.discard(o)
            self._cv.notify_all()

    def submit(self, batch: OracleBatch) -> Future:
        """Enqueue a batch's pending set; called by ``flush_async``.  The
        caller must not touch the batch again until the future resolves
        (one outstanding flush per batch — the submit-then-await protocol
        every pipeline stage follows)."""
        requests, batch._pending = batch._pending, []
        seg = _Segment(
            batch=batch, oracle=batch.oracle, requests=requests,
            future=Future(), rows=sum(len(r.idx) for r in requests),
        )
        with self._cv:
            if self._closed:
                batch._pending = requests
                raise RuntimeError("OracleService is closed")
            self._queue.append(seg)
            self._cv.notify_all()
        return seg.future

    def close(self) -> None:
        """Drain the queue, stop the dispatcher, shut the worker pool."""
        with self._cv:
            self._closed = True
            self._cv.notify_all()
        self._dispatcher.join()
        if self._pool is not None:
            self._pool.shutdown(wait=True)

    def __enter__(self) -> "OracleService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def stats(self) -> dict:
        return {
            "windows": self.windows,
            "segments": self.segments,
            "backend_calls": self.backend_calls,
            "rows_requested": self.rows_requested,
            "rows_labelled": self.rows_labelled,
            "segments_per_window": round(
                self.segments / max(self.windows, 1), 2
            ),
        }

    # ---- dispatcher --------------------------------------------------------

    def _run(self) -> None:
        while True:
            with self._cv:
                while not self._queue and not self._closed:
                    self._cv.wait()
                if not self._queue:
                    return                       # closed and drained
                window = [self._queue.pop(0)]
                rows = window[0].rows
                deadline = time.monotonic() + self.max_wait_s
                while rows < self.max_batch:
                    if self._queue:
                        seg = self._queue.pop(0)
                        window.append(seg)
                        rows += seg.rows
                        continue
                    present = {id(s.oracle) for s in window}
                    waiting = any(
                        id(o) not in present for o in self._clients
                    )
                    remain = deadline - time.monotonic()
                    if self._closed or remain <= 0 or not waiting:
                        break                    # nobody left to wait for
                    self._cv.wait(remain)
            try:
                self._process(window)
            except BaseException as e:  # noqa: BLE001 — dispatcher must survive
                for seg in window:
                    if not seg.future.done():
                        seg.fail(e)

    # ---- window processing -------------------------------------------------

    def _process(self, window: list[_Segment]) -> None:
        self.windows += 1
        self.segments += len(window)
        plans = self._plan(window)
        groups: dict = {}
        for plan in plans:
            groups.setdefault(plan.seg.oracle.service_group(), []).append(plan)
        for group in groups.values():
            self._execute_group(group)
        for plan in plans:                       # commit in arrival order
            if plan.seg.future.done():           # its group failed
                continue
            self._commit(plan)

    def _plan(self, window: list[_Segment]) -> list[_Plan]:
        """Per-segment dedup + budget check via the shared
        :func:`repro.core.oracle.plan_requests` (exactly local-flush
        semantics).  Earlier same-oracle segments in the window count as
        cached-to-be (same-oracle segments always share a service group, so
        they execute — and later commit — together or fail together)."""
        plans: list[_Plan] = []
        planned: dict[int, list[np.ndarray]] = {}   # id(oracle) -> key arrays
        for seg in window:
            o = seg.oracle
            try:
                prior = planned.get(id(o))
                keys_list, n_requested, new_keys = plan_requests(
                    o, seg.requests,
                    extra_planned=np.concatenate(prior) if prior else None,
                )
                plans.append(_Plan(
                    seg=seg, keys_list=keys_list, n_requested=n_requested,
                    new_keys=new_keys, new_idx=o._decode(new_keys),
                ))
                if len(new_keys):
                    planned.setdefault(id(o), []).append(new_keys)
            except BaseException as e:  # noqa: BLE001 — isolate per client
                seg.fail(e)
        return plans

    def _execute_group(self, group: list[_Plan]) -> None:
        """Concatenate a group's new rows into one super-batch, shard it over
        the worker pool, and scatter labels back per plan.  A backend error
        fails every segment of this group and only this group."""
        lens = [len(p.new_idx) for p in group]
        total = sum(lens)
        if total == 0:
            return
        idx = np.concatenate([p.new_idx for p in group if len(p.new_idx)])
        fn = group[0].seg.oracle._label     # same group => same pure backend
        try:
            vals = self._execute(fn, idx)
            if vals.shape != (total,):
                raise RuntimeError(
                    f"backend returned shape {vals.shape} for {total} rows"
                )
        except BaseException as e:  # noqa: BLE001 — isolate per group
            for p in group:
                p.seg.fail(e)
            return
        self.rows_labelled += total
        off = 0
        for p, n in zip(group, lens):
            p.vals = vals[off:off + n]
            off += n

    def _execute(self, fn: Callable, idx: np.ndarray) -> np.ndarray:
        n_shards = min(self.workers, len(idx) // self.min_shard)
        if self._pool is None or n_shards < 2:
            self.backend_calls += 1
            return np.asarray(fn(idx), np.float64)
        shards = np.array_split(idx, n_shards)
        self.backend_calls += n_shards
        futs = [self._pool.submit(fn, s) for s in shards]
        return np.concatenate(
            [np.asarray(f.result(), np.float64) for f in futs]
        )

    def _commit(self, plan: _Plan) -> None:
        """Atomic ledger charge + cache merge + per-client result routing via
        the shared :func:`repro.core.oracle.commit_requests`.  Runs only
        after the group's backend execution succeeded, so a failure anywhere
        earlier leaves this client's oracle untouched."""
        commit_requests(
            plan.seg.oracle, plan.seg.requests, plan.keys_list,
            plan.n_requested, plan.new_keys, plan.vals,
        )
        self.rows_requested += plan.n_requested
        plan.seg.future.set_result(None)


def serve_queries(service: OracleService, jobs: list) -> list:
    """Run ``jobs`` — callables ``job() -> result`` each owning one attached
    oracle — concurrently against one service.  Convenience for entry points
    and benchmarks: threads map 1:1 to queries (each blocks in
    ``future.result()`` while the service batches), results keep job order,
    and the first job exception propagates after all threads join.
    """
    results: list = [None] * len(jobs)
    errors: list = []

    def runner(i: int, job) -> None:
        try:
            results[i] = job()
        except BaseException as e:  # noqa: BLE001 — re-raised after join
            errors.append(e)

    threads = [
        threading.Thread(target=runner, args=(i, job), daemon=True)
        for i, job in enumerate(jobs)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    if errors:
        raise errors[0]
    return results


__all__ = ["OracleService", "serve_queries"]
