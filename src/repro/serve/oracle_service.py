"""Async oracle serving substrate: cross-query coalescing between
``OracleBatch.flush()`` and the scorer-worker pool.

Why
---
The paper's cost model makes the ML Oracle the dominant expense, so the
serving layer must keep the scorer saturated.  The batched execution layer
(``repro.core.oracle``) already coalesces each *query's* labelling into a
handful of flushes — but concurrent queries still serialize on one scorer,
and every flush blocks its query until the backend returns.  This module
turns the oracle layer from a per-query library into a shared serving
subsystem: one :class:`OracleService` feeds any number of concurrent queries.

Architecture
------------
::

    query 1 ── OracleBatch.flush_async() ──┐          (request queue)
    query 2 ── OracleBatch.flush_async() ──┼──►  ┌────────────────────┐
      ...                                  │     │  dispatcher thread  │
    query N ── OracleBatch.flush_async() ──┘     │  window assembly:   │
                                                 │  size- & deadline-  │
                 future.result() ◄── per-client  │  triggered flush    │
                 (labels resolved,   routing     └─────────┬──────────┘
                  ledger charged                           │ super-batch
                  atomically)                              ▼ (grouped by
                                                 ┌────────────────────┐
                                                 │  scorer worker pool │
                                                 │  shard 0 … shard W  │
                                                 │  (threads; each     │
                                                 │  scorer may itself  │
                                                 │  be mesh-sharded    │
                                                 │  via data_parallel) │
                                                 └────────────────────┘

* **Clients** are ordinary :class:`~repro.core.oracle.OracleBatch` objects.
  ``service.attach(oracle)`` routes that oracle's flushes here;
  ``flush_async()`` enqueues the pending request set and returns a future.
  Each query keeps its own Oracle (cache + budget ledger) — the service
  never mixes ledgers.
* The **dispatcher** assembles micro-batch *windows*: a window opens when the
  first flush arrives and closes when (a) the accumulated rows reach
  ``max_batch``, (b) ``max_wait_ms`` elapses, or (c) every attached client
  already has a flush in the window (nobody left to wait for).  A single
  attached client dispatches immediately — solo queries pay no windowing
  latency.
* Each window's segments are **planned sequentially in arrival order** with
  exactly the local-flush semantics: encode at flush time, dedup against the
  client's cache (and against earlier same-oracle segments in the window),
  check the budget.  Planning failures (:class:`BudgetExceeded`, encode
  errors) complete only that client's future; its requests return to the
  batch so the flush can be retried — one query's exhaustion never poisons
  another's batch.
* Planned rows are grouped by :meth:`Oracle.service_group` — oracles scoring
  through the same served model fuse into one **super-batch** per window —
  and each group is sharded over the worker pool.  Workers are threads (the
  backends release the GIL in numpy/XLA); each worker executes shards via
  the group's own ``_label``, and a :class:`~repro.serve.serve_loop.PairScorer`
  backend constructed with ``mesh=`` additionally shards every shard's batch
  dimension over the device mesh via ``launch.sharding.data_parallel`` —
  thread workers scale across hosts' independent scorers, the mesh path
  scales across one host's devices.  A backend error fails exactly the
  segments of that group (retryable), leaving other groups' results intact.
* **Commit** happens after execution, per segment in arrival order: merge the
  new labels into the client's cache, charge its ledger atomically, resolve
  the request handles, complete the future.
* With a **shared label store** attached (``label_store=``, see
  ``repro.serve.label_store``), a store-consultation phase sits between plan
  and execute: keys surviving the per-client dedup are split into resident
  hits, in-flight waits, and true misses *before any ledger is charged* —
  only misses execute, successful results are written back communally, and
  hits/waits are served at commit time under a charge-once budget policy
  (first requester pays; everyone else's ``calls`` still advances exactly
  as in serial execution, so estimates stay bit-identical).

* **Observability + admission control** (``repro.obs``): a pluggable
  :class:`~repro.obs.Tracker` receives window assembly latency, fill/dedup
  ratios, per-host shard latency, and per-query-class end-to-end flush
  latency; everything is summarised through one namespaced
  :meth:`OracleService.snapshot` surface.  Clients attached with a
  ``deadline_ms`` class are subject to deadline-based admission control:
  when the measured service rate times the queued backlog implies a
  deadline miss, their flushes are rejected *before anything is dequeued or
  charged* with a retryable :class:`AdmissionRejected`.  Worker hosts are
  health-checked in the background — a failing host is unregistered (its
  shards fall back to local execution, as in PR 4) and automatically
  re-registered when its ping answers again.

The window/plan/commit machinery here is transport-agnostic, and
``repro.serve.transport`` puts a network in front of it: remote client
processes submit pre-planned segments via :meth:`OracleService.submit_raw`
(they plan and commit against their own cache/ledger, so the service only
executes), window assembly counts connected transport clients exactly like
attached in-process oracles, and :meth:`OracleService.register_remote_worker`
extends the worker pool across hosts — super-batches for named wire groups
shard over worker hosts as well as local threads/devices.  The architecture
narrative, wire protocol spec, and deployment topology live in
docs/serving.md.
"""
from __future__ import annotations

import dataclasses
import threading
import time
import weakref
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Callable, Optional

import numpy as np

from repro.core.oracle import (
    Oracle,
    OracleBatch,
    commit_requests,
    plan_requests,
)
from repro.obs import NULL_TRACKER, NoopTracker, StreamingHistogram, merge_snapshots
from repro.serve.transport import ThroughputEWMA


class AdmissionRejected(RuntimeError):
    """A flush shed by deadline-based admission control.

    Raised by :meth:`OracleService.submit` *before* anything is dequeued,
    planned, or charged — the batch's pending set is untouched and the
    ledger never moves, so the caller may simply retry the flush (back off,
    or re-submit once the queue drains).  ``retryable`` mirrors the
    transport layer's error taxonomy."""

    retryable = True

    def __init__(self, qclass: str, deadline_ms: float, predicted_ms: float,
                 queue_rows: int):
        super().__init__(
            f"admission rejected: class {qclass!r} declared a "
            f"{deadline_ms:.0f}ms deadline but the predicted window wait is "
            f"{predicted_ms:.0f}ms ({queue_rows} rows queued)"
        )
        self.qclass = qclass
        self.deadline_ms = deadline_ms
        self.predicted_ms = predicted_ms
        self.queue_rows = queue_rows


@dataclasses.dataclass
class _Segment:
    """One enqueued flush: a client batch's pending set plus its future.

    Two flavours share the queue: **oracle segments** (an in-process
    ``OracleBatch`` flush — plan against the client's cache, commit to its
    ledger) and **raw segments** (pre-planned work from a transport client
    via :meth:`OracleService.submit_raw` — the remote client already planned
    against its own cache, so the service only executes and the future
    resolves to the label array)."""

    batch: Optional[OracleBatch]
    oracle: Optional[Oracle]
    requests: list
    future: Future
    rows: int
    # raw-segment fields (transport path)
    raw: bool = False
    key: object = None          # service-group key; raw: ("wire", name)
    fn: Optional[Callable] = None
    idx: Optional[np.ndarray] = None
    client_id: Optional[int] = None
    # observability: enqueue time (window assembly latency) + deadline class
    t_enqueue: float = 0.0
    qclass: str = "default"

    def group_key(self):
        return self.key if self.raw else self.oracle.service_group()

    def label_fn(self) -> Callable:
        return self.fn if self.raw else self.oracle._label

    def fail(self, exc: BaseException) -> None:
        """Complete exceptionally; for oracle segments additionally hand the
        requests back to the batch so the same flush can be retried (mirrors
        local-flush atomicity).  Raw segments hold no client state — the
        remote client's own batch keeps its pending set."""
        if not self.raw:
            self.batch._pending = self.requests + self.batch._pending
        self.future.set_exception(exc)


@dataclasses.dataclass
class _Plan:
    """A successfully planned segment, ready for group execution.

    With a shared label store attached, ``new_keys``/``new_idx`` hold only
    the store *misses* (the rows actually executed); ``store`` carries the
    consultation result — resident hits (values captured at plan time, so
    eviction can't fail the window), in-flight waits, and this plan's
    reservation token, which execution must publish or cancel."""

    seg: _Segment
    keys_list: list            # per-request encoded keys
    n_requested: int           # total rows incl. cache hits
    new_keys: np.ndarray       # unique uncached keys this segment labels
    new_idx: np.ndarray        # decoded (n_new, k) tuple indices
    vals: Optional[np.ndarray] = None   # labels for new_keys (set by execute)
    store: Optional[object] = None      # label_store.StorePlan (None = no store)
    row_keys: Optional[np.ndarray] = None   # raw segments: per-row flat keys


def _encoding_key(oracle: Oracle):
    """The key-encoding half of a label-store segment key: two oracles may
    share stored labels only when their int64 flat keys mean the same tuples
    (same bound sizes, or the same unbound bit packing)."""
    if oracle._sizes is not None:
        return ("sizes",) + tuple(oracle._sizes)
    if oracle._pack is not None:
        return ("pack",) + tuple(oracle._pack)
    return None


class OracleService:
    """Micro-batching request broker between OracleBatch clients and a pool
    of scorer workers (module docstring has the full architecture).

    Parameters
    ----------
    workers:
        Worker threads sharding each super-batch.  Shards run the group's
        vectorised ``_label`` concurrently; backends must be pure per row
        (true for every Oracle here — labels are per-tuple).
    max_batch:
        Row-count window trigger: a window dispatches as soon as its
        accumulated request rows reach this.
    max_wait_ms:
        Deadline window trigger: maximum time the dispatcher waits after the
        first flush of a window for more clients to arrive.
    min_shard:
        Smallest shard worth its own worker; groups below ``2 * min_shard``
        rows execute unsharded (sharding a padded scorer batch too finely
        wastes pad rows).
    index_store:
        Optional :class:`repro.core.index.IndexStore` shared by the queries
        served here: concurrent queries on the same table pair stratify from
        one resident artifact instead of each paying the sweep (route it via
        ``dispatch.run_auto(index_store=service.index_store)`` or
        ``JoinMLEngine(index_store=...)``).  The service owns no routing —
        it just gives the store a service-scoped home and merges its
        counters into :meth:`stats`.
    label_store:
        Optional :class:`repro.serve.label_store.LabelStore`: the window
        planner then dedupes each plan's uncached keys against the communal
        store *before any ledger is charged* — resident hits and keys
        reserved by another in-flight plan are served at commit time, only
        true misses execute (and are written back on success).  Off by
        default: without a store, served execution charges exactly like a
        local flush.  Raw (transport) segments get the same treatment
        whenever their tuple indices fit the store's bit packing, so remote
        clients' EXEC answers can be store-served too.  ``close()`` calls
        ``label_store.save()``.
    tracker:
        Optional :class:`repro.obs.Tracker` receiving the service's signals
        (window assembly latency, fill/dedup ratios, per-host shard latency,
        per-class flush latency, admission/worker events).  Defaults to the
        noop tracker — the uninstrumented fast path.  Attached stores that
        have no tracker of their own inherit this one.
    health_check_s:
        Period of the background worker-host health checker (started with
        the first :meth:`register_remote_worker`).  A host that fails a
        shard or a ping is unregistered — its groups fall back to local
        execution — and automatically re-registered (groups re-fetched)
        once its ping answers again.  ``0`` disables the checker: a failed
        host then stays unregistered, PR 4's fail-to-local behaviour.
    """

    def __init__(self, workers: int = 1, max_batch: int = 8192,
                 max_wait_ms: float = 4.0, min_shard: int = 256,
                 index_store=None, label_store=None, tracker=None,
                 health_check_s: float = 2.0):
        self.index_store = index_store
        self.label_store = label_store
        self.tracker = tracker if tracker is not None else NULL_TRACKER
        # one flag gate for the hot-path hooks: a NoopTracker pays nothing
        self._tracking = not isinstance(self.tracker, NoopTracker)
        for store in (index_store, label_store):
            if store is not None and isinstance(
                getattr(store, "tracker", None), NoopTracker
            ):
                store.tracker = self.tracker
        self.workers = max(int(workers), 1)
        self.max_batch = int(max_batch)
        self.max_wait_s = float(max_wait_ms) / 1e3
        self.min_shard = max(int(min_shard), 1)
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._queue: list[_Segment] = []
        # weak: an attached oracle that is dropped without detach must not
        # stall window assembly (or alias a recycled address) forever
        self._clients: "weakref.WeakSet[Oracle]" = weakref.WeakSet()
        # transport clients (repro.serve.transport): counted, not attached —
        # the server tells us how many connections could still contribute to
        # the open window (window assembly's remote analogue of _clients)
        self._remote_clients: set[int] = set()
        self._client_seq = 0
        # worker hosts (RemoteWorkerClient-shaped: .groups + .execute);
        # super-batches for wire groups they advertise shard across them
        self._remote_workers: list = []
        # hosts that failed a shard or a ping: skipped by _eligible_workers
        # until the health checker sees their ping answer again
        self._dead_workers: list = []
        self.health_check_s = float(health_check_s)
        self._health_thread: Optional[threading.Thread] = None
        self._health_stop = threading.Event()
        # deadline-based admission control: per-oracle deadline class
        # (attach(deadline_ms=...)), an EWMA of the measured service rate in
        # rows/s, and the backlog the next flush would queue behind
        self._deadlines: "weakref.WeakKeyDictionary[Oracle, float]" = (
            weakref.WeakKeyDictionary()
        )
        self._classes: "weakref.WeakKeyDictionary[Oracle, str]" = (
            weakref.WeakKeyDictionary()
        )
        self._service_rate = 0.0    # rows/s EWMA; 0 = not yet measured
        # per-deadline-class EWMAs: each window's rate sample updates every
        # class present in that window, so one slow class's measurements
        # never drag down the predicted wait of a fast class (global-rate
        # sharing let a slow tenant shed a fast tenant's queries)
        self._class_rates: dict[str, float] = {}
        self._queued_rows = 0
        self._inflight_rows = 0
        self._closed = False
        self._pool: Optional[ThreadPoolExecutor] = (
            ThreadPoolExecutor(max_workers=self.workers,
                               thread_name_prefix="oracle-worker")
            if self.workers > 1 else None
        )
        self._retired_pools: list[ThreadPoolExecutor] = []
        # observability (read via stats(); written by the dispatcher, except
        # remote_shards/remote_failures — worker-pool threads update those
        # under _stats_lock)
        self._stats_lock = threading.Lock()
        self.windows = 0
        self.segments = 0
        self.backend_calls = 0
        self.rows_requested = 0
        self.rows_labelled = 0
        self.window_rows = 0        # rows entering windows (fill ratio)
        self.rows_planned = 0       # rows surviving per-client cache dedup
        self.remote_shards = 0
        self.remote_failures = 0
        # per-executor rows/s EWMAs ("local" + one per worker host label):
        # _execute sizes shards in proportion to these (capacity-weighted
        # splits, ROADMAP serving item c).  Keyed creation is guarded by
        # _stats_lock; each EWMA is itself thread-safe.
        self._shard_rates: dict[str, ThroughputEWMA] = {}
        self.admission_rejections = 0
        self.worker_deaths = 0
        self.worker_rejoins = 0
        # last-N per-window fill/dedup ratios: the lifetime ratios in stats()
        # average warmup in forever; these power the *_recent snapshot keys
        # (written by the dispatcher only, read lock-free by snapshot())
        self._fill_hist = StreamingHistogram(window=256)
        self._dedup_hist = StreamingHistogram(window=256)
        self._dispatcher = threading.Thread(
            target=self._run, name="oracle-service", daemon=True
        )
        self._dispatcher.start()

    # ---- client lifecycle --------------------------------------------------

    def attach(self, *oracles: Oracle, deadline_ms: Optional[float] = None,
               query_class: Optional[str] = None) -> "OracleService":
        """Route the oracles' flushes through this service.  The attached set
        also drives window assembly: a window closes early once every
        attached client has a flush in it.

        ``deadline_ms`` declares a deadline class: flushes from these oracles
        are shed with :class:`AdmissionRejected` whenever the measured
        service rate and queued backlog predict a wait beyond the deadline.
        Clients without a deadline are never shed.  ``query_class`` names the
        class for per-class latency telemetry (defaults to ``dl<deadline>``,
        or ``"default"`` with no deadline)."""
        with self._cv:
            if self._closed:
                raise RuntimeError("OracleService is closed")
            for o in oracles:
                o.service = self
                self._clients.add(o)
                if deadline_ms is not None:
                    self._deadlines[o] = float(deadline_ms)
                    self._classes[o] = query_class or f"dl{int(deadline_ms)}"
                elif query_class is not None:
                    self._classes[o] = query_class
        return self

    def detach(self, *oracles: Oracle) -> None:
        """Return the oracles to local (synchronous) flushing.  Detaching
        finished queries keeps windows from waiting on clients that will
        never flush again."""
        with self._cv:
            for o in oracles:
                if o.service is self:
                    o.service = None
                self._clients.discard(o)
                self._deadlines.pop(o, None)
                self._classes.pop(o, None)
            self._cv.notify_all()

    def _predicted_wait_ms_locked(self, rows: int,
                                  qclass: str = "default") -> float:
        """Expected queue wait for a flush of ``rows`` rows, from the
        class's own EWMA service rate and the backlog (queued + in-flight +
        this flush) it would land behind, plus the window-assembly deadline.
        0 until the class has a measured window (admit during warmup) —
        falling back to another class's rate would reintroduce exactly the
        cross-tenant coupling the per-class budgets exist to remove."""
        rate = self._class_rates.get(qclass, 0.0)
        if rate <= 0.0:
            return 0.0
        backlog = self._queued_rows + self._inflight_rows + rows
        return 1e3 * backlog / rate + 1e3 * self.max_wait_s

    def submit(self, batch: OracleBatch) -> Future:
        """Enqueue a batch's pending set; called by ``flush_async``.  The
        caller must not touch the batch again until the future resolves
        (one outstanding flush per batch — the submit-then-await protocol
        every pipeline stage follows).

        If the batch's oracle declared a deadline class (``attach`` with
        ``deadline_ms``) and the predicted wait exceeds it, raises
        :class:`AdmissionRejected` *without dequeuing anything* — the
        pending set and the ledger are untouched, so the flush can simply
        be retried."""
        rows = sum(len(r.idx) for r in batch._pending)
        deadline_ms = self._deadlines.get(batch.oracle)
        qclass = self._classes.get(batch.oracle, "default")
        with self._cv:
            if self._closed:
                raise RuntimeError("OracleService is closed")
            if deadline_ms is not None:
                predicted = self._predicted_wait_ms_locked(rows, qclass)
                if predicted > deadline_ms:
                    self.admission_rejections += 1
                    queued = self._queued_rows + self._inflight_rows
                    self.tracker.count("service.admission.rejected")
                    self.tracker.event(
                        "service.admission.rejected", qclass=qclass,
                        deadline_ms=deadline_ms, predicted_ms=predicted,
                    )
                    raise AdmissionRejected(qclass, deadline_ms, predicted,
                                            queued)
            requests, batch._pending = batch._pending, []
            seg = _Segment(
                batch=batch, oracle=batch.oracle, requests=requests,
                future=Future(), rows=rows,
                t_enqueue=time.monotonic(), qclass=qclass,
            )
            self._queue.append(seg)
            self._queued_rows += rows
            self._cv.notify_all()
        if self._tracking:
            self._track_flush(seg)
        return seg.future

    def _track_flush(self, seg: _Segment) -> None:
        """Observe the segment's end-to-end latency under its deadline class
        when its future completes (success or failure)."""
        name = f"service.class.{seg.qclass}.flush_ms"

        def done(_fut) -> None:
            self.tracker.observe(
                name, (time.monotonic() - seg.t_enqueue) * 1e3
            )

        seg.future.add_done_callback(done)

    # ---- transport integration (repro.serve.transport) ---------------------

    def client_connected(self) -> int:
        """Register one announced transport connection for window assembly;
        returns its client id.  Windows wait (up to the deadline) for every
        registered transport client that is not yet present, exactly like
        attached in-process oracles.  The transport server calls this only
        for connections that declared themselves query clients (HELLO or a
        first EXEC), never for control-plane or silent connections."""
        with self._cv:
            self._client_seq += 1
            cid = self._client_seq
            self._remote_clients.add(cid)
            return cid

    def client_disconnected(self, client_id: int) -> None:
        """Forget a transport connection so windows stop waiting for it."""
        with self._cv:
            self._remote_clients.discard(client_id)
            self._cv.notify_all()

    def submit_raw(self, name: str, fn: Callable, idx: np.ndarray,
                   client_id: Optional[int] = None) -> Future:
        """Enqueue pre-planned label work: ``idx`` rows to execute through
        ``fn`` under wire group ``name``.  The returned future resolves to
        the (n,) float64 label array.  Used by the transport server — the
        remote client already planned (dedup + budget) against its own
        oracle, so these segments skip planning and commit and still get
        window coalescing, super-batch fusion, and worker sharding."""
        idx = np.asarray(idx)
        seg = _Segment(
            batch=None, oracle=None, requests=[], future=Future(),
            rows=int(len(idx)), raw=True, key=("wire", str(name)), fn=fn,
            idx=idx, client_id=client_id,
            t_enqueue=time.monotonic(), qclass="remote",
        )
        with self._cv:
            if self._closed:
                raise RuntimeError("OracleService is closed")
            self._queue.append(seg)
            self._queued_rows += seg.rows
            self._cv.notify_all()
        if self._tracking:
            self._track_flush(seg)
        return seg.future

    def register_remote_worker(self, worker) -> None:
        """Add a worker host to the execution pool.  ``worker`` needs
        ``.groups`` (wire group names it serves) and
        ``.execute(name, idx) -> labels`` (see
        :class:`repro.serve.transport.RemoteWorkerClient`).  Super-batches
        for those groups then shard across hosts as well as local threads;
        a worker failure mid-batch falls back to local execution for its
        shard, unregisters the host, and (with ``health_check_s > 0``) the
        background health checker re-registers it as soon as its ping
        answers again."""
        with self._cv:
            if self._closed:
                raise RuntimeError("OracleService is closed")
            self._remote_workers.append(worker)
            # remote round trips block a thread each: size the pool so every
            # worker host can run concurrently with the local shards.  The
            # old pool is retired, not shut down — the dispatcher may hold a
            # reference mid-window, and submitting to a shut-down pool would
            # fail that window's flushes; retired pools are drained at close()
            pool_size = (self.workers + len(self._remote_workers)
                         + len(self._dead_workers))
            if self._pool is not None:
                self._retired_pools.append(self._pool)
            self._pool = ThreadPoolExecutor(
                max_workers=pool_size, thread_name_prefix="oracle-worker"
            )
            if self._health_thread is None and self.health_check_s > 0:
                self._health_thread = threading.Thread(
                    target=self._health_loop, name="oracle-service-health",
                    daemon=True,
                )
                self._health_thread.start()

    # ---- worker health ------------------------------------------------------

    @staticmethod
    def _worker_alive(worker) -> bool:
        """One health probe.  ``ping`` may return a bool (transport style) or
        raise; hosts without a ping are assumed alive (test doubles)."""
        ping = getattr(worker, "ping", None)
        if ping is None:
            return True
        try:
            return ping() is not False
        except BaseException:  # noqa: BLE001 — an unreachable host is dead
            return False

    @staticmethod
    def _worker_label(worker) -> str:
        addr = getattr(worker, "address", None)
        if isinstance(addr, (tuple, list)) and len(addr) == 2:
            return f"{addr[0]}:{addr[1]}"
        return str(addr) if addr is not None else repr(worker)

    def _mark_worker_dead(self, worker) -> None:
        """Unregister a failing worker host: its groups stop routing to it
        (shards fall back to local) until the health checker sees it answer
        a ping again.  Idempotent — concurrent shard failures of one host
        record one death."""
        with self._cv:
            if worker not in self._remote_workers:
                return
            self._remote_workers.remove(worker)
            self._dead_workers.append(worker)
            self.worker_deaths += 1
        self.tracker.count("service.worker.deaths")
        self.tracker.event("service.worker.dead",
                           worker=self._worker_label(worker))

    def _revive_worker(self, worker) -> bool:
        """Probe one dead worker; on success re-fetch its group set and
        re-register it.  Returns True when the worker rejoined."""
        try:
            if not self._worker_alive(worker):
                return False
            refresh = getattr(worker, "refresh_groups", None)
            if refresh is not None:
                refresh()
        except BaseException:  # noqa: BLE001 — still dead, retry next sweep
            return False
        with self._cv:
            if worker not in self._dead_workers:
                return False
            self._dead_workers.remove(worker)
            self._remote_workers.append(worker)
            self.worker_rejoins += 1
        self.tracker.count("service.worker.rejoins")
        self.tracker.event("service.worker.rejoined",
                           worker=self._worker_label(worker))
        return True

    def _health_loop(self) -> None:
        """Background sweep: ping live hosts (a failure unregisters them
        without waiting for a mid-batch shard error) and probe dead ones
        (a success re-registers them, groups re-fetched)."""
        while True:
            with self._cv:
                if self._closed:
                    return
                live = list(self._remote_workers)
                dead = list(self._dead_workers)
            for worker in dead:
                self._revive_worker(worker)
            for worker in live:
                if not self._worker_alive(worker):
                    self._mark_worker_dead(worker)
            if self._health_stop.wait(self.health_check_s):
                return

    def close(self) -> None:
        """Drain the queue, stop the dispatcher, shut the worker pool, and
        persist the label store (a no-op unless it has a disk root)."""
        with self._cv:
            self._closed = True
            self._cv.notify_all()
        self._health_stop.set()
        self._dispatcher.join()
        if self._health_thread is not None:
            self._health_thread.join()
        for pool in [self._pool] + self._retired_pools:
            if pool is not None:
                pool.shutdown(wait=True)
        if self.label_store is not None:
            self.label_store.save()

    def __enter__(self) -> "OracleService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def stats(self) -> dict:
        out = {
            "windows": self.windows,
            "segments": self.segments,
            "backend_calls": self.backend_calls,
            "rows_requested": self.rows_requested,
            "rows_labelled": self.rows_labelled,
            "remote_shards": self.remote_shards,
            "remote_failures": self.remote_failures,
            "segments_per_window": round(
                self.segments / max(self.windows, 1), 2
            ),
            # how full windows run vs the max_batch trigger — low fill with
            # high window counts means max_wait_ms closes windows early
            "window_fill_ratio": round(
                self.window_rows / max(self.windows * self.max_batch, 1), 4
            ),
            # fraction of window rows already answered by per-client caches
            # before any backend (or store) work was planned
            "window_dedup_ratio": round(
                1.0 - self.rows_planned / max(self.window_rows, 1), 4
            ),
        }
        if self.index_store is not None:
            out.update(self.index_store.stats())
        if self.label_store is not None:
            out.update(self.label_store.stats())
        return out

    def snapshot(self) -> dict[str, float]:
        """The unified stats surface: one flat ``{dotted.name: float}`` dict
        merging the service's own counters (``service.*``), the attached
        stores (``index_store.*`` / ``label_store.*``), and everything the
        tracker recorded (histogram series expand to ``.p50``/``.p99``/...).
        ``service.window.fill_ratio_recent`` / ``.dedup_ratio_recent`` are
        last-N per-window means — steady state, unlike the lifetime ratios.
        """
        base = {
            "service.windows": float(self.windows),
            "service.segments": float(self.segments),
            "service.backend_calls": float(self.backend_calls),
            "service.rows_requested": float(self.rows_requested),
            "service.rows_labelled": float(self.rows_labelled),
            "service.rows_planned": float(self.rows_planned),
            "service.remote_shards": float(self.remote_shards),
            "service.remote_failures": float(self.remote_failures),
            "service.segments_per_window": (
                self.segments / max(self.windows, 1)
            ),
            "service.window.fill_ratio": (
                self.window_rows / max(self.windows * self.max_batch, 1)
            ),
            "service.window.dedup_ratio": (
                1.0 - self.rows_planned / max(self.window_rows, 1)
            ),
            "service.window.fill_ratio_recent": self._fill_hist.recent_mean(),
            "service.window.dedup_ratio_recent": (
                self._dedup_hist.recent_mean()
            ),
            "service.queue.rows": float(self._queued_rows),
            "service.rate_rows_per_s": float(self._service_rate),
            **{f"service.class.{qc}.rate_rows_per_s": float(r)
               for qc, r in self._class_rates.items()},
            "service.admission.rejected": float(self.admission_rejections),
            "service.worker.live": float(len(self._remote_workers)),
            "service.worker.dead": float(len(self._dead_workers)),
            "service.worker.deaths": float(self.worker_deaths),
            "service.worker.rejoins": float(self.worker_rejoins),
            **{f"service.shard.rate.{lb}": ewma.rate
               for lb, ewma in list(self._shard_rates.items())},
        }
        return merge_snapshots(
            self.tracker.snapshot(),
            self.index_store.snapshot() if self.index_store is not None
            else None,
            self.label_store.snapshot() if self.label_store is not None
            else None,
            base,
        )

    # ---- dispatcher --------------------------------------------------------

    def _run(self) -> None:
        while True:
            with self._cv:
                while not self._queue and not self._closed:
                    self._cv.wait()
                if not self._queue:
                    return                       # closed and drained
                window = [self._queue.pop(0)]
                rows = window[0].rows
                deadline = time.monotonic() + self.max_wait_s
                while rows < self.max_batch:
                    if self._queue:
                        seg = self._queue.pop(0)
                        window.append(seg)
                        rows += seg.rows
                        continue
                    present = {id(s.oracle) for s in window if not s.raw}
                    waiting = any(
                        id(o) not in present for o in self._clients
                    )
                    if not waiting and self._remote_clients:
                        remote_present = {
                            s.client_id for s in window
                            if s.client_id is not None
                        }
                        waiting = any(c not in remote_present
                                      for c in self._remote_clients)
                    remain = deadline - time.monotonic()
                    if self._closed or remain <= 0 or not waiting:
                        break                    # nobody left to wait for
                    self._cv.wait(remain)
                # the window is now in flight: flushes submitted from here on
                # queue behind it (admission control's backlog view)
                self._queued_rows -= rows
                self._inflight_rows = rows
            if self._tracking:
                t_dispatch = time.monotonic()
                for seg in window:
                    self.tracker.observe(
                        "service.window.assembly_ms",
                        (t_dispatch - seg.t_enqueue) * 1e3,
                    )
            t_proc = time.perf_counter()
            try:
                self._process(window)
            except BaseException as e:  # noqa: BLE001 — dispatcher must survive
                for seg in window:
                    if not seg.future.done():
                        seg.fail(e)
            finally:
                elapsed = time.perf_counter() - t_proc
                with self._cv:
                    self._inflight_rows = 0
                    if rows and elapsed > 0:
                        # EWMA of the measured service rate (rows/s) feeding
                        # admission control's predicted-wait estimate; the
                        # sample also updates every deadline class present in
                        # this window so each class predicts from its own
                        # history only
                        sample = rows / elapsed
                        self._service_rate = (
                            sample if self._service_rate <= 0.0
                            else 0.7 * self._service_rate + 0.3 * sample
                        )
                        for qc in {seg.qclass for seg in window}:
                            prev = self._class_rates.get(qc, 0.0)
                            self._class_rates[qc] = (
                                sample if prev <= 0.0
                                else 0.7 * prev + 0.3 * sample
                            )
            # pools retired by register_remote_worker are quiescent once the
            # window completes (this thread is their only submitter and
            # _execute awaits every shard), so their threads are reaped here
            # instead of leaking until close()
            with self._lock:
                retired, self._retired_pools = self._retired_pools, []
            for pool in retired:
                pool.shutdown(wait=True)

    # ---- window processing -------------------------------------------------

    def _process(self, window: list[_Segment]) -> None:
        self.windows += 1
        self.segments += len(window)
        rows_w = sum(seg.rows for seg in window)
        self.window_rows += rows_w
        planned_before = self.rows_planned
        plans = self._plan(window)
        # per-window fill/dedup observations: the *_recent snapshot keys and
        # (when a tracker is attached) the service.window.{fill,dedup} series
        fill = rows_w / self.max_batch
        dedup = 1.0 - (self.rows_planned - planned_before) / max(rows_w, 1)
        self._fill_hist.observe(fill)
        self._dedup_hist.observe(dedup)
        if self._tracking:
            self.tracker.observe("service.window.fill", fill)
            self.tracker.observe("service.window.dedup", dedup)
        try:
            groups: dict = {}
            for plan in plans:
                groups.setdefault(plan.seg.group_key(), []).append(plan)
            for key, group in groups.items():
                self._execute_group(key, group)
            for plan in plans:                   # commit in arrival order
                if plan.seg.future.done():       # its group failed
                    continue
                self._commit(plan)
        except BaseException as e:
            # a dispatcher-level failure must not leave store reservations
            # dangling — waiters (possibly in another service sharing the
            # store) would block on them forever
            for plan in plans:
                if plan.store is not None and self.label_store is not None:
                    self.label_store.cancel(plan.store, e)
            raise

    def _plan(self, window: list[_Segment]) -> list[_Plan]:
        """Per-segment dedup + budget check via the shared
        :func:`repro.core.oracle.plan_requests` (exactly local-flush
        semantics), then the store-consultation phase: keys surviving the
        client-cache dedup are split against the shared label store —
        resident hits and in-flight waits are served at commit, only misses
        stay in ``new_keys`` for execution.  Earlier same-oracle segments in
        the window count as cached-to-be with their *full* acquired key set
        (store-served keys land in the client cache at commit too)."""
        plans: list[_Plan] = []
        planned: dict[int, list[np.ndarray]] = {}   # id(oracle) -> key arrays
        store = self.label_store
        for seg in window:
            if seg.raw:
                plans.append(self._plan_raw(seg))
                continue
            o = seg.oracle
            try:
                prior = planned.get(id(o))
                keys_list, n_requested, new_keys = plan_requests(
                    o, seg.requests,
                    extra_planned=np.concatenate(prior) if prior else None,
                )
                if len(new_keys):
                    planned.setdefault(id(o), []).append(new_keys)
                self.rows_planned += len(new_keys)
                plan = _Plan(
                    seg=seg, keys_list=keys_list, n_requested=n_requested,
                    new_keys=new_keys, new_idx=None,
                )
                if store is not None and len(new_keys):
                    enc = _encoding_key(o)
                    if enc is not None:
                        plan.store = store.plan(
                            (o.service_group(), enc), new_keys
                        )
                        plan.new_keys = plan.store.miss_keys
                plan.new_idx = o._decode(plan.new_keys)
                plans.append(plan)
            except BaseException as e:  # noqa: BLE001 — isolate per client
                seg.fail(e)
        return plans

    def _plan_raw(self, seg: _Segment) -> _Plan:
        """Raw (transport) segments are pre-planned by the remote client
        against its own cache and ledger — nothing to dedup or budget-check.
        The store-consultation phase still applies when the tuple indices
        fit the store's bit packing: hits/waits are served at commit and
        only miss rows execute, so remote EXEC answers can be store-served
        (the client's plan/commit semantics never notice)."""
        plan = _Plan(
            seg=seg, keys_list=[], n_requested=seg.rows,
            new_keys=np.empty(0, np.int64), new_idx=seg.idx,
        )
        store = self.label_store
        if store is None or not len(seg.idx):
            self.rows_planned += seg.rows
            return plan
        from repro.serve.label_store import pack_tuples, unpack_tuples

        row_keys = pack_tuples(seg.idx)
        if row_keys is None:        # indices exceed the packing — skip store
            self.rows_planned += seg.rows
            return plan
        k = seg.idx.shape[1]
        ukeys = np.unique(row_keys)
        self.rows_planned += len(ukeys)
        plan.row_keys = row_keys
        plan.store = store.plan((seg.key, ("pack", k, 63 // k)), ukeys)
        plan.new_keys = plan.store.miss_keys
        plan.new_idx = unpack_tuples(plan.store.miss_keys, k)
        return plan

    def _execute_group(self, key, group: list[_Plan]) -> None:
        """Concatenate a group's new rows into one super-batch, shard it over
        the worker pool (and worker hosts serving this group), and scatter
        labels back per plan.  On success each plan's fresh labels are
        published to the shared store (releasing its reservations); a
        backend error cancels the reservations and fails every segment of
        this group and only this group — cancelled keys become reservable
        again, so the failed flushes retry cleanly."""
        lens = [len(p.new_idx) for p in group]
        total = sum(lens)
        if total == 0:
            return
        idx = np.concatenate([p.new_idx for p in group if len(p.new_idx)])
        fn = group[0].seg.label_fn()        # same group => same pure backend
        try:
            vals = self._execute(fn, idx, key)
            if vals.shape != (total,):
                raise RuntimeError(
                    f"backend returned shape {vals.shape} for {total} rows"
                )
        except BaseException as e:  # noqa: BLE001 — isolate per group
            for p in group:
                if p.store is not None and self.label_store is not None:
                    self.label_store.cancel(p.store, e)
                    p.store = None
                p.seg.fail(e)
            return
        self.rows_labelled += total
        off = 0
        for p, n in zip(group, lens):
            p.vals = vals[off:off + n]
            off += n
            if p.store is not None and self.label_store is not None:
                self.label_store.publish(p.store, p.vals)

    def _eligible_workers(self, key) -> list:
        """Worker hosts that can execute this group.  Only wire groups are
        routable across hosts — a worker host can't run an arbitrary
        in-process ``_label`` closure, it advertises named scorers."""
        if not (isinstance(key, tuple) and len(key) == 2 and key[0] == "wire"):
            return []
        return [w for w in self._remote_workers if key[1] in w.groups]

    def _record_rate(self, label: str, rows: int, seconds: float) -> None:
        """Fold one shard's measured throughput into its executor's EWMA."""
        with self._stats_lock:
            ewma = self._shard_rates.get(label)
            if ewma is None:
                ewma = self._shard_rates[label] = ThroughputEWMA()
        ewma.update(rows, seconds)

    def _capacity_split(self, idx: np.ndarray, labels: list) -> list:
        """Contiguous shards of ``idx`` sized in proportion to each
        executor's measured throughput (rows/s EWMA, see
        :class:`repro.serve.transport.ThroughputEWMA`).

        Executors without a measurement yet are assigned the mean measured
        rate — so the very first super-batch splits uniformly and later
        ones adapt.  The split is contiguous and order-preserving (largest
        remainder apportionment with a one-row floor per shard), so the
        concatenated result is bit-identical to the uniform split it
        replaces regardless of how the sizes skew."""
        n = len(labels)
        with self._stats_lock:
            rates = [
                self._shard_rates[lb].rate
                if lb in self._shard_rates
                and self._shard_rates[lb].samples > 0 else 0.0
                for lb in labels
            ]
        measured = [r for r in rates if r > 0.0]
        if not measured:
            return np.array_split(idx, n)
        fallback = sum(measured) / len(measured)
        weights = np.asarray(
            [r if r > 0.0 else fallback for r in rates], np.float64
        )
        raw = weights * (len(idx) / weights.sum())
        sizes = np.floor(raw).astype(np.int64)
        order = np.argsort(-(raw - sizes), kind="stable")
        for j in range(len(idx) - int(sizes.sum())):
            sizes[order[j % n]] += 1
        for i in range(n):          # one-row floor: steal from the largest
            while sizes[i] == 0:
                sizes[int(np.argmax(sizes))] -= 1
                sizes[i] += 1
        return np.split(idx, np.cumsum(sizes)[:-1])

    def _execute(self, fn: Callable, idx: np.ndarray, key=None) -> np.ndarray:
        """Shard a super-batch across the local thread pool and any worker
        hosts serving the group, each shard sized by the executor's measured
        throughput (``_capacity_split``); shard order is preserved, so
        results are bit-identical regardless of where each shard ran or how
        the sizes skew."""
        remotes = self._eligible_workers(key)
        n_shards = min(self.workers + len(remotes),
                       len(idx) // self.min_shard)
        if self._pool is None or n_shards < 2:
            self.backend_calls += 1
            return np.asarray(self._execute_local(fn, idx), np.float64)
        n_remote = min(len(remotes), n_shards - 1)  # keep >=1 shard local
        labels = [self._worker_label(w) for w in remotes[:n_remote]]
        labels += ["local"] * (n_shards - n_remote)
        shards = self._capacity_split(idx, labels)
        self.backend_calls += n_shards
        futs = [
            self._pool.submit(self._execute_remote, w, key[1], fn, s)
            for w, s in zip(remotes, shards[:n_remote])
        ]
        futs += [self._pool.submit(self._execute_local, fn, s)
                 for s in shards[n_remote:]]
        return np.concatenate(
            [np.asarray(f.result(), np.float64) for f in futs]
        )

    def _execute_local(self, fn: Callable, shard: np.ndarray):
        """One shard on the local pool, timed into the ``local`` throughput
        EWMA (and ``service.shard.local_ms`` when a tracker is attached)."""
        t0 = time.perf_counter()
        vals = fn(shard)
        dt = time.perf_counter() - t0
        self._record_rate("local", len(shard), dt)
        if self._tracking:
            self.tracker.observe("service.shard.local_ms", dt * 1e3)
        return vals

    def _execute_remote(self, worker, name: str, fn: Callable,
                        shard: np.ndarray) -> np.ndarray:
        """One shard on one worker host; falls back to local execution when
        the host fails mid-batch (labelling is pure, so re-execution is
        always safe) — a dead worker degrades throughput, never a query.
        The failing host is unregistered until its health check passes."""
        try:
            t0 = time.perf_counter()
            vals = np.asarray(worker.execute(name, shard), np.float64)
            if vals.shape != (len(shard),):
                raise RuntimeError(
                    f"worker returned shape {vals.shape} for "
                    f"{len(shard)} rows"
                )
            dt = time.perf_counter() - t0
            self._record_rate(self._worker_label(worker), len(shard), dt)
            if self._tracking:
                self.tracker.observe(
                    f"service.shard.{self._worker_label(worker)}_ms",
                    dt * 1e3,
                )
            with self._stats_lock:
                self.remote_shards += 1
            return vals
        except BaseException:  # noqa: BLE001 — degrade to local execution
            with self._stats_lock:
                self.remote_failures += 1
            self._mark_worker_dead(worker)
            return np.asarray(fn(shard), np.float64)

    def _resolve_store(self, plan: _Plan) -> tuple:
        """Gather the store-served labels for a plan: resident hits (values
        captured at plan time) plus keys reserved by other in-flight plans —
        their tokens resolve to the owner's ``(published_keys, vals)``.
        Within one service tokens are always done by commit time (publish
        precedes commit in ``_process``); across services sharing a store,
        ``result()`` blocks until the owning window publishes or cancels.
        Raises on a cancelled token — the segment then fails retryably."""
        sp = plan.store
        ks, vs = [sp.hit_keys], [sp.hit_vals]
        for token, keys in sp.wait:
            owner_keys, owner_vals = token.result(timeout=120.0)
            pos = np.searchsorted(owner_keys, keys)
            ks.append(keys)
            vs.append(owner_vals[pos])
        return np.concatenate(ks), np.concatenate(vs)

    def _commit(self, plan: _Plan) -> None:
        """Atomic ledger charge + cache merge + per-client result routing via
        the shared :func:`repro.core.oracle.commit_requests`.  Runs only
        after the group's backend execution succeeded, so a failure anywhere
        earlier leaves this client's oracle untouched.  Store-served keys
        merge into the client cache here (advancing ``calls`` exactly like
        serial execution; the charge-once discount lands on ``store_hits``/
        ``store_charge_saved``).  Raw segments have no local oracle to
        commit to — their future resolves to the labels (reassembled in
        request-row order from hits, waits, and executed rows) and the
        remote client commits on its own side."""
        store_keys = store_vals = None
        if plan.store is not None:
            try:
                store_keys, store_vals = self._resolve_store(plan)
            except BaseException as e:  # noqa: BLE001 — owner's call failed
                plan.seg.fail(e)
                return
        self.rows_requested += plan.n_requested
        if plan.seg.raw:
            if plan.row_keys is not None and store_keys is not None:
                # scatter hit + waited + executed values back to row order
                all_keys = np.concatenate([store_keys, plan.new_keys])
                all_vals = np.concatenate([
                    store_vals,
                    plan.vals if plan.vals is not None else np.empty(0),
                ])
                order = np.argsort(all_keys, kind="stable")
                pos = np.searchsorted(all_keys[order], plan.row_keys)
                vals = all_vals[order][pos]
            else:
                vals = plan.vals if plan.vals is not None else np.empty(0)
            plan.seg.future.set_result(np.asarray(vals, np.float64))
            return
        commit_requests(
            plan.seg.oracle, plan.seg.requests, plan.keys_list,
            plan.n_requested, plan.new_keys, plan.vals,
            store_keys=store_keys, store_vals=store_vals,
        )
        plan.seg.future.set_result(None)


def serve_queries(service: OracleService, jobs: list) -> list:
    """Run ``jobs`` — callables ``job() -> result`` each owning one attached
    oracle — concurrently against one service.  Convenience for entry points
    and benchmarks: threads map 1:1 to queries (each blocks in
    ``future.result()`` while the service batches), results keep job order,
    and the first job exception propagates after all threads join.
    """
    results: list = [None] * len(jobs)
    errors: list = []

    def runner(i: int, job) -> None:
        try:
            results[i] = job()
        except BaseException as e:  # noqa: BLE001 — re-raised after join
            errors.append(e)

    threads = [
        threading.Thread(target=runner, args=(i, job), daemon=True)
        for i, job in enumerate(jobs)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    if errors:
        raise errors[0]
    return results


__all__ = ["AdmissionRejected", "OracleService", "serve_queries"]
