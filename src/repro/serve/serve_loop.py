"""Serving layer: batched pair scoring (the Oracle endpoint BAS calls) and a
slot-based continuous batcher for autoregressive decode.

PairScorer — the paper's Oracle as a service: serialize a record pair to
tokens, run the scoring LM, read P(match) from the final-position logits of
the YES/NO token ids.  The Oracle batch layer (``repro.core.oracle``) hands
it one deduped request per pipeline stage; the scorer buckets those requests
into a small set of padded (batch, length) shapes — power-of-two sequence
buckets × a fixed batch dim — so the jitted forward compiles O(log max_len)
times total, and optionally shards the batch dimension over a device mesh
(``mesh=``, data-parallel ``shard_map``) so throughput scales with device
count.

ContinuousBatcher — fixed B decode slots; finished sequences vacate their
slot and queued requests are admitted mid-flight (per-slot positions), the
standard serving pattern for mixed-length batches.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.sharding import data_parallel, mesh_batch_shards
from repro.models import decode_step, forward, init_cache
from repro.models.config import ModelConfig


def _stable_yes_no_prob(lg: np.ndarray) -> np.ndarray:
    """P(yes) from (n, 2) [yes, no] logits, max-subtracted so large logits
    cannot overflow ``exp`` into NaN."""
    m = lg.max(axis=1, keepdims=True)
    e = np.exp(lg - m)
    return e[:, 0] / (e[:, 0] + e[:, 1])


class PairScorer:
    """Batched Oracle scoring: score(idx_pairs) -> P(match) per pair.

    ``mesh`` (optional) enables the data-parallel path: the batch dimension
    of the jitted forward is sharded over the mesh's batch axes (SERVE_RULES)
    via ``shard_map``; ``batch_size`` is rounded up to a multiple of the
    shard count.  ``forward_batches`` counts compiled-forward invocations —
    the unit the ISSUE's ceil(unique/batch_size) bound is stated in.
    """

    def __init__(self, cfg: ModelConfig, params, tokenize_pair: Callable,
                 yes_id: int, no_id: int, max_len: int = 128,
                 batch_size: int = 32, mesh=None, min_bucket: int = 16):
        self.cfg = cfg
        self.params = params
        self.tokenize_pair = tokenize_pair
        self.yes_id, self.no_id = yes_id, no_id
        self.max_len = max_len
        self.mesh = mesh
        self.forward_batches = 0   # compiled forward invocations
        self.pairs_scored = 0
        fwd = lambda p, b: forward(cfg, p, b)  # noqa: E731
        if mesh is not None:
            shards = mesh_batch_shards(mesh)
            batch_size = -(-batch_size // shards) * shards
            fwd = data_parallel(fwd, mesh)
        self.batch_size = batch_size
        self._fwd = jax.jit(fwd)
        # power-of-two padded lengths: a bounded shape set, so long flushes
        # never recompile and short pairs don't pay max_len compute
        buckets = []
        b = max(min(min_bucket, max_len), 1)
        while b < max_len:
            buckets.append(b)
            b *= 2
        buckets.append(max_len)
        self._buckets = np.array(buckets, np.int64)

    def _tokenize(self, pairs: np.ndarray) -> list:
        return [
            np.asarray(self.tokenize_pair(p), np.int32)[: self.max_len]
            for p in pairs
        ]

    @staticmethod
    def _pad_block(seqs: list, pad_len: int) -> tuple[np.ndarray, np.ndarray]:
        """Vectorized ragged->padded scatter: one fancy-index assignment for
        the whole block instead of a Python loop over rows."""
        n = len(seqs)
        lens = np.fromiter((len(s) for s in seqs), np.int64, n)
        toks = np.zeros((n, pad_len), np.int32)
        flat = np.concatenate(seqs) if n else np.zeros(0, np.int32)
        rows = np.repeat(np.arange(n), lens)
        starts = np.cumsum(lens) - lens
        cols = np.arange(int(lens.sum())) - np.repeat(starts, lens)
        toks[rows, cols] = flat
        return toks, np.maximum(lens - 1, 0).astype(np.int32)

    def score(self, pairs: np.ndarray) -> np.ndarray:
        pairs = np.asarray(pairs)
        n = len(pairs)
        if n == 0:
            return np.zeros(0, np.float64)
        seqs = self._tokenize(pairs)
        lens = np.fromiter((len(s) for s in seqs), np.int64, n)
        pad_of = self._buckets[np.searchsorted(self._buckets, lens)]
        out = np.empty(n, np.float64)
        bs = self.batch_size
        for pad_len in np.unique(pad_of):
            sel = np.nonzero(pad_of == pad_len)[0]
            for s in range(0, len(sel), bs):
                idxs = sel[s : s + bs]
                toks, last = self._pad_block([seqs[i] for i in idxs], int(pad_len))
                pad_rows = bs - len(idxs)
                if pad_rows:
                    toks = np.concatenate(
                        [toks, np.zeros((pad_rows, int(pad_len)), np.int32)]
                    )
                    last = np.concatenate([last, np.zeros(pad_rows, np.int32)])
                logits = self._fwd(self.params, {"tokens": jnp.asarray(toks)})
                self.forward_batches += 1
                lg = np.asarray(
                    logits[np.arange(bs), last, :][:, [self.yes_id, self.no_id]],
                    np.float64,
                )
                out[idxs] = _stable_yes_no_prob(lg)[: len(idxs)]
        self.pairs_scored += n
        return out


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray           # (L,) int32
    max_new_tokens: int = 16
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class ContinuousBatcher:
    """Slot-based continuous batching over the single-token decode step.

    Prefill is run through decode steps token-by-token per slot (correct and
    simple; a production setup runs a separate prefill graph).  All slots
    advance together each step; empty slots decode a pad token into a junk
    region that is never read.

    Admission: for the attention families the batcher passes **per-slot
    positions** to ``decode_step``, so a queued request is admitted into any
    freed slot mid-flight — its position rewinds to 0 and the per-slot causal
    mask keeps it from attending to the previous occupant's stale KV entries.
    The recurrent families (ssm / hybrid ring-buffer) carry state that cannot
    be rewound per slot — and even an idle slot absorbs pad tokens into its
    state every step — so admission is gated there: requests are only
    admitted at step 0, and when every slot has drained the batcher resets
    the cache and admits the next wave.
    """

    def __init__(self, cfg: ModelConfig, params, batch_size: int = 4,
                 max_len: int = 256, eos_id: int = 1,
                 greedy: bool = True):
        self.cfg = cfg
        self.params = params
        self.b = batch_size
        self.max_len = max_len
        self.eos_id = eos_id
        self.cache = init_cache(cfg, batch_size, max_len)
        self.slots: list = [None] * batch_size
        self.pos = np.zeros(batch_size, np.int64)         # per-slot next write position
        self.prompt_left: list = [0] * batch_size
        self.queue: list = []
        self.finished: list = []
        self._step = jax.jit(
            lambda p, c, t, pos: decode_step(cfg, p, c, t, pos)
        )
        self.global_pos = 0
        self.per_slot_pos = cfg.has_positional_cache

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        if self.per_slot_pos:
            for i in range(self.b):
                if self.slots[i] is None and self.queue:
                    req = self.queue.pop(0)
                    self.slots[i] = req
                    self.prompt_left[i] = len(req.prompt)
                    self.pos[i] = 0
            return
        # gated admission (scalar position): recurrent state absorbs pad
        # tokens even in idle slots, so only step 0 is safe; once everything
        # drained, reset the cache and start a new wave
        if self.queue and self.global_pos > 0 and all(s is None for s in self.slots):
            self.cache = init_cache(self.cfg, self.b, self.max_len)
            self.global_pos = 0
        if self.global_pos != 0:
            return
        for i in range(self.b):
            if self.slots[i] is None and self.queue:
                req = self.queue.pop(0)
                self.slots[i] = req
                self.prompt_left[i] = len(req.prompt)
                self.pos[i] = 0

    def step(self):
        """Advance every active slot by one token."""
        self._admit()
        toks = np.zeros((self.b, 1), np.int32)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            consumed = len(req.prompt) - self.prompt_left[i]
            if self.prompt_left[i] > 0:
                toks[i, 0] = req.prompt[consumed]
            else:
                toks[i, 0] = req.out_tokens[-1] if req.out_tokens else self.eos_id
        if self.per_slot_pos:
            position = jnp.asarray(np.minimum(self.pos, self.max_len - 1), jnp.int32)
        else:
            position = jnp.int32(self.global_pos)
        logits, self.cache = self._step(
            self.params, self.cache, jnp.asarray(toks), position
        )
        logits = np.asarray(logits, np.float32)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            self.pos[i] += 1
            if self.per_slot_pos and self.pos[i] >= self.max_len:
                # positional cache capacity exhausted (possibly still
                # mid-prompt): keep this step's token if we were generating,
                # then terminate rather than clobber the last KV position.
                # Recurrent families have no positional capacity to exhaust.
                if self.prompt_left[i] <= 1:
                    req.out_tokens.append(int(np.argmax(logits[i])))
                req.done = True
                self.finished.append(req)
                self.slots[i] = None
                continue
            if self.prompt_left[i] > 1:
                self.prompt_left[i] -= 1
                continue
            if self.prompt_left[i] == 1:
                self.prompt_left[i] = 0  # last prompt token consumed: sample
            nxt = int(np.argmax(logits[i]))
            req.out_tokens.append(nxt)
            if nxt == self.eos_id or len(req.out_tokens) >= req.max_new_tokens:
                req.done = True
                self.finished.append(req)
                self.slots[i] = None
        self.global_pos += 1

    def run_until_done(self, max_steps: int = 10_000):
        while (any(s is not None for s in self.slots) or self.queue) and max_steps:
            self.step()
            max_steps -= 1
        return self.finished
