"""Serving layer: batched pair scoring (the Oracle endpoint BAS calls) and a
slot-based continuous batcher for autoregressive decode.

PairScorer — the paper's Oracle as a service: serialize a record pair to
tokens, run the scoring LM, read P(match) from the final-position logits of
the YES/NO token ids.  Batches are padded to fixed shapes so the jitted
forward is reused (no recompilation per request).

ContinuousBatcher — fixed B decode slots; finished sequences vacate their
slot and queued requests are admitted mid-flight (per-slot positions), the
standard serving pattern for mixed-length batches.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, forward, init_cache
from repro.models.config import ModelConfig


class PairScorer:
    """Batched Oracle scoring: score(idx_pairs) -> P(match) per pair."""

    def __init__(self, cfg: ModelConfig, params, tokenize_pair: Callable,
                 yes_id: int, no_id: int, max_len: int = 128,
                 batch_size: int = 32):
        self.cfg = cfg
        self.params = params
        self.tokenize_pair = tokenize_pair
        self.yes_id, self.no_id = yes_id, no_id
        self.max_len = max_len
        self.batch_size = batch_size
        self._fwd = jax.jit(lambda p, b: forward(cfg, p, b))

    def _encode(self, pairs: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        toks = np.zeros((len(pairs), self.max_len), np.int32)
        last = np.zeros((len(pairs),), np.int32)
        for i, pair in enumerate(pairs):
            t = self.tokenize_pair(pair)[: self.max_len]
            toks[i, : len(t)] = t
            last[i] = len(t) - 1
        return toks, last

    def score(self, pairs: np.ndarray) -> np.ndarray:
        out = np.zeros((len(pairs),), np.float64)
        bs = self.batch_size
        for s in range(0, len(pairs), bs):
            chunk = pairs[s : s + bs]
            toks, last = self._encode(chunk)
            pad = bs - len(chunk)
            if pad:
                toks = np.concatenate([toks, np.zeros((pad, self.max_len), np.int32)])
                last = np.concatenate([last, np.zeros((pad,), np.int32)])
            logits = self._fwd(self.params, {"tokens": jnp.asarray(toks)})
            lg = np.asarray(
                logits[np.arange(bs), last, :][:, [self.yes_id, self.no_id]],
                np.float64,
            )
            p = np.exp(lg[:, 0]) / (np.exp(lg[:, 0]) + np.exp(lg[:, 1]) + 1e-30)
            out[s : s + len(chunk)] = p[: len(chunk)]
        return out


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray           # (L,) int32
    max_new_tokens: int = 16
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class ContinuousBatcher:
    """Slot-based continuous batching over the single-token decode step.

    Prefill is run through decode steps token-by-token per slot (correct and
    simple; a production setup runs a separate prefill graph).  All slots
    advance together each step; empty slots decode a pad token into a junk
    region that is never read.
    """

    def __init__(self, cfg: ModelConfig, params, batch_size: int = 4,
                 max_len: int = 256, eos_id: int = 1,
                 greedy: bool = True):
        self.cfg = cfg
        self.params = params
        self.b = batch_size
        self.max_len = max_len
        self.eos_id = eos_id
        self.cache = init_cache(cfg, batch_size, max_len)
        self.slots: list = [None] * batch_size
        self.pos = np.zeros(batch_size, np.int64)         # next write position
        self.prompt_left: list = [0] * batch_size
        self.queue: list = []
        self.finished: list = []
        self._step = jax.jit(
            lambda p, c, t, pos: decode_step(cfg, p, c, t, pos)
        )
        self.global_pos = 0

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for i in range(self.b):
            if self.slots[i] is None and self.queue:
                # slot reuse requires cache positions >= current global step;
                # simple policy: admit only at global_pos == 0 or into virgin
                # slots (tests cover mid-flight admission separately)
                req = self.queue.pop(0)
                self.slots[i] = req
                self.prompt_left[i] = len(req.prompt)
                self.pos[i] = 0

    def step(self):
        """Advance every active slot by one token."""
        self._admit()
        toks = np.zeros((self.b, 1), np.int32)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            consumed = len(req.prompt) - self.prompt_left[i]
            if self.prompt_left[i] > 0:
                toks[i, 0] = req.prompt[consumed]
            else:
                toks[i, 0] = req.out_tokens[-1] if req.out_tokens else self.eos_id
        logits, self.cache = self._step(
            self.params, self.cache, jnp.asarray(toks), jnp.int32(self.global_pos)
        )
        logits = np.asarray(logits, np.float32)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            if self.prompt_left[i] > 1:
                self.prompt_left[i] -= 1
                continue
            if self.prompt_left[i] == 1:
                self.prompt_left[i] = 0  # last prompt token consumed: sample
            nxt = int(np.argmax(logits[i]))
            req.out_tokens.append(nxt)
            if nxt == self.eos_id or len(req.out_tokens) >= req.max_new_tokens:
                req.done = True
                self.finished.append(req)
                self.slots[i] = None
        self.global_pos += 1

    def run_until_done(self, max_steps: int = 10_000):
        while (any(s is not None for s in self.slots) or self.queue) and max_steps:
            self.step()
            max_steps -= 1
        return self.finished
