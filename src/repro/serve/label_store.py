"""Service-resident shared label store: charge-once caching across queries.

Oracle labels are pure functions of (tuple indices, scorer), yet every query
keeps a *private* sorted flat-index cache (``repro.core.oracle``), so
concurrent and repeat queries on hot table pairs re-pay the ML oracle for
identical pairs — exactly the pairwise-execution cost the paper's BaS design
exists to avoid.  :class:`LabelStore` promotes that per-query cache into a
communal, service-scoped one: the :class:`~repro.serve.oracle_service
.OracleService` window planner dedupes each plan's uncached keys against the
store **before any ledger is charged**, serves hits from memory at commit
time, and writes misses back after a successful backend round trip.

Segments
--------
Labels live in *segments* keyed by ``(service_group(), encoding)``.  The
service-group part guarantees two oracles share a segment only when their
``_label`` is the same pure function (same served scorer + threshold, or the
same wire group); the encoding part — ``("sizes", s1, ..., sk)`` for
bound oracles, ``("pack", k, bits)`` for the unbound bit-packing — guarantees
their int64 flat keys mean the same tuples.  Keys whose service group is
:data:`~repro.core.oracle.PROCESS_LOCAL` (id()-derived, meaningless in
another process) still coalesce in memory but are never persisted.

Charge-once budget policy
-------------------------
A store-served label is *acquired* but not *executed*: the requesting
oracle's ``calls`` counter (which paces the BAS pipeline and meters the
user-facing budget guarantee) advances exactly as in serial execution — so
estimates stay bit-identical — while its ``charged`` counter (backend
executions actually paid for) does not.  The first requester of a pair pays
(``charged`` += misses); every later or concurrent requester rides for free
(``store_hits``/``store_charge_saved`` in ``QueryResult.detail["oracle"]``).
Summed over a workload, total charges equal the store's unique-miss count —
at most the number of distinct pairs ever labelled.

In-flight coalescing
--------------------
:meth:`plan` atomically classifies keys as **hit** (resident — values
captured immediately, so later eviction cannot fail the window), **wait**
(reserved by another in-flight plan — the waiter shares that plan's
``token`` future and its single backend call), or **miss** (this caller
reserves them and must :meth:`publish` or :meth:`cancel`).  Two windows —
even from two services sharing one store — racing on the same uncached pair
therefore trigger exactly one backend call.

Memory budget
-------------
``max_bytes`` bounds residency with LRU *segment* eviction, mirroring the
PR 6 ``IndexStore`` idiom (never the segment just touched, never one with
in-flight reservations).  Because one hot scorer group is the common case,
a lone over-budget segment additionally self-trims its oldest-inserted half
(``store_trimmed``) — so the budget holds even with a single segment.

Persistence
-----------
With ``root`` set, stable segments are written via
``repro.checkpoint.label_io`` (atomic tmp + ``os.replace``, self-verifying
meta.json — the same posture as the stratification index store) by
:meth:`save`, and loaded back at construction, so a service restart keeps
its hot labels.  ``OracleService.close()`` saves automatically.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from concurrent.futures import Future
from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core.oracle import PROCESS_LOCAL


def pack_tuples(idx: np.ndarray) -> Optional[np.ndarray]:
    """(n, k) tuple indices -> (n,) int64 keys under the fixed ``63 // k``-bit
    packing (the unbound :class:`~repro.core.oracle.Oracle` encoding), or
    ``None`` when some index does not fit — the caller then skips the store
    for that segment instead of colliding keys."""
    idx = np.asarray(idx)
    n, k = idx.shape
    bits = 63 // k
    if idx.size and (int(idx.min()) < 0 or int(idx.max()) >= (1 << bits)):
        return None
    keys = np.zeros(n, np.int64)
    for j in range(k):
        keys = (keys << bits) | idx[:, j].astype(np.int64)
    return keys


def unpack_tuples(keys: np.ndarray, k: int) -> np.ndarray:
    """Inverse of :func:`pack_tuples` for the rows a raw segment must still
    execute."""
    bits = 63 // k
    mask = (1 << bits) - 1
    keys = np.asarray(keys, np.int64)
    cols = [(keys >> (bits * (k - 1 - j))) & mask for j in range(k)]
    return np.stack(cols, axis=1).astype(np.int64)


def _flatten(obj):
    if isinstance(obj, (tuple, list)):
        for x in obj:
            yield from _flatten(x)
    else:
        yield obj


def persistable_key(key) -> bool:
    """True when a segment key survives a restart: built purely from
    str/int/float/bool and free of the :data:`PROCESS_LOCAL` marker that
    tags id()-derived (per-process) service groups."""
    parts = list(_flatten(key))
    if any(p == PROCESS_LOCAL for p in parts if isinstance(p, str)):
        return False
    return all(isinstance(p, (str, int, float, bool)) for p in parts)


class _StoreSegment:
    """One service group's resident labels: sorted int64 keys, aligned float64
    values, per-entry insertion generations (for oldest-first trimming), and
    the in-flight reservation map ``pending: key -> owning plan's token``."""

    __slots__ = ("keys", "vals", "gens", "pending")

    def __init__(self):
        self.keys = np.empty(0, np.int64)
        self.vals = np.empty(0, np.float64)
        self.gens = np.empty(0, np.int64)
        self.pending: dict[int, Future] = {}

    @property
    def nbytes(self) -> int:
        return self.keys.nbytes + self.vals.nbytes + self.gens.nbytes

    def resident_mask(self, keys: np.ndarray) -> tuple:
        pos = np.searchsorted(self.keys, keys)
        in_range = pos < len(self.keys)
        hit = np.zeros(len(keys), bool)
        hit[in_range] = self.keys[pos[in_range]] == keys[in_range]
        return hit, pos

    def merge(self, keys: np.ndarray, vals: np.ndarray, gen: int) -> int:
        """Insert (key, val) pairs not already resident; returns how many."""
        hit, _ = self.resident_mask(keys)
        keys, vals = keys[~hit], vals[~hit]
        if not len(keys):
            return 0
        merged_k = np.concatenate([self.keys, keys])
        merged_v = np.concatenate([self.vals, vals])
        merged_g = np.concatenate([self.gens, np.full(len(keys), gen, np.int64)])
        order = np.argsort(merged_k, kind="stable")
        self.keys, self.vals, self.gens = (
            merged_k[order], merged_v[order], merged_g[order]
        )
        return len(keys)

    def trim_oldest_half(self) -> int:
        """Drop the oldest-inserted half of the entries (keys stay sorted)."""
        n = len(self.keys)
        n_drop = max(n // 2, 1)
        order = np.argsort(self.gens, kind="stable")
        keep = np.ones(n, bool)
        keep[order[:n_drop]] = False
        self.keys, self.vals, self.gens = (
            self.keys[keep], self.vals[keep], self.gens[keep]
        )
        return n_drop


@dataclass
class StorePlan:
    """One atomic store consultation (see :meth:`LabelStore.plan`).

    ``hit_keys``/``hit_vals`` are served immediately; ``wait`` holds
    ``(token, keys)`` pairs for keys reserved by other in-flight plans (each
    token resolves to the owner's ``(published_keys, vals)``); ``miss_keys``
    are reserved by *this* plan — after the backend round trip the owner must
    :meth:`~LabelStore.publish` (success) or :meth:`~LabelStore.cancel`
    (failure), or every waiter deadlocks."""

    seg_key: object
    hit_keys: np.ndarray
    hit_vals: np.ndarray
    miss_keys: np.ndarray
    wait: list
    token: Optional[Future]


class LabelStore:
    """Thread-safe shared label cache, bounded by ``max_bytes``, optionally
    persisted under ``root`` (module docstring has the full semantics)."""

    def __init__(self, max_bytes: int = 256 << 20, root: Optional[str] = None,
                 tracker=None):
        from repro.obs import NULL_TRACKER

        self.max_bytes = int(max_bytes)
        self.root = root
        self.tracker = tracker if tracker is not None else NULL_TRACKER
        self._lock = threading.Lock()
        self._segments: "OrderedDict[object, _StoreSegment]" = OrderedDict()
        self._gen = 0
        self.hits = 0          # keys served from resident entries
        self.shared = 0        # keys served by riding another plan's call
        self.misses = 0        # keys reserved for backend execution
        self.insertions = 0
        self.evictions = 0     # whole segments dropped (LRU)
        self.trimmed = 0       # entries dropped from an over-budget segment
        self.saves = 0
        self.loads = 0
        if root is not None:
            self._load()

    # ---- the window-planner interface --------------------------------------

    def plan(self, seg_key, keys: np.ndarray) -> StorePlan:
        """Atomically classify sorted-unique ``keys`` into hit / wait / miss
        and reserve the misses (one token future for the whole miss set).
        Hit values are captured under the lock, so eviction between plan and
        commit can never fail a window."""
        keys = np.asarray(keys, np.int64)
        with self._lock:
            seg = self._segments.get(seg_key)
            if seg is None:
                seg = self._segments[seg_key] = _StoreSegment()
            self._segments.move_to_end(seg_key)
            hit, pos = seg.resident_mask(keys)
            hit_keys = keys[hit]
            hit_vals = seg.vals[pos[hit]]
            rest = keys[~hit]
            wait_map: "OrderedDict[Future, list]" = OrderedDict()
            if seg.pending:
                miss_list = []
                for k in rest.tolist():
                    fut = seg.pending.get(k)
                    if fut is None:
                        miss_list.append(k)
                    else:
                        wait_map.setdefault(fut, []).append(k)
                miss_keys = np.asarray(miss_list, np.int64)
            else:
                miss_keys = rest
            token = None
            if len(miss_keys):
                token = Future()
                for k in miss_keys.tolist():
                    seg.pending[k] = token
            self.hits += len(hit_keys)
            self.shared += len(rest) - len(miss_keys)
            self.misses += len(miss_keys)
            wait = [(fut, np.asarray(ks, np.int64))
                    for fut, ks in wait_map.items()]
        return StorePlan(seg_key=seg_key, hit_keys=hit_keys,
                         hit_vals=hit_vals, miss_keys=miss_keys,
                         wait=wait, token=token)

    def publish(self, plan: StorePlan, vals: np.ndarray) -> None:
        """Write back a successful backend round trip: insert the plan's miss
        keys, release their reservations, resolve the token (waiters — in
        this window or another service's — read ``(miss_keys, vals)`` from
        it), and enforce the memory budget."""
        if plan.token is None:
            return
        vals = np.asarray(vals, np.float64)
        with self._lock:
            seg = self._segments.get(plan.seg_key)
            if seg is not None:
                for k in plan.miss_keys.tolist():
                    if seg.pending.get(k) is plan.token:
                        del seg.pending[k]
                self._gen += 1
                self.insertions += seg.merge(plan.miss_keys, vals, self._gen)
                self._admit_locked(plan.seg_key)
        plan.token.set_result((plan.miss_keys, vals))

    def cancel(self, plan: StorePlan, exc: BaseException) -> None:
        """Release a failed plan's reservations and fail its token, so
        waiters fail retryably and the keys become reservable again."""
        if plan.token is None:
            return
        with self._lock:
            seg = self._segments.get(plan.seg_key)
            if seg is not None:
                for k in plan.miss_keys.tolist():
                    if seg.pending.get(k) is plan.token:
                        del seg.pending[k]
        if not plan.token.done():
            plan.token.set_exception(exc)

    def resident(self, seg_key, keys: np.ndarray) -> np.ndarray:
        """Boolean residency mask — observability/tests only: no counters,
        no reservations, no LRU touch."""
        keys = np.asarray(keys, np.int64)
        with self._lock:
            seg = self._segments.get(seg_key)
            if seg is None:
                return np.zeros(len(keys), bool)
            return seg.resident_mask(keys)[0]

    # ---- memory budget -----------------------------------------------------

    def _admit_locked(self, hot_key) -> None:
        total = sum(s.nbytes for s in self._segments.values())
        while total > self.max_bytes:
            victim = None
            for k, seg in self._segments.items():   # OrderedDict: LRU first
                if k == hot_key or seg.pending:
                    continue        # never the segment just touched, never
                    # one with in-flight reservations
                victim = k
                break
            if victim is not None:
                total -= self._segments.pop(victim).nbytes
                self.evictions += 1
                self.tracker.count("label_store.evictions")
                continue
            hot = self._segments.get(hot_key)
            if hot is None or len(hot.keys) <= 1:
                break
            self.trimmed += hot.trim_oldest_half()
            total = sum(s.nbytes for s in self._segments.values())

    # ---- persistence (repro.checkpoint.label_io) ---------------------------

    def save(self) -> int:
        """Persist every stable non-empty segment under ``root`` (atomic per
        segment); returns how many were written.  No-op without a root."""
        if self.root is None:
            return 0
        from repro.checkpoint.label_io import save_segment

        with self._lock:
            snap = [
                (key, seg.keys.copy(), seg.vals.copy())
                for key, seg in self._segments.items()
                if len(seg.keys) and persistable_key(key)
            ]
        for key, keys, vals in snap:
            save_segment(self.root, key, keys, vals)
        with self._lock:
            self.saves += len(snap)
        return len(snap)

    def _load(self) -> None:
        from repro.checkpoint.label_io import load_segments

        for key, keys, vals in load_segments(self.root):
            seg = _StoreSegment()
            seg.keys = np.asarray(keys, np.int64)
            seg.vals = np.asarray(vals, np.float64)
            seg.gens = np.zeros(len(seg.keys), np.int64)
            with self._lock:
                self._segments[key] = seg
                self.loads += 1
                self._admit_locked(key)

    # ---- observability -----------------------------------------------------

    @property
    def bytes_resident(self) -> int:
        with self._lock:
            return sum(s.nbytes for s in self._segments.values())

    def stats(self) -> dict:
        with self._lock:
            n_segments = len(self._segments)
            entries = sum(len(s.keys) for s in self._segments.values())
            nbytes = sum(s.nbytes for s in self._segments.values())
        served = self.hits + self.shared
        total = served + self.misses
        return {
            "store_segments": n_segments,
            "store_entries": entries,
            "store_bytes": nbytes,
            "store_hits": self.hits,
            "store_shared": self.shared,
            "store_misses": self.misses,
            "store_insertions": self.insertions,
            "store_evictions": self.evictions,
            "store_trimmed": self.trimmed,
            "store_saves": self.saves,
            "store_loads": self.loads,
            "store_hit_rate": round(served / total, 4) if total else 0.0,
        }

    def snapshot(self) -> dict[str, float]:
        """Unified stats surface: ``label_store.*`` namespaced floats."""
        return {
            "label_store." + k[len("store_"):]: float(v)
            for k, v in self.stats().items()
        }


__all__ = ["LabelStore", "StorePlan", "pack_tuples", "unpack_tuples",
           "persistable_key"]
