"""Multi-host oracle dispatch: a TCP transport in front of the oracle service.

This module is the network layer the ROADMAP's "Serving architecture" section
left open after PR 3: :class:`~repro.serve.oracle_service.OracleService`
already window-batches flushes across any number of in-process queries; here
the same window/plan/commit machinery is exposed over TCP so one serving
fleet feeds many *client processes*, and a server can additionally shard its
super-batches over *remote worker hosts* (each running its own — possibly
mesh-sharded — scorer).  Everything is stdlib ``socket``/``socketserver``;
no new dependencies.  docs/serving.md carries the full protocol spec and
deployment topology.

Wire protocol (v1)
------------------
Every message is one length-prefixed binary frame::

    +----------------+----------+---------------------------+
    | length: u32 BE | type: u8 | payload (length - 1 bytes)|
    +----------------+----------+---------------------------+

Message types:

====  ==========  =======================================================
code  name        payload
====  ==========  =======================================================
0x01  EXEC        :class:`repro.core.oracle.LabelRequest` bytes
0x02  RESULT      :class:`repro.core.oracle.LabelResult` bytes (labels)
0x03  ERROR       :class:`LabelResult` bytes (``error`` set, no rows)
0x04  PING        empty
0x05  PONG        empty
0x06  GROUPS      empty (request the server's registered group names)
0x07  GROUPS_OK   ``\\n``-joined utf-8 group names
0x08  HELLO       empty (one-way: announce a query client; no reply)
====  ==========  =======================================================

HELLO is how window assembly knows who to wait for: a query client
(:class:`RemoteOracle`) announces itself on every (re)connect and the
server's service then counts the connection toward window close, exactly
like an attached in-process oracle.  Un-announced connections — monitors,
registration handshakes, or sockets that never send a frame — are never
waited for (a connection's first EXEC also counts as an announcement).

A client keeps one connection and at most one in-flight EXEC (the batch
flush protocol is submit-then-await, so this is the natural discipline); the
server answers every EXEC with exactly one RESULT or ERROR on the same
connection.  There is no request pipelining in v1 — ``request_id`` exists so
a future pipelined revision stays wire-compatible.

Semantics and failure model
---------------------------
* **Planning and commit never leave the client.**  A :class:`RemoteOracle`
  is an ordinary :class:`~repro.core.oracle.Oracle` whose ``_label`` executes
  on the server, so ``OracleBatch.flush_async()`` gives a remote query
  exactly the local-flush semantics for free: dedup against its *own* cache,
  atomic budget charge on its *own* ledger, retryable atomic failure.  The
  server is a pure labelling fleet — it holds scorers, not ledgers.
* **Reconnect + retry.**  Labelling is pure, and the ledger is charged only
  after a successful round trip, so re-sending an EXEC after a transport
  drop is always safe (no double charge, bit-identical labels).
  :class:`ServiceConnection` retries transport failures (connection refused /
  reset / truncated frame) with backoff; application ERRORs raise
  :class:`RemoteExecutionError` immediately — they are the server telling the
  client something retries won't fix (e.g. an unregistered group).
* **Per-client isolation.**  Each connection gets its own handler thread and
  its own segments in the service queue; one client's failure or disconnect
  completes only that client's futures.
* **Remote workers.**  A worker host runs the same :class:`OracleServiceServer`
  (a server with no downstream is a worker); the front server registers it via
  :meth:`OracleServiceServer.register_worker`, and the service then shards
  each super-batch across local worker threads *and* worker hosts, falling
  back to local execution for any shard whose worker host fails mid-batch.
"""
from __future__ import annotations

import socket
import socketserver
import struct
import threading
import time
from typing import Callable, Optional

import numpy as np

from repro.core.oracle import LabelRequest, LabelResult, ModelOracle, Oracle

MSG_EXEC = 0x01
MSG_RESULT = 0x02
MSG_ERROR = 0x03
MSG_PING = 0x04
MSG_PONG = 0x05
MSG_GROUPS = 0x06
MSG_GROUPS_OK = 0x07
MSG_HELLO = 0x08

_LEN = struct.Struct("!I")
# One EXEC of n pairs is ~16n bytes; 256 MiB of frame is ~16M rows — far
# beyond any sane super-batch, so anything larger is a corrupt length prefix.
MAX_FRAME = 1 << 28


class TransportError(ConnectionError):
    """A transport-level failure (drop, truncation, corrupt frame) — the
    retryable class of failure."""


class RemoteExecutionError(RuntimeError):
    """The server executed the request and reports an application error
    (unknown group, backend failure).  Not retried by the transport: the
    flush fails atomically client-side and the *flush* can be retried once
    the cause is fixed, exactly like a local backend error."""


def send_frame(sock: socket.socket, mtype: int, payload: bytes = b"") -> None:
    sock.sendall(_LEN.pack(1 + len(payload)) + bytes([mtype]) + payload)


def recv_frame(sock: socket.socket) -> tuple[int, bytes]:
    """Read one frame; raises :class:`TransportError` on EOF/truncation."""
    hdr = _recv_exact(sock, _LEN.size)
    (length,) = _LEN.unpack(hdr)
    if not 1 <= length <= MAX_FRAME:
        raise TransportError(f"corrupt frame length {length}")
    body = _recv_exact(sock, length)
    return body[0], body[1:]


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    chunks = []
    while n:
        chunk = sock.recv(min(n, 1 << 20))
        if not chunk:
            raise TransportError("connection closed mid-frame")
        chunks.append(chunk)
        n -= len(chunk)
    return b"".join(chunks)


# ---- client side -----------------------------------------------------------


class ServiceConnection:
    """One client connection with reconnect-and-retry.

    ``execute`` is the workhorse: frame an EXEC, await the matching RESULT,
    and on any transport failure reconnect (with exponential backoff) and
    re-send — safe because the server's labelling is pure and commit happens
    on the caller's side only after success.  Thread-safe via a round-trip
    lock: concurrent callers (e.g. service worker threads sharding one
    super-batch over several hosts) serialize on the single connection.
    """

    def __init__(self, address: tuple[str, int], retries: int = 5,
                 backoff_s: float = 0.05, timeout_s: float = 120.0,
                 announce: bool = False):
        self.address = (str(address[0]), int(address[1]))
        self.retries = int(retries)
        self.backoff_s = float(backoff_s)
        self.timeout_s = float(timeout_s)
        # announce=True sends HELLO on every (re)connect: query clients do,
        # so the server's windows wait for them from the moment they connect;
        # control-plane connections (worker registration, monitors) don't
        self.announce = bool(announce)
        self.reconnects = 0           # observability: transport drops survived
        self._sock: Optional[socket.socket] = None
        self._lock = threading.Lock()
        self._seq = 0

    # -- lifecycle --

    def connect(self) -> bool:
        """Open the connection now instead of at the first round trip, so the
        server counts this client toward window assembly immediately (a
        late-connecting client fragments the windows its peers are already
        filling).  Returns False if the server is not reachable yet — the
        next round trip will retry."""
        try:
            with self._lock:
                self._ensure()
            return True
        except OSError:
            return False

    def _ensure(self) -> socket.socket:
        if self._sock is None:
            sock = socket.create_connection(self.address,
                                            timeout=self.timeout_s)
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            if self.announce:
                send_frame(sock, MSG_HELLO)     # one-way, no reply expected
            self._sock = sock
        return self._sock

    def _drop(self) -> None:
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
            self._sock = None

    def close(self) -> None:
        with self._lock:
            self._drop()

    def __enter__(self) -> "ServiceConnection":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- round trips --

    def _roundtrip(self, mtype: int, payload: bytes) -> tuple[int, bytes]:
        """Send one frame and read the reply, reconnecting and re-sending on
        transport failures.  The first attempt may ride a connection that
        died while idle (server restart between flushes) — that costs one
        retry, not a failed flush."""
        last: Exception = TransportError("no attempt made")
        for attempt in range(self.retries + 1):
            try:
                with self._lock:
                    fresh = self._sock is None
                    sock = self._ensure()
                    if fresh and attempt:
                        self.reconnects += 1
                    try:
                        send_frame(sock, mtype, payload)
                        return recv_frame(sock)
                    except (TransportError, OSError):
                        self._drop()
                        raise
            except (TransportError, OSError) as e:
                last = e
                if attempt < self.retries:
                    time.sleep(self.backoff_s * (2 ** attempt))
        raise TransportError(
            f"{self.address[0]}:{self.address[1]} unreachable after "
            f"{self.retries + 1} attempts: {last}"
        ) from last

    def execute(self, group: str, idx: np.ndarray) -> np.ndarray:
        """Label ``idx`` through the server-side ``group``; returns (n,)
        float64 labels.  Raises :class:`RemoteExecutionError` on application
        errors, :class:`TransportError` when the server stays unreachable."""
        idx = np.asarray(idx)
        if idx.ndim == 1:
            idx = idx[:, None]
        self._seq += 1
        req = LabelRequest(group=group, idx=idx, request_id=self._seq)
        mtype, payload = self._roundtrip(MSG_EXEC, req.to_bytes())
        if mtype not in (MSG_RESULT, MSG_ERROR):
            raise TransportError(f"unexpected reply type 0x{mtype:02x}")
        res = LabelResult.from_bytes(payload)
        # error replies surface before the id check: the server may not have
        # decoded our request far enough to know its id (one in-flight EXEC
        # per connection makes the attribution unambiguous anyway)
        if not res.ok:
            raise RemoteExecutionError(res.error)
        if res.request_id != req.request_id:
            raise TransportError(
                f"reply id {res.request_id} != request id {req.request_id}"
            )
        if len(res.labels) != len(idx):
            raise TransportError(
                f"reply carries {len(res.labels)} labels for {len(idx)} rows"
            )
        return res.labels

    def groups(self) -> tuple[str, ...]:
        """The server's registered group names (the worker handshake)."""
        mtype, payload = self._roundtrip(MSG_GROUPS, b"")
        if mtype != MSG_GROUPS_OK:
            raise TransportError(f"unexpected reply type 0x{mtype:02x}")
        text = payload.decode("utf-8")
        return tuple(g for g in text.split("\n") if g)

    def ping(self) -> bool:
        try:
            mtype, _ = self._roundtrip(MSG_PING, b"")
            return mtype == MSG_PONG
        except TransportError:
            return False


class RemoteOracle(Oracle):
    """An Oracle whose ``_label`` executes on a remote
    :class:`OracleServiceServer` — the client half of multi-host dispatch.

    Because this is an ordinary :class:`~repro.core.oracle.Oracle`, the whole
    batching stack composes unchanged: ``OracleBatch`` plans/commits against
    the local cache and ledger, ``flush_async()`` keeps the submit-then-await
    protocol, and attaching a *local* ``OracleService`` on the client side
    additionally overlaps the network round trip with the query's cheap work
    and coalesces multiple local queries before they ever hit the wire
    (RemoteOracles sharing a server address + group share a service group).
    """

    def __init__(self, address: tuple[str, int], group: str = "default",
                 retries: int = 5, backoff_s: float = 0.05,
                 timeout_s: float = 120.0):
        super().__init__()
        self.group = str(group)
        self.conn = ServiceConnection(address, retries=retries,
                                      backoff_s=backoff_s,
                                      timeout_s=timeout_s, announce=True)
        self.conn.connect()     # best-effort: count toward windows early

    def _label(self, idx: np.ndarray) -> np.ndarray:
        return self.conn.execute(self.group, idx)

    def service_group(self):
        return ("remote", self.conn.address, self.group)

    def close(self) -> None:
        """Drop the connection (the server sees a disconnect and stops
        counting this client toward window assembly)."""
        self.conn.close()

    def __enter__(self) -> "RemoteOracle":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class RemoteWorkerClient:
    """The front server's handle on one worker host: a
    :class:`ServiceConnection` plus the group names the worker advertised at
    registration.  ``OracleService._execute`` routes super-batch shards here.
    """

    def __init__(self, address: tuple[str, int], retries: int = 2,
                 backoff_s: float = 0.05, timeout_s: float = 120.0):
        self.conn = ServiceConnection(address, retries=retries,
                                      backoff_s=backoff_s,
                                      timeout_s=timeout_s)
        self.groups: frozenset = frozenset(self.conn.groups())

    @property
    def address(self) -> tuple[str, int]:
        return self.conn.address

    def execute(self, group: str, idx: np.ndarray) -> np.ndarray:
        return self.conn.execute(group, idx)

    def close(self) -> None:
        self.conn.close()


# ---- server side -----------------------------------------------------------


class _Server(socketserver.ThreadingTCPServer):
    allow_reuse_address = True      # restart-in-place (tests, rolling deploys)
    daemon_threads = True
    owner: "OracleServiceServer"


class _Handler(socketserver.BaseRequestHandler):
    """One connected client: count it toward window assembly, answer frames
    until EOF.  One thread per connection (ThreadingTCPServer), so blocking
    on the service future is the per-client await, not a server stall."""

    def handle(self) -> None:
        owner = self.server.owner
        self.request.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        owner._track(self.request, add=True)
        # window assembly waits only for ANNOUNCED connections: a query
        # client HELLOs at connect (and its first EXEC counts as an implicit
        # announcement), while control-plane traffic — PING health checks,
        # the GROUPS handshake of a front registering this host as a worker,
        # or a socket that never sends a frame at all — is never waited for.
        # An announced client that then only sends control frames is demoted
        # again, so a stray HELLO can't make every window run to the deadline.
        client_id = None
        counted, seen_exec = False, False
        try:
            while True:
                try:
                    mtype, payload = recv_frame(self.request)
                except (TransportError, OSError):
                    return                      # client went away
                if mtype == MSG_HELLO:
                    if not counted:
                        client_id = owner.service.client_connected()
                        counted = True
                    continue
                if mtype == MSG_EXEC:
                    if not counted:
                        client_id = owner.service.client_connected()
                        counted = True
                    seen_exec = True
                    self._exec(owner, client_id, payload)
                    continue
                if not seen_exec and counted:   # control-plane connection
                    owner.service.client_disconnected(client_id)
                    counted = False
                if mtype == MSG_PING:
                    send_frame(self.request, MSG_PONG)
                elif mtype == MSG_GROUPS:
                    names = "\n".join(sorted(owner.groups))
                    send_frame(self.request, MSG_GROUPS_OK,
                               names.encode("utf-8"))
                else:
                    res = LabelResult(error=f"ProtocolError: unknown message "
                                            f"type 0x{mtype:02x}")
                    send_frame(self.request, MSG_ERROR, res.to_bytes())
        finally:
            if counted:
                owner.service.client_disconnected(client_id)
            owner._track(self.request, add=False)

    def _exec(self, owner: "OracleServiceServer", client_id: int,
              payload: bytes) -> None:
        try:
            req = LabelRequest.from_bytes(payload)
        except Exception as e:
            # a deterministic protocol error (version skew, corrupt segment)
            # must be an ERROR reply, not a dropped connection the client
            # would misread as "server unreachable" and retry-loop against
            res = LabelResult(error=f"ProtocolError: undecodable EXEC "
                                    f"payload ({type(e).__name__}: {e})")
            send_frame(self.request, MSG_ERROR, res.to_bytes())
            return
        fn = owner.groups.get(req.group)
        if fn is None:
            res = LabelResult(request_id=req.request_id,
                              error=f"RemoteExecutionError: unknown group "
                                    f"{req.group!r} (registered: "
                                    f"{sorted(owner.groups)})")
            send_frame(self.request, MSG_ERROR, res.to_bytes())
            return
        try:
            fut = owner.service.submit_raw(req.group, fn, req.idx,
                                           client_id=client_id)
            labels = fut.result()
            mtype, res = MSG_RESULT, LabelResult(request_id=req.request_id,
                                                 labels=labels)
        except BaseException as e:  # noqa: BLE001 — isolate per client
            # ANY execution failure — including a backend raising OSError —
            # is an application error the client must see as ERROR (no
            # transport retry); only a failing send below drops the client
            mtype, res = MSG_ERROR, LabelResult(
                request_id=req.request_id, error=f"{type(e).__name__}: {e}"
            )
        send_frame(self.request, mtype, res.to_bytes())


class OracleServiceServer:
    """TCP front-end over an :class:`~repro.serve.oracle_service.OracleService`.

    ``groups`` maps wire group names to vectorised label functions
    ``fn(idx: (n, k) int array) -> (n,) float labels`` — e.g. a thresholded
    :class:`~repro.serve.serve_loop.PairScorer` (see :func:`scorer_group`).
    Segments arriving on different connections coalesce into the service's
    windows exactly like in-process flushes, fuse into per-group super-batches,
    and shard over the service's worker threads and any registered worker
    hosts.

    A server with no registered downstream workers *is* a worker host: run the
    same class on each host and point the front server at the others via
    :meth:`register_worker`.
    """

    def __init__(self, groups: dict[str, Callable], host: str = "127.0.0.1",
                 port: int = 0, service=None, **service_kwargs):
        from repro.serve.oracle_service import OracleService

        self.groups = dict(groups)
        self.service = service if service is not None else OracleService(
            **service_kwargs
        )
        self._owns_service = service is None
        self._workers: list[RemoteWorkerClient] = []
        self._conns: set = set()
        self._conns_lock = threading.Lock()
        self._tcp = _Server((host, int(port)), _Handler)
        self._tcp.owner = self
        self._thread = threading.Thread(
            target=self._tcp.serve_forever, name="oracle-server", daemon=True
        )
        self._thread.start()

    @property
    def address(self) -> tuple[str, int]:
        """The bound (host, port) — resolves ``port=0`` to the real port."""
        return self._tcp.server_address[:2]

    def register_worker(self, address: tuple[str, int]) -> RemoteWorkerClient:
        """Connect a worker host and hand it to the service: super-batches
        for any group the worker advertises now shard across hosts."""
        worker = RemoteWorkerClient(address)
        self._workers.append(worker)
        self.service.register_remote_worker(worker)
        return worker

    def _track(self, sock: socket.socket, add: bool) -> None:
        with self._conns_lock:
            (self._conns.add if add else self._conns.discard)(sock)

    def close(self) -> None:
        """Stop accepting, drop live connections (clients observe a transport
        drop and reconnect-retry elsewhere — or to a restarted server on the
        same port), close worker handles, and shut the service if owned."""
        self._tcp.shutdown()
        self._tcp.server_close()
        self._thread.join()
        with self._conns_lock:
            conns = list(self._conns)
        for sock in conns:
            try:
                sock.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                sock.close()
            except OSError:
                pass
        for w in self._workers:
            w.close()
        if self._owns_service:
            self.service.close()

    def __enter__(self) -> "OracleServiceServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def scorer_group(scorer, threshold: float = 0.5) -> Callable:
    """Adapt a pair scorer (``PairScorer`` instance or any vectorised
    probability callable) into a wire group's label function.  Literally
    :class:`~repro.core.oracle.ModelOracle`'s own ``_label`` (the throwaway
    oracle's cache/ledger are never touched), so remote and in-process
    execution are bit-identical by construction."""
    return ModelOracle(scorer, threshold=threshold)._label


def parse_address(spec: str, default_port: int = 7431) -> tuple[str, int]:
    """``"host[:port]"`` -> (host, port) for CLI flags."""
    host, _, port = spec.partition(":")
    return (host or "127.0.0.1", int(port) if port else default_port)


__all__ = [
    "MSG_EXEC", "MSG_RESULT", "MSG_ERROR", "MSG_PING", "MSG_PONG",
    "MSG_GROUPS", "MSG_GROUPS_OK", "MSG_HELLO",
    "TransportError", "RemoteExecutionError",
    "send_frame", "recv_frame",
    "ServiceConnection", "RemoteOracle", "RemoteWorkerClient",
    "OracleServiceServer", "scorer_group", "parse_address",
]
