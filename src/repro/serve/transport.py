"""Multi-host oracle dispatch: a TCP transport in front of the oracle service.

This module is the network layer the ROADMAP's "Serving architecture" section
left open after PR 3: :class:`~repro.serve.oracle_service.OracleService`
already window-batches flushes across any number of in-process queries; here
the same window/plan/commit machinery is exposed over TCP so one serving
fleet feeds many *client processes*, and a server can additionally shard its
super-batches over *remote worker hosts* (each running its own — possibly
mesh-sharded — scorer).  Everything is stdlib ``socket``/``socketserver``;
no new dependencies.  docs/serving.md carries the full protocol spec and
deployment topology.

Wire protocol (v1)
------------------
Every message is one length-prefixed binary frame::

    +----------------+----------+---------------------------+
    | length: u32 BE | type: u8 | payload (length - 1 bytes)|
    +----------------+----------+---------------------------+

Message types:

====  ==========  =======================================================
code  name        payload
====  ==========  =======================================================
0x01  EXEC        :class:`repro.core.oracle.LabelRequest` bytes
0x02  RESULT      :class:`repro.core.oracle.LabelResult` bytes (labels)
0x03  ERROR       :class:`LabelResult` bytes (``error`` set, no rows)
0x04  PING        empty
0x05  PONG        empty
0x06  GROUPS      empty (request the server's registered group names)
0x07  GROUPS_OK   ``\\n``-joined utf-8 group names
0x08  HELLO       empty (one-way: announce a query client; no reply)
====  ==========  =======================================================

HELLO is how window assembly knows who to wait for: a query client
(:class:`RemoteOracle`) announces itself on every (re)connect and the
server's service then counts the connection toward window close, exactly
like an attached in-process oracle.  Un-announced connections — monitors,
registration handshakes, or sockets that never send a frame — are never
waited for (a connection's first EXEC also counts as an announcement).

EXEC frames are **pipelined**: a client may keep any number of EXECs in
flight on one connection, each carrying a unique ``request_id``, and the
server answers every EXEC with exactly one RESULT or ERROR — possibly out
of order — on the same connection.  A background reader thread demuxes
replies by id (control replies — PONG, GROUPS_OK — are unnumbered and
matched FIFO, which is safe because the server handles control frames
inline in receive order).  Pipelining is what lets several worker threads
shard one super-batch over a single host connection concurrently, and lets
two in-flight flushes from one client fuse into one server window.  An
ERROR whose ``request_id`` is 0 (the server could not decode the request
far enough to know its id) fails every in-flight request on the connection
— attribution is ambiguous, and an undecodable frame means version skew
anyway.

Semantics and failure model
---------------------------
* **Planning and commit never leave the client.**  A :class:`RemoteOracle`
  is an ordinary :class:`~repro.core.oracle.Oracle` whose ``_label`` executes
  on the server, so ``OracleBatch.flush_async()`` gives a remote query
  exactly the local-flush semantics for free: dedup against its *own* cache,
  atomic budget charge on its *own* ledger, retryable atomic failure.  The
  server is a pure labelling fleet — it holds scorers, not ledgers.
* **Reconnect + retry.**  Labelling is pure, and the ledger is charged only
  after a successful round trip, so re-sending an EXEC after a transport
  drop is always safe (no double charge, bit-identical labels).
  :class:`ServiceConnection` retries transport failures (connection refused /
  reset / truncated frame) with backoff; application ERRORs raise
  :class:`RemoteExecutionError` immediately — they are the server telling the
  client something retries won't fix (e.g. an unregistered group).
* **Per-client isolation.**  Each connection gets its own handler thread and
  its own segments in the service queue; one client's failure or disconnect
  completes only that client's futures.
* **Remote workers.**  A worker host runs the same :class:`OracleServiceServer`
  (a server with no downstream is a worker); the front server registers it via
  :meth:`OracleServiceServer.register_worker`, and the service then shards
  each super-batch across local worker threads *and* worker hosts, falling
  back to local execution for any shard whose worker host fails mid-batch.
"""
from __future__ import annotations

import random
import socket
import socketserver
import struct
import threading
import time
from collections import deque
from concurrent.futures import Future
from concurrent.futures import TimeoutError as _FutureTimeout
from typing import Callable, Optional

import numpy as np

from repro.core.oracle import LabelRequest, LabelResult, ModelOracle, Oracle

MSG_EXEC = 0x01
MSG_RESULT = 0x02
MSG_ERROR = 0x03
MSG_PING = 0x04
MSG_PONG = 0x05
MSG_GROUPS = 0x06
MSG_GROUPS_OK = 0x07
MSG_HELLO = 0x08

_LEN = struct.Struct("!I")
# One EXEC of n pairs is ~16n bytes; 256 MiB of frame is ~16M rows — far
# beyond any sane super-batch, so anything larger is a corrupt length prefix.
MAX_FRAME = 1 << 28


class TransportError(ConnectionError):
    """A transport-level failure (drop, truncation, corrupt frame) — the
    retryable class of failure."""


class RemoteExecutionError(RuntimeError):
    """The server executed the request and reports an application error
    (unknown group, backend failure).  Not retried by the transport: the
    flush fails atomically client-side and the *flush* can be retried once
    the cause is fixed, exactly like a local backend error."""


def send_frame(sock: socket.socket, mtype: int, payload: bytes = b"") -> None:
    sock.sendall(_LEN.pack(1 + len(payload)) + bytes([mtype]) + payload)


def recv_frame(sock: socket.socket) -> tuple[int, bytes]:
    """Read one frame; raises :class:`TransportError` on EOF/truncation."""
    hdr = _recv_exact(sock, _LEN.size)
    (length,) = _LEN.unpack(hdr)
    if not 1 <= length <= MAX_FRAME:
        raise TransportError(f"corrupt frame length {length}")
    body = _recv_exact(sock, length)
    return body[0], body[1:]


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    chunks = []
    while n:
        chunk = sock.recv(min(n, 1 << 20))
        if not chunk:
            raise TransportError("connection closed mid-frame")
        chunks.append(chunk)
        n -= len(chunk)
    return b"".join(chunks)


# ---- client side -----------------------------------------------------------


class ServiceConnection:
    """One pipelined client connection with reconnect-and-retry.

    ``execute`` frames an EXEC, registers a per-request future keyed by
    ``request_id``, and awaits it; a background reader thread demuxes every
    reply on the connection to its future, so any number of caller threads
    keep requests in flight concurrently on the one socket.  On a transport
    failure (drop, truncation, reply timeout) every in-flight request on
    that connection epoch fails with :class:`TransportError` and each caller
    independently reconnects and re-sends with capped, jittered exponential
    backoff — safe because the server's labelling is pure and commit happens
    on the caller's side only after success.

    Epochs make reconnects race-free: each physical connect bumps an epoch
    counter, futures are registered under the epoch they were sent on, and
    a dying reader fails only its own epoch's futures — never requests that
    already moved to the replacement connection.
    """

    def __init__(self, address: tuple[str, int], retries: int = 5,
                 backoff_s: float = 0.05, max_backoff_s: float = 2.0,
                 timeout_s: float = 120.0, announce: bool = False,
                 tracker=None):
        from repro.obs import NULL_TRACKER, NoopTracker

        self.address = (str(address[0]), int(address[1]))
        # observability (repro.obs): RTT per round trip, reconnect/backoff
        # events, in-flight depth; a NoopTracker keeps the hooks free
        self.tracker = tracker if tracker is not None else NULL_TRACKER
        self._tracking = not isinstance(self.tracker, NoopTracker)
        self.retries = int(retries)
        self.backoff_s = float(backoff_s)
        self.max_backoff_s = float(max_backoff_s)
        self.timeout_s = float(timeout_s)
        # announce=True sends HELLO on every (re)connect: query clients do,
        # so the server's windows wait for them from the moment they connect;
        # control-plane connections (worker registration, monitors) don't
        self.announce = bool(announce)
        self.reconnects = 0           # observability: transport drops survived
        self._sock: Optional[socket.socket] = None
        self._epoch = 0               # bumped per physical connect
        self._lock = threading.Lock()       # connection + routing-table state
        self._send_lock = threading.Lock()  # frame writes are atomic
        self._seq = 0                       # globally monotonic request ids
        self._pending: dict[int, tuple[int, Future]] = {}
        self._ctrl: deque = deque()         # FIFO (epoch, Future) for PONG/…
        # control replies carry no request id, so they match their futures
        # by wire order; serializing control round trips (they are rare —
        # health checks and the worker handshake) keeps that trivial while
        # EXECs pipeline freely
        self._ctrl_lock = threading.Lock()

    # -- lifecycle --

    def connect(self) -> bool:
        """Open the connection now instead of at the first round trip, so the
        server counts this client toward window assembly immediately (a
        late-connecting client fragments the windows its peers are already
        filling).  Returns False if the server is not reachable yet — the
        next round trip will retry."""
        try:
            with self._lock:
                self._ensure()
            return True
        except OSError:
            return False

    def _ensure(self) -> tuple[socket.socket, int]:
        """(lock held) Current socket + its epoch, connecting if needed."""
        if self._sock is None:
            sock = socket.create_connection(self.address,
                                            timeout=self.timeout_s)
            # no read timeout after connect: the reader blocks on recv for
            # the connection's whole life (an announced client may idle far
            # longer than timeout_s between flushes); per-request deadlines
            # are enforced caller-side on the future instead
            sock.settimeout(None)
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            if self.announce:
                send_frame(sock, MSG_HELLO)     # one-way, no reply expected
            self._sock = sock
            if self._epoch:         # any connect after the first survived a
                self.reconnects += 1  # drop — count it even when the reader
                self.tracker.count("transport.reconnects")
                self.tracker.event("transport.reconnect",
                                   address=f"{self.address[0]}:"
                                           f"{self.address[1]}")
            self._epoch += 1          # noticed before a caller had to retry
            threading.Thread(target=self._read_loop,
                             args=(sock, self._epoch),
                             name="oracle-conn-reader", daemon=True).start()
        return self._sock, self._epoch

    def _drop(self) -> None:
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
            self._sock = None

    def close(self) -> None:
        self._fail_epoch(self._sock, None,
                         TransportError("connection closed"), drop=True)

    def __enter__(self) -> "ServiceConnection":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- reply demux --

    def _read_loop(self, sock: socket.socket, epoch: int) -> None:
        """Reader thread: one per connection epoch.  Routes numbered replies
        to their futures, control replies FIFO, and on any read failure fails
        every future of this epoch (callers then reconnect-retry)."""
        try:
            while True:
                mtype, payload = recv_frame(sock)
                if mtype in (MSG_RESULT, MSG_ERROR):
                    res = LabelResult.from_bytes(payload)
                    if mtype == MSG_ERROR and not res.request_id:
                        # the server could not decode a request far enough to
                        # know its id — attribution over a pipelined stream is
                        # ambiguous, so every in-flight request fails (the
                        # connection itself is still good: keep it)
                        self._fail_epoch(sock, epoch,
                                         RemoteExecutionError(res.error),
                                         drop=False)
                        continue
                    with self._lock:
                        entry = self._pending.pop(res.request_id, None)
                    if entry is None:       # reply raced a caller's timeout
                        continue
                    _, fut = entry
                    if mtype == MSG_ERROR:
                        fut.set_exception(RemoteExecutionError(res.error))
                    else:
                        fut.set_result(res)
                else:                       # PONG / GROUPS_OK / unknown
                    with self._lock:
                        fut = None
                        while self._ctrl:
                            e, f = self._ctrl.popleft()
                            if e == epoch:
                                fut = f
                                break
                    if fut is not None:
                        fut.set_result((mtype, payload))
        except Exception as e:  # noqa: BLE001 — any read failure kills epoch
            exc = e if isinstance(e, TransportError) else TransportError(
                f"{type(e).__name__}: {e}")
            self._fail_epoch(sock, epoch, exc, drop=True)

    def _fail_epoch(self, sock: Optional[socket.socket],
                    epoch: Optional[int], exc: Exception,
                    drop: bool) -> None:
        """Fail every in-flight future of ``epoch`` (all epochs if None) and,
        if ``drop``, retire the socket so the next attempt reconnects."""
        with self._lock:
            if drop and self._sock is sock:
                self._drop()
            doomed = [rid for rid, (e, _) in self._pending.items()
                      if epoch is None or e == epoch]
            victims = [self._pending.pop(rid)[1] for rid in doomed]
            keep = deque((e, f) for e, f in self._ctrl
                         if epoch is not None and e != epoch)
            victims += [f for e, f in self._ctrl
                        if epoch is None or e == epoch]
            self._ctrl = keep
        for fut in victims:
            if not fut.done():
                fut.set_exception(exc)

    # -- round trips --

    def _backoff(self, attempt: int) -> float:
        """Capped exponential backoff with full jitter: the cap keeps a long
        outage from stretching sleeps unboundedly, the jitter keeps a fleet
        of clients from reconnecting to a restarted server in lockstep."""
        base = min(self.backoff_s * (2 ** attempt), self.max_backoff_s)
        return base * (0.5 + random.random())

    def _submit(self, register, send) -> Future:
        """One attempt: connect if needed, register the reply future under
        the connection's epoch, write the frame.  A failed write fails the
        whole epoch (frame boundaries are lost once a sendall splits)."""
        with self._lock:
            sock, epoch = self._ensure()
            fut: Future = Future()
            register(epoch, fut)
        try:
            with self._send_lock:
                send(sock)
        except (TransportError, OSError) as e:
            self._fail_epoch(sock, epoch, TransportError(str(e)), drop=True)
        return fut

    def _await(self, fut: Future):
        """Block on a reply future with the per-request deadline; a timeout
        is a transport failure (kill the connection so in-flight peers retry
        too, rather than queueing behind a wedged server)."""
        try:
            return fut.result(timeout=self.timeout_s)
        except _FutureTimeout:
            with self._lock:
                sock, epoch = self._sock, self._epoch
            exc = TransportError(f"no reply within {self.timeout_s}s")
            self._fail_epoch(sock, epoch, exc, drop=True)
            raise exc from None

    def execute(self, group: str, idx: np.ndarray) -> np.ndarray:
        """Label ``idx`` through the server-side ``group``; returns (n,)
        float64 labels.  Raises :class:`RemoteExecutionError` on application
        errors, :class:`TransportError` when the server stays unreachable.
        Concurrent calls pipeline over the one connection."""
        idx = np.asarray(idx)
        if idx.ndim == 1:
            idx = idx[:, None]
        with self._lock:
            self._seq += 1
            rid = self._seq
        payload = LabelRequest(group=group, idx=idx,
                               request_id=rid).to_bytes()
        last: Exception = TransportError("no attempt made")
        for attempt in range(self.retries + 1):
            try:
                t0 = time.perf_counter()
                fut = self._submit(
                    lambda epoch, f: self._pending.__setitem__(
                        rid, (epoch, f)),
                    lambda sock: send_frame(sock, MSG_EXEC, payload),
                )
                if self._tracking:
                    self.tracker.gauge("transport.inflight",
                                       len(self._pending))
                res = self._await(fut)
            except (TransportError, OSError) as e:
                last = e
                if attempt < self.retries:
                    delay = self._backoff(attempt)
                    if self._tracking:
                        self.tracker.count("transport.retries")
                        self.tracker.event("transport.backoff",
                                           attempt=attempt, delay_s=delay)
                    time.sleep(delay)
                continue
            if len(res.labels) != len(idx):
                raise TransportError(
                    f"reply carries {len(res.labels)} labels for "
                    f"{len(idx)} rows"
                )
            if self._tracking:
                self.tracker.observe("transport.rtt_ms",
                                     (time.perf_counter() - t0) * 1e3)
                self.tracker.gauge("transport.inflight", len(self._pending))
            return res.labels
        raise TransportError(
            f"{self.address[0]}:{self.address[1]} unreachable after "
            f"{self.retries + 1} attempts: {last}"
        ) from last

    def _control(self, mtype: int, expect: int) -> bytes:
        """Unnumbered request/reply (GROUPS, PING) with the same
        reconnect-retry loop as ``execute``.  At most one control request is
        in flight per connection (``_ctrl_lock``) so wire-order matching of
        the unnumbered replies stays unambiguous."""
        last: Exception = TransportError("no attempt made")
        with self._ctrl_lock:
            for attempt in range(self.retries + 1):
                try:
                    fut = self._submit(
                        lambda epoch, f: self._ctrl.append((epoch, f)),
                        lambda sock: send_frame(sock, mtype),
                    )
                    rtype, payload = self._await(fut)
                except (TransportError, OSError) as e:
                    last = e
                    if attempt < self.retries:
                        time.sleep(self._backoff(attempt))
                    continue
                if rtype != expect:
                    raise TransportError(
                        f"unexpected reply type 0x{rtype:02x}")
                return payload
        raise TransportError(
            f"{self.address[0]}:{self.address[1]} unreachable after "
            f"{self.retries + 1} attempts: {last}"
        ) from last

    def groups(self) -> tuple[str, ...]:
        """The server's registered group names (the worker handshake)."""
        text = self._control(MSG_GROUPS, MSG_GROUPS_OK).decode("utf-8")
        return tuple(g for g in text.split("\n") if g)

    def ping(self) -> bool:
        try:
            self._control(MSG_PING, MSG_PONG)
            return True
        except (TransportError, RemoteExecutionError):
            return False


class RemoteOracle(Oracle):
    """An Oracle whose ``_label`` executes on a remote
    :class:`OracleServiceServer` — the client half of multi-host dispatch.

    Because this is an ordinary :class:`~repro.core.oracle.Oracle`, the whole
    batching stack composes unchanged: ``OracleBatch`` plans/commits against
    the local cache and ledger, ``flush_async()`` keeps the submit-then-await
    protocol, and attaching a *local* ``OracleService`` on the client side
    additionally overlaps the network round trip with the query's cheap work
    and coalesces multiple local queries before they ever hit the wire
    (RemoteOracles sharing a server address + group share a service group).
    """

    def __init__(self, address: tuple[str, int], group: str = "default",
                 retries: int = 5, backoff_s: float = 0.05,
                 max_backoff_s: float = 2.0, timeout_s: float = 120.0,
                 tracker=None):
        super().__init__()
        self.group = str(group)
        self.conn = ServiceConnection(address, retries=retries,
                                      backoff_s=backoff_s,
                                      max_backoff_s=max_backoff_s,
                                      timeout_s=timeout_s, announce=True,
                                      tracker=tracker)
        self.conn.connect()     # best-effort: count toward windows early

    def _label(self, idx: np.ndarray) -> np.ndarray:
        return self.conn.execute(self.group, idx)

    def service_group(self):
        # flat str/int parts so a shared LabelStore can persist segments for
        # this group (label_io only stores JSON-scalar key components)
        host, port = self.conn.address
        return ("remote", host, int(port), self.group)

    def close(self) -> None:
        """Drop the connection (the server sees a disconnect and stops
        counting this client toward window assembly)."""
        self.conn.close()

    def __enter__(self) -> "RemoteOracle":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class ThroughputEWMA:
    """Thread-safe rows/s exponentially-weighted moving average for one
    shard executor (the local pool or one worker host).

    ``OracleService._execute`` sizes super-batch shards in proportion to
    these rates, so a host that labels half as fast gets roughly half the
    rows — uniform splits make every super-batch as slow as the slowest
    host.  The first sample seeds the average (no zero-warmup bias);
    later samples blend in with weight ``alpha``, so a host that speeds
    up or slows down re-converges within a few windows."""

    def __init__(self, alpha: float = 0.3):
        self.alpha = float(alpha)
        self._lock = threading.Lock()
        self._rate = 0.0
        self._samples = 0

    def update(self, rows: int, seconds: float) -> float:
        """Fold one measured shard into the average; degenerate samples
        (no rows, or a timer resolution of zero) are dropped."""
        if rows <= 0 or seconds <= 0.0:
            return self.rate
        sample = rows / seconds
        with self._lock:
            if self._samples == 0:
                self._rate = sample
            else:
                self._rate += self.alpha * (sample - self._rate)
            self._samples += 1
            return self._rate

    @property
    def rate(self) -> float:
        """Current rows/s estimate; 0.0 until the first sample lands."""
        with self._lock:
            return self._rate

    @property
    def samples(self) -> int:
        with self._lock:
            return self._samples


class RemoteWorkerClient:
    """The front server's handle on one worker host: a
    :class:`ServiceConnection` plus the group names the worker advertised at
    registration.  ``OracleService._execute`` routes super-batch shards here.
    """

    def __init__(self, address: tuple[str, int], retries: int = 2,
                 backoff_s: float = 0.05, max_backoff_s: float = 2.0,
                 timeout_s: float = 120.0, tracker=None):
        self.conn = ServiceConnection(address, retries=retries,
                                      backoff_s=backoff_s,
                                      max_backoff_s=max_backoff_s,
                                      timeout_s=timeout_s, tracker=tracker)
        self.groups: frozenset = frozenset(self.conn.groups())

    @property
    def address(self) -> tuple[str, int]:
        return self.conn.address

    def execute(self, group: str, idx: np.ndarray) -> np.ndarray:
        return self.conn.execute(group, idx)

    def ping(self) -> bool:
        """One health probe; the service's checker drives re-registration."""
        return self.conn.ping()

    def refresh_groups(self) -> frozenset:
        """Re-fetch the worker's advertised groups (a restarted host may
        serve a different set); called on health-check rejoin."""
        self.groups = frozenset(self.conn.groups())
        return self.groups

    def close(self) -> None:
        self.conn.close()


# ---- server side -----------------------------------------------------------


class _Server(socketserver.ThreadingTCPServer):
    allow_reuse_address = True      # restart-in-place (tests, rolling deploys)
    daemon_threads = True
    owner: "OracleServiceServer"


class _Handler(socketserver.BaseRequestHandler):
    """One connected client: count it toward window assembly, answer frames
    until EOF.  One thread per connection (ThreadingTCPServer) keeps reading
    while EXECs execute asynchronously — replies are written from service
    callbacks when each future resolves, which is what makes client-side
    pipelining (several EXECs in flight on one connection) actually overlap
    server-side instead of queueing behind the first future."""

    def handle(self) -> None:
        owner = self.server.owner
        self.request.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        # interleaved replies from concurrent futures must not split frames
        self._wlock = threading.Lock()
        owner._track(self.request, add=True)
        # window assembly waits only for ANNOUNCED connections: a query
        # client HELLOs at connect (and its first EXEC counts as an implicit
        # announcement), while control-plane traffic — PING health checks,
        # the GROUPS handshake of a front registering this host as a worker,
        # or a socket that never sends a frame at all — is never waited for.
        # An announced client that then only sends control frames is demoted
        # again, so a stray HELLO can't make every window run to the deadline.
        client_id = None
        counted, seen_exec = False, False
        try:
            while True:
                try:
                    mtype, payload = recv_frame(self.request)
                except (TransportError, OSError):
                    return                      # client went away
                if mtype == MSG_HELLO:
                    if not counted:
                        client_id = owner.service.client_connected()
                        counted = True
                    continue
                if mtype == MSG_EXEC:
                    if not counted:
                        client_id = owner.service.client_connected()
                        counted = True
                    seen_exec = True
                    self._exec(owner, client_id, payload)
                    continue
                if not seen_exec and counted:   # control-plane connection
                    owner.service.client_disconnected(client_id)
                    counted = False
                if mtype == MSG_PING:
                    with self._wlock:
                        send_frame(self.request, MSG_PONG)
                elif mtype == MSG_GROUPS:
                    names = "\n".join(sorted(owner.groups))
                    with self._wlock:
                        send_frame(self.request, MSG_GROUPS_OK,
                                   names.encode("utf-8"))
                else:
                    res = LabelResult(error=f"ProtocolError: unknown message "
                                            f"type 0x{mtype:02x}")
                    with self._wlock:
                        send_frame(self.request, MSG_ERROR, res.to_bytes())
        finally:
            if counted:
                owner.service.client_disconnected(client_id)
            owner._track(self.request, add=False)

    def _reply(self, mtype: int, res: LabelResult) -> None:
        """Write one reply frame; a failing send means the client is gone —
        swallow it (the reader loop will notice EOF and clean up) rather
        than crash whichever service thread delivered the result."""
        try:
            with self._wlock:
                send_frame(self.request, mtype, res.to_bytes())
        except OSError:
            pass

    def _exec(self, owner: "OracleServiceServer", client_id: int,
              payload: bytes) -> None:
        try:
            req = LabelRequest.from_bytes(payload)
        except Exception as e:
            # a deterministic protocol error (version skew, corrupt segment)
            # must be an ERROR reply, not a dropped connection the client
            # would misread as "server unreachable" and retry-loop against
            self._reply(MSG_ERROR, LabelResult(
                error=f"ProtocolError: undecodable EXEC "
                      f"payload ({type(e).__name__}: {e})"))
            return
        fn = owner.groups.get(req.group)
        if fn is None:
            self._reply(MSG_ERROR, LabelResult(
                request_id=req.request_id,
                error=f"RemoteExecutionError: unknown group "
                      f"{req.group!r} (registered: "
                      f"{sorted(owner.groups)})"))
            return

        def _deliver(fut) -> None:
            try:
                labels = fut.result()
                mtype = MSG_RESULT
                res = LabelResult(request_id=req.request_id, labels=labels)
            except BaseException as e:  # noqa: BLE001 — isolate per client
                # ANY execution failure — including a backend raising
                # OSError — is an application error the client must see as
                # ERROR (no transport retry)
                mtype = MSG_ERROR
                res = LabelResult(request_id=req.request_id,
                                  error=f"{type(e).__name__}: {e}")
            self._reply(mtype, res)

        try:
            fut = owner.service.submit_raw(req.group, fn, req.idx,
                                           client_id=client_id)
        except BaseException as e:  # noqa: BLE001
            self._reply(MSG_ERROR, LabelResult(
                request_id=req.request_id,
                error=f"{type(e).__name__}: {e}"))
            return
        # reply when the window resolves — NOT inline — so this thread goes
        # straight back to recv and further pipelined EXECs from the same
        # client can join the window this one is still waiting on
        fut.add_done_callback(_deliver)


class OracleServiceServer:
    """TCP front-end over an :class:`~repro.serve.oracle_service.OracleService`.

    ``groups`` maps wire group names to vectorised label functions
    ``fn(idx: (n, k) int array) -> (n,) float labels`` — e.g. a thresholded
    :class:`~repro.serve.serve_loop.PairScorer` (see :func:`scorer_group`).
    Segments arriving on different connections coalesce into the service's
    windows exactly like in-process flushes, fuse into per-group super-batches,
    and shard over the service's worker threads and any registered worker
    hosts.

    A server with no registered downstream workers *is* a worker host: run the
    same class on each host and point the front server at the others via
    :meth:`register_worker`.
    """

    def __init__(self, groups: dict[str, Callable], host: str = "127.0.0.1",
                 port: int = 0, service=None, **service_kwargs):
        from repro.serve.oracle_service import OracleService

        self.groups = dict(groups)
        self.service = service if service is not None else OracleService(
            **service_kwargs
        )
        self._owns_service = service is None
        self._workers: list[RemoteWorkerClient] = []
        self._conns: set = set()
        self._conns_lock = threading.Lock()
        self._tcp = _Server((host, int(port)), _Handler)
        self._tcp.owner = self
        self._thread = threading.Thread(
            target=self._tcp.serve_forever, name="oracle-server", daemon=True
        )
        self._thread.start()

    @property
    def address(self) -> tuple[str, int]:
        """The bound (host, port) — resolves ``port=0`` to the real port."""
        return self._tcp.server_address[:2]

    def register_worker(self, address: tuple[str, int]) -> RemoteWorkerClient:
        """Connect a worker host and hand it to the service: super-batches
        for any group the worker advertises now shard across hosts.  The
        worker's connection reports into the service's tracker, and the
        service health-checks the host (re-registering it after an outage)."""
        worker = RemoteWorkerClient(address,
                                    tracker=self.service.tracker)
        self._workers.append(worker)
        self.service.register_remote_worker(worker)
        return worker

    def _track(self, sock: socket.socket, add: bool) -> None:
        with self._conns_lock:
            (self._conns.add if add else self._conns.discard)(sock)

    def close(self) -> None:
        """Stop accepting, drop live connections (clients observe a transport
        drop and reconnect-retry elsewhere — or to a restarted server on the
        same port), close worker handles, and shut the service if owned."""
        self._tcp.shutdown()
        self._tcp.server_close()
        self._thread.join()
        with self._conns_lock:
            conns = list(self._conns)
        for sock in conns:
            try:
                sock.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                sock.close()
            except OSError:
                pass
        for w in self._workers:
            w.close()
        if self._owns_service:
            self.service.close()

    def __enter__(self) -> "OracleServiceServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def scorer_group(scorer, threshold: float = 0.5) -> Callable:
    """Adapt a pair scorer (``PairScorer`` instance or any vectorised
    probability callable) into a wire group's label function.  Literally
    :class:`~repro.core.oracle.ModelOracle`'s own ``_label`` (the throwaway
    oracle's cache/ledger are never touched), so remote and in-process
    execution are bit-identical by construction."""
    return ModelOracle(scorer, threshold=threshold)._label


def parse_address(spec: str, default_port: int = 7431) -> tuple[str, int]:
    """``"host[:port]"`` -> (host, port) for CLI flags."""
    host, _, port = spec.partition(":")
    return (host or "127.0.0.1", int(port) if port else default_port)


__all__ = [
    "MSG_EXEC", "MSG_RESULT", "MSG_ERROR", "MSG_PING", "MSG_PONG",
    "MSG_GROUPS", "MSG_GROUPS_OK", "MSG_HELLO",
    "TransportError", "RemoteExecutionError",
    "send_frame", "recv_frame",
    "ServiceConnection", "RemoteOracle", "RemoteWorkerClient",
    "OracleServiceServer", "scorer_group", "parse_address",
]
