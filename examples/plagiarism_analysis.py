"""Paper §3 use case 1 — plagiarism analysis: COUNT of sentence pairs where an
article sentence paraphrases the reference collection (self-join-style
semantic join), with a budgeted Oracle and a valid CI.

    PYTHONPATH=src python examples/plagiarism_analysis.py

Flags: none.  Demonstration only — not run in CI.
"""

from repro.core import Agg, Query, run_bas, run_uniform
from repro.data import make_clustered_tables


def main():
    # article sentences vs reference db; entities = paraphrase clusters
    ds = make_clustered_tables(120, 2500, n_entities=900, noise=0.3, seed=4,
                               name="plagiarism")
    truth = float(ds.truth.sum())
    n_article = ds.truth.shape[0]
    plag_sentences = int((ds.truth.sum(axis=1) > 0).sum())
    print(f"article: {n_article} sentences; reference db: {ds.truth.shape[1]}")
    print(f"ground truth: {int(truth)} paraphrased pairs; "
          f"{plag_sentences}/{n_article} sentences plagiarised "
          f"({plag_sentences / n_article:.1%} plagiarism score)\n")

    budget = 9000
    q = Query(spec=ds.spec(), agg=Agg.COUNT, oracle=ds.oracle(), budget=budget,
              confidence=0.95)
    res = run_bas(q, seed=0)
    print("SELECT COUNT(*) FROM article JOIN db ON NL('{article.sentence} is "
          "paraphrased from {db.sentence}.')")
    print(f"  ORACLE BUDGET {budget} WITH PROBABILITY 0.95\n")
    print(f"BAS      COUNT ~= {res.estimate:.0f}  "
          f"CI=[{res.ci.lo:.0f}, {res.ci.hi:.0f}]  truth={truth:.0f}  "
          f"calls={res.oracle_calls}")
    q2 = Query(spec=ds.spec(), agg=Agg.COUNT, oracle=ds.oracle(), budget=budget)
    res_u = run_uniform(q2, seed=0)
    ratio = (f"{res_u.ci.width / res.ci.width:.1f}x BAS width"
             if res.ci.width > 1e-9 else "BAS was exact")
    print(f"UNIFORM  COUNT ~= {res_u.estimate:.0f}  "
          f"CI=[{res_u.ci.lo:.0f}, {res_u.ci.hi:.0f}] ({ratio})")


if __name__ == "__main__":
    main()
