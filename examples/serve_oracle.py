"""Serve a small model with batched requests: continuous-batching decode demo,
throughput of the batched pair-scoring (Oracle) endpoint, the async
OracleService running concurrent queries against one shared scorer, and a
loopback multi-process fleet — a TCP server (plus a registered worker host)
labelling for client processes that each run their own BAS query.

    PYTHONPATH=src python examples/serve_oracle.py

Flags: none.  Demonstration only (the CI-gated serving numbers live in
``benchmarks/bench_service.py``); the multi-process section spawns
``repro.launch.serve --mode client`` subprocesses against 127.0.0.1.
"""
import os
import subprocess
import sys
import time
from pathlib import Path

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.data.pipeline import ByteTokenizer, pair_example
from repro.models import init_params
from repro.serve.serve_loop import ContinuousBatcher, PairScorer, Request


def main():
    tok = ByteTokenizer()
    cfg = get_smoke_config("llama3.2-1b", vocab_size=tok.vocab_size, remat=False)
    params = init_params(cfg, jax.random.key(0))

    # --- continuous batching: mixed-length generation requests -------------
    rng = np.random.default_rng(0)
    cb = ContinuousBatcher(cfg, params, batch_size=4, max_len=96, eos_id=tok.EOS)
    n_req = 8
    for i in range(n_req):
        prompt = np.array(
            [tok.BOS] + tok.encode(f"record {i}:")[: 8 + i], np.int32
        )
        cb.submit(Request(uid=i, prompt=prompt, max_new_tokens=6))
    t0 = time.time()
    done = cb.run_until_done(max_steps=500)
    dt = time.time() - t0
    total_tokens = sum(len(r.out_tokens) for r in done)
    print(f"continuous batching: {len(done)}/{n_req} requests finished, "
          f"{total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens/max(dt,1e-9):.1f} tok/s on CPU, batch=4 slots)")

    # --- batched pair scoring (the Oracle endpoint) -------------------------
    records = [f"acme corp unit {i}" for i in range(32)]

    def tok_pair(pair):
        t, _ = pair_example(tok, records[pair[0]], records[pair[1]], None, 48)
        return t[t != tok.PAD]

    scorer = PairScorer(cfg, params, tok_pair, tok.YES, tok.NO, max_len=48,
                        batch_size=16)
    pairs = np.stack(np.meshgrid(np.arange(8), np.arange(8)), -1).reshape(-1, 2)
    t0 = time.time()
    p = scorer.score(pairs)
    dt = time.time() - t0
    print(f"pair scoring: {len(pairs)} pairs in {dt:.2f}s "
          f"({len(pairs)/max(dt,1e-9):.1f} pairs/s, "
          f"{scorer.forward_batches} device batches), mean P(match)={p.mean():.3f}")

    # --- the batched Oracle layer on top of the scorer ----------------------
    # Many call sites enqueue requests; one flush dedupes across all of them,
    # charges the budget ledger once, and reaches the model as a single batch.
    from repro.core import ModelOracle, OracleBatch

    oracle = ModelOracle(scorer, threshold=0.5)
    oracle.bind_sizes((32, 32))
    batch = OracleBatch(oracle)
    rng = np.random.default_rng(1)
    handles = [
        batch.submit(rng.integers(0, 32, size=(24, 2))) for _ in range(6)
    ]
    batch.flush()
    labels = np.concatenate([h.labels for h in handles])
    print(f"oracle batch: {oracle.requests} requests -> {oracle.calls} model "
          f"pairs in {oracle.batches} flush(es), dedup={oracle.dedup_ratio:.2f}, "
          f"match rate={labels.mean():.3f}")

    # --- the async oracle service: concurrent queries, one scorer -----------
    # Two BAS queries run on their own threads; their pilot/blocking/top-up
    # flushes coalesce into shared super-batches on the scorer, and each
    # query's budget ledger is still charged exactly as if it ran alone.
    from repro.core import Agg, BASConfig, Query, run_bas
    from repro.data import make_clustered_tables
    from repro.serve.oracle_service import OracleService, serve_queries

    ds = make_clustered_tables(32, 32, n_entities=48, noise=0.4, seed=3)
    oracles = [ModelOracle(scorer, threshold=0.5) for _ in range(2)]
    queries = [
        Query(spec=ds.spec(), agg=Agg.COUNT, oracle=o, budget=200)
        for o in oracles
    ]
    t0 = time.time()
    with OracleService(max_wait_ms=8.0) as svc:
        svc.attach(*oracles)

        def job(i):
            try:
                return run_bas(queries[i], BASConfig(n_bootstrap=100), seed=i)
            finally:
                svc.detach(oracles[i])

        results = serve_queries(svc, [lambda i=i: job(i) for i in range(2)])
        stats = svc.stats()
    dt = time.time() - t0
    total = sum(o.calls for o in oracles)
    print(f"oracle service: {len(queries)} concurrent queries, {total} labels "
          f"in {dt:.2f}s; {stats['windows']} windows at "
          f"{stats['segments_per_window']} flushes/window; estimates "
          + ", ".join(f"{r.estimate:.0f}" for r in results))

    # --- multi-host dispatch on loopback: server + worker + client procs ----
    # The same scorer now serves OTHER PROCESSES: an OracleServiceServer
    # exposes it over TCP, a second server registers as a worker host (so
    # super-batches shard across "hosts" — both on loopback here), and two
    # client processes each run a BAS query through a RemoteOracle.  Plan and
    # commit never leave the clients; only label work crosses the wire.
    from repro.serve.transport import OracleServiceServer, scorer_group

    group = {"default": scorer_group(scorer, threshold=0.5)}
    with OracleServiceServer(group, max_wait_ms=8.0) as worker:
        with OracleServiceServer(group, max_wait_ms=8.0,
                                 min_shard=64) as front:
            front.register_worker(worker.address)
            host, port = front.address
            env = dict(os.environ)
            src = str(Path(__file__).resolve().parents[1] / "src")
            env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
            env.setdefault("JAX_PLATFORMS", "cpu")
            cmd = [sys.executable, "-m", "repro.launch.serve",
                   "--mode", "client", "--connect", f"{host}:{port}",
                   "--queries", "1", "--budget", "150", "--n-side", "32"]
            t0 = time.time()
            procs = [subprocess.Popen(cmd, env=env, stdout=subprocess.PIPE,
                                      text=True) for _ in range(2)]
            outs = [p.communicate()[0] for p in procs]
            dt = time.time() - t0
            stats = front.service.stats()
        assert all(p.returncode == 0 for p in procs), outs
        for i, out in enumerate(outs):
            for line in out.strip().splitlines():
                print(f"  proc{i} {line}")
        print(f"multi-process fleet: 2 client processes in {dt:.1f}s; front "
              f"served {stats['rows_labelled']} rows in {stats['windows']} "
              f"windows, {stats['remote_shards']} shards on the worker host")


if __name__ == "__main__":
    main()
