"""Paper §7.4 — join-order optimisation with approximate COUNT: BAS cardinality
estimates feed DPccp (interval DP on chain joins) and pick a cheaper execution
order than uniform-sampling estimates.

    PYTHONPATH=src python examples/multiway_join_optimizer.py

Flags: none.  Demonstration only — not run in CI.
"""
import numpy as np

from repro.core import (
    bas_cardinality_provider,
    dp_chain_plan,
    plan_cost_under_truth,
    uniform_cardinality_provider,
)
from repro.core.oracle import PairChainOracle
from repro.data import make_chain_dataset


def true_card_fn(ds):
    def card(lo, hi):
        prod = None
        for e in range(lo, hi):
            m = ds.edge_truth[e].astype(np.float64)
            prod = m if prod is None else prod @ m
        return float(prod.sum()) if prod is not None else 0.0

    return card


def main():
    # 4-way chain with skewed edge densities (Ecomm-Q11 style)
    ds = make_chain_dataset([80, 12, 70, 15], d=24, n_entities=10, noise=0.35, seed=9)
    sizes = [e.shape[0] for e in ds.embeddings]
    tc = true_card_fn(ds)
    print("4-way chain join; true sub-join cardinalities:")
    for lo in range(4):
        for hi in range(lo + 1, 4):
            print(f"  |T{lo}..T{hi}| = {tc(lo, hi):.0f}")

    def oracle_factory(lo, hi):
        return PairChainOracle(ds.edge_truth[lo:hi])

    for name, provider in (
        ("BAS", bas_cardinality_provider(ds.spec(), oracle_factory, 800, seed=0)),
        ("UNIFORM", uniform_cardinality_provider(ds.spec(), oracle_factory, 800, seed=0)),
        ("TRUE", tc),
    ):
        plan = dp_chain_plan(4, sizes, provider)
        cost = plan_cost_under_truth(plan, sizes, tc)
        print(f"\n{name:8s} plan: {plan.order_str()}")
        print(f"         true execution cost (Oracle probes): {cost:,.0f}")


if __name__ == "__main__":
    main()
