"""Quickstart: approximate COUNT over a semantic join with BAS.

Builds a synthetic entity-matching workload (Company-style), registers the
tables with the JoinML engine, and runs the paper's Fig. 1 query syntax with
an Oracle budget + confidence — comparing BAS against uniform sampling.

    PYTHONPATH=src python examples/quickstart.py

Flags: none.  Demonstration only — the README quickstart snippet (smoke-run
by the CI docs job via ``scripts/check_docs.py``) is a condensed version of
this script.
"""

from repro.core import ArrayOracle, Catalog, JoinMLEngine, Table
from repro.data import make_clustered_tables


def main():
    ds = make_clustered_tables(800, 800, n_entities=1200, noise=0.4, seed=0,
                               name="companies")
    truth = float(ds.truth.sum())
    print(f"dataset: 800x800 cross product, {int(truth)} true matches "
          f"(selectivity {ds.selectivity:.2e})")

    cat = Catalog()
    cat.register(Table("wiki_companies", ds.emb1, ds.columns1))
    cat.register(Table("dbpedia_companies", ds.emb2, ds.columns2))
    engine = JoinMLEngine(cat, lambda nl, names: ArrayOracle(ds.truth))

    sql = (
        "SELECT COUNT(*) FROM wiki_companies JOIN dbpedia_companies "
        "ON NL('{wiki_companies.description} and {dbpedia_companies.description} "
        "describe the same company') "
        "ORACLE BUDGET 20000 WITH PROBABILITY 0.95"
    )
    print(f"\nquery:\n  {sql}\n")
    for method in ("bas", "wwj", "uniform"):
        res = engine.execute(sql, method=method, seed=0)
        err = abs(res.estimate - truth) / truth * 100
        print(
            f"{method:8s} estimate={res.estimate:9.1f}  truth={truth:.0f}  "
            f"err={err:5.1f}%  95% CI=[{res.ci.lo:9.1f}, {res.ci.hi:9.1f}]  "
            f"covered={res.ci.contains(truth)}  oracle_calls={res.oracle_calls}"
        )


if __name__ == "__main__":
    main()
