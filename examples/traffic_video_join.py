"""Paper §3 use case 2 — traffic analysis: AVG transit time between two
cameras over a semantic join on vehicle identity (VeRi-style re-id).

    PYTHONPATH=src python examples/traffic_video_join.py

Flags: none.  Demonstration only — not run in CI.
"""

from repro.core import Agg, Query, run_bas, run_wwj
from repro.data import make_clustered_tables


def main():
    ds = make_clustered_tables(700, 900, n_entities=140, noise=0.4, seed=6,
                               name="veri")
    ts1 = ds.columns1["ts"]
    ts2 = ds.columns2["ts"]

    def g(idx):
        return ts2[idx[:, 1]] - ts1[idx[:, 0]]

    m = ds.truth > 0
    true_avg = float((ts2[None, :] - ts1[:, None])[m].mean())
    print(f"cameras: {ds.truth.shape[0]} / {ds.truth.shape[1]} detections, "
          f"{int(m.sum())} same-vehicle pairs; true AVG transit = {true_avg:.2f}s\n")

    budget = 12000
    print("SELECT AVG(video2.ts - video1.ts) FROM video1 JOIN video2")
    print("ON NL('Frame {video1.frame} and Frame {video2.frame} contains the "
          f"same car.') ORACLE BUDGET {budget} WITH PROBABILITY 0.95\n")
    for name, runner in (("bas", run_bas), ("wwj", run_wwj)):
        q = Query(spec=ds.spec(), agg=Agg.AVG, oracle=ds.oracle(), g=g,
                  budget=budget, confidence=0.95)
        res = runner(q, seed=0)
        print(f"{name:5s} AVG ~= {res.estimate:8.2f}s  "
              f"CI=[{res.ci.lo:.2f}, {res.ci.hi:.2f}]  "
              f"err={abs(res.estimate - true_avg) / abs(true_avg):.1%}  "
              f"calls={res.oracle_calls}")


if __name__ == "__main__":
    main()
