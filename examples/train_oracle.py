"""End-to-end driver: TRAIN the Oracle, then ANSWER a join query with it.

1. Builds a synthetic entity-record corpus (noisy string variants).
2. Trains the pair-scoring Oracle LM (joinml-oracle config; reduced size by
   default, ``--full`` uses the ~100M configuration) with the full substrate:
   sharded deterministic loader, AdamW + schedule, microbatching, async
   checkpointing, preemption handling, straggler monitoring.
3. Serves the trained model as the budgeted ModelOracle of a BAS COUNT query
   and reports estimate/CI against ground truth — the paper's full pipeline
   with a *learned* Oracle instead of a ground-truth array.

    PYTHONPATH=src python examples/train_oracle.py [--steps 300] [--full]

Flags: ``--steps N`` (train steps, default 300), ``--batch N`` (default 16),
``--max-len N`` (sequence length, default 64), ``--full`` (~100M oracle
config), ``--ckpt PATH`` (checkpoint directory).  Demonstration only — not
run in CI.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import AsyncCheckpointer, restore_latest
from repro.configs import get_config, get_smoke_config
from repro.core import Agg, ModelOracle, Query, run_bas
from repro.core.similarity import normalize
from repro.core.types import JoinSpec
from repro.data.pipeline import (
    ByteTokenizer,
    ShardedLoader,
    make_entity_corpus,
    make_pair_batch,
    pair_example,
)
from repro.models import init_params
from repro.runtime.fault_tolerance import PreemptionHandler, StragglerMonitor
from repro.serve.serve_loop import PairScorer
from repro.train import OptimizerConfig, init_opt_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--full", action="store_true", help="~100M oracle config")
    ap.add_argument("--ckpt", default="/tmp/joinml_oracle_ckpt")
    args = ap.parse_args()

    tok = ByteTokenizer()
    if args.full:
        import dataclasses

        cfg = dataclasses.replace(
            get_config("joinml-oracle"), vocab_size=tok.vocab_size, remat=False
        )
    else:
        cfg = get_smoke_config(
            "joinml-oracle", vocab_size=tok.vocab_size, num_layers=4,
            d_model=128, num_heads=4, num_kv_heads=4, head_dim=32, d_ff=512,
        )
    print(f"oracle config: {cfg.name} ({cfg.param_count()/1e6:.1f}M params)")

    records, ids = make_entity_corpus(n_entities=80, records_per_entity=4,
                                      noise=0.08, seed=0)

    def batch_fn(rng):
        b = make_pair_batch(tok, records, ids, args.batch, args.max_len, rng)
        return {"tokens": b["tokens"], "loss_mask": b["loss_mask"]}

    loader = ShardedLoader(batch_fn, args.batch, num_hosts=1, host_id=0, seed=7)
    params = init_params(cfg, jax.random.key(0))
    opt = init_opt_state(params)
    ocfg = OptimizerConfig(peak_lr=2e-3, warmup_steps=20, decay_steps=args.steps)
    step_fn = jax.jit(make_train_step(cfg, ocfg, num_microbatches=2))

    ckpt = AsyncCheckpointer(args.ckpt, keep_last=2)
    preempt = PreemptionHandler()
    preempt.install()
    stragglers = StragglerMonitor(threshold=5.0)

    # resume if a checkpoint exists (restart path)
    restored, manifest = restore_latest(args.ckpt, {"params": params, "opt": opt})
    start = 0
    if restored is not None:
        params, opt = restored["params"], restored["opt"]
        start = manifest["step"]
        print(f"resumed from checkpoint step {start}")

    t_start = time.time()
    for _ in range(start, args.steps):
        t0 = time.time()
        step, batch = next(loader)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt, m = step_fn(params, opt, batch)
        stragglers.record(step, time.time() - t0)
        if step % 50 == 0 or step == args.steps - 1:
            print(f"step {step:4d}  loss={float(m['loss']):.4f}  "
                  f"lr={float(m['lr']):.2e}  gnorm={float(m['grad_norm']):.2f}")
        if step % 100 == 99 or preempt.preempted:
            ckpt.save(step + 1, {"params": params, "opt": opt})
        if preempt.preempted:
            print("preempted: checkpointed and exiting")
            ckpt.wait()
            return
    ckpt.save(args.steps, {"params": params, "opt": opt})
    ckpt.wait()
    loader.close()
    print(f"trained {args.steps} steps in {time.time()-t_start:.1f}s; "
          f"stragglers flagged: {len(stragglers.reports)}")

    # ---- serve the trained model as the Oracle of a BAS query -------------
    # two tables: one record variant of each entity per side (the classic EM
    # split — records are in-domain, the *pairs* are what the Oracle decides)
    r1, id1 = records[0::4], ids[0::4]
    r2, id2 = records[1::4], ids[1::4]
    truth = (np.array(id1)[:, None] == np.array(id2)[None, :]).astype(np.int8)

    def tok_pair(pair):
        t, _ = pair_example(tok, r1[pair[0]], r2[pair[1]], None, args.max_len)
        return t[t != tok.PAD]

    scorer = PairScorer(cfg, params, tok_pair, tok.YES, tok.NO,
                        max_len=args.max_len, batch_size=32)
    # oracle quality + threshold calibration on a labelled sample: the model
    # was trained on balanced pairs, so at 1% selectivity the decision
    # threshold must sit well above 0.5 to control false positives
    rng = np.random.default_rng(1)
    pos = np.argwhere(truth == 1)
    negs = np.argwhere(truth == 0)
    neg = negs[rng.choice(len(negs), 150)]
    sample = np.concatenate([pos[:50], neg])
    labels = truth[sample[:, 0], sample[:, 1]]
    p_scores = scorer.score(sample)
    thresh = float(np.quantile(p_scores[labels == 0], 0.995))
    pred = p_scores > thresh
    prec = float(labels[pred].mean()) if pred.any() else 0.0
    rec = float(pred[labels == 1].mean())
    print(f"\ntrained-oracle on held-out pairs: precision={prec:.0%} "
          f"recall={rec:.0%} at calibrated threshold {thresh:.2f}")

    # embeddings: character 3-gram hashes (cheap proxy, like TF-IDF in §7.6)
    def embed(recs):
        out = np.zeros((len(recs), 64), np.float32)
        for i, r in enumerate(recs):
            for j in range(len(r) - 2):
                out[i, hash(r[j : j + 3]) % 64] += 1.0
        return normalize(out)

    spec = JoinSpec(embeddings=[embed(r1), embed(r2)])
    oracle = ModelOracle(lambda idx: scorer.score(idx), threshold=thresh)
    q = Query(spec=spec, agg=Agg.COUNT, oracle=oracle, budget=1500,
              confidence=0.95)
    res = run_bas(q, seed=0)
    true_count = float(truth.sum())
    print(f"BAS with learned Oracle: COUNT ~= {res.estimate:.0f} "
          f"CI=[{res.ci.lo:.0f}, {res.ci.hi:.0f}]  "
          f"ground truth={true_count:.0f}  oracle_calls={res.oracle_calls} "
          f"(budget 1500 of {truth.size} pairs)")
    print("note: BAS estimates the *Oracle's* answer with guarantees — any "
          "residual gap to ground truth is the trained Oracle's own error "
          "(paper §2 assumes the Oracle is ground truth).")


if __name__ == "__main__":
    main()
