import numpy as np

from repro.core.stratify import (
    auto_num_strata,
    collect_top,
    stratify_dense,
    stratify_streaming,
    threshold_for_top_m,
    weight_histogram,
)
from repro.core.similarity import normalize, pair_weights
from repro.core.types import BASConfig

CFG = BASConfig()


def test_auto_num_strata_clamps():
    assert auto_num_strata(0.2, 1000, CFG) == 5        # min K
    assert auto_num_strata(0.2, 100_000, CFG) == 20    # alpha*b/1000
    assert auto_num_strata(0.2, 10_000_000, CFG) == 64  # max K


def test_stratify_dense_invariants():
    rng = np.random.default_rng(0)
    w = rng.random(10_000)
    strat = stratify_dense(w, alpha=0.2, budget=5000, cfg=CFG)
    m = strat.blocking_regime_size()
    assert m == 1000  # alpha * budget
    # order is sorted descending
    ow = w[strat.order]
    assert np.all(np.diff(ow) <= 1e-12)
    # order really is the global top-m
    thresh = np.sort(w)[::-1][m - 1]
    assert ow.min() >= thresh - 1e-12
    # strata partition the blocking regime into equal (±1) sizes
    sizes = strat.stratum_sizes()
    assert sizes[1:].sum() == m
    assert sizes[0] == 10_000 - m
    assert sizes[1:].max() - sizes[1:].min() <= 1
    # strata are similarity-ordered: min weight of stratum i >= max of i+1
    for i in range(1, strat.num_strata):
        a = w[strat.stratum_indices(i)]
        b = w[strat.stratum_indices(i + 1)]
        assert a.min() >= b.max() - 1e-12


def test_stratify_dense_small_space():
    w = np.array([0.9, 0.1, 0.5])
    strat = stratify_dense(w, alpha=0.5, budget=100, cfg=CFG)
    assert strat.blocking_regime_size() == 3  # capped at |D|
    assert strat.stratum_sizes().sum() == 3


def test_histogram_threshold_matches_exact():
    rng = np.random.default_rng(1)
    e1 = normalize(rng.standard_normal((200, 16)))
    e2 = normalize(rng.standard_normal((150, 16)))
    w = pair_weights(e1, e2).reshape(-1)
    counts, edges = weight_histogram(e1, e2, n_bins=512)
    assert counts.sum() == len(w)
    m = 500
    thr = threshold_for_top_m(counts, edges, m)
    n_above = int((w >= thr).sum())
    assert n_above >= m  # threshold is conservative (collects at least m)
    top = collect_top(e1, e2, thr, m)
    exact_top = np.argsort(w)[::-1][:m]
    # identical up to bin-boundary ties: overlap must be near-total
    overlap = len(set(top.tolist()) & set(exact_top.tolist())) / m
    assert overlap > 0.98


def test_stratify_streaming_close_to_dense():
    rng = np.random.default_rng(2)
    e1 = normalize(rng.standard_normal((100, 16)))
    e2 = normalize(rng.standard_normal((100, 16)))
    w = pair_weights(e1, e2).reshape(-1)
    dense = stratify_dense(w, alpha=0.2, budget=2000, cfg=CFG)
    stream = stratify_streaming(e1, e2, alpha=0.2, budget=2000, cfg=CFG)
    assert stream.blocking_regime_size() == dense.blocking_regime_size()
    overlap = len(
        set(stream.order.tolist()) & set(dense.order.tolist())
    ) / dense.blocking_regime_size()
    assert overlap > 0.98
