import dataclasses

import numpy as np
import pytest

from repro.core.stratify import (
    auto_num_strata,
    collect_top,
    stratify_dense,
    stratify_streaming,
    sweep_pass,
    threshold_for_top_m,
    weight_histogram,
)
from repro.core.similarity import normalize, pair_weights
from repro.core.types import BASConfig

CFG = BASConfig()


def test_auto_num_strata_clamps():
    assert auto_num_strata(0.2, 1000, CFG) == 5        # min K
    assert auto_num_strata(0.2, 100_000, CFG) == 20    # alpha*b/1000
    assert auto_num_strata(0.2, 10_000_000, CFG) == 64  # max K


def test_stratify_dense_invariants():
    rng = np.random.default_rng(0)
    w = rng.random(10_000)
    strat = stratify_dense(w, alpha=0.2, budget=5000, cfg=CFG)
    m = strat.blocking_regime_size()
    assert m == 1000  # alpha * budget
    # order is sorted descending
    ow = w[strat.order]
    assert np.all(np.diff(ow) <= 1e-12)
    # order really is the global top-m
    thresh = np.sort(w)[::-1][m - 1]
    assert ow.min() >= thresh - 1e-12
    # strata partition the blocking regime into equal (±1) sizes
    sizes = strat.stratum_sizes()
    assert sizes[1:].sum() == m
    assert sizes[0] == 10_000 - m
    assert sizes[1:].max() - sizes[1:].min() <= 1
    # strata are similarity-ordered: min weight of stratum i >= max of i+1
    for i in range(1, strat.num_strata):
        a = w[strat.stratum_indices(i)]
        b = w[strat.stratum_indices(i + 1)]
        assert a.min() >= b.max() - 1e-12


def test_stratify_dense_small_space():
    w = np.array([0.9, 0.1, 0.5])
    strat = stratify_dense(w, alpha=0.5, budget=100, cfg=CFG)
    assert strat.blocking_regime_size() == 3  # capped at |D|
    assert strat.stratum_sizes().sum() == 3


def test_histogram_threshold_matches_exact():
    rng = np.random.default_rng(1)
    e1 = normalize(rng.standard_normal((200, 16)))
    e2 = normalize(rng.standard_normal((150, 16)))
    w = pair_weights(e1, e2).reshape(-1)
    counts, edges = weight_histogram(e1, e2, n_bins=512)
    assert counts.sum() == len(w)
    m = 500
    thr = threshold_for_top_m(counts, edges, m)
    n_above = int((w >= thr).sum())
    assert n_above >= m  # threshold is conservative (collects at least m)
    top = collect_top(e1, e2, thr, m)
    exact_top = np.argsort(w)[::-1][:m]
    # identical up to bin-boundary ties: overlap must be near-total
    overlap = len(set(top.tolist()) & set(exact_top.tolist())) / m
    assert overlap > 0.98


def test_stratify_streaming_close_to_dense():
    rng = np.random.default_rng(2)
    e1 = normalize(rng.standard_normal((100, 16)))
    e2 = normalize(rng.standard_normal((100, 16)))
    w = pair_weights(e1, e2).reshape(-1)
    dense = stratify_dense(w, alpha=0.2, budget=2000, cfg=CFG)
    stream = stratify_streaming(e1, e2, alpha=0.2, budget=2000, cfg=CFG)
    assert stream.blocking_regime_size() == dense.blocking_regime_size()
    overlap = len(
        set(stream.order.tolist()) & set(dense.order.tolist())
    ) / dense.blocking_regime_size()
    assert overlap > 0.98


# ----------------------------------------------------------------------------
# threshold_for_top_m edge cases
# ----------------------------------------------------------------------------

def test_threshold_edge_cases():
    counts = np.array([5, 0, 3, 2], np.int64)
    edges = np.linspace(0.0, 1.0, 5)
    # m = 0: top edge — nothing needs collecting
    assert threshold_for_top_m(counts, edges, 0) == edges[-1]
    # m == total mass: bottom edge — collect everything
    assert threshold_for_top_m(counts, edges, 10) == edges[0]
    # m beyond total mass: still the bottom edge
    assert threshold_for_top_m(counts, edges, 10_000) == edges[0]
    # empty histogram: bottom edge
    assert threshold_for_top_m(np.zeros(4, np.int64), edges, 1) == edges[0]
    # all mass in one bin: that bin's lower edge, for any m <= mass
    one = np.array([0, 0, 7, 0], np.int64)
    assert threshold_for_top_m(one, edges, 1) == edges[2]
    assert threshold_for_top_m(one, edges, 7) == edges[2]
    # m exactly the top-bin mass: top bin's lower edge
    assert threshold_for_top_m(counts, edges, 2) == edges[3]


def test_threshold_collects_at_least_m():
    rng = np.random.default_rng(7)
    w = rng.random(5000)
    edges = np.linspace(0.0, 1.0, 257)
    counts, _ = np.histogram(w, bins=edges)
    for m in (1, 7, 100, 2500, 5000):
        thr = threshold_for_top_m(counts.astype(np.int64), edges, m)
        assert int((w >= thr).sum()) >= m


# ----------------------------------------------------------------------------
# single-sweep path (sweep_pass + sweep-aware collection)
# ----------------------------------------------------------------------------

def _strata_identical(a, b):
    return (
        np.array_equal(a.order, b.order)
        and np.array_equal(a.bounds, b.bounds)
        and a.n_total == b.n_total
    )


@pytest.mark.parametrize("use_kernel", [False, True])
def test_sweep_bit_identical_to_two_pass(use_kernel):
    """The fused single-sweep stratification must produce *bit-identical*
    strata to the retired two-pass (histogram then collect) schedule, on
    both the numpy fallback and the Pallas kernel path."""
    rng = np.random.default_rng(21)
    e1 = normalize(rng.standard_normal((130, 16)))
    e2 = normalize(rng.standard_normal((90, 16)))
    one = stratify_streaming(e1, e2, 0.2, 2500, CFG, use_kernel=use_kernel,
                             use_sweep=True)
    two = stratify_streaming(e1, e2, 0.2, 2500, CFG, use_kernel=use_kernel,
                             use_sweep=False)
    assert _strata_identical(one, two)
    assert one.sweep is not None and two.sweep is None
    assert one.sweep.kernel == use_kernel
    assert one.order_weights is not None and len(one.order_weights) == len(one.order)
    # collected weights really are the strata weights, sorted descending
    assert np.all(np.diff(one.order_weights) <= 1e-12)


def test_sweep_fallback_hist_matches_two_pass_hist():
    rng = np.random.default_rng(22)
    e1 = normalize(rng.standard_normal((300, 8)))
    e2 = normalize(rng.standard_normal((50, 8)))
    sw = sweep_pass(e1, e2, n_bins=512, block=128)
    counts, edges = weight_histogram(e1, e2, n_bins=512, block=128)
    np.testing.assert_array_equal(sw.counts, counts)
    np.testing.assert_array_equal(sw.block_counts.sum(axis=0), counts)
    assert sw.block_counts.shape == (3, 512) and sw.block_rows == 128


def test_sweep_block_skipping_is_conservative():
    """Dense collection guided by the count tiles must return exactly the
    full-scan result — skipped blocks are *proven* empty."""
    rng = np.random.default_rng(23)
    # two clusters: rows 0-63 near e2's cluster, rows 64-255 far away
    base = normalize(rng.standard_normal((1, 16)))
    near = normalize(base + 0.05 * rng.standard_normal((64, 16)))
    far = normalize(rng.standard_normal((192, 16)))
    e1 = np.concatenate([near, far])
    e2 = normalize(base + 0.05 * rng.standard_normal((40, 16)))
    sw = sweep_pass(e1, e2, n_bins=512, block=64)
    thr = threshold_for_top_m(sw.counts, sw.edges, 200)
    got = collect_top(e1, e2, thr, 200, sweep=sw)
    want = collect_top(e1, e2, thr, 200)
    np.testing.assert_array_equal(got, want)
    assert sw.stats["blocks_rescanned"] < sw.stats["blocks_total"]


@pytest.mark.parametrize("use_sweep", [False, True])
def test_collect_top_beyond_candidate_cap(use_sweep):
    """Regression for the hard k=64 top-k candidate cap: a few hot left
    rows with > 64 qualifying right rows each (amid cold rows, so the
    top-k path engages) — the raised-k retry / targeted rescan must still
    collect exactly the dense-scan result instead of silently dropping
    the pairs beyond the cap."""
    rng = np.random.default_rng(24)
    base = normalize(rng.standard_normal((1, 16)))
    hot = normalize(base + 0.01 * rng.standard_normal((4, 16)))
    cold = normalize(rng.standard_normal((60, 16)))
    e1 = np.concatenate([hot, cold])
    e2 = normalize(base + 0.01 * rng.standard_normal((200, 16)))
    w = pair_weights(e1, e2)
    ws = np.sort(w.reshape(-1))
    m_cap = 400
    thr = float((ws[-m_cap] + ws[-m_cap - 1]) / 2)  # off any exact weight
    assert (w[:4] >= thr).sum(axis=1).min() > 64  # hot rows exceed the cap
    assert m_cap < 16 * e1.shape[0]  # the top-k collection path engages
    sw = sweep_pass(e1, e2, n_bins=512, use_kernel=True) if use_sweep else None
    got = collect_top(e1, e2, thr, m_cap, use_kernel=True, sweep=sw)
    want = collect_top(e1, e2, thr, m_cap, use_kernel=False)
    assert set(got.tolist()) == set(want.tolist())
    assert len(got) == m_cap
    if use_sweep:
        assert sw.stats.get("topk_retry_rows", 0) > 0


# ----------------------------------------------------------------------------
# end-to-end: sweep vs two-pass estimates, low-precision opt-in
# ----------------------------------------------------------------------------

def _small_query(budget=900):
    from repro.core import Agg, Query
    from repro.data import make_clustered_tables

    ds = make_clustered_tables(150, 150, n_entities=80, noise=0.4, seed=5)
    return Query(spec=ds.spec(), agg=Agg.COUNT, oracle=ds.oracle(),
                 budget=budget)


def test_bas_streaming_sweep_estimates_match_two_pass():
    """The fused sweep path draws the same samples as the two-pass schedule
    (identical strata and oracle calls); its walk setup reads the sweep's
    compensated f32 row sums instead of recomputing them in f64, so the
    estimate agrees to the compensated-accumulation contract (~1 f32 ulp),
    not bit-exactly (see kernels/sim_sweep: one-pass chain statistics)."""
    from repro.core.bas_streaming import run_bas_streaming

    r1 = run_bas_streaming(_small_query(), seed=0, use_sweep=True)
    r2 = run_bas_streaming(_small_query(), seed=0, use_sweep=False)
    assert r1.estimate == pytest.approx(r2.estimate, rel=1e-7)
    assert r1.ci.lo == pytest.approx(r2.ci.lo, rel=1e-6)
    assert r1.ci.hi == pytest.approx(r2.ci.hi, rel=1e-6)
    assert r1.oracle_calls == r2.oracle_calls
    assert r1.detail["stratify"]["path"] == "sweep"
    assert r1.detail["stratify"]["walk_setup"] == "fused"
    assert "stratify" not in r2.detail or r2.detail["stratify"]["path"] == "two-pass"


@pytest.mark.parametrize("precision", ["bf16", "int8"])
def test_bas_streaming_low_precision_within_tolerance(precision):
    """The opt-in bf16/int8 sweep must (a) report its CDF deviation, (b)
    stay within the documented per-precision tolerance, and (c) land the
    estimate within a few percent of the fp32 run (same seed)."""
    from repro.configs.joinml_embedder import EMBEDDING_PRECISIONS
    from repro.core.bas_streaming import run_bas_streaming

    ref = run_bas_streaming(_small_query(), seed=0)
    low = run_bas_streaming(_small_query(), seed=0, precision=precision)
    st = low.detail["stratify"]
    assert st["precision"] == precision
    assert st["lowp_cdf_dev"] <= EMBEDDING_PRECISIONS[precision].max_cdf_shift
    assert abs(low.estimate - ref.estimate) <= 0.05 * max(abs(ref.estimate), 1.0)


def test_low_precision_tolerance_fallback():
    """A sweep whose low-precision CDF drifts past the tolerance must fall
    back to fp32 (and say so in its stats)."""
    rng = np.random.default_rng(25)
    e1 = normalize(rng.standard_normal((64, 16)))
    e2 = normalize(rng.standard_normal((64, 16)))
    with pytest.warns(UserWarning, match="falling back to fp32"):
        sw = sweep_pass(e1, e2, n_bins=256, use_kernel=True,
                        precision="bf16", tolerance=0.0)
    assert sw.precision == "fp32"
    assert "lowp_fallback" in sw.stats
    ref = sweep_pass(e1, e2, n_bins=256, use_kernel=True)
    np.testing.assert_array_equal(sw.counts, ref.counts)


def test_unknown_precision_rejected():
    rng = np.random.default_rng(26)
    e1 = normalize(rng.standard_normal((32, 8)))
    e2 = normalize(rng.standard_normal((32, 8)))
    for use_kernel in (True, False):  # validated on the fallback path too
        with pytest.raises(ValueError, match="unknown sweep precision"):
            sweep_pass(e1, e2, use_kernel=use_kernel, precision="fp4")


def test_low_precision_warns_when_kernel_unavailable():
    """A bf16/int8 opt-in that can only run the numpy fallback must say so
    instead of silently computing fp32."""
    rng = np.random.default_rng(27)
    e1 = normalize(rng.standard_normal((32, 8)))
    e2 = normalize(rng.standard_normal((32, 8)))
    with pytest.warns(UserWarning, match="numpy fallback computes fp32"):
        sw = sweep_pass(e1, e2, use_kernel=False, precision="int8")
    assert sw.precision == "fp32" and not sw.kernel


def test_sweep_config_is_plumbed_through_dispatch():
    from repro.core import dispatch

    q = _small_query()
    cfg = dataclasses.replace(BASConfig(), max_dense_weight_bytes=0)
    res = dispatch.run_auto(q, cfg, seed=0)
    assert res.detail["dispatch"]["path"] == "streaming"
    assert res.detail["dispatch"]["sweep"] is True
    assert res.detail["dispatch"]["sweep_precision"] == "fp32"
    assert res.detail["stratify"]["path"] == "sweep"
