import numpy as np

from repro.core.similarity import (
    chain_weights,
    flat_to_tuples,
    normalize,
    pair_weights,
    tuples_to_flat,
)


def test_pair_weights_matches_manual():
    rng = np.random.default_rng(0)
    e1 = normalize(rng.standard_normal((17, 8)))
    e2 = normalize(rng.standard_normal((23, 8)))
    w = pair_weights(e1, e2, exponent=1.0, floor=1e-6)
    cos = e1.astype(np.float64) @ e2.astype(np.float64).T
    manual = np.maximum(np.clip(cos, 0, 1), 1e-6)
    np.testing.assert_allclose(w, manual, rtol=1e-5, atol=1e-6)


def test_pair_weights_exponent():
    rng = np.random.default_rng(1)
    e1 = normalize(rng.standard_normal((5, 4)))
    e2 = normalize(rng.standard_normal((7, 4)))
    w1 = pair_weights(e1, e2, exponent=1.0)
    w2 = pair_weights(e1, e2, exponent=2.0)
    np.testing.assert_allclose(w2, w1**2, rtol=1e-5)


def test_pair_weights_blocked_consistent():
    rng = np.random.default_rng(2)
    e1 = normalize(rng.standard_normal((100, 8)))
    e2 = normalize(rng.standard_normal((40, 8)))
    full = pair_weights(e1, e2)
    blocked = pair_weights(e1, e2, block=16)
    np.testing.assert_allclose(full, blocked, rtol=1e-6)


def test_chain_weights_is_product():
    rng = np.random.default_rng(3)
    embs = [normalize(rng.standard_normal((n, 6))) for n in (4, 5, 3)]
    w = chain_weights(embs)
    w01 = pair_weights(embs[0], embs[1])
    w12 = pair_weights(embs[1], embs[2])
    manual = (w01[:, :, None] * w12[None, :, :]).reshape(-1)
    np.testing.assert_allclose(w, manual, rtol=1e-6)


def test_flat_tuple_roundtrip():
    sizes = (4, 5, 3)
    flat = np.arange(4 * 5 * 3)
    tup = flat_to_tuples(flat, sizes)
    assert tup.shape == (60, 3)
    back = tuples_to_flat(tup, sizes)
    np.testing.assert_array_equal(back, flat)


def test_normalize_unit_norm():
    rng = np.random.default_rng(4)
    e = normalize(rng.standard_normal((10, 16)) * 7.0)
    np.testing.assert_allclose(np.linalg.norm(e, axis=1), 1.0, rtol=1e-5)
