"""Tier-1 statistical-coverage harness (paper §6 guarantee, Fig. 2/5).

The product the paper sells is not the point estimate but the guarantee:
``P(mu in CI) >= p`` at any oracle budget.  These tests promote that claim
from a reporting benchmark (``benchmarks/bench_guarantees.py``) into the
fast test tier: every estimator path the engine routes — dense BAS,
streaming BAS, and the multi-fidelity cascade on both regimes — runs ~50
seeded replicates over a small synthetic workload with known ground truth,
and the empirical CI coverage must stay above ``nominal - slack``.

Everything is deterministic (fixed dataset seed, replicate seeds 0..N-1),
so a coverage regression fails CI reproducibly rather than flaking.  The
slack (0.10 under nominal 0.95) absorbs the binomial noise of 50
replicates (sd ~ 0.03 at p=0.95) plus small-sample bootstrap-t error; a
real guarantee break (e.g. a biased correction term, a variance formula
dropping a regime) lands far below it.

The workload is sized for signal, not triviality: the budget is small
enough that every path actually samples (non-zero RMSE) instead of
blocking its way to exactness.
"""
import numpy as np
import pytest

from repro.core import (
    Agg,
    ArrayOracle,
    BASConfig,
    Query,
    run_bas,
    run_bas_cascade,
    run_bas_streaming,
)
from repro.data import make_clustered_tables

N_REP = 50
NOMINAL = 0.95
SLACK = 0.10
BUDGET = 500

# modest bootstrap depth keeps the harness in the fast tier; CI *quality*
# at n_bootstrap=1000 is the default config's concern, not this test's
CFG = BASConfig(n_bootstrap=200)


@pytest.fixture(scope="module")
def workload():
    ds = make_clustered_tables(96, 96, n_entities=150, noise=0.45, seed=11)
    truth = float(ds.truth.sum())
    assert truth > 0
    return ds, truth


def _mk_query(ds, agg=Agg.COUNT, g=None):
    return Query(spec=ds.spec(), agg=agg, oracle=ds.oracle(), budget=BUDGET,
                 g=g)


def _coverage(ds, truth, run_one, agg=Agg.COUNT, g=None):
    hits, ests = 0, []
    for seed in range(N_REP):
        res = run_one(_mk_query(ds, agg, g), seed)
        hits += res.ci.contains(truth)
        ests.append(res.estimate)
    return hits / N_REP, ests


PATHS = {
    "bas-dense": lambda q, s: run_bas(q, CFG, seed=s),
    "bas-streaming": lambda q, s: run_bas_streaming(q, CFG, seed=s),
    "cascade-dense": lambda q, s: run_bas_cascade(q, CFG, seed=s,
                                                  path="dense"),
    "cascade-streaming": lambda q, s: run_bas_cascade(q, CFG, seed=s,
                                                      path="streaming"),
}


@pytest.mark.parametrize("path", sorted(PATHS))
def test_count_ci_coverage_at_nominal(workload, path):
    """Empirical COUNT coverage >= nominal - slack on every estimator path,
    including both cascade-routed regimes (the acceptance bar: the
    difference-estimator correction must not cost guarantee validity)."""
    ds, truth = workload
    cov, ests = _coverage(ds, truth, PATHS[path])
    assert cov >= NOMINAL - SLACK, (
        f"{path}: coverage {cov:.2f} < {NOMINAL - SLACK:.2f} "
        f"(mean est {np.mean(ests):.1f}, truth {truth:.1f})"
    )
    # the workload must exercise sampling, not collapse to an exact scan
    assert np.std(ests) > 0.0
    # and the estimator stays centred (bias regression guard, generous band)
    assert abs(np.mean(ests) - truth) < 0.25 * truth


@pytest.mark.parametrize("path", ["cascade-dense", "bas-dense"])
def test_sum_ci_coverage_at_nominal(workload, path):
    """SUM with a real attribute column holds coverage through the cascade's
    two-regime decomposition (g rides both the proxy and correction terms)."""
    ds, truth_count = workload
    col = ds.columns1["value"]
    g = lambda idx: col[idx[:, 0]]  # noqa: E731
    truth = float((col[:, None] * ds.truth).sum())
    cov, ests = _coverage(ds, truth, PATHS[path], agg=Agg.SUM, g=g)
    assert cov >= NOMINAL - SLACK, (
        f"{path}: SUM coverage {cov:.2f} < {NOMINAL - SLACK:.2f}"
    )
    assert abs(np.mean(ests) - truth) < 0.3 * truth


def test_cascade_coverage_robust_to_garbage_proxy(workload):
    """An adversarial proxy (labels = coin flips, uncorrelated with truth)
    widens the cascade's CIs but must not break their validity — the
    difference estimator corrects any proxy bias by construction."""
    ds, truth = workload
    rng = np.random.default_rng(99)
    garbage = ArrayOracle((rng.random(ds.truth.shape) < 0.5)
                          .astype(np.float64))
    hits = 0
    for seed in range(N_REP):
        q = _mk_query(ds)
        q.proxy = garbage
        res = run_bas_cascade(q, CFG, seed=seed, path="dense")
        hits += res.ci.contains(truth)
    assert hits / N_REP >= NOMINAL - SLACK
