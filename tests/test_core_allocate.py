import numpy as np
import pytest

from repro.core.allocate import argmin_beta, budget_assign, estimate_mse


def rand_instance(rng, k):
    sigma2 = rng.lognormal(0, 2, k + 1)
    wsum = rng.random(k + 1) + 0.01
    sizes = rng.integers(50, 200, size=k + 1)
    b2 = int(sizes.sum() * 0.6)
    return sigma2, wsum, sizes, b2


def brute_force(sigma2, wsum, sizes, b2):
    k = len(sigma2) - 1
    best, best_mse = None, np.inf
    for mask_bits in range(1 << k):
        mask = np.zeros(k + 1, bool)
        for i in range(1, k + 1):
            mask[i] = (mask_bits >> (i - 1)) & 1
        mse = estimate_mse(sigma2, wsum, sizes, mask, b2)
        if mse < best_mse:
            best_mse, best = mse, mask.copy()
    return best, best_mse


@pytest.mark.parametrize("seed", range(5))
def test_exact_matches_brute_force(seed):
    rng = np.random.default_rng(seed)
    sigma2, wsum, sizes, b2 = rand_instance(rng, 6)
    alloc = argmin_beta(sigma2, wsum, sizes, b2, exact_max_k=16)
    _, bf_mse = brute_force(sigma2, wsum, sizes, b2)
    assert alloc.est_mse == pytest.approx(bf_mse, rel=1e-9)


@pytest.mark.parametrize("seed", range(5))
def test_greedy_close_to_exact(seed):
    rng = np.random.default_rng(100 + seed)
    sigma2, wsum, sizes, b2 = rand_instance(rng, 8)
    exact = argmin_beta(sigma2, wsum, sizes, b2, exact_max_k=16)
    greedy = argmin_beta(sigma2, wsum, sizes, b2, exact_max_k=0)
    # greedy+swap must be feasible and near-optimal (<= 25% worse)
    assert np.isfinite(greedy.est_mse)
    assert greedy.est_mse <= exact.est_mse * 1.25 + 1e-12


def test_budget_assign_properties():
    wsum = np.array([1.0, 2.0, 3.0, 4.0])
    sizes = np.array([1000, 50, 50, 50])
    mask = np.array([False, False, True, False])
    n = budget_assign(500, wsum, sizes, mask)
    # blocked stratum gets its size
    assert n[2] == 50
    # remaining budget split ∝ weight over unblocked
    rem = 500 - 50
    np.testing.assert_allclose(n[0], rem * 1.0 / 7.0)
    np.testing.assert_allclose(n[3], rem * 4.0 / 7.0)
    np.testing.assert_allclose(n[~mask].sum(), rem)


def test_blocking_high_variance_stratum_helps():
    # one stratum dominates variance; blocking it should be chosen
    sigma2 = np.array([0.1, 1e6, 0.1, 0.1])
    wsum = np.array([1.0, 1.0, 1.0, 1.0])
    sizes = np.array([10_000, 100, 100, 100])
    alloc = argmin_beta(sigma2, wsum, sizes, b2=1000, exact_max_k=16)
    assert 1 in set(alloc.beta.tolist())


def test_infeasible_blocking_rejected():
    # blocking everything would exceed the budget -> est mse finite only for
    # feasible subsets
    sigma2 = np.array([1.0, 1.0])
    wsum = np.array([1.0, 1.0])
    sizes = np.array([100, 10_000])
    alloc = argmin_beta(sigma2, wsum, sizes, b2=500, exact_max_k=16)
    assert 1 not in set(alloc.beta.tolist())
    assert np.isfinite(alloc.est_mse)


def test_d0_never_blocked():
    sigma2 = np.array([1e9, 1.0, 1.0])
    wsum = np.ones(3)
    sizes = np.array([100, 100, 100])
    alloc = argmin_beta(sigma2, wsum, sizes, b2=10_000, exact_max_k=16)
    assert 0 not in set(alloc.beta.tolist())
