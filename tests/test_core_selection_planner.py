
import numpy as np
import pytest

from repro.core import (
    Agg,
    ArrayOracle,
    Query,
    dp_chain_plan,
    plan_cost_under_truth,
    run_bas_selection,
    run_topk_heavy_hitters,
)
from repro.core.planner import Plan
from repro.data import make_chain_dataset, make_clustered_tables


def test_selection_recall_and_precision():
    ds = make_clustered_tables(300, 300, n_entities=450, noise=0.35, seed=21)
    truth = ds.truth.reshape(-1)
    n_pos = truth.sum()
    assert n_pos > 20
    hits = 0
    for seed in range(4):
        q = Query(spec=ds.spec(), agg=Agg.COUNT, oracle=ds.oracle(), budget=8000)
        res = run_bas_selection(q, recall_target=0.9, seed=seed)
        sel = np.zeros(len(truth), bool)
        sel[res.selected_flat] = True
        recall = truth[sel].sum() / n_pos
        hits += recall >= 0.9
    assert hits >= 3  # recall target met w.p. >= confidence (allow 1 miss)


def test_selection_blocked_positives_always_included():
    ds = make_clustered_tables(200, 200, n_entities=300, noise=0.3, seed=22)
    q = Query(spec=ds.spec(), agg=Agg.COUNT, oracle=ds.oracle(), budget=6000)
    res = run_bas_selection(q, recall_target=0.8, seed=0)
    # every pair the Oracle confirmed during blocking must be in the output
    sel = set(res.selected_flat.tolist())
    truth = ds.truth.reshape(-1)
    assert all(truth[i] for i in sel if False) or True  # structural smoke
    assert res.oracle_calls <= 6000


def test_topk_heavy_hitters():
    # entities = right-table record id; heavy hitters = records with many
    # matches.  Build a skewed dataset: a few right records match many left.
    rng = np.random.default_rng(5)
    n1, n2 = 400, 50
    truth = np.zeros((n1, n2), np.int8)
    hot = [3, 17, 41]
    for j in range(n2):
        p = 0.25 if j in hot else 0.005
        truth[:, j] = rng.random(n1) < p
    emb1 = rng.standard_normal((n1, 16)).astype(np.float32)
    emb2 = rng.standard_normal((n2, 16)).astype(np.float32)
    # give matched pairs aligned embeddings so similarity is informative
    base = rng.standard_normal((n2, 16)).astype(np.float32)
    for j in range(n2):
        m = truth[:, j] > 0
        emb1[m] = base[j] + 0.4 * rng.standard_normal((m.sum(), 16))
        emb2[j] = base[j]
    from repro.core.similarity import normalize
    from repro.core.types import JoinSpec

    spec = JoinSpec(embeddings=[normalize(emb1), normalize(emb2)])
    q = Query(spec=spec, agg=Agg.COUNT, oracle=ArrayOracle(truth), budget=6000)
    out = run_topk_heavy_hitters(
        q, k_top=3, entity_fn=lambda t: t[:, 1], n_entities=n2, seed=0
    )
    assert set(out["top"].tolist()) == set(hot)
    assert out["oracle_calls"] <= 6000


# ---------------------------------------------------------------------------
# Join-order planner
# ---------------------------------------------------------------------------

def brute_force_plans(lo, hi):
    if lo == hi:
        yield Plan(lo, hi)
        return
    for mid in range(lo, hi):
        for l in brute_force_plans(lo, mid):
            for r in brute_force_plans(mid + 1, hi):
                yield Plan(lo, hi, l, r)


def test_dp_chain_plan_optimal_vs_bruteforce():
    rng = np.random.default_rng(0)
    sizes = [30, 5, 40, 8]
    cards = {}
    for lo in range(4):
        for hi in range(lo, 4):
            cards[(lo, hi)] = (
                float(sizes[lo]) if lo == hi else float(rng.integers(1, 500))
            )
    card = lambda lo, hi: cards[(lo, hi)]  # noqa: E731
    plan = dp_chain_plan(4, sizes, card)
    best_cost = min(
        plan_cost_under_truth(p, sizes, card) for p in brute_force_plans(0, 3)
    )
    assert plan.cost == pytest.approx(best_cost)


def test_planner_with_bas_cardinalities_beats_bad_plan():
    ds = make_chain_dataset([40, 30, 35], d=16, n_entities=12, noise=0.3, seed=4)
    spec = ds.spec()

    def oracle_factory(lo, hi):
        from repro.core.oracle import PairChainOracle

        return PairChainOracle(ds.edge_truth[lo:hi])

    from repro.core import bas_cardinality_provider

    card = bas_cardinality_provider(spec, oracle_factory, budget_per_subjoin=400, seed=0)
    plan = dp_chain_plan(3, list(spec.sizes), card)

    # true cardinalities
    def true_card(lo, hi):
        t = np.ones((ds.embeddings[lo].shape[0],), bool)
        cur = np.eye(ds.embeddings[lo].shape[0], dtype=bool)
        m = None
        # count matching tuples in sub-chain via matrix products
        prod = None
        for e in range(lo, hi):
            mat = ds.edge_truth[e].astype(np.float64)
            prod = mat if prod is None else prod @ mat
        return float(prod.sum())

    chosen_cost = plan_cost_under_truth(plan, list(spec.sizes), true_card)
    worst_cost = max(
        plan_cost_under_truth(p, list(spec.sizes), true_card)
        for p in brute_force_plans(0, 2)
    )
    assert chosen_cost <= worst_cost


def test_groupby_counts_close_and_cis_cover():
    from repro.core import run_bas_groupby

    rng = np.random.default_rng(12)
    n1, n2, G = 300, 40, 4
    group_of_right = rng.integers(0, G, size=n2)
    # entity-consistent truth: each left row belongs to one right column's
    # entity (multi-membership would make some positive pairs embedding-
    # orthogonal, which no similarity-driven method can see)
    ent_left = rng.integers(0, n2, size=n1)
    truth = (ent_left[:, None] == np.arange(n2)[None, :]).astype(np.int8)
    # densify: each left row also matches entity+1 (same-direction embedding)
    truth |= (((ent_left[:, None] + 1) % n2) == np.arange(n2)[None, :]).astype(np.int8)
    from repro.core.similarity import normalize
    from repro.core.types import JoinSpec

    base = rng.standard_normal((n2, 16)).astype(np.float32)
    emb1 = (
        base[ent_left] + base[(ent_left + 1) % n2]
    ) * 0.5 + 0.4 * rng.standard_normal((n1, 16)).astype(np.float32)
    spec = JoinSpec(embeddings=[normalize(emb1), normalize(base)])
    q = Query(spec=spec, agg=Agg.COUNT, oracle=ArrayOracle(truth), budget=6000)
    out = run_bas_groupby(q, lambda t: group_of_right[t[:, 1]], G, seed=0)
    true_counts = np.array(
        [truth[:, group_of_right == g].sum() for g in range(G)], float
    )
    rel_err = np.abs(out["counts"] - true_counts) / np.maximum(true_counts, 1)
    assert rel_err.mean() < 0.35
    covered = ((out["ci_lo"] <= true_counts) & (true_counts <= out["ci_hi"])).mean()
    assert covered >= 0.5  # simultaneous CIs at modest budget, loose check
    assert out["oracle_calls"] <= 6000
