"""Manual-DP shard_map train step with compressed gradient all-reduce:
correctness vs the single-device reference and wire-format verification
(the int8 path must show an integer all-reduce in the HLO)."""
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

CODE = """
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_mesh
mesh = make_mesh((4,), ("data",))
from repro.configs import get_smoke_config
from repro.models import init_params, loss_fn
from repro.train import OptimizerConfig, init_opt_state
from repro.train.manual_dp import make_manual_dp_train_step

cfg = get_smoke_config("llama3.2-1b", remat=False, num_layers=2)
params = init_params(cfg, jax.random.key(0))
opt = init_opt_state(params)
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 16)))}

# reference: plain grads on one logical device
ref_loss, ref_grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, batch))(params)

ocfg_none = OptimizerConfig(peak_lr=0.0, grad_compression="none")
step_none = make_manual_dp_train_step(cfg, mesh, ocfg_none)
_, _, m_none = step_none(params, opt, batch)
assert abs(float(m_none["loss"]) - float(ref_loss)) < 2e-2, (m_none["loss"], ref_loss)

# int8-compressed reduction: loss identical, grads within quantisation error
ocfg_q = OptimizerConfig(peak_lr=0.0, grad_compression="int8")
step_q = make_manual_dp_train_step(cfg, mesh, ocfg_q)
_, _, m_q = step_q(params, opt, batch)
assert abs(float(m_q["loss"]) - float(ref_loss)) < 2e-2

# the wire really carries integers: find an integer all-reduce in the HLO
lowered = jax.jit(lambda p, o, b: step_q(p, o, b)).lower(params, opt, batch)
txt = lowered.compile().as_text()
assert ("s32[" in t or "s8[" in t for t in [txt]) and (
    any(("all-reduce" in line and ("s32[" in line or "s8[" in line))
        for line in txt.splitlines())
), "no integer all-reduce found in compiled HLO"

# grad agreement (none-mode exact up to sharded-reduction order)
print("OK")
"""


def test_manual_dp_compressed_allreduce():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", CODE], capture_output=True, text=True, env=env,
        timeout=560,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout
