"""Unit tests for :mod:`repro.checkpoint.checkpoint` failure modes.

The happy paths (roundtrip, async, reshard, restart) live in
tests/test_substrates.py; this file pins down what happens when a
checkpoint is *wrong*: partially written, structurally mismatched, or
corrupted on disk.  These are the cases the atomic-write guarantee and
restore-time validation exist for, so each one must fail loudly (or be
invisible), never restore garbage.
"""
import json
import os
import shutil

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import (cleanup, latest_step, restore,
                                         restore_latest, save)


def _tree():
    return {
        "w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((2,), jnp.bfloat16)},
    }


# ----------------------------------------------------------------------------
# partial writes are invisible
# ----------------------------------------------------------------------------

def test_partial_tmp_dir_is_not_a_checkpoint(tmp_path):
    """A crash mid-save leaves only a ``.tmp_`` dir — discovery must not see
    it, and a later save of the same step must clobber it cleanly."""
    root = str(tmp_path)
    tmp = os.path.join(root, ".tmp_00000003")
    os.makedirs(tmp)
    # half-written leaf, no manifest: exactly what a kill -9 leaves behind
    np.save(os.path.join(tmp, "leaf_00000.npy"), np.zeros(4))
    assert latest_step(root) is None
    out, manifest = restore_latest(root, _tree())
    assert out is None and manifest is None
    save(root, 3, _tree())
    assert latest_step(root) == 3
    assert not [d for d in os.listdir(root) if d.startswith(".tmp")]


def test_step_dir_without_manifest_is_skipped(tmp_path):
    """A step directory whose manifest is missing (torn non-atomic copy from
    some external tool) is not offered by latest_step."""
    root = str(tmp_path)
    save(root, 1, _tree())
    fake = os.path.join(root, "step_00000009")
    os.makedirs(fake)
    assert latest_step(root) == 1


# ----------------------------------------------------------------------------
# corrupt / mismatched checkpoints fail loudly
# ----------------------------------------------------------------------------

def test_restore_missing_leaf_raises_keyerror(tmp_path):
    """Restoring a target tree with a leaf the checkpoint never saved is a
    structural mismatch -> KeyError naming the missing path."""
    root = str(tmp_path)
    save(root, 1, {"w": jnp.zeros((2,))})
    with pytest.raises(KeyError, match="nested/b"):
        restore(root, 1, _tree())


def test_restore_shape_mismatch_raises_valueerror(tmp_path):
    root = str(tmp_path)
    save(root, 1, {"w": jnp.zeros((2, 3))})
    with pytest.raises(ValueError, match="shape"):
        restore(root, 1, {"w": jnp.zeros((4, 4))})


def test_restore_truncated_leaf_file_raises(tmp_path):
    """Bit-rot on a leaf file (truncated npy) must not restore silently."""
    root = str(tmp_path)
    d = save(root, 1, {"w": jnp.arange(64, dtype=jnp.float32)})
    leaf = os.path.join(d, "leaf_00000.npy")
    with open(leaf, "rb") as f:
        blob = f.read()
    with open(leaf, "wb") as f:
        f.write(blob[: len(blob) // 2])
    with pytest.raises(Exception):
        restore(root, 1, {"w": jnp.zeros((64,), jnp.float32)})


def test_restore_corrupt_manifest_raises(tmp_path):
    root = str(tmp_path)
    d = save(root, 1, _tree())
    with open(os.path.join(d, "manifest.json"), "w") as f:
        f.write("{not json")
    with pytest.raises(json.JSONDecodeError):
        restore(root, 1, _tree())


def test_restore_wrong_dtype_leaf_swap(tmp_path):
    """Swapping a leaf file for one with a different byte size per element
    trips either the dtype re-view or the shape check — never a silent
    reinterpretation."""
    root = str(tmp_path)
    d = save(root, 1, {"w": jnp.arange(8, dtype=jnp.float32)})
    np.save(os.path.join(d, "leaf_00000.npy"), np.zeros(3, np.float64))
    with pytest.raises(ValueError):
        restore(root, 1, {"w": jnp.zeros((8,), jnp.float32)})


# ----------------------------------------------------------------------------
# overwrite + retention
# ----------------------------------------------------------------------------

def test_save_same_step_overwrites_atomically(tmp_path):
    root = str(tmp_path)
    save(root, 5, {"w": jnp.zeros((2,))})
    save(root, 5, {"w": jnp.full((2,), 9.0)})
    out, _ = restore(root, 5, {"w": jnp.zeros((2,))})
    np.testing.assert_array_equal(np.asarray(out["w"]), [9.0, 9.0])


def test_cleanup_keeps_newest_and_tolerates_strays(tmp_path):
    root = str(tmp_path)
    for s in (1, 2, 3, 4):
        save(root, s, {"w": jnp.zeros((1,))})
    stray = os.path.join(root, "step_00000099")   # manifest-less stray
    os.makedirs(stray)
    cleanup(root, keep_last=2)
    kept = sorted(
        d for d in os.listdir(root)
        if d.startswith("step_")
        and os.path.isfile(os.path.join(root, d, "manifest.json"))
    )
    assert kept == ["step_00000003", "step_00000004"]
    shutil.rmtree(stray)
    # keep_last <= 0 disables retention entirely
    cleanup(root, keep_last=0)
    assert latest_step(root) == 4
