"""Persistent stratification index: bit-identity, delta maintenance, store,
and on-disk IO (``core.index`` + ``checkpoint.index_io``).

The acceptance contract this file pins down:

* hydrating a :class:`~repro.core.index.IndexArtifact` is **bit-identical**
  at fp32 to the fresh sweep it replaces — strata AND end-to-end BAS
  estimates, for the streaming path and for index-routed dense-footprint
  queries, including after a save -> mmap-load round trip;
* :func:`~repro.core.index.append_rows` equals a full recompute **exactly**
  (integer tiles, merged top-k, re-derived content key) over random append
  splits, on the fp32 numpy fallback, the fp32 kernel path, and the int8
  kernel path — the property the paper's build-once/query-many economics
  rest on;
* the content key tracks exactly the quantities that change sweep output
  (tables, binning, weight transform, requested precision) and nothing
  execution-specific (block size, kernel on/off);
* :class:`~repro.core.index.IndexStore` shares one build per key, evicts
  by memory budget, falls back to the on-disk store, and exposes the
  serving counters; corrupt or misplaced on-disk artifacts fail loudly.
"""
import dataclasses
import json
import os
import shutil

import numpy as np
import pytest

from repro.core import (
    Agg,
    BASConfig,
    IndexStore,
    Query,
    append_rows,
    artifact_key,
    build_index,
    run_auto,
    run_bas_streaming,
)
from repro.core.similarity import normalize
from repro.core.stratify import stratify_streaming, sweep_pass
from repro.data import make_clustered_tables

CFG = BASConfig()
BINS = 512


def _tables(n1, n2, d=16, seed=0):
    rng = np.random.default_rng(seed)
    return (
        normalize(rng.standard_normal((n1, d))).astype(np.float32),
        normalize(rng.standard_normal((n2, d))).astype(np.float32),
    )


def _build(embs, **kw):
    kw.setdefault("n_bins", BINS)
    kw.setdefault("exponent", CFG.weight_exponent)
    kw.setdefault("floor", CFG.weight_floor)
    return build_index(list(embs), **kw)


def _assert_artifacts_equal(a, b):
    assert a.key == b.key
    assert a.sizes == b.sizes
    np.testing.assert_array_equal(np.asarray(a.counts), np.asarray(b.counts))
    np.testing.assert_array_equal(np.asarray(a.edges), np.asarray(b.edges))
    np.testing.assert_array_equal(np.asarray(a.block_counts),
                                  np.asarray(b.block_counts))
    if a.topk_vals is not None or b.topk_vals is not None:
        np.testing.assert_array_equal(np.asarray(a.topk_valid),
                                      np.asarray(b.topk_valid))
        valid = np.asarray(a.topk_valid)
        np.testing.assert_array_equal(np.asarray(a.topk_vals)[valid],
                                      np.asarray(b.topk_vals)[valid])
        np.testing.assert_array_equal(np.asarray(a.topk_idx)[valid],
                                      np.asarray(b.topk_idx)[valid])


# ----------------------------------------------------------------------------
# content key
# ----------------------------------------------------------------------------

def test_key_tracks_sweep_inputs_not_execution_details():
    e1, e2 = _tables(40, 50)
    base = artifact_key([e1, e2], BINS, 1.0, 1e-3, "fp32")
    assert base == artifact_key([e1, e2], BINS, 1.0, 1e-3, "fp32")
    # anything that changes sweep output changes the key
    assert base != artifact_key([e2, e1], BINS, 1.0, 1e-3, "fp32")
    assert base != artifact_key([e1, e2], 2 * BINS, 1.0, 1e-3, "fp32")
    assert base != artifact_key([e1, e2], BINS, 2.0, 1e-3, "fp32")
    assert base != artifact_key([e1, e2], BINS, 1.0, 1e-2, "fp32")
    assert base != artifact_key([e1, e2], BINS, 1.0, 1e-3, "int8")
    bumped = e1.copy()
    bumped[0, 0] += 1e-3
    assert base != artifact_key([normalize(bumped), e2], BINS, 1.0, 1e-3,
                                "fp32")
    # execution details (block size, kernel on/off) are NOT key components
    assert (_build([e1, e2], block=32, use_kernel=False).key
            == _build([e1, e2], block=4096, use_kernel=True).key == base)


def test_artifact_check_rejects_mismatched_query():
    e1, e2 = _tables(40, 50)
    art = _build([e1, e2])
    art.check(sizes=(40, 50), n_bins=BINS, exponent=CFG.weight_exponent,
              floor=CFG.weight_floor)
    with pytest.raises(ValueError, match="n_bins"):
        art.check(n_bins=BINS * 2)
    with pytest.raises(ValueError, match="covers tables"):
        art.check(sizes=(41, 50))
    with pytest.raises(ValueError):
        sweep_pass(e1, e2, n_bins=BINS * 2, artifact=art)


# ----------------------------------------------------------------------------
# hydration bit-identity (fp32)
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("use_kernel", [False, True])
def test_hydrated_sweep_is_bit_identical(use_kernel):
    e1, e2 = _tables(150, 130, seed=3)
    art = _build([e1, e2], use_kernel=use_kernel)
    fresh = sweep_pass(e1, e2, n_bins=BINS, exponent=CFG.weight_exponent,
                       floor=CFG.weight_floor, use_kernel=use_kernel)
    hyd = sweep_pass(e1, e2, n_bins=BINS, exponent=CFG.weight_exponent,
                     floor=CFG.weight_floor, artifact=art)
    np.testing.assert_array_equal(np.asarray(hyd.counts),
                                  np.asarray(fresh.counts))
    np.testing.assert_array_equal(np.asarray(hyd.edges),
                                  np.asarray(fresh.edges))
    assert hyd.stats["index_version"] == 1


@pytest.mark.parametrize("use_kernel", [False, True])
def test_hydrated_stratification_matches_fresh(use_kernel):
    e1, e2 = _tables(150, 130, seed=3)
    art = _build([e1, e2], use_kernel=use_kernel)
    budget = 600
    fresh = stratify_streaming(e1, e2, CFG.alpha, budget, CFG, n_bins=BINS,
                               use_kernel=use_kernel)
    hyd = stratify_streaming(e1, e2, CFG.alpha, budget, CFG, n_bins=BINS,
                             artifact=art)
    np.testing.assert_array_equal(fresh.order, hyd.order)
    np.testing.assert_array_equal(fresh.bounds, hyd.bounds)
    np.testing.assert_array_equal(fresh.order_weights, hyd.order_weights)


def test_streaming_estimates_bit_identical_with_index(tmp_path):
    """Fresh sweep, resident artifact, store-resolved artifact, and a
    save -> mmap-load round trip must all land the SAME estimate and CI."""
    ds = make_clustered_tables(130, 130, n_entities=160, noise=0.4, seed=5)

    def q():
        return Query(spec=ds.spec(), agg=Agg.COUNT, oracle=ds.oracle(),
                     budget=1500)

    base = run_bas_streaming(q(), CFG, seed=0, n_bins=BINS)
    embs = [np.asarray(e, np.float32) for e in ds.spec().embeddings]
    art = _build(embs, use_kernel=CFG.use_kernel)
    hyd = run_bas_streaming(q(), CFG, seed=0, n_bins=BINS, artifact=art)
    store = IndexStore()
    cold = run_bas_streaming(q(), CFG, seed=0, n_bins=BINS,
                             index_store=store)
    warm = run_bas_streaming(q(), CFG, seed=0, n_bins=BINS,
                             index_store=store)

    from repro.checkpoint.index_io import load_index, save_index

    save_index(str(tmp_path), art)
    loaded = load_index(str(tmp_path), art.key)
    disk = run_bas_streaming(q(), CFG, seed=0, n_bins=BINS, artifact=loaded)

    for res in (hyd, cold, warm, disk):
        assert res.estimate == base.estimate
        assert res.ci.lo == base.ci.lo and res.ci.hi == base.ci.hi
    # observability: the stratify detail says how the sweep was obtained
    assert "index_hit" not in base.detail["stratify"]
    assert hyd.detail["stratify"]["path"] == "index"
    assert hyd.detail["stratify"]["index_hit"] is True
    assert cold.detail["stratify"]["index_hit"] is False
    assert cold.detail["stratify"]["index_build_ms"] >= 0
    assert warm.detail["stratify"]["index_hit"] is True
    assert disk.detail["stratify"]["index_version"] == 1
    assert disk.detail["stratify"]["delta_blocks"] == 0


def test_run_auto_routes_through_resident_index():
    """Dense-footprint queries route dense on an empty store, but a fresh
    resident artifact overrides the memory model (``streaming-index``) and
    reproduces the plain streaming estimate bit-for-bit."""
    ds = make_clustered_tables(120, 120, n_entities=150, noise=0.4, seed=7)

    def q():
        return Query(spec=ds.spec(), agg=Agg.COUNT, oracle=ds.oracle(),
                     budget=1200)

    store = IndexStore()
    res = run_auto(q(), CFG, seed=0, n_bins=BINS, index_store=store)
    assert res.detail["dispatch"]["path"] == "dense"   # miss stays dense
    assert store.stats()["index_build"] == 0

    embs = [np.asarray(e, np.float32) for e in ds.spec().embeddings]
    store.add(_build(embs, use_kernel=CFG.use_kernel))
    routed = run_auto(q(), CFG, seed=0, n_bins=BINS, index_store=store)
    assert routed.detail["dispatch"]["path"] == "streaming-index"
    plain = run_bas_streaming(q(), CFG, seed=0, n_bins=BINS)
    assert routed.estimate == plain.estimate

    # streaming-routed miss builds through the store -> next query hits
    cfg_small = dataclasses.replace(CFG, max_dense_weight_bytes=1024)
    store2 = IndexStore()
    first = run_auto(q(), cfg_small, seed=0, n_bins=BINS, index_store=store2)
    assert first.detail["dispatch"]["path"] == "streaming"
    assert store2.stats()["index_build"] == 1
    second = run_auto(q(), cfg_small, seed=0, n_bins=BINS, index_store=store2)
    assert second.detail["dispatch"]["path"] == "streaming-index"
    assert first.estimate == second.estimate


# ----------------------------------------------------------------------------
# delta maintenance == full recompute (property, random splits)
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("use_kernel", [False, True])
@pytest.mark.parametrize("table", [0, 1])
def test_append_equals_full_recompute_random_splits(use_kernel, table):
    """Property: for random table sizes and split points, building an index
    on a prefix and appending the remainder is EXACTLY a build on the full
    tables — tiles, top-k, and content key.  ``block=32`` forces multiple
    row tiles so boundary-straddling appends are exercised."""
    rng = np.random.default_rng(42 + table + 2 * use_kernel)
    for trial in range(4):
        n1, n2 = int(rng.integers(40, 120)), int(rng.integers(40, 120))
        delta = int(rng.integers(1, 40))
        full = _tables(n1 + (delta if table == 0 else 0),
                       n2 + (delta if table == 1 else 0),
                       seed=int(rng.integers(1 << 30)))
        prefix = [full[0][:n1], full[1][:n2]]
        art = _build(prefix, block=32, use_kernel=use_kernel)
        grown = append_rows(art, table, full[table][-delta:],
                            use_kernel=use_kernel)
        ref = _build(list(full), block=32, use_kernel=use_kernel)
        _assert_artifacts_equal(grown, ref)
        assert grown.version == 2 and grown.stats["appends"] == 1
        assert grown.stats["delta_rows"] == delta


def test_append_equals_full_recompute_int8():
    """The low-precision (int8 kernel) tiles must obey the same exactness:
    the delta sweep quantises identically, so appended tiles equal a full
    int8 recompute.  ``tolerance=inf`` pins the effective precision to int8
    on both sides (no fp32 fallback)."""
    rng = np.random.default_rng(11)
    for trial in range(2):
        n1, n2 = int(rng.integers(48, 100)), int(rng.integers(48, 100))
        delta = int(rng.integers(4, 32))
        full = _tables(n1, n2 + delta, seed=int(rng.integers(1 << 30)))
        prefix = [full[0], full[1][:n2]]
        art = _build(prefix, block=32, use_kernel=True, precision="int8",
                     tolerance=float("inf"))
        assert art.precision == "int8"
        grown = append_rows(art, 1, full[1][-delta:], use_kernel=True)
        ref = _build(list(full), block=32, use_kernel=True, precision="int8",
                     tolerance=float("inf"))
        _assert_artifacts_equal(grown, ref)


def test_append_lowp_without_kernel_refuses():
    """A lowp artifact whose delta could only run the fp32 numpy fallback
    must refuse rather than silently mix precisions across tiles."""
    e1, e2 = _tables(64, 64)
    art = _build([e1, e2], use_kernel=True, precision="int8",
                 tolerance=float("inf"))
    with pytest.raises(RuntimeError, match="without the sweep kernel"), \
            pytest.warns(UserWarning, match="numpy fallback"):
        append_rows(art, 1, _tables(8, 8, seed=9)[1], use_kernel=False)


def test_append_chain_artifact_not_supported():
    e1, e2 = _tables(32, 32)
    e3 = _tables(32, 32, seed=2)[0]
    art = _build([e1, e2, e3], use_kernel=False)
    with pytest.raises(NotImplementedError):
        append_rows(art, 1, e3[:4])


def test_stale_artifact_no_longer_matches_after_append():
    """Freshness is structural: once the live tables grow, the old
    artifact's key stops matching, so lookups miss instead of serving a
    stale sweep."""
    e1, e2 = _tables(60, 60)
    store = IndexStore()
    art, hit = store.get_or_build([e1, e2], n_bins=BINS)
    assert not hit
    extra = _tables(8, 8, seed=3)[1]
    grown_tables = [e1, np.concatenate([e2, extra])]
    assert store.lookup(grown_tables, n_bins=BINS) is None
    grown = append_rows(art, 1, extra, use_kernel=CFG.use_kernel)
    store.add(grown)
    found = store.lookup(grown_tables, n_bins=BINS)
    assert found is not None and found.version == 2
    assert store.stats()["delta_blocks"] == grown.stats["last_delta_blocks"]


# ----------------------------------------------------------------------------
# IndexStore behaviour
# ----------------------------------------------------------------------------

def test_store_shares_one_build_and_counts():
    e1, e2 = _tables(60, 60)
    store = IndexStore()
    a1, hit1 = store.get_or_build([e1, e2], n_bins=BINS)
    a2, hit2 = store.get_or_build([e1, e2], n_bins=BINS)
    assert (hit1, hit2) == (False, True) and a1 is a2
    s = store.stats()
    assert s["index_build"] == 1 and s["index_hit"] == 1
    assert s["index_miss"] == 1 and s["index_bytes"] == a1.nbytes
    # lookup never builds and never counts a miss
    other = _tables(30, 30, seed=9)
    assert store.lookup(list(other), n_bins=BINS) is None
    assert store.stats()["index_miss"] == 1


def test_store_evicts_lru_under_memory_budget():
    e1, e2 = _tables(60, 60, seed=0)
    probe = build_index([e1, e2], n_bins=BINS)
    store = IndexStore(max_bytes=int(probe.nbytes * 1.5))
    store.get_or_build([e1, e2], n_bins=BINS)
    f1, f2 = _tables(60, 60, seed=1)
    store.get_or_build([f1, f2], n_bins=BINS)      # evicts the first
    assert store.stats()["index_evict"] == 1
    assert store.lookup([e1, e2], n_bins=BINS) is None
    assert store.lookup([f1, f2], n_bins=BINS) is not None
    assert store.bytes_resident <= store.max_bytes


def test_store_loads_from_disk_root(tmp_path):
    from repro.checkpoint.index_io import save_index

    e1, e2 = _tables(60, 60)
    art = _build([e1, e2], use_kernel=CFG.use_kernel)
    save_index(str(tmp_path), art)
    store = IndexStore(root=str(tmp_path))
    got, hit = store.get_or_build([e1, e2], n_bins=BINS,
                                  exponent=CFG.weight_exponent,
                                  floor=CFG.weight_floor)
    assert not hit and got.key == art.key
    s = store.stats()
    assert s["index_load"] == 1 and s["index_build"] == 0
    np.testing.assert_array_equal(np.asarray(got.counts), art.counts)


# ----------------------------------------------------------------------------
# on-disk IO: roundtrip, versioning, corruption
# ----------------------------------------------------------------------------

def test_index_io_roundtrip_and_versions(tmp_path):
    from repro.checkpoint.index_io import (latest_version, list_indexes,
                                           load_index, save_index)

    root = str(tmp_path)
    e1, e2 = _tables(70, 60)
    art = _build([e1, e2], use_kernel=CFG.use_kernel)
    save_index(root, art)
    got = load_index(root, art.key)
    _assert_artifacts_equal(got, art)
    for s in ("version", "n_bins", "exponent", "floor", "precision",
              "precision_requested", "kernel", "block_rows"):
        assert getattr(got, s) == getattr(art, s), s
    assert isinstance(got.counts, np.memmap)   # zero-copy read

    # append -> v2 next to v1; loader picks newest, explicit version works
    extra = _tables(8, 8, seed=4)[1]
    v2 = append_rows(art, 1, extra, use_kernel=CFG.use_kernel)
    save_index(root, v2)
    assert latest_version(root, art.key) == 1   # old lineage untouched
    assert latest_version(root, v2.key) == 2    # version follows the lineage
    listed = list_indexes(root)
    assert sorted(x["key"] for x in listed) == sorted({art.key, v2.key})
    assert load_index(root, v2.key).sizes == (70, 68)

    # same-key versions prune beyond keep_last
    same = load_index(root, art.key, mmap=False)
    for v in (2, 3, 4):
        same = dataclasses.replace(same, version=v)
        save_index(root, same, keep_last=2)
    assert latest_version(root, art.key) == 4
    with pytest.raises(FileNotFoundError):
        load_index(root, art.key, version=1)    # pruned
    assert load_index(root, art.key, version=3).version == 3


def test_index_io_corruption_fails_loudly(tmp_path):
    from repro.checkpoint.index_io import load_index, save_index

    root = str(tmp_path)
    e1, e2 = _tables(50, 50)
    art = _build([e1, e2], use_kernel=CFG.use_kernel)
    d = save_index(root, art)

    with pytest.raises(FileNotFoundError):
        load_index(root, "deadbeef" * 8)

    # manifest/file shape mismatch (backup kept outside the store tree)
    bak = os.path.join(str(tmp_path), "bak")
    shutil.copytree(d, bak)
    np.save(os.path.join(d, "counts.npy"), np.zeros(10))
    with pytest.raises(ValueError, match="counts"):
        load_index(root, art.key)
    shutil.rmtree(d)
    shutil.copytree(bak, d)

    # missing array
    os.remove(os.path.join(d, "edges.npy"))
    with pytest.raises(ValueError, match="edges"):
        load_index(root, art.key)
    shutil.rmtree(d)
    shutil.copytree(bak, d)

    # artifact misfiled under another key's directory
    wrong = os.path.join(root, "0" * 64)
    shutil.copytree(os.path.join(root, art.key), wrong)
    with pytest.raises(ValueError, match="does not match"):
        load_index(root, "0" * 64)

    # format bump
    meta_path = os.path.join(d, "meta.json")
    with open(meta_path) as f:
        meta = json.load(f)
    meta["format"] = 999
    with open(meta_path, "w") as f:
        json.dump(meta, f)
    with pytest.raises(ValueError, match="format"):
        load_index(root, art.key)

    # a torn write (.tmp_ dir) is never visible
    shutil.rmtree(d)
    shutil.copytree(bak, d)
    os.makedirs(os.path.join(root, art.key, ".tmp_00000002"))
    assert load_index(root, art.key).version == 1


# ----------------------------------------------------------------------------
# service observability
# ----------------------------------------------------------------------------

def test_oracle_service_stats_carry_index_counters():
    from repro.serve.oracle_service import OracleService

    e1, e2 = _tables(50, 50)
    store = IndexStore()
    with OracleService(workers=1, index_store=store) as svc:
        base = svc.stats()
        assert base["index_hit"] == 0 and base["index_miss"] == 0
        store.get_or_build([e1, e2], n_bins=BINS)
        store.get_or_build([e1, e2], n_bins=BINS)
        s = svc.stats()
    assert s["index_hit"] == 1 and s["index_build"] == 1
    assert s["index_bytes"] > 0
    with OracleService(workers=1) as svc:   # no store -> no index keys
        assert "index_hit" not in svc.stats()
