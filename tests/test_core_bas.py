import numpy as np
import pytest

from repro.core import (
    Agg,
    Query,
    run_bas,
    run_uniform,
)
from repro.core.oracle import BudgetExceeded
from repro.data import make_clustered_tables, make_syn_scores


@pytest.fixture(scope="module")
def ds():
    return make_clustered_tables(250, 250, n_entities=400, noise=0.4, seed=7)


def make_query(ds, agg, budget=4000, g=None):
    return Query(spec=ds.spec(), agg=agg, oracle=ds.oracle(), budget=budget, g=g)


def test_bas_exact_when_budget_covers_space():
    ds = make_clustered_tables(40, 40, n_entities=60, noise=0.3, seed=1)
    q = make_query(ds, Agg.COUNT, budget=40 * 40 + 10)
    res = run_bas(q, seed=0)
    assert res.estimate == ds.truth.sum()
    assert res.ci.width == 0.0


def test_bas_budget_never_exceeded(ds):
    for seed in range(3):
        q = make_query(ds, Agg.COUNT, budget=1500)
        res = run_bas(q, seed=seed)
        assert res.oracle_calls <= 1500


def test_bas_count_close_and_covered(ds):
    truth = float(ds.truth.sum())
    hits, errs = 0, []
    n_rep = 8
    for seed in range(n_rep):
        q = make_query(ds, Agg.COUNT, budget=5000)
        res = run_bas(q, seed=seed)
        errs.append(abs(res.estimate - truth) / truth)
        hits += res.ci.contains(truth)
    assert np.mean(errs) < 0.5
    assert hits >= n_rep - 2  # 95% nominal; allow slack at 8 reps


def test_bas_sum_and_avg(ds):
    g_col = ds.columns1["value"]

    def g(idx):
        return g_col[idx[:, 0]]

    m = ds.truth > 0
    truth_sum = float((g_col[:, None] * ds.truth)[m].sum())
    truth_avg = truth_sum / ds.truth.sum()
    q = make_query(ds, Agg.SUM, budget=6000, g=g)
    rs = run_bas(q, seed=0)
    assert abs(rs.estimate - truth_sum) / truth_sum < 0.6
    q = make_query(ds, Agg.AVG, budget=6000, g=g)
    ra = run_bas(q, seed=0)
    assert abs(ra.estimate - truth_avg) / truth_avg < 0.5


def test_bas_extremes_and_median(ds):
    g_col = ds.columns1["value"]

    def g(idx):
        return g_col[idx[:, 0]]

    vals = np.broadcast_to(g_col[:, None], ds.truth.shape)[ds.truth > 0]
    q = make_query(ds, Agg.MAX, budget=6000, g=g)
    q.g_bounds = (float(g_col.min()), float(g_col.max()))
    rmax = run_bas(q, seed=0)
    assert rmax.estimate <= vals.max() + 1e-9   # observed max never exceeds truth
    assert rmax.estimate >= np.quantile(vals, 0.5)  # and should find a high one
    assert rmax.ci.hi >= vals.max()             # CI upper bound = global bound
    q = make_query(ds, Agg.MEDIAN, budget=6000, g=g)
    rmed = run_bas(q, seed=0)
    assert np.quantile(vals, 0.05) <= rmed.estimate <= np.quantile(vals, 0.95)


def test_bas_beats_uniform_on_low_selectivity():
    ds = make_syn_scores(400, 400, selectivity=2e-3, fnr=0.1, fpr=0.1, seed=3)
    truth = float(ds.truth.sum())
    w = ds.weights_override
    bas_err, uni_err = [], []
    for seed in range(6):
        qb = Query(spec=ds.spec(), agg=Agg.COUNT, oracle=ds.oracle(), budget=4000)
        rb = run_bas(qb, seed=seed, weights=w)
        qu = Query(spec=ds.spec(), agg=Agg.COUNT, oracle=ds.oracle(), budget=4000)
        ru = run_uniform(qu, seed=seed)
        bas_err.append((rb.estimate - truth) ** 2)
        uni_err.append((ru.estimate - truth) ** 2)
    assert np.sqrt(np.mean(bas_err)) < np.sqrt(np.mean(uni_err))


def test_oracle_ledger_blocks_overspend():
    ds = make_clustered_tables(50, 50, n_entities=60, noise=0.3, seed=2)
    oracle = ds.oracle()
    oracle.set_budget(10)
    with pytest.raises(BudgetExceeded):
        oracle.label(np.stack([np.arange(20), np.arange(20)], axis=1))


def test_oracle_cache_free_requeries():
    ds = make_clustered_tables(50, 50, n_entities=60, noise=0.3, seed=2)
    oracle = ds.oracle()
    oracle.set_budget(10)
    idx = np.stack([np.arange(10), np.arange(10)], axis=1)
    oracle.label(idx)
    assert oracle.calls == 10
    oracle.label(idx)  # cached: no budget movement, no exception
    assert oracle.calls == 10
    assert oracle.requests == 20


def test_streaming_bas_matches_dense_and_scales():
    """The O(N1+N2+b) streaming path (histogram stratification via the
    sim_hist kernel + walk/rejection D_0 sampling) agrees with the dense path
    and stays within budget on a cross product we never materialise."""
    from repro.core import run_bas_streaming

    ds = make_clustered_tables(400, 500, n_entities=700, noise=0.5, seed=13)
    truth = float(ds.truth.sum())
    budget = 8000
    errs_d, errs_s, covered = [], [], 0
    n_rep = 4
    for seed in range(n_rep):
        qd = make_query(ds, Agg.COUNT, budget=budget)
        rd = run_bas(qd, seed=seed)
        qs = make_query(ds, Agg.COUNT, budget=budget)
        rs = run_bas_streaming(qs, seed=seed, use_kernel=True)
        assert rs.oracle_calls <= budget
        errs_d.append(abs(rd.estimate - truth) / truth)
        errs_s.append(abs(rs.estimate - truth) / truth)
        covered += rs.ci.contains(truth)
    # streaming is statistically comparable to dense (same design)
    assert np.mean(errs_s) < max(2.5 * np.mean(errs_d), 0.30)
    assert covered >= n_rep - 2


def test_streaming_bas_sum():
    from repro.core import run_bas_streaming

    ds = make_clustered_tables(300, 300, n_entities=500, noise=0.5, seed=14)
    g_col = ds.columns1["value"]
    g = lambda idx: g_col[idx[:, 0]]  # noqa: E731
    m = ds.truth > 0
    truth_sum = float((g_col[:, None] * ds.truth)[m].sum())
    q = make_query(ds, Agg.SUM, budget=7000, g=g)
    res = run_bas_streaming(q, seed=0)
    assert abs(res.estimate - truth_sum) / truth_sum < 0.6
