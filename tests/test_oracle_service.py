"""OracleService: cross-query coalescing semantics, in-process and over TCP.

The contract under test: routing any number of concurrent queries through one
service changes *where* labelling executes (shared micro-batched windows on a
worker pool — possibly behind a network transport, possibly sharded across
worker hosts) but nothing about *what* each query computes — estimates are
bit-identical to serial execution, ledgers stay per-query, and one query's
budget exhaustion, backend failure, or transport drop never touches another
query's batch.
"""
import threading

import numpy as np
import pytest

from repro.core import Agg, FnOracle, ModelOracle, OracleBatch, Query, run_bas
from repro.core.oracle import BudgetExceeded, LabelRequest, LabelResult
from repro.data import make_clustered_tables
from repro.serve.oracle_service import OracleService, serve_queries
from repro.serve.transport import (
    OracleServiceServer,
    RemoteExecutionError,
    RemoteOracle,
)


def _mk_query(seed, budget=1500, n=100):
    ds = make_clustered_tables(n, n, n_entities=150, noise=0.4, seed=seed)
    return Query(spec=ds.spec(), agg=Agg.COUNT, oracle=ds.oracle(),
                 budget=budget)


# ----------------------------------------------------------------------------
# bit-identical estimates + untouched ledgers
# ----------------------------------------------------------------------------

def test_concurrent_queries_bit_identical_to_serial():
    """Two (and more) queries sharing one OracleService must produce exactly
    the estimates, CIs, and ledger counts of running them serially."""
    seeds = (1, 2, 3, 4)
    serial = []
    for s in seeds:
        q = _mk_query(s)
        res = run_bas(q, seed=s)
        serial.append((res, q.oracle.calls, q.oracle.requests))

    with OracleService(workers=2, max_wait_ms=20.0) as svc:
        queries = [_mk_query(s) for s in seeds]
        svc.attach(*[q.oracle for q in queries])

        def job(q, s):
            try:
                return run_bas(q, seed=s)
            finally:
                svc.detach(q.oracle)

        results = serve_queries(
            svc, [lambda q=q, s=s: job(q, s) for q, s in zip(queries, seeds)]
        )
        stats = svc.stats()

    for (ref, calls, requests), got, q in zip(serial, results, queries):
        assert got.estimate == ref.estimate          # bit-identical
        assert got.ci.lo == ref.ci.lo and got.ci.hi == ref.ci.hi
        assert q.oracle.calls == calls               # same ledger charge
        assert q.oracle.requests == requests
    # and the flushes actually coalesced across queries
    assert stats["segments"] >= 4 * len(seeds)
    assert stats["windows"] < stats["segments"]


def test_budget_exhausted_query_leaves_others_untouched():
    """A query that blows its budget mid-pipeline fails alone; a concurrent
    query in the same service windows is bit-identical to running solo."""
    ok_ref = _mk_query(7)
    ref = run_bas(ok_ref, seed=7)

    with OracleService(max_wait_ms=20.0) as svc:
        # budget 6 < the pilot-stage minimum draw -> BudgetExceeded mid-pipeline
        poor = _mk_query(5, budget=6)
        ok = _mk_query(7)
        svc.attach(poor.oracle, ok.oracle)
        errs = []

        def run_poor():
            try:
                run_bas(poor, seed=5)
            except BudgetExceeded as e:
                errs.append(e)
            finally:
                svc.detach(poor.oracle)

        def run_ok():
            try:
                return run_bas(ok, seed=7)
            finally:
                svc.detach(ok.oracle)

        t = threading.Thread(target=run_poor)
        t.start()
        res = run_ok()
        t.join()

    assert len(errs) == 1                            # poor query failed...
    assert poor.oracle.calls == 0                    # ...charging nothing
    assert res.estimate == ref.estimate              # other query untouched
    assert res.ci.lo == ref.ci.lo and res.ci.hi == ref.ci.hi
    assert ok.oracle.calls == ok_ref.oracle.calls


# ----------------------------------------------------------------------------
# window-level failure isolation + retry
# ----------------------------------------------------------------------------

def _flush_concurrently(batches):
    """Flush all batches from separate threads so they land in one service
    window; returns the futures' exceptions (None for success)."""
    outcomes = [None] * len(batches)
    barrier = threading.Barrier(len(batches))

    def go(i):
        barrier.wait()
        try:
            batches[i].flush_async().result()
        except BaseException as e:  # noqa: BLE001
            outcomes[i] = e

    threads = [threading.Thread(target=go, args=(i,)) for i in range(len(batches))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return outcomes


def _parity_oracle(n=64):
    o = FnOracle(lambda idx: (idx.sum(axis=1) % 2).astype(np.float64))
    o.bind_sizes((n, n))
    return o

def test_budget_failure_isolated_and_retryable_in_one_window():
    a, b = _parity_oracle(), _parity_oracle()
    a.set_budget(2)
    idx_a = np.array([[0, 1], [2, 3], [4, 5]])      # 3 new > budget 2
    idx_b = np.array([[1, 2], [3, 4]])
    with OracleService(max_wait_ms=500.0) as svc:
        svc.attach(a, b)
        ba, bb = OracleBatch(a), OracleBatch(b)
        ha, hb = ba.submit(idx_a), bb.submit(idx_b)
        out = _flush_concurrently([ba, bb])
        assert isinstance(out[0], BudgetExceeded)
        assert out[1] is None
        # b's window-mate failure never reached b
        np.testing.assert_array_equal(hb.labels, idx_b.sum(1) % 2)
        assert b.calls == 2 and b.requests == 2
        # a is untouched and retryable: raise the budget, same batch succeeds
        assert a.calls == 0 and a.requests == 0 and a.batches == 0
        a.set_budget(5)
        ba.flush_async().result()
        np.testing.assert_array_equal(ha.labels, idx_a.sum(1) % 2)
        assert a.calls == 3


def test_backend_error_isolated_and_retryable_in_one_window():
    state = {"fail": True}

    def flaky(idx):
        if state["fail"]:
            raise RuntimeError("transient backend error")
        return (idx.sum(axis=1) % 2).astype(np.float64)

    a = FnOracle(flaky)
    a.bind_sizes((64, 64))
    b = _parity_oracle()
    idx = np.array([[1, 2], [3, 4], [5, 6]])
    with OracleService(max_wait_ms=500.0) as svc:
        svc.attach(a, b)
        ba, bb = OracleBatch(a), OracleBatch(b)
        ha, hb = ba.submit(idx), bb.submit(idx)
        out = _flush_concurrently([ba, bb])
        assert isinstance(out[0], RuntimeError)
        assert out[1] is None
        np.testing.assert_array_equal(hb.labels, idx.sum(1) % 2)
        assert a.calls == 0 and a.batches == 0       # atomic failure
        state["fail"] = False
        ba.flush_async().result()                    # retryable
        np.testing.assert_array_equal(ha.labels, idx.sum(1) % 2)
        assert a.calls == 3


# ----------------------------------------------------------------------------
# cross-query super-batch fusion + worker sharding
# ----------------------------------------------------------------------------

def test_shared_scorer_queries_fuse_into_one_backend_call():
    """ModelOracles scoring through one shared scorer share a service group:
    concurrent flushes fuse into a single backend execution."""
    calls = []

    def scorer(idx):
        calls.append(np.array(idx))
        return (idx.sum(axis=1) % 2).astype(np.float64)

    a = ModelOracle(scorer, threshold=0.5)
    b = ModelOracle(scorer, threshold=0.5)
    for o in (a, b):
        o.bind_sizes((64, 64))
    assert a.service_group() == b.service_group()
    # a scorer *object* with a .score method (the PairScorer shape) must fuse
    # too: ModelOracle stores the bound method, whose id is per-access
    class _Scorer:
        def score(self, idx):
            return np.zeros(len(idx))

    shared = _Scorer()
    assert (ModelOracle(shared).service_group()
            == ModelOracle(shared).service_group())
    assert (ModelOracle(shared).service_group()
            != ModelOracle(_Scorer()).service_group())
    idx_a = np.array([[0, 1], [2, 3]])
    idx_b = np.array([[2, 3], [4, 5]])              # overlaps a; NOT deduped
    with OracleService(max_wait_ms=500.0) as svc:
        svc.attach(a, b)
        ba, bb = OracleBatch(a), OracleBatch(b)
        ha, hb = ba.submit(idx_a), bb.submit(idx_b)
        out = _flush_concurrently([ba, bb])
    assert out == [None, None]
    assert len(calls) == 1                           # one fused super-batch
    assert len(calls[0]) == 4                        # ledgers stay per-query:
    assert a.calls == 2 and b.calls == 2             # no cross-oracle dedup
    np.testing.assert_array_equal(ha.labels, idx_a.sum(1) % 2)
    np.testing.assert_array_equal(hb.labels, idx_b.sum(1) % 2)


def test_worker_pool_shards_large_flushes():
    sizes = []
    lock = threading.Lock()

    def fn(idx):
        with lock:
            sizes.append(len(idx))
        return (idx.sum(axis=1) % 2).astype(np.float64)

    o = FnOracle(fn)
    o.bind_sizes((1000, 1000))
    rng = np.random.default_rng(0)
    idx = np.unique(rng.integers(0, 1000, size=(4096, 2)), axis=0)
    with OracleService(workers=4, min_shard=256, max_wait_ms=1.0) as svc:
        svc.attach(o)
        got = o.label(idx)
    assert len(sizes) == 4                           # sharded over the pool
    assert sum(sizes) == len(idx)
    np.testing.assert_array_equal(got, idx.sum(1) % 2)


def test_solo_client_dispatches_without_deadline_wait():
    """With every attached client already in the window there is nobody to
    wait for: a solo query must not pay the windowing deadline."""
    import time

    o = _parity_oracle()
    with OracleService(max_wait_ms=5000.0) as svc:
        svc.attach(o)
        t0 = time.perf_counter()
        o.label(np.array([[1, 2], [3, 4]]))
        dt = time.perf_counter() - t0
    assert dt < 2.0                                  # far below the deadline


def test_detached_oracle_flushes_locally_again():
    o = _parity_oracle()
    svc = OracleService(max_wait_ms=1.0)
    svc.attach(o)
    assert o.service is svc
    svc.detach(o)
    assert o.service is None
    np.testing.assert_array_equal(
        o.label(np.array([[1, 2]])), [1.0]
    )
    svc.close()


def test_submit_after_close_raises_and_restores_pending():
    o = _parity_oracle()
    svc = OracleService(max_wait_ms=1.0)
    svc.attach(o)
    svc.close()
    batch = OracleBatch(o)
    batch.submit(np.array([[1, 2]]))
    with pytest.raises(RuntimeError):
        batch.flush_async()
    assert len(batch._pending) == 1                  # retryable after detach
    o.service = None
    batch.flush()
    assert o.calls == 1


# ----------------------------------------------------------------------------
# multi-host dispatch: the TCP transport (repro.serve.transport)
# ----------------------------------------------------------------------------

def _parity_fn(idx):
    return (idx.sum(axis=1) % 2).astype(np.float64)


def test_wire_payload_roundtrip():
    """LabelRequest/LabelResult survive encode->decode exactly, including
    empty segments and error results (the transport's unit contract)."""
    req = LabelRequest("pairs", np.array([[1, 2], [3, 4], [5, 6]]),
                       request_id=42)
    got = LabelRequest.from_bytes(req.to_bytes())
    assert got.group == "pairs" and got.request_id == 42
    assert got.idx.dtype == np.int64
    np.testing.assert_array_equal(got.idx, req.idx)

    empty = LabelRequest.from_bytes(
        LabelRequest("g", np.empty((0, 3), np.int64)).to_bytes()
    )
    assert empty.idx.shape == (0, 3)

    res = LabelResult.from_bytes(
        LabelResult(request_id=42, labels=np.array([1.0, 0.0, 1.0])).to_bytes()
    )
    assert res.ok and res.request_id == 42
    np.testing.assert_array_equal(res.labels, [1.0, 0.0, 1.0])

    err = LabelResult.from_bytes(
        LabelResult(request_id=7, error="RuntimeError: boom").to_bytes()
    )
    assert not err.ok and err.error == "RuntimeError: boom"


def test_remote_execution_bit_identical_to_in_process():
    """A BAS query labelling through a loopback TCP server must produce
    exactly the estimate, CI, and ledger counts of the same query labelling
    in-process — the transport changes where labels execute, nothing else."""
    ds = make_clustered_tables(80, 80, n_entities=120, noise=0.4, seed=11)
    local = ds.oracle()
    q_local = Query(spec=ds.spec(), agg=Agg.COUNT, oracle=local, budget=1200)
    ref = run_bas(q_local, seed=11)

    with OracleServiceServer({"truth": local._label},
                             max_wait_ms=5.0) as server:
        with RemoteOracle(server.address, "truth") as remote:
            q_remote = Query(spec=ds.spec(), agg=Agg.COUNT, oracle=remote,
                             budget=1200)
            got = run_bas(q_remote, seed=11)
            assert got.estimate == ref.estimate
            assert got.ci.lo == ref.ci.lo and got.ci.hi == ref.ci.hi
            assert remote.calls == local.calls
            assert remote.requests == local.requests
        stats = server.service.stats()
    assert stats["rows_labelled"] == local.calls     # server executed it all


def test_remote_flushes_coalesce_across_connections():
    """EXEC segments arriving on different client connections land in shared
    service windows, exactly like attached in-process oracles."""
    with OracleServiceServer({"parity": _parity_fn},
                             max_wait_ms=500.0) as server:
        a = RemoteOracle(server.address, "parity")
        b = RemoteOracle(server.address, "parity")
        for o in (a, b):
            o.bind_sizes((64, 64))
        ba, bb = OracleBatch(a), OracleBatch(b)
        ha = ba.submit(np.array([[0, 1], [2, 3]]))
        hb = bb.submit(np.array([[4, 5], [6, 7], [8, 9]]))
        out = _flush_concurrently([ba, bb])
        assert out == [None, None]
        np.testing.assert_array_equal(ha.labels, [1, 1])
        np.testing.assert_array_equal(hb.labels, [1, 1, 1])
        stats = server.service.stats()
        a.close()
        b.close()
    assert stats["windows"] == 1 and stats["segments"] == 2


def test_server_restart_mid_query_reconnects_without_double_charge():
    """The acceptance scenario: the server dies and is replaced between two
    flushes of one query.  The client's next flush rides the dead connection,
    observes the drop, reconnects, retries — and because the ledger is
    charged client-side only after a successful round trip, the charge is
    exact (no double charge, dedup intact across the restart)."""
    server = OracleServiceServer({"parity": _parity_fn}, max_wait_ms=2.0)
    host, port = server.address
    o = RemoteOracle((host, port), "parity", backoff_s=0.01)
    o.bind_sizes((64, 64))
    o.set_budget(5)
    batch = OracleBatch(o)
    h1 = batch.submit(np.array([[1, 2], [3, 4]]))
    batch.flush()
    np.testing.assert_array_equal(h1.labels, [1, 1])
    assert (o.calls, o.requests) == (2, 2)

    server.close()                                   # the fleet host dies...
    server = OracleServiceServer({"parity": _parity_fn}, host=host,
                                 port=port, max_wait_ms=2.0)  # ...and returns
    try:
        # one duplicate of flush 1 (served from the local cache, never sent)
        # and two new tuples (sent after reconnect)
        h2 = batch.submit(np.array([[3, 4], [5, 6], [7, 8]]))
        batch.flush()
        np.testing.assert_array_equal(h2.labels, [1, 1, 1])
        assert o.conn.reconnects >= 1                # the drop was observed
        assert (o.calls, o.requests) == (4, 5)       # exact charge, no double
        assert o.remaining == 1
    finally:
        o.close()
        server.close()


def test_remote_transport_failure_is_atomic_and_retryable():
    """With no server listening at all, the flush fails with a transport
    error, the batch keeps its pending set, and the oracle is untouched —
    bringing the server up makes the SAME batch succeed."""
    o = RemoteOracle(("127.0.0.1", 1), "parity", retries=1, backoff_s=0.01)
    o.bind_sizes((64, 64))
    batch = OracleBatch(o)
    h = batch.submit(np.array([[1, 2], [3, 4]]))
    with pytest.raises(ConnectionError):
        batch.flush()
    assert len(batch._pending) == 1                  # atomic failure
    assert o.calls == 0 and o.requests == 0

    with OracleServiceServer({"parity": _parity_fn},
                             max_wait_ms=2.0) as server:
        o.conn.address = server.address              # point at the live server
        batch.flush()                                # same batch, now succeeds
        np.testing.assert_array_equal(h.labels, [1, 1])
        assert o.calls == 2
        o.close()


def test_undecodable_exec_payload_gets_error_reply_not_a_drop():
    """A corrupt EXEC payload is a deterministic protocol error: the server
    must answer with an ERROR frame (-> RemoteExecutionError on attempt 1),
    not drop the connection and send the client into a reconnect loop."""
    import socket

    from repro.serve.transport import MSG_ERROR, MSG_EXEC, recv_frame, send_frame

    with OracleServiceServer({"parity": _parity_fn},
                             max_wait_ms=2.0) as server:
        with socket.create_connection(server.address) as sock:
            send_frame(sock, MSG_EXEC, b"\x01\x02garbage")
            mtype, payload = recv_frame(sock)
    assert mtype == MSG_ERROR
    assert "ProtocolError" in LabelResult.from_bytes(payload).error


def test_control_plane_connections_do_not_stall_windows():
    """Connections that never announce query work — PING/GROUPS control
    traffic, or a socket that sends no frame at all — must not count toward
    window assembly: a solo query next to them still dispatches without
    paying the deadline."""
    import socket
    import time

    from repro.serve.transport import ServiceConnection

    with OracleServiceServer({"parity": _parity_fn},
                             max_wait_ms=5000.0) as server:
        mon = ServiceConnection(server.address)
        assert mon.ping()
        assert mon.groups() == ("parity",)
        silent = socket.create_connection(server.address)  # never speaks
        with RemoteOracle(server.address, "parity") as o:
            o.bind_sizes((64, 64))
            t0 = time.perf_counter()
            np.testing.assert_array_equal(
                o.label(np.array([[1, 2], [3, 4]])), [1, 1]
            )
            dt = time.perf_counter() - t0
        mon.close()
        silent.close()
    assert dt < 2.0                                  # far below the deadline


def test_pipelined_execs_on_one_connection_fuse_into_one_window():
    """Request pipelining: two concurrent EXECs on ONE connection must both
    be in flight server-side — i.e. fuse into a single window and a single
    backend call.  Pre-pipelining, the server thread blocked on the first
    EXEC's future before reading the second, which made same-connection
    fusion impossible."""
    from repro.serve.transport import ServiceConnection

    calls = []
    lock = threading.Lock()

    def fn(idx):
        with lock:
            calls.append(np.array(idx))
        return _parity_fn(idx)

    idxs = [np.array([[0, 1], [2, 3]]), np.array([[4, 5], [6, 7]])]
    results = [None, None]
    with OracleServiceServer({"parity": fn}, max_wait_ms=500.0) as server:
        # an announced client that never flushes holds the window open for
        # the full deadline — long enough for both pipelined EXECs to join
        holder = ServiceConnection(server.address, announce=True)
        holder.connect()
        with ServiceConnection(server.address, announce=True) as conn:
            barrier = threading.Barrier(2)

            def go(i):
                barrier.wait()
                results[i] = conn.execute("parity", idxs[i])

            threads = [threading.Thread(target=go, args=(i,))
                       for i in range(2)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
        holder.close()
    assert len(calls) == 1                           # one fused backend call
    assert len(calls[0]) == 4
    for i in range(2):                               # demuxed to the right
        np.testing.assert_array_equal(results[i], idxs[i].sum(1) % 2)


def test_reconnect_backoff_is_capped_and_jittered():
    from repro.serve.transport import ServiceConnection

    c = ServiceConnection(("127.0.0.1", 1), backoff_s=0.05, max_backoff_s=0.2)
    sleeps = [c._backoff(a) for a in range(10)] * 3
    assert all(0 < s <= 0.2 * 1.5 for s in sleeps)   # cap * max jitter
    assert len({round(c._backoff(5), 9) for _ in range(20)}) > 1  # jittered


def test_remote_unknown_group_raises_application_error():
    with OracleServiceServer({"parity": _parity_fn},
                             max_wait_ms=2.0) as server:
        o = RemoteOracle(server.address, "no-such-group")
        o.bind_sizes((64, 64))
        batch = OracleBatch(o)
        batch.submit(np.array([[1, 2]]))
        with pytest.raises(RemoteExecutionError, match="unknown group"):
            batch.flush()
        assert len(batch._pending) == 1 and o.calls == 0
        o.close()


def test_remote_backend_error_reaches_client_and_is_retryable():
    state = {"fail": True}

    def flaky(idx):
        if state["fail"]:
            raise RuntimeError("transient backend error")
        return _parity_fn(idx)

    with OracleServiceServer({"flaky": flaky}, max_wait_ms=2.0) as server:
        o = RemoteOracle(server.address, "flaky")
        o.bind_sizes((64, 64))
        batch = OracleBatch(o)
        h = batch.submit(np.array([[1, 2], [3, 4]]))
        with pytest.raises(RemoteExecutionError, match="transient"):
            batch.flush()
        assert o.calls == 0                          # atomic failure
        state["fail"] = False
        batch.flush()                                # retryable
        np.testing.assert_array_equal(h.labels, [1, 1])
        assert o.calls == 2
        o.close()


def test_super_batches_shard_across_worker_hosts():
    """A front server with a registered worker host splits each super-batch
    across hosts; results are bit-identical to local-only execution."""
    worker_rows, local_rows = [], []
    lock = threading.Lock()

    def worker_fn(idx):
        with lock:
            worker_rows.append(len(idx))
        return _parity_fn(idx)

    def local_fn(idx):
        with lock:
            local_rows.append(len(idx))
        return _parity_fn(idx)

    rng = np.random.default_rng(3)
    idx = np.unique(rng.integers(0, 1000, size=(768, 2)), axis=0)
    with OracleServiceServer({"parity": worker_fn},
                             max_wait_ms=1.0) as worker:
        with OracleServiceServer({"parity": local_fn}, max_wait_ms=1.0,
                                 workers=1, min_shard=64) as front:
            front.register_worker(worker.address)
            with RemoteOracle(front.address, "parity") as o:
                o.bind_sizes((1000, 1000))
                got = o.label(idx)
            stats = front.service.stats()
    np.testing.assert_array_equal(got, idx.sum(1) % 2)
    assert sum(worker_rows) > 0 and sum(local_rows) > 0   # both hosts worked
    assert sum(worker_rows) + sum(local_rows) == len(idx)
    assert stats["remote_shards"] >= 1


def test_capacity_split_proportions_and_order():
    """The capacity-weighted split is contiguous, order-preserving, sized in
    proportion to measured rows/s EWMAs (mean-rate fallback for unmeasured
    executors, uniform when nothing is measured), and never emits an empty
    shard."""
    svc = OracleService(workers=2, max_wait_ms=1.0)
    try:
        idx = np.arange(1000)
        # nothing measured yet -> uniform
        assert [len(p) for p in svc._capacity_split(idx, ["a", "local"])] \
            == [500, 500]
        svc._record_rate("a", 100, 1.0)       # 100 rows/s
        svc._record_rate("local", 300, 1.0)   # 300 rows/s
        parts = svc._capacity_split(idx, ["a", "local"])
        assert [len(p) for p in parts] == [250, 750]
        np.testing.assert_array_equal(np.concatenate(parts), idx)
        # an unmeasured executor is assigned the mean measured rate
        parts = svc._capacity_split(idx, ["a", "b", "local"])
        sizes = [len(p) for p in parts]
        assert sum(sizes) == 1000
        assert abs(sizes[1] - 1000 * 200 / 600) <= 1
        assert sizes[0] < sizes[1] < sizes[2]
        np.testing.assert_array_equal(np.concatenate(parts), idx)
        # one-row floor: a very slow executor still gets a shard
        svc._record_rate("crawl", 1, 1000.0)  # 0.001 rows/s
        parts = svc._capacity_split(idx, ["crawl", "local"])
        assert [len(p) for p in parts] == [1, 999]
        np.testing.assert_array_equal(np.concatenate(parts), idx)
    finally:
        svc.close()


def test_slow_worker_host_gets_smaller_shard_bit_identical():
    """Capacity-weighted sharding (ROADMAP serving item c): after a uniform
    warm-up round measures per-host throughput, a deliberately slow worker
    host receives a proportionally smaller shard — and because the split is
    contiguous and order-preserving, labels stay bit-identical to the
    reference."""
    import time as _time

    worker_shards, local_shards = [], []
    lock = threading.Lock()

    def slow_worker_fn(idx):
        with lock:
            worker_shards.append(len(idx))
        _time.sleep(0.05)                     # a host ~100x slower per row
        return _parity_fn(idx)

    def local_fn(idx):
        with lock:
            local_shards.append(len(idx))
        return _parity_fn(idx)

    rng = np.random.default_rng(7)
    idx1 = np.unique(rng.integers(0, 1000, size=(640, 2)), axis=0)
    idx2 = np.unique(rng.integers(1000, 2000, size=(640, 2)), axis=0)
    with OracleServiceServer({"parity": slow_worker_fn},
                             max_wait_ms=1.0) as worker:
        with OracleServiceServer({"parity": local_fn}, max_wait_ms=1.0,
                                 workers=1, min_shard=64) as front:
            front.register_worker(worker.address)
            with RemoteOracle(front.address, "parity") as o:
                o.bind_sizes((2000, 2000))
                got1 = o.label(idx1)          # uniform warm-up round
                got2 = o.label(idx2)          # capacity-weighted round
            snap = front.service.snapshot()
    np.testing.assert_array_equal(got1, idx1.sum(1) % 2)
    np.testing.assert_array_equal(got2, idx2.sum(1) % 2)
    assert len(worker_shards) == 2 and len(local_shards) == 2
    # warm-up split evenly; the weighted round shrinks the slow host's share
    assert abs(worker_shards[0] - len(idx1) // 2) <= 1
    assert worker_shards[1] < worker_shards[0]
    assert worker_shards[1] < len(idx2) // 2 < local_shards[1]
    assert worker_shards[1] + local_shards[1] == len(idx2)
    # the rates back the snapshot surface: slow host measured slower
    rates = {k: v for k, v in snap.items()
             if k.startswith("service.shard.rate.")}
    assert rates["service.shard.rate.local"] > 0.0
    worker_rate = [v for k, v in rates.items() if k.endswith(
        f":{worker.address[1]}")]
    assert worker_rate and worker_rate[0] < rates["service.shard.rate.local"]


def test_dead_worker_host_degrades_to_local_execution():
    """A worker host that died is unregistered on its first failed shard;
    the shard falls back to local execution — a dead worker costs
    throughput, never a query (the health checker would re-register it
    if the host came back; see test_worker_health_check_reregistration)."""
    worker = OracleServiceServer({"parity": _parity_fn}, max_wait_ms=1.0)
    front = OracleServiceServer({"parity": _parity_fn}, max_wait_ms=1.0,
                                workers=1, min_shard=64)
    try:
        front.register_worker(worker.address)
        worker.close()                               # host dies after joining
        rng = np.random.default_rng(4)
        idx = np.unique(rng.integers(0, 1000, size=(512, 2)), axis=0)
        with RemoteOracle(front.address, "parity") as o:
            o.bind_sizes((1000, 1000))
            got = o.label(idx)
        np.testing.assert_array_equal(got, idx.sum(1) % 2)
        assert front.service.stats()["remote_failures"] >= 1
    finally:
        front.close()


def test_worker_health_check_reregistration():
    """A worker host that dies is marked dead by the health checker; when it
    comes back on the same port it is re-registered automatically (groups
    re-fetched), shards route remotely again, and labels are bit-identical
    across the death/rejoin cycle."""
    import time

    worker = OracleServiceServer({"parity": _parity_fn}, max_wait_ms=1.0)
    port = worker.address[1]
    front = OracleServiceServer({"parity": _parity_fn}, max_wait_ms=1.0,
                                workers=1, min_shard=64, health_check_s=0.05)
    try:
        front.register_worker(worker.address)
        assert front.service.snapshot()["service.worker.live"] == 1.0
        worker.close()                               # host dies

        def wait_for(pred, timeout=10.0):
            deadline = time.monotonic() + timeout
            while time.monotonic() < deadline:
                snap = front.service.snapshot()
                if pred(snap):
                    return snap
                time.sleep(0.02)
            raise AssertionError(f"timeout; last snapshot: {snap}")

        # the background checker notices the death without any query traffic
        snap = wait_for(lambda s: s["service.worker.deaths"] >= 1.0)
        assert snap["service.worker.live"] == 0.0
        assert snap["service.worker.dead"] == 1.0

        rng = np.random.default_rng(11)
        idx = np.unique(rng.integers(0, 1000, size=(512, 2)), axis=0)
        with RemoteOracle(front.address, "parity") as o:
            o.bind_sizes((1000, 1000))
            during = o.label(idx)                    # all-local while dead
        np.testing.assert_array_equal(during, idx.sum(1) % 2)
        shards_before = front.service.stats()["remote_shards"]

        # host restarts on the same port -> checker re-registers it
        worker = OracleServiceServer({"parity": _parity_fn}, port=port,
                                     max_wait_ms=1.0)
        snap = wait_for(lambda s: s["service.worker.rejoins"] >= 1.0)
        assert snap["service.worker.live"] == 1.0
        assert snap["service.worker.dead"] == 0.0

        with RemoteOracle(front.address, "parity") as o:
            o.bind_sizes((1000, 1000))
            after = o.label(idx)
        np.testing.assert_array_equal(after, during)  # bit-identical
        # shards flow to the rejoined host again
        assert front.service.stats()["remote_shards"] > shards_before
    finally:
        worker.close()
        front.close()


# ----------------------------------------------------------------------------
# deadline-based admission control
# ----------------------------------------------------------------------------

def test_admission_sheds_only_over_deadline_class_and_never_charges():
    """Under a saturated queue, only flushes whose declared deadline the
    predicted wait would miss are shed — with a typed, retryable error and
    zero ledger movement.  Deadline-free clients are never shed, and the
    shed client succeeds on retry once the backlog drains."""
    import time

    from repro.obs import InMemoryTracker
    from repro.serve.oracle_service import AdmissionRejected

    def slow_fn(idx):                                # ~1e4 rows/s ceiling
        time.sleep(len(idx) * 1e-4)
        return (idx.sum(axis=1) % 2).astype(np.float64)

    tight, lax = FnOracle(slow_fn), FnOracle(slow_fn)
    tight.bind_sizes((10_000, 10_000))
    lax.bind_sizes((10_000, 10_000))
    tracker = InMemoryTracker()
    with OracleService(workers=1, max_wait_ms=5.0, min_shard=1 << 30,
                       tracker=tracker) as svc:
        svc.attach(tight, deadline_ms=100.0, query_class="tight")
        svc.attach(lax)

        # warmup: admitted (no rate measured yet) and establishes the EWMA
        warm = np.stack([np.arange(100), np.arange(100) + 1], axis=1)
        np.testing.assert_array_equal(tight.label(warm), warm.sum(1) % 2)
        assert tight.calls == len(warm)
        snap = svc.snapshot()
        assert snap["service.rate_rows_per_s"] > 0.0

        # saturate: an 8000-row raw backlog -> predicted wait ~0.8 s
        big = np.stack([np.arange(8000), np.arange(8000) + 1], axis=1)
        bulk = svc.submit_raw("bulk", slow_fn, big)

        small = np.array([[5001, 2], [5002, 7]])  # not in warm (uncached)
        calls_before, charged_before = tight.calls, tight.charged
        with pytest.raises(AdmissionRejected) as ei:
            tight.label(small)                       # predicted >> 100 ms
        assert ei.value.retryable is True
        assert ei.value.qclass == "tight"
        assert ei.value.deadline_ms == 100.0
        assert ei.value.predicted_ms > 100.0
        assert ei.value.queue_rows >= len(big)
        assert tight.calls == calls_before           # zero ledger movement
        assert tight.charged == charged_before

        # the deadline-free client rides out the same backlog un-shed
        np.testing.assert_array_equal(lax.label(small), small.sum(1) % 2)
        assert lax.calls == len(small)

        # recovery: after the backlog drains the same flush is admitted
        np.testing.assert_array_equal(bulk.result(), big.sum(1) % 2)
        deadline = time.monotonic() + 10.0
        while time.monotonic() < deadline:
            try:
                got = tight.label(small)
                break
            except AdmissionRejected:
                time.sleep(0.01)
        else:
            raise AssertionError("shed flush never re-admitted after drain")
        np.testing.assert_array_equal(got, small.sum(1) % 2)
        assert tight.calls == calls_before + len(small)

        snap = svc.snapshot()
        assert snap["service.admission.rejected"] >= 1.0
        assert snap["service.admission.rejected.events"] >= 1.0
        assert "service.class.tight.flush_ms.p50" in snap
    assert tracker.histogram("service.class.default.flush_ms") is not None

def test_slow_class_cannot_shed_fast_class():
    """Per-deadline-class admission budgets: each class predicts its wait
    from its OWN measured EWMA rate.  Regression for the single global-rate
    design, under which a slow tenant's measurements inflated the predicted
    wait of a fast tenant enough to shed it."""
    import time

    def slow_fn(idx):                                # ~1e4 rows/s ceiling
        time.sleep(len(idx) * 1e-4)
        return np.ones(len(idx), np.float64)

    slow, fast = FnOracle(slow_fn), FnOracle(lambda idx: np.ones(len(idx)))
    slow.bind_sizes((10_000, 10_000))
    fast.bind_sizes((10_000, 10_000))
    with OracleService(workers=1, max_wait_ms=5.0, min_shard=1 << 30) as svc:
        svc.attach(slow, deadline_ms=60_000.0, query_class="slow")
        svc.attach(fast, deadline_ms=100.0, query_class="fast")

        # the slow class measures its (terrible) rate into its own EWMA
        warm = np.stack([np.arange(2000), np.arange(2000) + 1], axis=1)
        slow.label(warm)
        with svc._cv:
            global_rate = svc._service_rate
        assert global_rate > 0.0

        # a backlog that, at the slow class's measured rate, predicts far
        # beyond the fast class's 100 ms deadline
        big = np.stack([np.arange(6000), np.arange(6000) + 1], axis=1)
        bulk = svc.submit_raw("bulk", slow_fn, big)

        small = np.array([[7001, 2], [7002, 7]])
        with svc._cv:
            backlog = svc._queued_rows + svc._inflight_rows + len(small)
        # the retired global-rate design would have shed the fast class here
        assert 1e3 * backlog / global_rate > 100.0
        got = fast.label(small)      # per-class rate: fast is unmeasured ->
        np.testing.assert_array_equal(got, np.ones(2))   # admitted
        assert fast.calls == len(small)

        bulk.result()
        snap = svc.snapshot()
        assert snap["service.class.slow.rate_rows_per_s"] > 0.0
        assert snap["service.class.fast.rate_rows_per_s"] > 0.0
        assert snap["service.admission.rejected"] == 0.0
