"""OracleService: cross-query coalescing semantics.

The contract under test: routing any number of concurrent queries through one
service changes *where* labelling executes (shared micro-batched windows on a
worker pool) but nothing about *what* each query computes — estimates are
bit-identical to serial execution, ledgers stay per-query, and one query's
budget exhaustion or backend failure never touches another query's batch.
"""
import threading

import numpy as np
import pytest

from repro.core import Agg, FnOracle, ModelOracle, OracleBatch, Query, run_bas
from repro.core.oracle import BudgetExceeded
from repro.data import make_clustered_tables
from repro.serve.oracle_service import OracleService, serve_queries


def _mk_query(seed, budget=1500, n=100):
    ds = make_clustered_tables(n, n, n_entities=150, noise=0.4, seed=seed)
    return Query(spec=ds.spec(), agg=Agg.COUNT, oracle=ds.oracle(),
                 budget=budget)


# ----------------------------------------------------------------------------
# bit-identical estimates + untouched ledgers
# ----------------------------------------------------------------------------

def test_concurrent_queries_bit_identical_to_serial():
    """Two (and more) queries sharing one OracleService must produce exactly
    the estimates, CIs, and ledger counts of running them serially."""
    seeds = (1, 2, 3, 4)
    serial = []
    for s in seeds:
        q = _mk_query(s)
        res = run_bas(q, seed=s)
        serial.append((res, q.oracle.calls, q.oracle.requests))

    with OracleService(workers=2, max_wait_ms=20.0) as svc:
        queries = [_mk_query(s) for s in seeds]
        svc.attach(*[q.oracle for q in queries])

        def job(q, s):
            try:
                return run_bas(q, seed=s)
            finally:
                svc.detach(q.oracle)

        results = serve_queries(
            svc, [lambda q=q, s=s: job(q, s) for q, s in zip(queries, seeds)]
        )
        stats = svc.stats()

    for (ref, calls, requests), got, q in zip(serial, results, queries):
        assert got.estimate == ref.estimate          # bit-identical
        assert got.ci.lo == ref.ci.lo and got.ci.hi == ref.ci.hi
        assert q.oracle.calls == calls               # same ledger charge
        assert q.oracle.requests == requests
    # and the flushes actually coalesced across queries
    assert stats["segments"] >= 4 * len(seeds)
    assert stats["windows"] < stats["segments"]


def test_budget_exhausted_query_leaves_others_untouched():
    """A query that blows its budget mid-pipeline fails alone; a concurrent
    query in the same service windows is bit-identical to running solo."""
    ok_ref = _mk_query(7)
    ref = run_bas(ok_ref, seed=7)

    with OracleService(max_wait_ms=20.0) as svc:
        # budget 6 < the pilot-stage minimum draw -> BudgetExceeded mid-pipeline
        poor = _mk_query(5, budget=6)
        ok = _mk_query(7)
        svc.attach(poor.oracle, ok.oracle)
        errs = []

        def run_poor():
            try:
                run_bas(poor, seed=5)
            except BudgetExceeded as e:
                errs.append(e)
            finally:
                svc.detach(poor.oracle)

        def run_ok():
            try:
                return run_bas(ok, seed=7)
            finally:
                svc.detach(ok.oracle)

        t = threading.Thread(target=run_poor)
        t.start()
        res = run_ok()
        t.join()

    assert len(errs) == 1                            # poor query failed...
    assert poor.oracle.calls == 0                    # ...charging nothing
    assert res.estimate == ref.estimate              # other query untouched
    assert res.ci.lo == ref.ci.lo and res.ci.hi == ref.ci.hi
    assert ok.oracle.calls == ok_ref.oracle.calls


# ----------------------------------------------------------------------------
# window-level failure isolation + retry
# ----------------------------------------------------------------------------

def _flush_concurrently(batches):
    """Flush all batches from separate threads so they land in one service
    window; returns the futures' exceptions (None for success)."""
    outcomes = [None] * len(batches)
    barrier = threading.Barrier(len(batches))

    def go(i):
        barrier.wait()
        try:
            batches[i].flush_async().result()
        except BaseException as e:  # noqa: BLE001
            outcomes[i] = e

    threads = [threading.Thread(target=go, args=(i,)) for i in range(len(batches))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return outcomes


def _parity_oracle(n=64):
    o = FnOracle(lambda idx: (idx.sum(axis=1) % 2).astype(np.float64))
    o.bind_sizes((n, n))
    return o

def test_budget_failure_isolated_and_retryable_in_one_window():
    a, b = _parity_oracle(), _parity_oracle()
    a.set_budget(2)
    idx_a = np.array([[0, 1], [2, 3], [4, 5]])      # 3 new > budget 2
    idx_b = np.array([[1, 2], [3, 4]])
    with OracleService(max_wait_ms=500.0) as svc:
        svc.attach(a, b)
        ba, bb = OracleBatch(a), OracleBatch(b)
        ha, hb = ba.submit(idx_a), bb.submit(idx_b)
        out = _flush_concurrently([ba, bb])
        assert isinstance(out[0], BudgetExceeded)
        assert out[1] is None
        # b's window-mate failure never reached b
        np.testing.assert_array_equal(hb.labels, idx_b.sum(1) % 2)
        assert b.calls == 2 and b.requests == 2
        # a is untouched and retryable: raise the budget, same batch succeeds
        assert a.calls == 0 and a.requests == 0 and a.batches == 0
        a.set_budget(5)
        ba.flush_async().result()
        np.testing.assert_array_equal(ha.labels, idx_a.sum(1) % 2)
        assert a.calls == 3


def test_backend_error_isolated_and_retryable_in_one_window():
    state = {"fail": True}

    def flaky(idx):
        if state["fail"]:
            raise RuntimeError("transient backend error")
        return (idx.sum(axis=1) % 2).astype(np.float64)

    a = FnOracle(flaky)
    a.bind_sizes((64, 64))
    b = _parity_oracle()
    idx = np.array([[1, 2], [3, 4], [5, 6]])
    with OracleService(max_wait_ms=500.0) as svc:
        svc.attach(a, b)
        ba, bb = OracleBatch(a), OracleBatch(b)
        ha, hb = ba.submit(idx), bb.submit(idx)
        out = _flush_concurrently([ba, bb])
        assert isinstance(out[0], RuntimeError)
        assert out[1] is None
        np.testing.assert_array_equal(hb.labels, idx.sum(1) % 2)
        assert a.calls == 0 and a.batches == 0       # atomic failure
        state["fail"] = False
        ba.flush_async().result()                    # retryable
        np.testing.assert_array_equal(ha.labels, idx.sum(1) % 2)
        assert a.calls == 3


# ----------------------------------------------------------------------------
# cross-query super-batch fusion + worker sharding
# ----------------------------------------------------------------------------

def test_shared_scorer_queries_fuse_into_one_backend_call():
    """ModelOracles scoring through one shared scorer share a service group:
    concurrent flushes fuse into a single backend execution."""
    calls = []

    def scorer(idx):
        calls.append(np.array(idx))
        return (idx.sum(axis=1) % 2).astype(np.float64)

    a = ModelOracle(scorer, threshold=0.5)
    b = ModelOracle(scorer, threshold=0.5)
    for o in (a, b):
        o.bind_sizes((64, 64))
    assert a.service_group() == b.service_group()
    # a scorer *object* with a .score method (the PairScorer shape) must fuse
    # too: ModelOracle stores the bound method, whose id is per-access
    class _Scorer:
        def score(self, idx):
            return np.zeros(len(idx))

    shared = _Scorer()
    assert (ModelOracle(shared).service_group()
            == ModelOracle(shared).service_group())
    assert (ModelOracle(shared).service_group()
            != ModelOracle(_Scorer()).service_group())
    idx_a = np.array([[0, 1], [2, 3]])
    idx_b = np.array([[2, 3], [4, 5]])              # overlaps a; NOT deduped
    with OracleService(max_wait_ms=500.0) as svc:
        svc.attach(a, b)
        ba, bb = OracleBatch(a), OracleBatch(b)
        ha, hb = ba.submit(idx_a), bb.submit(idx_b)
        out = _flush_concurrently([ba, bb])
    assert out == [None, None]
    assert len(calls) == 1                           # one fused super-batch
    assert len(calls[0]) == 4                        # ledgers stay per-query:
    assert a.calls == 2 and b.calls == 2             # no cross-oracle dedup
    np.testing.assert_array_equal(ha.labels, idx_a.sum(1) % 2)
    np.testing.assert_array_equal(hb.labels, idx_b.sum(1) % 2)


def test_worker_pool_shards_large_flushes():
    sizes = []
    lock = threading.Lock()

    def fn(idx):
        with lock:
            sizes.append(len(idx))
        return (idx.sum(axis=1) % 2).astype(np.float64)

    o = FnOracle(fn)
    o.bind_sizes((1000, 1000))
    rng = np.random.default_rng(0)
    idx = np.unique(rng.integers(0, 1000, size=(4096, 2)), axis=0)
    with OracleService(workers=4, min_shard=256, max_wait_ms=1.0) as svc:
        svc.attach(o)
        got = o.label(idx)
    assert len(sizes) == 4                           # sharded over the pool
    assert sum(sizes) == len(idx)
    np.testing.assert_array_equal(got, idx.sum(1) % 2)


def test_solo_client_dispatches_without_deadline_wait():
    """With every attached client already in the window there is nobody to
    wait for: a solo query must not pay the windowing deadline."""
    import time

    o = _parity_oracle()
    with OracleService(max_wait_ms=5000.0) as svc:
        svc.attach(o)
        t0 = time.perf_counter()
        o.label(np.array([[1, 2], [3, 4]]))
        dt = time.perf_counter() - t0
    assert dt < 2.0                                  # far below the deadline


def test_detached_oracle_flushes_locally_again():
    o = _parity_oracle()
    svc = OracleService(max_wait_ms=1.0)
    svc.attach(o)
    assert o.service is svc
    svc.detach(o)
    assert o.service is None
    np.testing.assert_array_equal(
        o.label(np.array([[1, 2]])), [1.0]
    )
    svc.close()


def test_submit_after_close_raises_and_restores_pending():
    o = _parity_oracle()
    svc = OracleService(max_wait_ms=1.0)
    svc.attach(o)
    svc.close()
    batch = OracleBatch(o)
    batch.submit(np.array([[1, 2]]))
    with pytest.raises(RuntimeError):
        batch.flush_async()
    assert len(batch._pending) == 1                  # retryable after detach
    o.service = None
    batch.flush()
    assert o.calls == 1
