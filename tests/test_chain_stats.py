"""One-pass chain statistics: the fused sweep's compensated walk sums.

Contract under test (kernels/sim_sweep, "one-pass chain statistics"): the
single blocked sweep additionally emits per-row walk sums and the chain
total weight, accumulated in compensated (two-float) f32 — and those agree
with the f64 numpy reference to 1e-6 relative even on adversarial magnitude
spreads, for both the Pallas kernel (interpret on CPU) and the blocked
numpy fallback.  Downstream: walk setup consumes the fused statistics, so a
warm-index (or cold fused-sweep) streaming query launches ZERO standalone
passes over the cross product — asserted via the pass-launch counters in
``repro.core.similarity.PASS_COUNTS``.

The property sweep runs over a deterministic seeded grid always; when
``hypothesis`` is installed the same check also runs under ``@given`` draws
(exponent/floor/shape/spread), widening coverage without adding a
dependency.
"""
import numpy as np
import pytest

from repro.core import similarity
from repro.core.similarity import (
    chain_total_weight,
    edge_row_sums_raw,
    pair_weights,
)
from repro.core.stratify import sweep_pass, sweep_pass_chain
from repro.kernels.sim_sweep.ops import sim_sweep

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # property sweep falls back to the seeded grid only
    HAVE_HYPOTHESIS = False

REL_TOL = 1e-6


def _unit_rows(rng, n, d):
    e = rng.normal(size=(n, d)).astype(np.float32)
    return e / np.linalg.norm(e, axis=1, keepdims=True)


def _spread_v(rng, n, decades=4.0):
    """A backward vector spanning ~10**(2*decades) in magnitude — the
    adversarial summand spread naive f32 accumulation cannot absorb."""
    return (10.0 ** rng.uniform(-decades, decades, n)).astype(np.float32)


def _check_fused_pair_sums(seed, n1, n2, d, exponent, floor, decades):
    """Fused kernel sums vs the f64 reference, one pair sweep."""
    rng = np.random.default_rng(seed)
    e1, e2 = _unit_rows(rng, n1, d), _unit_rows(rng, n2, d)
    v = _spread_v(rng, n2, decades)
    out = sim_sweep(e1, e2, n_bins=64, exponent=exponent, floor=floor,
                    block=64, back_v=v)
    w64 = pair_weights(e1, e2, exponent, floor)
    ref = (w64 * v.astype(np.float64)).sum(axis=1)
    np.testing.assert_allclose(out.row_sums, ref, rtol=REL_TOL)


PAIR_GRID = [
    (0, 50, 70, 16, 1.0, 1e-3, 0.0),
    (1, 33, 190, 32, 2.5, 1e-2, 2.0),
    (2, 130, 65, 48, 4.0, 1e-4, 4.0),
    (3, 7, 260, 8, 3.0, 1e-3, 4.0),
    (4, 64, 64, 24, 1.5, 1e-2, 3.0),
]


@pytest.mark.parametrize("case", PAIR_GRID, ids=lambda c: f"seed{c[0]}")
def test_fused_kernel_sums_match_f64_seeded(case):
    _check_fused_pair_sums(*case)


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        n1=st.integers(3, 140),
        n2=st.integers(3, 270),
        d=st.integers(4, 48),
        exponent=st.floats(0.5, 4.0),
        floor=st.floats(1e-4, 1e-1),
        decades=st.floats(0.0, 4.0),
    )
    def test_fused_kernel_sums_match_f64_property(seed, n1, n2, d, exponent,
                                                  floor, decades):
        _check_fused_pair_sums(seed, n1, n2, d, exponent, floor, decades)


def _check_fallback_pair_sums(seed, n1, n2, d, exponent, floor):
    """Numpy-fallback sweep_pass emits the same statistics contract."""
    rng = np.random.default_rng(seed)
    e1, e2 = _unit_rows(rng, n1, d), _unit_rows(rng, n2, d)
    info = sweep_pass(e1, e2, n_bins=64, exponent=exponent, floor=floor,
                      block=64, use_kernel=False)
    ref = pair_weights(e1, e2, exponent, floor).sum(axis=1)
    np.testing.assert_allclose(info.row_sums[0], ref, rtol=REL_TOL)
    assert info.total_weight == pytest.approx(float(ref.sum()), rel=REL_TOL)


@pytest.mark.parametrize("case", PAIR_GRID, ids=lambda c: f"seed{c[0]}")
def test_fallback_sums_match_f64_seeded(case):
    _check_fallback_pair_sums(*case[:6])


@pytest.mark.parametrize("use_kernel", [True, False])
@pytest.mark.parametrize("k", [2, 3])
def test_chain_sweep_sums_match_f64(use_kernel, k):
    """k-way chain: every per-edge row-sum vector and the chain total
    emitted by the fused sweep agree with the standalone f64 recomputation
    — kernel and fallback paths."""
    rng = np.random.default_rng(17 + k)
    sizes = [60, 70, 50][:k]
    embeddings = [_unit_rows(rng, n, 32) for n in sizes]
    exponent, floor = 2.0, 1e-3
    info = sweep_pass_chain(embeddings, n_bins=64, exponent=exponent,
                            floor=floor, block=64, use_kernel=use_kernel)
    refs = edge_row_sums_raw(embeddings, exponent, floor)
    assert info.row_sums is not None and len(info.row_sums) == k - 1
    for got, ref in zip(info.row_sums, refs):
        np.testing.assert_allclose(got, ref, rtol=REL_TOL)
    ref_total = chain_total_weight(embeddings, exponent, floor)
    assert info.total_weight == pytest.approx(ref_total, rel=REL_TOL)


def test_naive_f32_fails_where_compensated_passes():
    """The regression the compensated accumulator exists for: one large
    summand followed by thousands of small ones.  A running f32 sum loses
    the entire small mass (each add rounds to nothing against the large
    partial); the kernel's two-float pairwise reduction keeps it."""
    import jax.numpy as jnp

    from repro.kernels.sim_sweep.kernel import comp_block_sum

    vals = np.ones(4096, np.float32)
    vals[0] = np.float32(1e8)
    ref = vals.astype(np.float64).sum()

    naive = np.float32(0.0)
    for x in vals:
        naive = np.float32(naive + x)
    assert abs(float(naive) - ref) / ref > REL_TOL     # naive f32 fails

    hi, lo = comp_block_sum(jnp.asarray(vals)[None, :])
    comp = float(np.asarray(hi)[0, 0]) + float(np.asarray(lo)[0, 0])
    assert abs(comp - ref) / ref < 1e-9                # compensated passes


def test_fused_sweep_absorbs_adversarial_back_vector():
    """End-to-end version of the regression: the same large/small spread
    arriving through the backward chain vector still meets the 1e-6 rel
    contract inside the fused sweep (cross-block carry is compensated too).
    """
    rng = np.random.default_rng(9)
    e1, e2 = _unit_rows(rng, 40, 16), _unit_rows(rng, 1500, 16)
    v = np.ones(1500, np.float32)
    v[0] = np.float32(1e8)
    out = sim_sweep(e1, e2, n_bins=64, exponent=1.0, floor=1e-3,
                    block=64, back_v=v)
    ref = (pair_weights(e1, e2, 1.0, 1e-3) * v.astype(np.float64)).sum(axis=1)
    np.testing.assert_allclose(out.row_sums, ref, rtol=REL_TOL)


# ----------------------------------------------------------------------------
# zero standalone passes: walk setup consumes the fused statistics
# ----------------------------------------------------------------------------

def _small_query(budget=900):
    from repro.core import Agg, Query
    from repro.data import make_clustered_tables

    ds = make_clustered_tables(150, 150, n_entities=80, noise=0.4, seed=5)
    return Query(spec=ds.spec(), agg=Agg.COUNT, oracle=ds.oracle(),
                 budget=budget)


def _pass_delta(fn):
    before = dict(similarity.PASS_COUNTS)
    result = fn()
    return result, {k: similarity.PASS_COUNTS[k] - before[k]
                    for k in before}


def test_cold_fused_query_launches_zero_standalone_passes():
    from repro.core.bas_streaming import run_bas_streaming

    r, delta = _pass_delta(lambda: run_bas_streaming(_small_query(), seed=0))
    assert delta == {"edge_row_sums": 0, "chain_total_weight": 0}
    assert r.telemetry.stratify.extra["walk_setup"] == "fused"


def test_warm_index_query_launches_zero_standalone_passes(tmp_path):
    from repro.core import IndexStore
    from repro.core.bas_streaming import run_bas_streaming

    store = IndexStore(root=tmp_path)
    # cold build populates the store (and computes sums inside the sweep)
    r_cold, delta_cold = _pass_delta(
        lambda: run_bas_streaming(_small_query(), seed=0, index_store=store)
    )
    assert delta_cold == {"edge_row_sums": 0, "chain_total_weight": 0}
    # warm hit: statistics hydrate from the artifact — no sweep, no passes
    r_warm, delta_warm = _pass_delta(
        lambda: run_bas_streaming(_small_query(), seed=0, index_store=store)
    )
    assert delta_warm == {"edge_row_sums": 0, "chain_total_weight": 0}
    assert r_warm.telemetry.index.hit is True
    assert r_warm.telemetry.stratify.extra["walk_setup"] == "fused"
    assert r_warm.estimate == r_cold.estimate


def test_two_pass_baseline_still_counts_passes():
    """The counter itself works: the retired two-pass schedule
    (use_sweep=False) launches both standalone passes."""
    from repro.core.bas_streaming import run_bas_streaming

    r, delta = _pass_delta(
        lambda: run_bas_streaming(_small_query(), seed=0, use_sweep=False)
    )
    assert delta["edge_row_sums"] >= 1
    assert delta["chain_total_weight"] >= 1
    assert r.telemetry.stratify.extra["walk_setup"] == "recompute"


# ----------------------------------------------------------------------------
# persistence: sums survive save/load and O(delta) append maintenance
# ----------------------------------------------------------------------------

def test_index_persists_and_appends_fused_sums(tmp_path):
    from repro.checkpoint.index_io import load_index, save_index
    from repro.core.index import append_rows, build_index

    rng = np.random.default_rng(3)
    e1, e2 = _unit_rows(rng, 60, 24), _unit_rows(rng, 75, 24)
    art = build_index([e1, e2], n_bins=64, exponent=1.5, floor=1e-2,
                      block=64)
    assert art.row_sums is not None and art.total_weight is not None

    # save/load round-trip is exact
    save_index(tmp_path / "idx", art)
    back = load_index(tmp_path / "idx", art.key)
    np.testing.assert_array_equal(back.row_sums[0], art.row_sums[0])
    assert back.total_weight == art.total_weight

    # O(delta) append maintenance matches a fresh cold build to 1e-6
    d1, d2 = _unit_rows(rng, 17, 24), _unit_rows(rng, 11, 24)
    grown = append_rows(art, 0, d1)
    grown = append_rows(grown, 1, d2)
    fresh = build_index([np.vstack([e1, d1]), np.vstack([e2, d2])],
                        n_bins=64, exponent=1.5, floor=1e-2, block=64)
    np.testing.assert_allclose(grown.row_sums[0], fresh.row_sums[0],
                               rtol=REL_TOL)
    assert grown.total_weight == pytest.approx(fresh.total_weight,
                                               rel=REL_TOL)


# ----------------------------------------------------------------------------
# autotuner: compiled-only, cached on disk, routed into the ops
# ----------------------------------------------------------------------------

def test_autotune_schedule(tmp_path, monkeypatch):
    from repro.kernels import autotune

    autotune.reset()
    try:
        # CPU / interpret mode: no measurement, no behaviour change
        assert autotune.schedule("sim_sweep", 512, 512, 32,
                                 backend="cpu") is None

        calls = []

        def fake_measure(op, m, n, d, precision, candidates):
            calls.append((op, m, n, d, precision, tuple(candidates)))
            return candidates[-1]

        monkeypatch.setattr(autotune, "_measure", fake_measure)
        autotune.configure(tmp_path / "autotune.json")

        won = autotune.schedule("sim_sweep", 300, 500, 32, backend="tpu")
        assert won in autotune.CANDIDATES
        assert len(calls) == 1
        # same shape bucket: served from memory, no re-measurement
        assert autotune.schedule("sim_sweep", 280, 510, 32,
                                 backend="tpu") == won
        assert len(calls) == 1

        # the winner persisted — a fresh process (reset) rereads the disk
        # cache without measuring again
        autotune.reset()
        autotune.configure(tmp_path / "autotune.json")
        assert autotune.schedule("sim_sweep", 300, 500, 32,
                                 backend="tpu") == won
        assert len(calls) == 1
    finally:
        autotune.reset()


def test_index_store_configures_autotune_cache(tmp_path, monkeypatch):
    """Opening a persistent IndexStore points the autotune disk cache next
    to the index artifacts, so tuned schedules ship with the store."""
    from repro.core import IndexStore
    from repro.kernels import autotune

    autotune.reset()
    try:
        monkeypatch.setattr(autotune, "_measure",
                            lambda *a: autotune.CANDIDATES[0])
        IndexStore(root=tmp_path)
        assert autotune.schedule("sim_sweep", 128, 128, 32,
                                 backend="tpu") == autotune.CANDIDATES[0]
        assert (tmp_path / "autotune.json").exists()
    finally:
        autotune.reset()
