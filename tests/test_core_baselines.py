import numpy as np
import pytest

from repro.core import (
    Agg,
    Query,
    calibrate_threshold,
    run_abae,
    run_blazeit,
    run_blocking,
    run_uniform,
    run_wwj,
)
from repro.data import make_clustered_tables, make_syn_scores


@pytest.fixture(scope="module")
def ds():
    return make_clustered_tables(200, 200, n_entities=250, noise=0.4, seed=11)


def _q(ds, budget=3000, agg=Agg.COUNT, g=None):
    return Query(spec=ds.spec(), agg=agg, oracle=ds.oracle(), budget=budget, g=g)


def test_uniform_unbiased_ish(ds):
    truth = float(ds.truth.sum())
    ests = [run_uniform(_q(ds), seed=s).estimate for s in range(10)]
    assert abs(np.mean(ests) - truth) / truth < 0.35


def test_wwj_close(ds):
    truth = float(ds.truth.sum())
    res = run_wwj(_q(ds, budget=4000), seed=0)
    assert abs(res.estimate - truth) / truth < 0.5
    assert res.ci.lo <= res.estimate <= res.ci.hi


def test_wwj_flat_weights_mode():
    ds = make_syn_scores(200, 200, selectivity=5e-3, seed=5)
    truth = float(ds.truth.sum())
    q = Query(spec=ds.spec(), agg=Agg.COUNT, oracle=ds.oracle(), budget=3000)
    res = run_wwj(q, seed=0, weights=ds.weights_override)
    assert abs(res.estimate - truth) / truth < 0.4


def test_blocking_biased_under_false_negatives():
    """The paper's Fig. 2/5 failure mode: with FNR, blocking underestimates
    systematically and its CI misses the truth."""
    ds = make_syn_scores(300, 300, selectivity=5e-3, fnr=0.05, fpr=0.0, seed=9)
    truth = float(ds.truth.sum())
    w = ds.weights_override
    # calibrate on a disjoint validation dataset with the same construction
    val = make_syn_scores(300, 300, selectivity=5e-3, fnr=0.05, fpr=0.0, seed=10)
    tau = calibrate_threshold(val.weights_override, val.truth_flat(), 0.9)
    ests, misses = [], 0
    for seed in range(5):
        q = Query(spec=ds.spec(), agg=Agg.COUNT, oracle=ds.oracle(), budget=20000)
        r = run_blocking(q, threshold=tau, seed=seed, weights=w)
        ests.append(r.estimate)
        misses += not r.ci.contains(truth)
    # estimates converge below the truth: bias ≈ share of positives under tau
    # (the calibration leaves ~10% of positives below the threshold)
    assert np.mean(ests) < truth * 0.97
    # and the CI is invalid — it misses the truth far more often than 5%
    assert misses >= 3


def test_abae_and_blazeit_run(ds):
    truth = float(ds.truth.sum())
    ra = run_abae(_q(ds, budget=4000), seed=0)
    rb = run_blazeit(_q(ds, budget=4000), seed=0)
    for r in (ra, rb):
        assert np.isfinite(r.estimate)
        assert r.oracle_calls <= 4000
        assert abs(r.estimate - truth) / truth < 2.0


def test_blazeit_variance_not_worse_than_uniform():
    ds = make_clustered_tables(150, 150, n_entities=40, noise=0.35, seed=3)
    truth = float(ds.truth.sum())
    uni = [run_uniform(_q(ds, budget=2000), seed=s).estimate for s in range(12)]
    blz = [run_blazeit(_q(ds, budget=2000), seed=s).estimate for s in range(12)]
    rmse_u = np.sqrt(np.mean((np.array(uni) - truth) ** 2))
    rmse_b = np.sqrt(np.mean((np.array(blz) - truth) ** 2))
    assert rmse_b <= rmse_u * 1.3  # control variates shouldn't hurt much
